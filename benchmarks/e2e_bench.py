"""End-to-end compiled-plan throughput (the per-PR Table-4 analogue).

For each benchmarked topology — the three paper nets plus the generalized
non-paper ones (cifar10_full: overlapping 3x3/stride-2 pool;
cifar10_strided: stride-2 downsampling convs) — lower a full plan through
``compile_dhm`` (the single lowering path everything routes through)
twice — fp32 and at the selected bit-width (weights + in-kernel
feature-stream quantization) — and measure frames/sec of the whole plan:
fused conv stages + FC head. The rows land in ``BENCH_kernels.json``
alongside the kernel micro-benchmarks, so the end-to-end throughput
trajectory is recorded per PR, not just the isolated kernel times.
"""
from __future__ import annotations

import time

import jax

from repro.core.dhm.compiler import QuantSpec, compile_dhm
from repro.models.cnn import ALL_TOPOLOGIES, init_cnn

# Paper bit-widths (Table 3): 3 bits LeNet5, 6 bits Cifar10/SVHN; the
# non-paper Cifar10 variants inherit the Cifar10 regime.
PAPER_BITS = {
    "lenet5": 3, "cifar10": 6, "svhn": 6,
    "cifar10_full": 6, "cifar10_strided": 6,
}
BATCH = 8


def _time(fn, *args, reps=10, passes=3):
    """Best-of-``passes`` timing (each pass averages ``reps`` calls), so
    the recorded per-PR trajectory reflects the achievable rate rather
    than scheduler noise on a shared machine. Every rep blocks on its own
    output: with only the last rep blocked, JAX's async dispatch overlaps
    host-side dispatch of rep i+1 with device execution of rep i and the
    per-call latency under-reports."""
    fn(*args).block_until_ready()  # compile
    best = float("inf")
    for _ in range(passes):
        t0 = time.time()
        for _ in range(reps):
            fn(*args).block_until_ready()
        best = min(best, (time.time() - t0) / reps * 1e6)
    return best


def run() -> list:
    rows = []
    for name in (
        "lenet5", "cifar10", "svhn", "cifar10_full", "cifar10_strided"
    ):
        topo = ALL_TOPOLOGIES[name]
        bits = PAPER_BITS[name]
        params = init_cnn(jax.random.PRNGKey(0), topo)
        h_in, w_in = topo.input_shape
        x = jax.random.normal(
            jax.random.PRNGKey(1),
            (BATCH, h_in, w_in, topo.input_channels),
        )
        variants = (
            ("fp32", QuantSpec()),
            ("quant", QuantSpec(weight_bits=bits, act_bits=bits)),
        )
        for label, quant in variants:
            plan = compile_dhm(topo, params, quant=quant)
            fwd = jax.jit(lambda xb, p=plan: p(xb))
            us = _time(fwd, x)
            fps = BATCH / (us * 1e-6)
            gops = topo.feature_extractor_ops() * fps / 1e9
            qdesc = (
                "fp32"
                if label == "fp32"
                else f"w{bits}b + in-kernel act{bits}b stream quant"
            )
            rows.append(
                {
                    "name": f"e2e/{name}_{label}_plan",
                    "us_per_call": us,
                    "path": f"e2e_{label}",
                    "frames_per_s": fps,
                    "derived": (
                        f"{fps:.0f} frames/s ({gops:.2f} effective Gop/s) "
                        f"for the full compiled plan (batch={BATCH}, "
                        f"{qdesc}, fused stages + FC head)"
                    ),
                }
            )
    return rows


if __name__ == "__main__":
    for r in run():
        print(r["name"], "|", f"{r['us_per_call']:.1f}us", "|", r["derived"])
