"""End-to-end compiled-plan throughput (the per-PR Table-4 analogue).

For each benchmarked topology — the three paper nets plus the generalized
non-paper ones (cifar10_full: overlapping 3x3/stride-2 pool;
cifar10_strided: stride-2 downsampling convs) — lower a full plan through
``compile_dhm`` (the single lowering path everything routes through)
twice per quantization variant (fp32, fake-quant at the paper bitwidths,
and ``int8`` — the true-integer compute path, whose fused row carries
``int8_speedup`` vs the fp32 fused plan plus the dtype-aware fusion
widening probe fields):

- the **fused** plan (default VMEM budget): the feature extractor runs as
  cross-layer fusion groups — one fused pyramid kernel per group, with
  inter-layer feature slabs kept on-chip;
- the **per-layer** plan (``vmem_budget=0``): today's pre-fusion baseline,
  one kernel call per conv layer with every intermediate feature map
  round-tripping through memory.

Both execute through the plan's cached end-to-end jitted closure
(``CompiledDHM.__call__``), so the comparison isolates the fusion
decision, and both rows land in ``BENCH_kernels.json`` — the fused row
carries ``fusion_speedup`` vs its per-layer twin. After timing, the
benchmark asserts the plan never retraced across reps (the jit cache
holds exactly one entry).

Two more row families measure the SPATIAL pipeline on a multi-device
``(stage, data)`` host-platform mesh (device counts must be forced before
JAX initializes, so both are measured in one subprocess —
``python -m benchmarks.e2e_bench --pipelined-json --handoff <npz>`` with
``--xla_force_host_platform_device_count=8``):

- ``path: pipeline_sweep`` — the µbatch/batch-grain crossover sweep:
  cifar10 and svhn fp32 across (n_microbatches, grain, overlap) configs,
  each row carrying its config fields + ``pipeline_speedup``. These rows
  are what ``throughput.fit_constants`` / ``autotune_pipeline`` consume
  from ``BENCH_history.jsonl``.
- ``path: e2e_pipelined`` — every (topology, precision) served through
  the ``Engine`` at the configuration the measurement-driven autotuner
  picked (measured sweep points outrank the fitted cost model), logits
  verified and ``pipeline_speedup`` recorded vs the single-device plan.

The single-device references (logits + frames/s per group size) are
measured ONCE in the main process and handed to the subprocess as an
``.npz`` file — the subprocess never recompiles or re-runs the reference
plan.
"""
from __future__ import annotations

import json
import os
import pathlib
import subprocess
import sys
import tempfile
import time

import jax
import numpy as np

from repro.core.dhm.compiler import QuantSpec, compile_dhm
from repro.models.cnn import ALL_TOPOLOGIES, init_cnn

# Paper bit-widths (Table 3): 3 bits LeNet5, 6 bits Cifar10/SVHN; the
# non-paper Cifar10 variants inherit the Cifar10 regime.
PAPER_BITS = {
    "lenet5": 3, "cifar10": 6, "svhn": 6,
    "cifar10_full": 6, "cifar10_strided": 6,
}
BATCH = 8
PIPE_TOPOS = ("lenet5", "cifar10", "svhn", "cifar10_full", "cifar10_strided")
# Group sizes (frames per engine dispatch) the pipelined paths may use;
# the main process pre-measures the single-device reference at each.
PIPE_GROUPS = (32, 64, 128, 256)
# The crossover sweep: (n_microbatches, batch grain, overlap) on a
# (3 stage x 2 data) mesh for the two paper CNNs with 3 conv layers.
SWEEP_TOPOS = ("cifar10", "svhn")
SWEEP_GRID = (
    (2, 16, False),
    (4, 16, False),
    (8, 16, False),
    (4, 32, False),
    (8, 32, False),
    (4, 32, True),  # overlapped-collective point: records the crossover
)


def _stages_of(name: str) -> int:
    return min(3, len(ALL_TOPOLOGIES[name].conv_layers))


def _pipe_input(name: str, group: int) -> np.ndarray:
    """Deterministic input frames shared by the main process (reference)
    and the mesh subprocess (pipelined runs) — numpy RNG, so the two
    processes agree bit-for-bit without shipping the arrays."""
    topo = ALL_TOPOLOGIES[name]
    h, w = topo.input_shape
    rng = np.random.RandomState(1)
    return rng.standard_normal(
        (group, h, w, topo.input_channels)
    ).astype(np.float32)


def _time(fn, *args, reps=10, passes=3):
    """Best-of-``passes`` timing (each pass averages ``reps`` calls), so
    the recorded per-PR trajectory reflects the achievable rate rather
    than scheduler noise on a shared machine. Every rep blocks on its own
    output: with only the last rep blocked, JAX's async dispatch overlaps
    host-side dispatch of rep i+1 with device execution of rep i and the
    per-call latency under-reports. ``jax.block_until_ready`` (not the
    array method) so callables that hand back host numpy — e.g. the
    serving Engine, which packs and scatters batches host-side — time
    the same way as device-array producers."""
    jax.block_until_ready(fn(*args))  # compile
    best = float("inf")
    for _ in range(passes):
        t0 = time.time()
        for _ in range(reps):
            jax.block_until_ready(fn(*args))
        best = min(best, (time.time() - t0) / reps * 1e6)
    return best


def _measure_plan(plan, x):
    """us/call through the plan's cached jitted closure, asserting the
    closure never retraces across reps."""
    us = _time(plan, x)
    fwd = plan.jitted_forward()
    n_traces = fwd._cache_size()
    assert n_traces == 1, (
        f"plan retraced across reps: jit cache holds {n_traces} entries"
    )
    return us


def _write_handoff(plans: dict, path: str) -> None:
    """Measure the single-device reference ONCE per (topology, precision,
    group size) — logits + frames/s — and save it for the mesh
    subprocess, which must never recompile the reference plan. Called
    after ``_measure_plan`` has already asserted the no-retrace invariant
    at the e2e batch (these extra shapes legitimately add cache entries)."""
    blobs = {}
    for (name, label), plan in plans.items():
        for g in PIPE_GROUPS:
            x = _pipe_input(name, g)
            us = _time(plan, x, reps=3, passes=2)
            blobs[f"{name}|{label}|{g}|ref"] = np.asarray(plan(x))
            blobs[f"{name}|{label}|{g}|fps"] = np.float64(g / (us * 1e-6))
    np.savez(path, **blobs)


def _mesh_logits_fn(plan, mesh, cfg, n_microbatches, microbatch):
    """The raw pipelined logits closure (runner + FC head as one jitted
    computation) used by the sweep — the serving Engine adds host-side
    batching on top; the sweep prices the pipeline itself."""
    from repro.core.dhm.engine import build_plan_pipeline

    runner = build_plan_pipeline(
        plan, mesh=mesh, cfg=cfg, microbatch=microbatch
    )

    def _fwd(leaves, frames):
        mbs = frames.reshape(
            (n_microbatches, microbatch) + frames.shape[1:]
        )
        feats = runner.apply(leaves, mbs)
        flat = feats.reshape(
            (n_microbatches * microbatch,) + feats.shape[2:]
        )
        return plan.head_fn(flat)

    fjit = jax.jit(_fwd)
    return lambda frames: fjit(runner.stacked_leaves, frames), runner


def _sweep_rows_here(handoff) -> list:
    """The ``path: pipeline_sweep`` rows: the µbatch/grain crossover for
    the sweep topologies, each point verified against the pre-measured
    single-device logits and stamped with its full configuration (these
    rows are the autotuner's measurement source)."""
    from repro.core.dhm.pipeline import PipelineConfig

    rows = []
    for name in SWEEP_TOPOS:
        topo = ALL_TOPOLOGIES[name]
        params = init_cnn(jax.random.PRNGKey(0), topo)
        S = _stages_of(name)
        data = 2
        mesh = jax.make_mesh((S, data), ("stage", "data"))
        plan = compile_dhm(topo, params, n_stages=S)
        for M, mb, overlap in SWEEP_GRID:
            group = M * mb
            cfg = PipelineConfig(
                S, M, data_axis="data", overlap=overlap, edge_mode="auto"
            )
            fn, runner = _mesh_logits_fn(plan, mesh, cfg, M, mb)
            x = _pipe_input(name, group)
            got = np.asarray(fn(x))
            ref = handoff[f"{name}|fp32|{group}|ref"]
            assert np.allclose(got, ref, rtol=1e-4, atol=1e-4), (
                f"{name} sweep M={M} mb={mb} overlap={overlap}: "
                f"pipelined logits diverge from single-device"
            )
            us = _time(fn, x, reps=3, passes=2)
            fps = group / (us * 1e-6)
            fps_single = float(handoff[f"{name}|fp32|{group}|fps"])
            tag = f"M{M}x{mb}" + ("_ov" if overlap else "")
            rows.append(
                {
                    "name": f"e2e/{name}_fp32_sweep_{tag}",
                    "us_per_call": us,
                    "path": "pipeline_sweep",
                    "topology": name,
                    "label": "fp32",
                    "n_stages": S,
                    "n_microbatches": M,
                    "microbatch": mb,
                    "data": data,
                    "overlap": overlap,
                    "edge_mode": "auto",
                    "edge_path": runner.edge_plan.mode,
                    "frames_per_s": fps,
                    "pipeline_speedup": fps / fps_single,
                    "derived": (
                        f"{fps:.0f} frames/s sweep point ({M}x{mb}-frame "
                        f"groups, data={data}, "
                        f"{'overlapped' if overlap else 'serial'} schedule, "
                        f"{runner.edge_plan.mode} edges): "
                        f"x{fps / fps_single:.2f} vs single-device "
                        f"({fps_single:.0f} frames/s)"
                    ),
                }
            )
    return rows


def _pipelined_rows_here(handoff_path: str) -> list:
    """Measure the pipelined serving rows IN THIS PROCESS (requires a
    multi-device backend — the subprocess entry below forces 8 host
    devices): first the crossover sweep, then every topology through the
    ``Engine`` at the configuration the autotuner picked from the sweep.
    Single-device references come from the handoff file — nothing is
    recompiled here."""
    from repro.core.dhm.engine import Engine
    from repro.core.dhm.throughput import (
        autotune_pipeline, fit_constants, sweep_sample,
    )

    handoff = dict(np.load(handoff_path))
    n_dev = len(jax.devices())
    rows = _sweep_rows_here(handoff)
    sweep_rows = list(rows)

    # Fit the machine constants (FLOP/s, bytes/s, tick overhead) from the
    # measured serial sweep points — they are topology-independent, so
    # the un-swept topologies get model-tuned with measured constants.
    sweep_plans = {}
    samples = []
    for name in SWEEP_TOPOS:
        topo = ALL_TOPOLOGIES[name]
        params = init_cnn(jax.random.PRNGKey(0), topo)
        sweep_plans[name] = compile_dhm(
            topo, params, n_stages=_stages_of(name)
        )
    for r in sweep_rows:
        samples.append(
            sweep_sample(
                sweep_plans[r["topology"]],
                n_microbatches=r["n_microbatches"],
                microbatch=r["microbatch"],
                data=r["data"],
                frames_per_s=r["frames_per_s"],
                overlap=r["overlap"],
                edge_mode=r["edge_mode"],
            )
        )
    constants = fit_constants(samples)

    for name in PIPE_TOPOS:
        topo = ALL_TOPOLOGIES[name]
        bits = PAPER_BITS[name]
        S = _stages_of(name)
        data = max(1, n_dev // S)
        if S * data > n_dev:
            raise RuntimeError(
                f"pipelined bench needs {S * data} devices, have {n_dev}"
            )
        params = init_cnn(jax.random.PRNGKey(0), topo)
        mesh = jax.make_mesh((S, data), ("stage", "data"))
        for label, quant in (
            ("fp32", QuantSpec()),
            ("quant", QuantSpec(weight_bits=bits, act_bits=bits)),
        ):
            plan = compile_dhm(topo, params, quant=quant, n_stages=S)
            measured = [
                r for r in sweep_rows
                if r["topology"] == name and r["label"] == label
            ]
            tuning = autotune_pipeline(
                plan, n_dev,
                measurements=measured,
                constants=constants,
                microbatches=(2, 4, 8),
                grains=(16, 32),
                overlaps=(False,),
            )
            if measured:
                # The acceptance contract: with a sweep on record the
                # tuner's choice is within 20% of the best measured point.
                best_fps = max(r["frames_per_s"] for r in measured)
                assert tuning.frames_per_s >= 0.8 * best_fps, (
                    f"{name}/{label}: tuner picked "
                    f"{tuning.frames_per_s:.0f} frames/s, best measured "
                    f"{best_fps:.0f}"
                )
            group = tuning.n_microbatches * tuning.microbatch
            eng = Engine(plan, mesh=mesh, data_axis="data", tuning=tuning)
            assert eng.group == group
            x = _pipe_input(name, group)
            got = eng.infer(x)
            ref = handoff[f"{name}|{label}|{group}|ref"]
            assert np.allclose(
                np.asarray(got), ref, rtol=1e-4, atol=1e-4
            ), f"{name}/{label}: pipelined logits diverge from single-device"
            us = _time(eng.infer, x, reps=5, passes=2)
            fps = group / (us * 1e-6)
            fps_single = float(handoff[f"{name}|{label}|{group}|fps"])
            edge_path = eng._runner.edge_plan.mode
            bits_fields = (
                {"weight_bits": bits, "act_bits": bits}
                if label == "quant"
                else {}
            )
            rows.append(
                {
                    "name": f"e2e/{name}_{label}_pipelined_plan",
                    "us_per_call": us,
                    "path": "e2e_pipelined",
                    "frames_per_s": fps,
                    "pipeline_speedup": fps / fps_single,
                    **bits_fields,
                    "n_microbatches": tuning.n_microbatches,
                    "microbatch": tuning.microbatch,
                    "tuning_source": tuning.source,
                    "edge_path": edge_path,
                    "derived": (
                        f"{fps:.0f} frames/s through the serving Engine on "
                        f"a ({S} stage x {data} data) "
                        f"{jax.default_backend()} mesh "
                        f"({tuning.n_microbatches}x{tuning.microbatch}"
                        f"-frame groups autotuned [{tuning.source}], "
                        f"{edge_path} ICI edges): x{fps / fps_single:.2f} "
                        f"vs the single-device plan ({fps_single:.0f} "
                        f"frames/s), logits verified equal"
                    ),
                }
            )
    return rows


def run_pipelined() -> list:
    """The ``path: pipeline_sweep`` + ``path: e2e_pipelined`` rows,
    measured in a subprocess with 8 forced host-platform devices (the
    flag must be set before JAX initializes, and the main benchmark
    process may be single-device). The single-device references are
    measured HERE first and handed off — the subprocess never runs the
    reference plan."""
    repo_root = pathlib.Path(__file__).resolve().parents[1]

    # Reference pass: one plan per (topology, precision), measured at
    # every candidate group size.
    plans = {}
    for name in PIPE_TOPOS:
        topo = ALL_TOPOLOGIES[name]
        bits = PAPER_BITS[name]
        params = init_cnn(jax.random.PRNGKey(0), topo)
        for label, quant in (
            ("fp32", QuantSpec()),
            ("quant", QuantSpec(weight_bits=bits, act_bits=bits)),
        ):
            plans[(name, label)] = compile_dhm(
                topo, params, quant=quant, n_stages=_stages_of(name)
            )

    env = {
        **os.environ,
        "XLA_FLAGS": "--xla_force_host_platform_device_count=8",
        "JAX_PLATFORMS": "cpu",
        "PYTHONPATH": str(repo_root / "src")
        + (os.pathsep + os.environ["PYTHONPATH"]
           if os.environ.get("PYTHONPATH") else ""),
    }
    with tempfile.TemporaryDirectory() as td:
        handoff = os.path.join(td, "single_device_refs.npz")
        _write_handoff(plans, handoff)
        res = subprocess.run(
            [sys.executable, "-m", "benchmarks.e2e_bench",
             "--pipelined-json", "--handoff", handoff],
            capture_output=True, text=True, env=env, cwd=str(repo_root),
            timeout=1800,
        )
    if res.returncode != 0:
        raise RuntimeError(
            "pipelined benchmark subprocess failed:\n" + res.stderr[-3000:]
        )
    # The rows are the last stdout line (JAX may log above them).
    return json.loads(res.stdout.strip().splitlines()[-1])


def run() -> list:
    from repro.core.dhm.fusion import widening_budget

    rows = []
    for name in PIPE_TOPOS:
        topo = ALL_TOPOLOGIES[name]
        bits = PAPER_BITS[name]
        params = init_cnn(jax.random.PRNGKey(0), topo)
        h_in, w_in = topo.input_shape
        x = jax.random.normal(
            jax.random.PRNGKey(1),
            (BATCH, h_in, w_in, topo.input_channels),
        )
        variants = (
            ("fp32", QuantSpec()),
            ("quant", QuantSpec(weight_bits=bits, act_bits=bits)),
            (
                "int8",
                QuantSpec(
                    weight_bits=bits, act_bits=bits, int8_compute=True
                ),
            ),
        )
        fused_fps = {}
        for label, quant in variants:
            plan = compile_dhm(topo, params, quant=quant)
            plan_pl = compile_dhm(topo, params, quant=quant, vmem_budget=0)
            us_pl = _measure_plan(plan_pl, x)
            us = _measure_plan(plan, x)
            fps = BATCH / (us * 1e-6)
            fps_pl = BATCH / (us_pl * 1e-6)
            fused_fps[label] = fps
            gops = topo.feature_extractor_ops() * fps / 1e9
            speedup = us_pl / us
            qdesc = {
                "fp32": "fp32",
                "quant": f"w{bits}b + in-kernel act{bits}b stream quant",
                "int8": (
                    f"true int8 compute: w{bits}b codes, int32 accumulate, "
                    f"requantizing act{bits}b epilogue"
                ),
            }[label]
            gdesc = "+".join(
                str(len(g.layers)) for g in plan.fusion_groups
            )
            # DPN boundary streams of the fused interior layer edges: the
            # inter-layer pixel traffic that no longer crosses external
            # memory (DPN layer i+1 is conv layer i; layer 0 the source).
            onchip = sum(
                plan.graph.boundary_stream_bytes(li + 1)
                for g in plan.fusion_groups
                for li in g.layers[:-1]
            )
            fused_row = {
                "name": f"e2e/{name}_{label}_plan",
                "us_per_call": us,
                "path": f"e2e_{label}",
                "frames_per_s": fps,
                "fusion_speedup": speedup,
                "derived": (
                    f"{fps:.0f} frames/s ({gops:.2f} effective Gop/s) "
                    f"for the full compiled plan (batch={BATCH}, "
                    f"{qdesc}, fused groups [{gdesc} layers/kernel] + "
                    f"FC head, one jitted closure): x{speedup:.2f} vs "
                    f"per-layer stages, {onchip / 1024:.0f} KiB/frame "
                    f"of inter-layer streams stay on-chip"
                ),
            }
            perlayer_row = {
                "name": f"e2e/{name}_{label}_perlayer_plan",
                "us_per_call": us_pl,
                "path": f"e2e_{label}_perlayer",
                "frames_per_s": fps_pl,
                "derived": (
                    f"{fps_pl:.0f} frames/s pre-fusion baseline "
                    f"(vmem_budget=0: one kernel call per conv layer, "
                    f"intermediates round-trip through memory)"
                ),
            }
            if label != "fp32":
                for row in (fused_row, perlayer_row):
                    row["weight_bits"] = bits
                    row["act_bits"] = bits
            if label == "int8":
                int8_speedup = fps / fused_fps["fp32"]
                fused_row["int8_speedup"] = int8_speedup
                # Dtype-aware fusion widening: the budget (1 B under the
                # cheapest fp32 whole-run cost) at which int8 slab costing
                # fuses a strictly larger group than fp32 costing.
                probe = widening_budget(
                    topo, tuple(range(len(topo.conv_layers)))
                )
                if probe is not None:
                    fused_row["widening_budget"] = probe["budget"]
                    fused_row["fp32_max_group"] = probe["fp32_max_group"]
                    fused_row["int8_max_group"] = probe["int8_max_group"]
                fused_row["derived"] += (
                    f"; x{int8_speedup:.2f} vs the fp32 fused plan"
                )
                if probe is not None:
                    fused_row["derived"] += (
                        f"; at a {probe['budget']}-B budget int8 slab "
                        f"costing fuses {probe['int8_max_group']} layers "
                        f"where fp32 fits {probe['fp32_max_group']}"
                    )
            rows.append(fused_row)
            rows.append(perlayer_row)
    rows += run_pipelined()
    return rows


if __name__ == "__main__":
    if "--pipelined-json" in sys.argv:
        # Subprocess entry: this process was launched with 8 forced host
        # devices; emit the sweep + pipelined rows as one JSON line on
        # stdout, reading single-device references from the handoff file.
        handoff_path = sys.argv[sys.argv.index("--handoff") + 1]
        print(json.dumps(_pipelined_rows_here(handoff_path)))
    else:
        for r in run():
            print(r["name"], "|", f"{r['us_per_call']:.1f}us", "|", r["derived"])
