"""End-to-end compiled-plan throughput (the per-PR Table-4 analogue).

For each benchmarked topology — the three paper nets plus the generalized
non-paper ones (cifar10_full: overlapping 3x3/stride-2 pool;
cifar10_strided: stride-2 downsampling convs) — lower a full plan through
``compile_dhm`` (the single lowering path everything routes through)
twice per quantization variant:

- the **fused** plan (default VMEM budget): the feature extractor runs as
  cross-layer fusion groups — one fused pyramid kernel per group, with
  inter-layer feature slabs kept on-chip;
- the **per-layer** plan (``vmem_budget=0``): today's pre-fusion baseline,
  one kernel call per conv layer with every intermediate feature map
  round-tripping through memory.

Both execute through the plan's cached end-to-end jitted closure
(``CompiledDHM.__call__``), so the comparison isolates the fusion
decision, and both rows land in ``BENCH_kernels.json`` — the fused row
carries ``fusion_speedup`` vs its per-layer twin. After timing, the
benchmark asserts the plan never retraced across reps (the jit cache
holds exactly one entry).

A third family of rows (``path: e2e_pipelined``) measures the SPATIAL
pipeline: every topology served through the ``Engine`` on a multi-device
``(stage, data)`` host-platform mesh (heterogeneous stages over boxed ICI
edges, GPipe schedule). Host-platform device counts must be forced before
JAX initializes, so these rows are measured in a subprocess
(``python -m benchmarks.e2e_bench --pipelined-json``) with
``--xla_force_host_platform_device_count=8``; each row is checked against
the single-device plan before it is recorded.
"""
from __future__ import annotations

import json
import os
import pathlib
import subprocess
import sys
import time

import jax

from repro.core.dhm.compiler import QuantSpec, compile_dhm
from repro.models.cnn import ALL_TOPOLOGIES, init_cnn

# Paper bit-widths (Table 3): 3 bits LeNet5, 6 bits Cifar10/SVHN; the
# non-paper Cifar10 variants inherit the Cifar10 regime.
PAPER_BITS = {
    "lenet5": 3, "cifar10": 6, "svhn": 6,
    "cifar10_full": 6, "cifar10_strided": 6,
}
BATCH = 8


def _time(fn, *args, reps=10, passes=3):
    """Best-of-``passes`` timing (each pass averages ``reps`` calls), so
    the recorded per-PR trajectory reflects the achievable rate rather
    than scheduler noise on a shared machine. Every rep blocks on its own
    output: with only the last rep blocked, JAX's async dispatch overlaps
    host-side dispatch of rep i+1 with device execution of rep i and the
    per-call latency under-reports. ``jax.block_until_ready`` (not the
    array method) so callables that hand back host numpy — e.g. the
    serving Engine, which packs and scatters batches host-side — time
    the same way as device-array producers."""
    jax.block_until_ready(fn(*args))  # compile
    best = float("inf")
    for _ in range(passes):
        t0 = time.time()
        for _ in range(reps):
            jax.block_until_ready(fn(*args))
        best = min(best, (time.time() - t0) / reps * 1e6)
    return best


def _measure_plan(plan, x):
    """us/call through the plan's cached jitted closure, asserting the
    closure never retraces across reps."""
    us = _time(plan, x)
    fwd = plan.jitted_forward()
    n_traces = fwd._cache_size()
    assert n_traces == 1, (
        f"plan retraced across reps: jit cache holds {n_traces} entries"
    )
    return us


def _pipelined_rows_here() -> list:
    """Measure the pipelined serving rows IN THIS PROCESS (requires a
    multi-device backend — the subprocess entry below forces 8 host
    devices). Each topology runs through the Engine on a (stage, data)
    mesh and is checked against the single-device plan before timing."""
    import numpy as np

    from repro.core.dhm.engine import Engine

    n_dev = len(jax.devices())
    rows = []
    for name in (
        "lenet5", "cifar10", "svhn", "cifar10_full", "cifar10_strided"
    ):
        topo = ALL_TOPOLOGIES[name]
        bits = PAPER_BITS[name]
        n_stages = min(3, len(topo.conv_layers))
        data = 2
        if n_stages * data > n_dev:
            raise RuntimeError(
                f"pipelined bench needs {n_stages * data} devices, "
                f"have {n_dev}"
            )
        params = init_cnn(jax.random.PRNGKey(0), topo)
        h_in, w_in = topo.input_shape
        mesh = jax.make_mesh((n_stages, data), ("stage", "data"))
        mb, M = 8, 4
        group = mb * M
        x = jax.random.normal(
            jax.random.PRNGKey(1), (group, h_in, w_in, topo.input_channels)
        )
        for label, quant in (
            ("fp32", QuantSpec()),
            ("quant", QuantSpec(weight_bits=bits, act_bits=bits)),
        ):
            plan = compile_dhm(topo, params, quant=quant, n_stages=n_stages)
            eng = Engine(
                plan, microbatch=mb, mesh=mesh, n_microbatches=M,
                data_axis="data",
            )
            got = eng.infer(x)
            ref = plan(x)
            assert np.allclose(
                np.asarray(got), np.asarray(ref), rtol=1e-4, atol=1e-4
            ), f"{name}/{label}: pipelined logits diverge from single-device"
            us_single = _measure_plan(plan, x)
            us = _time(eng.infer, x, reps=5, passes=2)
            fps = group / (us * 1e-6)
            fps_single = group / (us_single * 1e-6)
            rows.append(
                {
                    "name": f"e2e/{name}_{label}_pipelined_plan",
                    "us_per_call": us,
                    "path": "e2e_pipelined",
                    "frames_per_s": fps,
                    "pipeline_speedup": fps / fps_single,
                    "derived": (
                        f"{fps:.0f} frames/s through the serving Engine on "
                        f"a ({n_stages} stage x {data} data) "
                        f"{jax.default_backend()} mesh ({M}x{mb}-frame "
                        f"groups, heterogeneous stages over boxed ICI "
                        f"edges): x{fps / fps_single:.2f} vs the "
                        f"single-device plan ({fps_single:.0f} frames/s), "
                        f"logits verified equal"
                    ),
                }
            )
    return rows


def run_pipelined() -> list:
    """The ``path: e2e_pipelined`` rows, measured in a subprocess with 8
    forced host-platform devices (the flag must be set before JAX
    initializes, and the main benchmark process may be single-device)."""
    repo_root = pathlib.Path(__file__).resolve().parents[1]
    env = {
        **os.environ,
        "XLA_FLAGS": "--xla_force_host_platform_device_count=8",
        "JAX_PLATFORMS": "cpu",
        "PYTHONPATH": str(repo_root / "src")
        + (os.pathsep + os.environ["PYTHONPATH"]
           if os.environ.get("PYTHONPATH") else ""),
    }
    res = subprocess.run(
        [sys.executable, "-m", "benchmarks.e2e_bench", "--pipelined-json"],
        capture_output=True, text=True, env=env, cwd=str(repo_root),
        timeout=1800,
    )
    if res.returncode != 0:
        raise RuntimeError(
            "pipelined benchmark subprocess failed:\n" + res.stderr[-3000:]
        )
    # The rows are the last stdout line (JAX may log above them).
    return json.loads(res.stdout.strip().splitlines()[-1])


def run() -> list:
    rows = []
    for name in (
        "lenet5", "cifar10", "svhn", "cifar10_full", "cifar10_strided"
    ):
        topo = ALL_TOPOLOGIES[name]
        bits = PAPER_BITS[name]
        params = init_cnn(jax.random.PRNGKey(0), topo)
        h_in, w_in = topo.input_shape
        x = jax.random.normal(
            jax.random.PRNGKey(1),
            (BATCH, h_in, w_in, topo.input_channels),
        )
        variants = (
            ("fp32", QuantSpec()),
            ("quant", QuantSpec(weight_bits=bits, act_bits=bits)),
        )
        for label, quant in variants:
            plan = compile_dhm(topo, params, quant=quant)
            plan_pl = compile_dhm(topo, params, quant=quant, vmem_budget=0)
            us_pl = _measure_plan(plan_pl, x)
            us = _measure_plan(plan, x)
            fps = BATCH / (us * 1e-6)
            fps_pl = BATCH / (us_pl * 1e-6)
            gops = topo.feature_extractor_ops() * fps / 1e9
            speedup = us_pl / us
            qdesc = (
                "fp32"
                if label == "fp32"
                else f"w{bits}b + in-kernel act{bits}b stream quant"
            )
            gdesc = "+".join(
                str(len(g.layers)) for g in plan.fusion_groups
            )
            # DPN boundary streams of the fused interior layer edges: the
            # inter-layer pixel traffic that no longer crosses external
            # memory (DPN layer i+1 is conv layer i; layer 0 the source).
            onchip = sum(
                plan.graph.boundary_stream_bytes(li + 1)
                for g in plan.fusion_groups
                for li in g.layers[:-1]
            )
            rows.append(
                {
                    "name": f"e2e/{name}_{label}_plan",
                    "us_per_call": us,
                    "path": f"e2e_{label}",
                    "frames_per_s": fps,
                    "fusion_speedup": speedup,
                    "derived": (
                        f"{fps:.0f} frames/s ({gops:.2f} effective Gop/s) "
                        f"for the full compiled plan (batch={BATCH}, "
                        f"{qdesc}, fused groups [{gdesc} layers/kernel] + "
                        f"FC head, one jitted closure): x{speedup:.2f} vs "
                        f"per-layer stages, {onchip / 1024:.0f} KiB/frame "
                        f"of inter-layer streams stay on-chip"
                    ),
                }
            )
            rows.append(
                {
                    "name": f"e2e/{name}_{label}_perlayer_plan",
                    "us_per_call": us_pl,
                    "path": f"e2e_{label}_perlayer",
                    "frames_per_s": fps_pl,
                    "derived": (
                        f"{fps_pl:.0f} frames/s pre-fusion baseline "
                        f"(vmem_budget=0: one kernel call per conv layer, "
                        f"intermediates round-trip through memory)"
                    ),
                }
            )
    rows += run_pipelined()
    return rows


if __name__ == "__main__":
    if "--pipelined-json" in sys.argv:
        # Subprocess entry: this process was launched with 8 forced host
        # devices; emit the pipelined rows as one JSON line on stdout.
        print(json.dumps(_pipelined_rows_here()))
    else:
        for r in run():
            print(r["name"], "|", f"{r['us_per_call']:.1f}us", "|", r["derived"])
