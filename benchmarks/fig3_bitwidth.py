"""Paper Fig. 3: classification accuracy vs bit-width.

Quantizes the trained float model at each width and fine-tunes briefly
(the paper's footnote-2 retraining), reproducing the knee: LeNet5 is usable
at ~3 bits, the Cifar10/SVHN topology needs ~6.
"""
from __future__ import annotations

import time

from repro.core.quant import search_bitwidth
from repro.data import make_image_dataset
from repro.models.cnn import PAPER_TOPOLOGIES
from repro.paper.train_cnn import (
    evaluate,
    get_trained_cnn,
    topology_seed,
    train_cnn,
)

BIT_RANGE = (2, 3, 4, 6, 8)
FINETUNE_STEPS = 40


def run(networks=("lenet5",)) -> list:
    """Full sweep for LeNet5 by default (cifar10/svhn add ~minutes each;
    enable via networks=('lenet5','cifar10','svhn'))."""
    rows = []
    for name in networks:
        topo = PAPER_TOPOLOGIES[name]
        trained = get_trained_cnn(name)
        # The same dataset the model was trained (and float-evaluated) on:
        # fine-tuned quant accuracies must be comparable to
        # trained.float_accuracy, so the synthetic task must match.
        ds = make_image_dataset(
            hw=topo.square_input_hw(), channels=topo.input_channels,
            seed=topology_seed(name),
        )

        def eval_at(bits: int) -> float:
            ft = train_cnn(
                topo,
                steps=FINETUNE_STEPS,
                dataset=ds,
                weight_bits=bits,
                act_bits=max(bits, 4),
                init_params=trained.params,
                peak_lr=5e-4,
            )
            return ft.float_accuracy  # accuracy of the fine-tuned quant model

        t0 = time.time()
        res = search_bitwidth(
            eval_at,
            float_accuracy=trained.float_accuracy,
            bit_range=BIT_RANGE,
            max_drop=0.04,
        )
        us = (time.time() - t0) * 1e6
        curve = " ".join(f"{b}b:{a:.3f}" for b, a in res.curve())
        rows.append(
            {
                "name": f"fig3/{name}",
                "us_per_call": us,
                "derived": (
                    f"float={res.float_accuracy:.3f} {curve} "
                    f"selected={res.selected_bits}b "
                    f"[paper selected: "
                    f"{'3' if name == 'lenet5' else '6'}b]"
                ),
            }
        )
    return rows


if __name__ == "__main__":
    for r in run(networks=("lenet5", "cifar10", "svhn")):
        print(r["name"], "|", r["derived"])
