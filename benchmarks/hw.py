"""Target hardware constants (TPU v5e-class, per chip)."""
PEAK_FLOPS_BF16 = 197e12  # FLOP/s
HBM_BW = 819e9  # bytes/s
ICI_BW = 50e9  # bytes/s per link
