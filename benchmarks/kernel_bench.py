"""Kernel micro-benchmarks.

Measures the compiled kernel paths against the seed designs so the perf
trajectory is recorded per PR (``benchmarks/run.py`` dumps these rows to
``BENCH_kernels.json``). The headline row is the streaming conv on a
CIFAR-10-sized layer (32x32x3 -> 32, K=5, SAME):

  - ``seed_interpret``: the original one-row-per-step, K^2-dots-per-row
    kernel through the Pallas interpreter — the repo's state before the
    row-block rewrite.
  - ``fused``: the row-blocked kernel (ONE matmul per row block) with the
    fused bias+ReLU+2x2-pool epilogue, on the compiled backend — and it is
    doing strictly more work than the seed (which computed conv only).

The derived column still reports the structural quantities that determine
TPU performance (weight bytes moved, line-buffer working set); wall-times
on CPU compare compiled XLA lowering vs the interpreter, not TPU numbers.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.kernels.pow2_matmul import pow2_matmul, quantize_weights
from repro.kernels.stream_conv import (
    stream_conv2d,
    stream_conv_block,
    stream_conv_pyramid,
)
from repro.kernels.stream_conv.legacy import stream_conv2d_pallas_seed


def _time(fn, *args, reps=3):
    """Every rep blocks on its own output — blocking only on the last
    dispatch lets async dispatch overlap reps and under-report latency."""
    fn(*args).block_until_ready()  # compile
    t0 = time.time()
    for _ in range(reps):
        fn(*args).block_until_ready()
    return (time.time() - t0) / reps * 1e6


def run() -> list:
    rows = []
    m = k = n = 256
    x = jax.random.normal(jax.random.PRNGKey(0), (m, k))
    w = jax.random.normal(jax.random.PRNGKey(1), (k, n))
    packed, scale = quantize_weights(w)

    us = _time(
        lambda a, b, c: pow2_matmul(a, b, c, block_m=128, block_n=128,
                                    block_k=128),
        x, packed, scale,
    )
    bf16_bytes = k * n * 2
    packed_bytes = packed.size + scale.size * 4
    rows.append(
        {
            "name": f"kernel/pow2_matmul_{m}x{k}x{n}",
            "us_per_call": us,
            "derived": (
                f"weight_bytes={packed_bytes} vs bf16={bf16_bytes} "
                f"(x{bf16_bytes/packed_bytes:.2f} compression); decode is "
                f"exponent-shift only (0 multiplies/weight)"
            ),
        }
    )

    xc = jax.random.normal(jax.random.PRNGKey(2), (1, 28, 28, 1))
    wc = jax.random.normal(jax.random.PRNGKey(3), (5, 5, 1, 20)) * 0.2
    us = _time(lambda a, b: stream_conv2d(a, b, padding="VALID"), xc, wc)
    halo = (5 - 1) * 28 * 1 * 4  # (K-1) halo lines x W x C x 4B
    rows.append(
        {
            "name": "kernel/stream_conv_lenet_c1",
            "us_per_call": us,
            "derived": (
                "compiled default backend (row-blocked, one matmul/row "
                f"block); per-block working set bounded, halo_bytes={halo} "
                f"(vs full-frame im2col {24*24*25*4})"
            ),
        }
    )

    # CIFAR-10 conv1 (paper Table 1): 32x32x3 -> 32, K=5, SAME, a µbatch of
    # 8 frames (so compute, not dispatch overhead, dominates both paths).
    # Seed path (interpret-mode, K^2 dots/row) vs the fused row-block
    # rewrite.
    kk = 5
    xs = jax.random.normal(jax.random.PRNGKey(4), (8, 32, 32, 3))
    ws = jax.random.normal(jax.random.PRNGKey(5), (kk, kk, 3, 32)) * 0.2
    bs = jax.random.normal(jax.random.PRNGKey(6), (32,)) * 0.1
    pad = kk // 2
    xs_same = jnp.pad(xs, ((0, 0), (pad, pad), (pad, pad), (0, 0)))
    w_taps = ws.reshape(kk * kk, 3, 32)

    seed_us = _time(
        lambda a, b: stream_conv2d_pallas_seed(a, b, k=kk, interpret=True),
        xs_same, w_taps, reps=2,
    )
    rows.append(
        {
            "name": "kernel/stream_conv_cifar_c1_seed_interpret",
            "us_per_call": seed_us,
            "path": "seed",
            "derived": (
                f"seed design: 1 row/step, {kk*kk} per-tap dots/row, "
                "interpret-mode only, conv output written back unfused"
            ),
        }
    )

    fused_us = _time(
        lambda a, b, c: stream_conv_block(
            a, b, c, padding="SAME", act="relu", pool=2, backend="pallas"
        ),
        xs, ws, bs, reps=10,
    )
    speedup = seed_us / fused_us
    rows.append(
        {
            "name": "kernel/stream_conv_cifar_c1_fused",
            "us_per_call": fused_us,
            "path": "fused",
            "speedup_vs_seed": speedup,
            "derived": (
                "row-block kernel, ONE matmul/row block + fused "
                f"bias+relu+2x2pool epilogue, compiled backend: "
                f"x{speedup:.1f} vs seed interpret path (and 4x smaller "
                "writeback: pooled output only)"
            ),
        }
    )

    # Cross-layer fused pyramid: the whole CIFAR-10 conv stack (3 layers)
    # as ONE kernel group vs the chained per-layer fused blocks — the
    # kernel-level view of what the compiler's fusion planner buys.
    from repro.models.cnn import CIFAR10, init_cnn

    cparams = init_cnn(jax.random.PRNGKey(7), CIFAR10)["conv"]
    cw = tuple(p["w"] for p in cparams)
    cb = tuple(p["b"] for p in cparams)
    specs = CIFAR10.conv_layers
    xf = jax.random.normal(jax.random.PRNGKey(8), (8, 32, 32, 3))

    def chain(a):
        for spec, p in zip(specs, cparams):
            a = stream_conv_block(
                a, p["w"], p["b"], padding=spec.padding, act=spec.act,
                pool=spec.pool, backend="pallas",
            )
        return a

    chain_us = _time(jax.jit(chain), xf, reps=10)
    # jit both sides identically: the chain and the pyramid each cost one
    # cached-jit dispatch per rep, so the recorded speedup is the kernel
    # difference, not Python wrapper overhead charged to one side.
    pyr_us = _time(
        jax.jit(
            lambda a: stream_conv_pyramid(
                a, cw, cb, layers=specs, backend="pallas"
            )
        ),
        xf, reps=10,
    )
    group_speedup = chain_us / pyr_us
    rows.append(
        {
            "name": "kernel/stream_conv_pyramid_cifar_stack",
            "us_per_call": pyr_us,
            "path": "fused_group",
            "speedup_vs_perlayer": group_speedup,
            "derived": (
                "3-layer conv pyramid as ONE fused kernel group "
                "(inter-layer slabs on-chip, one matmul/layer, "
                f"pool-before-act epilogue): x{group_speedup:.2f} vs the "
                "chained per-layer fused blocks"
            ),
        }
    )
    return rows


if __name__ == "__main__":
    for r in run():
        print(r["name"], "|", f"{r['us_per_call']:.1f}us", "|", r["derived"])
