"""Kernel micro-benchmarks.

Wall-time here is CPU interpret-mode (correctness harness), NOT TPU
performance — the derived column reports the structural quantities that
determine TPU performance: weight bytes moved (the pow2 kernel's 4x
compression is the paper's multiplier-area saving translated to bandwidth)
and the line-buffer working set of the streaming conv.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.kernels.pow2_matmul import pow2_matmul, quantize_weights
from repro.kernels.stream_conv import stream_conv2d


def _time(fn, *args, reps=3):
    fn(*args).block_until_ready()  # compile
    t0 = time.time()
    for _ in range(reps):
        out = fn(*args)
    out.block_until_ready()
    return (time.time() - t0) / reps * 1e6


def run() -> list:
    rows = []
    m = k = n = 256
    x = jax.random.normal(jax.random.PRNGKey(0), (m, k))
    w = jax.random.normal(jax.random.PRNGKey(1), (k, n))
    packed, scale = quantize_weights(w)

    us = _time(
        lambda a, b, c: pow2_matmul(a, b, c, block_m=128, block_n=128,
                                    block_k=128),
        x, packed, scale,
    )
    bf16_bytes = k * n * 2
    packed_bytes = packed.size + scale.size * 4
    rows.append(
        {
            "name": f"kernel/pow2_matmul_{m}x{k}x{n}",
            "us_per_call": us,
            "derived": (
                f"weight_bytes={packed_bytes} vs bf16={bf16_bytes} "
                f"(x{bf16_bytes/packed_bytes:.2f} compression); decode is "
                f"exponent-shift only (0 multiplies/weight)"
            ),
        }
    )

    xc = jax.random.normal(jax.random.PRNGKey(2), (1, 28, 28, 1))
    wc = jax.random.normal(jax.random.PRNGKey(3), (5, 5, 1, 20)) * 0.2
    us = _time(lambda a, b: stream_conv2d(a, b, padding="VALID"), xc, wc)
    lbuf = (5 - 1) * 28 * 1 * 4  # (K-1) lines x W x C x 4B
    rows.append(
        {
            "name": "kernel/stream_conv_lenet_c1",
            "us_per_call": us,
            "derived": (
                f"line_buffer_bytes={lbuf} (vs full-frame im2col "
                f"{24*24*25*4}); HBM traffic = 1 read + 1 write, "
                f"0 intermediate spills"
            ),
        }
    )
    return rows


if __name__ == "__main__":
    for r in run():
        print(r["name"], "|", r["derived"])
