"""Roofline analysis over the dry-run artifacts (EXPERIMENTS.md §Roofline).

Three terms per (arch x shape x mesh) cell, in seconds:

  compute    = HLO_FLOPs / (chips * peak)    [= per-device FLOPs / peak]
  memory     = HLO_bytes / (chips * HBM_bw)  [= per-device bytes / HBM_bw]
  collective = collective operand bytes per device / link_bw

cost_analysis() reports *per-partition* FLOPs/bytes under SPMD, so the
division by chips is already done. Collective bytes come from parsing the
post-optimization HLO (operand shard sizes of all-gather / all-reduce /
reduce-scatter / all-to-all / collective-permute); ring-algorithm wire
amplification (~2x for all-reduce) is noted, not modeled.

Also reported: MODEL_FLOPS (6*N_active*D or 2*N_active*D), the useful-work
ratio MODEL_FLOPS / HLO_FLOPs, and the roofline fraction
ideal_time / max(terms) — the headline number in §Perf.
"""
from __future__ import annotations

import glob
import json
import os

from benchmarks.hw import HBM_BW, ICI_BW, PEAK_FLOPS_BF16

RESULTS_DIR = os.environ.get("REPRO_RESULTS_DIR", "results")


def load_cells(mesh: str = "16x16", opt_name: str = "baseline"):
    suffix = f"__{mesh}.json" if opt_name == "baseline" else (
        f"__{mesh}__{opt_name}.json"
    )
    cells = []
    for f in sorted(glob.glob(os.path.join(RESULTS_DIR, "dryrun", "*" + suffix))):
        with open(f) as fh:
            cells.append(json.load(fh))
    return cells


def analyze(cell: dict) -> dict:
    if cell["status"] != "ok":
        return {**cell, "analysis": None}
    n_dev = cell["n_devices"]
    if "analysis" in cell:  # trip-count-aware HLO analysis (preferred)
        flops_dev = cell["analysis"]["flops_per_device"]
        bytes_dev = cell["analysis"]["hbm_bytes_per_device"]
        coll_dev = cell["analysis"]["collective_bytes_per_device"]
    else:  # legacy cells: XLA cost model (undercounts while bodies)
        flops_dev = cell["cost"]["flops_per_device"]
        bytes_dev = cell["cost"]["bytes_accessed_per_device"]
        coll_dev = sum(
            s["operand_bytes"] for s in cell["collectives"].values()
        )
    compute_s = flops_dev / PEAK_FLOPS_BF16
    memory_s = bytes_dev / HBM_BW
    collective_s = coll_dev / ICI_BW
    terms = {"compute": compute_s, "memory": memory_s, "collective": collective_s}
    dominant = max(terms, key=terms.get)
    ideal_s = cell["model_flops"] / (n_dev * PEAK_FLOPS_BF16)
    max_term = max(terms.values())
    return {
        "arch": cell["arch"],
        "shape": cell["shape"],
        "mesh": cell["mesh"],
        "compute_s": compute_s,
        "memory_s": memory_s,
        "collective_s": collective_s,
        "dominant": dominant,
        "model_flops": cell["model_flops"],
        "hlo_flops_total": flops_dev * n_dev,
        "useful_ratio": cell["model_flops"] / max(1.0, flops_dev * n_dev),
        "ideal_s": ideal_s,
        "roofline_fraction": ideal_s / max(1e-12, max_term),
        "params": cell.get("params"),
        "memory_per_device_gb": (
            cell["memory"]["argument_size_in_bytes"]
            + cell["memory"]["temp_size_in_bytes"]
            + cell["memory"]["output_size_in_bytes"]
            - cell["memory"]["alias_size_in_bytes"]
        )
        / 2**30,
    }


def table(mesh: str = "16x16", opt_name: str = "baseline") -> list:
    return [analyze(c) for c in load_cells(mesh, opt_name)]


def render_markdown(mesh: str = "16x16", opt_name: str = "baseline") -> str:
    rows = table(mesh, opt_name)
    out = [
        f"| arch | shape | compute s | memory s | collective s | dominant "
        f"| useful ratio | roofline frac | mem/dev GB |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for r in rows:
        if r.get("compute_s") is None:
            if r.get("status") == "skipped":
                out.append(
                    f"| {r['arch']} | {r['shape']} | — | — | — | skipped "
                    f"(full attn @500k) | — | — | — |"
                )
            continue
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['compute_s']:.3e} "
            f"| {r['memory_s']:.3e} | {r['collective_s']:.3e} "
            f"| {r['dominant']} | {r['useful_ratio']:.2f} "
            f"| {r['roofline_fraction']:.3f} | {r['memory_per_device_gb']:.2f} |"
        )
    return "\n".join(out)


def _all_variants(mesh: str = "16x16"):
    """Every artifact for a mesh, keyed (arch, shape) -> [(opt_name, row)]."""
    out: dict = {}
    for f in sorted(glob.glob(os.path.join(RESULTS_DIR, "dryrun", "*.json"))):
        with open(f) as fh:
            cell = json.load(fh)
        if cell.get("status") != "ok" or cell.get("mesh") != mesh:
            continue
        row = analyze(cell)
        out.setdefault((cell["arch"], cell["shape"]), []).append(
            (cell.get("opt", "baseline"), row)
        )
    return out


def best_table(mesh: str = "16x16") -> list:
    """Per-cell best configuration (min bottleneck) across all recorded
    opt variants — what a per-cell tuning loop deploys."""
    rows = []
    for (arch, shape), variants in sorted(_all_variants(mesh).items()):
        base = next((r for n, r in variants if n == "baseline"), None)

        def bottleneck(r):
            return max(r["compute_s"], r["memory_s"], r["collective_s"])

        opt_name, best = min(variants, key=lambda nv: bottleneck(nv[1]))
        rows.append(
            {
                "arch": arch,
                "shape": shape,
                "best_opt": opt_name,
                "bottleneck_s": bottleneck(best),
                "baseline_s": bottleneck(base) if base else None,
                "speedup": (bottleneck(base) / bottleneck(best))
                if base
                else None,
                "roofline_fraction": best["roofline_fraction"],
                "dominant": best["dominant"],
            }
        )
    return rows


def render_best_markdown(mesh: str = "16x16") -> str:
    out = [
        "| arch | shape | best config | baseline s | best s | speedup "
        "| roofline frac | dominant |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for r in best_table(mesh):
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['best_opt']} "
            f"| {r['baseline_s']:.3f} | {r['bottleneck_s']:.3f} "
            f"| {r['speedup']:.2f}x | {r['roofline_fraction']:.4f} "
            f"| {r['dominant']} |"
        )
    return "\n".join(out)


def main():
    for mesh in ("16x16", "2x16x16"):
        rows = [r for r in table(mesh) if r.get("compute_s") is not None]
        if not rows:
            continue
        print(f"\n=== Roofline ({mesh}) ===")
        print(render_markdown(mesh))
    print("\n=== Best configuration per cell (16x16) ===")
    print(render_best_markdown("16x16"))


if __name__ == "__main__":
    main()
