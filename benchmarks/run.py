"""Benchmark entry point: one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV and writes the kernel rows to
``BENCH_kernels.json`` (machine-readable, one file per run: schema
``{"benchmark", "jax_backend", "rows": [{name, us_per_call, derived, and
per-row extras such as path/speedup_vs_seed}]}``) so the perf trajectory
of the Pallas kernels is recorded across PRs. ``BENCH_kernels.json`` is a
snapshot (overwritten per run); every run additionally APPENDS its record
to ``BENCH_history.jsonl`` — one JSON line per run with the git SHA and a
UTC timestamp — so the cross-PR perf trajectory survives instead of being
clobbered. Invoke as ``PYTHONPATH=src python -m benchmarks.run`` (add
``--full`` to run the slow full Fig. 3 sweep for all three CNNs and the
full roofline dump).
"""
from __future__ import annotations

import argparse
import csv
import datetime
import json
import subprocess
import sys


def _git_sha() -> str:
    """Current commit SHA (with a -dirty suffix for uncommitted changes);
    'unknown' outside a git checkout. The benchmark artifacts themselves
    (BENCH*) are excluded from the dirty check — the run rewrites them
    before this stamp, and a record must not call a clean code state
    dirty just because it recorded itself."""
    try:
        sha = subprocess.run(
            ["git", "rev-parse", "HEAD"],
            capture_output=True, text=True, check=True, timeout=10,
        ).stdout.strip()
        dirty = subprocess.run(
            ["git", "status", "--porcelain", "--", ".",
             ":(exclude)BENCH_kernels.json", ":(exclude)BENCH.csv",
             ":(exclude)BENCH_history.jsonl"],
            capture_output=True, text=True, check=True, timeout=10,
        ).stdout.strip()
        return sha + ("-dirty" if dirty else "")
    except Exception:  # noqa: BLE001 — not a git checkout / no git binary
        return "unknown"


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="full Fig. 3 sweep (all 3 CNNs)")
    args = ap.parse_args()

    from benchmarks import (
        e2e_bench,
        fig3_bitwidth,
        kernel_bench,
        serve_bench,
        table1_param_classes,
        table2_mult_strategies,
        table3_device_fit,
        table4_throughput,
    )

    rows = []
    rows += table1_param_classes.run()
    rows += table2_mult_strategies.run()
    rows += table3_device_fit.run()
    rows += table4_throughput.run()
    rows += fig3_bitwidth.run(
        networks=("lenet5", "cifar10", "svhn") if args.full else ("lenet5",)
    )
    kernel_rows = kernel_bench.run()
    # End-to-end compiled-plan rows (frames/sec per topology, fp32 vs
    # quantized plan) ride in the same record: the Table-4-style
    # throughput trajectory per PR.
    kernel_rows += e2e_bench.run()
    # Serving-under-load rows (path: serve_load): p50/p99 latency and
    # shed/error rates vs offered load through the fault-tolerant Engine.
    kernel_rows += serve_bench.run()
    rows += kernel_rows

    # Machine-readable kernel perf record (seed path vs fused path, plus
    # the end-to-end compiled plans).
    import jax

    record = {
        "benchmark": "kernels",
        "jax_backend": jax.default_backend(),
        "rows": kernel_rows,
    }
    with open("BENCH_kernels.json", "w") as f:
        json.dump(record, f, indent=2)
    print("# wrote BENCH_kernels.json", file=sys.stderr)

    # Append this run to the cross-PR trajectory (BENCH_kernels.json is a
    # snapshot; the history is what plots perf over time).
    with open("BENCH_history.jsonl", "a") as f:
        f.write(
            json.dumps(
                {
                    "git_sha": _git_sha(),
                    "timestamp": datetime.datetime.now(
                        datetime.timezone.utc
                    ).isoformat(timespec="seconds"),
                    **record,
                }
            )
            + "\n"
        )
    print("# appended BENCH_history.jsonl", file=sys.stderr)

    # Roofline summary rows (from the dry-run artifacts, if present).
    try:
        from benchmarks import roofline

        for mesh in ("16x16", "2x16x16"):
            for r in roofline.table(mesh):
                if r.get("compute_s") is None:
                    continue
                rows.append(
                    {
                        "name": f"roofline/{r['arch']}/{r['shape']}/{mesh}",
                        "us_per_call": max(
                            r["compute_s"], r["memory_s"], r["collective_s"]
                        )
                        * 1e6,
                        "derived": (
                            f"dominant={r['dominant']} "
                            f"frac={r['roofline_fraction']:.3f} "
                            f"useful={r['useful_ratio']:.2f}"
                        ),
                    }
                )
    except Exception as e:  # noqa: BLE001 — roofline needs dry-run artifacts
        print(f"# roofline skipped: {e}", file=sys.stderr)

    w = csv.writer(sys.stdout)
    w.writerow(["name", "us_per_call", "derived"])
    for r in rows:
        w.writerow([r["name"], f"{r['us_per_call']:.1f}", r["derived"]])


if __name__ == "__main__":
    main()
