"""Serving-under-load benchmark (``path: serve_load`` rows).

The kernel and e2e benches measure the *compute* trajectory; this one
measures the *serving* trajectory: the fault-tolerant ``Engine`` with its
background flusher, deadline SLOs, and shed-oldest admission control
under an open-loop load generator.

Method: first measure the engine's capacity (frames/s through one
group-sized dispatch of the compiled plan). Then, for each offered-load
factor (0.5x, 1.0x, 2.0x capacity), submit a fixed number of single-frame
requests at a constant paced inter-arrival, each carrying a deadline SLO,
against an engine with a bounded shedding queue. Per level we record
client-side p50/p99 latency over completed requests, the achieved
throughput, and the shed / deadline-exceeded / error rates — the numbers
that tell whether admission control actually bounds latency at overload
instead of letting the queue grow without limit.

Every request must complete (logits or a structured error) — the bench
asserts it, so a hang regression fails the benchmark run, not just the
chaos suite.

A second row family (``path: serve_multitenant``) pushes the same load
through the multi-tenant ``Router``: two tenants each offered 1x their
capacity, one of them under a tenant-scoped transient-fault storm. The
row records per-tenant p50/p99, shed/error rates, and the isolation
ratio (faulted p99 / clean p99) — the bulkhead's blast-radius number
over time.
"""
from __future__ import annotations

import time

import jax
import numpy as np

from repro.core.dhm.compiler import compile_dhm
from repro.core.dhm.engine import Engine
from repro.core.dhm.faults import DispatchError, FaultPlan
from repro.core.dhm.multitenant import Router
from repro.models.cnn import ALL_TOPOLOGIES, init_cnn

TOPO_NAME = "lenet5"
MICROBATCH = 8
N_REQUESTS = 160
LOAD_FACTORS = (0.5, 1.0, 2.0)
MAX_QUEUE = 32  # requests; shed-oldest beyond this


def _percentile(xs, q):
    return float(np.percentile(np.asarray(xs), q)) if xs else float("nan")


def _capacity_rps(plan, frame_shape) -> float:
    """Requests/s (single-frame requests) the engine can clear: one
    group-sized dispatch serves ``group`` requests, so capacity is
    group / dispatch latency."""
    eng = Engine(plan, microbatch=MICROBATCH)
    x = jax.random.normal(
        jax.random.PRNGKey(1), (eng.group,) + frame_shape
    )
    eng.infer(x)  # warm
    t0 = time.perf_counter()
    reps = 5
    for _ in range(reps):
        eng.infer(x)
    dt = (time.perf_counter() - t0) / reps
    return eng.group / dt


def _run_level(plan, frame_shape, offered_rps: float, deadline_ms: float):
    """Open-loop constant-rate load against a fresh auto-flushing engine;
    returns (requests, wall_s, stats)."""
    # Host-side frames: the generator must be able to outrun the engine
    # at overload, so per-submit cost stays off the device.
    frames = np.asarray(
        jax.random.normal(jax.random.PRNGKey(2), (N_REQUESTS,) + frame_shape)
    )
    inter = 1.0 / offered_rps
    with Engine(
        plan,
        microbatch=MICROBATCH,
        auto_flush=True,
        flush_interval_ms=2.0,
        max_queue=MAX_QUEUE,
        admission="shed_oldest",
        default_deadline_ms=deadline_ms,
    ) as eng:
        reqs = []
        t0 = time.perf_counter()
        for i in range(N_REQUESTS):
            target = t0 + i * inter
            delay = target - time.perf_counter()
            if delay > 0:
                time.sleep(delay)
            reqs.append(eng.submit(frames[i]))
        for r in reqs:
            if not r.done:
                r._event.wait(30.0)
        wall = time.perf_counter() - t0
    # Engine stopped and drained: every request must have completed.
    assert all(r.done for r in reqs), "serve_bench: request left pending"
    return reqs, wall, eng.stats()


def _run_multitenant(plan, frame_shape, capacity: float, deadline_ms: float):
    """Two tenants, each offered 1x its fair share of the host's serving
    capacity (so the pair sums to 1x — isolation measured at full load,
    not at overload), tenant 'faulted' under a seeded transient
    DispatchError storm scoped to it alone. Returns the per-tenant
    client latency lists and engine stats."""
    n = 120  # requests per tenant, single-frame
    frames = np.asarray(
        jax.random.normal(jax.random.PRNGKey(3), (2 * n,) + frame_shape)
    )
    faults = FaultPlan(
        [DispatchError(prob=0.25, tenant="faulted")], seed=11
    )
    # Per-tenant fair share with serving headroom: at exactly 1x
    # aggregate the shared dispatcher has zero slack, so ANY fault cost
    # must queue someone and the ratio measures saturation, not the
    # bulkhead. 0.7x utilization is the regime the SLOs are set for.
    inter = 1.0 / (0.7 * capacity / 2.0)
    reqs = {"clean": [], "faulted": []}
    with Router(
        fault_plan=faults,
        microbatch=MICROBATCH,
        flush_interval_ms=2.0,
        scheduler_interval_ms=1.0,
        max_queue=MAX_QUEUE,
        admission="shed_oldest",
        default_deadline_ms=deadline_ms,
        max_retries=4,
        # A retry must cost something real for the faulted tenant's p99
        # to carry the fault signal the isolation ratio compares against.
        retry_backoff_s=2e-3,
        # Pin the rung: a rare retry-exhaustion becomes a structured
        # BatchFailed, not a demotion whose per_layer recompile would
        # stall the shared scheduler mid-bench.
        allow_degraded=False,
        breaker_threshold=8,
        breaker_reset_s=0.05,
    ) as router:
        router.add("clean", plan)
        router.add("faulted", plan)
        t0 = time.perf_counter()
        for i in range(n):
            target = t0 + i * inter
            delay = target - time.perf_counter()
            if delay > 0:
                time.sleep(delay)
            reqs["clean"].append(router.submit("clean", frames[2 * i]))
            reqs["faulted"].append(
                router.submit("faulted", frames[2 * i + 1])
            )
        for rs in reqs.values():
            for r in rs:
                if not r.done:
                    r._event.wait(30.0)
        stats = {name: router.engine(name).stats() for name in reqs}
    for name, rs in reqs.items():
        assert all(r.done for r in rs), (
            f"serve_bench multitenant: {name} request left pending"
        )
    lats = {
        name: [r.latency_s * 1e3 for r in rs if r.ok]
        for name, rs in reqs.items()
    }
    return lats, stats


def run_multitenant(plan=None, capacity=None, deadline_ms=None) -> list:
    """The ``serve_multitenant`` row: bulkhead isolation as a tracked
    benchmark number, not just a chaos-suite pass/fail."""
    topo = ALL_TOPOLOGIES[TOPO_NAME]
    h, w = topo.input_shape
    frame_shape = (h, w, topo.input_channels)
    if plan is None:
        params = init_cnn(jax.random.PRNGKey(0), topo)
        plan = compile_dhm(topo, params)
    if capacity is None:
        capacity = _capacity_rps(plan, frame_shape)
    if deadline_ms is None:
        deadline_ms = max(25.0, 6.0 * MICROBATCH / capacity * 1e3)

    lats, stats = _run_multitenant(plan, frame_shape, capacity, deadline_ms)
    row = {
        "name": f"serve/{TOPO_NAME}_multitenant_faulted_vs_clean",
        "path": "serve_multitenant",
    }
    for name in ("clean", "faulted"):
        st = stats[name]
        row[f"{name}_p50_ms"] = _percentile(lats[name], 50)
        row[f"{name}_p99_ms"] = _percentile(lats[name], 99)
        row[f"{name}_shed_rate"] = (
            st.n_shed / st.n_requests if st.n_requests else 0.0
        )
        row[f"{name}_error_rate"] = (
            st.n_errors / st.n_requests if st.n_requests else 0.0
        )
    row["isolation_ratio"] = (
        row["faulted_p99_ms"] / row["clean_p99_ms"]
        if row["clean_p99_ms"] > 0
        else float("nan")
    )
    row["us_per_call"] = row["clean_p99_ms"] * 1e3  # clean-tenant p99, us
    row["derived"] = (
        f"2 tenants at 0.7x fair share ({0.7 * capacity / 2:.0f} req/s "
        f"each), tenant "
        f"'faulted' under seeded transient DispatchError (p=0.25): clean "
        f"p50 {row['clean_p50_ms']:.2f} ms p99 {row['clean_p99_ms']:.2f} "
        f"ms (shed {row['clean_shed_rate']:.1%}, errors "
        f"{row['clean_error_rate']:.1%}); faulted p50 "
        f"{row['faulted_p50_ms']:.2f} ms p99 {row['faulted_p99_ms']:.2f} "
        f"ms (shed {row['faulted_shed_rate']:.1%}, errors "
        f"{row['faulted_error_rate']:.1%}); isolation ratio "
        f"{row['isolation_ratio']:.2f}"
    )
    return [row]


def run() -> list:
    topo = ALL_TOPOLOGIES[TOPO_NAME]
    params = init_cnn(jax.random.PRNGKey(0), topo)
    plan = compile_dhm(topo, params)
    h, w = topo.input_shape
    frame_shape = (h, w, topo.input_channels)

    capacity = _capacity_rps(plan, frame_shape)
    # SLO: a few dispatch periods of headroom at capacity.
    deadline_ms = max(25.0, 6.0 * MICROBATCH / capacity * 1e3)

    rows = []
    for factor in LOAD_FACTORS:
        offered = capacity * factor
        reqs, wall, st = _run_level(plan, frame_shape, offered, deadline_ms)
        lats_ms = [r.latency_s * 1e3 for r in reqs if r.ok]
        p50 = _percentile(lats_ms, 50)
        p99 = _percentile(lats_ms, 99)
        shed_rate = st.n_shed / st.n_requests
        ddl_rate = st.n_deadline_exceeded / st.n_requests
        err_rate = st.n_errors / st.n_requests
        achieved = st.n_ok / wall
        rows.append(
            {
                "name": f"serve/{TOPO_NAME}_load_x{factor:g}",
                "us_per_call": p99 * 1e3,  # p99 latency, us
                "path": "serve_load",
                "offered_rps": offered,
                "achieved_rps": achieved,
                "p50_ms": p50,
                "p99_ms": p99,
                "shed_rate": shed_rate,
                "deadline_exceeded_rate": ddl_rate,
                "error_rate": err_rate,
                "derived": (
                    f"offered {offered:.0f} req/s ({factor:g}x capacity "
                    f"{capacity:.0f}): served {achieved:.0f} req/s, latency "
                    f"p50 {p50:.2f} ms p99 {p99:.2f} ms (SLO "
                    f"{deadline_ms:.0f} ms), shed {shed_rate:.1%}, "
                    f"deadline-exceeded {ddl_rate:.1%}, errors "
                    f"{err_rate:.1%} over {st.n_requests} single-frame "
                    f"requests (queue<={MAX_QUEUE}, shed_oldest)"
                ),
            }
        )
    # The multitenant row reuses the plan and measured capacity.
    rows += run_multitenant(plan, capacity, deadline_ms)
    return rows


if __name__ == "__main__":
    for r in run():
        print(r["name"], "|", f"{r['us_per_call']:.1f}us", "|", r["derived"])
