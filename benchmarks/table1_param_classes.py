"""Paper Table 1: fraction of quantized parameters in {0, ±1, ±2^k, other}.

Trains each topology on the synthetic task (cached), quantizes the conv
stack at the paper's selected bit-width, classifies. The paper's claim under
test: zero+one+pow2 ("multiplierless") is *by far* more than 90%.

Each named model trains with its own ``topology_seed(name)`` (dataset draw
+ init): cifar10 and svhn share one topology dataclass, and with a single
global seed they produced byte-identical trained parameters — and thus
byte-identical Table 1 rows for two supposedly different models.
"""
from __future__ import annotations

import time

import jax.numpy as jnp
import numpy as np

from repro.paper.analysis import classify_model
from repro.paper.train_cnn import get_trained_cnn

SELECTED_BITS = {"lenet5": 3, "cifar10": 6, "svhn": 6}
PAPER = {  # (zero %, one %, pow2 %, other %)
    "lenet5": (88.59, 6.31, 0.05, 5.05),
    "cifar10": (33.78, 45.32, 16.40, 4.50),
    "svhn": (37.14, 46.50, 13.62, 2.74),
}


def run() -> list:
    rows = []
    for name, bits in SELECTED_BITS.items():
        t0 = time.time()
        trained = get_trained_cnn(name)
        stats = classify_model(trained.params, bits)
        us = (time.time() - t0) * 1e6
        rows.append(
            {
                "name": f"table1/{name}",
                "us_per_call": us,
                "derived": (
                    f"bits={bits} zero={100*stats.zero:.1f}% "
                    f"one={100*stats.one:.1f}% pow2={100*stats.pow2:.1f}% "
                    f"other={100*stats.other:.1f}% "
                    f"multiplierless={100*stats.multiplierless:.1f}% "
                    f"(paper: z={PAPER[name][0]} o={PAPER[name][1]} "
                    f"p2={PAPER[name][2]} other={PAPER[name][3]})"
                ),
                "multiplierless": stats.multiplierless,
            }
        )
    return rows


if __name__ == "__main__":
    for r in run():
        print(r["name"], "|", r["derived"])
