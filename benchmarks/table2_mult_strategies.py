"""Paper Table 2: LeNet5@5bit on Cyclone V under the three multiplier
strategies. Published points: DSP 24480 blocks (7159%), LE 433,500 ALMs
(381%), LE+const 50,452 ALMs (44%)."""
from __future__ import annotations

import time

from repro.core.dhm import (
    CYCLONE_V_5CGXFC9E7,
    MultiplierStrategy,
    cnn_to_dpn,
    estimate_resources,
)
from repro.core.dhm.resources import PAPER_TABLE1
from repro.models.cnn import LENET5

PAPER = {
    MultiplierStrategy.DSP: ("dsp", 24480, 71.59),
    MultiplierStrategy.LE: ("alm", 433_500, 3.81),
    MultiplierStrategy.LE_CONST: ("alm", 50_452, 0.44),
}


def run() -> list:
    rows = []
    g = cnn_to_dpn(LENET5, bits=5)
    for strat in MultiplierStrategy:
        t0 = time.time()
        rep = estimate_resources(
            g,
            CYCLONE_V_5CGXFC9E7,
            bits=5,
            strategy=strat,
            fractions=PAPER_TABLE1["lenet5"],
        )
        us = (time.time() - t0) * 1e6
        unit, paper_n, paper_util = PAPER[strat]
        used = rep.dsp_used if unit == "dsp" else rep.logic_used
        util = rep.dsp_utilization if unit == "dsp" else rep.logic_utilization
        rows.append(
            {
                "name": f"table2/{strat.value}",
                "us_per_call": us,
                "derived": (
                    f"{unit}={used} ({100*util:.0f}%) "
                    f"fits={rep.fits} "
                    f"[paper: {paper_n} ({100*paper_util:.0f}%)]"
                ),
            }
        )
    return rows


if __name__ == "__main__":
    for r in run():
        print(r["name"], "|", r["derived"])
