"""Paper Table 3: the three accelerators on Cyclone V and Kintex 7 —
all fit, zero DSPs, ~1% memory."""
from __future__ import annotations

import time

from repro.core.dhm import (
    CYCLONE_V_5CGXFC9E7,
    KINTEX7_XC7Z045,
    MultiplierStrategy,
    cnn_to_dpn,
    estimate_resources,
)
from repro.core.dhm.resources import PAPER_TABLE1
from repro.models.cnn import CIFAR10, LENET5

PAPER_LOGIC = {  # (cyclone ALMs, kintex LUTs)
    "lenet5": (8067, 25031),
    "cifar10": (51276, 172219),
    "svhn": (39513, 136675),
}
BITS = {"lenet5": 3, "cifar10": 6, "svhn": 6}


def run() -> list:
    rows = []
    topos = {"lenet5": LENET5, "cifar10": CIFAR10, "svhn": CIFAR10}
    for name, topo in topos.items():
        g = cnn_to_dpn(topo, bits=BITS[name])
        for di, dev in enumerate((CYCLONE_V_5CGXFC9E7, KINTEX7_XC7Z045)):
            t0 = time.time()
            rep = estimate_resources(
                g,
                dev,
                bits=BITS[name],
                strategy=MultiplierStrategy.LE_CONST,
                fractions=PAPER_TABLE1[name],
            )
            us = (time.time() - t0) * 1e6
            paper = PAPER_LOGIC[name][di]
            rows.append(
                {
                    "name": f"table3/{name}/{dev.name}",
                    "us_per_call": us,
                    "derived": (
                        f"logic={rep.logic_used} ({100*rep.logic_utilization:.0f}%) "
                        f"dsp=0 mem_bits={rep.memory_bits} fits={rep.fits} "
                        f"[paper: {paper}, model/paper="
                        f"{rep.logic_used/paper:.2f}]"
                    ),
                }
            )
    return rows


if __name__ == "__main__":
    for r in run():
        print(r["name"], "|", r["derived"])
