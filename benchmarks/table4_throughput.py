"""Paper Table 4: DHM throughput vs published accelerators.

The DHM law (throughput = f_clk * ops_per_frame / input_samples) reproduces
the paper's three Haddoc2 rows; the comparison rows are published constants
(fpgaConvNet / Qiu / FINN / GPU / ASIC) used for the speedup ratios."""
from __future__ import annotations

import time

from repro.core.dhm import dhm_throughput_gops
from repro.models.cnn import CIFAR10, LENET5

ROWS = (
    # (topo, f_clk MHz, paper Gop/s, platform)
    (LENET5, 65.71, 318.48, "cyclone_v"),
    (CIFAR10, 63.89, 515.78, "cyclone_v"),
    (CIFAR10, 54.17, 437.30, "zynq_xc706"),
)
FPGACONVNET_CIFAR10 = 166.16  # Gop/s on the 24.8 Mop workload (Zynq)
FPGACONVNET_LENET5 = 185.81


def run() -> list:
    rows = []
    for topo, f, paper_gops, platform in ROWS:
        t0 = time.time()
        rep = dhm_throughput_gops(topo, f)
        us = (time.time() - t0) * 1e6
        rows.append(
            {
                "name": f"table4/{topo.name}@{platform}",
                "us_per_call": us,
                "derived": (
                    f"{rep.gops:.2f} Gop/s @ {f} MHz "
                    f"({rep.frames_per_s:.0f} fps) "
                    f"[paper: {paper_gops}, model/paper="
                    f"{rep.gops/paper_gops:.3f}]"
                ),
            }
        )
    speedup = dhm_throughput_gops(CIFAR10, 54.17).gops / FPGACONVNET_CIFAR10
    rows.append(
        {
            "name": "table4/speedup_vs_fpgaconvnet",
            "us_per_call": 0.0,
            "derived": f"x{speedup:.2f} on cifar10/Zynq [paper: x2.63]",
        }
    )
    return rows


if __name__ == "__main__":
    for r in run():
        print(r["name"], "|", r["derived"])
