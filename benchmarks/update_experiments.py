"""Inject the artifact-generated roofline tables into EXPERIMENTS.md at the
<!-- ROOFLINE_BASELINE --> / <!-- ROOFLINE_OPTIMIZED --> markers.

    PYTHONPATH=src:. python -m benchmarks.update_experiments
"""
from __future__ import annotations

import re

from benchmarks.roofline import render_markdown

MARKERS = {
    "ROOFLINE_BASELINE": ("16x16", "baseline"),
    "ROOFLINE_OPTIMIZED": (
        "16x16",
        "tpserve+seqcache+bf16attn+ceremat+mb8+bf16ssm+attnpin",
    ),
}


def main() -> None:
    with open("EXPERIMENTS.md") as f:
        text = f.read()
    for marker, (mesh, opt) in MARKERS.items():
        table = render_markdown(mesh, opt)
        block = f"<!-- {marker} -->\n\n{table}\n\n<!-- /{marker} -->"
        pat = re.compile(
            rf"<!-- {marker} -->.*?(<!-- /{marker} -->|$(?=\n###|\nReading))",
            re.S,
        )
        if f"<!-- /{marker} -->" in text:
            text = re.sub(
                rf"<!-- {marker} -->.*?<!-- /{marker} -->", block, text,
                flags=re.S,
            )
        else:
            text = text.replace(f"<!-- {marker} -->", block)
    with open("EXPERIMENTS.md", "w") as f:
        f.write(text)
    print("EXPERIMENTS.md roofline tables updated")


if __name__ == "__main__":
    main()
