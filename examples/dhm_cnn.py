import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=4")

"""The paper, end to end: train LeNet5, pick the bit-width, classify the
constants, estimate FPGA resources under all three multiplier strategies,
report DHM throughput — then run the TPU analogue: map the layer graph onto
a 4-stage spatial pipeline (shard_map + ppermute) and stream µbatches
through it.

    PYTHONPATH=src python examples/dhm_cnn.py
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.dhm import (
    CYCLONE_V_5CGXFC9E7,
    KINTEX7_XC7Z045,
    MultiplierStrategy,
    balance_report,
    cnn_to_dpn,
    dhm_throughput_gops,
    estimate_resources,
    partition_stages,
)
from repro.core.dhm.pipeline import (
    PipelineConfig,
    make_conv_stage,
    pipeline_forward,
    stack_stage_params,
)
from repro.core.dhm.resources import ParamClassFractions
from repro.kernels.stream_conv import stream_conv_block, stream_conv_block_ref
from repro.models.cnn import LENET5
from repro.paper.analysis import classify_model
from repro.paper.train_cnn import evaluate, get_trained_cnn


def main():
    print("== 1. Train + quantize (paper §4.1) ==")
    trained = get_trained_cnn("lenet5")
    print(f"LeNet5 float accuracy (synthetic task): "
          f"{trained.float_accuracy:.3f}")
    bits = 3
    stats = classify_model(trained.params, bits)
    print(f"param classes @ {bits}b: zero={100*stats.zero:.1f}% "
          f"one={100*stats.one:.1f}% pow2={100*stats.pow2:.1f}% "
          f"other={100*stats.other:.1f}% -> "
          f"{100*stats.multiplierless:.1f}% multiplierless")

    print("\n== 2. DHM resource mapping (paper §4.2, Tables 2-3) ==")
    g = cnn_to_dpn(LENET5, bits=bits)
    print(f"DPN: {len(g.actors)} actors, {g.total_multipliers()} multipliers,"
          f" {g.total_line_buffer_bits()} line-buffer bits")
    fr = ParamClassFractions(stats.zero, stats.one, stats.pow2, stats.other)
    for strat in MultiplierStrategy:
        rep = estimate_resources(
            g, CYCLONE_V_5CGXFC9E7, bits=bits, strategy=strat,
            fractions=fr,
        )
        print("  " + rep.summary())

    print("\n== 3. DHM throughput (paper Table 4) ==")
    print("  " + dhm_throughput_gops(LENET5, 65.71).summary())

    print("\n== 4. TPU analogue: spatial pipeline mapping ==")
    costs = [sum(a.flops for a in layer) for layer in g.layers()]
    costs = [c for c in costs if c > 0]
    pa = partition_stages(costs, 2)
    br = balance_report(costs, 2, n_microbatches=8)
    print(f"  layer costs {[f'{c/1e3:.0f}k' for c in costs]} -> stages "
          f"{pa.boundaries}, bottleneck {pa.bottleneck/1e3:.0f}k flops, "
          f"pipeline efficiency {br.pipeline_efficiency:.2f}")

    # Stream µbatches through a 4-stage pipeline on 4 virtual devices —
    # each stage has private devices (DHM: private resources per actor) and
    # each stage body is one fused streaming-conv actor chain
    # (conv -> bias -> tanh as a single kernel call, SAME, C == N so the
    # activation shape is homogeneous across stages).
    mesh = jax.make_mesh((4,), ("stage",))
    hw, ch, kk = 8, 4, 3
    keys = jax.random.split(jax.random.PRNGKey(0), 4)
    stage_params = stack_stage_params(
        [
            {
                "w": jax.random.normal(k, (kk, kk, ch, ch)) * 0.2,
                "b": jnp.zeros((ch,)),
            }
            for k in keys
        ]
    )
    mbs = jax.random.normal(jax.random.PRNGKey(1), (8, 2, hw, hw, ch))
    stage_fn = make_conv_stage(padding="SAME", act="tanh", pool=0)

    t0 = time.time()
    out = pipeline_forward(
        stage_fn, stage_params, mbs, mesh=mesh, cfg=PipelineConfig(4, 8)
    )
    ref = mbs.reshape(-1, hw, hw, ch)
    for i in range(4):
        ref = stream_conv_block_ref(
            ref, stage_params["w"][i], stage_params["b"][i],
            padding="SAME", act="tanh", pool=0,
        )
    ref = ref.reshape(mbs.shape)
    ok = np.allclose(np.asarray(out), np.asarray(ref), atol=1e-5)
    print(f"  4-stage shard_map conv pipeline: correct={ok} "
          f"({time.time()-t0:.2f}s, bubble={PipelineConfig(4,8).n_stages-1}"
          f"/{8+3} ticks)")

    print("\n== 5. Fused streaming-conv kernel (one matmul / row block) ==")
    # LeNet5 conv1 as one fused actor chain: conv(20,5) -> bias -> 2x2
    # max-pool -> tanh, straight from the trained parameters.
    p0 = trained.params["conv"][0]
    x = jnp.asarray(
        np.random.default_rng(0).normal(size=(8, 28, 28, 1)), jnp.float32
    )
    fused = stream_conv_block(
        x, p0["w"], p0["b"], padding="VALID", act="tanh", pool=2
    )
    unfused = stream_conv_block_ref(
        x, p0["w"], p0["b"], padding="VALID", act="tanh", pool=2
    )
    ok = np.allclose(np.asarray(fused), np.asarray(unfused), atol=1e-4)
    fused.block_until_ready()
    t0 = time.time()
    for _ in range(5):
        out = stream_conv_block(
            x, p0["w"], p0["b"], padding="VALID", act="tanh", pool=2
        )
    out.block_until_ready()
    us = (time.time() - t0) / 5 * 1e6
    print(f"  fused conv+bias+tanh+pool {tuple(x.shape)} -> "
          f"{tuple(fused.shape)}: correct={ok}, {us:.0f} us/call")
    print("OK")


if __name__ == "__main__":
    main()
