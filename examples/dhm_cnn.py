import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=4")

"""The paper, end to end: train LeNet5, pick the bit-width, classify the
constants, estimate FPGA resources under all three multiplier strategies,
report DHM throughput — then run the TPU analogue through the DHM
compiler: build a topology, ``compile_dhm`` it (topology -> DPN -> stages
-> fused-kernel plan, quantization baked in), and run the plan either
single-device or as a 4-stage spatial pipeline (shard_map + ppermute).

    PYTHONPATH=src python examples/dhm_cnn.py
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.dhm import (
    CYCLONE_V_5CGXFC9E7,
    MultiplierStrategy,
    QuantSpec,
    balance_report,
    cnn_to_dpn,
    compile_dhm,
    dhm_throughput_gops,
    estimate_resources,
)
from repro.core.dhm.resources import ParamClassFractions
from repro.models.cnn import LENET5, cnn_apply_reference
from repro.paper.analysis import classify_model
from repro.paper.train_cnn import get_trained_cnn


def main():
    print("== 1. Train + quantize (paper §4.1) ==")
    trained = get_trained_cnn("lenet5")
    print(f"LeNet5 float accuracy (synthetic task): "
          f"{trained.float_accuracy:.3f}")
    bits = 3
    stats = classify_model(trained.params, bits)
    print(f"param classes @ {bits}b: zero={100*stats.zero:.1f}% "
          f"one={100*stats.one:.1f}% pow2={100*stats.pow2:.1f}% "
          f"other={100*stats.other:.1f}% -> "
          f"{100*stats.multiplierless:.1f}% multiplierless")

    print("\n== 2. DHM resource mapping (paper §4.2, Tables 2-3) ==")
    g = cnn_to_dpn(LENET5, bits=bits)
    print(f"DPN: {len(g.actors)} actors, {g.total_multipliers()} multipliers,"
          f" {g.total_line_buffer_bits()} line-buffer bits")
    fr = ParamClassFractions(stats.zero, stats.one, stats.pow2, stats.other)
    for strat in MultiplierStrategy:
        rep = estimate_resources(
            g, CYCLONE_V_5CGXFC9E7, bits=bits, strategy=strat,
            fractions=fr,
        )
        print("  " + rep.summary())

    print("\n== 3. DHM throughput (paper Table 4) ==")
    print("  " + dhm_throughput_gops(LENET5, 65.71).summary())

    print("\n== 4. Compile: topology -> DPN -> stages -> fused plan ==")
    # The whole TPU mapping is now one pass: compile_dhm expands the
    # topology to the paper-granularity actor graph, partitions it with the
    # min-max DP mapper (costed from actor FLOP payloads), and emits fused
    # conv->bias->act(->pool->quant) kernel closures per stage, with the
    # paper's 3-bit quantization baked into the plan.
    plan = compile_dhm(
        LENET5, trained.params,
        quant=QuantSpec(weight_bits=bits, act_bits=bits),
        n_stages=2,
    )
    br = balance_report(
        [s.cost_flops for s in plan.stages], plan.n_stages, n_microbatches=8
    )
    print(f"  {plan.n_stages} stages over {len(plan.conv_params)} conv "
          f"layers: boundaries {plan.assignment.boundaries}, bottleneck "
          f"{plan.assignment.bottleneck/1e3:.0f}k flops, pipeline "
          f"efficiency {br.pipeline_efficiency:.2f}")
    x = jnp.asarray(
        np.random.default_rng(0).normal(size=(8, 28, 28, 1)), jnp.float32
    )
    ref = cnn_apply_reference(trained.params, LENET5, x,
                              weight_bits=bits, act_bits=bits)
    logits = plan(x)
    logits.block_until_ready()
    t0 = time.time()
    for _ in range(5):
        out = plan(x)
    out.block_until_ready()
    us = (time.time() - t0) / 5 * 1e6
    ok = np.allclose(np.asarray(logits), np.asarray(ref), atol=1e-4)
    print(f"  quantized compiled plan {tuple(x.shape)} -> "
          f"{tuple(logits.shape)}: matches fake-quant reference={ok}, "
          f"{us:.0f} us/call ({8 / (us * 1e-6):.0f} frames/s)")

    print("\n== 4b. Generalized layer vocabulary: cifar10_full ==")
    # Beyond the paper's three nets: Caffe's cifar10_full uses OVERLAPPING
    # 3x3/stride-2 pooling (window != stride). The same compile_dhm pass
    # lowers it — generalized fused epilogue, pool-aware row blocking —
    # with no topology-specific code.
    from repro.models.cnn import CIFAR10_FULL, init_cnn as _init

    full_params = _init(jax.random.PRNGKey(2), CIFAR10_FULL)
    full_plan = compile_dhm(
        CIFAR10_FULL, full_params, quant=QuantSpec(weight_bits=6, act_bits=6)
    )
    xf = jax.random.normal(jax.random.PRNGKey(3), (4, 32, 32, 3))
    ref_f = cnn_apply_reference(full_params, CIFAR10_FULL, xf,
                                weight_bits=6, act_bits=6)
    ok = np.allclose(np.asarray(full_plan(xf)), np.asarray(ref_f), atol=1e-4)
    shapes = " -> ".join(
        f"{h}x{w}" for (_, _, _, h, w) in CIFAR10_FULL.conv_shapes()
    )
    print(f"  cifar10_full (3x3/stride-2 overlapping pool, conv dims "
          f"{shapes}): quantized plan matches reference={ok}")

    print("\n== 5. THE SAME LeNet5 plan, spatial pipeline on a mesh ==")
    # The quantized LeNet5 plan from step 4 — heterogeneous stages
    # (28x28x1 -> 12x12x20 -> 4x4x50) — streams through the spatial
    # pipeline directly: each stage gets a private device group (DHM:
    # private resources per actor), activations flow over boxed ICI edges
    # sized from the compiler's per-stage StageIOSpec, and a 2D
    # (stage, data) mesh adds data-parallel batch sharding on top.
    for st in plan.stages:
        print(f"  stage {st.index}: {st.io.in_shape} -> {st.io.out_shape}")
    mesh = jax.make_mesh((2, 2), ("stage", "data"))
    mbs = jnp.asarray(
        np.random.default_rng(1).normal(size=(6, 4, 28, 28, 1)), jnp.float32
    )
    t0 = time.time()
    out = plan.run_pipelined(mbs, mesh=mesh, data_axis="data")
    seq = jnp.stack([plan.features(mbs[i]) for i in range(6)])
    ok = np.allclose(np.asarray(out), np.asarray(seq), atol=1e-5)
    print(f"  2-stage heterogeneous pipeline on (2 stage x 2 data): "
          f"matches single-device plan={ok} ({time.time()-t0:.2f}s, "
          f"bubble={plan.n_stages-1}/{6+1} ticks)")

    print("\n== 6. Serving engine: µbatch queue over the same plan ==")
    # The Engine is the serving front end: requests queue up, get packed
    # into fixed micro-batches, and run through the plan's DONATED jitted
    # closure (double-buffered under async dispatch); stats track
    # per-request latency and engine throughput.
    from repro.core.dhm import Engine

    eng = Engine(plan, microbatch=8)
    reqs = [
        eng.submit(jnp.asarray(
            np.random.default_rng(10 + i).normal(
                size=(np.random.default_rng(20 + i).integers(1, 6),
                      28, 28, 1)
            ), jnp.float32,
        ))
        for i in range(5)
    ]
    eng.flush()
    total = sum(r.result().shape[0] for r in reqs)
    print(f"  served {len(reqs)} requests ({total} frames); "
          f"{eng.stats().summary()}")
    print("OK")


if __name__ == "__main__":
    main()
