import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=4")

"""The paper, end to end: train LeNet5, pick the bit-width, classify the
constants, estimate FPGA resources under all three multiplier strategies,
report DHM throughput — then run the TPU analogue through the DHM
compiler: build a topology, ``compile_dhm`` it (topology -> DPN -> stages
-> fused-kernel plan, quantization baked in), and run the plan either
single-device or as a 4-stage spatial pipeline (shard_map + ppermute).

    PYTHONPATH=src python examples/dhm_cnn.py
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.dhm import (
    CYCLONE_V_5CGXFC9E7,
    MultiplierStrategy,
    QuantSpec,
    balance_report,
    cnn_to_dpn,
    compile_dhm,
    dhm_throughput_gops,
    estimate_resources,
)
from repro.core.dhm.resources import ParamClassFractions
from repro.models.cnn import CNNTopology, ConvLayerSpec, LENET5, cnn_apply_reference
from repro.paper.analysis import classify_model
from repro.paper.train_cnn import get_trained_cnn


def main():
    print("== 1. Train + quantize (paper §4.1) ==")
    trained = get_trained_cnn("lenet5")
    print(f"LeNet5 float accuracy (synthetic task): "
          f"{trained.float_accuracy:.3f}")
    bits = 3
    stats = classify_model(trained.params, bits)
    print(f"param classes @ {bits}b: zero={100*stats.zero:.1f}% "
          f"one={100*stats.one:.1f}% pow2={100*stats.pow2:.1f}% "
          f"other={100*stats.other:.1f}% -> "
          f"{100*stats.multiplierless:.1f}% multiplierless")

    print("\n== 2. DHM resource mapping (paper §4.2, Tables 2-3) ==")
    g = cnn_to_dpn(LENET5, bits=bits)
    print(f"DPN: {len(g.actors)} actors, {g.total_multipliers()} multipliers,"
          f" {g.total_line_buffer_bits()} line-buffer bits")
    fr = ParamClassFractions(stats.zero, stats.one, stats.pow2, stats.other)
    for strat in MultiplierStrategy:
        rep = estimate_resources(
            g, CYCLONE_V_5CGXFC9E7, bits=bits, strategy=strat,
            fractions=fr,
        )
        print("  " + rep.summary())

    print("\n== 3. DHM throughput (paper Table 4) ==")
    print("  " + dhm_throughput_gops(LENET5, 65.71).summary())

    print("\n== 4. Compile: topology -> DPN -> stages -> fused plan ==")
    # The whole TPU mapping is now one pass: compile_dhm expands the
    # topology to the paper-granularity actor graph, partitions it with the
    # min-max DP mapper (costed from actor FLOP payloads), and emits fused
    # conv->bias->act(->pool->quant) kernel closures per stage, with the
    # paper's 3-bit quantization baked into the plan.
    plan = compile_dhm(
        LENET5, trained.params,
        quant=QuantSpec(weight_bits=bits, act_bits=bits),
        n_stages=2,
    )
    br = balance_report(
        [s.cost_flops for s in plan.stages], plan.n_stages, n_microbatches=8
    )
    print(f"  {plan.n_stages} stages over {len(plan.conv_params)} conv "
          f"layers: boundaries {plan.assignment.boundaries}, bottleneck "
          f"{plan.assignment.bottleneck/1e3:.0f}k flops, pipeline "
          f"efficiency {br.pipeline_efficiency:.2f}")
    x = jnp.asarray(
        np.random.default_rng(0).normal(size=(8, 28, 28, 1)), jnp.float32
    )
    ref = cnn_apply_reference(trained.params, LENET5, x,
                              weight_bits=bits, act_bits=bits)
    logits = plan(x)
    logits.block_until_ready()
    t0 = time.time()
    for _ in range(5):
        out = plan(x)
    out.block_until_ready()
    us = (time.time() - t0) / 5 * 1e6
    ok = np.allclose(np.asarray(logits), np.asarray(ref), atol=1e-4)
    print(f"  quantized compiled plan {tuple(x.shape)} -> "
          f"{tuple(logits.shape)}: matches fake-quant reference={ok}, "
          f"{us:.0f} us/call ({8 / (us * 1e-6):.0f} frames/s)")

    print("\n== 4b. Generalized layer vocabulary: cifar10_full ==")
    # Beyond the paper's three nets: Caffe's cifar10_full uses OVERLAPPING
    # 3x3/stride-2 pooling (window != stride). The same compile_dhm pass
    # lowers it — generalized fused epilogue, pool-aware row blocking —
    # with no topology-specific code.
    from repro.models.cnn import CIFAR10_FULL, init_cnn as _init

    full_params = _init(jax.random.PRNGKey(2), CIFAR10_FULL)
    full_plan = compile_dhm(
        CIFAR10_FULL, full_params, quant=QuantSpec(weight_bits=6, act_bits=6)
    )
    xf = jax.random.normal(jax.random.PRNGKey(3), (4, 32, 32, 3))
    ref_f = cnn_apply_reference(full_params, CIFAR10_FULL, xf,
                                weight_bits=6, act_bits=6)
    ok = np.allclose(np.asarray(full_plan(xf)), np.asarray(ref_f), atol=1e-4)
    shapes = " -> ".join(
        f"{h}x{w}" for (_, _, _, h, w) in CIFAR10_FULL.conv_shapes()
    )
    print(f"  cifar10_full (3x3/stride-2 overlapping pool, conv dims "
          f"{shapes}): quantized plan matches reference={ok}")

    print("\n== 5. Same plan, spatial pipeline on 4 virtual devices ==")
    # A homogeneous 4-conv-layer topology (SAME, pool=0, C == N) so every
    # compiled stage is shape-identical; the SAME compiled plan then runs
    # on a mesh — each stage gets a private device group (DHM: private
    # resources per actor) and µbatches stream over ICI.
    pipe_topo = CNNTopology(
        name="pipe4", input_hw=8, input_channels=4,
        conv_layers=tuple(
            ConvLayerSpec(n_out=4, kernel=3, padding="SAME", pool=0,
                          act="tanh")
            for _ in range(4)
        ),
        fc_dims=(), n_classes=2,
    )
    from repro.models.cnn import init_cnn

    pipe_plan = compile_dhm(
        pipe_topo, init_cnn(jax.random.PRNGKey(0), pipe_topo), n_stages=4
    )
    mesh = jax.make_mesh((4,), ("stage",))
    mbs = jax.random.normal(jax.random.PRNGKey(1), (8, 2, 8, 8, 4))
    t0 = time.time()
    out = pipe_plan.run_pipelined(mbs, mesh=mesh)
    seq = pipe_plan.features(mbs.reshape(-1, 8, 8, 4)).reshape(mbs.shape)
    ok = np.allclose(np.asarray(out), np.asarray(seq), atol=1e-5)
    print(f"  4-stage compiled pipeline: matches single-device plan={ok} "
          f"({time.time()-t0:.2f}s, bubble={pipe_plan.n_stages-1}"
          f"/{8+3} ticks)")
    print("OK")


if __name__ == "__main__":
    main()
