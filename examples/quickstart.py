"""Quickstart: build a small LM, train a few steps, apply the paper's
pow2 (constant-specialized-multiplier) quantization, and serve tokens.

    PYTHONPATH=src python examples/quickstart.py
"""
import dataclasses
import time

import jax
import jax.numpy as jnp

from repro.configs import get_arch
from repro.data import TokenStreamConfig, synthetic_token_batches
from repro.models import transformer as T
from repro.models.layers import pack_linear_pow2
from repro.optim import AdamWConfig, adamw_init, adamw_update, clip_by_global_norm


def main():
    # A reduced qwen2.5 — same family, CPU-sized (the full configs are
    # exercised by the multi-pod dry-run, not on this host).
    cfg = get_arch("qwen2.5-3b").scaled_down(
        n_layers=4, d_model=128, vocab_size=512
    )
    print(f"arch={cfg.name} (reduced): {cfg.n_layers}L d={cfg.d_model}")

    params = T.init_params(jax.random.PRNGKey(0), cfg)
    opt_cfg = AdamWConfig(weight_decay=0.01)
    opt = adamw_init(params, opt_cfg)

    stream_cfg = TokenStreamConfig(
        vocab_size=cfg.vocab_size, seq_len=64, batch_size=16
    )
    batches = synthetic_token_batches(stream_cfg, seed=0)
    print(f"token stream loss floor: {stream_cfg.loss_floor:.3f} nats")

    @jax.jit
    def step(params, opt, tokens):
        def loss_fn(p):
            loss, m = T.train_loss(p, cfg, {"tokens": tokens}, vocab_chunk=256)
            return loss

        loss, grads = jax.value_and_grad(loss_fn)(params)
        grads, _ = clip_by_global_norm(grads, 1.0)
        params, opt = adamw_update(grads, opt, params, opt_cfg,
                                   jnp.asarray(1e-3))
        return params, opt, loss

    t0 = time.time()
    for i in range(30):
        batch = next(batches)
        params, opt, loss = step(params, opt, jnp.asarray(batch["tokens"]))
        if i % 10 == 0 or i == 29:
            print(f"step {i:3d} loss {float(loss):.3f}")
    print(f"trained 30 steps in {time.time() - t0:.1f}s")

    # --- The paper's tactic: constant-specialize the weights (pow2 codes).
    from repro.core.quant.pow2 import pow2_codes
    from repro.core.quant import classify_params
    w = params["stack"]["units"][0]["ffn"]["up"]["w"]
    codes, scale = pow2_codes(w[0], channel_axis=1)
    nz = float(jnp.mean(codes == 0))
    print(f"pow2-quantized ffn/up: {100*nz:.1f}% zero codes, "
          f"4 bits/weight (4x bandwidth saving vs bf16)")

    # --- Serve: prefill + a few greedy decode steps.
    prompt = jnp.asarray(next(batches)["tokens"])[:2, :16]
    logits, cache = T.prefill(params, cfg, prompt, max_len=32)
    toks = []
    tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    for t in range(8):
        logits, cache = T.decode_step(
            params, cfg, tok, cache, jnp.asarray(16 + t)
        )
        tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
        toks.append(int(tok[0, 0]))
    print("greedy continuation:", toks)
    print("OK")


if __name__ == "__main__":
    main()
