"""Batched serving demo: prefill a batch of prompts, decode with greedy or
temperature sampling, optionally with pow2-packed ("constant-specialized")
weights for every linear layer — the paper's tactic as an LM serving
feature (4 bits/weight).

    PYTHONPATH=src python examples/serve.py --batch 4 --new-tokens 16
    PYTHONPATH=src python examples/serve.py --pow2
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_arch
from repro.models import transformer as T
from repro.models.layers import pack_params_pow2

# Pack every linear in the stack to pow2 codes (serving format). Stacked
# scan-layer weights are handled inside pack_linear_pow2 (per-layer
# scales via vmap, odd widths zero-padded) — the packing logic lives in
# repro.models.layers, shared with the single-linear path.
quantize_stack_pow2 = pack_params_pow2


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2.5-3b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--pow2", action="store_true",
                    help="serve with pow2-packed weights (paper tactic)")
    args = ap.parse_args()

    cfg = get_arch(args.arch).scaled_down(n_layers=4, d_model=128,
                                          vocab_size=1024)
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    if args.pow2:
        n_before = sum(
            x.size * x.dtype.itemsize
            for x in jax.tree_util.tree_leaves(params["stack"])
        )
        params = dict(params, stack=quantize_stack_pow2(params["stack"]))
        n_after = sum(
            x.size * x.dtype.itemsize
            for x in jax.tree_util.tree_leaves(params["stack"])
        )
        print(f"pow2-packed stack: {n_before/1e6:.1f} MB -> "
              f"{n_after/1e6:.1f} MB ({n_before/n_after:.2f}x)")

    b, p_len = args.batch, args.prompt_len
    prompts = jax.random.randint(jax.random.PRNGKey(1), (b, p_len), 0,
                                 cfg.vocab_size)
    max_len = p_len + args.new_tokens + 1

    t0 = time.time()
    logits, cache = T.prefill(params, cfg, prompts, max_len=max_len)
    prefill_s = time.time() - t0
    print(f"prefill: batch={b} len={p_len} in {prefill_s*1e3:.0f} ms "
          f"({b*p_len/prefill_s:.0f} tok/s)")

    decode = jax.jit(
        lambda pr, tok, cache, idx: T.decode_step(pr, cfg, tok, cache, idx)
    )
    key = jax.random.PRNGKey(2)
    tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    out_tokens = [tok]
    t0 = time.time()
    for t in range(args.new_tokens):
        logits, cache = decode(params, tok, cache, jnp.asarray(p_len + t))
        if args.temperature > 0:
            key, sk = jax.random.split(key)
            tok = jax.random.categorical(
                sk, logits / args.temperature, axis=-1
            )[:, None].astype(jnp.int32)
        else:
            tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
        out_tokens.append(tok)
    decode_s = time.time() - t0
    seqs = jnp.concatenate(out_tokens, axis=1)
    print(f"decode: {args.new_tokens} tokens x {b} seqs in "
          f"{decode_s*1e3:.0f} ms ({b*args.new_tokens/decode_s:.1f} tok/s)")
    print("sample continuation:", [int(t) for t in seqs[0][:12]])
    print("OK")


if __name__ == "__main__":
    main()
