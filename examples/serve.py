"""Batched serving demo: prefill a batch of prompts, decode with greedy or
temperature sampling, optionally with pow2-packed ("constant-specialized")
weights for every linear layer — the paper's tactic as an LM serving
feature (4 bits/weight).

    PYTHONPATH=src python examples/serve.py --batch 4 --new-tokens 16
    PYTHONPATH=src python examples/serve.py --pow2
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_arch
from repro.models import transformer as T
from repro.models.layers import pack_linear_pow2


def quantize_stack_pow2(params: dict) -> dict:
    """Pack every linear in the stack to pow2 codes (serving format)."""

    def walk(node):
        if isinstance(node, dict):
            if "w" in node and getattr(node["w"], "ndim", 0) >= 2:
                return pack_linear_pow2_nd(node)
            return {k: walk(v) for k, v in node.items()}
        if isinstance(node, list):
            return [walk(v) for v in node]
        return node

    def pack_linear_pow2_nd(p):
        w = p["w"]
        if w.ndim == 2:
            return pack_linear_pow2(p)
        # Stacked (scan) weights: per-layer quantization via vmap so every
        # layer keeps its own per-channel scales. Odd layer widths get a
        # zero pad column for packing (quantize_weights-style); the stored
        # scale keeps the true width so the decode path slices it back.
        from repro.core.quant.packing import pack_codes_u4
        from repro.core.quant.pow2 import pow2_codes

        lead = w.shape[:-2]
        n = w.shape[-1]
        if n % 2:
            w = jnp.pad(w, [(0, 0)] * (w.ndim - 1) + [(0, 1)])
        w2 = w.reshape((-1,) + w.shape[-2:])
        codes, scale = jax.vmap(
            lambda wi: pow2_codes(wi, channel_axis=1)
        )(w2)  # codes (L,K,N_even), scale (L,1,N_even)
        out = {
            "codes": pack_codes_u4(codes).reshape(
                lead + (w.shape[-2], w.shape[-1] // 2)
            ),
            "scale": scale[..., :n].reshape(lead + (1, n)),
        }
        if "b" in p:
            out["b"] = p["b"]
        return out

    return walk(params)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2.5-3b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--pow2", action="store_true",
                    help="serve with pow2-packed weights (paper tactic)")
    args = ap.parse_args()

    cfg = get_arch(args.arch).scaled_down(n_layers=4, d_model=128,
                                          vocab_size=1024)
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    if args.pow2:
        n_before = sum(
            x.size * x.dtype.itemsize
            for x in jax.tree_util.tree_leaves(params["stack"])
        )
        params = dict(params, stack=quantize_stack_pow2(params["stack"]))
        n_after = sum(
            x.size * x.dtype.itemsize
            for x in jax.tree_util.tree_leaves(params["stack"])
        )
        print(f"pow2-packed stack: {n_before/1e6:.1f} MB -> "
              f"{n_after/1e6:.1f} MB ({n_before/n_after:.2f}x)")

    b, p_len = args.batch, args.prompt_len
    prompts = jax.random.randint(jax.random.PRNGKey(1), (b, p_len), 0,
                                 cfg.vocab_size)
    max_len = p_len + args.new_tokens + 1

    t0 = time.time()
    logits, cache = T.prefill(params, cfg, prompts, max_len=max_len)
    prefill_s = time.time() - t0
    print(f"prefill: batch={b} len={p_len} in {prefill_s*1e3:.0f} ms "
          f"({b*p_len/prefill_s:.0f} tok/s)")

    decode = jax.jit(
        lambda pr, tok, cache, idx: T.decode_step(pr, cfg, tok, cache, idx)
    )
    key = jax.random.PRNGKey(2)
    tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    out_tokens = [tok]
    t0 = time.time()
    for t in range(args.new_tokens):
        logits, cache = decode(params, tok, cache, jnp.asarray(p_len + t))
        if args.temperature > 0:
            key, sk = jax.random.split(key)
            tok = jax.random.categorical(
                sk, logits / args.temperature, axis=-1
            )[:, None].astype(jnp.int32)
        else:
            tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
        out_tokens.append(tok)
    decode_s = time.time() - t0
    seqs = jnp.concatenate(out_tokens, axis=1)
    print(f"decode: {args.new_tokens} tokens x {b} seqs in "
          f"{decode_s*1e3:.0f} ms ({b*args.new_tokens/decode_s:.1f} tok/s)")
    print("sample continuation:", [int(t) for t in seqs[0][:12]])
    print("OK")


if __name__ == "__main__":
    main()
