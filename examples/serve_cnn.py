import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

"""CNN serving demo: compile a topology once, stand up the serving Engine,
and stream inference requests through it — single-device (micro-batch
queue + double-buffered donated closures), fault-tolerant (deadline SLOs
through the background flusher, admission control, injected faults healed
by retry or one-rung demotion), and spatially pipelined on a
(stage, data) host-device mesh (every compiled stage owns a private
device group; heterogeneous activations flow over boxed ICI edges).

    PYTHONPATH=src python examples/serve_cnn.py
    PYTHONPATH=src python examples/serve_cnn.py --topology cifar10_full \
        --bits 6 --requests 32
"""
import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.dhm import Engine, QuantSpec, compile_dhm
from repro.core.dhm.faults import DispatchError, FaultPlan, NaNActivation
from repro.models.cnn import ALL_TOPOLOGIES, init_cnn


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--topology", default="cifar10",
                    choices=sorted(ALL_TOPOLOGIES))
    ap.add_argument("--bits", type=int, default=0,
                    help="fixed-point bits for weights + feature stream "
                         "(0 = fp32)")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--microbatch", type=int, default=8)
    ap.add_argument("--stages", type=int, default=0,
                    help="pipeline stages for the mesh engine "
                         "(0 = one per conv layer, capped at 3)")
    args = ap.parse_args()

    topo = ALL_TOPOLOGIES[args.topology]
    quant = (
        QuantSpec(weight_bits=args.bits, act_bits=args.bits)
        if args.bits else QuantSpec()
    )
    params = init_cnn(jax.random.PRNGKey(0), topo)
    rng = np.random.default_rng(0)
    h, w = topo.input_shape

    def random_request(i):
        n = int(rng.integers(1, args.microbatch + 1))
        return jnp.asarray(
            rng.normal(size=(n, h, w, topo.input_channels)), jnp.float32
        )

    print(f"== single-device engine: {topo.name}, "
          f"{'fp32' if not args.bits else f'{args.bits}-bit'} plan ==")
    plan = compile_dhm(topo, params, quant=quant)
    eng = Engine(plan, microbatch=args.microbatch)
    reqs = [eng.submit(random_request(i)) for i in range(args.requests)]
    eng.flush()
    total = sum(r.result().shape[0] for r in reqs)
    x0 = random_request(0)
    np.testing.assert_allclose(
        np.asarray(eng.infer(x0)), np.asarray(plan(x0)), rtol=1e-4, atol=1e-4
    )
    print(f"  served {len(reqs)} requests / {total} frames, logits match "
          f"the plan; {eng.stats().summary()}")

    print("\n== SLO serving: background flusher, 25 ms deadlines, "
          "shed-oldest admission ==")
    with Engine(
        plan, microbatch=args.microbatch, auto_flush=True,
        max_queue=2 * args.requests, admission="shed_oldest",
        default_deadline_ms=25.0,
    ) as slo_eng:
        slo_reqs = [slo_eng.submit(random_request(i))
                    for i in range(args.requests)]
        done, missed = [], []
        for r in slo_reqs:
            try:
                r.result(timeout=30.0)
                done.append(r)
            except Exception as e:      # DeadlineExceeded / Shed: structured
                missed.append(f"{type(e).__name__}: {e}")
    print(f"  {len(done)}/{len(slo_reqs)} requests met their SLO; "
          f"{slo_eng.stats().summary()}")
    for msg in missed[:3]:
        print(f"  missed: {msg}")

    print("\n== chaos: injected dispatch errors + NaN activations ==")
    chaos_eng = Engine(
        plan, microbatch=args.microbatch,
        fault_plan=FaultPlan([
            DispatchError(at=0, times=2),     # transient: retry heals
            NaNActivation(at=3, times=1),     # corrupted logits: caught
        ]),
        retry_backoff_s=1e-3,
    )
    for i in range(4):
        xi = random_request(i)
        np.testing.assert_allclose(
            np.asarray(chaos_eng.infer(xi)), np.asarray(plan(xi)),
            rtol=1e-4, atol=1e-4,
        )
    st = chaos_eng.stats()
    print(f"  survived {st.n_retries} injected failures by retry "
          f"(rung: {st.rung}, demotions: {st.n_demotions}); logits verified "
          f"against the plan; {st.summary()}")

    print("\n== chaos: persistent fused-rung failure -> demotion ladder ==")
    demoted_eng = Engine(
        plan, microbatch=args.microbatch,
        fault_plan=FaultPlan(
            [DispatchError(at=0, times=None, rung="fused")]
        ),
        max_retries=1, retry_backoff_s=1e-3,
    )
    np.testing.assert_allclose(
        np.asarray(demoted_eng.infer(x0)), np.asarray(plan(x0)),
        rtol=1e-4, atol=1e-4,
    )
    for d in demoted_eng.demotions:
        print(f"  demoted off rung {d['rung']!r}: {d['reason']}")
    print(f"  now serving on rung {demoted_eng.rung!r}, logits still match "
          f"the healthy plan")

    n_dev = len(jax.devices())
    n_stages = args.stages or min(3, len(topo.conv_layers))
    data = max(1, min(2, n_dev // n_stages))
    if n_stages * data > n_dev or n_stages < 2:
        print(f"\n(skipping mesh engine: need >= {max(2, n_stages) * data} "
              f"devices, have {n_dev})")
        return
    print(f"\n== pipelined engine: ({n_stages} stage x {data} data) mesh, "
          f"{n_dev} host devices ==")
    plan_s = compile_dhm(topo, params, quant=quant, n_stages=n_stages)
    for st in plan_s.stages:
        print(f"  stage {st.index}: {st.io.in_shape} -> {st.io.out_shape} "
              f"({st.cost_flops / 1e6:.2f} Mflop)")
    mesh_axes = (("stage", "data") if data > 1 else ("stage",))
    mesh_shape = (n_stages, data) if data > 1 else (n_stages,)
    mesh = jax.make_mesh(mesh_shape, mesh_axes)
    engp = Engine(
        plan_s, microbatch=args.microbatch, mesh=mesh, n_microbatches=4,
        data_axis="data" if data > 1 else None,
    )
    reqs = [engp.submit(random_request(i)) for i in range(args.requests)]
    engp.flush()
    total = sum(r.result().shape[0] for r in reqs)
    np.testing.assert_allclose(
        np.asarray(engp.infer(x0)), np.asarray(plan_s(x0)),
        rtol=1e-4, atol=1e-4,
    )
    print(f"  served {len(reqs)} requests / {total} frames through the "
          f"spatial pipeline, logits match the single-device plan; "
          f"{engp.stats().summary()}")
    print("OK")


if __name__ == "__main__":
    main()
