import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

"""CNN serving demo: compile a topology once, stand up the serving Engine,
and stream inference requests through it — single-device (micro-batch
queue + double-buffered donated closures), fault-tolerant (deadline SLOs
through the background flusher, admission control, injected faults healed
by retry or one-rung demotion), multi-tenant (two plans behind one
Router: a faulted tenant trips its circuit breaker while the other's
SLOs hold, then a verified hot swap + rollback), and spatially
pipelined on a (stage, data) host-device mesh (every compiled stage
owns a private device group; heterogeneous activations flow over boxed
ICI edges).

    PYTHONPATH=src python examples/serve_cnn.py
    PYTHONPATH=src python examples/serve_cnn.py --topology cifar10_full \
        --bits 6 --requests 32
"""
import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.dhm import Engine, QuantSpec, Router, compile_dhm
from repro.core.dhm.faults import DispatchError, FaultPlan, NaNActivation
from repro.models.cnn import ALL_TOPOLOGIES, init_cnn


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--topology", default="cifar10",
                    choices=sorted(ALL_TOPOLOGIES))
    ap.add_argument("--bits", type=int, default=0,
                    help="fixed-point bits for weights + feature stream "
                         "(0 = fp32)")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--microbatch", type=int, default=8)
    ap.add_argument("--stages", type=int, default=0,
                    help="pipeline stages for the mesh engine "
                         "(0 = one per conv layer, capped at 3)")
    args = ap.parse_args()

    topo = ALL_TOPOLOGIES[args.topology]
    quant = (
        QuantSpec(weight_bits=args.bits, act_bits=args.bits)
        if args.bits else QuantSpec()
    )
    params = init_cnn(jax.random.PRNGKey(0), topo)
    rng = np.random.default_rng(0)
    h, w = topo.input_shape

    def random_request(i):
        n = int(rng.integers(1, args.microbatch + 1))
        return jnp.asarray(
            rng.normal(size=(n, h, w, topo.input_channels)), jnp.float32
        )

    print(f"== single-device engine: {topo.name}, "
          f"{'fp32' if not args.bits else f'{args.bits}-bit'} plan ==")
    plan = compile_dhm(topo, params, quant=quant)
    eng = Engine(plan, microbatch=args.microbatch)
    reqs = [eng.submit(random_request(i)) for i in range(args.requests)]
    eng.flush()
    total = sum(r.result().shape[0] for r in reqs)
    x0 = random_request(0)
    np.testing.assert_allclose(
        np.asarray(eng.infer(x0)), np.asarray(plan(x0)), rtol=1e-4, atol=1e-4
    )
    print(f"  served {len(reqs)} requests / {total} frames, logits match "
          f"the plan; {eng.stats().summary()}")

    print("\n== SLO serving: background flusher, 25 ms deadlines, "
          "shed-oldest admission ==")
    with Engine(
        plan, microbatch=args.microbatch, auto_flush=True,
        max_queue=2 * args.requests, admission="shed_oldest",
        default_deadline_ms=25.0,
    ) as slo_eng:
        slo_reqs = [slo_eng.submit(random_request(i))
                    for i in range(args.requests)]
        done, missed = [], []
        for r in slo_reqs:
            try:
                r.result(timeout=30.0)
                done.append(r)
            except Exception as e:      # DeadlineExceeded / Shed: structured
                missed.append(f"{type(e).__name__}: {e}")
    print(f"  {len(done)}/{len(slo_reqs)} requests met their SLO; "
          f"{slo_eng.stats().summary()}")
    for msg in missed[:3]:
        print(f"  missed: {msg}")

    print("\n== chaos: injected dispatch errors + NaN activations ==")
    chaos_eng = Engine(
        plan, microbatch=args.microbatch,
        fault_plan=FaultPlan([
            DispatchError(at=0, times=2),     # transient: retry heals
            NaNActivation(at=3, times=1),     # corrupted logits: caught
        ]),
        retry_backoff_s=1e-3,
    )
    for i in range(4):
        xi = random_request(i)
        np.testing.assert_allclose(
            np.asarray(chaos_eng.infer(xi)), np.asarray(plan(xi)),
            rtol=1e-4, atol=1e-4,
        )
    st = chaos_eng.stats()
    print(f"  survived {st.n_retries} injected failures by retry "
          f"(rung: {st.rung}, demotions: {st.n_demotions}); logits verified "
          f"against the plan; {st.summary()}")

    print("\n== chaos: persistent fused-rung failure -> demotion ladder ==")
    demoted_eng = Engine(
        plan, microbatch=args.microbatch,
        fault_plan=FaultPlan(
            [DispatchError(at=0, times=None, rung="fused")]
        ),
        max_retries=1, retry_backoff_s=1e-3,
    )
    np.testing.assert_allclose(
        np.asarray(demoted_eng.infer(x0)), np.asarray(plan(x0)),
        rtol=1e-4, atol=1e-4,
    )
    for d in demoted_eng.demotions:
        print(f"  demoted off rung {d['rung']!r}: {d['reason']}")
    print(f"  now serving on rung {demoted_eng.rung!r}, logits still match "
          f"the healthy plan")

    print("\n== multi-tenant router: two plans, bulkheads, circuit "
          "breaker, hot swap ==")
    lenet = ALL_TOPOLOGIES["lenet5"]
    cifar = ALL_TOPOLOGIES["cifar10"]
    plan_mnist = compile_dhm(lenet, init_cnn(jax.random.PRNGKey(0), lenet))
    plan_cifar = compile_dhm(cifar, init_cnn(jax.random.PRNGKey(0), cifar))

    def tenant_frames(t, n, seed):
        th, tw = t.input_shape
        return jnp.asarray(
            np.random.default_rng(seed).normal(
                size=(n, th, tw, t.input_channels)
            ),
            jnp.float32,
        )

    # Every dispatch of tenant 'mnist' is faulted; 'cifar' is untouched.
    router = Router(
        fault_plan=FaultPlan(
            [DispatchError(at=0, times=None, tenant="mnist")]
        ),
        max_retries=0, allow_degraded=False,
        breaker_threshold=3, breaker_reset_s=60.0,
        microbatch=args.microbatch,
    )
    router.add("mnist", plan_mnist)
    router.add("cifar", plan_cifar)
    with router:
        mnist_errors = 0
        for i in range(6):
            try:
                router.submit(
                    "mnist", tenant_frames(lenet, 2, 10 + i)
                ).result(timeout=30.0)
            except Exception:   # BatchFailed, then CircuitOpen: structured
                mnist_errors += 1
            xc = tenant_frames(cifar, 2, 20 + i)
            np.testing.assert_allclose(
                np.asarray(router.infer("cifar", xc)),
                np.asarray(plan_cifar(xc)), rtol=1e-4, atol=1e-4,
            )
        st_cifar = router.engine("cifar").stats()
        print(f"  tenant 'mnist': {mnist_errors}/6 failed, breaker "
              f"{router.breaker('mnist').state!r} (fails fast, no "
              f"dispatches wasted)")
        print(f"  tenant 'cifar': {st_cifar.n_ok} ok / "
              f"{st_cifar.n_errors} errors — the bulkhead held")

        # Verified hot swap: retrained cifar weights go live with zero
        # dropped requests; a plan that fails verify_plan is refused.
        plan_cifar_v2 = compile_dhm(
            cifar, init_cnn(jax.random.PRNGKey(7), cifar)
        )
        pre_swap = router.submit("cifar", tenant_frames(cifar, 2, 40))
        router.swap("cifar", plan_cifar_v2)
        np.testing.assert_allclose(
            np.asarray(pre_swap.result(timeout=30.0)),
            np.asarray(plan_cifar(tenant_frames(cifar, 2, 40))),
            rtol=1e-4, atol=1e-4,
        )
        x_post = tenant_frames(cifar, 2, 41)
        np.testing.assert_allclose(
            np.asarray(router.infer("cifar", x_post)),
            np.asarray(plan_cifar_v2(x_post)), rtol=1e-4, atol=1e-4,
        )
        print("  hot swap 'cifar' -> v2: in-flight request answered by "
              "the OLD plan, next by the NEW — zero drops")
        try:
            router.swap("cifar", plan_mnist)  # wrong serving surface
        except Exception as e:
            print(f"  swap to incompatible plan refused: "
                  f"{type(e).__name__} (old plan still serving)")
        router.rollback("cifar")
        np.testing.assert_allclose(
            np.asarray(router.infer("cifar", x_post)),
            np.asarray(plan_cifar(x_post)), rtol=1e-4, atol=1e-4,
        )
        print("  rollback 'cifar': v1 weights answering again")

    n_dev = len(jax.devices())
    n_stages = args.stages or min(3, len(topo.conv_layers))
    data = max(1, min(2, n_dev // n_stages))
    if n_stages * data > n_dev or n_stages < 2:
        print(f"\n(skipping mesh engine: need >= {max(2, n_stages) * data} "
              f"devices, have {n_dev})")
        return
    print(f"\n== pipelined engine: ({n_stages} stage x {data} data) mesh, "
          f"{n_dev} host devices ==")
    plan_s = compile_dhm(topo, params, quant=quant, n_stages=n_stages)
    for st in plan_s.stages:
        print(f"  stage {st.index}: {st.io.in_shape} -> {st.io.out_shape} "
              f"({st.cost_flops / 1e6:.2f} Mflop)")
    mesh_axes = (("stage", "data") if data > 1 else ("stage",))
    mesh_shape = (n_stages, data) if data > 1 else (n_stages,)
    mesh = jax.make_mesh(mesh_shape, mesh_axes)
    engp = Engine(
        plan_s, microbatch=args.microbatch, mesh=mesh, n_microbatches=4,
        data_axis="data" if data > 1 else None,
    )
    reqs = [engp.submit(random_request(i)) for i in range(args.requests)]
    engp.flush()
    total = sum(r.result().shape[0] for r in reqs)
    np.testing.assert_allclose(
        np.asarray(engp.infer(x0)), np.asarray(plan_s(x0)),
        rtol=1e-4, atol=1e-4,
    )
    print(f"  served {len(reqs)} requests / {total} frames through the "
          f"spatial pipeline, logits match the single-device plan; "
          f"{engp.stats().summary()}")
    print("OK")


if __name__ == "__main__":
    main()
