"""End-to-end training driver: data pipeline -> sharded step -> AdamW ->
checkpoint/restart, with fault tolerance and straggler monitoring.

    PYTHONPATH=src python examples/train_lm.py --preset smoke
    PYTHONPATH=src python examples/train_lm.py --preset 20m --steps 200
    PYTHONPATH=src python examples/train_lm.py --preset 100m --steps 300

The 100m preset is the deliverable configuration (~100M params); on this
1-core CPU container it runs at minutes/step, so CI uses `smoke` and the
recorded convergence run uses `20m` (see EXPERIMENTS.md §Training).
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.configs.base import ArchConfig
from repro.data import ShardedLoader, TokenStreamConfig, synthetic_token_batches
from repro.models import transformer as T
from repro.optim import (
    AdamWConfig,
    adamw_init,
    adamw_update,
    clip_by_global_norm,
    linear_warmup_cosine,
)
from repro.runtime import FaultInjector, ResilientTrainer, StragglerMonitor

PRESETS = {
    # name: (layers, d_model, heads, kv, d_ff, vocab, seq, batch)
    # The synthetic affine-recurrence task is a vocab-sized lookup, so the
    # vocab is kept small enough that each embedding row gets O(100s) of
    # gradient updates within a few-hundred-step run.
    "smoke": (2, 128, 4, 2, 256, 512, 64, 8),
    "20m": (8, 384, 8, 4, 1024, 2048, 256, 8),
    "100m": (12, 768, 12, 4, 2048, 32768, 512, 8),
}


def make_cfg(preset: str) -> ArchConfig:
    l, d, h, kv, ff, v, _, _ = PRESETS[preset]
    return ArchConfig(
        name=f"lm-{preset}",
        family="dense",
        n_layers=l,
        d_model=d,
        n_heads=h,
        n_kv_heads=kv,
        d_ff=ff,
        vocab_size=v,
        dtype="float32",
        remat="none",
    )


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", choices=PRESETS, default="smoke")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--ckpt-dir", default="results/train_lm_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--inject-failure-at", type=int, default=-1,
                    help="simulate a node failure at this step")
    args = ap.parse_args()

    cfg = make_cfg(args.preset)
    _, _, _, _, _, _, seq, batch = PRESETS[args.preset]
    from repro.models.accounting import param_count

    n_params = param_count(cfg)
    print(f"model: {cfg.n_layers}L d={cfg.d_model} -> {n_params/1e6:.1f}M params")

    stream_cfg = TokenStreamConfig(
        vocab_size=cfg.vocab_size, seq_len=seq, batch_size=batch
    )
    print(f"stream loss floor: {stream_cfg.loss_floor:.3f} nats")

    params = T.init_params(jax.random.PRNGKey(0), cfg)
    opt_cfg = AdamWConfig(weight_decay=0.01)
    opt = adamw_init(params, opt_cfg)
    sched = linear_warmup_cosine(1e-3, warmup_steps=20, total_steps=args.steps)

    @jax.jit
    def jit_step(params, opt, tokens, step_idx):
        def loss_fn(p):
            loss, m = T.train_loss(
                p, cfg, {"tokens": tokens},
                vocab_chunk=min(8192, cfg.vocab_size),
            )
            return loss

        loss, grads = jax.value_and_grad(loss_fn)(params)
        grads, gnorm = clip_by_global_norm(grads, 1.0)
        params, opt = adamw_update(grads, opt, params, opt_cfg,
                                   sched(step_idx))
        return params, opt, loss, gnorm

    def batch_fn(step):
        # Deterministic per-step stream => bitwise replay after restart.
        it = synthetic_token_batches(stream_cfg, seed=1000 + step)
        return jnp.asarray(next(it)["tokens"])

    tokens_per_step = batch * seq
    losses = []

    def step_fn(state, tokens, step):
        params, opt = state
        t0 = time.time()
        params, opt, loss, gnorm = jit_step(params, opt, tokens,
                                            jnp.asarray(step))
        loss = float(loss)
        losses.append(loss)
        dt = time.time() - t0
        if step % 10 == 0 or step == args.steps - 1:
            print(
                f"step {step:4d} loss {loss:.4f} gnorm {float(gnorm):.2f} "
                f"{tokens_per_step / dt:.0f} tok/s",
                flush=True,
            )
        return (params, opt), {"loss": loss}

    injector = (
        FaultInjector(fail_at_steps=(args.inject_failure_at,))
        if args.inject_failure_at >= 0
        else None
    )
    trainer = ResilientTrainer(
        step_fn,
        batch_fn,
        CheckpointManager(args.ckpt_dir, keep=2),
        ckpt_every=args.ckpt_every,
        straggler=StragglerMonitor(),
        fault_injector=injector,
    )
    t0 = time.time()
    (params, opt), last = trainer.run((params, opt), num_steps=args.steps)
    wall = time.time() - t0
    print(
        f"done: {args.steps} steps in {wall:.1f}s "
        f"({args.steps * tokens_per_step / wall:.0f} tok/s), "
        f"final loss {losses[-1]:.4f} (floor {stream_cfg.loss_floor:.3f}), "
        f"restarts={trainer.restarts}, stragglers={len(trainer.straggler.flagged)}"
    )


if __name__ == "__main__":
    main()
