#!/usr/bin/env bash
# One-command smoke: tier-1 test suite + the (non --full) benchmark run.
# Usage: scripts/smoke.sh
# Leaves BENCH_kernels.json and BENCH.csv in the repo root.
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

echo "== tier-1 tests =="
python -m pytest -x -q

echo "== benchmarks (non-full) =="
python -m benchmarks.run | tee BENCH.csv

echo "== kernel perf record =="
python - <<'EOF'
import json
rec = json.load(open("BENCH_kernels.json"))
paths = {r.get("path") for r in rec["rows"]}
assert {"seed", "fused"} <= paths, f"missing kernel paths in record: {paths}"
fused = next(r for r in rec["rows"] if r.get("path") == "fused")
print(f"fused stream conv: {fused['us_per_call']:.0f} us/call, "
      f"x{fused['speedup_vs_seed']:.1f} vs seed interpret path")
EOF
echo "SMOKE OK"
