#!/usr/bin/env bash
# One-command smoke: tier-1 test suite + the (non --full) benchmark run.
# Usage: scripts/smoke.sh
# Leaves BENCH_kernels.json and BENCH.csv in the repo root.
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

echo "== tier-1 tests =="
python -m pytest -x -q

echo "== benchmarks (non-full) =="
python -m benchmarks.run | tee BENCH.csv

echo "== kernel perf record =="
python - <<'EOF'
import json
import sys

try:
    rec = json.load(open("BENCH_kernels.json"))
except FileNotFoundError:
    sys.exit("FATAL: BENCH_kernels.json missing — benchmarks.run did not "
             "write the kernel perf record")

rows = {r["name"]: r for r in rec.get("rows", [])}
expected = [
    "kernel/stream_conv_cifar_c1_seed_interpret",
    "kernel/stream_conv_cifar_c1_fused",
] + [
    f"e2e/{net}_{variant}_plan"
    for net in ("lenet5", "cifar10", "svhn")
    for variant in ("fp32", "quant")
]
missing = [n for n in expected if n not in rows]
if missing:
    sys.exit(f"FATAL: BENCH_kernels.json is missing expected rows: {missing}\n"
             f"present: {sorted(rows)}")
paths = {r.get("path") for r in rec["rows"]}
assert {"seed", "fused"} <= paths, f"missing kernel paths in record: {paths}"

fused = rows["kernel/stream_conv_cifar_c1_fused"]
print(f"fused stream conv: {fused['us_per_call']:.0f} us/call, "
      f"x{fused['speedup_vs_seed']:.1f} vs seed interpret path")
for net in ("lenet5", "cifar10", "svhn"):
    fp = rows[f"e2e/{net}_fp32_plan"]
    q = rows[f"e2e/{net}_quant_plan"]
    print(f"e2e {net}: fp32 {fp['frames_per_s']:.0f} frames/s, "
          f"quant {q['frames_per_s']:.0f} frames/s")
EOF
echo "SMOKE OK"
