#!/usr/bin/env bash
# One-command smoke: test suite + the (non --full) benchmark run.
# Usage: scripts/smoke.sh [--full]
#   default: fast tier (slow-marked tests skipped — the interpret-mode
#            oracle subprocess/e2e tests that dominate wall time)
#   --full:  the whole tier-1 suite (what CI's nightly / the driver runs:
#            PYTHONPATH=src python -m pytest -x -q)
# Leaves BENCH_kernels.json and BENCH.csv in the repo root and appends the
# run to BENCH_history.jsonl (the cross-PR perf trajectory). The perf
# guard compares the fused e2e rows against benchmarks/bench_baseline.json:
# each row must reach SMOKE_PERF_FLOOR x baseline frames/s (default 0.35 —
# a low floor because CI runners and dev boxes differ widely); set
# SMOKE_PERF_FLOOR=0 to skip the guard. The mesh job gets its own floor:
# SMOKE_PIPELINE_FLOOR (default 0.25, even more lenient — the pipelined
# path runs on 8 *emulated* host devices, where scheduler noise is worse)
# guards the e2e_pipelined rows the same way; 0 disables it.
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

PYTEST_ARGS=(-x -q)
if [[ "${1:-}" == "--full" || "${SMOKE_FULL:-0}" == "1" ]]; then
  echo "== tier-1 tests (full) =="
else
  echo "== tier-1 tests (fast tier; slow-marked skipped — use --full) =="
  PYTEST_ARGS+=(-m "not slow")
fi
python -m pytest "${PYTEST_ARGS[@]}"

echo "== static plan verifier (no-FLOPs invariant check) =="
# Fast subset: trace-and-verify two topologies. CI's `static` job runs
# the full matrix (all topologies, fp32 + quant, + AST lint + ruff).
python -m repro.analysis verify --topology lenet5,cifar10

HISTORY_LINES_BEFORE=0
[[ -f BENCH_history.jsonl ]] && HISTORY_LINES_BEFORE=$(wc -l < BENCH_history.jsonl)
export HISTORY_LINES_BEFORE

echo "== benchmarks (non-full) =="
python -m benchmarks.run | tee BENCH.csv

echo "== kernel perf record =="
python - <<'EOF'
import json
import os
import sys

try:
    rec = json.load(open("BENCH_kernels.json"))
except FileNotFoundError:
    sys.exit("FATAL: BENCH_kernels.json missing — benchmarks.run did not "
             "write the kernel perf record")

rows = {r["name"]: r for r in rec.get("rows", [])}
nets = ("lenet5", "cifar10", "svhn", "cifar10_full", "cifar10_strided")
expected = [
    "kernel/stream_conv_cifar_c1_seed_interpret",
    "kernel/stream_conv_cifar_c1_fused",
    "kernel/stream_conv_pyramid_cifar_stack",
] + [
    f"e2e/{net}_{variant}_plan"
    for net in nets
    for variant in (
        "fp32", "quant", "int8",
        "fp32_perlayer", "quant_perlayer", "int8_perlayer",
        "fp32_pipelined", "quant_pipelined",
    )
]
missing = [n for n in expected if n not in rows]
if missing:
    sys.exit(f"FATAL: BENCH_kernels.json is missing expected rows: {missing}\n"
             f"present: {sorted(rows)}")
paths = {r.get("path") for r in rec["rows"]}
assert {"seed", "fused", "fused_group", "serve_load",
        "serve_multitenant"} <= paths, \
    f"missing kernel paths in record: {paths}"

# -- serving-under-load rows: p50/p99 + shed rate vs offered load must be
# recorded (the fault-tolerant Engine's serving trajectory).
serve_rows = [r for r in rec["rows"] if r.get("path") == "serve_load"]
expected_serve = [
    f"serve/lenet5_load_x{f:g}" for f in (0.5, 1.0, 2.0)
]
missing_serve = [n for n in expected_serve if n not in rows]
if missing_serve:
    sys.exit(f"FATAL: BENCH_kernels.json misses serve_load rows: "
             f"{missing_serve}")
for r in serve_rows:
    for field in ("p50_ms", "p99_ms", "shed_rate", "offered_rps"):
        if field not in r:
            sys.exit(f"FATAL: serve_load row {r['name']} misses {field!r}")
    print(f"serve {r['name']}: offered {r['offered_rps']:.0f} req/s -> "
          f"p50 {r['p50_ms']:.2f} ms p99 {r['p99_ms']:.2f} ms, "
          f"shed {r['shed_rate']:.1%}")
expected += expected_serve

# -- multi-tenant serving row: per-tenant p50/p99 + shed/error rates and
# the isolation ratio (faulted p99 / clean p99) must be on record — the
# bulkhead's blast-radius trajectory across PRs.
mt_rows = [r for r in rec["rows"] if r.get("path") == "serve_multitenant"]
if "serve/lenet5_multitenant_faulted_vs_clean" not in rows or not mt_rows:
    sys.exit("FATAL: BENCH_kernels.json misses the serve_multitenant row")
for r in mt_rows:
    for field in ("clean_p50_ms", "clean_p99_ms", "faulted_p50_ms",
                  "faulted_p99_ms", "clean_shed_rate", "faulted_shed_rate",
                  "clean_error_rate", "faulted_error_rate",
                  "isolation_ratio"):
        if field not in r:
            sys.exit(f"FATAL: serve_multitenant row {r['name']} misses "
                     f"{field!r}")
    print(f"serve {r['name']}: clean p99 {r['clean_p99_ms']:.2f} ms, "
          f"faulted p99 {r['faulted_p99_ms']:.2f} ms, isolation ratio "
          f"{r['isolation_ratio']:.2f}")
expected.append("serve/lenet5_multitenant_faulted_vs_clean")

# -- pipelined rows: every e2e_pipelined row must carry its speedup vs
# the single-device plan (the cross-PR gap trajectory) plus the autotuned
# configuration that produced it, and the µbatch/grain crossover sweep
# (path: pipeline_sweep — the autotuner's measurement source) must be on
# record with full config fields.
pipe_rows = [r for r in rec["rows"] if r.get("path") == "e2e_pipelined"]
for r in pipe_rows:
    for field in ("pipeline_speedup", "n_microbatches", "microbatch",
                  "tuning_source", "edge_path"):
        if field not in r:
            sys.exit(f"FATAL: e2e_pipelined row {r['name']} misses "
                     f"{field!r}")
sweep_rows = [r for r in rec["rows"] if r.get("path") == "pipeline_sweep"]
if not sweep_rows:
    sys.exit("FATAL: no pipeline_sweep rows — the µbatch/grain crossover "
             "sweep was not recorded")
for r in sweep_rows:
    for field in ("pipeline_speedup", "frames_per_s", "topology", "label",
                  "n_microbatches", "microbatch", "overlap", "edge_mode"):
        if field not in r:
            sys.exit(f"FATAL: pipeline_sweep row {r['name']} misses "
                     f"{field!r}")
best_sweep = max(sweep_rows, key=lambda r: r["frames_per_s"])
print(f"pipeline sweep: {len(sweep_rows)} points recorded, best "
      f"{best_sweep['name']} at {best_sweep['frames_per_s']:.0f} frames/s "
      f"(x{best_sweep['pipeline_speedup']:.2f} vs single device)")

# -- true-int8 rows: every topology must record an e2e_int8 row carrying
# its measured speedup vs the fp32 fused plan, and every quantized row
# (fake-quant and int8, fused/per-layer/pipelined) must record the
# bitwidths it ran at — the mixed-bitwidth trajectory is unreadable
# without them.
int8_rows = [r for r in rec["rows"] if r.get("path") == "e2e_int8"]
if len(int8_rows) < len(nets):
    sys.exit(f"FATAL: expected one e2e_int8 row per topology "
             f"({len(nets)}), got {len(int8_rows)}")
for r in int8_rows:
    for field in ("int8_speedup", "weight_bits", "act_bits",
                  "fusion_speedup"):
        if field not in r:
            sys.exit(f"FATAL: e2e_int8 row {r['name']} misses {field!r}")
for r in rec["rows"]:
    if r.get("path", "").startswith(("e2e_quant", "e2e_int8")) or (
        r.get("path") == "e2e_pipelined" and "_quant_" in r["name"]
    ):
        for field in ("weight_bits", "act_bits"):
            if field not in r:
                sys.exit(f"FATAL: quantized row {r['name']} misses "
                         f"{field!r}")

fused = rows["kernel/stream_conv_cifar_c1_fused"]
print(f"fused stream conv: {fused['us_per_call']:.0f} us/call, "
      f"x{fused['speedup_vs_seed']:.1f} vs seed interpret path")
for net in nets:
    fp = rows[f"e2e/{net}_fp32_plan"]
    q = rows[f"e2e/{net}_quant_plan"]
    i8 = rows[f"e2e/{net}_int8_plan"]
    pp = rows[f"e2e/{net}_fp32_pipelined_plan"]
    print(f"e2e {net}: fp32 {fp['frames_per_s']:.0f} frames/s "
          f"(x{fp.get('fusion_speedup', 0):.2f} vs per-layer), "
          f"quant {q['frames_per_s']:.0f} frames/s "
          f"(x{q.get('fusion_speedup', 0):.2f} vs per-layer), "
          f"int8 {i8['frames_per_s']:.0f} frames/s "
          f"(x{i8.get('int8_speedup', 0):.2f} vs fp32 fused, "
          f"w{i8['weight_bits']}/a{i8['act_bits']}), "
          f"pipelined {pp['frames_per_s']:.0f} frames/s on a host mesh "
          f"(x{pp.get('pipeline_speedup', 0):.2f} vs single device)")

# -- history append sanity (the cross-PR trajectory must actually grow) --
before = int(os.environ.get("HISTORY_LINES_BEFORE", "0"))
try:
    lines = open("BENCH_history.jsonl").read().splitlines()
except FileNotFoundError:
    sys.exit("FATAL: BENCH_history.jsonl missing — benchmarks.run did not "
             "append the trajectory record")
if len(lines) <= before:
    sys.exit(f"FATAL: BENCH_history.jsonl did not grow ({before} -> "
             f"{len(lines)} lines) — the run was not appended")
last = json.loads(lines[-1])
for field in ("git_sha", "timestamp", "jax_backend", "rows"):
    if field not in last:
        sys.exit(f"FATAL: BENCH_history.jsonl last record misses {field!r}")
hist_names = {r["name"] for r in last["rows"]}
if not set(expected) <= hist_names:
    sys.exit("FATAL: BENCH_history.jsonl last record misses expected rows: "
             f"{sorted(set(expected) - hist_names)}")
print(f"history: {len(lines)} runs recorded "
      f"(last: {last['git_sha'][:12]} @ {last['timestamp']})")

# -- perf-regression guard: fused e2e rows vs the committed baseline.
# SMOKE_PERF_FLOOR is the fraction of baseline throughput each fused row
# must reach (0.35 = fail below 35% of baseline; 0 disables the guard).
floor_frac = float(os.environ.get("SMOKE_PERF_FLOOR", "0.35"))
if floor_frac > 0:
    try:
        base = json.load(open("benchmarks/bench_baseline.json"))
    except FileNotFoundError:
        sys.exit("FATAL: benchmarks/bench_baseline.json missing — commit a "
                 "baseline (see benchmarks/run.py) or set SMOKE_PERF_FLOOR=0")
    if base.get("jax_backend") != rec["jax_backend"]:
        print(f"perf guard skipped: baseline recorded on "
              f"{base.get('jax_backend')!r}, this run is "
              f"{rec['jax_backend']!r} — absolute frames/s are not "
              f"comparable across substrates")
    else:
        failures = []
        for name, base_fps in base.get("e2e_frames_per_s", {}).items():
            row = rows.get(name)
            if row is None:
                failures.append(f"{name}: row missing from this run")
                continue
            floor = base_fps * floor_frac
            if row["frames_per_s"] < floor:
                failures.append(
                    f"{name}: {row['frames_per_s']:.0f} frames/s < "
                    f"{floor:.0f} (baseline {base_fps:.0f} x floor "
                    f"{floor_frac})"
                )
        if failures:
            sys.exit("FATAL: perf regression vs "
                     "benchmarks/bench_baseline.json "
                     f"(floor {floor_frac}):\n  " + "\n  ".join(failures))
        print(f"perf guard: {len(base.get('e2e_frames_per_s', {}))} fused "
              f"e2e rows above {floor_frac} x baseline")

        # True-int8 rows get the same floor from their own baseline
        # section, so an int8-path regression cannot hide behind healthy
        # fake-quant numbers.
        failures = []
        for name, base_fps in base.get("int8_frames_per_s", {}).items():
            row = rows.get(name)
            if row is None:
                failures.append(f"{name}: row missing from this run")
                continue
            floor = base_fps * floor_frac
            if row["frames_per_s"] < floor:
                failures.append(
                    f"{name}: {row['frames_per_s']:.0f} frames/s < "
                    f"{floor:.0f} (baseline {base_fps:.0f} x floor "
                    f"{floor_frac})"
                )
        if failures:
            sys.exit("FATAL: int8 perf regression vs "
                     "benchmarks/bench_baseline.json "
                     f"(floor {floor_frac}):\n  " + "\n  ".join(failures))
        print(f"int8 guard: {len(base.get('int8_frames_per_s', {}))} "
              f"int8 rows above {floor_frac} x baseline")

        # Mesh-job floor: the pipelined serving rows, separately tunable
        # (and more lenient by default — 8 emulated host devices).
        pipe_floor = float(os.environ.get("SMOKE_PIPELINE_FLOOR", "0.25"))
        if pipe_floor > 0:
            failures = []
            for name, base_fps in base.get(
                "pipelined_frames_per_s", {}
            ).items():
                row = rows.get(name)
                if row is None:
                    failures.append(f"{name}: row missing from this run")
                    continue
                floor = base_fps * pipe_floor
                if row["frames_per_s"] < floor:
                    failures.append(
                        f"{name}: {row['frames_per_s']:.0f} frames/s < "
                        f"{floor:.0f} (baseline {base_fps:.0f} x floor "
                        f"{pipe_floor})"
                    )
            if failures:
                sys.exit("FATAL: pipelined perf regression vs "
                         "benchmarks/bench_baseline.json "
                         f"(floor {pipe_floor}):\n  "
                         + "\n  ".join(failures))
            print(f"pipeline guard: "
                  f"{len(base.get('pipelined_frames_per_s', {}))} "
                  f"pipelined rows above {pipe_floor} x baseline")
EOF
echo "SMOKE OK"
