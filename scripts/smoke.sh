#!/usr/bin/env bash
# One-command smoke: test suite + the (non --full) benchmark run.
# Usage: scripts/smoke.sh [--full]
#   default: fast tier (slow-marked tests skipped — the interpret-mode
#            oracle subprocess/e2e tests that dominate wall time)
#   --full:  the whole tier-1 suite (what CI's nightly / the driver runs:
#            PYTHONPATH=src python -m pytest -x -q)
# Leaves BENCH_kernels.json and BENCH.csv in the repo root.
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

PYTEST_ARGS=(-x -q)
if [[ "${1:-}" == "--full" || "${SMOKE_FULL:-0}" == "1" ]]; then
  echo "== tier-1 tests (full) =="
else
  echo "== tier-1 tests (fast tier; slow-marked skipped — use --full) =="
  PYTEST_ARGS+=(-m "not slow")
fi
python -m pytest "${PYTEST_ARGS[@]}"

echo "== benchmarks (non-full) =="
python -m benchmarks.run | tee BENCH.csv

echo "== kernel perf record =="
python - <<'EOF'
import json
import sys

try:
    rec = json.load(open("BENCH_kernels.json"))
except FileNotFoundError:
    sys.exit("FATAL: BENCH_kernels.json missing — benchmarks.run did not "
             "write the kernel perf record")

rows = {r["name"]: r for r in rec.get("rows", [])}
expected = [
    "kernel/stream_conv_cifar_c1_seed_interpret",
    "kernel/stream_conv_cifar_c1_fused",
] + [
    f"e2e/{net}_{variant}_plan"
    for net in ("lenet5", "cifar10", "svhn", "cifar10_full",
                "cifar10_strided")
    for variant in ("fp32", "quant")
]
missing = [n for n in expected if n not in rows]
if missing:
    sys.exit(f"FATAL: BENCH_kernels.json is missing expected rows: {missing}\n"
             f"present: {sorted(rows)}")
paths = {r.get("path") for r in rec["rows"]}
assert {"seed", "fused"} <= paths, f"missing kernel paths in record: {paths}"

fused = rows["kernel/stream_conv_cifar_c1_fused"]
print(f"fused stream conv: {fused['us_per_call']:.0f} us/call, "
      f"x{fused['speedup_vs_seed']:.1f} vs seed interpret path")
for net in ("lenet5", "cifar10", "svhn", "cifar10_full", "cifar10_strided"):
    fp = rows[f"e2e/{net}_fp32_plan"]
    q = rows[f"e2e/{net}_quant_plan"]
    print(f"e2e {net}: fp32 {fp['frames_per_s']:.0f} frames/s, "
          f"quant {q['frames_per_s']:.0f} frames/s")
EOF
echo "SMOKE OK"
