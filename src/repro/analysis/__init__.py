"""Static analysis for DHM plans: a plan verifier (named jaxpr/resource
invariants over ``CompiledDHM`` artifacts, no FLOPs executed) and an AST
linter (this repo's jax sharp edges as DHM0xx rules).

CLI: ``python -m repro.analysis [verify|lint|all] --topology all``.

Exports resolve lazily so importing the package never pulls in jax —
``__main__`` must be able to set XLA_FLAGS first, and the linter runs
accelerator-free.
"""

_EXPORTS = {
    "Finding": "repro.analysis.findings",
    "render_report": "repro.analysis.findings",
    "count_primitive": "repro.analysis.jaxpr_utils",
    "count_primitive_in_pallas": "repro.analysis.jaxpr_utils",
    "Invariant": "repro.analysis.invariants",
    "REGISTRY": "repro.analysis.invariants",
    "verify_plan": "repro.analysis.verify",
    "check_plan": "repro.analysis.verify",
    "make_pipeline_probe": "repro.analysis.verify",
    "RULES": "repro.analysis.ast_lint",
    "lint_paths": "repro.analysis.ast_lint",
    "lint_source": "repro.analysis.ast_lint",
}

__all__ = sorted(_EXPORTS)


def __getattr__(name):
    if name in _EXPORTS:
        import importlib

        return getattr(importlib.import_module(_EXPORTS[name]), name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
