"""Module entrypoint. The pipelined-closure probe needs a stage mesh, so
force 8 emulated host devices BEFORE anything imports jax — the flag is
read once at backend init and ignored afterwards."""

import os
import sys

_flags = os.environ.get("XLA_FLAGS", "")
if "xla_force_host_platform_device_count" not in _flags:
    os.environ["XLA_FLAGS"] = (
        _flags + " --xla_force_host_platform_device_count=8"
    ).strip()

from repro.analysis.cli import main  # noqa: E402 — after the env mutation

if __name__ == "__main__":
    sys.exit(main())
