"""AST lint: this repo's hard-won jax sharp edges as named rules.

Each rule encodes a bug class a past PR actually hit, with file:line
diagnostics:

- ``DHM001`` eager ``jnp.concatenate``/``jnp.stack`` on host paths in
  serving code — varying request shapes retrace the op per shape
  (~100 ms/flush); pack with numpy on the host instead.
- ``DHM002`` param stacking (``jnp.stack``/``jnp.concatenate``) inside a
  jitted function — on 2D meshes shard_map receives a mis-partitioned
  operand; box and stack eagerly, pass resident leaves as arguments.
- ``DHM003`` timing a jax dispatch without ``block_until_ready`` —
  async dispatch returns before the work runs, so the window measures
  nothing.
- ``DHM004`` bare ``except:`` or a swallowed ``RequestError`` in the
  degradation ladder — failures must demote or surface, never vanish.
- ``DHM005`` float64 on the device path — jax silently truncates to
  f32 without x64 enabled, so the cast is at best a no-op and at worst
  a 2x memory surprise when x64 is on.
- ``DHM006`` a background thread created in serving code with no
  timeout-bounded ``join`` anywhere in the module — a wedged dispatch
  leaks the thread past interpreter shutdown (the PR-9 ``stop()`` bug
  class); shutdown paths must join with a timeout and fail loudly.

Rules are scoped by path pattern (``fnmatch``; ``*`` crosses
directories) so e.g. the serving-path rules never fire on kernel
bodies. The module is accelerator-free: pure ``ast``, no jax import.
"""

from __future__ import annotations

import ast
import dataclasses
import fnmatch
import os
from typing import Callable, Dict, List, Tuple

from repro.analysis.findings import Finding

# Names under which the degradation ladder's structured request errors
# travel (engine.py) — swallowing one hides a serving failure (DHM004).
_REQUEST_ERRORS = {
    "RequestError", "DeadlineExceeded", "Rejected", "Shed",
    "InvalidRequest", "BatchFailed", "CircuitOpen",
}

_TIME_CALLS = {
    "time.time", "time.perf_counter", "time.monotonic",
    "perf_counter", "monotonic",
}


@dataclasses.dataclass(frozen=True)
class Rule:
    id: str
    name: str
    doc: str
    path_globs: tuple  # fnmatch patterns against the posix relpath
    fn: Callable  # (ast.Module, src: str, relpath: str) -> [(line, msg)]

    def applies_to(self, relpath: str) -> bool:
        p = relpath.replace(os.sep, "/")
        return any(fnmatch.fnmatch(p, g) for g in self.path_globs)


RULES: Dict[str, Rule] = {}


def rule(id: str, *, name: str, path_globs):
    def deco(fn):
        if id in RULES:
            raise ValueError(f"duplicate rule id {id!r}")
        RULES[id] = Rule(
            id=id, name=name, doc=fn.__doc__ or "",
            path_globs=tuple(path_globs), fn=fn,
        )
        return fn

    return deco


# ---------------------------------------------------------------------------
# shared AST helpers


def _dotted(node) -> str:
    """Best-effort dotted source name of a call target ('jnp.stack',
    'time.perf_counter', 'jax.jit', ...); '' when not name-shaped."""
    if isinstance(node, ast.Attribute):
        base = _dotted(node.value)
        return f"{base}.{node.attr}" if base else node.attr
    if isinstance(node, ast.Name):
        return node.id
    return ""


def _parent_functions(tree) -> Dict[ast.AST, ast.AST]:
    """Map every node to its innermost enclosing function def (or None)."""
    owner: Dict[ast.AST, ast.AST] = {}

    def walk(node, fn):
        for child in ast.iter_child_nodes(node):
            owner[child] = fn
            walk(
                child,
                child
                if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef))
                else fn,
            )

    walk(tree, None)
    return owner


def _jitted_functions(tree) -> set:
    """Function defs that become jit traces: decorated with jax.jit (or
    partial(jax.jit, ...)), or later passed to a jax.jit(...) call by
    name anywhere in the module."""
    jitted = set()
    by_name: Dict[str, List[ast.AST]] = {}
    for node in ast.walk(tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            by_name.setdefault(node.name, []).append(node)
            for dec in node.decorator_list:
                target = dec.func if isinstance(dec, ast.Call) else dec
                nm = _dotted(target)
                if nm.endswith("jit"):
                    jitted.add(node)
                elif nm.endswith("partial") and isinstance(dec, ast.Call):
                    if any(_dotted(a).endswith("jit") for a in dec.args):
                        jitted.add(node)
    for node in ast.walk(tree):
        if isinstance(node, ast.Call) and _dotted(node.func).endswith(
            ("jax.jit", "jax.pmap")
        ):
            for arg in node.args[:1]:
                for fndef in by_name.get(_dotted(arg), []):
                    jitted.add(fndef)
    return jitted


def _enclosing_chain(node, owner):
    fn = owner.get(node)
    while fn is not None:
        yield fn
        fn = owner.get(fn)


# ---------------------------------------------------------------------------
# rules


@rule(
    "DHM001",
    name="eager-concat-on-host-path",
    path_globs=("*core/dhm/engine.py", "*serve*.py"),
)
def _eager_concat(tree, src, relpath):
    """Eager jnp.concatenate/jnp.stack in serving code outside any jit:
    every distinct request-batch shape retraces the op (the PR-6
    100 ms/flush recompile). Pack with numpy on the host."""
    owner = _parent_functions(tree)
    jitted = _jitted_functions(tree)
    out = []
    for node in ast.walk(tree):
        if not isinstance(node, ast.Call):
            continue
        nm = _dotted(node.func)
        if nm not in ("jnp.concatenate", "jnp.stack"):
            continue
        if any(fn in jitted for fn in _enclosing_chain(node, owner)):
            continue  # inside a jit trace: DHM002's domain
        out.append((
            node.lineno,
            f"eager {nm} on the serving host path retraces per shape — "
            "pack with np.concatenate/np.stack instead",
        ))
    return out


@rule(
    "DHM002",
    name="param-stack-inside-jit",
    path_globs=(
        "*core/dhm/pipeline.py", "*core/dhm/engine.py",
        "*core/dhm/compiler.py",
    ),
)
def _stack_inside_jit(tree, src, relpath):
    """jnp.stack/jnp.concatenate inside a jitted function: on 2D meshes
    the stacked operand reaches shard_map mis-partitioned (the PR-5/7
    sharp edge). Box + stack eagerly; pass resident leaves as args."""
    owner = _parent_functions(tree)
    jitted = _jitted_functions(tree)
    out = []
    for node in ast.walk(tree):
        if not isinstance(node, ast.Call):
            continue
        nm = _dotted(node.func)
        if nm not in ("jnp.concatenate", "jnp.stack"):
            continue
        if any(fn in jitted for fn in _enclosing_chain(node, owner)):
            out.append((
                node.lineno,
                f"{nm} inside a jitted function — stack params eagerly "
                "outside the trace and pass the resident leaves in",
            ))
    return out


@rule(
    "DHM003",
    name="timing-without-block",
    path_globs=("*bench*.py", "*benchmarks/*"),
)
def _timing_without_block(tree, src, relpath):
    """A timing window around a jax dispatch with no block_until_ready
    in the function: async dispatch returns immediately, so the window
    under-reports (the PR-3 bug class)."""
    owner = _parent_functions(tree)
    out = []
    fns = [
        n for n in ast.walk(tree)
        if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef))
    ]
    for fn in fns:
        time_lines, dispatches, blocks = [], [], False
        for node in ast.walk(fn):
            if not isinstance(node, ast.Call):
                continue
            if owner.get(node) is not fn:
                continue  # a call inside a nested def belongs to that def
            nm = _dotted(node.func)
            if nm in _TIME_CALLS:
                time_lines.append(node.lineno)
            if "block_until_ready" in nm:
                blocks = True
            if (
                nm.startswith(("jnp.", "jax."))
                and "block_until_ready" not in nm
            ):
                dispatches.append(node)
        if blocks or len(time_lines) < 2:
            continue
        lo, hi = min(time_lines), max(time_lines)
        for node in dispatches:
            if lo < node.lineno < hi:
                out.append((
                    node.lineno,
                    f"jax dispatch {_dotted(node.func)} timed without "
                    "block_until_ready — async dispatch under-reports",
                ))
    return out


@rule(
    "DHM004",
    name="swallowed-request-error",
    path_globs=("*core/dhm/*.py",),
)
def _swallowed_errors(tree, src, relpath):
    """Bare ``except:`` or a RequestError-family handler whose body only
    passes: a degradation-ladder failure silently vanishes instead of
    demoting or surfacing."""
    out = []
    for node in ast.walk(tree):
        if not isinstance(node, ast.ExceptHandler):
            continue
        if node.type is None:
            out.append((
                node.lineno,
                "bare except: swallows every failure including "
                "KeyboardInterrupt — name the exception",
            ))
            continue
        types = (
            node.type.elts if isinstance(node.type, ast.Tuple)
            else [node.type]
        )
        names = {_dotted(t).rsplit(".", 1)[-1] for t in types}
        if not (names & _REQUEST_ERRORS):
            continue
        body_is_noop = all(
            isinstance(stmt, (ast.Pass, ast.Continue))
            or (
                isinstance(stmt, ast.Expr)
                and isinstance(stmt.value, ast.Constant)
            )
            for stmt in node.body
        )
        if body_is_noop:
            out.append((
                node.lineno,
                f"swallowed {sorted(names & _REQUEST_ERRORS)} — a request "
                "failure must demote, complete the request, or re-raise",
            ))
    return out


@rule("DHM005", name="float64-on-device-path", path_globs=("*.py",))
def _float64(tree, src, relpath):
    """float64 on the device path: without x64 enabled jax silently
    truncates to f32 (the cast is a no-op); with it, a 2x memory
    surprise. Stay in float32."""
    out = []
    for node in ast.walk(tree):
        if isinstance(node, ast.Attribute) and node.attr == "float64":
            base = _dotted(node.value)
            if base.endswith("jnp") or base.endswith("jax.numpy"):
                out.append((
                    node.lineno,
                    "jnp.float64 — jax runs f32 unless x64 is enabled; "
                    "this cast silently truncates",
                ))
        if isinstance(node, ast.Call):
            nm = _dotted(node.func)
            suspects = [
                a for a in node.args
                if nm.endswith(".astype") or nm.endswith(".asarray")
            ] + [kw.value for kw in node.keywords if kw.arg == "dtype"]
            for a in suspects:
                if isinstance(a, ast.Constant) and a.value == "float64":
                    out.append((
                        a.lineno,
                        '"float64" dtype on a device value — jax silently '
                        "truncates to f32 without x64",
                    ))
    return out


@rule(
    "DHM006",
    name="unbounded-background-thread",
    path_globs=(
        "*core/dhm/engine.py", "*core/dhm/multitenant.py", "*serve*.py",
    ),
)
def _unbounded_background_thread(tree, src, relpath):
    """A serving module that constructs ``threading.Thread`` must also
    contain a timeout-bounded ``.join(...)`` — an unbounded (or absent)
    join lets a dispatch wedged past the watchdog leak the thread into
    interpreter shutdown. Bound the join and fail loudly on expiry."""
    thread_ctors = []
    bounded_join = False
    for node in ast.walk(tree):
        if not isinstance(node, ast.Call):
            continue
        nm = _dotted(node.func)
        if nm in ("threading.Thread", "Thread"):
            thread_ctors.append(node)
        if (
            isinstance(node.func, ast.Attribute)
            and node.func.attr == "join"
            # a str.join ('; '.join(...)) is not a thread join
            and not (
                isinstance(node.func.value, ast.Constant)
                and isinstance(node.func.value.value, str)
            )
            and (
                node.args
                or any(kw.arg == "timeout" for kw in node.keywords)
            )
        ):
            bounded_join = True
    if bounded_join:
        return []
    return [
        (
            node.lineno,
            "background thread created but the module has no "
            "timeout-bounded .join(...) — a wedged dispatch leaks the "
            "thread past shutdown; join with a timeout and fail loudly",
        )
        for node in thread_ctors
    ]


# ---------------------------------------------------------------------------
# driver


def lint_source(
    src: str, relpath: str, rules=None
) -> List[Finding]:
    """Lint one file's source; returns findings (never raises on a
    syntactically valid file)."""
    if rules is None:
        active = list(RULES.values())
    elif isinstance(rules, dict):
        active = list(rules.values())
    else:
        active = list(rules)
    tree = ast.parse(src)
    findings = []
    for r in active:
        if not r.applies_to(relpath):
            continue
        for line, msg in r.fn(tree, src, relpath):
            findings.append(Finding(
                rule=r.id, name=r.name, severity="error", message=msg,
                where=f"{relpath}:{line}",
            ))
    return findings


def lint_paths(paths, *, root: str = ".", rules=None) -> List[Finding]:
    """Walk ``paths`` (files or directories) and lint every ``.py`` file;
    ``where`` carries paths relative to ``root``."""
    findings: List[Finding] = []
    files: List[Tuple[str, str]] = []
    for p in paths:
        if os.path.isfile(p):
            files.append((p, os.path.relpath(p, root)))
        else:
            for dirpath, _dirnames, filenames in os.walk(p):
                for fn in sorted(filenames):
                    if fn.endswith(".py"):
                        full = os.path.join(dirpath, fn)
                        files.append((full, os.path.relpath(full, root)))
    for full, rel in files:
        with open(full, encoding="utf-8") as fh:
            src = fh.read()
        try:
            findings.extend(lint_source(src, rel, rules=rules))
        except SyntaxError as e:
            findings.append(Finding(
                rule="DHM000", name="syntax-error", severity="error",
                message=f"file does not parse: {e}", where=f"{rel}:{e.lineno}",
            ))
    return findings
