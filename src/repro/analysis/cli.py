"""``python -m repro.analysis [verify|lint|all]`` — the static gate.

``verify`` compiles each requested topology (fp32 + quant, mirroring the
e2e bench's per-network paper bitwidths) and abstractly interprets three
artifacts per combination against the invariant registry:

- the default-backend single-device plan (plan/structure/resource scopes),
- a ``pallas_interpret`` probe plan, the only CPU path where pallas_call
  bodies are visible to tracing (kernel-structure + traced-working-set),
- the pipelined closure on a stage mesh (pipeline scope: the EdgePlan's
  collectives). The module entrypoint forces 8 host devices before jax
  loads, so this works from single-device CI runners.

``lint`` runs the DHM rule set over ``src/repro`` and ``benchmarks``.
No model is ever executed. Exit status 1 iff any error-severity finding.
"""

from __future__ import annotations

import argparse
import json
import sys

# Per-network paper bitwidths for the "quant" variant — same contract the
# e2e bench measures (benchmarks/e2e_bench.py PAPER_BITS).
PAPER_BITS = {
    "lenet5": 3, "cifar10": 6, "svhn": 6,
    "cifar10_full": 6, "cifar10_strided": 6,
}
_DEFAULT_BITS = 6
_MAX_PIPELINE_STAGES = 4


def _parse_args(argv):
    p = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="DHM static analysis: plan verifier + AST lint",
    )
    p.add_argument(
        "command", choices=("verify", "lint", "all"),
        help="verify compiled plans, lint sources, or both",
    )
    p.add_argument(
        "--topology", default="all",
        help="comma-separated topology names, or 'all' (default)",
    )
    p.add_argument(
        "--quant", default="all", choices=("all", "fp32", "quant", "int8"),
        help="which quantization variants to verify (default all)",
    )
    p.add_argument(
        "--format", default="text", choices=("text", "json"),
        dest="fmt", help="report format on stdout",
    )
    p.add_argument(
        "--out", default=None,
        help="also write the JSON findings report to this path",
    )
    p.add_argument(
        "--paths", nargs="*", default=None,
        help="lint roots (default: src/repro and benchmarks)",
    )
    p.add_argument(
        "--no-pipeline", action="store_true",
        help="skip the pipelined-closure probe (single-device quick mode)",
    )
    p.add_argument(
        "--batch", type=int, default=2,
        help="abstract batch size used by the probe traces",
    )
    return p.parse_args(argv)


def _repo_paths():
    """(repo_root, default lint roots) derived from the installed
    package, so the CLI works from any cwd."""
    import os

    import repro

    pkg_dir = os.path.abspath(list(repro.__path__)[0])
    repo_root = os.path.dirname(os.path.dirname(pkg_dir))
    roots = [pkg_dir]
    bench = os.path.join(repo_root, "benchmarks")
    if os.path.isdir(bench):
        roots.append(bench)
    return repo_root, roots


def _select_topologies(spec: str):
    from repro.models.cnn import ALL_TOPOLOGIES

    if spec == "all":
        return dict(ALL_TOPOLOGIES)
    out = {}
    for name in spec.split(","):
        name = name.strip()
        if name not in ALL_TOPOLOGIES:
            raise SystemExit(
                f"unknown topology {name!r}; have {sorted(ALL_TOPOLOGIES)}"
            )
        out[name] = ALL_TOPOLOGIES[name]
    return out


def run_verify(
    topologies, *, quants="all", batch=2, pipeline=True, log=lambda s: None
):
    """Verify every requested topology x quant; returns findings."""
    import jax

    from repro.analysis.verify import make_pipeline_probe, verify_plan
    from repro.core.dhm.compiler import QuantSpec, compile_dhm
    from repro.models.cnn import init_cnn

    findings = []
    for name, topo in topologies.items():
        params = init_cnn(jax.random.PRNGKey(0), topo)
        bits = PAPER_BITS.get(name, _DEFAULT_BITS)
        variants = [
            ("fp32", QuantSpec()),
            ("quant", QuantSpec(weight_bits=bits, act_bits=bits)),
            (
                "int8",
                QuantSpec(
                    weight_bits=min(bits, 8),
                    act_bits=min(bits, 8),
                    int8_compute=True,
                ),
            ),
        ]
        if quants != "all":
            variants = [v for v in variants if v[0] == quants]
        for qlabel, qs in variants:
            where = f"{name}/{qlabel}"
            log(f"verify {where}")
            plan = compile_dhm(topo, params, quant=qs)
            findings += verify_plan(
                plan,
                scopes=("plan", "structure", "resource"),
                where=where,
                batch=batch,
            )
            # pallas_call bodies are only visible to tracing on the
            # interpret backend (CPU "pallas" falls back to XLA): run the
            # kernel-body invariants against a dedicated probe plan.
            probe_plan = compile_dhm(
                topo, params, quant=qs, backend="pallas_interpret"
            )
            findings += verify_plan(
                probe_plan,
                ids=("V001", "V002", "V003", "V007", "V008", "V203", "V204"),
                where=f"{where}/interpret",
                batch=batch,
            )
            if pipeline:
                S = min(
                    len(topo.conv_layers), _MAX_PIPELINE_STAGES,
                    len(jax.devices()),
                )
                if S >= 2:
                    pipe_plan = compile_dhm(
                        topo, params, quant=qs, n_stages=S
                    )
                    probe = make_pipeline_probe(pipe_plan, microbatch=batch)
                    findings += verify_plan(
                        pipe_plan,
                        scopes=("plan", "pipeline"),
                        where=f"{where}/pipelined",
                        batch=batch,
                        pipeline=probe,
                    )
                else:
                    log(f"  pipelined probe skipped for {where}: "
                        f"{len(jax.devices())} device(s)")
    return findings


def run_lint(paths=None):
    from repro.analysis.ast_lint import lint_paths

    root, default_roots = _repo_paths()
    return lint_paths(paths or default_roots, root=root)


def main(argv=None) -> int:
    args = _parse_args(argv if argv is not None else sys.argv[1:])
    from repro.analysis.findings import render_report

    log = (lambda s: print(s, file=sys.stderr)) if args.fmt == "text" else (
        lambda s: None
    )
    findings = []
    if args.command in ("verify", "all"):
        findings += run_verify(
            _select_topologies(args.topology),
            quants=args.quant,
            batch=args.batch,
            pipeline=not args.no_pipeline,
            log=log,
        )
    if args.command in ("lint", "all"):
        findings += run_lint(args.paths)

    n_err = sum(1 for f in findings if f.is_error)
    report = {
        "command": args.command,
        "findings": [f.to_json() for f in findings],
        "errors": n_err,
        "warnings": len(findings) - n_err,
    }
    if args.out:
        with open(args.out, "w", encoding="utf-8") as fh:
            json.dump(report, fh, indent=2)
            fh.write("\n")
    if args.fmt == "json":
        print(json.dumps(report, indent=2))
    else:
        print(render_report(findings, header=f"== repro.analysis {args.command} =="))
    return 1 if n_err else 0
