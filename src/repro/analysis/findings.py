"""Finding: the one record type both analysis engines emit.

Kept free of jax imports so the AST linter (and the CLI's ``--format``
plumbing) can run without touching the accelerator stack.
"""

from __future__ import annotations

import dataclasses

SEVERITIES = ("error", "warning")


@dataclasses.dataclass(frozen=True)
class Finding:
    """One diagnostic from the plan verifier or the AST linter.

    ``rule`` is the stable ID (``V0xx``/``V1xx``/``V2xx``/``V3xx`` for
    plan invariants, ``DHM0xx`` for lint rules); ``where`` locates it —
    ``file:line`` for lint, ``topology/quant/artifact`` for plan checks.
    """

    rule: str
    name: str
    severity: str  # "error" | "warning"
    message: str
    where: str = ""

    def __post_init__(self):
        if self.severity not in SEVERITIES:
            raise ValueError(
                f"severity must be one of {SEVERITIES}, got {self.severity!r}"
            )

    @property
    def is_error(self) -> bool:
        return self.severity == "error"

    def render(self) -> str:
        loc = f"{self.where}: " if self.where else ""
        return f"{loc}{self.severity.upper()} [{self.rule}] {self.message}"

    def to_json(self) -> dict:
        return dataclasses.asdict(self)


def has_errors(findings) -> bool:
    return any(f.is_error for f in findings)


def render_report(findings, *, header: str = "") -> str:
    """Human-readable multi-line report (``--format text``)."""
    lines = [header] if header else []
    for f in findings:
        lines.append(f.render())
    n_err = sum(1 for f in findings if f.is_error)
    n_warn = len(findings) - n_err
    lines.append(f"{n_err} error(s), {n_warn} warning(s)")
    return "\n".join(lines)
