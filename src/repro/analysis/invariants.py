"""The named invariant registry the plan verifier enforces.

Every invariant is a pure function ``(ProbeContext) -> list[Finding]``
registered under a stable ID. IDs are grouped by scope:

- ``plan``  (V3xx): serving-fitness checks — finite baked params and
  consistent stage IO geometry via ``jax.eval_shape``. This scope IS the
  ``check_plan`` rung-probe: the serving engine and CI enforce the same
  registry.
- ``structure`` (V0xx): jaxpr-structure proofs — one ``dot_general`` per
  conv layer, one ``pallas_call`` per fusion group, no conv primitive,
  no dtype drift, no host transfers, donation actually declared,
  in-kernel stream quantization.
- ``resource`` (V2xx): working-set fit — planner budget respected, cost
  model self-consistent, and the *traced* aval footprint bounded by the
  recorded working set.
- ``pipeline`` (V1xx): the traced ``run_pipelined`` closure contains
  exactly the ``EdgePlan``'s collectives — per-class exact-shape
  ppermutes covering the S-1 interior edges; boxed fallback is flagged
  with its padding fraction.

Invariants self-gate: one that does not apply to the probed artifact
(e.g. a pallas-body check against a ``ref``-backend plan) returns no
findings. Add a new invariant with the :func:`invariant` decorator —
it is picked up by the CLI, ``check_plan``, and the tests without
further wiring.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Dict

from repro.analysis.jaxpr_utils import (
    aval_bytes,
    count_primitive,
    count_primitive_in_pallas,
    find_primitive,
    float_avals,
    iter_eqns,
)

SCOPES = ("plan", "structure", "resource", "pipeline")

# Backends whose lowering goes through the pallas stream-conv kernels
# (the structural one-dot-per-layer contract); "ref" lowers through lax
# reference ops and is exempt from kernel-structure invariants.
_PALLAS_BACKENDS = ("pallas", "pallas_interpret")

# On CPU the default "pallas" backend falls back to XLA, so pallas_call
# only appears in traces under the interpret backend — body-structure
# invariants run against the interpret probe plan the CLI compiles.
_INTERPRET_BACKEND = "pallas_interpret"

# Primitives that would smuggle a host round-trip into the serving hot
# path (V005).
_HOST_TRANSFER_PRIMS = frozenset(
    {
        "device_put", "pure_callback", "io_callback", "debug_callback",
        "callback", "infeed", "outfeed",
    }
)


@dataclasses.dataclass(frozen=True)
class Invariant:
    id: str
    name: str
    scope: str
    doc: str
    fn: Callable


REGISTRY: Dict[str, Invariant] = {}


def invariant(id: str, *, name: str, scope: str):
    """Register an invariant check under a stable ID."""
    if scope not in SCOPES:
        raise ValueError(f"scope must be one of {SCOPES}, got {scope!r}")

    def deco(fn):
        if id in REGISTRY:
            raise ValueError(f"duplicate invariant id {id!r}")
        REGISTRY[id] = Invariant(
            id=id, name=name, scope=scope, doc=fn.__doc__ or "", fn=fn
        )
        return fn

    return deco


def by_scope(*scopes: str):
    return [inv for inv in REGISTRY.values() if inv.scope in scopes]


# ---------------------------------------------------------------------------
# plan scope (V3xx) — the check_plan rung-probe set


@invariant("V301", name="finite-params", scope="plan")
def _finite_params(ctx):
    """Every baked conv parameter is finite — NaN/Inf weights must never
    reach a serving rung."""
    import jax.numpy as jnp

    out = []
    for li, p in enumerate(ctx.plan.conv_params):
        for k, v in p.items():
            if not bool(jnp.isfinite(v).all()):
                out.append(ctx.error(
                    "V301",
                    f"conv layer {li} parameter {k!r} contains non-finite "
                    "values — the plan cannot serve",
                ))
    return out


@invariant("V302", name="io-chain", scope="plan")
def _io_chain(ctx):
    """StageIOSpec geometry is present, starts at the topology input, and
    chains stage-to-stage."""
    plan = ctx.plan
    ios = [st.io for st in plan.stages]
    if any(io is None for io in ios):
        return [ctx.error("V302", "plan stages miss StageIOSpec geometry")]
    out = []
    h, w = plan.topo.input_shape
    if tuple(ios[0].in_shape) != (h, w, plan.topo.input_channels):
        out.append(ctx.error(
            "V302",
            f"stage 0 input {ios[0].in_shape} does not match the topology "
            f"input {(h, w, plan.topo.input_channels)}",
        ))
    for s in range(len(ios) - 1):
        if tuple(ios[s].out_shape) != tuple(ios[s + 1].in_shape):
            out.append(ctx.error(
                "V302",
                f"stage {s} output {ios[s].out_shape} does not chain into "
                f"stage {s + 1} input {ios[s + 1].in_shape}",
            ))
    return out


@invariant("V303", name="stage-io-shape", scope="plan")
def _stage_io_shape(ctx):
    """Each emitted stage body, abstractly interpreted on its declared
    input (``jax.eval_shape`` — no FLOPs), produces exactly the shape its
    StageIOSpec promises."""
    import jax
    import jax.numpy as jnp

    plan = ctx.plan
    out = []
    for st in plan.stages:
        if st.io is None:
            continue  # V302 reports the missing geometry
        try:
            got = jax.eval_shape(
                st.fn,
                plan.stage_params(st.index),
                jax.ShapeDtypeStruct(
                    (1,) + tuple(st.io.in_shape), jnp.float32
                ),
            )
        except Exception as e:  # noqa: BLE001 — surfaced as a finding
            out.append(ctx.error(
                "V303",
                f"stage {st.index} body fails to trace on its declared "
                f"input {st.io.in_shape}: {e}",
            ))
            continue
        if tuple(got.shape[1:]) != tuple(st.io.out_shape):
            out.append(ctx.error(
                "V303",
                f"stage {st.index} body produces {tuple(got.shape[1:])}, "
                f"but its StageIOSpec promises {tuple(st.io.out_shape)}",
            ))
    return out


@invariant("V304", name="head-io", scope="plan")
def _head_io(ctx):
    """The FC head, abstractly interpreted on the final feature shape,
    yields rank-2 float logits."""
    import jax
    import jax.numpy as jnp

    plan = ctx.plan
    last = plan.stages[-1].io
    if last is None:
        return []
    try:
        got = jax.eval_shape(
            plan.head_fn,
            jax.ShapeDtypeStruct((1,) + tuple(last.out_shape), jnp.float32),
        )
    except Exception as e:  # noqa: BLE001 — surfaced as a finding
        return [ctx.error(
            "V304",
            f"head fails to trace on the final feature shape "
            f"{tuple(last.out_shape)}: {e}",
        )]
    if len(got.shape) != 2 or got.shape[0] != 1:
        return [ctx.error(
            "V304",
            f"head produces shape {tuple(got.shape)}; expected rank-2 "
            "(batch, n_classes) logits",
        )]
    return []


@invariant("V305", name="serving-surface", scope="plan")
def _serving_surface(ctx):
    """The plan exposes the full surface the serving layer consumes —
    ``topo.input_shape``/``input_channels`` (frame geometry), callable
    ``features`` and ``head_fn``, and at least one stage — so a hot-swap
    target missing any of it is rejected by ``verify_plan`` instead of
    crashing the tenant's warmup dispatch."""
    plan = ctx.plan
    out = []
    topo = getattr(plan, "topo", None)
    shape = getattr(topo, "input_shape", None)
    if (
        topo is None
        or not isinstance(shape, (tuple, list))
        or len(shape) != 2
        or not isinstance(getattr(topo, "input_channels", None), int)
    ):
        out.append(ctx.error(
            "V305",
            "plan topology does not declare the serving frame geometry "
            "(input_shape pair + integer input_channels)",
        ))
    if not callable(getattr(plan, "features", None)):
        out.append(ctx.error(
            "V305", "plan has no callable ``features`` extractor"
        ))
    if not callable(getattr(plan, "head_fn", None)):
        out.append(ctx.error(
            "V305", "plan has no callable ``head_fn``"
        ))
    if not getattr(plan, "stages", ()):
        out.append(ctx.error("V305", "plan has no stages"))
    return out


# ---------------------------------------------------------------------------
# structure scope (V0xx)


@invariant("V001", name="one-dot-per-conv-layer", scope="structure")
def _one_dot_per_layer(ctx):
    """The traced feature extractor contains exactly one ``dot_general``
    per conv layer — the paper's one-MACC-array-per-actor mapping; a
    kernel that decomposes into per-tap matmuls (the seed's 25-dot
    lowering) fails here."""
    plan = ctx.plan
    if plan.backend not in _PALLAS_BACKENDS:
        return []
    n_conv = sum(len(st.conv_layers) for st in plan.stages)
    got = count_primitive(ctx.features_jaxpr(), "dot_general")
    if got != n_conv:
        return [ctx.error(
            "V001",
            f"feature trace has {got} dot_general eqns for {n_conv} conv "
            "layers — expected exactly one per layer",
        )]
    return []


@invariant("V002", name="one-pallas-call-per-group", scope="structure")
def _one_pallas_call_per_group(ctx):
    """On the pallas path each fusion group lowers to exactly ONE fused
    kernel invocation (pallas_call) — the no-external-memory dataflow
    across fused layer boundaries."""
    plan = ctx.plan
    if plan.backend != _INTERPRET_BACKEND:
        return []  # pallas_call is only visible under the interpret probe
    n_groups = len(plan.fusion_groups)
    got = count_primitive(ctx.features_jaxpr(), "pallas_call")
    if got != n_groups:
        return [ctx.error(
            "V002",
            f"feature trace has {got} pallas_call eqns for {n_groups} "
            "fusion groups — expected exactly one per group",
        )]
    return []


@invariant("V003", name="no-conv-primitive", scope="structure")
def _no_conv_primitive(ctx):
    """No ``conv_general_dilated`` survives in the feature trace — the
    DHM lowering maps convolutions onto streamed matmuls, never onto
    XLA's im2col convolution."""
    plan = ctx.plan
    if plan.backend not in _PALLAS_BACKENDS:
        return []
    got = count_primitive(ctx.features_jaxpr(), "conv_general_dilated")
    if got:
        return [ctx.error(
            "V003",
            f"feature trace contains {got} conv_general_dilated eqn(s) — "
            "the plan fell back to XLA convolution",
        )]
    return []


@invariant("V004", name="dtype-drift", scope="structure")
def _dtype_drift(ctx):
    """All floating-point values in the end-to-end closure are float32
    (no float64/bfloat16 drift), and the logits are not weak-typed."""
    jaxpr = ctx.forward_jaxpr()
    out = []
    bad = sorted(
        {str(a.dtype) for a in float_avals(jaxpr) if str(a.dtype) != "float32"}
    )
    if bad:
        out.append(ctx.error(
            "V004",
            f"closure trace contains non-float32 float dtypes: {bad}",
        ))
    for var in jaxpr.jaxpr.outvars if hasattr(jaxpr, "jaxpr") else jaxpr.outvars:
        aval = getattr(var, "aval", None)
        if getattr(aval, "weak_type", False):
            out.append(ctx.error(
                "V004",
                "closure output is weak-typed — a python-scalar promotion "
                "leaked into the logits",
            ))
    return out


@invariant("V005", name="no-host-transfer", scope="structure")
def _no_host_transfer(ctx):
    """The jitted closure contains no host-transfer primitives
    (device_put / callbacks / infeed) — nothing may stall the serving
    hot path on a host round-trip."""
    seen = {}
    for eqn in iter_eqns(ctx.forward_jaxpr()):
        nm = eqn.primitive.name
        if nm in _HOST_TRANSFER_PRIMS:
            seen[nm] = seen.get(nm, 0) + 1
    if seen:
        return [ctx.error(
            "V005",
            f"closure trace contains host-transfer primitives: {seen}",
        )]
    return []


@invariant("V006", name="donation-declared", scope="structure")
def _donation_declared(ctx):
    """``jitted_forward(donate=True)`` really declares its input donation:
    either the lowering carries an aliasing/donation marker, or jax
    reports the donation unusable (input cannot alias the logits — still
    a declared donation). Neither signal means the donate flag was
    silently dropped."""
    text, warned = ctx.lower_donated()
    if text is None:
        return []  # plan has no jitted_forward(donate=) surface
    if "jax.buffer_donor" in text or "tf.aliasing_output" in text or warned:
        return []
    return [ctx.error(
        "V006",
        "donate=True produced neither an aliasing marker in the lowering "
        "nor an unusable-donation report — the donation was dropped",
    )]


@invariant("V007", name="in-kernel-stream-quant", scope="structure")
def _in_kernel_stream_quant(ctx):
    """With ``act_bits`` set, the feature-stream quantization rounds live
    INSIDE the fused kernels (one per conv layer), not as separate XLA
    ops between kernel calls — the paper quantizes the pixel flow inside
    the actor. Int8 plans additionally quantize each group's INPUT frame
    host-side (outside the kernel, so the resident frame is 1-byte codes
    — the V204 contract): exactly one extra round per fusion group is
    legal there, and no more."""
    plan = ctx.plan
    if plan.backend != _INTERPRET_BACKEND or plan.quant.act_bits is None:
        return []
    jaxpr = ctx.features_jaxpr()
    n_conv = sum(len(st.conv_layers) for st in plan.stages)
    inside = count_primitive_in_pallas(jaxpr, "round")
    total = count_primitive(jaxpr, "round")
    int8 = bool(getattr(plan.quant, "int8_compute", False))
    allowed_outside = len(plan.fusion_groups) if int8 else 0
    out = []
    if inside != n_conv:
        out.append(ctx.error(
            "V007",
            f"{inside} in-kernel stream-quant round(s) for {n_conv} conv "
            "layers — expected one per layer inside the pallas bodies",
        ))
    if total - inside != allowed_outside:
        out.append(ctx.error(
            "V007",
            f"{total - inside} stream-quant round(s) outside the kernels "
            f"in the XLA graph — expected {allowed_outside} "
            f"({'one input-quantize per fusion group' if int8 else 'none'})",
        ))
    return out


@invariant("V008", name="integer-conv-compute", scope="structure")
def _integer_conv_compute(ctx):
    """An ``int8_compute`` plan really computes in integers: every conv
    contraction in the feature trace takes integer operands and
    accumulates into an int32 result (``preferred_element_type``) — no
    hidden decode-to-fp32 matmul before the requantizing epilogue."""
    plan = ctx.plan
    if plan.backend not in _PALLAS_BACKENDS:
        return []
    if not bool(getattr(plan.quant, "int8_compute", False)):
        return []
    out = []
    dots = find_primitive(ctx.features_jaxpr(), "dot_general")
    if not dots:
        return [ctx.error(
            "V008", "int8 plan's feature trace contains no dot_general eqns"
        )]
    import jax.numpy as jnp

    for di, eqn in enumerate(dots):
        in_dtypes = [getattr(v.aval, "dtype", None) for v in eqn.invars]
        if not all(
            d is not None and jnp.issubdtype(d, jnp.integer) for d in in_dtypes
        ):
            out.append(ctx.error(
                "V008",
                f"dot_general #{di} takes {[str(d) for d in in_dtypes]} "
                "operands — an int8 plan upcast to float before the matmul",
            ))
            continue
        out_dtype = getattr(eqn.outvars[0].aval, "dtype", None)
        if out_dtype != jnp.int32:
            out.append(ctx.error(
                "V008",
                f"dot_general #{di} accumulates into {out_dtype} — int8 "
                "contractions must accumulate into int32 "
                "(preferred_element_type)",
            ))
    return out


# ---------------------------------------------------------------------------
# resource scope (V2xx)


@invariant("V201", name="group-budget", scope="resource")
def _group_budget(ctx):
    """Every fusion group's costed working set fits the plan's VMEM
    budget (skipped for budget-0 per-layer lowerings, whose single-layer
    groups are emitted unconditionally)."""
    plan = ctx.plan
    if plan.vmem_budget <= 0:
        return []
    out = []
    for gi, g in enumerate(plan.fusion_groups):
        if g.working_set > plan.vmem_budget:
            out.append(ctx.error(
                "V201",
                f"fusion group {gi} (layers {tuple(g.layers)}) working set "
                f"{g.working_set} B exceeds the vmem budget "
                f"{plan.vmem_budget} B",
            ))
    return out


@invariant("V202", name="cost-model-consistent", scope="resource")
def _cost_model_consistent(ctx):
    """Each group's recorded working set equals what ``fusion.py`` /
    ``halo.py`` cost today for the same layers and block_rows — a stale
    or hand-edited plan cannot smuggle in an outdated cost."""
    from repro.core.dhm.fusion import (
        group_working_set,
        group_working_set_breakdown,
        plan_elem_bytes,
    )

    plan = ctx.plan
    elem_bytes = plan_elem_bytes(plan.quant)
    out = []
    for gi, g in enumerate(plan.fusion_groups):
        try:
            want = group_working_set(
                plan.topo, g.layers, block_rows=g.block_rows,
                elem_bytes=elem_bytes,
            )
        except Exception as e:  # noqa: BLE001 — surfaced as a finding
            out.append(ctx.error(
                "V202",
                f"fusion group {gi} (layers {tuple(g.layers)}) cannot be "
                f"re-costed: {e}",
            ))
            continue
        if want != g.working_set:
            parts = group_working_set_breakdown(
                plan.topo, g.layers, block_rows=g.block_rows,
                elem_bytes=elem_bytes,
            )
            top = max(parts, key=parts.get)
            out.append(ctx.error(
                "V202",
                f"fusion group {gi} (layers {tuple(g.layers)}) records a "
                f"working set of {g.working_set} B but the cost model says "
                f"{want} B at {elem_bytes} B/elt (largest component: {top} "
                f"= {parts[top]} B)",
            ))
    return out


@invariant("V203", name="traced-working-set", scope="resource")
def _traced_working_set(ctx):
    """The traced per-kernel footprint (pallas_call operand avals + the
    widest body intermediate) stays under the group's costed working set
    — a planner under-estimate surfaces here, not as a Mosaic OOM."""
    plan = ctx.plan
    if plan.backend != _INTERPRET_BACKEND:
        return []
    calls = find_primitive(ctx.features_jaxpr(), "pallas_call")
    groups = plan.fusion_groups
    if len(calls) != len(groups):
        return []  # V002 reports the mismatch
    out = []
    for gi, (eqn, g) in enumerate(zip(calls, groups)):
        operands = sum(aval_bytes(v.aval) for v in eqn.invars)
        widest = 0
        for sub in iter_eqns(eqn.params.get("jaxpr", [])):
            for var in sub.outvars:
                widest = max(widest, aval_bytes(getattr(var, "aval", None)))
        bound = operands + widest
        if bound > g.working_set:
            out.append(ctx.error(
                "V203",
                f"fusion group {gi} (layers {tuple(g.layers)}): traced "
                f"footprint lower bound {bound} B (operands {operands} + "
                f"widest intermediate {widest}) exceeds the costed working "
                f"set {g.working_set} B — the planner under-estimated",
            ))
    return out


@invariant("V204", name="int8-slab-costing", scope="resource")
def _int8_slab_costing(ctx):
    """An int8 plan charges int8 slab bytes (1 B/elt for the resident
    frame, feature slabs and weight codes; int32 accumulators stay 4 B)
    against ``vmem_budget`` — the recorded working sets must equal the
    int8 costing and, for multi-layer groups, be strictly below what the
    same group costs at fp32. A plan that books fp32 bytes under an int8
    contract wastes the budget headroom the 1-byte slabs buy."""
    from repro.core.dhm.fusion import group_working_set, plan_elem_bytes

    plan = ctx.plan
    if plan_elem_bytes(plan.quant) != 1:
        return []
    out = []
    for gi, g in enumerate(plan.fusion_groups):
        try:
            want_int8 = group_working_set(
                plan.topo, g.layers, block_rows=g.block_rows, elem_bytes=1
            )
            want_fp32 = group_working_set(
                plan.topo, g.layers, block_rows=g.block_rows, elem_bytes=4
            )
        except Exception as e:  # noqa: BLE001 — surfaced as a finding
            out.append(ctx.error(
                "V204",
                f"fusion group {gi} (layers {tuple(g.layers)}) cannot be "
                f"re-costed: {e}",
            ))
            continue
        if g.working_set != want_int8:
            out.append(ctx.error(
                "V204",
                f"fusion group {gi} (layers {tuple(g.layers)}) records "
                f"{g.working_set} B under an int8 plan; the int8 costing "
                f"says {want_int8} B",
            ))
        elif g.working_set >= want_fp32:
            out.append(ctx.error(
                "V204",
                f"fusion group {gi} (layers {tuple(g.layers)}) int8 "
                f"working set {g.working_set} B is not below the fp32 "
                f"costing {want_fp32} B — int8 slabs bought no headroom",
            ))
    return out


# ---------------------------------------------------------------------------
# pipeline scope (V1xx)


def _ppermute_by_class(ctx):
    """Map each traced ppermute eqn to its EdgePlan shape class by perm
    identity; returns (assignments, unmatched_eqns)."""
    probe = ctx.pipeline
    calls = find_primitive(probe.jaxpr, "ppermute")
    pairs_of = {
        c: frozenset(map(tuple, probe.edge_plan.class_pairs(c)))
        for c in range(probe.edge_plan.n_classes)
    }
    assigned, unmatched = [], []
    for eqn in calls:
        perm = frozenset(map(tuple, eqn.params.get("perm", ())))
        for c, pairs in pairs_of.items():
            if perm == pairs:
                assigned.append((c, eqn))
                break
        else:
            unmatched.append(eqn)
    return assigned, unmatched


@invariant("V101", name="interior-edge-count", scope="pipeline")
def _interior_edge_count(ctx):
    """The traced pipelined closure contains exactly the EdgePlan's
    collectives: one ppermute per shape class, whose perms together cover
    every interior edge (s, s+1) exactly once — S-1 edges total."""
    probe = ctx.pipeline
    if probe is None:
        return []
    ep = probe.edge_plan
    if ep.n_edges == 0:
        return []
    assigned, unmatched = _ppermute_by_class(ctx)
    out = []
    if unmatched:
        perms = [sorted(e.params.get("perm", ())) for e in unmatched]
        out.append(ctx.error(
            "V101",
            f"{len(unmatched)} traced ppermute(s) match no EdgePlan shape "
            f"class: perms {perms}",
        ))
    got_classes = sorted(c for c, _ in assigned)
    want_classes = list(range(ep.n_classes))
    if got_classes != want_classes:
        out.append(ctx.error(
            "V101",
            f"traced collectives cover shape classes {got_classes}; the "
            f"EdgePlan requires exactly one ppermute per class "
            f"{want_classes}",
        ))
        return out
    covered = set()
    for c, _ in assigned:
        covered.update(map(tuple, ep.class_pairs(c)))
    want = {(s, s + 1) for s in range(ep.n_edges)}
    if covered != want:
        out.append(ctx.error(
            "V101",
            f"traced ppermutes cover interior edges {sorted(covered)}; the "
            f"plan has {ep.n_edges} interior edges {sorted(want)}",
        ))
    return out


@invariant("V102", name="edge-exact-shape", scope="pipeline")
def _edge_exact_shape(ctx):
    """Each class's ppermute moves exactly (microbatch, *class_shape)
    elements — no silently widened (padded) transfer on the exact path."""
    probe = ctx.pipeline
    if probe is None or probe.edge_plan.n_edges == 0:
        return []
    assigned, _ = _ppermute_by_class(ctx)
    out = []
    for c, eqn in assigned:
        want = (probe.mb_local,) + tuple(probe.edge_plan.class_shapes[c])
        got = tuple(eqn.invars[0].aval.shape)
        if got != want:
            out.append(ctx.error(
                "V102",
                f"shape class {c} ppermute moves {got}; the EdgePlan "
                f"promises exactly {want}",
            ))
    return out


@invariant("V103", name="boxed-padding", scope="pipeline")
def _boxed_padding(ctx):
    """A boxed (max-shape) edge fallback is legal but pays padding bytes
    on every hop — flag it with the fraction so the regression is
    visible, not silent."""
    probe = ctx.pipeline
    if probe is None or probe.edge_plan.mode != "boxed":
        return []
    frac = probe.edge_plan.padding_fraction()
    return [ctx.warning(
        "V103",
        f"edge plan fell back to boxed transfers: "
        f"{frac:.1%} of every interior-edge hop is padding",
    )]
