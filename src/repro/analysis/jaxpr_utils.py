"""Jaxpr-walking helpers — the ONE home of ``count_primitive``.

Previously three tests each hand-rolled their own ``_count_primitive``;
they (and the plan verifier) now share these. Everything duck-types on
``.eqns`` / ``.jaxpr`` rather than isinstance-checking ``jax.core``
classes, so the module imports without pulling in jax — the CLI's lint
path stays accelerator-free.
"""

from __future__ import annotations


def subjaxprs(val):
    """Yield every (open) jaxpr reachable from an ``eqn.params`` value —
    a ClosedJaxpr, a bare Jaxpr, or (nested) lists/tuples of either."""
    if hasattr(val, "jaxpr") and hasattr(getattr(val, "jaxpr"), "eqns"):
        yield val.jaxpr  # ClosedJaxpr
    elif hasattr(val, "eqns"):
        yield val  # Jaxpr
    elif isinstance(val, (list, tuple)):
        for v in val:
            yield from subjaxprs(v)


def as_jaxpr(j):
    """Accept a Jaxpr or ClosedJaxpr (or anything make_jaxpr returned)."""
    return j.jaxpr if hasattr(j, "jaxpr") and hasattr(j.jaxpr, "eqns") else j


def iter_eqns(jaxpr, *, into: str = "all"):
    """Depth-first over every eqn of ``jaxpr`` and its sub-jaxprs.

    ``into="all"`` descends into every sub-jaxpr (pjit, scan, cond,
    pallas_call bodies alike); ``into="outside_pallas"`` stops at
    pallas_call boundaries (yields the pallas_call eqn itself but not its
    body); ``into="inside_pallas"`` yields only eqns that live inside
    some pallas_call body.
    """
    jaxpr = as_jaxpr(jaxpr)

    def walk(j, in_pallas):
        for eqn in j.eqns:
            is_pallas = eqn.primitive.name == "pallas_call"
            if into == "all":
                yield eqn
            elif into == "outside_pallas" and not in_pallas:
                yield eqn
            elif into == "inside_pallas" and in_pallas:
                yield eqn
            if into == "outside_pallas" and is_pallas:
                continue
            for v in eqn.params.values():
                for sub in subjaxprs(v):
                    yield from walk(sub, in_pallas or is_pallas)

    yield from walk(jaxpr, False)


def count_primitive(jaxpr, name: str, *, into: str = "all") -> int:
    """Recursively count occurrences of primitive ``name`` in a jaxpr
    (descends into pjit/scan/pallas_call sub-jaxprs per ``into``)."""
    return sum(
        1 for eqn in iter_eqns(jaxpr, into=into) if eqn.primitive.name == name
    )


def count_primitive_in_pallas(jaxpr, name: str) -> int:
    """Count occurrences of ``name`` that live INSIDE pallas_call bodies."""
    return count_primitive(jaxpr, name, into="inside_pallas")


def find_primitive(jaxpr, name: str, *, into: str = "all") -> list:
    """All eqns whose primitive is ``name`` (same descent as iter_eqns)."""
    return [
        eqn for eqn in iter_eqns(jaxpr, into=into)
        if eqn.primitive.name == name
    ]


def aval_bytes(aval) -> int:
    """Bytes of one abstract value (0 for non-array avals)."""
    shape = getattr(aval, "shape", None)
    dtype = getattr(aval, "dtype", None)
    if shape is None or dtype is None:
        return 0
    n = 1
    for d in shape:
        n *= int(d)
    return n * dtype.itemsize


def _is_float(dt) -> bool:
    # kind == "f" misses the ml_dtypes extension types (bfloat16, fp8
    # variants register with kind "V") — match on the dtype name too.
    return dt is not None and (dt.kind == "f" or "float" in str(dt))


def float_avals(jaxpr, *, into: str = "all"):
    """Every floating-point aval appearing as an eqn output (plus the
    jaxpr's own outputs) — the surface the dtype-drift invariant scans."""
    jaxpr = as_jaxpr(jaxpr)
    seen = []
    for eqn in iter_eqns(jaxpr, into=into):
        for var in eqn.outvars:
            aval = getattr(var, "aval", None)
            if _is_float(getattr(aval, "dtype", None)):
                seen.append(aval)
    for var in jaxpr.outvars:
        aval = getattr(var, "aval", None)
        if _is_float(getattr(aval, "dtype", None)):
            seen.append(aval)
    return seen
