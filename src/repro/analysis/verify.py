"""Plan verifier: abstract-interpret a ``CompiledDHM`` against the
invariant registry — ``jax.eval_shape`` / ``jax.make_jaxpr`` only, no
FLOPs executed.

``verify_plan`` returns findings; ``check_plan`` (what
``CompiledDHM.self_check`` now delegates to) raises ``PlanCheckError``
carrying the failed invariant IDs, so the serving engine's rung probe
and the CLI enforce the same registry.
"""

from __future__ import annotations

import dataclasses
import warnings
from typing import Optional

from repro.analysis.findings import Finding
from repro.analysis.invariants import REGISTRY, SCOPES


@dataclasses.dataclass
class PipelineProbe:
    """A traced ``run_pipelined`` closure plus the EdgePlan it must
    realize: what the pipeline-scope invariants inspect."""

    jaxpr: object  # make_jaxpr(runner.apply)(leaves, microbatches)
    edge_plan: object
    cfg: object
    mb_local: int  # per-device microbatch rows each ppermute moves


class ProbeContext:
    """Cached abstract traces of one plan artifact; the argument every
    invariant check receives."""

    def __init__(self, plan, *, where: str = "", batch: int = 2,
                 pipeline: Optional[PipelineProbe] = None):
        self.plan = plan
        self.batch = batch
        self.where = where or getattr(plan.topo, "name", "plan")
        self.pipeline = pipeline
        self._features_jaxpr = None
        self._forward_jaxpr = None
        self._donated = None

    # -- finding constructors ------------------------------------------------

    def error(self, rule: str, message: str) -> Finding:
        return Finding(
            rule=rule, name=REGISTRY[rule].name, severity="error",
            message=message, where=self.where,
        )

    def warning(self, rule: str, message: str) -> Finding:
        return Finding(
            rule=rule, name=REGISTRY[rule].name, severity="warning",
            message=message, where=self.where,
        )

    # -- cached abstract traces ----------------------------------------------

    def _input_spec(self):
        import jax
        import jax.numpy as jnp

        h, w = self.plan.topo.input_shape
        c = self.plan.topo.input_channels
        return jax.ShapeDtypeStruct((self.batch, h, w, c), jnp.float32)

    def features_jaxpr(self):
        """Trace of the conv stack alone (no FC head): the surface the
        kernel-structure counts run against."""
        import jax

        if self._features_jaxpr is None:
            self._features_jaxpr = jax.make_jaxpr(self.plan.features)(
                self._input_spec()
            )
        return self._features_jaxpr

    def forward_jaxpr(self):
        """Trace of the end-to-end jitted closure (features + head)."""
        import jax

        if self._forward_jaxpr is None:
            self._forward_jaxpr = jax.make_jaxpr(
                self.plan.jitted_forward()
            )(self._input_spec())
        return self._forward_jaxpr

    def lower_donated(self):
        """(lowered_text, donation_warning_fired) of
        ``jitted_forward(donate=True)``; (None, False) when the plan has
        no such surface."""
        if self._donated is None:
            # Memoized on the plan: the unusable-donation warning only
            # fires on a FRESH compile, so re-verifying the same plan
            # (hot-swap admission does) would misread the jit-cache hit
            # as a dropped donation. The verdict is a property of the
            # plan's closure and cannot change after first probe.
            cached = getattr(self.plan, "_donation_probe", None)
            if cached is not None:
                self._donated = cached
                return self._donated
            fwd = getattr(self.plan, "jitted_forward", None)
            if fwd is None:
                self._donated = (None, False)
            else:
                with warnings.catch_warnings(record=True) as caught:
                    warnings.simplefilter("always")
                    lowered = fwd(donate=True).lower(self._input_spec())
                    text = lowered.as_text()
                    if (
                        "jax.buffer_donor" not in text
                        and "tf.aliasing_output" not in text
                        and not _donation_warned(caught)
                    ):
                        # Lowering alone may defer the donation check to
                        # compile time — pay the compile before concluding
                        # the donation was dropped.
                        lowered.compile()
                self._donated = (text, _donation_warned(caught))
            try:
                object.__setattr__(
                    self.plan, "_donation_probe", self._donated
                )
            except (AttributeError, TypeError):
                pass  # probe-only stand-ins (e.g. test doubles w/ slots)
        return self._donated


def _donation_warned(caught) -> bool:
    return any("donated" in str(w.message).lower() for w in caught)


def verify_plan(
    plan,
    *,
    scopes=None,
    ids=None,
    where: str = "",
    batch: int = 2,
    pipeline: Optional[PipelineProbe] = None,
) -> list:
    """Run the invariant registry against one plan artifact.

    ``scopes`` restricts to registry scopes (default: all);
    ``ids`` restricts to specific invariant IDs. Returns the findings
    (possibly empty); never executes the model.
    """
    if scopes is None:
        scopes = SCOPES
    unknown = set(scopes) - set(SCOPES)
    if unknown:
        raise ValueError(f"unknown scopes {sorted(unknown)}; have {SCOPES}")
    ctx = ProbeContext(plan, where=where, batch=batch, pipeline=pipeline)
    findings = []
    for inv in REGISTRY.values():
        if inv.scope not in scopes:
            continue
        if ids is not None and inv.id not in ids:
            continue
        findings.extend(inv.fn(ctx))
    return findings


def check_plan(plan) -> None:
    """The serving-fitness probe: run the ``plan``-scope invariants and
    raise ``PlanCheckError`` (carrying the failed invariant IDs) on any
    error — what ``CompiledDHM.self_check`` and the engine's rung
    activation enforce."""
    findings = [f for f in verify_plan(plan, scopes=("plan",)) if f.is_error]
    if findings:
        from repro.core.dhm.compiler import PlanCheckError

        ids = sorted({f.rule for f in findings})
        detail = "; ".join(f.message for f in findings)
        raise PlanCheckError(
            f"{getattr(plan.topo, 'name', 'plan')}: plan check failed "
            f"[{', '.join(ids)}]: {detail}",
            invariants=ids,
        )


def make_pipeline_probe(
    plan, *, mesh=None, n_microbatches: Optional[int] = None,
    microbatch: int = 2, overlap: bool = False, edge_mode: str = "auto",
) -> PipelineProbe:
    """Build and TRACE (never run) the plan's pipelined closure on a
    stage mesh; returns the :class:`PipelineProbe` the pipeline-scope
    invariants consume. Requires ``len(mesh devices) >= plan.n_stages``
    (the CLI forces 8 host devices before importing jax)."""
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.core.dhm.engine import build_plan_pipeline
    from repro.core.dhm.pipeline import PipelineConfig

    S = plan.n_stages
    if mesh is None:
        devs = jax.devices()
        if len(devs) < S:
            raise ValueError(
                f"pipeline probe needs >= {S} devices, have {len(devs)} — "
                "set XLA_FLAGS=--xla_force_host_platform_device_count=8 "
                "before importing jax (the analysis CLI does this)"
            )
        mesh = jax.sharding.Mesh(np.asarray(devs[:S]), ("stage",))
    M = n_microbatches if n_microbatches is not None else max(S, 2)
    cfg = PipelineConfig(
        S, M, stage_axis=mesh.axis_names[0], overlap=overlap,
        edge_mode=edge_mode,
    )
    runner = build_plan_pipeline(plan, mesh=mesh, cfg=cfg, microbatch=microbatch)
    h, w = plan.topo.input_shape
    mbs = jax.ShapeDtypeStruct(
        (M, microbatch, h, w, plan.topo.input_channels), jnp.float32
    )
    jaxpr = jax.make_jaxpr(runner.apply)(runner.stacked_leaves, mbs)
    return PipelineProbe(
        jaxpr=jaxpr, edge_plan=runner.edge_plan, cfg=cfg, mb_local=microbatch
    )
