"""Checkpointing: atomic, step-indexed, resumable save/restore of the full
training state (params + optimizer + data cursor)."""
from repro.checkpoint.store import (
    CheckpointManager,
    save_pytree,
    load_pytree,
)

__all__ = ["CheckpointManager", "save_pytree", "load_pytree"]
