"""Checkpoint store.

Design (production contract, scaled to this container):
- **Atomic commits**: state is written to ``step_N.tmp/`` then renamed;
  a crash mid-write never corrupts the latest checkpoint. The rename is
  the commit point (restart-safe).
- **Step-indexed retention**: ``keep`` newest checkpoints are retained; a
  checkpoint currently being restored is never deleted.
- **Pytree layout preserved**: leaves stored as .npy (zero-copy via numpy),
  structure as a JSON treedef, dtypes/shapes validated on load.
- **Multi-host**: on a real cluster each host writes only the shards it
  owns (via ``jax.experimental.multihost_utils``); here process count is 1
  and whole arrays are written. The manager's API is already
  process-indexed so the swap-in is local.

Async: ``save`` returns after enqueueing device->host transfers and does
file IO on a worker thread (overlap with the next step), matching the
standard async-checkpoint pattern; ``wait()`` joins outstanding writes.
"""
from __future__ import annotations

import json
import os
import re
import shutil
import threading
from typing import Any, Optional

import jax
import numpy as np

_LEAF_FILE = "leaf_{:05d}.npy"


def _flatten_with_paths(tree):
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return leaves, treedef


def save_pytree(path: str, tree: Any) -> None:
    """Write a pytree to ``path`` (directory), atomically."""
    tmp = path + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp, exist_ok=True)
    leaves, treedef = _flatten_with_paths(tree)
    meta = {"n_leaves": len(leaves), "treedef": str(treedef)}
    for i, leaf in enumerate(leaves):
        np.save(os.path.join(tmp, _LEAF_FILE.format(i)), np.asarray(leaf))
    with open(os.path.join(tmp, "meta.json"), "w") as f:
        json.dump(meta, f)
    if os.path.exists(path):
        shutil.rmtree(path)
    os.rename(tmp, path)  # commit point


def load_pytree(path: str, like: Any) -> Any:
    """Load into the structure of ``like`` (shape/dtype validated)."""
    leaves_like, treedef = jax.tree_util.tree_flatten(like)
    with open(os.path.join(path, "meta.json")) as f:
        meta = json.load(f)
    if meta["n_leaves"] != len(leaves_like):
        raise ValueError(
            f"checkpoint has {meta['n_leaves']} leaves, expected "
            f"{len(leaves_like)}"
        )
    out = []
    for i, ref in enumerate(leaves_like):
        arr = np.load(os.path.join(path, _LEAF_FILE.format(i)))
        ref_shape = tuple(getattr(ref, "shape", ()))
        if tuple(arr.shape) != ref_shape:
            raise ValueError(
                f"leaf {i}: checkpoint shape {arr.shape} != expected "
                f"{ref_shape}"
            )
        out.append(arr)
    return jax.tree_util.tree_unflatten(treedef, out)


class CheckpointManager:
    """Step-indexed checkpoint directory with retention + async writes."""

    STEP_RE = re.compile(r"^step_(\d+)$")

    def __init__(self, directory: str, *, keep: int = 3, async_write: bool = True):
        self.directory = directory
        self.keep = keep
        self.async_write = async_write
        self._pending: Optional[threading.Thread] = None
        os.makedirs(directory, exist_ok=True)

    def _step_path(self, step: int) -> str:
        return os.path.join(self.directory, f"step_{step}")

    def all_steps(self) -> list:
        steps = []
        for name in os.listdir(self.directory):
            m = self.STEP_RE.match(name)
            if m and not name.endswith(".tmp"):
                steps.append(int(m.group(1)))
        return sorted(steps)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def save(self, step: int, state: Any) -> None:
        """Snapshot to host then write (optionally on a worker thread)."""
        host_state = jax.tree_util.tree_map(np.asarray, state)

        def _write():
            save_pytree(self._step_path(step), host_state)
            self._gc()

        self.wait()
        if self.async_write:
            self._pending = threading.Thread(target=_write, daemon=True)
            self._pending.start()
        else:
            _write()

    def wait(self) -> None:
        if self._pending is not None:
            self._pending.join()
            self._pending = None

    def restore(self, like: Any, step: Optional[int] = None) -> tuple:
        """Returns (state, step). Raises FileNotFoundError if none exist."""
        self.wait()
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.directory}")
        return load_pytree(self._step_path(step), like), step

    def _gc(self) -> None:
        steps = self.all_steps()
        for s in steps[: -self.keep] if self.keep else []:
            shutil.rmtree(self._step_path(s), ignore_errors=True)
