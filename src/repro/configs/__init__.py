"""Architecture configs: the paper's CNNs + the 10 assigned LM-family
architectures, each with its input-shape set."""
from repro.configs.base import ArchConfig, ShapeConfig, SHAPES, get_arch, list_archs

__all__ = ["ArchConfig", "ShapeConfig", "SHAPES", "get_arch", "list_archs"]
