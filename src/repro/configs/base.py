"""Config schema shared by all architectures, plus the assigned input-shape
set and the config registry."""
from __future__ import annotations

import dataclasses
import importlib
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0  # 0 -> d_model // n_heads
    # Block structure ------------------------------------------------------
    block_pattern: Tuple[str, ...] = ("attn",)  # cycle: attn | mamba | rglru
    attn_pattern: Tuple[str, ...] = ("causal",)  # cycle over *attn* layers
    window: int = 0  # local-attention window
    chunk: int = 0  # chunked-attention chunk (llama4 iRoPE)
    parallel_block: bool = False  # x + attn(ln x) + mlp(ln x) (command-r)
    norm: str = "rmsnorm"  # rmsnorm | layernorm
    mlp_act: str = "silu"  # silu | gelu_glu | gelu
    qkv_bias: bool = False
    qk_norm: bool = False  # qwen3
    rope_theta: float = 10_000.0
    pos_embedding: str = "rope"  # rope | learned | none
    max_position: int = 0  # learned pos table size
    tie_embeddings: bool = False
    # MoE --------------------------------------------------------------
    n_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0
    shared_expert_d_ff: int = 0
    capacity_factor: float = 1.25
    # SSM / RG-LRU -------------------------------------------------------
    ssm_state: int = 0
    ssm_conv: int = 4
    ssm_expand: int = 2
    lru_width: int = 0
    # Encoder-decoder ----------------------------------------------------
    n_encoder_layers: int = 0
    encoder_seq: int = 0  # whisper: 1500 frames after the conv stub
    # VLM ----------------------------------------------------------------
    n_prefix_tokens: int = 0  # precomputed patch embeddings prepended
    # Misc -----------------------------------------------------------------
    dtype: str = "bfloat16"
    remat: str = "full"  # full | dots | none
    scan_layers: bool = True
    # §Perf optimization switches (see launch/optflags.py; default = the
    # paper-faithful baseline).
    opt_no_f32_cast_attn: bool = False  # bf16 attn operands, f32 accumulate
    opt_ce_remat: bool = False  # recompute CE logit chunks in backward
    opt_bf16_ssm: bool = False  # bf16 SSM discretized inputs
    opt_shard_attn_batch: bool = False  # pin batch sharding inside attention

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def q_groups(self) -> int:
        return self.n_heads // self.n_kv_heads if self.n_kv_heads else 0

    @property
    def is_subquadratic(self) -> bool:
        """True when no layer attends over unbounded context (long_500k ok)."""
        if all(b != "attn" for b in self.block_pattern):
            return True
        return all(k in ("local", "chunked") for k in self.attn_pattern) or (
            self.window > 0 and "causal" not in self.attn_pattern
        )

    def supports_shape(self, shape: "ShapeConfig") -> bool:
        if shape.kind == "long_decode":
            # Sub-quadratic only (see DESIGN.md §Arch-applicability). Archs
            # with a bounded-window pattern qualify even if a minority of
            # layers are full-attention ONLY when those layers are
            # attention-free... llama4's 1:4 full-attn layers use a decode
            # KV cache that stays O(S) in memory but O(1) per step compute;
            # we admit patterns whose quadratic-layer fraction is 0, plus
            # ssm/hybrid/chunked families.
            return self.family in ("ssm", "hybrid") or self.chunk > 0
        return True

    def attn_kind_for_layer(self, layer_idx: int) -> str:
        return self.attn_pattern[layer_idx % len(self.attn_pattern)]

    def scaled_down(self, **overrides) -> "ArchConfig":
        """A reduced same-family config for CPU smoke tests."""
        small = dict(
            n_layers=max(2, len(self.block_pattern)),
            d_model=64,
            n_heads=4,
            n_kv_heads=max(1, 4 // max(1, self.q_groups)),
            head_dim=16,
            d_ff=128 if self.d_ff else 0,
            vocab_size=256,
            window=min(self.window, 16) if self.window else 0,
            chunk=min(self.chunk, 16) if self.chunk else 0,
            n_experts=min(self.n_experts, 4),
            top_k=min(self.top_k, 2),
            moe_d_ff=64 if self.moe_d_ff else 0,
            shared_expert_d_ff=64 if self.shared_expert_d_ff else 0,
            ssm_state=min(self.ssm_state, 8),
            lru_width=64 if self.lru_width else 0,
            n_encoder_layers=min(self.n_encoder_layers, 2),
            encoder_seq=min(self.encoder_seq, 16) if self.encoder_seq else 0,
            n_prefix_tokens=min(self.n_prefix_tokens, 8),
            max_position=min(self.max_position, 128) if self.max_position else 0,
            dtype="float32",
            remat="none",
        )
        small.update(overrides)
        return dataclasses.replace(self, **small)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode | long_decode


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "long_decode"),
}

ARCH_IDS = (
    "command-r-35b",
    "granite-34b",
    "stablelm-12b",
    "qwen2.5-3b",
    "whisper-base",
    "internvl2-2b",
    "recurrentgemma-9b",
    "qwen3-moe-235b-a22b",
    "llama4-scout-17b-a16e",
    "falcon-mamba-7b",
)


def get_arch(name: str) -> ArchConfig:
    mod = importlib.import_module(
        "repro.configs." + name.replace("-", "_").replace(".", "_")
    )
    return mod.CONFIG


def list_archs():
    return list(ARCH_IDS)
