"""Paper CNN: Cifar10 (Table 1). Selected bit-width: 6."""
from repro.models.cnn import CIFAR10 as CONFIG  # noqa: F401

SELECTED_BITS = 6
