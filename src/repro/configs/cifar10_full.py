"""Non-paper CNN: Caffe's cifar10_full — 5x5 SAME convs with OVERLAPPING
3x3/stride-2 max-pool (32 -> 15 -> 7 -> 3). Exercises the generalized
pool-window != pool-stride lowering path. Selected bit-width: 6 (as
Cifar10, same parameter statistics regime)."""
from repro.models.cnn import CIFAR10_FULL as CONFIG  # noqa: F401

SELECTED_BITS = 6
