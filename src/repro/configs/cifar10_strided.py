"""Non-paper CNN: stride-2 downsampling variant of the Cifar10 topology —
the first two layers downsample with conv stride 2 instead of pooling
(32 -> 16 -> 8), the last keeps a 2x2/2 pool. Exercises the generalized
conv-stride lowering path. Selected bit-width: 6."""
from repro.models.cnn import CIFAR10_STRIDED as CONFIG  # noqa: F401

SELECTED_BITS = 6
