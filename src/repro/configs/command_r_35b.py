"""Command R 35B [hf:CohereForAI/c4ai-command-r-v01].

Dense GQA decoder: 40L, d_model 8192, 64 heads / 8 KV, d_ff 22528,
vocab 256000. Cohere blocks are *parallel* (x + attn(ln x) + mlp(ln x)),
use LayerNorm (no bias convention kept via our layernorm), no QKV bias,
tied embeddings. Pure full attention -> long_500k skipped (DESIGN.md
§Arch-applicability).
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="command-r-35b",
    family="dense",
    n_layers=40,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=22528,
    vocab_size=256_000,
    head_dim=128,
    parallel_block=True,
    norm="layernorm",
    mlp_act="silu",
    rope_theta=8e6,
    tie_embeddings=True,
)
