"""Falcon-Mamba 7B [arXiv:2410.05355].

Attention-free Mamba-1 SSM: 64L, d_model 4096, d_inner 8192 (expand 2),
ssm_state 16, conv 4, vocab 65024, rmsnorm. No MLP (d_ff = 0): each layer
is norm -> mamba -> residual. O(1) decode state -> long_500k RUNS.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="falcon-mamba-7b",
    family="ssm",
    n_layers=64,
    d_model=4096,
    n_heads=1,  # unused (attention-free)
    n_kv_heads=1,
    d_ff=0,
    vocab_size=65_024,
    block_pattern=("mamba",),
    norm="rmsnorm",
    ssm_state=16,
    ssm_conv=4,
    ssm_expand=2,
    tie_embeddings=False,
)
