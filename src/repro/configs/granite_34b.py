"""Granite Code 34B [arXiv:2405.04324].

Deep-narrow MQA code model: 88L, d_model 6144, 48 heads / 1 KV (MQA),
d_ff 24576, vocab 49152. Llama-style blocks per the assignment note
(rmsnorm + swiglu + rope). The 88-layer depth makes this the best
DHM-pipeline stress case. Full attention -> long_500k skipped.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="granite-34b",
    family="dense",
    n_layers=88,
    d_model=6144,
    n_heads=48,
    n_kv_heads=1,
    d_ff=24576,
    vocab_size=49152,
    head_dim=128,
    norm="rmsnorm",
    mlp_act="silu",
    rope_theta=10_000.0,
)
