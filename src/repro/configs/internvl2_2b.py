"""InternVL2 2B [arXiv:2404.16821].

VLM: InternViT-300M frontend (STUB — ``input_specs`` provides 256
precomputed patch embeddings at d_model after the MLP projector) +
InternLM2-1.8B LM backbone: 24L, d_model 2048, 16 heads / 8 KV,
d_ff 8192, vocab 92553; rmsnorm + swiglu + rope. Full attention ->
long_500k skipped.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="internvl2-2b",
    family="vlm",
    n_layers=24,
    d_model=2048,
    n_heads=16,
    n_kv_heads=8,
    d_ff=8192,
    vocab_size=92_553,
    head_dim=128,
    norm="rmsnorm",
    mlp_act="silu",
    rope_theta=1e6,
    n_prefix_tokens=256,
)
