"""Paper CNN: LeNet5 (Table 1). Selected bit-width: 3."""
from repro.models.cnn import LENET5 as CONFIG  # noqa: F401

SELECTED_BITS = 3
