"""Llama 4 Scout 17B-16E [hf:meta-llama/Llama-4-Scout-17B-16E].

MoE decoder: 48L, d_model 5120, 40 heads / 8 KV, vocab 202048. Every layer
routes over 16 experts top-1 (+ a shared expert, d_ff 8192 each). iRoPE
attention: 3 of 4 layers use *chunked* attention (8192-token chunks, RoPE);
every 4th layer is full attention with NoPE. The chunked pattern bounds the
KV window -> long_500k RUNS (full-attn layers are O(1)/step at decode with
an O(S) cache).
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="llama4-scout-17b-a16e",
    family="moe",
    n_layers=48,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    d_ff=8192,
    vocab_size=202_048,
    head_dim=128,
    block_pattern=("attn", "attn", "attn", "attn"),
    attn_pattern=("chunked", "chunked", "chunked", "causal"),
    chunk=8192,
    norm="rmsnorm",
    mlp_act="silu",
    rope_theta=5e5,
    n_experts=16,
    top_k=1,
    moe_d_ff=8192,
    shared_expert_d_ff=8192,
)
