"""Qwen2.5 3B [hf:Qwen/Qwen2.5-3B].

Dense GQA decoder: 36L, d_model 2048, 16 heads / 2 KV, d_ff 11008,
vocab 151936. Qwen2 family uses QKV *bias* (assignment note), rmsnorm,
swiglu, rope theta 1e6, tied embeddings at this size. Full attention ->
long_500k skipped.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="qwen2.5-3b",
    family="dense",
    n_layers=36,
    d_model=2048,
    n_heads=16,
    n_kv_heads=2,
    d_ff=11008,
    vocab_size=151_936,
    head_dim=128,
    qkv_bias=True,
    norm="rmsnorm",
    mlp_act="silu",
    rope_theta=1e6,
    tie_embeddings=True,
)
