"""Qwen3-235B-A22B MoE [hf:Qwen/Qwen3-235B-A22B].

MoE decoder: 94L, d_model 4096, 64 heads / 4 KV (head_dim 128), vocab
151936. Every layer routes over 128 experts, top-8, per-expert d_ff 1536,
normalized top-k gates, QK-norm (Qwen3 signature). Experts shard over the
``model`` axis (expert parallelism). Full attention -> long_500k skipped.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="qwen3-moe-235b-a22b",
    family="moe",
    n_layers=94,
    d_model=4096,
    n_heads=64,
    n_kv_heads=4,
    d_ff=1536,  # listed dense dim; experts use moe_d_ff below
    vocab_size=151_936,
    head_dim=128,
    qk_norm=True,
    norm="rmsnorm",
    mlp_act="silu",
    rope_theta=1e6,
    n_experts=128,
    top_k=8,
    moe_d_ff=1536,
)
