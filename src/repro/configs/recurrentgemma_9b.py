"""RecurrentGemma 9B (Griffin) [arXiv:2402.19427].

Hybrid: pattern (RG-LRU, RG-LRU, local-attention) repeated — 38 layers =
12 full units + 2 tail RG-LRU blocks. d_model 4096, 16 heads / 1 KV (MQA),
d_ff 12288 GeGLU, lru_width 4096, local window 2048, vocab 256000.
Sub-quadratic (bounded window + O(1) recurrent state) -> long_500k RUNS.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="recurrentgemma-9b",
    family="hybrid",
    n_layers=38,
    d_model=4096,
    n_heads=16,
    n_kv_heads=1,
    d_ff=12288,
    vocab_size=256_000,
    head_dim=256,
    block_pattern=("rglru", "rglru", "attn"),
    attn_pattern=("local", "local", "local"),
    window=2048,
    norm="rmsnorm",
    mlp_act="gelu_glu",
    rope_theta=10_000.0,
    lru_width=4096,
    ssm_conv=4,
)
