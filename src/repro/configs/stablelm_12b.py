"""StableLM 2 12B [hf:stabilityai/stablelm-2-12b].

Dense GQA decoder: 40L, d_model 5120, 32 heads / 8 KV, d_ff 13824,
vocab 100352; rmsnorm + swiglu + rope. Full attention -> long_500k
skipped.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="stablelm-12b",
    family="dense",
    n_layers=40,
    d_model=5120,
    n_heads=32,
    n_kv_heads=8,
    d_ff=13824,
    vocab_size=100_352,
    head_dim=160,
    norm="rmsnorm",
    mlp_act="silu",
    rope_theta=10_000.0,
)
