"""Paper CNN: SVHN (Table 1). Selected bit-width: 6."""
from repro.models.cnn import SVHN as CONFIG  # noqa: F401

SELECTED_BITS = 6
