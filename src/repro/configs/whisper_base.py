"""Whisper base [arXiv:2212.04356].

Encoder-decoder: 6+6L, d_model 512, 8 heads (MHA: kv=8), d_ff 2048,
vocab 51865. LayerNorm + plain-GELU MLP + *learned* positional embeddings
(no rope). The conv audio frontend is a STUB: ``input_specs`` provides
precomputed frame embeddings (B, 1500, 512) per the assignment. Decode
shapes run with the assigned 32k self-attention cache (a stress config;
the real model caps at 448 decoder positions — noted in DESIGN.md).
Full attention -> long_500k skipped.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="whisper-base",
    family="encdec",
    n_layers=6,
    d_model=512,
    n_heads=8,
    n_kv_heads=8,
    d_ff=2048,
    vocab_size=51_865,
    head_dim=64,
    norm="layernorm",
    mlp_act="gelu",
    pos_embedding="learned",
    max_position=32_768 + 8,  # assigned decode_32k stress shape
    n_encoder_layers=6,
    encoder_seq=1500,
)
