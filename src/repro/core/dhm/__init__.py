"""Direct Hardware Mapping (DHM) core — the paper's contribution.

- ``graph``: dataflow-process-network (DPN) IR; CNN/LM graph builders at the
  paper's actor granularity (conv engines, adder trees, activations).
- ``resources``: the FPGA resource model for the three multiplier strategies
  (paper Tables 2 & 3).
- ``throughput``: the streaming-throughput model (paper Table 4).
- ``mapping``: spatial mapping of a DPN onto a TPU mesh (stage partitioning)
  — the TPU-native act of "direct mapping".
- ``pipeline``: the streaming pipelined executor (shard_map + ppermute).
"""
from repro.core.dhm.graph import (
    Actor,
    ActorKind,
    DataflowGraph,
    cnn_to_dpn,
    layer_costs_to_dpn,
)
from repro.core.dhm.resources import (
    DeviceModel,
    CYCLONE_V_5CGXFC9E7,
    KINTEX7_XC7Z045,
    MultiplierStrategy,
    ResourceReport,
    estimate_resources,
)
from repro.core.dhm.throughput import dhm_throughput_gops, ThroughputReport
from repro.core.dhm.mapping import StageAssignment, partition_stages, balance_report

__all__ = [
    "Actor",
    "ActorKind",
    "DataflowGraph",
    "cnn_to_dpn",
    "layer_costs_to_dpn",
    "DeviceModel",
    "CYCLONE_V_5CGXFC9E7",
    "KINTEX7_XC7Z045",
    "MultiplierStrategy",
    "ResourceReport",
    "estimate_resources",
    "dhm_throughput_gops",
    "ThroughputReport",
    "StageAssignment",
    "partition_stages",
    "balance_report",
]
