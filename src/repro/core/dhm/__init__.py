"""Direct Hardware Mapping (DHM) core — the paper's contribution, organised
as a compiler pipeline:

    CNNTopology --(graph)--> DPN actor graph --(mapping)--> stages
               --(compiler)--> CompiledDHM plan --(pipeline)--> mesh

- ``graph``: dataflow-process-network (DPN) IR; CNN/LM graph builders at the
  paper's actor granularity (conv engines, adder trees, activations).
- ``mapping``: exact min-max DP partitioning of the (topologically ordered)
  actor layers into contiguous stages — the TPU-native act of "direct
  mapping" (the FPGA's critical actor becomes the bottleneck stage).
- ``compiler``: the single lowering path. ``compile_dhm(topo, params,
  quant=QuantSpec(...), n_stages=..., backend=...)`` validates the
  topology, expands it to the DPN, partitions it from the actor FLOP
  payloads, and emits per-stage fused-kernel closures with quantization
  baked in (weights fake-quantized / pow2-projected once; the feature
  stream quantized inside the kernel epilogue; the FC head lowered through
  the packed pow2 matmul when requested). Every consumer — ``cnn_apply``,
  pipeline stage bodies, examples, e2e benchmarks — routes through it.
- ``pipeline``: the streaming pipelined executor (shard_map + ppermute);
  runs a CompiledDHM's stages on disjoint device groups, GPipe schedule.
  Heterogeneous stage geometries (pool/stride shrink, channel growth)
  stream over exact-shape ICI edge classes planned from the per-edge
  ``StageIOSpec`` the compiler emits (``plan_edges``; max-shape boxing is
  the fallback), optionally with double-buffered overlapped collectives;
  a 2D ``(stage, data)`` mesh adds batch sharding.
- ``engine``: where compiled plans execute — the eager/jitted forward
  paths, the mesh executor entry (``run_pipelined``), and the
  fault-tolerant serving ``Engine`` (continuous batching with deadline
  SLOs, bounded-queue admission control, watchdog + retry + a graceful
  degradation ladder, structured per-request errors).
- ``faults``: deterministic, seed-driven fault injection (delayed flush,
  dispatch errors, stalled collectives, NaN activations, device loss)
  wired through ``Engine(fault_plan=...)`` for the chaos suite; fault
  windows can be scoped to one tenant for bulkhead testing.
- ``multitenant``: N compiled plans resident behind one ``Router`` —
  per-tenant queues/SLOs, deficit-round-robin weighted-fair scheduling,
  per-tenant circuit breakers, and verified hot plan swap with one-call
  rollback.
- ``resources``: the FPGA resource model for the three multiplier
  strategies (paper Tables 2 & 3).
- ``throughput``: the streaming-throughput model (paper Table 4) plus the
  spatial-pipeline cost model and the measurement-driven µbatch autotuner
  (``estimate_pipeline`` / ``fit_constants`` / ``autotune_pipeline``) that
  picks n_microbatches / batch grain / overlap per (plan, device count).
"""
from repro.core.dhm.compiler import (
    CompiledDHM,
    CompiledStage,
    PlanCheckError,
    QuantSpec,
    check_plan,
    compile_dhm,
    emit_conv_stage,
    validate_topology,
)
from repro.core.dhm.engine import (
    BatchFailed,
    DeadlineExceeded,
    Engine,
    EngineStats,
    FlusherWedged,
    InvalidRequest,
    LadderExhausted,
    Rejected,
    RequestError,
    Shed,
    run_pipelined,
)
from repro.core.dhm.multitenant import (
    CircuitBreaker,
    CircuitOpen,
    Router,
    SwapRejected,
    UnknownTenant,
)
from repro.core.dhm.faults import (
    DelayedFlush,
    DeviceLoss,
    DispatchError,
    FaultPlan,
    InjectedDeviceLoss,
    InjectedDispatchError,
    InjectedFault,
    NaNActivation,
    StalledDispatch,
)
from repro.core.dhm.pipeline import (
    CollectiveTimeout,
    EDGE_MODES,
    EdgePlan,
    PipelineConfig,
    StageIOSpec,
    call_with_timeout,
    pipeline_forward,
    plan_edges,
)
from repro.core.dhm.graph import (
    Actor,
    ActorKind,
    DataflowGraph,
    cnn_to_dpn,
    layer_costs_to_dpn,
)
from repro.core.dhm.resources import (
    DeviceModel,
    CYCLONE_V_5CGXFC9E7,
    KINTEX7_XC7Z045,
    MultiplierStrategy,
    ResourceReport,
    estimate_resources,
)
from repro.core.dhm.throughput import (
    PipelineCostConstants,
    PipelineEstimate,
    PipelineTuning,
    ThroughputReport,
    autotune_pipeline,
    candidate_grid,
    dhm_throughput_gops,
    estimate_pipeline,
    fit_constants,
    load_sweep_measurements,
    pipeline_workload,
    streaming_throughput,
    sweep_sample,
)
from repro.core.dhm.mapping import StageAssignment, partition_stages, balance_report

__all__ = [
    "Actor",
    "ActorKind",
    "BatchFailed",
    "CollectiveTimeout",
    "CompiledDHM",
    "CompiledStage",
    "DataflowGraph",
    "DeadlineExceeded",
    "DelayedFlush",
    "DeviceLoss",
    "DispatchError",
    "CircuitBreaker",
    "CircuitOpen",
    "Engine",
    "EngineStats",
    "FaultPlan",
    "FlusherWedged",
    "Router",
    "SwapRejected",
    "UnknownTenant",
    "InjectedDeviceLoss",
    "InjectedDispatchError",
    "InjectedFault",
    "InvalidRequest",
    "LadderExhausted",
    "NaNActivation",
    "EDGE_MODES",
    "EdgePlan",
    "PipelineConfig",
    "PipelineCostConstants",
    "PipelineEstimate",
    "PipelineTuning",
    "PlanCheckError",
    "QuantSpec",
    "Rejected",
    "RequestError",
    "Shed",
    "StageIOSpec",
    "StalledDispatch",
    "call_with_timeout",
    "check_plan",
    "pipeline_forward",
    "run_pipelined",
    "cnn_to_dpn",
    "compile_dhm",
    "emit_conv_stage",
    "layer_costs_to_dpn",
    "validate_topology",
    "DeviceModel",
    "CYCLONE_V_5CGXFC9E7",
    "KINTEX7_XC7Z045",
    "MultiplierStrategy",
    "ResourceReport",
    "estimate_resources",
    "dhm_throughput_gops",
    "ThroughputReport",
    "StageAssignment",
    "partition_stages",
    "balance_report",
    "autotune_pipeline",
    "candidate_grid",
    "estimate_pipeline",
    "fit_constants",
    "load_sweep_measurements",
    "pipeline_workload",
    "plan_edges",
    "streaming_throughput",
    "sweep_sample",
]
