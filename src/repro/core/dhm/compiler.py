"""Graph-driven DHM compiler: CNNTopology -> DPN -> stages -> execution plan.

This is the repo's rendering of HADDOC2's "network description in,
synthesizable actor graph out" pass as ONE lowering pipeline (the paper and
its companion report arXiv:1705.04543 frame direct hardware mapping as a
compiler problem). ``compile_dhm`` is the single entry point every consumer
routes through — ``cnn_apply``, the pipeline stage bodies, the examples and
the end-to-end benchmarks — so new topologies, backends or sharding
strategies plug in here instead of growing parallel hand-wired paths.

Lowering stages:

1. **Validate** the topology: ``act`` / ``pool`` / ``padding`` strings are
   checked against the fused-epilogue vocabulary at compile time, so a
   typo'd ``act="rleu"`` raises here with the valid options, not as an
   opaque KeyError deep inside a kernel trace.
2. **Expand** the CNN description into the paper-granularity dataflow
   process network (``cnn_to_dpn``): one conv engine per (map, channel),
   neuron sums, activation and pool actors, line buffers sized by the
   fixed-point width of the quantization spec.
3. **Partition** the actor graph into ``n_stages`` contiguous stages with
   the exact min-max DP mapper, costed from the actor FLOP payloads — the
   critical-actor balancing the FPGA gets from its clock, solved here as a
   linear-partition problem.
4. **Fuse** each stage's layer run into maximal cross-layer fusion groups
   under a VMEM budget (``repro.core.dhm.fusion``): a group of consecutive
   conv layers is streamed through ONE fused pyramid kernel with all
   inter-layer feature slabs on-chip — the paper's no-external-memory
   dataflow property, recovered across layer boundaries. Groups that
   don't fit the budget fall back to single-layer kernel calls.
5. **Emit** per-stage fused-kernel closures (``stream_conv_pyramid`` /
   ``stream_conv_block`` actor chains) with the quantization *baked into
   the plan*: weights are
   fixed-point fake-quantized / pow2-projected once at compile time, and
   the feature-stream quantization runs inside the fused kernel epilogue
   (``act_bits``), never as a separate pass over HBM. The FC head lowers
   through the packed ``pow2_matmul`` kernel when ``quant.pow2_weights``
   (with straight-through gradients, so pow2 QAT still trains).

The resulting :class:`CompiledDHM` is a *plan*; execution lives in
``repro.core.dhm.engine``: single-device (sequential fused stages — the
default path under ``cnn_apply``), spatially on a mesh via
``pipeline_forward`` (``run_pipelined`` — heterogeneous stage shapes flow
through per-edge :class:`~repro.core.dhm.pipeline.StageIOSpec` geometry
emitted here), or behind the micro-batched serving ``Engine``. Each stage
owns a private device group exactly as each DHM actor owns private
silicon.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Callable, Optional, Sequence

import jax
import jax.numpy as jnp

from repro.core.dhm.fusion import (
    DEFAULT_VMEM_BUDGET,
    plan_fusion_groups,
)
from repro.core.dhm.graph import DataflowGraph, cnn_to_dpn
from repro.core.dhm.mapping import StageAssignment, partition_stages
from repro.core.dhm.pipeline import StageIOSpec
from repro.kernels.backends import DEFAULT_BACKEND, validate_backend
from repro.kernels.stream_conv.epilogue import ACTS, normalize_pool

PADDINGS = ("SAME", "VALID")


class PlanCheckError(ValueError):
    """A compiled plan failed its self-check (non-finite baked parameters
    or inconsistent stage IO geometry) — the plan is not fit to serve.

    ``invariants`` names the registry IDs (``repro.analysis.invariants``)
    that failed, so demotion records and CI findings cite the same IDs.
    """

    def __init__(self, message: str, *, invariants=()):
        super().__init__(message)
        self.invariants = tuple(invariants)


@dataclasses.dataclass(frozen=True)
class QuantSpec:
    """The quantization contract baked into a compiled plan.

    ``weight_bits``: fixed-point fake-quant of all parameters (dynamic
    power-of-two scales, STE gradients — the paper's Q-format QAT).
    ``act_bits``: fixed-point width of the inter-actor feature stream,
    applied INSIDE the fused kernel epilogue (the paper quantizes the pixel
    flow, not just the parameters).
    ``pow2_weights``: project weights onto the {0, ±2^k} codebook; the FC
    head then lowers through the packed ``pow2_matmul`` kernel (when no
    additional ``weight_bits`` re-quantization is stacked on top).
    ``int8_compute``: execute the quantized plan in TRUE integer
    arithmetic: conv weights are baked to int8 codes + a static pow2
    scale, the feature stream enters each kernel as int8 codes, and the
    conv matmuls contract integers into int32 accumulators
    (``preferred_element_type``) with the requantization to the stream's
    ``act_bits`` grid fused into the existing epilogue. Requires a weight
    AND act width (<= 8) for every conv layer. Int8 plans are
    forward-only (serving), not QAT paths.
    ``per_layer_bits``: per-conv-layer bit widths (a tuple, one entry per
    conv layer) overriding BOTH ``weight_bits`` and ``act_bits`` for that
    layer — the paper's Fig. 3 bitwidth sweep as a compile-time plan
    attribute (see ``repro.core.quant.bitwidth_search``).
    """

    weight_bits: Optional[int] = None
    act_bits: Optional[int] = None
    pow2_weights: bool = False
    int8_compute: bool = False
    per_layer_bits: Optional[tuple] = None

    def __post_init__(self):
        for name in ("weight_bits", "act_bits"):
            v = getattr(self, name)
            if v is not None and v < 2:
                raise ValueError(f"{name} must be >= 2 (or None), got {v}")
        if self.per_layer_bits is not None:
            object.__setattr__(
                self, "per_layer_bits", tuple(self.per_layer_bits)
            )
            for b in self.per_layer_bits:
                if not isinstance(b, int) or isinstance(b, bool) or b < 2:
                    raise ValueError(
                        f"per_layer_bits entries must be ints >= 2, got "
                        f"{self.per_layer_bits}"
                    )
        if self.int8_compute:
            n = (
                len(self.per_layer_bits)
                if self.per_layer_bits is not None
                else 1
            )
            for i in range(n):
                wb, ab = self.conv_weight_bits(i), self.conv_act_bits(i)
                if wb is None or ab is None:
                    raise ValueError(
                        "int8_compute requires a weight AND act bit width "
                        "for every conv layer (weight_bits/act_bits or "
                        "per_layer_bits)"
                    )
                if wb > 8 or ab > 8:
                    raise ValueError(
                        f"int8_compute requires all conv bit widths <= 8, "
                        f"got weight={wb} act={ab} for layer {i}"
                    )

    def conv_weight_bits(self, i: int) -> Optional[int]:
        """Weight bit width of conv layer ``i`` (per-layer override wins)."""
        if self.per_layer_bits is not None:
            return self.per_layer_bits[i]
        return self.weight_bits

    def conv_act_bits(self, i: int) -> Optional[int]:
        """Feature-stream bit width AFTER conv layer ``i`` (per-layer
        override wins)."""
        if self.per_layer_bits is not None:
            return self.per_layer_bits[i]
        return self.act_bits

    @property
    def mixed_bitwidth(self) -> bool:
        """Whether the plan carries per-layer bit choices."""
        return self.per_layer_bits is not None

    @property
    def stream_bits(self) -> int:
        """Fixed-point width used to size DPN line buffers and streams."""
        if self.per_layer_bits is not None:
            return max(self.per_layer_bits)
        return self.act_bits or self.weight_bits or 32

    @property
    def packed_fc_head(self) -> bool:
        """Whether the FC head lowers through the packed pow2 kernel.

        With ``weight_bits`` stacked on top of the pow2 projection the
        weights leave the pure codebook, so the head falls back to the
        dense (projected + fake-quantized) matmul. ``per_layer_bits``
        only governs conv layers, so it does not demote the head.
        """
        return self.pow2_weights and self.weight_bits is None


def _spec_fields(spec) -> dict:
    """The layer vocabulary of a (duck-typed) conv-layer spec, with the
    generalized fields defaulted for specs that predate them."""
    return dict(
        padding=spec.padding,
        act=spec.act,
        pool=spec.pool,
        pool_stride=getattr(spec, "pool_stride", None),
        stride=getattr(spec, "stride", 1),
    )


def _validate_layer(
    where: str, *, padding: str, act: str, pool: int,
    pool_stride: int | None = None, stride: int = 1,
) -> None:
    """Compile-time validation of the layer vocabulary — a typo raises
    here with the options listed, not as a trace-time KeyError."""
    if act not in ACTS:
        raise ValueError(f"{where}: unknown act {act!r}; expected one of {ACTS}")
    try:
        normalize_pool(pool, pool_stride)
    except ValueError as e:
        raise ValueError(f"{where}: {e}") from None
    if not isinstance(stride, int) or isinstance(stride, bool) or stride < 1:
        raise ValueError(
            f"{where}: conv stride must be a positive int, got {stride!r}"
        )
    if padding not in PADDINGS:
        raise ValueError(
            f"{where}: unknown padding {padding!r}; expected one of {PADDINGS}"
        )


def validate_topology(topo) -> None:
    """Validate every conv layer of a CNNTopology at compile time: the
    layer vocabulary, and (when the topology exposes shape methods) that
    every layer keeps positive spatial dims — a pool window larger than
    its conv output raises here, instead of silently emitting a
    zero-sized frame."""
    for li, spec in enumerate(topo.conv_layers):
        _validate_layer(f"{topo.name} conv layer {li}", **_spec_fields(spec))
    if not hasattr(topo, "input_shape"):
        return
    h, w = topo.input_shape
    for li, spec in enumerate(topo.conv_layers):
        where = f"{topo.name} conv layer {li}"
        h_c, w_c = spec.conv_hw(h, w)
        if h_c < 1 or w_c < 1:
            raise ValueError(
                f"{where}: conv output {h_c}x{w_c} is empty for a {h}x{w} "
                f"input (kernel={spec.kernel}, stride={spec.stride}, "
                f"padding={spec.padding})"
            )
        pw, _ = spec.pool_cfg
        if pw and (h_c < pw or w_c < pw):
            raise ValueError(
                f"{where}: conv output {h_c}x{w_c} too small for a "
                f"{pw}x{pw} pool window"
            )
        h, w = spec.out_hw(h, w)


@functools.lru_cache(maxsize=64)
def _cached_dpn(topo, bits: int) -> DataflowGraph:
    """CNNTopology is a frozen (hashable) dataclass, so the actor-graph
    expansion — thousands of actors for CIFAR-sized nets — is built once
    per (topology, bit-width), not once per trace."""
    return cnn_to_dpn(topo, bits=bits)


def _conv_layer_costs(graph: DataflowGraph, n_conv: int) -> list:
    """Per-conv-layer FLOP cost summed from the actor payloads (conv layer
    i owns DPN topological layer i + 1; layer 0 is the source)."""
    by_layer: dict = {}
    for a in graph.actors:
        by_layer[a.layer] = by_layer.get(a.layer, 0.0) + a.flops
    return [by_layer.get(i + 1, 0.0) for i in range(n_conv)]


@functools.lru_cache(maxsize=256)
def _cached_layout(topo, bits: int, n_stages: int) -> StageAssignment:
    """Cost aggregation (a Python walk over thousands of actors) + the DP
    partition depend only on (topology, bit-width, n_stages) — memoized so
    eager per-batch ``cnn_apply`` calls don't re-walk the graph."""
    graph = _cached_dpn(topo, bits)
    costs = _conv_layer_costs(graph, len(topo.conv_layers))
    return partition_stages(costs, n_stages)


def emit_conv_stage(
    specs: Sequence,
    *,
    backend: Optional[str] = None,
    act_bits=None,  # int | None | per-layer tuple
    int8_scales: Optional[Sequence] = None,  # per-layer Int8Scales | None
    block_r: int = 8,
    block_w: int = 0,
    block_c: int = 0,
    block_n: int = 0,
    groups: Optional[Sequence] = None,
) -> Callable:
    """Emit one pipeline-stage body: a chain of fused conv actor chains.

    ``specs`` is a sequence of conv-layer specs (anything with ``padding``,
    ``act``, ``pool`` attributes — e.g. ``ConvLayerSpec``; the generalized
    ``stride``/``pool_stride`` fields default to 1/window when absent).
    ``groups`` partitions the stage's layers into fusion groups — a
    sequence of ``(local_layer_indices, block_rows)`` pairs covering the
    stage contiguously. A multi-layer group lowers through ONE
    ``stream_conv_pyramid`` call (inter-layer slabs VMEM-resident);
    singleton groups lower through today's single-layer
    ``stream_conv_block`` (with its channel/width blocking knobs).
    ``groups=None`` means all-singleton — the pre-fusion stage body.

    ``act_bits`` may be a single width for the whole stage or a per-layer
    tuple (mixed-bitwidth plans); ``int8_scales`` (one
    ``epilogue.Int8Scales`` per stage layer) switches the kernels to the
    true-integer rendering — int8 weight codes are then expected in
    ``params``.

    The returned ``stage_fn(params, x)`` runs conv -> bias -> act (-> pool
    -> stream quant) per layer. ``params`` is a list with one
    ``{"w": (K, K, C, N), "b": (N,)}`` dict per layer (a bare dict is
    accepted for single-layer stages).
    """
    from repro.kernels.stream_conv import stream_conv_block, stream_conv_pyramid

    specs = tuple(specs)
    if not specs:
        raise ValueError("a conv stage needs at least one layer spec")
    bits = (
        tuple(act_bits)
        if isinstance(act_bits, (tuple, list))
        else (act_bits,) * len(specs)
    )
    if len(bits) != len(specs):
        raise ValueError(
            f"act_bits tuple has {len(bits)} entries for a "
            f"{len(specs)}-layer stage"
        )
    scales = None if int8_scales is None else tuple(int8_scales)
    if scales is not None and len(scales) != len(specs):
        raise ValueError(
            f"int8_scales has {len(scales)} entries for a "
            f"{len(specs)}-layer stage"
        )
    layer_kw = []
    for li, spec in enumerate(specs):
        fields = _spec_fields(spec)
        _validate_layer(f"stage layer {li}", **fields)
        layer_kw.append(fields)
    resolved = validate_backend(
        DEFAULT_BACKEND if backend is None else backend
    )
    if groups is None:
        group_plan = tuple(((li,), 0) for li in range(len(specs)))
    else:
        group_plan = tuple((tuple(g), int(br)) for g, br in groups)
        covered = [li for g, _ in group_plan for li in g]
        if covered != list(range(len(specs))):
            raise ValueError(
                f"fusion groups {group_plan} do not cover stage layers "
                f"0..{len(specs) - 1} contiguously"
            )

    def stage_fn(params, x):
        layer_params = [params] if isinstance(params, dict) else list(params)
        if len(layer_params) != len(specs):
            raise ValueError(
                f"stage has {len(specs)} layers but got "
                f"{len(layer_params)} param dicts"
            )
        for g, block_rows in group_plan:
            if len(g) == 1:
                p = layer_params[g[0]]
                x = stream_conv_block(
                    x,
                    p["w"],
                    p["b"],
                    act_bits=bits[g[0]],
                    int8_scales=None if scales is None else scales[g[0]],
                    backend=resolved,
                    block_r=block_r,
                    block_w=block_w,
                    block_c=block_c,
                    block_n=block_n,
                    **layer_kw[g[0]],
                )
            else:
                x = stream_conv_pyramid(
                    x,
                    [layer_params[li]["w"] for li in g],
                    [layer_params[li]["b"] for li in g],
                    layers=[specs[li] for li in g],
                    act_bits=tuple(bits[li] for li in g),
                    int8_scales=(
                        None
                        if scales is None
                        else tuple(scales[li] for li in g)
                    ),
                    block_rows=block_rows,
                    backend=resolved,
                )
        return x

    return stage_fn


# ---------------------------------------------------------------------------
# Quantization baking


def _bake_conv_params(conv_params, quant: QuantSpec):
    """Mirror the fake-quant reference composition order: pow2 projection
    (STE) first, then fixed-point fake-quant of every tensor.

    Returns ``(baked_params, w_scales)``. Under ``quant.int8_compute`` the
    weights bake to int8 CODES on the same dynamic pow2 grid
    ``fake_quant_dynamic`` would use (``codes * scale ==
    fake_quant_dynamic(w, bits)`` exactly), and ``w_scales`` carries the
    static per-layer pow2 scale the kernels fold into their int32
    dequantization; otherwise ``w_scales`` is None.
    """
    from repro.core.quant.fixed_point import (
        dynamic_spec,
        fake_quant_dynamic,
        quantize_fixed,
    )
    from repro.core.quant.pow2 import project_pow2_ste

    out, w_scales = [], []
    for i, p in enumerate(conv_params):
        w, b = p["w"], p["b"]
        wb = quant.conv_weight_bits(i)
        if quant.pow2_weights:
            w = project_pow2_ste(w)
        if quant.int8_compute:
            wspec = dynamic_spec(w, wb)
            w = quantize_fixed(w, wspec).astype(jnp.int8)
            b = fake_quant_dynamic(b, wb)
            w_scales.append(float(wspec.scale))
        elif wb is not None:
            w = fake_quant_dynamic(w, wb)
            b = fake_quant_dynamic(b, wb)
        out.append({"w": w, "b": b})
    return tuple(out), (tuple(w_scales) if quant.int8_compute else None)


@functools.partial(jax.custom_vjp, nondiff_argnums=(2, 3))
def _pow2_linear_ste(x, w, backend, x_spec=None):
    """Forward through the packed pow2 kernel (x @ decode(pack(w)));
    backward straight-through, as if the layer were ``x @ project_pow2(w)``
    — so pow2 QAT keeps training while serving-path lowering is exercised
    in the forward pass. A static ``x_spec`` (the activation's fixed-point
    grid) forwards through the true-integer shift-add rendering where the
    backend supports it (see ``pow2_matmul``)."""
    from repro.kernels.pow2_matmul import pow2_matmul, quantize_weights

    packed, scale = quantize_weights(w)
    return pow2_matmul(x, packed, scale, backend=backend, x_spec=x_spec)


def _pow2_linear_ste_fwd(x, w, backend, x_spec=None):
    from repro.core.quant.pow2 import project_pow2

    return (
        _pow2_linear_ste(x, w, backend, x_spec),
        (x, project_pow2(w, channel_axis=1)),
    )


def _pow2_linear_ste_bwd(backend, x_spec, res, g):
    x, w_proj = res
    return (
        jnp.dot(g, w_proj.T.astype(g.dtype)),
        jnp.dot(x.T.astype(g.dtype), g),  # STE: identity through the projection
    )


_pow2_linear_ste.defvjp(_pow2_linear_ste_fwd, _pow2_linear_ste_bwd)


def _emit_head(
    fc_params, quant: QuantSpec, backend: str, head_in_bits=None
) -> Callable:
    """Emit the classifier head: flatten -> FC stack, with the same
    quantization contract as the conv stages (tanh + feature-stream quant
    between hidden layers; logits unquantized, as in the reference).

    Under ``int8_compute`` with a packed pow2 head, each FC forwards
    through the integer shift-add rendering: the first FC's input grid is
    the LAST conv layer's stream spec (``head_in_bits``), later FCs see
    the head's own ``act_bits`` stream quant.
    """
    from repro.core.quant.fixed_point import fake_quant_dynamic, fake_quant_ste
    from repro.core.quant.pow2 import project_pow2_ste
    from repro.kernels.stream_conv.epilogue import stream_quant_spec

    baked = []
    for p in fc_params:
        w, b = p["w"], p["b"]
        if quant.pow2_weights and not quant.packed_fc_head:
            w = project_pow2_ste(w)
        if quant.weight_bits is not None:
            w = fake_quant_dynamic(w, quant.weight_bits)
            b = fake_quant_dynamic(b, quant.weight_bits)
        baked.append({"w": w, "b": b})

    # Same Q-format as the in-kernel stream quantization of the conv stages.
    qact_spec = (
        stream_quant_spec(quant.act_bits) if quant.act_bits is not None else None
    )
    int_head = (
        quant.int8_compute
        and quant.packed_fc_head
        and quant.act_bits is not None
    )
    # The activation grid each FC's input lives on: the conv stream for the
    # first FC, the head's own stream quant after that.
    first_spec = (
        stream_quant_spec(
            head_in_bits if head_in_bits is not None else quant.act_bits
        )
        if int_head
        else None
    )

    def head_fn(h):
        h = h.reshape(h.shape[0], -1)
        for i, p in enumerate(baked):
            if quant.packed_fc_head:
                x_spec = (
                    (first_spec if i == 0 else qact_spec) if int_head else None
                )
                h = _pow2_linear_ste(h, p["w"], backend, x_spec) + p["b"]
            else:
                h = h @ p["w"] + p["b"]
            if i < len(baked) - 1:
                h = jnp.tanh(h)
                if qact_spec is not None:
                    h = fake_quant_ste(h, qact_spec)
        return h

    return head_fn


# ---------------------------------------------------------------------------
# The compiled plan


@dataclasses.dataclass(frozen=True)
class CompiledStage:
    """One pipeline stage: a contiguous run of conv layers lowered as a
    chain of fusion groups (each group one fused kernel invocation)."""

    index: int
    conv_layers: tuple  # conv-layer indices owned by this stage
    specs: tuple  # the ConvLayerSpec per owned layer
    fn: Callable  # (params_list, x) -> y
    cost_flops: float  # summed actor payloads (the mapper's stage cost)
    groups: tuple = ()  # FusionGroup per kernel invocation in this stage
    io: Optional[StageIOSpec] = None  # (H, W, C) activation edge geometry


@dataclasses.dataclass(frozen=True)
class CompiledDHM:
    """Executable lowering of a CNN topology: quantized parameters +
    per-stage fused-kernel closures + the FC head, plus the IR artifacts
    (DPN graph, stage assignment) the lowering went through."""

    topo: object
    quant: QuantSpec
    backend: str
    graph: DataflowGraph
    assignment: StageAssignment
    stages: tuple
    conv_params: tuple  # per conv layer {"w", "b"}, quantization baked
    head_fn: Callable
    vmem_budget: int = DEFAULT_VMEM_BUDGET
    int8_scales: tuple = ()  # per conv layer Int8Scales when int8_compute

    @property
    def n_stages(self) -> int:
        return len(self.stages)

    def stage_quant_kwargs(self, stage: int) -> dict:
        """The quantization kwargs ``emit_conv_stage`` needs to re-emit
        stage ``stage``'s body (degradation-ladder rebuilds must inherit
        the plan's int8/mixed-bitwidth contract, not just ``act_bits``)."""
        st = self.stages[stage]
        if not self.int8_scales and not self.quant.mixed_bitwidth:
            return {"act_bits": self.quant.act_bits}
        kw = {
            "act_bits": tuple(
                self.quant.conv_act_bits(i) for i in st.conv_layers
            )
        }
        if self.int8_scales:
            kw["int8_scales"] = tuple(
                self.int8_scales[i] for i in st.conv_layers
            )
        return kw

    @property
    def fusion_groups(self) -> tuple:
        """Every FusionGroup of the plan, in execution order."""
        return tuple(g for st in self.stages for g in st.groups)

    def stage_params(self, stage: int) -> list:
        return [self.conv_params[i] for i in self.stages[stage].conv_layers]

    def self_check(self) -> None:
        """Health-probe the plan (see :func:`check_plan`); raises
        :class:`PlanCheckError` when the plan is not fit to serve."""
        check_plan(self)

    def features(self, x: jax.Array) -> jax.Array:
        """Run the conv stages sequentially (single-device execution)."""
        for st in self.stages:
            x = st.fn(self.stage_params(st.index), x)
        return x

    def jitted_forward(self, *, donate: bool = False) -> Callable:
        """The plan's cached end-to-end jitted closure (see
        ``repro.core.dhm.engine.plan_jitted_forward``, where execution
        lives). ``donate=True`` donates the input buffer — the serving
        ``Engine``'s double-buffered path."""
        from repro.core.dhm.engine import plan_jitted_forward

        return plan_jitted_forward(self, donate=donate)

    def __call__(self, x: jax.Array) -> jax.Array:
        """x: (B, H, W, C) NHWC -> logits (B, n_classes). Runs the cached
        end-to-end jitted closure (``jitted_forward``)."""
        return self.jitted_forward()(x)

    # -- spatial (mesh) execution ------------------------------------------

    def pipeline_spec(self):
        """Per-stage closures + params + per-edge activation geometry
        (:class:`StageIOSpec`) for the heterogeneous streaming executor.
        Stages may freely pool/stride down and grow channels between
        boundaries — the executor groups the interior edges into
        shape classes (see :meth:`edge_plan`) and each stage computes on
        its exact geometry."""
        from repro.core.dhm.engine import pipeline_spec

        return pipeline_spec(self)

    def edge_plan(self, *, mode: str = "auto", max_classes: int = 4):
        """How this plan's interior stage-boundary activations would
        travel over ICI: the :class:`~repro.core.dhm.pipeline.EdgePlan`
        (shape classes, per-class partial-permutation pairs, padding
        fraction) the executor builds from the :class:`StageIOSpec`
        chain. Inspect ``.mode`` to see whether the plan streams
        exact-shape edges or falls back to the boxed max-shape buffer."""
        from repro.core.dhm.pipeline import plan_edges

        return plan_edges(
            [st.io for st in self.stages], mode=mode, max_classes=max_classes
        )

    def edge_shapes(self) -> tuple:
        """The exact per-interior-edge activation element shapes (stage
        s -> s+1), straight off the :class:`StageIOSpec` chain."""
        return tuple(
            tuple(self.stages[s].io.out_shape)
            for s in range(self.n_stages - 1)
        )

    def run_pipelined(
        self, microbatches, *, mesh, cfg=None, data_axis=None,
        overlap=False, edge_mode="auto",
    ):
        """Stream (M, mb, H, W, C) µbatches through the conv stages on a
        mesh (one device group per stage; with ``data_axis`` the µbatch
        dim is additionally batch-sharded on a 2D ``(stage, data)`` mesh;
        ``overlap``/``edge_mode`` select the double-buffered schedule and
        the ICI edge path). Returns the feature stream; apply ``head_fn``
        after re-flattening for logits."""
        from repro.core.dhm.engine import run_pipelined

        return run_pipelined(
            self, microbatches, mesh=mesh, cfg=cfg, data_axis=data_axis,
            overlap=overlap, edge_mode=edge_mode,
        )


def check_plan(plan: CompiledDHM) -> None:
    """Self-check a compiled plan: the ``plan``-scope invariants of the
    ``repro.analysis`` registry — every baked parameter finite (V301),
    the per-stage IO geometry chains (V302), every emitted stage body and
    the head produce the shapes their :class:`StageIOSpec` promises via
    ``jax.eval_shape`` (V303/V304) — no FLOPs spent.

    Raises :class:`PlanCheckError` carrying the failed invariant IDs.
    This doubles as the serving engine's health probe: a rung of the
    degradation ladder is only promoted into service after the plan it
    runs passes this check, so serving and CI enforce the SAME registry.
    """
    from repro.analysis.verify import check_plan as _registry_check

    _registry_check(plan)


def compile_dhm(
    topo,
    params: dict,
    *,
    quant: QuantSpec = QuantSpec(),
    n_stages: int = 1,
    backend: Optional[str] = None,
    block_r: int = 8,
    block_w: int = 0,
    block_c: int = 0,
    block_n: int = 0,
    vmem_budget: Optional[int] = None,
) -> CompiledDHM:
    """Lower a CNNTopology + params to an executable DHM plan.

    Args:
      topo: a ``repro.models.cnn.CNNTopology`` (or any object with the same
        ``conv_layers`` / ``conv_shapes()`` duck type).
      params: ``{"conv": [{"w", "b"}...], "fc": [{"w", "b"}...]}`` as built
        by ``init_cnn``. Quantization per ``quant`` is baked into the plan
        here, once.
      quant: the :class:`QuantSpec` contract.
      n_stages: contiguous pipeline stages to partition the conv stack into
        (1 = the whole feature extractor as one sequential plan).
      backend: kernel backend enum (``repro.kernels.backends``); None means
        the compiled default.
      vmem_budget: per-block VMEM byte budget of the cross-layer fusion
        planner (``repro.core.dhm.fusion``). Within each stage the planner
        walks the DPN's conv layers and emits maximal contiguous fusion
        groups whose costed working set (weights + composed-halo feature
        slabs + tap operands) fits the budget; each multi-layer group runs
        as ONE fused pyramid kernel with inter-layer slabs VMEM-resident —
        the paper's no-external-memory dataflow across layer boundaries.
        ``None`` means :data:`~repro.core.dhm.fusion.DEFAULT_VMEM_BUDGET`
        (~one TPU core's VMEM; under it every paper topology's feature
        extractor fuses into a single group); ``0`` disables fusion, which
        reproduces the per-layer-stage plan exactly (each layer one
        ``stream_conv_block`` call with the ``block_*`` knobs).
    """
    validate_topology(topo)
    resolved = validate_backend(DEFAULT_BACKEND if backend is None else backend)
    n_conv = len(topo.conv_layers)
    if not 1 <= n_stages <= n_conv:
        raise ValueError(
            f"n_stages must be in [1, {n_conv}] for {topo.name}, got {n_stages}"
        )
    if quant.per_layer_bits is not None and len(quant.per_layer_bits) != n_conv:
        raise ValueError(
            f"per_layer_bits has {len(quant.per_layer_bits)} entries but "
            f"{topo.name} has {n_conv} conv layers"
        )
    if quant.int8_compute:
        for i in range(n_conv):
            wb, ab = quant.conv_weight_bits(i), quant.conv_act_bits(i)
            if wb is None or ab is None or wb > 8 or ab > 8:
                raise ValueError(
                    f"int8_compute needs weight/act bits <= 8 for every "
                    f"conv layer; layer {i} has weight={wb} act={ab}"
                )
    resolved_budget = (
        DEFAULT_VMEM_BUDGET if vmem_budget is None else vmem_budget
    )
    if resolved_budget < 0:
        raise ValueError(
            f"vmem_budget must be >= 0 (0 disables fusion), got {vmem_budget}"
        )

    graph = _cached_dpn(topo, quant.stream_bits)
    assignment = _cached_layout(topo, quant.stream_bits, n_stages)

    conv_params, w_scales = _bake_conv_params(params["conv"], quant)
    if quant.int8_compute:
        from repro.kernels.stream_conv.epilogue import Int8Scales

        # Layer i's input stream is layer i-1's quantized output; layer 0
        # quantizes the frame onto its own stream grid (the plan contract
        # for int8 input ingestion).
        int8_scales = tuple(
            Int8Scales(
                in_bits=quant.conv_act_bits(max(i - 1, 0)),
                w_scale=w_scales[i],
            )
            for i in range(n_conv)
        )
    else:
        int8_scales = ()
    elem_bytes = 1 if quant.int8_compute else 4
    per_layer_act = tuple(quant.conv_act_bits(i) for i in range(n_conv))
    varies = quant.mixed_bitwidth or quant.int8_compute

    stages = []
    h, w = topo.input_shape
    c = topo.input_channels
    for s in range(n_stages):
        idxs = tuple(assignment.layers_of_stage(s))
        specs = tuple(topo.conv_layers[i] for i in idxs)
        in_shape = (h, w, c)
        for spec in specs:
            h, w = spec.out_hw(h, w)
            c = spec.n_out
        io = StageIOSpec(in_shape=in_shape, out_shape=(h, w, c))
        groups = plan_fusion_groups(
            topo, idxs, vmem_budget=resolved_budget, elem_bytes=elem_bytes
        )
        local_groups = tuple(
            (tuple(li - idxs[0] for li in g.layers), g.block_rows)
            for g in groups
        )
        stages.append(
            CompiledStage(
                index=s,
                conv_layers=idxs,
                specs=specs,
                fn=emit_conv_stage(
                    specs,
                    backend=resolved,
                    act_bits=(
                        tuple(per_layer_act[i] for i in idxs)
                        if varies
                        else quant.act_bits
                    ),
                    int8_scales=(
                        tuple(int8_scales[i] for i in idxs)
                        if quant.int8_compute
                        else None
                    ),
                    block_r=block_r,
                    block_w=block_w,
                    block_c=block_c,
                    block_n=block_n,
                    groups=local_groups,
                ),
                cost_flops=assignment.stage_costs[s],
                groups=groups,
                io=io,
            )
        )

    head_fn = _emit_head(
        params["fc"], quant, resolved, head_in_bits=per_layer_act[-1]
    )
    return CompiledDHM(
        topo=topo,
        quant=quant,
        backend=resolved,
        graph=graph,
        assignment=assignment,
        stages=tuple(stages),
        conv_params=conv_params,
        head_fn=head_fn,
        vmem_budget=resolved_budget,
        int8_scales=int8_scales,
    )
