"""Execution + serving subsystem for compiled DHM plans.

``compiler.py`` is the *lowering* pass (topology -> DPN -> stages -> fused
kernel closures); this module is where compiled plans *execute*:

- :func:`forward` — the eager stage/head composition (``cnn_apply``'s
  path: a fresh per-call plan must not retrace a per-plan jit, so eval
  loops keep the process-wide kernel caches).
- :func:`plan_jitted_forward` — the plan's cached end-to-end jitted
  closure (conv stages + FC head as ONE compiled computation); the
  ``donate=True`` variant transfers input-buffer ownership to XLA for
  serving loops.
- :func:`pipeline_spec` / :func:`run_pipelined` — spatial execution on a
  mesh: per-stage closures + per-edge :class:`StageIOSpec` geometry feed
  the heterogeneous GPipe executor (``pipeline.pipeline_forward``), with
  optional data-parallel batch sharding on a 2D ``(stage, data)`` mesh.
- :class:`Engine` — the fault-tolerant continuous-batching server every
  consumer routes through. Requests carry per-request deadlines
  (``submit(x, deadline_ms=...)``); a background flush loop packs a
  micro-batch when it fills *or* the earliest deadline approaches;
  admission control bounds the queue (``block | reject | shed_oldest``)
  and validates every frame at the gate; dispatch runs under a watchdog
  timeout with bounded retry-with-backoff; persistent failures demote the
  engine down a health-checked execution ladder (mesh pipeline ->
  single-device fused plan -> per-layer plan -> ``ref`` backend) instead
  of taking the process down. Failures surface as structured per-request
  errors (:class:`DeadlineExceeded`, :class:`Rejected`, :class:`Shed`,
  :class:`InvalidRequest`, :class:`BatchFailed`) — ``result()`` raises,
  it never hangs. A seed-driven :class:`~repro.core.dhm.faults.FaultPlan`
  injects failures deterministically for the chaos suite.
"""
from __future__ import annotations

import collections
import dataclasses
import logging
import threading
import time
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.dhm.faults import FaultPlan, InjectedDeviceLoss
from repro.core.dhm.pipeline import CollectiveTimeout, call_with_timeout

_LOG = logging.getLogger("repro.dhm.engine")


# ---------------------------------------------------------------------------
# Plan execution (extracted from compiler.py — the compiler lowers, the
# engine runs).


def forward(plan, x: jax.Array) -> jax.Array:
    """Eager single-device forward: sequential fused stages + FC head.
    x: (B, H, W, C) NHWC -> logits (B, n_classes)."""
    return plan.head_fn(plan.features(x))


def plan_jitted_forward(plan, *, donate: bool = False) -> Callable:
    """The plan's cached end-to-end jitted closure (conv stages + FC head
    as ONE compiled computation — no per-stage Python re-entry, no eager
    head ops). Built once per plan and reused across calls, so repeated
    inference never retraces.

    ``donate=True`` returns a variant that donates the input buffer to the
    computation (XLA may reuse its memory for intermediates) — for serving
    loops that hand off ownership; the caller's array is invalidated, so
    the default keeps the input alive.
    """
    cache = getattr(plan, "_fwd_cache", None)
    if cache is None:
        cache = {}
        object.__setattr__(plan, "_fwd_cache", cache)
    if donate not in cache:
        cache[donate] = jax.jit(
            lambda xb: plan.head_fn(plan.features(xb)),
            donate_argnums=(0,) if donate else (),
        )
    return cache[donate]


def pipeline_spec(plan):
    """The heterogeneous pipeline description of a compiled plan: per-stage
    closures, per-stage params, and the per-edge activation geometry
    (:class:`~repro.core.dhm.pipeline.StageIOSpec` per stage, computed by
    the compiler from the topology)."""
    return (
        [st.fn for st in plan.stages],
        [plan.stage_params(s) for s in range(plan.n_stages)],
        tuple(st.io for st in plan.stages),
    )


def build_plan_pipeline(plan, *, mesh, cfg, microbatch=None):
    """Build the plan's spatial-pipeline runner once (params boxed,
    stacked and made resident per stage device group) — the repeated-
    serving path the ``Engine`` jits with the leaves passed as
    arguments."""
    from repro.core.dhm.pipeline import build_pipeline

    stage_fns, stage_params, io_specs = pipeline_spec(plan)
    return build_pipeline(
        stage_fns, stage_params, mesh=mesh, cfg=cfg, io_specs=io_specs,
        microbatch=microbatch,
    )


def run_pipelined(
    plan, microbatches, *, mesh, cfg=None, data_axis=None,
    overlap=False, edge_mode="auto",
):
    """Stream (M, mb, H, W, C) µbatches through the plan's conv stages on
    a mesh (one device group per stage; heterogeneous stage shapes flow
    over exact-shape-class ICI edges — ``edge_mode="boxed"`` forces the
    max-shape fallback, ``overlap=True`` double-buffers the edge slots).
    Returns the feature stream; apply ``plan.head_fn`` after re-flattening
    for logits."""
    from repro.core.dhm.pipeline import PipelineConfig

    if cfg is None:
        cfg = PipelineConfig(
            plan.n_stages, microbatches.shape[0], data_axis=data_axis,
            overlap=overlap, edge_mode=edge_mode,
        )
    runner = build_plan_pipeline(
        plan, mesh=mesh, cfg=cfg, microbatch=microbatches.shape[1]
    )
    return runner(microbatches)


# ---------------------------------------------------------------------------
# Structured per-request errors: a request always completes — with logits
# or with one of these; ``result()`` raises, it never hangs.


class RequestError(RuntimeError):
    """Base class of structured per-request serving failures."""


class DeadlineExceeded(RequestError):
    """The request's SLO deadline passed before it could be dispatched."""


class Rejected(RequestError):
    """Admission control turned the request away (queue full, policy
    ``reject``)."""


class Shed(Rejected):
    """The request was admitted but later evicted to make room for newer
    work (queue full, policy ``shed_oldest``)."""


class InvalidRequest(RequestError):
    """Gate validation failed the request (non-finite frames / bad dtype)
    — it never entered a packed batch, so it cannot poison one."""


class BatchFailed(RequestError):
    """The request's batch failed on every rung of the execution ladder
    (after retries and demotion) — resubmit or inspect the engine log."""


class LadderExhausted(RuntimeError):
    """Every rung of the execution ladder failed for the current batch;
    the engine stays on its last rung and keeps accepting work."""


class FlusherWedged(RuntimeError):
    """``stop()`` could not join the background flush thread within its
    timeout — a dispatch is stuck past the watchdog. The engine has
    already completed every still-queued request with :class:`Shed`
    (nothing hangs), but the wedged thread may leak; the condition is
    raised loudly instead of being silently swallowed at interpreter
    shutdown."""


class _PoisonedBatch(RuntimeError):
    """Internal: a packed batch carries non-finite input frames — rerun
    the requests isolated instead of retrying or demoting."""


class _NonFiniteOutput(RuntimeError):
    """Internal: a dispatch produced non-finite logits from finite inputs
    (corrupted activations / bad rung) — transient, retry then demote."""


ADMISSION_POLICIES = ("block", "reject", "shed_oldest")


# ---------------------------------------------------------------------------
# Requests + stats.


@dataclasses.dataclass
class Request:
    """One submitted inference request (a batch of frames).

    Completes exactly once: either with logits (``result()`` returns) or
    with a structured :class:`RequestError` (``result()`` raises). With a
    deadline, the flusher guarantees completion by ``deadline_at`` (give
    or take the flush interval) — success or :class:`DeadlineExceeded`.
    """

    index: int
    n_frames: int
    submitted_at: float
    deadline_at: Optional[float]
    _engine: "Engine"
    _frames: Optional[jax.Array] = None
    _result: Optional[jax.Array] = None
    _error: Optional[BaseException] = None
    done_at: Optional[float] = None
    _event: threading.Event = dataclasses.field(
        default_factory=threading.Event, repr=False
    )

    @property
    def done(self) -> bool:
        """The request has completed — with a result or with an error."""
        return self._event.is_set()

    @property
    def ok(self) -> bool:
        return self._result is not None

    @property
    def error(self) -> Optional[BaseException]:
        """The structured failure, or None (pending or succeeded)."""
        return self._error

    @property
    def latency_s(self) -> float:
        if self.done_at is None:
            raise RuntimeError("request not finished; call result() first")
        return self.done_at - self.submitted_at

    def result(self, timeout: Optional[float] = None) -> jax.Array:
        """Logits for this request's frames. Flushes the queue if the
        request has not been scheduled yet (or waits for the background
        flusher, up to ``timeout`` seconds). Raises the request's
        structured :class:`RequestError` if it failed — never hangs."""
        if not self._event.is_set():
            if self._engine._flusher_alive():
                budget = 60.0 if timeout is None else timeout
                if not self._event.wait(budget):
                    raise TimeoutError(
                        f"request {self.index} not completed within "
                        f"{budget:.1f}s — flusher wedged?"
                    )
            else:
                self._engine.flush()
        if self._error is not None:
            raise self._error
        if self._result is None:
            raise RuntimeError(
                f"request {self.index} was not completed by flush() — it "
                "was likely dropped by an earlier flush failure; resubmit"
            )
        return self._result


# Per-rung latency reservoir size: enough samples for a stable p99 at
# serving rates, bounded so a long-lived engine never grows without limit.
_LAT_WINDOW = 2048


def _percentile_ms(samples, q: float) -> float:
    """q-th percentile of a latency sample list, in milliseconds
    (nearest-rank; 0.0 on an empty pool)."""
    if not samples:
        return 0.0
    xs = sorted(samples)
    idx = min(len(xs) - 1, max(0, int(round(q / 100.0 * (len(xs) - 1)))))
    return xs[idx] * 1e3


@dataclasses.dataclass(frozen=True)
class EngineStats:
    """Aggregate serving statistics since engine construction (or the
    last :meth:`Engine.reset_stats`).

    Counts every terminal outcome, not only successes: rejected / shed
    admissions, deadline-exceeded and gate-invalid requests, batch
    failures, plus dispatch retries and ladder demotions.
    ``rung_latency_ms`` records p50/p99 **per execution-ladder rung**
    (over a bounded window of recent completions), so a demotion is
    visible as a latency regime change instead of vanishing into one
    aggregate pool."""

    n_requests: int
    n_frames: int
    n_batches: int  # jitted-closure invocations (incl. padding batches)
    busy_s: float  # wall time spent inside flush()
    mean_latency_s: float
    max_latency_s: float
    n_ok: int = 0
    n_rejected: int = 0
    n_shed: int = 0
    n_deadline_exceeded: int = 0
    n_invalid: int = 0
    n_failed: int = 0
    n_retries: int = 0
    n_demotions: int = 0
    rung: str = ""
    # rung name -> {"p50_ms", "p99_ms", "n"} over the recent window.
    rung_latency_ms: dict = dataclasses.field(default_factory=dict)

    @property
    def frames_per_s(self) -> float:
        return self.n_frames / self.busy_s if self.busy_s > 0 else 0.0

    @property
    def n_errors(self) -> int:
        """Requests that completed with a structured error."""
        return (
            self.n_rejected + self.n_shed + self.n_deadline_exceeded
            + self.n_invalid + self.n_failed
        )

    def summary(self) -> str:
        s = (
            f"{self.n_requests} requests / {self.n_frames} frames in "
            f"{self.n_batches} micro-batches: {self.frames_per_s:.0f} "
            f"frames/s, latency mean {self.mean_latency_s * 1e3:.2f} ms "
            f"max {self.max_latency_s * 1e3:.2f} ms"
        )
        if self.n_errors:
            s += (
                f"; errors: {self.n_rejected} rejected, {self.n_shed} shed, "
                f"{self.n_deadline_exceeded} deadline-exceeded, "
                f"{self.n_invalid} invalid, {self.n_failed} failed"
            )
        if self.n_retries:
            s += f"; {self.n_retries} dispatch retries"
        if self.n_demotions:
            s += f"; {self.n_demotions} demotions"
        if self.rung:
            s += f" (rung: {self.rung})"
        for rung, lat in self.rung_latency_ms.items():
            s += (
                f"\n  rung {rung}: p50 {lat['p50_ms']:.2f} ms "
                f"p99 {lat['p99_ms']:.2f} ms ({lat['n']} samples)"
            )
        return s


# ---------------------------------------------------------------------------
# The serving engine.


class Engine:
    """Fault-tolerant continuous-batching server around a
    :class:`CompiledDHM` plan.

    Requests (frames or frame batches) enter a bounded queue via
    :meth:`submit`, each optionally carrying a latency SLO
    (``deadline_ms``). :meth:`flush` packs the queue into fixed-size
    micro-batches (tail padded with zero frames, outputs sliced back per
    request) and runs them through the active rung's **donated** jitted
    closure; with :meth:`start` (or ``auto_flush=True``, or the context
    manager) a background flush loop does this continuously — a batch is
    dispatched when it fills *or* when the earliest queued deadline
    approaches, and requests whose deadline passed complete with
    :class:`DeadlineExceeded` instead of blocking the batch.

    **Admission control** (``max_queue`` + ``admission``): a full queue
    blocks the submitter, rejects the new request, or sheds the oldest
    queued one — always with a structured error, never silent loss. Gate
    validation (``validate=True``) fails non-finite / wrong-dtype frames
    at submit, so one bad frame can never poison a packed batch; if a bad
    frame does slip in (``validate=False``), the poisoned batch is rerun
    with each request isolated and only the invalid ones fail.

    **Graceful degradation**: execution runs on a health-checked ladder —
    mesh pipeline (when ``mesh`` is given) -> single-device fused plan ->
    per-layer plan (the ``vmem_budget=0`` lowering) -> ``ref`` backend.
    Each dispatch runs under a watchdog timeout
    (:func:`~repro.core.dhm.pipeline.call_with_timeout`); transient
    failures retry with exponential backoff, and a rung that keeps
    raising, times out, or loses a device is demoted with a logged reason
    (``engine.demotions``). A rung is only promoted into service after
    the plan passes its compiler self-check and the rung's closure
    completes a warmup probe.

    ``fault_plan`` injects deterministic failures
    (:mod:`repro.core.dhm.faults`) for chaos testing.
    """

    def __init__(
        self,
        plan,
        *,
        name: Optional[str] = None,
        microbatch: int = 8,
        mesh=None,
        n_microbatches=4,  # int, or "auto" to run the µbatch autotuner
        data_axis: Optional[str] = None,
        stage_axis: str = "stage",
        overlap: bool = False,
        edge_mode: str = "auto",
        tuning=None,  # a throughput.PipelineTuning overriding the knobs
        donate: bool = True,
        warmup: bool = True,
        # -- robustness knobs -------------------------------------------
        max_queue: int = 0,
        admission: str = "block",
        default_deadline_ms: Optional[float] = None,
        deadline_margin_ms: float = 2.0,
        validate: bool = True,
        check_outputs: bool = True,
        auto_flush: bool = False,
        flush_interval_ms: float = 5.0,
        dispatch_timeout_s: Optional[float] = 120.0,
        warmup_timeout_s: Optional[float] = None,
        max_retries: int = 2,
        retry_backoff_s: float = 0.005,
        allow_degraded: bool = True,
        fault_plan: Optional[FaultPlan] = None,
    ):
        # Autotuned pipeline geometry: an explicit PipelineTuning (from
        # throughput.autotune_pipeline over measured sweeps) or
        # n_microbatches="auto" (model-priced grid — no measurements)
        # overrides microbatch/n_microbatches/overlap/edge_mode.
        if tuning is None and n_microbatches == "auto":
            if mesh is None:
                raise ValueError(
                    'n_microbatches="auto" needs a mesh to tune for'
                )
            from repro.core.dhm.throughput import autotune_pipeline

            tuning = autotune_pipeline(plan, mesh.size)
        if tuning is not None:
            microbatch = tuning.microbatch
            n_microbatches = tuning.n_microbatches
            overlap = tuning.overlap
            edge_mode = tuning.edge_mode
        self.tuning = tuning
        if microbatch < 1:
            raise ValueError(f"microbatch must be >= 1, got {microbatch}")
        admission = admission.replace("-", "_")
        if admission not in ADMISSION_POLICIES:
            raise ValueError(
                f"unknown admission policy {admission!r}; expected one of "
                f"{ADMISSION_POLICIES}"
            )
        if max_queue < 0:
            raise ValueError("max_queue must be >= 0 (0 = unbounded)")
        if mesh is not None and (
            not isinstance(n_microbatches, int) or n_microbatches < 1
        ):
            raise ValueError(
                f"n_microbatches must be >= 1, got {n_microbatches}"
            )
        self.plan = plan
        self.microbatch = microbatch
        self.mesh = mesh
        self.n_microbatches = n_microbatches
        self.data_axis = data_axis
        self.stage_axis = stage_axis
        self.overlap = overlap
        self.edge_mode = edge_mode
        self.donate = donate
        self.warmup = warmup
        self.max_queue = max_queue
        self.admission = admission
        self.default_deadline_ms = default_deadline_ms
        self.deadline_margin_ms = deadline_margin_ms
        self.validate = validate
        self.check_outputs = check_outputs
        self.flush_interval_ms = flush_interval_ms
        self.dispatch_timeout_s = dispatch_timeout_s
        # Warmup probes include compile time, which is unbounded by design;
        # ``dispatch_timeout_s`` watches steady-state dispatches only (the
        # probe has already compiled the rung's closure at the serving
        # shape). Set this to also bound rung warmup/compilation.
        self.warmup_timeout_s = warmup_timeout_s
        self.max_retries = max_retries
        self.retry_backoff_s = retry_backoff_s
        self._faults = fault_plan
        # Tenant name: threaded into fault hooks so a FaultPlan can scope
        # its trigger windows to ONE tenant's engine (bulkhead chaos
        # testing); None = the untenanted single-engine stream.
        self.name = name

        h, w = plan.topo.input_shape
        self._frame_shape = (h, w, plan.topo.input_channels)
        # Frames one jitted-closure invocation consumes.
        self.group = (
            microbatch if mesh is None else microbatch * n_microbatches
        )

        self._lock = threading.RLock()
        self._cv = threading.Condition(self._lock)
        self._flush_lock = threading.Lock()
        self._queue: list = []  # pending Requests (frames attached)
        self._queue_frames = 0
        self._requests = 0
        # Stats report requests relative to this base so ``reset_stats``
        # can zero the window without reusing request indices.
        self._requests_base = 0
        self._frames = 0
        self._batches = 0
        self._busy_s = 0.0
        # Running latency aggregates (a serving engine lives long — no
        # per-request history kept).
        self._lat_n = 0
        self._lat_sum = 0.0
        self._lat_max = 0.0
        # Per-rung latency reservoirs (rung -> deque of recent latencies):
        # a demotion shows up as a new rung key with its own p50/p99
        # instead of smearing into the aggregate pool.
        self._rung_lat: dict = {}
        # Terminal-outcome counters beyond success.
        self._n_ok = 0
        self._n_rejected = 0
        self._n_shed = 0
        self._n_deadline = 0
        self._n_invalid = 0
        self._n_failed = 0
        self._n_retries = 0
        self.demotions: list = []  # [{"rung", "reason"}] per rung left
        self._flusher: Optional[threading.Thread] = None
        # A router's scheduler registers itself here (a zero-arg liveness
        # predicate): while it is alive the engine behaves as if a
        # background flusher runs — ``result()`` waits and block-policy
        # submits park on the condition instead of inline-draining.
        self._external_flusher: Optional[Callable[[], bool]] = None
        self._stop = threading.Event()

        # The execution ladder, best rung first. Each entry is
        # (name, closure factory); a rung is activated lazily and only
        # after the plan self-check + a warmup probe pass.
        self._ladder: list = []
        if mesh is not None:
            self._ladder.append(("mesh", self._build_mesh_fwd))
        self._ladder.append(("fused", self._build_fused_fwd))
        if allow_degraded:
            self._ladder.append(
                ("per_layer", lambda: self._build_unfused_fwd(plan.backend))
            )
            if getattr(plan, "backend", "ref") != "ref":
                self._ladder.append(
                    ("ref", lambda: self._build_unfused_fwd("ref"))
                )
        # Health probe: a plan that fails its own self-check (non-finite
        # baked params, inconsistent stage IO) must not serve at all.
        if hasattr(plan, "self_check"):
            plan.self_check()
        self._rung_idx = -1
        self._rung_name = ""
        self._fwd: Optional[Callable] = None
        if not self._activate_rung(0, reason=None):
            raise LadderExhausted(
                "no rung of the execution ladder passed its warmup probe"
            )
        if auto_flush:
            self.start()

    # -- execution ladder ---------------------------------------------------

    @property
    def rung(self) -> str:
        """Name of the ladder rung currently serving."""
        return self._rung_name

    def _build_fused_fwd(self) -> Callable:
        return plan_jitted_forward(self.plan, donate=self.donate)

    def _build_unfused_fwd(self, backend: str) -> Callable:
        """A degraded single-device closure: per-layer kernel calls (the
        ``vmem_budget=0`` lowering) on ``backend``, same baked params and
        head as the plan."""
        from repro.core.dhm.compiler import emit_conv_stage

        plan = self.plan
        stage_fns = [
            emit_conv_stage(
                st.specs, backend=backend, **plan.stage_quant_kwargs(st.index)
            )
            for st in plan.stages
        ]

        def _fwd(xb):
            for s, fn in enumerate(stage_fns):
                xb = fn(plan.stage_params(s), xb)
            return plan.head_fn(xb)

        return jax.jit(_fwd, donate_argnums=(0,) if self.donate else ())

    def _build_mesh_fwd(self) -> Callable:
        from repro.core.dhm.pipeline import PipelineConfig

        plan, mesh = self.plan, self.mesh
        microbatch, n_microbatches = self.microbatch, self.n_microbatches
        cfg = PipelineConfig(
            plan.n_stages, n_microbatches, stage_axis=self.stage_axis,
            data_axis=self.data_axis, overlap=self.overlap,
            edge_mode=self.edge_mode,
        )
        # Box + stack + make the per-stage params resident ONCE, here
        # (eagerly — stacking inside the jit trace would hand shard_map a
        # mis-partitioned operand on 2D meshes); the jitted closure then
        # takes the resident leaves as arguments.
        runner = build_plan_pipeline(
            plan, mesh=mesh, cfg=cfg, microbatch=microbatch
        )
        self._runner = runner

        def _pipe_fwd(leaves, frames):
            mbs = frames.reshape(
                (n_microbatches, microbatch) + frames.shape[1:]
            )
            feats = runner.apply(leaves, mbs)
            flat = feats.reshape(
                (n_microbatches * microbatch,) + feats.shape[2:]
            )
            return plan.head_fn(flat)

        pipe_jit = jax.jit(
            _pipe_fwd, donate_argnums=(1,) if self.donate else ()
        )
        return lambda frames: pipe_jit(runner.stacked_leaves, frames)

    @staticmethod
    def _demotion_record(rung: str, cause) -> dict:
        """A demotion ledger entry; when the cause is a
        :class:`PlanCheckError` (or anything else carrying registry
        ``invariants``), the record cites the failed invariant IDs so the
        ledger names the same checks CI's static gate enforces."""
        rec = {"rung": rung, "reason": str(cause)}
        ids = getattr(cause, "invariants", ())
        if ids:
            rec["invariants"] = list(ids)
        return rec

    def _activate_rung(
        self, idx: int, reason: Optional[str], cause=None
    ) -> bool:
        """Walk the ladder from ``idx`` until a rung builds and passes its
        warmup probe; record every rung skipped or left as a demotion.
        Returns False when the ladder is exhausted (current rung kept)."""
        if reason is not None and self._rung_name:
            self.demotions.append(
                self._demotion_record(self._rung_name, cause or reason)
            )
            _LOG.warning(
                "engine demoting off rung %r: %s", self._rung_name, reason
            )
        while idx < len(self._ladder):
            name, factory = self._ladder[idx]
            try:
                fwd = factory()
                if self.warmup:
                    probe = jnp.zeros(
                        (self.group,) + self._frame_shape, jnp.float32
                    )

                    def _probe():
                        out = fwd(self._stage(probe))
                        return jax.block_until_ready(out)

                    out = call_with_timeout(
                        _probe,
                        timeout_s=self.warmup_timeout_s,
                        what=f"warmup probe (rung {name})",
                    )
                    if not bool(jnp.isfinite(out).all()):
                        raise _NonFiniteOutput(
                            f"rung {name} warmup probe produced non-finite "
                            "logits"
                        )
            except Exception as e:  # noqa: BLE001 — any failure demotes
                self.demotions.append(self._demotion_record(name, e))
                _LOG.warning(
                    "engine rung %r failed its warmup probe: %s", name, e
                )
                idx += 1
                continue
            self._rung_idx = idx
            self._rung_name = name
            self._fwd = fwd
            return True
        return False

    def _demote(self, cause: BaseException) -> None:
        if not self._activate_rung(
            self._rung_idx + 1, reason=str(cause), cause=cause
        ):
            raise LadderExhausted(
                f"every execution-ladder rung failed (last: {cause})"
            ) from cause

    # -- request queue + admission -------------------------------------------

    def submit(
        self, x: jax.Array, *, deadline_ms: Optional[float] = None
    ) -> Request:
        """Enqueue a frame ((H, W, C)) or batch of frames ((B, H, W, C));
        returns a :class:`Request` whose ``result()`` yields its logits or
        raises its structured error.

        ``deadline_ms`` is the request's latency SLO: the background
        flusher dispatches early to honor it, and once it expires the
        request completes with :class:`DeadlineExceeded` instead of
        holding up the batch. Malformed shapes raise ``ValueError``
        immediately (a caller bug); non-finite or wrong-dtype frames fail
        the request with :class:`InvalidRequest` at the gate (bad data
        must never enter a packed batch). A full queue is handled per the
        engine's admission policy.
        """
        req = self._new_request(x, deadline_ms=deadline_ms)
        if req.done:  # failed at the validation gate
            return req
        return self._enqueue(req)

    def _new_request(
        self, x: jax.Array, *, deadline_ms: Optional[float] = None
    ) -> Request:
        """Parse + gate-validate frames into a :class:`Request` WITHOUT
        enqueueing it (the router uses this to fail a request fast —
        e.g. circuit open — before it ever touches the queue). Malformed
        shapes raise ``ValueError``; gate failures return the request
        already completed with :class:`InvalidRequest`."""
        # Queued frames live on the HOST: the flush packs variable request
        # counts with numpy (eager device concats would compile per
        # distinct shape) and only the fixed-shape packed group is staged
        # onto the device.
        x = np.asarray(x)
        if x.shape == self._frame_shape:
            x = x[None]
        if x.ndim != 4 or tuple(x.shape[1:]) != self._frame_shape:
            raise ValueError(
                f"expected frames of shape {self._frame_shape} (optionally "
                f"batched), got {tuple(x.shape)}"
            )
        now = time.perf_counter()
        if deadline_ms is None:
            deadline_ms = self.default_deadline_ms
        with self._lock:
            index = self._requests
            self._requests += 1
        req = Request(
            index=index,
            n_frames=x.shape[0],
            submitted_at=now,
            deadline_at=(
                now + deadline_ms / 1e3 if deadline_ms is not None else None
            ),
            _engine=self,
            _frames=x,
        )
        if self.validate:
            if not jnp.issubdtype(x.dtype, jnp.floating):
                self._fail(
                    req,
                    InvalidRequest(
                        f"request {req.index}: frames must be floating "
                        f"point, got dtype {x.dtype}"
                    ),
                )
                return req
            if not bool(np.isfinite(x).all()):
                self._fail(
                    req,
                    InvalidRequest(
                        f"request {req.index}: frames contain NaN/Inf — "
                        "rejected at the admission gate"
                    ),
                )
                return req
        return req

    def _enqueue(self, req: Request) -> Request:
        """Admit a gate-validated request into the bounded queue per the
        engine's admission policy (block | reject | shed_oldest)."""
        while True:
            with self._cv:
                if not self.max_queue or len(self._queue) < self.max_queue:
                    self._queue.append(req)
                    self._queue_frames += req.n_frames
                    self._cv.notify_all()
                    return req
                if self.admission == "reject":
                    self._fail(
                        req,
                        Rejected(
                            f"request {req.index}: queue full "
                            f"({self.max_queue} requests), policy=reject"
                        ),
                    )
                    return req
                if self.admission == "shed_oldest":
                    victim = self._queue.pop(0)
                    self._queue_frames -= victim.n_frames
                    self._fail(
                        victim,
                        Shed(
                            f"request {victim.index}: shed by newer work "
                            f"(queue full at {self.max_queue} requests, "
                            "policy=shed_oldest)"
                        ),
                    )
                    continue
                # policy == "block": wait for the flusher to drain...
                if self._flusher_alive():
                    self._cv.wait(timeout=0.05)
                    continue
            # ...or drain inline when no background flusher runs.
            self.flush()

    def _fail(self, req: Request, err: RequestError) -> None:
        """Complete a request with a structured error (exactly once)."""
        with self._lock:
            if req.done:
                return
            if isinstance(err, Shed):
                self._n_shed += 1
            elif isinstance(err, Rejected):
                self._n_rejected += 1
            elif isinstance(err, DeadlineExceeded):
                self._n_deadline += 1
            elif isinstance(err, InvalidRequest):
                self._n_invalid += 1
            else:
                self._n_failed += 1
            req._error = err
            req.done_at = time.perf_counter()
            req._frames = None
            req._event.set()

    def _complete(self, req: Request, logits: jax.Array, done: float) -> None:
        with self._lock:
            if req.done:
                return
            req._result = logits
            req.done_at = done
            req._frames = None
            req._event.set()
            lat = done - req.submitted_at
            self._lat_n += 1
            self._lat_sum += lat
            self._lat_max = max(self._lat_max, lat)
            self._rung_lat.setdefault(
                self._rung_name, collections.deque(maxlen=_LAT_WINDOW)
            ).append(lat)
            self._n_ok += 1
            self._frames += req.n_frames

    # -- dispatch: faults, watchdog, retry, demotion --------------------------

    def _stage(self, batch: jax.Array) -> jax.Array:
        """Stage a packed micro-batch into a fresh buffer the closure can
        consume. The copy is what makes donation safe (the caller's arrays
        stay valid and a failed dispatch can restage for its retry);
        because the closure is dispatched asynchronously, the flush loop
        stages batch k+1 while batch k's donated buffer is still being
        computed on — the double-buffered serving path."""
        return jnp.array(batch, copy=True)

    def _corrupted_forward(self, frames: jax.Array, stage: int) -> jax.Array:
        """Eager forward with NaN corruption injected at the boundary
        after conv stage ``stage`` (the fault-injection path — models
        silent mid-pipeline data corruption)."""
        x = self._stage(frames)
        for st in self.plan.stages:
            x = st.fn(self.plan.stage_params(st.index), x)
            if st.index == stage:
                x = jnp.full_like(x, jnp.nan)
        return self.plan.head_fn(x)

    def _run_group(self, frames: jax.Array) -> jax.Array:
        """Run one exactly-``group``-sized batch through the active rung,
        blocked until ready: fault effects applied, watchdog timeout,
        bounded retry-with-backoff on transient failures, demotion on
        persistent ones. Raises :class:`LadderExhausted` when no rung can
        complete the batch, or :class:`_PoisonedBatch` when the inputs
        themselves are non-finite (the flush isolates per request)."""
        backoff = self.retry_backoff_s
        retries_left = self.max_retries
        while True:
            eff = (
                self._faults.dispatch_effects(
                    rung=self._rung_name, tenant=self.name
                )
                if self._faults is not None
                else None
            )

            def _attempt():
                if eff is not None:
                    if eff.stall_s:
                        time.sleep(eff.stall_s)
                    if eff.exc is not None:
                        raise eff.exc
                    if eff.corrupt_stage is not None:
                        return jax.block_until_ready(
                            self._corrupted_forward(frames, eff.corrupt_stage)
                        )
                return jax.block_until_ready(self._fwd(self._stage(frames)))

            try:
                out = call_with_timeout(
                    _attempt,
                    timeout_s=self.dispatch_timeout_s,
                    what=f"dispatch (rung {self._rung_name})",
                )
                with self._lock:
                    self._batches += 1
                if self.check_outputs and not bool(jnp.isfinite(out).all()):
                    if not bool(np.isfinite(np.asarray(frames)).all()):
                        raise _PoisonedBatch(
                            "packed batch carries non-finite input frames"
                        )
                    raise _NonFiniteOutput(
                        f"rung {self._rung_name} produced non-finite logits "
                        "from finite inputs"
                    )
                return out
            except _PoisonedBatch:
                raise
            except (InjectedDeviceLoss, CollectiveTimeout) as e:
                # Not transient: a lost device or wedged collective will
                # not heal on retry — demote off the rung immediately.
                self._demote(e)
                retries_left = self.max_retries
                backoff = self.retry_backoff_s
            except Exception as e:  # noqa: BLE001 — retry then demote
                if retries_left > 0:
                    retries_left -= 1
                    with self._lock:
                        self._n_retries += 1
                    _LOG.info(
                        "dispatch failed on rung %r (%s); retrying in "
                        "%.3fs (%d retries left)",
                        self._rung_name, e, backoff, retries_left,
                    )
                    time.sleep(backoff)
                    backoff *= 2
                else:
                    self._demote(e)
                    retries_left = self.max_retries
                    backoff = self.retry_backoff_s

    # -- flushing -------------------------------------------------------------

    def flush(self, max_frames: Optional[int] = None) -> int:
        """Drain the queue: pack pending frames into ``group``-sized
        micro-batches (zero-padded tail), run each through the active
        rung, and scatter the logits back to their requests. Expired
        deadlines complete with :class:`DeadlineExceeded` at pack time; a
        failed batch is isolated per request so invalid requests fail
        alone. Explicitly a no-op on an empty queue (double-flush safe);
        thread-safe against the background flusher.

        ``max_frames`` bounds one call to roughly that many frames from
        the queue head (always at least one request) — the router's
        deficit-round-robin scheduler uses this to dispatch exactly one
        scheduling quantum per turn. Returns the number of frames taken
        off the queue (0 = nothing pending)."""
        with self._flush_lock:
            return self._flush_once(max_frames)

    def _flush_once(self, max_frames: Optional[int] = None) -> int:
        if self._faults is not None:
            delay = self._faults.on_flush(tenant=self.name)
            if delay:
                time.sleep(delay)
        with self._cv:
            if not self._queue:
                return 0
            if max_frames is None:
                pending, self._queue = self._queue, []
                self._queue_frames = 0
            else:
                # Take whole requests from the head up to ~max_frames
                # (never split a request; always take at least one).
                pending = []
                taken = 0
                while self._queue and (
                    not pending
                    or taken + self._queue[0].n_frames <= max_frames
                ):
                    r = self._queue.pop(0)
                    pending.append(r)
                    taken += r.n_frames
                self._queue_frames -= taken
            self._cv.notify_all()
        n_taken = sum(r.n_frames for r in pending)
        t0 = time.perf_counter()
        live = []
        for req in pending:
            if req.deadline_at is not None and t0 > req.deadline_at:
                self._fail(
                    req,
                    DeadlineExceeded(
                        f"request {req.index}: deadline passed "
                        f"{(t0 - req.deadline_at) * 1e3:.1f} ms before "
                        "dispatch"
                    ),
                )
            else:
                live.append(req)
        if not live:
            return n_taken
        try:
            # Pack on the HOST: the request count (and so the concat/pad
            # shapes) varies per flush, and eager jnp ops compile once per
            # distinct shape — numpy packing keeps the device path at the
            # one fixed group shape the jitted closure was compiled for.
            frames = np.concatenate(
                [np.asarray(r._frames) for r in live], axis=0
            )
            n = frames.shape[0]
            pad = -n % self.group
            if pad:
                frames = np.concatenate(
                    [frames,
                     np.zeros((pad,) + self._frame_shape, frames.dtype)]
                )
            outs = []
            for start in range(0, frames.shape[0], self.group):
                outs.append(
                    np.asarray(
                        self._run_group(frames[start : start + self.group])
                    )
                )
            logits = (
                outs[0][:n] if len(outs) == 1
                else np.concatenate(outs, axis=0)[:n]
            )
        except _PoisonedBatch:
            self._isolate(live)
            with self._lock:
                self._busy_s += time.perf_counter() - t0
            return n_taken
        except LadderExhausted as e:
            for req in live:
                self._fail(
                    req,
                    BatchFailed(f"request {req.index}: batch failed — {e}"),
                )
            with self._lock:
                self._busy_s += time.perf_counter() - t0
            return n_taken
        except Exception as e:  # noqa: BLE001 — never drop requests silently
            _LOG.exception("unexpected flush failure")
            for req in live:
                self._fail(
                    req,
                    BatchFailed(
                        f"request {req.index}: unexpected flush failure — "
                        f"{type(e).__name__}: {e}"
                    ),
                )
            with self._lock:
                self._busy_s += time.perf_counter() - t0
            return n_taken
        done = time.perf_counter()
        off = 0
        for req in live:
            self._complete(req, logits[off : off + req.n_frames], done)
            off += req.n_frames
        with self._lock:
            self._busy_s += done - t0
        return n_taken

    def _isolate(self, reqs: list) -> None:
        """Rerun a poisoned batch one request at a time: invalid requests
        fail alone with :class:`InvalidRequest`, the rest recompute
        cleanly — one bad frame never takes down its batchmates."""
        for req in reqs:
            x = np.asarray(req._frames)
            if not bool(np.isfinite(x).all()):
                self._fail(
                    req,
                    InvalidRequest(
                        f"request {req.index}: frames contain NaN/Inf — "
                        "isolated from its batch"
                    ),
                )
                continue
            pad = -req.n_frames % self.group
            if pad:
                x = np.concatenate(
                    [x, np.zeros((pad,) + self._frame_shape, x.dtype)]
                )
            try:
                outs = []
                for start in range(0, x.shape[0], self.group):
                    outs.append(
                        np.asarray(self._run_group(x[start : start + self.group]))
                    )
                logits = np.concatenate(outs, axis=0)[: req.n_frames]
            except (LadderExhausted, _PoisonedBatch) as e:
                self._fail(
                    req,
                    BatchFailed(
                        f"request {req.index}: isolated rerun failed — {e}"
                    ),
                )
                continue
            self._complete(req, logits, time.perf_counter())

    # -- background flush loop ------------------------------------------------

    def _flusher_alive(self) -> bool:
        if self._flusher is not None and self._flusher.is_alive():
            return True
        ext = self._external_flusher
        return bool(ext is not None and ext())

    def start(self) -> "Engine":
        """Start the background flush loop (idempotent): micro-batches are
        dispatched when they fill, when the earliest queued deadline is
        within ``deadline_margin_ms``, or every ``flush_interval_ms`` —
        continuous batching, no cooperative ``flush()`` needed."""
        if self._flusher_alive():
            return self
        self._stop = threading.Event()
        self._flusher = threading.Thread(
            target=self._flush_loop, daemon=True, name="dhm-engine-flusher"
        )
        self._flusher.start()
        return self

    def _shed_all(self, why: str) -> int:
        """Complete every still-queued request with a structured
        :class:`Shed` error (exactly-once semantics hold: a request a
        late-waking flusher already picked up is a no-op here and vice
        versa). Returns the number of requests shed."""
        with self._cv:
            pending, self._queue = self._queue, []
            self._queue_frames = 0
            self._cv.notify_all()
        for req in pending:
            self._fail(req, Shed(f"request {req.index}: {why}"))
        return len(pending)

    def stop(self, *, drain: bool = True, join_timeout_s: float = 30.0) -> None:
        """Stop the background flush loop; by default drain what is still
        queued (every in-flight request still completes).

        The join is bounded: if the flusher does not exit within
        ``join_timeout_s`` (a dispatch wedged past the watchdog), every
        still-queued request is completed with :class:`Shed` — nothing
        hangs — and :class:`FlusherWedged` is raised loudly instead of
        leaking the thread silently into interpreter shutdown."""
        flusher = self._flusher
        if flusher is not None:
            self._stop.set()
            with self._cv:
                self._cv.notify_all()
            flusher.join(timeout=join_timeout_s)
            self._flusher = None
            if flusher.is_alive():
                shed = self._shed_all(
                    "engine stopping with a wedged flush thread"
                )
                raise FlusherWedged(
                    f"flush thread did not exit within {join_timeout_s:.1f}s "
                    f"of stop(); {shed} queued request(s) completed with "
                    "Shed. A dispatch is stuck past the watchdog — inspect "
                    "engine.demotions and the active rung."
                )
        if drain:
            self.flush()

    def __enter__(self) -> "Engine":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop()

    def _flush_loop(self) -> None:
        interval = self.flush_interval_ms / 1e3
        margin = self.deadline_margin_ms / 1e3
        last_flush = time.perf_counter()
        while not self._stop.is_set():
            with self._cv:
                if not self._queue:
                    self._cv.wait(timeout=interval)
                    continue
                full = self._queue_frames >= self.group
                ddl = min(
                    (r.deadline_at for r in self._queue
                     if r.deadline_at is not None),
                    default=None,
                )
            now = time.perf_counter()
            due = (
                full
                or (ddl is not None and now >= ddl - margin)
                or (now - last_flush >= interval)
            )
            if due:
                try:
                    self.flush()
                except Exception:  # noqa: BLE001 — the loop must survive
                    _LOG.exception("background flush failed; loop continues")
                last_flush = time.perf_counter()
            else:
                wait = interval - (now - last_flush)
                if ddl is not None:
                    wait = min(wait, ddl - margin - now)
                with self._cv:
                    self._cv.wait(timeout=max(1e-4, wait))
        # Drain whatever arrived before the stop signal.
        try:
            self.flush()
        except Exception:  # noqa: BLE001
            _LOG.exception("final drain flush failed")

    # -- conveniences ----------------------------------------------------------

    def infer(self, x: jax.Array, *, deadline_ms: Optional[float] = None):
        """Convenience: submit + flush + result."""
        req = self.submit(x, deadline_ms=deadline_ms)
        if not self._flusher_alive():
            self.flush()
        return req.result()

    def stats(self) -> EngineStats:
        with self._lock:
            return EngineStats(
                n_requests=self._requests - self._requests_base,
                n_frames=self._frames,
                n_batches=self._batches,
                busy_s=self._busy_s,
                mean_latency_s=(
                    self._lat_sum / self._lat_n if self._lat_n else 0.0
                ),
                max_latency_s=self._lat_max,
                n_ok=self._n_ok,
                n_rejected=self._n_rejected,
                n_shed=self._n_shed,
                n_deadline_exceeded=self._n_deadline,
                n_invalid=self._n_invalid,
                n_failed=self._n_failed,
                n_retries=self._n_retries,
                n_demotions=len(self.demotions),
                rung=self._rung_name,
                rung_latency_ms={
                    rung: {
                        "p50_ms": _percentile_ms(lat, 50.0),
                        "p99_ms": _percentile_ms(lat, 99.0),
                        "n": len(lat),
                    }
                    for rung, lat in self._rung_lat.items()
                },
            )

    def reset_stats(self) -> None:
        """Zero every counter and latency reservoir so a measurement run
        (load bench, SLO window) excludes warmup / prior-phase samples.
        The demotion ledger is kept — it is an audit trail, not a metric
        — and the queue and rung state are untouched."""
        with self._lock:
            self._requests_base = self._requests
            self._frames = 0
            self._batches = 0
            self._busy_s = 0.0
            self._lat_n = 0
            self._lat_sum = 0.0
            self._lat_max = 0.0
            self._rung_lat = {}
            self._n_ok = 0
            self._n_rejected = 0
            self._n_shed = 0
            self._n_deadline = 0
            self._n_invalid = 0
            self._n_failed = 0
            self._n_retries = 0
