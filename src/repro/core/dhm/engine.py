"""Execution + serving subsystem for compiled DHM plans.

``compiler.py`` is the *lowering* pass (topology -> DPN -> stages -> fused
kernel closures); this module is where compiled plans *execute*:

- :func:`forward` — the eager stage/head composition (``cnn_apply``'s
  path: a fresh per-call plan must not retrace a per-plan jit, so eval
  loops keep the process-wide kernel caches).
- :func:`plan_jitted_forward` — the plan's cached end-to-end jitted
  closure (conv stages + FC head as ONE compiled computation); the
  ``donate=True`` variant transfers input-buffer ownership to XLA for
  serving loops.
- :func:`pipeline_spec` / :func:`run_pipelined` — spatial execution on a
  mesh: per-stage closures + per-edge :class:`StageIOSpec` geometry feed
  the heterogeneous GPipe executor (``pipeline.pipeline_forward``), with
  optional data-parallel batch sharding on a 2D ``(stage, data)`` mesh.
- :class:`Engine` — the serving front end every consumer routes through:
  a micro-batch request queue, double-buffered donated jitted closures,
  warmup, and per-request latency / engine throughput stats. Runs either
  single-device (sequential fused stages) or pipelined on a mesh.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Optional

import jax
import jax.numpy as jnp


# ---------------------------------------------------------------------------
# Plan execution (extracted from compiler.py — the compiler lowers, the
# engine runs).


def forward(plan, x: jax.Array) -> jax.Array:
    """Eager single-device forward: sequential fused stages + FC head.
    x: (B, H, W, C) NHWC -> logits (B, n_classes)."""
    return plan.head_fn(plan.features(x))


def plan_jitted_forward(plan, *, donate: bool = False) -> Callable:
    """The plan's cached end-to-end jitted closure (conv stages + FC head
    as ONE compiled computation — no per-stage Python re-entry, no eager
    head ops). Built once per plan and reused across calls, so repeated
    inference never retraces.

    ``donate=True`` returns a variant that donates the input buffer to the
    computation (XLA may reuse its memory for intermediates) — for serving
    loops that hand off ownership; the caller's array is invalidated, so
    the default keeps the input alive.
    """
    cache = getattr(plan, "_fwd_cache", None)
    if cache is None:
        cache = {}
        object.__setattr__(plan, "_fwd_cache", cache)
    if donate not in cache:
        cache[donate] = jax.jit(
            lambda xb: plan.head_fn(plan.features(xb)),
            donate_argnums=(0,) if donate else (),
        )
    return cache[donate]


def pipeline_spec(plan):
    """The heterogeneous pipeline description of a compiled plan: per-stage
    closures, per-stage params, and the per-edge activation geometry
    (:class:`~repro.core.dhm.pipeline.StageIOSpec` per stage, computed by
    the compiler from the topology)."""
    return (
        [st.fn for st in plan.stages],
        [plan.stage_params(s) for s in range(plan.n_stages)],
        tuple(st.io for st in plan.stages),
    )


def build_plan_pipeline(plan, *, mesh, cfg, microbatch=None):
    """Build the plan's spatial-pipeline runner once (params boxed,
    stacked and made resident per stage device group) — the repeated-
    serving path the ``Engine`` jits with the leaves passed as
    arguments."""
    from repro.core.dhm.pipeline import build_pipeline

    stage_fns, stage_params, io_specs = pipeline_spec(plan)
    return build_pipeline(
        stage_fns, stage_params, mesh=mesh, cfg=cfg, io_specs=io_specs,
        microbatch=microbatch,
    )


def run_pipelined(plan, microbatches, *, mesh, cfg=None, data_axis=None):
    """Stream (M, mb, H, W, C) µbatches through the plan's conv stages on
    a mesh (one device group per stage; heterogeneous stage shapes flow
    through boxed ICI buffers). Returns the feature stream; apply
    ``plan.head_fn`` after re-flattening for logits."""
    from repro.core.dhm.pipeline import PipelineConfig

    if cfg is None:
        cfg = PipelineConfig(
            plan.n_stages, microbatches.shape[0], data_axis=data_axis
        )
    runner = build_plan_pipeline(
        plan, mesh=mesh, cfg=cfg, microbatch=microbatches.shape[1]
    )
    return runner(microbatches)


# ---------------------------------------------------------------------------
# The serving engine.


@dataclasses.dataclass
class Request:
    """One submitted inference request (a batch of frames)."""

    index: int
    n_frames: int
    submitted_at: float
    _engine: "Engine"
    _result: Optional[jax.Array] = None
    done_at: Optional[float] = None

    @property
    def done(self) -> bool:
        return self._result is not None

    @property
    def latency_s(self) -> float:
        if self.done_at is None:
            raise RuntimeError("request not finished; call result() first")
        return self.done_at - self.submitted_at

    def result(self) -> jax.Array:
        """Logits for this request's frames (flushes the queue if the
        request has not been scheduled yet)."""
        if self._result is None:
            self._engine.flush()
        if self._result is None:
            raise RuntimeError(
                f"request {self.index} was not completed by flush() — it "
                "was likely dropped by an earlier flush failure; resubmit"
            )
        return self._result


@dataclasses.dataclass(frozen=True)
class EngineStats:
    """Aggregate serving statistics since engine construction."""

    n_requests: int
    n_frames: int
    n_batches: int  # jitted-closure invocations (incl. padding batches)
    busy_s: float  # wall time spent inside flush()
    mean_latency_s: float
    max_latency_s: float

    @property
    def frames_per_s(self) -> float:
        return self.n_frames / self.busy_s if self.busy_s > 0 else 0.0

    def summary(self) -> str:
        return (
            f"{self.n_requests} requests / {self.n_frames} frames in "
            f"{self.n_batches} micro-batches: {self.frames_per_s:.0f} "
            f"frames/s, latency mean {self.mean_latency_s * 1e3:.2f} ms "
            f"max {self.max_latency_s * 1e3:.2f} ms"
        )


class Engine:
    """Micro-batched serving engine around a :class:`CompiledDHM` plan.

    Requests (frames or frame batches) enter a queue via :meth:`submit`;
    :meth:`flush` packs the queue into fixed-size micro-batches (tail
    padded with zero frames, outputs sliced back per request) and runs
    them through the plan's **donated** jitted closure. Two staging slots
    alternate per micro-batch (double buffering): slot k+1 is staged while
    slot k's computation is still in flight under JAX's async dispatch,
    and donation lets XLA reuse each staged buffer for intermediates.

    With ``mesh`` set, micro-batches are grouped ``n_microbatches`` at a
    time and streamed through the spatial pipeline
    (:func:`run_pipelined` — heterogeneous stages over boxed ICI edges,
    optional ``data_axis`` batch sharding), then through the FC head, as
    one jitted closure.
    """

    def __init__(
        self,
        plan,
        *,
        microbatch: int = 8,
        mesh=None,
        n_microbatches: int = 4,
        data_axis: Optional[str] = None,
        stage_axis: str = "stage",
        donate: bool = True,
        warmup: bool = True,
    ):
        if microbatch < 1:
            raise ValueError(f"microbatch must be >= 1, got {microbatch}")
        self.plan = plan
        self.microbatch = microbatch
        self.mesh = mesh
        self.n_microbatches = n_microbatches
        self.donate = donate
        h, w = plan.topo.input_shape
        self._frame_shape = (h, w, plan.topo.input_channels)
        self._queue: list = []
        self._requests = 0
        self._frames = 0
        self._batches = 0
        self._busy_s = 0.0
        # Running latency aggregates (a serving engine lives long — no
        # per-request history kept).
        self._lat_n = 0
        self._lat_sum = 0.0
        self._lat_max = 0.0

        if mesh is None:
            self._fwd = plan_jitted_forward(plan, donate=donate)
        else:
            from repro.core.dhm.pipeline import PipelineConfig

            if n_microbatches < 1:
                raise ValueError(
                    f"n_microbatches must be >= 1, got {n_microbatches}"
                )
            cfg = PipelineConfig(
                plan.n_stages, n_microbatches, stage_axis=stage_axis,
                data_axis=data_axis,
            )
            # Box + stack + make the per-stage params resident ONCE, here
            # (eagerly — stacking inside the jit trace would hand
            # shard_map a mis-partitioned operand on 2D meshes); the
            # jitted closure then takes the resident leaves as arguments.
            runner = build_plan_pipeline(
                plan, mesh=mesh, cfg=cfg, microbatch=microbatch
            )
            self._runner = runner

            def _pipe_fwd(leaves, frames):
                mbs = frames.reshape(
                    (n_microbatches, microbatch) + frames.shape[1:]
                )
                feats = runner.apply(leaves, mbs)
                flat = feats.reshape(
                    (n_microbatches * microbatch,) + feats.shape[2:]
                )
                return plan.head_fn(flat)

            pipe_jit = jax.jit(
                _pipe_fwd, donate_argnums=(1,) if donate else ()
            )
            self._fwd = lambda frames: pipe_jit(runner.stacked_leaves, frames)
        # Frames one jitted-closure invocation consumes.
        self.group = (
            microbatch if mesh is None else microbatch * n_microbatches
        )
        if warmup:
            self._fwd(self._stage(jnp.zeros((self.group,) + self._frame_shape)))

    # -- request queue -----------------------------------------------------

    def submit(self, x: jax.Array) -> Request:
        """Enqueue a frame ((H, W, C)) or batch of frames ((B, H, W, C));
        returns a :class:`Request` whose ``result()`` yields its logits."""
        x = jnp.asarray(x)
        if x.shape == self._frame_shape:
            x = x[None]
        if x.ndim != 4 or tuple(x.shape[1:]) != self._frame_shape:
            raise ValueError(
                f"expected frames of shape {self._frame_shape} (optionally "
                f"batched), got {tuple(x.shape)}"
            )
        req = Request(
            index=self._requests,
            n_frames=x.shape[0],
            submitted_at=time.perf_counter(),
            _engine=self,
        )
        self._requests += 1
        self._queue.append((req, x))
        return req

    def _stage(self, batch: jax.Array) -> jax.Array:
        """Stage a packed micro-batch into a fresh buffer the closure can
        consume. The copy is what makes donation safe (the caller's arrays
        stay valid); because the closure is dispatched asynchronously, the
        flush loop stages batch k+1 while batch k's donated buffer is
        still being computed on — the double-buffered serving path."""
        return jnp.array(batch, copy=True)

    def flush(self) -> None:
        """Drain the queue: pack pending frames into ``group``-sized
        micro-batches (zero-padded tail), run each through the donated
        closure, and scatter the logits back to their requests."""
        if not self._queue:
            return
        t0 = time.perf_counter()
        pending, self._queue = self._queue, []
        try:
            frames = jnp.concatenate([x for _, x in pending], axis=0)
            n = frames.shape[0]
            pad = -n % self.group
            if pad:
                frames = jnp.concatenate(
                    [frames,
                     jnp.zeros((pad,) + self._frame_shape, frames.dtype)]
                )
            outs = []
            for start in range(0, frames.shape[0], self.group):
                staged = self._stage(frames[start : start + self.group])
                outs.append(self._fwd(staged))
                self._batches += 1
            logits = jnp.concatenate(outs, axis=0)[:n]
            logits.block_until_ready()
        except Exception:
            # Put the batch back so the requests are not silently lost;
            # a retry flush (or result()) sees them again.
            self._queue = pending + self._queue
            raise
        done = time.perf_counter()
        off = 0
        for req, _ in pending:
            req._result = logits[off : off + req.n_frames]
            req.done_at = done
            off += req.n_frames
            lat = req.done_at - req.submitted_at
            self._lat_n += 1
            self._lat_sum += lat
            self._lat_max = max(self._lat_max, lat)
        self._frames += n
        self._busy_s += done - t0

    def infer(self, x: jax.Array) -> jax.Array:
        """Convenience: submit + flush + result."""
        req = self.submit(x)
        self.flush()
        return req.result()

    def stats(self) -> EngineStats:
        return EngineStats(
            n_requests=self._requests,
            n_frames=self._frames,
            n_batches=self._batches,
            busy_s=self._busy_s,
            mean_latency_s=self._lat_sum / self._lat_n if self._lat_n else 0.0,
            max_latency_s=self._lat_max,
        )
