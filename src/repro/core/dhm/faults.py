"""Deterministic fault injection for the DHM serving engine.

The paper's dataflow argument is that an always-firing actor graph has no
control-flow surprises — but a *serving* runtime wrapped around it meets
plenty: wedged collectives, transient dispatch failures, corrupted
activations, lost devices. This module simulates those failure classes
**deterministically** (seed-driven, counter-triggered) so the chaos suite
can assert the engine's contract under each of them: structured
per-request errors or a one-rung demotion, never a hang or a crash.

A :class:`FaultPlan` is a sequence of fault specs plus a seed. The engine
consults it at two hook points:

- ``on_flush()`` — before a flush packs its batch (:class:`DelayedFlush`
  sleeps here, so deadline handling can be exercised);
- ``dispatch_effects(rung=...)`` — before each micro-batch dispatch;
  returns the :class:`DispatchEffects` to apply *inside* the timed
  dispatch call (a pre-dispatch stall, a raised error, or a
  NaN-corruption of the activations at a chosen stage boundary).

Each fault fires on a trigger window of dispatch/flush events
(``at``-th event onwards, for ``times`` events; ``times=None`` = forever)
or probabilistically via the plan's seeded RNG (``prob``), and can be
restricted to one execution-ladder rung (``rung="mesh"`` models a fault
of the collective path that vanishes after demotion to single-device)
and/or to one serving **tenant** (``tenant="A"`` models a fault whose
blast radius the multi-tenant router's bulkheads must contain: only
tenant A's engine sees it, and the chaos suite asserts tenant B's error
rate and latency stay untouched). Event counters are kept **per tenant**
(the ``None`` tenant is the single-engine legacy stream), so "fault A's
2nd dispatch" stays deterministic no matter how B's traffic interleaves.
Everything is reproducible from ``(faults, seed)`` — no wall-clock or
global randomness.
"""
from __future__ import annotations

import dataclasses
import random
import threading
from typing import Optional, Sequence


class InjectedFault(RuntimeError):
    """Base class of all errors raised *by* injected faults (so tests and
    the engine can tell simulated failures from real ones)."""


class InjectedDispatchError(InjectedFault):
    """A transient dispatch failure (the kind retry-with-backoff heals)."""


class InjectedDeviceLoss(InjectedFault):
    """A device dropped out of the mesh — not transient: the engine must
    demote off the affected rung immediately rather than retry into it."""


@dataclasses.dataclass(frozen=True)
class Fault:
    """Base fault spec: a trigger window over the fault's event counter.

    ``at``: 0-based event index the window opens at (counted per tenant).
    ``times``: events the window stays open for (``None`` = forever).
    ``prob``: if > 0, ignore the window and fire per-event with this
      probability from the plan's seeded RNG (deterministic per seed).
    ``rung``: only fire while the engine serves on this ladder rung
      (``None`` = any rung). Flush-scoped faults ignore it.
    ``tenant``: only fire for the engine serving this tenant (``None`` =
      any tenant, including the untenanted single-engine stream). A
      tenant-scoped fault never fires for an engine that does not carry
      that tenant name — the bulkhead-isolation contract.
    """

    at: int = 0
    times: Optional[int] = 1
    prob: float = 0.0
    rung: Optional[str] = None
    tenant: Optional[str] = None

    def _in_window(self, count: int) -> bool:
        if count < self.at:
            return False
        return self.times is None or count < self.at + self.times


@dataclasses.dataclass(frozen=True)
class DelayedFlush(Fault):
    """Sleep ``delay_s`` before the flush packs its batch — models a
    stalled flusher/host; requests whose deadline expires during the stall
    must complete with ``DeadlineExceeded``, not block the batch."""

    delay_s: float = 0.05


@dataclasses.dataclass(frozen=True)
class DispatchError(Fault):
    """Raise from inside the dispatch call — a transient launch failure
    (bounded retry-with-backoff is the expected response)."""

    message: str = "injected dispatch failure"


@dataclasses.dataclass(frozen=True)
class StalledDispatch(Fault):
    """Sleep ``stall_s`` inside the dispatch call before it runs — models
    a wedged mesh collective / hung kernel; with ``stall_s`` above the
    engine's dispatch timeout, the watchdog fires and the engine demotes
    one rung instead of hanging."""

    stall_s: float = 0.5


@dataclasses.dataclass(frozen=True)
class NaNActivation(Fault):
    """Corrupt the activations at the boundary after conv stage ``stage``
    with NaNs — models silent data corruption mid-pipeline; the engine's
    output validation must catch the non-finite logits and retry/demote,
    and surviving retries must stay bit-exact."""

    stage: int = 0


@dataclasses.dataclass(frozen=True)
class DeviceLoss(Fault):
    """Raise :class:`InjectedDeviceLoss` from the dispatch call — models
    losing a device of the pipeline mesh. Non-transient: the engine must
    demote off the rung (mesh -> single device) without burning retries."""


@dataclasses.dataclass(frozen=True)
class DispatchEffects:
    """What the fault plan injects into ONE dispatch attempt (applied by
    the engine inside the timed dispatch callable, in this order)."""

    stall_s: float = 0.0
    exc: Optional[BaseException] = None
    corrupt_stage: Optional[int] = None

    @property
    def clean(self) -> bool:
        return not self.stall_s and self.exc is None and self.corrupt_stage is None


class FaultPlan:
    """A deterministic schedule of injected faults.

    ``FaultPlan([DispatchError(at=0, times=2)], seed=0)`` makes the first
    two dispatch attempts raise and every later one run clean — the chaos
    suite asserts a retried batch then completes bit-exact. Thread-safe:
    the engine's flusher thread and callers may consult it concurrently.
    """

    def __init__(self, faults: Sequence[Fault] = (), seed: int = 0):
        for f in faults:
            if not isinstance(f, Fault):
                raise TypeError(f"expected Fault specs, got {f!r}")
        self.faults = tuple(faults)
        self.seed = seed
        self._rng = random.Random(seed)
        self._lock = threading.Lock()
        # Event counters are PER TENANT (key None = the untenanted
        # single-engine stream) so a tenant-scoped window is deterministic
        # regardless of how other tenants' traffic interleaves.
        self._flushes: dict = {}
        self._dispatches: dict = {}

    def _fires(
        self, f: Fault, count: int, rung: Optional[str],
        tenant: Optional[str],
    ) -> bool:
        if f.tenant is not None and f.tenant != tenant:
            return False
        if f.rung is not None and rung is not None and f.rung != rung:
            return False
        if f.prob > 0:
            return self._rng.random() < f.prob
        return f._in_window(count)

    # -- hooks ---------------------------------------------------------------

    def on_flush(self, *, tenant: Optional[str] = None) -> float:
        """Seconds the flush should stall before packing (0 = clean).
        Advances ``tenant``'s flush event counter."""
        with self._lock:
            count = self._flushes.get(tenant, 0)
            self._flushes[tenant] = count + 1
            delay = 0.0
            for f in self.faults:
                if isinstance(f, DelayedFlush) and self._fires(
                    f, count, None, tenant
                ):
                    delay += f.delay_s
            return delay

    def dispatch_effects(
        self, *, rung: Optional[str] = None, tenant: Optional[str] = None
    ) -> DispatchEffects:
        """The effects to apply to ``tenant``'s next dispatch attempt on
        ``rung``. Advances ``tenant``'s dispatch event counter."""
        with self._lock:
            count = self._dispatches.get(tenant, 0)
            self._dispatches[tenant] = count + 1
            stall, exc, corrupt = 0.0, None, None
            for f in self.faults:
                if not self._fires(f, count, rung, tenant):
                    continue
                if isinstance(f, StalledDispatch):
                    stall += f.stall_s
                elif isinstance(f, DispatchError):
                    exc = InjectedDispatchError(
                        f"{f.message} (dispatch #{count}, rung {rung})"
                    )
                elif isinstance(f, DeviceLoss):
                    exc = InjectedDeviceLoss(
                        f"injected device loss (dispatch #{count}, rung {rung})"
                    )
                elif isinstance(f, NaNActivation):
                    corrupt = f.stage
            return DispatchEffects(stall_s=stall, exc=exc, corrupt_stage=corrupt)

    # -- introspection (for tests) -------------------------------------------

    @property
    def n_dispatch_events(self) -> int:
        """Total dispatch events across every tenant stream."""
        with self._lock:
            return sum(self._dispatches.values())

    @property
    def n_flush_events(self) -> int:
        """Total flush events across every tenant stream."""
        with self._lock:
            return sum(self._flushes.values())

    def n_dispatch_events_for(self, tenant: Optional[str]) -> int:
        with self._lock:
            return self._dispatches.get(tenant, 0)

    def n_flush_events_for(self, tenant: Optional[str]) -> int:
        with self._lock:
            return self._flushes.get(tenant, 0)
