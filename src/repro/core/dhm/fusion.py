"""VMEM-budget-aware fusion planner: DPN layers -> fusion groups.

The source paper's direct-hardware-mapping premise is that the whole CNN
graph executes as one on-chip dataflow pipeline — intermediate feature
maps never round-trip through external memory. The per-layer compiled
plan broke that property at every layer boundary (each stage was one
conv layer's kernel call, its output written to and re-read from HBM).
This planner restores it as a *compiler decision*: walk the DPN's conv
layers in order and greedily grow contiguous **fusion groups**, where a
group of layers is streamed through ONE fused pyramid kernel
(``stream_conv_pyramid``) with all inter-layer slabs VMEM-resident.

A candidate group is costed with the composed-halo geometry
(``halo.group_geometry`` + ``halo.working_set_bytes``): per block of
final-output rows, the working set is the resident input frame, every
layer's halo'd input slab, tap operands, conv/pooled slabs, and the
group's weights. The planner picks the largest block size whose working
set fits the budget (whole-frame first, then halving); if even
one-row blocks do not fit — or a shape the pyramid kernel cannot lower
appears — the group stops growing and the layer falls back to today's
single-layer stage (which has its own channel/width blocking). Singleton
groups are therefore always legal: with a zero budget the plan is
exactly the per-layer plan.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

from repro.core.dhm.mapping import partition_greedy_budget
from repro.kernels.stream_conv.halo import (
    as_pyramid_layers,
    group_geometry,
    working_set_bytes,
)

# One TPU core's VMEM is ~16 MiB; leave the kernel's own headroom to the
# Mosaic allocator and plan against the full size (the cost model is
# deliberately conservative: it sums every slab and operand as if they
# were all live at once).
DEFAULT_VMEM_BUDGET = 16 * 1024 * 1024


@dataclasses.dataclass(frozen=True)
class FusionGroup:
    """A contiguous run of conv layers fused into one kernel invocation."""

    layers: tuple  # global conv-layer indices, contiguous
    block_rows: int  # final-output rows per block (0 only for singletons)
    working_set: int  # costed VMEM bytes per block (0 for singletons)

    @property
    def fused(self) -> bool:
        return len(self.layers) > 1


def group_working_set(
    topo, layer_indices: Sequence[int], *, block_rows: int = 0
) -> int:
    """Costed per-block VMEM bytes of fusing ``layer_indices`` (contiguous
    run) of ``topo`` — the quantity the planner compares to its budget.
    Exposed so tests (and users sizing a budget) can read the model."""
    return working_set_bytes(_group_geom(topo, layer_indices, block_rows))


def group_working_set_breakdown(
    topo, layer_indices: Sequence[int], *, block_rows: int = 0
) -> dict:
    """Per-component bytes behind :func:`group_working_set` (see
    ``halo.working_set_breakdown``) — what the plan verifier cites when
    a group's recorded cost and the model disagree."""
    from repro.kernels.stream_conv.halo import working_set_breakdown

    return working_set_breakdown(_group_geom(topo, layer_indices, block_rows))


def _group_geom(topo, layer_indices: Sequence[int], block_rows: int):
    idxs = tuple(layer_indices)
    h, w = topo.input_shape
    for spec in topo.conv_layers[: idxs[0]]:
        h, w = spec.out_hw(h, w)
    c = (
        topo.input_channels
        if idxs[0] == 0
        else topo.conv_layers[idxs[0] - 1].n_out
    )
    specs = [topo.conv_layers[i] for i in idxs]
    return group_geometry(
        h, w, c,
        as_pyramid_layers(specs),
        tuple(s.kernel for s in specs),
        tuple(s.n_out for s in specs),
        block_rows=block_rows,
    )


def _fit_block_rows(topo, idxs, budget: int) -> Optional[tuple]:
    """Largest feasible (block_rows, working_set) for fusing ``idxs``
    under ``budget``: whole-frame first, then halved row blocks down to
    one row. None if nothing fits (or the geometry is unsupported)."""
    h, w = topo.input_shape
    for spec in topo.conv_layers[: idxs[-1] + 1]:
        h, w = spec.out_hw(h, w)
    candidates = []
    r = h  # final output rows of the group
    while r >= 1:
        candidates.append(r)
        if r == 1:
            break
        r = -(-r // 2)
    for r in candidates:
        try:
            ws = group_working_set(topo, idxs, block_rows=r)
        except ValueError:
            return None  # shape the pyramid cannot lower -> no fusion
        if ws <= budget:
            return r, ws
    return None


def plan_fusion_groups(
    topo,
    layer_indices: Sequence[int],
    *,
    vmem_budget: Optional[int] = None,
) -> tuple:
    """Partition a contiguous run of conv layers into maximal fusion
    groups under the VMEM budget.

    Greedy left-to-right: each group is extended while the grown group
    still fits (so groups are maximal), and closed when the next layer
    would blow the budget — that layer starts the next group. Layers that
    cannot fuse at all become singleton groups, which lower through the
    single-layer kernel path (bit-identical to the pre-fusion plan).
    ``vmem_budget=None`` means :data:`DEFAULT_VMEM_BUDGET`; ``0`` turns
    fusion off entirely.
    """
    idxs = tuple(layer_indices)
    if not idxs:
        return ()
    if list(idxs) != list(range(idxs[0], idxs[-1] + 1)):
        raise ValueError(f"fusion groups need contiguous layers, got {idxs}")
    budget = DEFAULT_VMEM_BUDGET if vmem_budget is None else vmem_budget
    if budget < 0:
        raise ValueError(f"vmem_budget must be >= 0, got {budget}")

    fit_cache: dict = {}

    def fit_of(i: int, j: int):
        if (i, j) not in fit_cache:
            fit_cache[(i, j)] = _fit_block_rows(topo, idxs[i:j], budget)
        return fit_cache[(i, j)]

    def fits(i: int, j: int) -> bool:
        if j - i == 1:
            return True  # singletons lower through the single-layer path
        if budget == 0:
            return False
        return fit_of(i, j) is not None

    groups = []
    for i, j in partition_greedy_budget(len(idxs), fits):
        run = idxs[i:j]
        fit = fit_of(i, j) if j - i > 1 else None
        groups.append(
            FusionGroup(
                layers=run,
                block_rows=fit[0] if fit else 0,
                working_set=fit[1] if fit else 0,
            )
        )
    return tuple(groups)
