"""VMEM-budget-aware fusion planner: DPN layers -> fusion groups.

The source paper's direct-hardware-mapping premise is that the whole CNN
graph executes as one on-chip dataflow pipeline — intermediate feature
maps never round-trip through external memory. The per-layer compiled
plan broke that property at every layer boundary (each stage was one
conv layer's kernel call, its output written to and re-read from HBM).
This planner restores it as a *compiler decision*: walk the DPN's conv
layers in order and greedily grow contiguous **fusion groups**, where a
group of layers is streamed through ONE fused pyramid kernel
(``stream_conv_pyramid``) with all inter-layer slabs VMEM-resident.

A candidate group is costed with the composed-halo geometry
(``halo.group_geometry`` + ``halo.working_set_bytes``): per block of
final-output rows, the working set is the resident input frame, every
layer's halo'd input slab, tap operands, conv/pooled slabs, and the
group's weights. The planner picks the largest block size whose working
set fits the budget (whole-frame first, then halving); if even
one-row blocks do not fit — or a shape the pyramid kernel cannot lower
appears — the group stops growing and the layer falls back to today's
single-layer stage (which has its own channel/width blocking). Singleton
groups are therefore always legal: with a zero budget the plan is
exactly the per-layer plan.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

from repro.core.dhm.mapping import partition_greedy_budget
from repro.kernels.stream_conv.halo import (
    as_pyramid_layers,
    group_geometry,
    working_set_bytes,
)

# One TPU core's VMEM is ~16 MiB; leave the kernel's own headroom to the
# Mosaic allocator and plan against the full size (the cost model is
# deliberately conservative: it sums every slab and operand as if they
# were all live at once).
DEFAULT_VMEM_BUDGET = 16 * 1024 * 1024


@dataclasses.dataclass(frozen=True)
class FusionGroup:
    """A contiguous run of conv layers fused into one kernel invocation."""

    layers: tuple  # global conv-layer indices, contiguous
    block_rows: int  # final-output rows per block (0 only for singletons)
    working_set: int  # costed VMEM bytes per block (0 for singletons)

    @property
    def fused(self) -> bool:
        return len(self.layers) > 1


def plan_elem_bytes(quant) -> int:
    """Streamed-slab byte width of a plan's quantization regime: 1 when
    the plan computes in true int8 (frames, inter-layer slabs, tap
    operands and weight codes really are int8 in VMEM), else 4 (the
    fp32/fake-quant paths, where the quantized stream is a rounding
    contract, not a storage format). Accepts any ``QuantSpec``-shaped
    object (or None)."""
    return 1 if getattr(quant, "int8_compute", False) else 4


def group_working_set(
    topo, layer_indices: Sequence[int], *, block_rows: int = 0,
    elem_bytes: int = 4,
) -> int:
    """Costed per-block VMEM bytes of fusing ``layer_indices`` (contiguous
    run) of ``topo`` — the quantity the planner compares to its budget.
    Exposed so tests (and users sizing a budget) can read the model.
    ``elem_bytes`` is the streamed-slab width (see :func:`plan_elem_bytes`);
    accumulators are always charged at 4 bytes (int32/fp32 epilogue)."""
    return working_set_bytes(
        _group_geom(topo, layer_indices, block_rows),
        elem_bytes=elem_bytes, acc_bytes=4,
    )


def group_working_set_breakdown(
    topo, layer_indices: Sequence[int], *, block_rows: int = 0,
    elem_bytes: int = 4,
) -> dict:
    """Per-component bytes behind :func:`group_working_set` (see
    ``halo.working_set_breakdown``) — what the plan verifier cites when
    a group's recorded cost and the model disagree."""
    from repro.kernels.stream_conv.halo import working_set_breakdown

    return working_set_breakdown(
        _group_geom(topo, layer_indices, block_rows),
        elem_bytes=elem_bytes, acc_bytes=4,
    )


def _group_geom(topo, layer_indices: Sequence[int], block_rows: int):
    idxs = tuple(layer_indices)
    h, w = topo.input_shape
    for spec in topo.conv_layers[: idxs[0]]:
        h, w = spec.out_hw(h, w)
    c = (
        topo.input_channels
        if idxs[0] == 0
        else topo.conv_layers[idxs[0] - 1].n_out
    )
    specs = [topo.conv_layers[i] for i in idxs]
    return group_geometry(
        h, w, c,
        as_pyramid_layers(specs),
        tuple(s.kernel for s in specs),
        tuple(s.n_out for s in specs),
        block_rows=block_rows,
    )


def _block_row_candidates(topo, idxs) -> list:
    """The planner's block-size ladder for a group: whole frame first,
    then halved row blocks down to one row."""
    h, w = topo.input_shape
    for spec in topo.conv_layers[: idxs[-1] + 1]:
        h, w = spec.out_hw(h, w)
    candidates = []
    r = h  # final output rows of the group
    while r >= 1:
        candidates.append(r)
        if r == 1:
            break
        r = -(-r // 2)
    return candidates


def _fit_block_rows(
    topo, idxs, budget: int, elem_bytes: int = 4
) -> Optional[tuple]:
    """Largest feasible (block_rows, working_set) for fusing ``idxs``
    under ``budget``: whole-frame first, then halved row blocks down to
    one row. None if nothing fits (or the geometry is unsupported)."""
    for r in _block_row_candidates(topo, idxs):
        try:
            ws = group_working_set(
                topo, idxs, block_rows=r, elem_bytes=elem_bytes
            )
        except ValueError:
            return None  # shape the pyramid cannot lower -> no fusion
        if ws <= budget:
            return r, ws
    return None


def widening_budget(topo, layer_indices: Sequence[int]) -> Optional[dict]:
    """The structural int8-widens-fusion probe: the largest budget at
    which NO fp32-costed block size can fuse the whole run, paired with
    what each costing plans there. Returns ``{"budget", "fp32_max_group",
    "int8_max_group", "n_layers"}`` — int8 widening is demonstrated when
    ``int8_max_group > fp32_max_group`` — or None when even the probe
    budget cannot separate the two costings (e.g. a single-layer run).
    """
    idxs = tuple(layer_indices)
    if len(idxs) < 2:
        return None
    costs = []
    for r in _block_row_candidates(topo, idxs):
        try:
            costs.append(group_working_set(topo, idxs, block_rows=r))
        except ValueError:
            continue
    if not costs:
        return None
    budget = min(costs) - 1  # fp32 cannot fuse the full run at any block
    plans = {
        eb: plan_fusion_groups(
            topo, idxs, vmem_budget=budget, elem_bytes=eb
        )
        for eb in (4, 1)
    }
    return {
        "budget": budget,
        "fp32_max_group": max(len(g.layers) for g in plans[4]),
        "int8_max_group": max(len(g.layers) for g in plans[1]),
        "n_layers": len(idxs),
    }


def plan_fusion_groups(
    topo,
    layer_indices: Sequence[int],
    *,
    vmem_budget: Optional[int] = None,
    elem_bytes: int = 4,
) -> tuple:
    """Partition a contiguous run of conv layers into maximal fusion
    groups under the VMEM budget.

    Greedy left-to-right: each group is extended while the grown group
    still fits (so groups are maximal), and closed when the next layer
    would blow the budget — that layer starts the next group. Layers that
    cannot fuse at all become singleton groups, which lower through the
    single-layer kernel path (bit-identical to the pre-fusion plan).
    ``vmem_budget=None`` means :data:`DEFAULT_VMEM_BUDGET`; ``0`` turns
    fusion off entirely. ``elem_bytes`` is the streamed-slab byte width
    the costing charges (``plan_elem_bytes(quant)`` — 1 for true-int8
    plans, whose slabs really occupy a quarter of the fp32 bytes, so the
    same budget admits strictly wider groups).
    """
    idxs = tuple(layer_indices)
    if not idxs:
        return ()
    if list(idxs) != list(range(idxs[0], idxs[-1] + 1)):
        raise ValueError(f"fusion groups need contiguous layers, got {idxs}")
    budget = DEFAULT_VMEM_BUDGET if vmem_budget is None else vmem_budget
    if budget < 0:
        raise ValueError(f"vmem_budget must be >= 0, got {budget}")

    fit_cache: dict = {}

    def fit_of(i: int, j: int):
        if (i, j) not in fit_cache:
            fit_cache[(i, j)] = _fit_block_rows(
                topo, idxs[i:j], budget, elem_bytes
            )
        return fit_cache[(i, j)]

    def fits(i: int, j: int) -> bool:
        if j - i == 1:
            return True  # singletons lower through the single-layer path
        if budget == 0:
            return False
        return fit_of(i, j) is not None

    groups = []
    for i, j in partition_greedy_budget(len(idxs), fits):
        run = idxs[i:j]
        fit = fit_of(i, j) if j - i > 1 else None
        groups.append(
            FusionGroup(
                layers=run,
                block_rows=fit[0] if fit else 0,
                working_set=fit[1] if fit else 0,
            )
        )
    return tuple(groups)
