"""Dataflow-process-network IR (paper §4, Figs. 1-2).

A model is a :class:`DataflowGraph` of :class:`Actor` nodes connected by
unidirectional stream edges. The granularity follows the paper exactly:

- one **conv engine** per (output-map n, input-channel c) pair: K*K
  multipliers + one adder-tree actor + a (K-1)-line line buffer;
- one **neuron sum** actor per output map (sums C engine outputs + bias);
- one **activation** actor per output map;
- one **pool** actor per output map.

For the Fig. 2 example (C=3, N=5, K=3) this yields 15 conv engines
(135 multipliers, 15 adder trees), 5 neuron adders and 5 activations —
matching the paper's count of "135 multiplications, 20 sums and 5
activations".

The same IR carries transformer layer graphs (one actor per layer-block) for
the TPU spatial mapper — there the per-actor payload is FLOPs/bytes rather
than multiplier counts.
"""
from __future__ import annotations

import dataclasses
import enum
from typing import Mapping, Sequence


class ActorKind(enum.Enum):
    SOURCE = "source"
    WINDOW = "window"  # (K-1)-line sliding-window buffer, one per input stream
    CONV_ENGINE = "conv_engine"  # K*K multipliers + adder tree
    NEURON_SUM = "neuron_sum"  # sums C conv-engine streams + bias
    ACTIVATION = "activation"
    POOL = "pool"
    DENSE = "dense"
    BLOCK = "block"  # coarse-grain actor (transformer layer etc.)
    SINK = "sink"


@dataclasses.dataclass(frozen=True)
class Actor:
    name: str
    kind: ActorKind
    # Hardware payload (paper granularity):
    multipliers: int = 0  # constant-coefficient multipliers inside
    adders: int = 0  # adder actors inside (tree counted as 1 per engine)
    line_buffer_bits: int = 0  # (K-1) lines x line_width x bits
    # Workload payload (TPU granularity):
    flops: float = 0.0  # per processed frame/token-batch
    param_bytes: float = 0.0
    stream_bytes: float = 0.0  # output stream per frame/token-batch
    layer: int = -1  # topological layer index (stage partitioning)


@dataclasses.dataclass
class DataflowGraph:
    name: str
    actors: list
    edges: list  # (producer_name, consumer_name)

    def actor(self, name: str) -> Actor:
        for a in self.actors:
            if a.name == name:
                return a
        raise KeyError(name)

    def count(self, kind: ActorKind) -> int:
        return sum(1 for a in self.actors if a.kind == kind)

    def total_multipliers(self) -> int:
        return sum(a.multipliers for a in self.actors)

    def total_adders(self) -> int:
        return sum(a.adders for a in self.actors)

    def total_line_buffer_bits(self) -> int:
        return sum(a.line_buffer_bits for a in self.actors)

    def total_flops(self) -> float:
        return sum(a.flops for a in self.actors)

    def layers(self) -> list:
        """Actors grouped by topological layer index."""
        by_layer: dict = {}
        for a in self.actors:
            by_layer.setdefault(a.layer, []).append(a)
        return [by_layer[k] for k in sorted(by_layer)]

    def layer_payloads(self) -> list:
        """Aggregate actor payloads per topological layer: one dict of
        {flops, param_bytes, stream_bytes, stream_bytes_by_kind,
        line_buffer_bits, multipliers} per layer. The benchmarks use this
        to report what cross-layer fusion keeps on-chip: a conv layer's
        *boundary* stream (the frame its terminal pool — or activation,
        when unpooled — actors emit) is exactly the inter-layer traffic
        that no longer crosses external memory once the layer fuses with
        its consumer."""
        by_layer: dict = {}
        for a in self.actors:
            d = by_layer.setdefault(
                a.layer,
                {
                    "flops": 0.0,
                    "param_bytes": 0.0,
                    "stream_bytes": 0.0,
                    "stream_bytes_by_kind": {},
                    "line_buffer_bits": 0,
                    "multipliers": 0,
                },
            )
            d["flops"] += a.flops
            d["param_bytes"] += a.param_bytes
            d["stream_bytes"] += a.stream_bytes
            by_kind = d["stream_bytes_by_kind"]
            by_kind[a.kind.value] = by_kind.get(a.kind.value, 0.0) + a.stream_bytes
            d["line_buffer_bits"] += a.line_buffer_bits
            d["multipliers"] += a.multipliers
        return [by_layer[k] for k in sorted(by_layer)]

    def boundary_stream_bytes(self, layer: int) -> float:
        """Bytes/frame of the named topological layer's output stream —
        the pool actors' streams when the layer pools, else the
        activation actors' (the frame handed to the next layer)."""
        by_kind = self.layer_payloads()[layer]["stream_bytes_by_kind"]
        if ActorKind.POOL.value in by_kind:
            return by_kind[ActorKind.POOL.value]
        return by_kind.get(ActorKind.ACTIVATION.value, 0.0)

    def validate(self) -> None:
        names = {a.name for a in self.actors}
        if len(names) != len(self.actors):
            raise ValueError(f"duplicate actor names in {self.name}")
        for p, c in self.edges:
            if p not in names or c not in names:
                raise ValueError(f"edge ({p},{c}) references unknown actor")


def cnn_to_dpn(topo, *, bits: int) -> DataflowGraph:
    """Expand a CNN topology into the paper's actor graph (Figs. 1-2).

    ``bits`` is the fixed-point width: it sizes line buffers and stream
    widths. Only the feature extractor is expanded (the paper maps the
    feature extractor; Table 4 footnote).
    """
    actors: list = [Actor(name="input", kind=ActorKind.SOURCE, layer=0)]
    edges: list = []
    prev_outputs = ["input"]
    layer_idx = 0
    h_in, w_in = topo.input_shape
    for li, (c_in, n_out, k, h_out, w_out) in enumerate(topo.conv_shapes()):
        spec = topo.conv_layers[li]
        layer_idx += 1
        acc_bits = 2 * bits + _ceil_log2(k * k * max(1, c_in))
        # The sliding-window buffer holds (K-1) *input* lines: with SAME
        # stride-1 convs (the paper nets) the input and conv-output widths
        # coincide, but strided/VALID layers must buffer the wider input
        # frame, not the conv output.
        line_w = w_in
        # One sliding-window line buffer per *input stream*, shared by all N
        # engines that read it ([10]; this is why the paper's memory
        # footprint stays tiny).
        window_names = []
        for c in range(c_in):
            wname = f"win{li + 1}_c{c}"
            actors.append(
                Actor(
                    name=wname,
                    kind=ActorKind.WINDOW,
                    line_buffer_bits=(k - 1) * line_w * bits,
                    stream_bytes=h_out * w_out * bits / 8.0,
                    layer=layer_idx,
                )
            )
            edges.append((prev_outputs[c % len(prev_outputs)], wname))
            window_names.append(wname)
        neuron_names = []
        for n in range(n_out):
            engine_outs = []
            for c in range(c_in):
                name = f"conv{li + 1}_n{n}_c{c}"
                actors.append(
                    Actor(
                        name=name,
                        kind=ActorKind.CONV_ENGINE,
                        multipliers=k * k,
                        adders=1,  # the engine's adder tree, paper-counted as 1
                        flops=2.0 * k * k * h_out * w_out,
                        param_bytes=k * k * bits / 8.0,
                        stream_bytes=h_out * w_out * acc_bits / 8.0,
                        layer=layer_idx,
                    )
                )
                edges.append((window_names[c], name))
                engine_outs.append(name)
            sum_name = f"sum{li + 1}_n{n}"
            actors.append(
                Actor(
                    name=sum_name,
                    kind=ActorKind.NEURON_SUM,
                    adders=1,
                    flops=2.0 * c_in * h_out * w_out,
                    stream_bytes=h_out * w_out * acc_bits / 8.0,
                    layer=layer_idx,
                )
            )
            for e in engine_outs:
                edges.append((e, sum_name))
            act_name = f"act{li + 1}_n{n}"
            actors.append(
                Actor(
                    name=act_name,
                    kind=ActorKind.ACTIVATION,
                    flops=1.0 * h_out * w_out,
                    stream_bytes=h_out * w_out * bits / 8.0,
                    layer=layer_idx,
                )
            )
            edges.append((sum_name, act_name))
            out_name = act_name
            pw, ps = spec.pool_cfg
            if pw:
                pool_name = f"pool{li + 1}_n{n}"
                # VALID sliding-window output dims: window pw, stride ps
                # (NOT h_out // window — that silently mis-shapes every
                # overlapping pool). The streaming pool buffers (pw - 1)
                # conv-output lines regardless of stride.
                h_p = (h_out - pw) // ps + 1
                w_p = (w_out - pw) // ps + 1
                actors.append(
                    Actor(
                        name=pool_name,
                        kind=ActorKind.POOL,
                        flops=1.0 * pw * pw * h_p * w_p,
                        line_buffer_bits=(pw - 1) * w_out * bits,
                        stream_bytes=h_p * w_p * bits / 8.0,
                        layer=layer_idx,
                    )
                )
                edges.append((act_name, pool_name))
                out_name = pool_name
            neuron_names.append(out_name)
        prev_outputs = neuron_names
        h_in, w_in = spec.out_hw(h_in, w_in)
    actors.append(
        Actor(name="output", kind=ActorKind.SINK, layer=layer_idx + 1)
    )
    for p in prev_outputs:
        edges.append((p, "output"))
    g = DataflowGraph(name=topo.name, actors=actors, edges=edges)
    g.validate()
    return g


def layer_costs_to_dpn(
    name: str, layer_costs: Sequence[Mapping[str, float]]
) -> DataflowGraph:
    """Coarse-grain DPN for the TPU spatial mapper: one BLOCK actor per
    layer, payloads = {'flops', 'param_bytes', 'stream_bytes'}."""
    actors = [Actor(name="input", kind=ActorKind.SOURCE, layer=0)]
    edges = []
    prev = "input"
    for i, cost in enumerate(layer_costs):
        nm = f"layer{i}"
        actors.append(
            Actor(
                name=nm,
                kind=ActorKind.BLOCK,
                flops=float(cost.get("flops", 0.0)),
                param_bytes=float(cost.get("param_bytes", 0.0)),
                stream_bytes=float(cost.get("stream_bytes", 0.0)),
                layer=i + 1,
            )
        )
        edges.append((prev, nm))
        prev = nm
    actors.append(Actor(name="output", kind=ActorKind.SINK, layer=len(layer_costs) + 1))
    edges.append((prev, "output"))
    g = DataflowGraph(name=name, actors=actors, edges=edges)
    g.validate()
    return g


def _ceil_log2(x: int) -> int:
    n = 0
    v = 1
    while v < x:
        v *= 2
        n += 1
    return n
