"""Spatial mapping of a DPN onto a TPU mesh — the DHM act itself.

On the FPGA every actor gets private silicon and throughput is set by the
clock. On a TPU mesh the analogue is: partition the (topologically ordered)
layer graph into S contiguous *stages*, assign each stage a private mesh
sub-slice, and stream µbatches through the stages. Steady-state throughput
is set by the slowest stage (the "critical actor"), so the mapper solves the
classic linear-partition problem: minimize max stage cost.

Exact DP (O(L^2 * S)) — L is layer count (<=100 here), so exactness is free.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence


@dataclasses.dataclass(frozen=True)
class StageAssignment:
    """Contiguous stage partition: stage s owns layers
    [boundaries[s], boundaries[s+1])."""

    n_layers: int
    boundaries: tuple  # len = n_stages + 1; [0, ..., n_layers]
    stage_costs: tuple

    @property
    def n_stages(self) -> int:
        return len(self.boundaries) - 1

    @property
    def bottleneck(self) -> float:
        return max(self.stage_costs)

    def stage_of_layer(self, layer: int) -> int:
        for s in range(self.n_stages):
            if self.boundaries[s] <= layer < self.boundaries[s + 1]:
                return s
        raise ValueError(f"layer {layer} out of range")

    def layers_of_stage(self, stage: int):
        return range(self.boundaries[stage], self.boundaries[stage + 1])


def partition_stages(costs: Sequence[float], n_stages: int) -> StageAssignment:
    """Optimal contiguous partition of per-layer costs into n_stages,
    minimizing the max per-stage cost (dynamic programming)."""
    L = len(costs)
    if n_stages < 1:
        raise ValueError("n_stages must be >= 1")
    if n_stages > L:
        raise ValueError(f"more stages ({n_stages}) than layers ({L})")
    prefix = [0.0]
    for c in costs:
        prefix.append(prefix[-1] + float(c))

    def seg(i: int, j: int) -> float:  # cost of layers [i, j)
        return prefix[j] - prefix[i]

    INF = float("inf")
    # dp[s][j] = best bottleneck using s stages for first j layers
    dp = [[INF] * (L + 1) for _ in range(n_stages + 1)]
    cut = [[0] * (L + 1) for _ in range(n_stages + 1)]
    dp[0][0] = 0.0
    for s in range(1, n_stages + 1):
        for j in range(s, L + 1):
            # last stage covers [i, j)
            for i in range(s - 1, j):
                cand = max(dp[s - 1][i], seg(i, j))
                if cand < dp[s][j]:
                    dp[s][j] = cand
                    cut[s][j] = i
    bounds = [L]
    j = L
    for s in range(n_stages, 0, -1):
        j = cut[s][j]
        bounds.append(j)
    bounds.reverse()
    stage_costs = tuple(
        seg(bounds[s], bounds[s + 1]) for s in range(n_stages)
    )
    return StageAssignment(
        n_layers=L, boundaries=tuple(bounds), stage_costs=stage_costs
    )


def partition_greedy_budget(n: int, fits) -> tuple:
    """Maximal contiguous left-to-right partition of ``n`` layers under a
    hard per-run feasibility bound.

    ``fits(i, j)`` says whether the run [i, j) is feasible as one group.
    Each run is grown while feasible and closed at the first infeasible
    extension; singleton runs are always allowed (they fall back to the
    caller's per-layer path). This is the dual of ``partition_stages``:
    maximal groups under a hard bound (the fusion planner's VMEM budget)
    instead of balanced groups minimizing the max cost. Greedy is optimal
    for "fewest groups" here because feasibility is monotone in the run
    length (a sub-run of a feasible run is feasible).

    Returns a tuple of (start, end) half-open index pairs covering
    [0, n).
    """
    if n < 0:
        raise ValueError(f"n must be >= 0, got {n}")
    runs = []
    i = 0
    while i < n:
        j = i + 1
        while j < n and fits(i, j + 1):
            j += 1
        runs.append((i, j))
        i = j
    return tuple(runs)


@dataclasses.dataclass(frozen=True)
class BalanceReport:
    assignment: StageAssignment
    n_microbatches: int

    @property
    def perfect_stage_cost(self) -> float:
        return sum(self.assignment.stage_costs) / self.assignment.n_stages

    @property
    def imbalance(self) -> float:
        """bottleneck / perfect (1.0 = perfectly balanced)."""
        return self.assignment.bottleneck / max(1e-12, self.perfect_stage_cost)

    @property
    def bubble_fraction(self) -> float:
        """GPipe fill/drain bubble: (S-1) / (m + S - 1)."""
        s = self.assignment.n_stages
        return (s - 1) / (self.n_microbatches + s - 1)

    @property
    def pipeline_efficiency(self) -> float:
        """Fraction of ideal (all-devices-busy) throughput achieved."""
        return (1.0 - self.bubble_fraction) / self.imbalance


def balance_report(
    costs: Sequence[float], n_stages: int, n_microbatches: int
) -> BalanceReport:
    return BalanceReport(
        assignment=partition_stages(costs, n_stages),
        n_microbatches=n_microbatches,
    )
