"""Multi-tenant serving: N compiled plans resident behind one router.

The paper's DHM thesis is per-actor hardware ownership — independent
workloads never contend for a shared compute engine. This module extends
that isolation guarantee from the layer graph to the *serving* layer:
each tenant (a named :class:`~repro.core.dhm.engine.Engine` around one
compiled plan) owns its queue, admission policy, deadlines, degradation
ladder, watchdog, and retry budget, and a single :class:`Router`
schedules flushes across them.

Three mechanisms make the bulkheads real:

- **Weighted-fair scheduling** — a deficit-round-robin loop over the
  tenants, with per-group cost priced from the plan's analytic workload
  (:func:`~repro.core.dhm.throughput.pipeline_workload`): a heavy tenant
  (big model, big micro-batch) burns its deficit faster and cannot
  starve a light one. A tenant whose earliest queued deadline is about
  to expire is dispatched immediately (its deficit goes negative — the
  debt is repaid in later rounds, so long-run fairness holds).
- **Per-tenant circuit breakers** — ``K`` consecutive failed flushes
  (request failures or ladder demotions: the BatchFailed / watchdog-
  timeout signal) open the tenant's breaker: its queue is completed with
  :class:`CircuitOpen` and new submits fail fast, so a faulting tenant
  consumes no scheduler turns. After ``breaker_reset_s`` the breaker
  goes half-open and one probe runs: the PR-8 plan-scope health check
  (``verify_plan(plan, scopes=("plan",))``) plus one real warmup
  dispatch; success closes the breaker, failure re-opens it.
- **Verified hot plan swap** — :meth:`Router.swap` admits a replacement
  plan only after it passes ``verify_plan`` plan+structure scopes, a
  compatibility check (same frame geometry and logits width, abstractly
  traced), and a shadow warmup dispatch (the new engine's rung probe —
  it never touches live traffic). The switch is atomic with zero
  dropped in-flight requests: submissions quiesce, the old engine
  drains (pre-swap requests resolve bit-exact through the OLD plan),
  then the tenant's engine reference flips. The old engine is retained
  for one-call :meth:`Router.rollback`.

Chaos testing: give the router a
:class:`~repro.core.dhm.faults.FaultPlan` whose faults carry
``tenant="A"`` — only tenant A's engine sees them, and the suite asserts
tenant B's error rate and p99 stay inside its bulkhead.
"""
from __future__ import annotations

import dataclasses
import logging
import math
import threading
import time
from typing import Callable, Dict, Optional

import numpy as np

from repro.core.dhm.engine import (
    Engine,
    EngineStats,
    FlusherWedged,
    Rejected,
    Request,
    Shed,
)
from repro.core.dhm.faults import FaultPlan

_LOG = logging.getLogger("repro.dhm.multitenant")


class CircuitOpen(Rejected):
    """The tenant's circuit breaker is open — the request fails fast
    without touching the queue (counted as a rejection in the tenant's
    stats). The breaker half-opens after its reset window and closes
    again once a probe dispatch succeeds."""


class SwapRejected(RuntimeError):
    """:meth:`Router.swap` refused the replacement plan — verification
    findings, an incompatible serving surface, or a failed shadow warmup.
    The old plan is still serving; nothing changed. ``invariants`` lists
    the failed registry IDs when verification rejected the plan."""

    def __init__(self, message: str, invariants=()):
        super().__init__(message)
        self.invariants = tuple(invariants)


class UnknownTenant(KeyError):
    """No tenant registered under that name."""


# Breaker states.
CLOSED, OPEN, HALF_OPEN = "closed", "open", "half_open"


@dataclasses.dataclass
class CircuitBreaker:
    """Per-tenant breaker state (mutated only under the tenant's lock).

    ``closed`` -> (K consecutive failed flushes) -> ``open`` ->
    (reset window elapses) -> ``half_open`` -> probe ok -> ``closed``
    / probe fails -> ``open`` again.
    """

    threshold: int
    reset_s: float
    state: str = CLOSED
    consecutive_failures: int = 0
    opened_at: float = 0.0  # time.monotonic() of the last open
    n_opens: int = 0
    n_probes: int = 0

    def record_failure(self) -> bool:
        """Count one failed flush; returns True when this failure opens
        the breaker."""
        self.consecutive_failures += 1
        if self.state == CLOSED and (
            self.consecutive_failures >= self.threshold
        ):
            self.trip()
            return True
        return False

    def record_success(self) -> None:
        self.consecutive_failures = 0

    def trip(self) -> None:
        self.state = OPEN
        self.opened_at = time.monotonic()
        self.n_opens += 1

    def close(self) -> None:
        self.state = CLOSED
        self.consecutive_failures = 0

    @property
    def due_for_probe(self) -> bool:
        return (
            self.state == OPEN
            and time.monotonic() - self.opened_at >= self.reset_s
        )


class _TenantState:
    """Router-internal per-tenant record: the live engine, its DRR
    accounting, breaker, and the swap/rollback bookkeeping."""

    def __init__(self, name: str, plan, engine: Engine, weight: float,
                 breaker: CircuitBreaker):
        self.name = name
        self.plan = plan
        self.engine = engine
        self.weight = weight
        self.breaker = breaker
        self.deficit = 0.0
        self.group_cost = _group_cost(plan, engine)
        # ``lock``/``cv`` guard the engine *reference*, breaker state and
        # the swap protocol; they are never held across a dispatch.
        self.lock = threading.RLock()
        self.cv = threading.Condition(self.lock)
        self.swapping = False  # a swap is quiescing/switching this tenant
        self.inflight_submits = 0  # submits holding the engine reference
        self.previous = None  # (plan, engine) retained for rollback
        self.n_swaps = 0


def _group_cost(plan, engine: Engine) -> float:
    """Analytic cost of ONE jitted-closure invocation for this tenant —
    the DRR billing unit. Priced from the plan's per-stage FLOP workload
    (:func:`pipeline_workload`); falls back to frame count when a plan
    carries no stage geometry, so scheduling still works."""
    try:
        from repro.core.dhm.throughput import pipeline_workload

        stage_flops, _ = pipeline_workload(plan)
        per_frame = float(sum(stage_flops))
    except Exception:  # noqa: BLE001 — cost model is advisory
        per_frame = 1.0
    return max(per_frame, 1.0) * engine.group


class Router:
    """N resident tenants behind one weighted-fair scheduler.

    ``router.add("mnist", plan)`` registers a tenant (its own
    :class:`Engine`, queue, SLOs and failure domain); ``router.submit
    ("mnist", x, deadline_ms=...)`` routes a request; a background
    scheduler thread (started by :meth:`start` / the context manager)
    flushes tenants by deficit round-robin. See the module docstring for
    the isolation, breaker, and hot-swap semantics.

    Scheduler/engine knob defaults passed at construction apply to every
    ``add()`` unless overridden per tenant.
    """

    def __init__(
        self,
        *,
        quantum: Optional[float] = None,
        breaker_threshold: int = 3,
        breaker_reset_s: float = 0.25,
        scheduler_interval_ms: float = 2.0,
        deadline_margin_ms: float = 2.0,
        fault_plan: Optional[FaultPlan] = None,
        join_timeout_s: float = 30.0,
        **engine_defaults,
    ):
        if breaker_threshold < 1:
            raise ValueError("breaker_threshold must be >= 1")
        self.breaker_threshold = breaker_threshold
        self.breaker_reset_s = breaker_reset_s
        self.scheduler_interval_ms = scheduler_interval_ms
        self.deadline_margin_ms = deadline_margin_ms
        self.join_timeout_s = join_timeout_s
        self._fault_plan = fault_plan
        self._engine_defaults = dict(engine_defaults)
        self._quantum_cfg = quantum
        self._quantum = quantum or 1.0
        self._tenants: Dict[str, _TenantState] = {}
        self._lock = threading.RLock()  # guards the tenant table
        self._sched_cv = threading.Condition(threading.Lock())
        self._sched_pending = False  # wake arrived while a round was running
        self._scheduler: Optional[threading.Thread] = None
        self._stop_evt = threading.Event()

    # -- tenant table --------------------------------------------------------

    def add(
        self, name: str, plan, *, weight: float = 1.0, **engine_kwargs
    ) -> Engine:
        """Register a tenant: compile-free — the plan is already
        compiled; building the tenant's :class:`Engine` runs its rung
        warmup probe. ``weight`` scales the tenant's share of scheduler
        bandwidth. Engine knobs (``microbatch``, ``max_queue``,
        ``admission``, ``dispatch_timeout_s``, ...) override the
        router-wide defaults."""
        if not name or not isinstance(name, str):
            raise ValueError(f"tenant name must be a non-empty str, got {name!r}")
        if weight <= 0:
            raise ValueError(f"tenant weight must be > 0, got {weight}")
        with self._lock:
            if name in self._tenants:
                raise ValueError(f"tenant {name!r} already registered")
        engine = self._build_engine(name, plan, engine_kwargs)
        ts = _TenantState(
            name, plan, engine, weight,
            CircuitBreaker(self.breaker_threshold, self.breaker_reset_s),
        )
        with self._lock:
            if name in self._tenants:  # lost a registration race
                raise ValueError(f"tenant {name!r} already registered")
            self._tenants[name] = ts
            self._recompute_quantum()
        self._wake()
        return engine

    def remove(self, name: str) -> None:
        """Deregister a tenant; its still-queued requests complete with a
        structured :class:`Shed` error (never silently dropped)."""
        ts = self._state(name)
        with self._lock:
            self._tenants.pop(name, None)
            self._recompute_quantum()
        with ts.cv:
            eng = ts.engine
        eng._external_flusher = None
        eng._shed_all(f"tenant {name!r} removed from the router")

    @property
    def tenants(self) -> tuple:
        with self._lock:
            return tuple(self._tenants)

    def engine(self, name: str) -> Engine:
        """The tenant's live engine (reference valid until the next
        swap/rollback)."""
        ts = self._state(name)
        with ts.cv:
            return ts.engine

    def _state(self, name: str) -> _TenantState:
        with self._lock:
            ts = self._tenants.get(name)
        if ts is None:
            raise UnknownTenant(name)
        return ts

    def _build_engine(self, name: str, plan, overrides: dict) -> Engine:
        kwargs = dict(self._engine_defaults)
        kwargs.update(overrides)
        if kwargs.pop("auto_flush", False):
            raise ValueError(
                "router tenants must not run their own flusher "
                "(auto_flush=True); the router's scheduler flushes them"
            )
        kwargs.setdefault("fault_plan", self._fault_plan)
        engine = Engine(plan, name=name, auto_flush=False, **kwargs)
        engine._external_flusher = self._scheduler_alive
        return engine

    def _recompute_quantum(self) -> None:
        # One DRR round must let a weight-1 tenant afford at least one
        # group of the costliest tenant, else heavy tenants starve.
        if self._quantum_cfg is not None:
            return
        costs = [ts.group_cost for ts in self._tenants.values()]
        self._quantum = max(costs) if costs else 1.0

    # -- request path --------------------------------------------------------

    def submit(
        self, tenant: str, x, *, deadline_ms: Optional[float] = None
    ) -> Request:
        """Route one request to ``tenant``. Same contract as
        :meth:`Engine.submit` (structured errors, never hangs), plus:
        while the tenant's breaker is open the request fails fast with
        :class:`CircuitOpen`, and a submit racing a hot swap parks until
        the switch completes (microseconds — the drain happens before
        submissions are blocked out)."""
        ts = self._state(tenant)
        with ts.cv:
            while ts.swapping:
                ts.cv.wait(timeout=1.0)
            eng = ts.engine
            if ts.breaker.state != CLOSED:
                req = eng._new_request(x, deadline_ms=deadline_ms)
                if not req.done:
                    eng._fail(
                        req,
                        CircuitOpen(
                            f"request {req.index}: tenant {tenant!r} circuit "
                            f"breaker {ts.breaker.state} "
                            f"({ts.breaker.consecutive_failures} consecutive "
                            "failed flushes) — retry after the reset window"
                        ),
                    )
                return req
            ts.inflight_submits += 1
        # Enqueue OUTSIDE the tenant lock: a block-policy submit may park
        # until the scheduler drains, and the scheduler takes ts.cv for
        # its turn — holding it here would deadlock.
        try:
            req = eng.submit(x, deadline_ms=deadline_ms)
        finally:
            with ts.cv:
                ts.inflight_submits -= 1
                ts.cv.notify_all()
        self._wake()
        return req

    def infer(self, tenant: str, x, *, deadline_ms: Optional[float] = None):
        """Convenience: submit + result (the scheduler flushes)."""
        return self.submit(tenant, x, deadline_ms=deadline_ms).result()

    # -- circuit breaker -----------------------------------------------------

    def breaker(self, name: str) -> CircuitBreaker:
        """A snapshot of the tenant's breaker."""
        ts = self._state(name)
        with ts.cv:
            return dataclasses.replace(ts.breaker)

    def _shed_queue(self, eng: Engine, why: str) -> int:
        """Complete every request queued on ``eng`` with
        :class:`CircuitOpen` (counted as rejections)."""
        with eng._cv:
            pending, eng._queue = eng._queue, []
            eng._queue_frames = 0
            eng._cv.notify_all()
        for req in pending:
            eng._fail(req, CircuitOpen(f"request {req.index}: {why}"))
        return len(pending)

    def _observe_flush(self, ts: _TenantState, eng: Engine,
                       failed0: int, demoted0: int, ok0: int) -> None:
        """Feed one flush's counter deltas to the tenant's breaker. A
        flush counts as a failure when it failed requests (BatchFailed
        path) or demoted a rung (watchdog timeout / device loss path);
        failure takes precedence over same-flush successes."""
        with eng._lock:
            d_failed = eng._n_failed - failed0
            d_demoted = len(eng.demotions) - demoted0
            d_ok = eng._n_ok - ok0
        with ts.cv:
            if d_failed > 0 or d_demoted > 0:
                if ts.breaker.record_failure():
                    shed = self._shed_queue(
                        eng,
                        f"tenant {ts.name!r} circuit breaker opened after "
                        f"{ts.breaker.consecutive_failures} consecutive "
                        "failed flushes",
                    )
                    _LOG.warning(
                        "tenant %r breaker OPEN (%d queued requests "
                        "completed with CircuitOpen)", ts.name, shed,
                    )
            elif d_ok > 0:
                ts.breaker.record_success()

    def _probe_tenant(self, ts: _TenantState, eng: Engine) -> bool:
        """The half-open probe: the PR-8 plan-scope registry check plus
        one real (zero-frame) dispatch through the tenant's active rung.
        Never touches queued traffic."""
        try:
            from repro.analysis.verify import verify_plan

            findings = verify_plan(ts.plan, scopes=("plan",))
            if any(f.severity == "error" for f in findings):
                _LOG.warning(
                    "tenant %r probe failed plan-scope verification: %s",
                    ts.name, [f.rule for f in findings],
                )
                return False
        except ImportError:  # analysis package unavailable: dispatch-only
            pass
        probe = np.zeros((eng.group,) + eng._frame_shape, np.float32)
        try:
            out = eng._run_group(probe)
            return bool(np.isfinite(np.asarray(out)).all())
        except Exception as e:  # noqa: BLE001 — a failed probe re-opens
            _LOG.info("tenant %r probe dispatch failed: %s", ts.name, e)
            return False

    # -- verified hot plan swap ---------------------------------------------

    def swap(self, tenant: str, new_plan, **engine_kwargs) -> None:
        """Atomically replace ``tenant``'s plan with ``new_plan``.

        Admission order (all before live traffic is touched):

        1. ``verify_plan(new_plan, scopes=("plan", "structure"))`` — any
           error finding rejects the swap (:class:`SwapRejected` carries
           the failed invariant IDs).
        2. Serving-surface compatibility: the new plan must consume the
           same frame geometry and produce the same logits width
           (abstractly traced — no dispatch).
        3. A shadow warmup: the replacement :class:`Engine` is built off
           to the side and must pass its rung warmup probe.

        Then the switch: submissions quiesce, the old engine drains (all
        pre-swap requests resolve bit-exact through the OLD plan), the
        engine reference flips, and the breaker resets. The old engine
        is retained — :meth:`rollback` restores it in one call."""
        ts = self._state(tenant)
        self._verify_swap_target(ts, new_plan)
        try:
            new_engine = self._build_engine(tenant, new_plan, engine_kwargs)
        except Exception as e:  # noqa: BLE001 — warmup/build failures reject
            raise SwapRejected(
                f"tenant {tenant!r}: replacement engine failed its shadow "
                f"warmup: {type(e).__name__}: {e}"
            ) from e
        self._switch(ts, new_plan, new_engine, keep_previous=True)
        _LOG.info("tenant %r swapped to a new plan (rollback available)",
                  tenant)

    def rollback(self, tenant: str) -> None:
        """Swap ``tenant`` back to the plan it served before the last
        :meth:`swap` — one call, no re-verification (the old plan already
        proved itself in service)."""
        ts = self._state(tenant)
        with ts.cv:
            if ts.previous is None:
                raise RuntimeError(
                    f"tenant {tenant!r} has no previous plan to roll back to"
                )
            prev_plan, prev_engine = ts.previous
        self._switch(ts, prev_plan, prev_engine, keep_previous=False)
        _LOG.info("tenant %r rolled back to its previous plan", tenant)

    def _verify_swap_target(self, ts: _TenantState, new_plan) -> None:
        from repro.analysis.verify import verify_plan

        findings = [
            f for f in verify_plan(new_plan, scopes=("plan", "structure"))
            if f.severity == "error"
        ]
        if findings:
            ids = sorted({f.rule for f in findings})
            raise SwapRejected(
                f"tenant {ts.name!r}: replacement plan failed verification "
                f"({', '.join(ids)}): "
                + "; ".join(f.message for f in findings[:3]),
                invariants=ids,
            )
        old_sig = _serving_signature(ts.plan)
        new_sig = _serving_signature(new_plan)
        if old_sig is not None and new_sig is not None and old_sig != new_sig:
            raise SwapRejected(
                f"tenant {ts.name!r}: replacement serving surface "
                f"{new_sig} does not match the live plan's {old_sig} "
                "(frame geometry + logits width must be identical for a "
                "hot swap)"
            )

    def _switch(self, ts: _TenantState, plan, engine: Engine,
                keep_previous: bool) -> None:
        with ts.cv:
            ts.swapping = True
            # Quiesce: wait out submits already holding the old engine
            # reference (ts.cv released while waiting; new submits park).
            deadline = time.monotonic() + self.join_timeout_s
            while ts.inflight_submits > 0:
                if not ts.cv.wait(timeout=0.1) and (
                    time.monotonic() > deadline
                ):
                    ts.swapping = False
                    ts.cv.notify_all()
                    raise SwapRejected(
                        f"tenant {ts.name!r}: in-flight submissions did not "
                        f"quiesce within {self.join_timeout_s:.0f}s"
                    )
            old_plan, old_engine = ts.plan, ts.engine
            try:
                # Drain pre-swap requests through the OLD plan (bit-exact
                # with what they would have gotten without the swap). A
                # scheduler turn racing us serializes on the engine's
                # flush lock; either way every request completes.
                while True:
                    if old_engine.flush() == 0:
                        break
            finally:
                ts.plan = plan
                ts.engine = engine
                ts.group_cost = _group_cost(plan, engine)
                ts.deficit = 0.0
                ts.previous = (old_plan, old_engine) if keep_previous else None
                ts.n_swaps += 1
                ts.breaker.close()
                ts.swapping = False
                ts.cv.notify_all()
        with self._lock:
            self._recompute_quantum()
        self._wake()

    # -- weighted-fair scheduler --------------------------------------------

    def _scheduler_alive(self) -> bool:
        t = self._scheduler
        return t is not None and t.is_alive()

    def start(self) -> "Router":
        """Start the scheduler thread (idempotent)."""
        if self._scheduler_alive():
            return self
        self._stop_evt = threading.Event()
        self._scheduler = threading.Thread(
            target=self._sched_loop, daemon=True, name="dhm-router-scheduler"
        )
        self._scheduler.start()
        return self

    def stop(self, *, drain: bool = True) -> None:
        """Stop the scheduler; by default drain every tenant queue (all
        in-flight requests complete). The join is bounded: a wedged
        scheduler sheds the queues with structured errors and raises
        :class:`~repro.core.dhm.engine.FlusherWedged` — never a silent
        leak, never a hang."""
        scheduler = self._scheduler
        if scheduler is not None:
            self._stop_evt.set()
            self._wake()
            scheduler.join(timeout=self.join_timeout_s)
            self._scheduler = None
            if scheduler.is_alive():
                shed = 0
                for name in self.tenants:
                    eng = self.engine(name)
                    shed += eng._shed_all(
                        "router stopping with a wedged scheduler thread"
                    )
                raise FlusherWedged(
                    f"router scheduler did not exit within "
                    f"{self.join_timeout_s:.1f}s of stop(); {shed} queued "
                    "request(s) completed with Shed. A tenant dispatch is "
                    "stuck past its watchdog — inspect the tenants' "
                    "demotion ledgers."
                )
        if drain:
            for name in self.tenants:
                try:
                    self.engine(name).flush()
                except UnknownTenant:
                    pass

    def __enter__(self) -> "Router":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop()

    def _wake(self) -> None:
        # The pending flag closes the lost-wakeup window: a submit that
        # lands while the scheduler is mid-round (not waiting on the cv)
        # would otherwise be noticed only after a full idle interval.
        with self._sched_cv:
            self._sched_pending = True
            self._sched_cv.notify_all()

    def _sched_loop(self) -> None:
        interval = self.scheduler_interval_ms / 1e3
        margin = self.deadline_margin_ms / 1e3
        while not self._stop_evt.is_set():
            try:
                did_work = self._sched_round(margin)
            except Exception:  # noqa: BLE001 — the loop must survive
                _LOG.exception("scheduler round failed; loop continues")
                did_work = False
            if not did_work:
                with self._sched_cv:
                    if not self._sched_pending:
                        self._sched_cv.wait(timeout=interval)
                    self._sched_pending = False
        # Final drain: whatever arrived before the stop signal.
        for name in self.tenants:
            try:
                self._state(name).engine.flush()
            except Exception:  # noqa: BLE001 — the drain must not raise
                _LOG.exception("final drain failed for tenant %r", name)

    def _sched_round(self, margin: float) -> bool:
        """One deficit-round-robin pass over the tenants; returns True if
        any work (dispatch or probe) was done."""
        did_work = False
        for name in self.tenants:
            try:
                ts = self._state(name)
            except UnknownTenant:
                continue
            probe = False
            with ts.cv:
                if ts.swapping:
                    continue
                eng = ts.engine
                if ts.breaker.state == OPEN:
                    if not ts.breaker.due_for_probe:
                        continue
                    ts.breaker.state = HALF_OPEN
                    ts.breaker.n_probes += 1
                    probe = True
                elif ts.breaker.state == HALF_OPEN:
                    probe = True  # a prior probe round was interrupted
            if probe:
                ok = self._probe_tenant(ts, eng)
                with ts.cv:
                    if ok:
                        ts.breaker.close()
                        _LOG.info("tenant %r breaker CLOSED (probe ok)", name)
                    else:
                        ts.breaker.trip()
                did_work = True
                continue
            did_work |= self._drr_turn(ts, eng, margin)
        return did_work

    def _drr_turn(self, ts: _TenantState, eng: Engine, margin: float) -> bool:
        with eng._cv:
            if not eng._queue:
                ts.deficit = 0.0
                return False
            earliest = min(
                (r.deadline_at for r in eng._queue
                 if r.deadline_at is not None),
                default=None,
            )
        ts.deficit += self._quantum * ts.weight
        urgent = (
            earliest is not None
            and time.perf_counter() >= earliest - margin
        )
        dispatched = False
        while ts.deficit >= ts.group_cost or urgent:
            with eng._lock:
                failed0 = eng._n_failed
                demoted0 = len(eng.demotions)
                ok0 = eng._n_ok
            n = eng.flush(max_frames=eng.group)
            if n == 0:
                ts.deficit = 0.0
                break
            dispatched = True
            urgent = False
            ts.deficit -= math.ceil(n / eng.group) * ts.group_cost
            self._observe_flush(ts, eng, failed0, demoted0, ok0)
            with ts.cv:
                if ts.breaker.state != CLOSED:
                    ts.deficit = 0.0
                    return True
            with eng._cv:
                if not eng._queue:
                    ts.deficit = 0.0
                    break
        return dispatched

    # -- introspection -------------------------------------------------------

    def stats(self) -> Dict[str, EngineStats]:
        """Per-tenant serving stats (each tenant's live engine)."""
        out = {}
        for name in self.tenants:
            try:
                out[name] = self._state(name).engine.stats()
            except UnknownTenant:
                pass
        return out

    def describe(self) -> Dict[str, dict]:
        """Operator view: per tenant — rung, breaker state/opens, weight,
        swaps, rollback availability."""
        out = {}
        for name in self.tenants:
            try:
                ts = self._state(name)
            except UnknownTenant:
                continue
            with ts.cv:
                out[name] = {
                    "rung": ts.engine.rung,
                    "weight": ts.weight,
                    "group_cost": ts.group_cost,
                    "breaker": ts.breaker.state,
                    "breaker_opens": ts.breaker.n_opens,
                    "breaker_probes": ts.breaker.n_probes,
                    "n_swaps": ts.n_swaps,
                    "rollback_available": ts.previous is not None,
                }
        return out


def _serving_signature(plan):
    """(frame shape, logits width) of a plan, abstractly traced — the
    identity a hot swap must preserve; None when it cannot be derived
    (verification has already vouched for the surface)."""
    import jax
    import jax.numpy as jnp

    try:
        h, w = plan.topo.input_shape
        frame = (h, w, plan.topo.input_channels)
        out = jax.eval_shape(
            lambda xb: plan.head_fn(plan.features(xb)),
            jax.ShapeDtypeStruct((1,) + frame, jnp.float32),
        )
        return frame, int(out.shape[-1])
    except Exception:  # noqa: BLE001
        return None
