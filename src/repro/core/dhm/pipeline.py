"""Streaming pipelined executor: the TPU incarnation of DHM's "all actors
always firing" model, for *heterogeneous* stage geometries.

Stages are assigned to disjoint device groups along a mesh axis
(``stage``). Each device group keeps its stage's parameters resident
(private resources, as in DHM) and processes a stream of µbatches; the
activation stream flows stage -> stage+1 over ICI via
``jax.lax.ppermute`` — the edge of the dataflow graph becomes a physical
link, never touching host or "external" memory.

Schedule: GPipe fill/steady/drain. For M µbatches and S stages the loop
runs T = M + S - 1 ticks; at tick t stage s processes µbatch (t - s) when
0 <= t - s < M. All stages fire every tick (fill/drain ticks process
garbage that is masked out) — matching the paper's fully-pipelined,
always-firing actors.

Real CNN topologies pool/stride down and grow channels between stages, so
stage bodies are NOT shape-homogeneous. The executor sizes the ICI stream
to the actual tensor traffic: the interior edge shapes (stage s ->
stage s+1, from the compiler's :class:`StageIOSpec` chain) are grouped
into **shape classes** (:func:`plan_edges`). Each class gets its own
in-flight buffer and its own *partial* ``ppermute`` — only the devices
whose out-edge belongs to the class appear as sources, so every edge
moves exactly its own bytes (the stage-0 input and the final output never
travel over ICI and never inflate a buffer). When every class holds edges
of a single shape the stream is **exact** (zero padding, zero slack —
the default, taken by every real topology); collapsing all edges into one
max-shape class is the **boxed** general fallback
(``PipelineConfig.edge_mode="boxed"``), numerics untouched either way.
Since each device executes one stage, the per-stage bodies are selected
with ``lax.switch`` on the device's stage index — one SPMD program, S
different actor chains. Parameters are boxed the old way (leaf-wise
pad-to-max, stacked on a leading stage axis) so each device group holds
exactly its own stage's weights.

With ``PipelineConfig.overlap=True`` the edge slots are double-buffered:
the scan carry holds separate in-flight *send* and *recv* slots, so the
``ppermute`` of µbatch m (launched from the send slot filled last tick)
is independent of — and overlaps with — the ``lax.switch`` stage body of
µbatch m+1 in the same tick. Each edge then costs one extra pipeline tick
(T = M + 2(S-1) instead of M + S - 1), the classic latency-for-bandwidth
trade: worth it when collectives run asynchronously beside compute (real
ICI), not on an emulated host mesh — the µbatch autotuner
(``repro.core.dhm.throughput``) decides from measured sweeps. Both
schedules compute bit-identical outputs.

A 2D ``(stage, data)`` mesh composes data-parallel batch sharding with the
spatial pipeline: the µbatch dimension is sharded along ``data_axis`` and
each data column runs an independent pipeline over its batch shard.

Stage bodies emitted by the compiler (``emit_conv_stage``) fuse a stage's
layer run into cross-layer pyramid groups under the VMEM budget — the
stage then executes as one (or a few) ``stream_conv_pyramid`` kernel calls
and only stage boundaries remain activation-streaming edges over ICI.
"""
from __future__ import annotations

import dataclasses
import threading
from typing import Callable, Optional, Sequence

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P


class CollectiveTimeout(RuntimeError):
    """A dispatched step (typically the mesh collective runner) failed to
    complete within its deadline — the runtime analogue of a wedged
    ``ppermute``. The serving engine treats this as a rung-level failure
    and demotes (mesh pipeline -> single device) instead of hanging."""


def call_with_timeout(fn: Callable, *, timeout_s: Optional[float], what: str = "dispatch"):
    """Run ``fn()`` (which must block until its result is ready) under a
    watchdog: if it does not return within ``timeout_s``, raise
    :class:`CollectiveTimeout` — the caller regains control even though
    the wedged computation cannot be cancelled (the worker thread is
    abandoned as a daemon and its eventual result discarded).

    ``timeout_s=None`` (or <= 0) runs ``fn`` inline with no watchdog.
    This is the timeout hook the serving engine wraps around every
    dispatch — most importantly the collective runner, where a lost peer
    stalls the whole mesh instead of raising.
    """
    if not timeout_s or timeout_s <= 0:
        return fn()
    box: dict = {}
    done = threading.Event()

    def _run():
        try:
            box["value"] = fn()
        except BaseException as e:  # noqa: BLE001 — relayed to the caller
            box["error"] = e
        finally:
            done.set()

    t = threading.Thread(target=_run, daemon=True, name=f"watchdog-{what}")
    t.start()
    if not done.wait(timeout_s):
        raise CollectiveTimeout(
            f"{what} did not complete within {timeout_s:.3f}s"
        )
    if "error" in box:
        raise box["error"]
    return box["value"]


@dataclasses.dataclass(frozen=True)
class StageIOSpec:
    """Static activation geometry of one pipeline stage: the per-µbatch
    element shape entering and leaving the stage (without the µbatch
    dimension — e.g. ``(H, W, C)`` for conv stages). Consecutive stages
    must chain: ``io[s].out_shape == io[s + 1].in_shape``."""

    in_shape: tuple
    out_shape: tuple

    def __post_init__(self):
        for name in ("in_shape", "out_shape"):
            shp = getattr(self, name)
            if not all(isinstance(d, int) and d >= 1 for d in shp):
                raise ValueError(
                    f"StageIOSpec.{name} must be positive ints, got {shp!r}"
                )


EDGE_MODES = ("auto", "exact", "boxed")


@dataclasses.dataclass(frozen=True)
class PipelineConfig:
    n_stages: int
    n_microbatches: int
    stage_axis: str = "stage"
    data_axis: Optional[str] = None  # optional batch-sharding mesh axis
    # How interior-edge activations travel over ICI (see plan_edges):
    # "auto" sends exact-shape per-class buffers, collapsing to one boxed
    # class only past max_edge_classes; "exact" never collapses; "boxed"
    # forces the single max-shape box (the general fallback).
    edge_mode: str = "auto"
    max_edge_classes: int = 4
    # Double-buffer the edge slots so the ppermute of µbatch m overlaps
    # the stage body of µbatch m+1 (one extra tick of latency per edge).
    overlap: bool = False

    def __post_init__(self):
        if self.n_microbatches < 1 or self.n_stages < 1:
            raise ValueError("n_stages and n_microbatches must be >= 1")
        if self.data_axis is not None and self.data_axis == self.stage_axis:
            raise ValueError("data_axis must differ from stage_axis")
        if self.edge_mode not in EDGE_MODES:
            raise ValueError(
                f"unknown edge_mode {self.edge_mode!r}; expected one of "
                f"{EDGE_MODES}"
            )
        if self.max_edge_classes < 1:
            raise ValueError("max_edge_classes must be >= 1")


# ---------------------------------------------------------------------------
# Boxing: embed heterogeneous shapes in one max-shape buffer.


def _aligned(shape: tuple, rank: int) -> tuple:
    """Rank-align a shape by prepending singleton dims."""
    return (1,) * (rank - len(shape)) + tuple(shape)


def _box_of(shapes: Sequence[tuple]) -> tuple:
    """The elementwise-max box that embeds every (rank-aligned) shape."""
    rank = max(len(s) for s in shapes)
    return tuple(max(dims) for dims in zip(*(_aligned(s, rank) for s in shapes)))


def _fit(a: jax.Array, box: tuple) -> jax.Array:
    """Zero-pad ``a`` (rank-aligned) into the box shape."""
    a = a.reshape(_aligned(a.shape, len(box)))
    return jnp.pad(a, [(0, b - d) for d, b in zip(a.shape, box)])


def _unfit(a_box: jax.Array, shape: tuple) -> jax.Array:
    """Slice the true ``shape`` back out of a boxed array (inverse of
    :func:`_fit` — exact, no numerics touched)."""
    idx = tuple(slice(0, d) for d in _aligned(shape, a_box.ndim))
    return a_box[idx].reshape(shape)


def _fit_elem(y: jax.Array, class_shape: tuple) -> jax.Array:
    """Zero-pad a (mb, *elem) activation into (mb, *class_shape) — the
    element dims are rank-aligned AFTER the µbatch dim. Pad-free (a pure
    reshape) when the class shape equals the element shape, i.e. on every
    exact-shape edge."""
    el = _aligned(y.shape[1:], len(class_shape))
    y = y.reshape((y.shape[0],) + el)
    pad = [(0, 0)] + [(0, b - d) for d, b in zip(el, class_shape)]
    if all(p == (0, 0) for p in pad):
        return y
    return jnp.pad(y, pad)


def _unfit_elem(y_box: jax.Array, shape: tuple) -> jax.Array:
    """Slice the true (mb, *shape) activation back out of a class buffer
    (inverse of :func:`_fit_elem` — exact, no numerics touched)."""
    idx = (slice(None),) + tuple(
        slice(0, d) for d in _aligned(shape, y_box.ndim - 1)
    )
    return y_box[idx].reshape((y_box.shape[0],) + tuple(shape))


# ---------------------------------------------------------------------------
# Edge planning: size the ICI stream to the actual tensor traffic.


@dataclasses.dataclass(frozen=True)
class EdgePlan:
    """How stage-boundary activations travel over ICI.

    The pipeline's S-1 *interior* edges (stage s -> s+1; the stage-0 input
    and final output never cross ICI) are grouped into shape classes. Each
    class owns one in-flight buffer of ``class_shapes[c]`` and one partial
    ``ppermute`` whose pairs are exactly the class's edges — devices whose
    out-edge is in another class send nothing, so per-tick edge traffic is
    the sum of the true edge payloads, not S-1 copies of the global max
    box.

    ``mode`` is ``"exact"`` when every class holds edges of one shape
    (class buffers carry zero padding — the fast path every chain-CNN
    topology takes) and ``"boxed"`` when classes were collapsed into a
    max-shape box (the general fallback, numerics identical).
    """

    mode: str
    edge_shapes: tuple  # per interior edge: the exact element shape
    class_shapes: tuple  # per class: the (rank-aligned) buffer elem shape
    edge_class: tuple  # per interior edge: index into class_shapes

    @property
    def n_edges(self) -> int:
        return len(self.edge_shapes)

    @property
    def n_classes(self) -> int:
        return len(self.class_shapes)

    def class_pairs(self, c: int) -> list:
        """The ``ppermute`` permutation of class ``c``: (s, s+1) for every
        stage s whose out-edge belongs to the class."""
        return [
            (e, e + 1) for e in range(self.n_edges) if self.edge_class[e] == c
        ]

    def class_bytes(self, itemsize: int = 4) -> tuple:
        """Per-class buffer bytes for one element (no µbatch dim)."""
        out = []
        for cs in self.class_shapes:
            n = 1
            for d in cs:
                n *= d
            out.append(n * itemsize)
        return tuple(out)

    def padding_fraction(self, itemsize: int = 4) -> float:
        """Fraction of the per-tick ICI traffic that is zero padding
        (0.0 on the exact path)."""
        sent = sum(
            self.class_bytes(itemsize)[self.edge_class[e]]
            for e in range(self.n_edges)
        )
        true = sum(
            itemsize * _prod(self.edge_shapes[e]) for e in range(self.n_edges)
        )
        return 1.0 - true / sent if sent else 0.0


def _prod(shape: tuple) -> int:
    n = 1
    for d in shape:
        n *= d
    return n


def plan_edges(
    io_specs: Sequence[StageIOSpec],
    *,
    mode: str = "auto",
    max_classes: int = 4,
) -> EdgePlan:
    """Group the pipeline's interior edge shapes into ICI shape classes.

    ``mode="auto"`` emits one class per distinct (rank-aligned) edge shape
    — the exact-shape stream — collapsing everything into a single
    max-shape box only when that would exceed ``max_classes`` in-flight
    buffers; ``"exact"`` never collapses; ``"boxed"`` always does (the
    general fallback the boxed executor used for every topology).
    """
    if mode not in EDGE_MODES:
        raise ValueError(
            f"unknown edge mode {mode!r}; expected one of {EDGE_MODES}"
        )
    io_specs = tuple(io_specs)
    edges = tuple(
        tuple(io_specs[s].out_shape) for s in range(len(io_specs) - 1)
    )
    if not edges:
        return EdgePlan(
            mode="exact", edge_shapes=(), class_shapes=(), edge_class=()
        )
    rank = max(len(e) for e in edges)
    aligned = [_aligned(e, rank) for e in edges]
    distinct = []
    for a in aligned:
        if a not in distinct:
            distinct.append(a)
    if mode == "boxed" or (mode == "auto" and len(distinct) > max_classes):
        return EdgePlan(
            mode="boxed",
            edge_shapes=edges,
            class_shapes=(_box_of(edges),),
            edge_class=(0,) * len(edges),
        )
    return EdgePlan(
        mode="exact",
        edge_shapes=edges,
        class_shapes=tuple(distinct),
        edge_class=tuple(distinct.index(a) for a in aligned),
    )


def _box_stage_params(per_stage_params: Sequence):
    """Box heterogeneous per-stage param pytrees into stackable leaves.

    Returns ``(stacked, meta)`` where ``stacked`` is a list of
    ``(S, *box)`` arrays (leaf slot i of every stage, padded to the slot's
    max shape; stages with fewer leaves contribute zeros) and ``meta``
    carries the static per-stage treedefs / leaf shapes / dtypes needed to
    reconstruct each stage's exact params inside its branch.
    """
    flat = [jax.tree_util.tree_flatten(p) for p in per_stage_params]
    leaves = [[jnp.asarray(x) for x in l] for l, _ in flat]
    treedefs = [td for _, td in flat]
    n_slots = max(len(l) for l in leaves)
    boxes, box_dtypes = [], []
    for i in range(n_slots):
        slot = [l[i] for l in leaves if len(l) > i]
        boxes.append(_box_of([x.shape for x in slot]))
        box_dtypes.append(jnp.result_type(*[x.dtype for x in slot]))
    stacked = []
    for i in range(n_slots):
        stacked.append(
            jnp.stack(
                [
                    _fit(l[i].astype(box_dtypes[i]), boxes[i])
                    if len(l) > i
                    else jnp.zeros(boxes[i], box_dtypes[i])
                    for l in leaves
                ]
            )
        )
    meta = {
        "treedefs": treedefs,
        "shapes": [[x.shape for x in l] for l in leaves],
        "dtypes": [[x.dtype for x in l] for l in leaves],
    }
    return stacked, meta


def derive_io_specs(
    stage_fns: Sequence[Callable], per_stage_params: Sequence, in_shape: tuple
) -> tuple:
    """Chain ``jax.eval_shape`` through the stage bodies to recover every
    boundary's activation geometry (used when the caller has no compiler
    plan to emit :class:`StageIOSpec` from)."""
    specs = []
    shape = tuple(in_shape)
    for fn, params in zip(stage_fns, per_stage_params):
        out = jax.eval_shape(
            fn, params, jax.ShapeDtypeStruct((1,) + shape, jnp.float32)
        )
        specs.append(StageIOSpec(in_shape=shape, out_shape=tuple(out.shape[1:])))
        shape = tuple(out.shape[1:])
    return tuple(specs)


def _validate_io_chain(io_specs: Sequence[StageIOSpec]):
    for s in range(len(io_specs) - 1):
        if tuple(io_specs[s].out_shape) != tuple(io_specs[s + 1].in_shape):
            raise ValueError(
                f"stage {s} output {tuple(io_specs[s].out_shape)} does not "
                f"chain into stage {s + 1} input "
                f"{tuple(io_specs[s + 1].in_shape)}"
            )


# ---------------------------------------------------------------------------
# The executor.


def _shard_map(fn, mesh, in_specs, out_specs):
    # jax.shard_map only exists on newer jax; fall back to the experimental
    # home (same API modulo the check_rep/check_vma rename).
    if hasattr(jax, "shard_map"):
        return jax.shard_map(
            fn, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
            check_vma=False,
        )
    from jax.experimental.shard_map import shard_map as _sm

    return _sm(
        fn, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
        check_rep=False,
    )


@dataclasses.dataclass(frozen=True)
class PipelinedRunner:
    """A built spatial pipeline: the shard_map'd GPipe executor plus the
    boxed per-stage parameter leaves, stacked ONCE at build time (eagerly
    — never inside an enclosing ``jit`` trace, where a 2D-mesh shard_map
    operand produced by a traced ``stack`` is mis-partitioned on
    jax 0.4.37) and laid out so each stage's device group holds exactly
    its own weights (DHM's private resources).

    ``runner(microbatches)`` runs the resident leaves; ``runner.apply``
    is the pure ``(leaves, microbatches) -> outputs`` function for
    composing under ``jit`` with the leaves passed as arguments (the
    serving ``Engine``'s path).
    """

    cfg: PipelineConfig
    io_specs: tuple
    edge_plan: EdgePlan  # how interior edges travel over ICI (see plan_edges)
    stacked_leaves: list  # (S, *box) per leaf slot, sharded P(stage_axis)
    _apply: Callable

    def apply(self, leaves, microbatches: jax.Array) -> jax.Array:
        """Pure executor: (stacked leaves, (M, mb, *elem) µbatches) ->
        (M, mb, *out_elem) final-stage outputs."""
        return self._apply(leaves, microbatches)

    def apply_with_timeout(
        self, leaves, microbatches: jax.Array, *, timeout_s: Optional[float]
    ) -> jax.Array:
        """:meth:`apply` under the :func:`call_with_timeout` watchdog,
        blocked until ready — raises :class:`CollectiveTimeout` instead of
        hanging when the mesh collective wedges (the serving engine's
        demotion hook)."""

        def _run():
            out = self._apply(leaves, microbatches)
            return jax.block_until_ready(out)

        return call_with_timeout(
            _run, timeout_s=timeout_s, what="pipelined collective"
        )

    def __call__(self, microbatches: jax.Array) -> jax.Array:
        return self._apply(self.stacked_leaves, microbatches)


def build_pipeline(
    stage_fns: Sequence[Callable],
    stage_params: Sequence,
    *,
    mesh: jax.sharding.Mesh,
    cfg: PipelineConfig,
    io_specs: Optional[Sequence[StageIOSpec]] = None,
    microbatch: Optional[int] = None,
    dtype=jnp.float32,
) -> PipelinedRunner:
    """Build the heterogeneous spatial pipeline once: validate the edge
    geometry, box + stack the per-stage params (eagerly), and close the
    shard_map'd fill/steady/drain executor over the static metadata.

    Args:
      stage_fns: S per-stage callables ``(params_s, x) -> y``; shapes may
        differ per boundary (pool/stride shrink, channel growth).
      stage_params: per-stage param pytrees (structure and leaf shapes
        may differ per stage).
      mesh: mesh containing ``cfg.stage_axis`` (and ``cfg.data_axis``).
      io_specs: per-stage :class:`StageIOSpec` (the compiler emits these
        from the topology's geometry; :func:`derive_io_specs` recovers
        them from the stage bodies when no plan is at hand). Required.
      microbatch: µbatch size (for the data-axis divisibility check at
        build time; otherwise checked at call time).
      dtype: dtype of the boxed activation stream.
    """
    S, M = cfg.n_stages, cfg.n_microbatches
    ax = cfg.stage_axis
    if mesh.shape[ax] != S:
        raise ValueError(
            f"mesh axis {ax!r} has {mesh.shape[ax]} devices, need {S}"
        )
    D = 1
    if cfg.data_axis is not None:
        D = mesh.shape[cfg.data_axis]
        if microbatch is not None and microbatch % D:
            raise ValueError(
                f"µbatch size {microbatch} not divisible by data axis "
                f"{cfg.data_axis!r} ({D} devices)"
            )
    stage_fns = list(stage_fns)
    if len(stage_fns) != S:
        raise ValueError(f"got {len(stage_fns)} stage fns for {S} stages")
    stage_params = list(stage_params)
    if len(stage_params) != S:
        raise ValueError(
            f"got {len(stage_params)} per-stage param trees for {S} stages"
        )

    if io_specs is None:
        raise ValueError(
            "build_pipeline needs io_specs (or use pipeline_forward, which "
            "derives them from the µbatch stream)"
        )
    io_specs = tuple(io_specs)
    if len(io_specs) != S:
        raise ValueError(f"got {len(io_specs)} io specs for {S} stages")
    _validate_io_chain(io_specs)

    # Size the ICI stream to the actual tensor traffic: group the S-1
    # interior edges into shape classes (stage-0 input and final output
    # never travel over ICI, so they inflate no buffer).
    edge_plan = plan_edges(
        io_specs, mode=cfg.edge_mode, max_classes=cfg.max_edge_classes
    )
    class_pairs = [edge_plan.class_pairs(c) for c in range(edge_plan.n_classes)]
    elem_shape = tuple(io_specs[0].in_shape)
    out_elem = tuple(io_specs[-1].out_shape)
    box_dtype = dtype

    stacked_leaves, meta = _box_stage_params(stage_params)
    # Each stage's device group keeps its own (boxed) weights resident.
    sharding = jax.sharding.NamedSharding(mesh, P(ax))
    stacked_leaves = [jax.device_put(l, sharding) for l in stacked_leaves]

    # Each edge adds one tick of pipeline delay per hop; the overlapped
    # schedule double-buffers every hop (send slot this tick, ppermute
    # next tick), doubling the fill/drain delay in exchange for making the
    # collective independent of the same-tick stage body.
    delay = (2 if cfg.overlap else 1) * (S - 1)
    n_ticks = M + delay

    def _per_stage(leaves, mb_stream):
        # Inside shard_map: each boxed leaf has leading dim 1 (this stage's
        # slice); mb_stream is this data column's (M, mb_local, *elem).
        local = [l[0] for l in leaves]
        mb_local = mb_stream.shape[1]
        stage_id = jax.lax.axis_index(ax)
        slot_shapes = [
            (mb_local,) + tuple(cs) for cs in edge_plan.class_shapes
        ]

        def make_branch(s):
            shapes_s = meta["shapes"][s]
            dtypes_s = meta["dtypes"][s]

            def branch(operand):
                x0, recv, lv_box = operand
                lv = [
                    _unfit(lv_box[i], shapes_s[i]).astype(dtypes_s[i])
                    for i in range(len(shapes_s))
                ]
                params = jax.tree_util.tree_unflatten(meta["treedefs"][s], lv)
                if s == 0:
                    x = x0  # injected directly; never crossed ICI
                else:
                    x = _unfit_elem(
                        recv[edge_plan.edge_class[s - 1]],
                        io_specs[s].in_shape,
                    )
                y = stage_fns[s](params, x)
                want = (mb_local,) + tuple(io_specs[s].out_shape)
                if tuple(y.shape) != want:
                    raise ValueError(
                        f"stage {s} produced {tuple(y.shape)}, but its "
                        f"StageIOSpec promises {want}"
                    )
                y = y.astype(box_dtype)
                # Every branch returns identical avals: one send slot per
                # class (this stage fills only its own out-edge's class)
                # and the exact final-edge output (zeros off-final).
                sends = tuple(
                    _fit_elem(y, edge_plan.class_shapes[c])
                    if s < S - 1 and edge_plan.edge_class[s] == c
                    else jnp.zeros(slot_shapes[c], box_dtype)
                    for c in range(edge_plan.n_classes)
                )
                out = (
                    y
                    if s == S - 1
                    else jnp.zeros((mb_local,) + out_elem, box_dtype)
                )
                return sends, out

            return branch

        branches = [make_branch(s) for s in range(S)]
        zero_slots = tuple(
            jnp.zeros(shp, box_dtype) for shp in slot_shapes
        )
        out_buf0 = jnp.zeros((M, mb_local) + out_elem, box_dtype)

        def shift(slots):
            # One ICI hop per shape class: partial permutation — only the
            # stages whose out-edge is in the class send; everyone else's
            # slot arrives as zeros (ppermute semantics).
            return tuple(
                jax.lax.ppermute(slots[c], ax, class_pairs[c])
                for c in range(edge_plan.n_classes)
            )

        def write_out(out_buf, out, t):
            # µbatch index this stage just finished; masked fill/drain.
            mb_idx = t - (2 if cfg.overlap else 1) * stage_id
            valid = jnp.logical_and(mb_idx >= 0, mb_idx < M)
            slot = jnp.clip(mb_idx, 0, M - 1)
            prev = jax.lax.dynamic_index_in_dim(
                out_buf, slot, axis=0, keepdims=False
            )
            return jax.lax.dynamic_update_index_in_dim(
                out_buf, jnp.where(valid, out, prev), slot, axis=0
            )

        def inject(t):
            # Stage 0 injects µbatch t (zeros once the stream is drained).
            i = jnp.where(t < M, t, 0)
            x0 = jax.lax.dynamic_index_in_dim(
                mb_stream, i, axis=0, keepdims=False
            )
            return x0.astype(box_dtype)

        if cfg.overlap:

            def tick(carry, t):
                recv, send, out_buf = carry
                # The hop of last tick's send slot is data-independent of
                # this tick's switch body — XLA overlaps them.
                new_recv = shift(send)
                sends, out = jax.lax.switch(
                    stage_id, branches, (inject(t), recv, local)
                )
                return (new_recv, sends, write_out(out_buf, out, t)), None

            carry0 = (zero_slots, zero_slots, out_buf0)
            (_, _, out_buf), _ = jax.lax.scan(
                tick, carry0, jnp.arange(n_ticks)
            )
        else:

            def tick(carry, t):
                recv, out_buf = carry
                sends, out = jax.lax.switch(
                    stage_id, branches, (inject(t), recv, local)
                )
                return (shift(sends), write_out(out_buf, out, t)), None

            (_, out_buf), _ = jax.lax.scan(
                tick, (zero_slots, out_buf0), jnp.arange(n_ticks)
            )
        # Leading singleton stage axis so out_specs can shard it.
        return out_buf[None]

    dax = cfg.data_axis
    in_specs = (
        [P(ax) for _ in stacked_leaves],
        P(None, dax) if dax else P(),  # µbatch stream (only stage 0 reads it)
    )
    out_specs = P(ax, None, dax) if dax else P(ax)
    shmap = _shard_map(_per_stage, mesh, in_specs, out_specs)

    def _apply(leaves, microbatches):
        if microbatches.shape[0] != M:
            raise ValueError(
                f"expected {M} microbatches, got {microbatches.shape[0]}"
            )
        if tuple(microbatches.shape[2:]) != elem_shape:
            raise ValueError(
                f"µbatch element shape {tuple(microbatches.shape[2:])} does "
                f"not match stage 0 input {elem_shape}"
            )
        mb = microbatches.shape[1]
        if mb % D:
            raise ValueError(
                f"µbatch size {mb} not divisible by data axis "
                f"{cfg.data_axis!r} ({D} devices)"
            )
        stacked = shmap(leaves, microbatches)  # (S, M, mb, *out_elem)
        # Output buffers are exact-shape; only stage S-1 wrote real values.
        return stacked[-1]

    return PipelinedRunner(
        cfg=cfg, io_specs=io_specs, edge_plan=edge_plan,
        stacked_leaves=stacked_leaves, _apply=_apply,
    )


def pipeline_forward(
    stage_fn,
    stage_params,
    microbatches: jax.Array,
    *,
    mesh: jax.sharding.Mesh,
    cfg: PipelineConfig,
    io_specs: Optional[Sequence[StageIOSpec]] = None,
):
    """Run the µbatch stream through the spatial pipeline (one-shot sugar
    over :func:`build_pipeline` — for repeated serving build the runner
    once, or use the ``Engine``).

    Args:
      stage_fn: either a sequence of S per-stage callables
        ``(params_s, x) -> y`` (heterogeneous stages — shapes may differ
        per boundary), or a single callable shared by every stage (the
        homogeneous sugar).
      stage_params: a list of per-stage param pytrees (one per stage; the
        pytrees may differ in structure and leaf shapes). With a single
        shared ``stage_fn``, a pytree whose leaves are stacked on a
        leading axis of size ``n_stages`` is also accepted.
      microbatches: (M, mb, *elem) stacked µbatch inputs. With
        ``cfg.data_axis`` set, the ``mb`` dimension is sharded along that
        mesh axis (each data column pipelines its own batch shard).
      mesh: mesh containing ``cfg.stage_axis`` (and ``cfg.data_axis``).
      io_specs: per-stage :class:`StageIOSpec` (the compiler emits these
        from the topology's geometry); derived via ``jax.eval_shape``
        chaining when omitted.

    Returns:
      (M, mb, *out_elem) outputs of the final stage.
    """
    S = cfg.n_stages
    if callable(stage_fn):
        stage_fns = [stage_fn] * S
        if not isinstance(stage_params, (list, tuple)):
            # Homogeneous sugar: leaves stacked on a leading stage axis.
            stage_params = [
                jax.tree_util.tree_map(lambda l, s=s: l[s], stage_params)
                for s in range(S)
            ]
    else:
        stage_fns = list(stage_fn)
    if io_specs is None:
        io_specs = derive_io_specs(
            stage_fns, stage_params, tuple(microbatches.shape[2:])
        )
    runner = build_pipeline(
        stage_fns,
        stage_params,
        mesh=mesh,
        cfg=cfg,
        io_specs=io_specs,
        microbatch=microbatches.shape[1],
        dtype=microbatches.dtype,
    )
    return runner(microbatches)


def stack_stage_params(per_stage_params: list):
    """Stack a list of per-stage param pytrees along a new leading axis
    (homogeneous-stage sugar for :func:`pipeline_forward`)."""
    return jax.tree_util.tree_map(
        lambda *xs: jnp.stack(xs, axis=0), *per_stage_params
    )


def make_conv_stage(
    *,
    padding: str = "SAME",
    act: str = "relu",
    pool: int = 0,
    pool_stride: int | None = None,
    stride: int = 1,
    act_bits: int | None = None,
    backend: str | None = None,
    n_out: int = 0,
    kernel: int = 0,
):
    """Build a single-layer pipeline stage body — a compiler-emitted DHM
    actor chain (conv -> bias -> activation (-> pool -> stream quant)) as
    one fused kernel call on ``params = {"w": (K, K, C, N), "b": (N,)}``.

    Thin veneer over :func:`repro.core.dhm.compiler.emit_conv_stage`: the
    layer description goes through the same validated ``ConvLayerSpec``
    dataclass as ``compile_dhm`` topologies, so the pipeline stage bodies
    and the single-device plans share ONE lowering path (act / pool /
    padding / stride are validated at build time there). ``n_out`` and
    ``kernel`` describe the expected parameter geometry; they default to 0
    ("any") because the emitted stage body takes its shapes from the
    params at call time.
    """
    from repro.core.dhm.compiler import emit_conv_stage
    from repro.models.cnn import ConvLayerSpec

    spec = ConvLayerSpec(
        n_out=n_out,
        kernel=kernel,
        padding=padding,
        pool=pool,
        act=act,
        stride=stride,
        pool_stride=pool_stride,
    )
    return emit_conv_stage((spec,), backend=backend, act_bits=act_bits)
