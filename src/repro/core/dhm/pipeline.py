"""Streaming pipelined executor: the TPU incarnation of DHM's "all actors
always firing" model.

Stages are assigned to disjoint device groups along a mesh axis
(``stage``). Each device group keeps its stage's parameters resident
(private resources, as in DHM) and processes a stream of µbatches; the
activation stream flows stage -> stage+1 over ICI via
``jax.lax.ppermute`` — the edge of the dataflow graph become a physical
link, never touching host or "external" memory.

Schedule: GPipe fill/steady/drain. For M µbatches and S stages the loop runs
T = M + S - 1 ticks; at tick t stage s processes µbatch (t - s) when
0 <= t - s < M. All stages fire every tick (fill/drain ticks process
garbage that is masked out) — matching the paper's fully-pipelined,
always-firing actors.

The stage body must be shape-homogeneous (same activation shape in/out),
which holds for transformer stacks and for the CNN topologies once grouped
into stages by the mapper. ``make_conv_stage`` builds such a body from the
fused streaming-conv kernel (conv+bias+act in one kernel call), so each
pipeline stage is itself a fused DHM actor chain. Stage bodies emitted by
the compiler (``emit_conv_stage``) may additionally fuse a stage's layer
run into cross-layer pyramid groups under the VMEM budget — the stage
then executes as one (or a few) ``stream_conv_pyramid`` kernel calls
instead of one call per layer, and only stage boundaries remain
activation-streaming edges over ICI.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Callable

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P


@dataclasses.dataclass(frozen=True)
class PipelineConfig:
    n_stages: int
    n_microbatches: int
    stage_axis: str = "stage"

    def __post_init__(self):
        if self.n_microbatches < 1 or self.n_stages < 1:
            raise ValueError("n_stages and n_microbatches must be >= 1")


def pipeline_forward(
    stage_fn: Callable,
    stage_params,
    microbatches: jax.Array,
    *,
    mesh: jax.sharding.Mesh,
    cfg: PipelineConfig,
):
    """Run the µbatch stream through the spatial pipeline.

    Args:
      stage_fn: (params_for_one_stage, x) -> y with y.shape == x.shape.
      stage_params: pytree whose leaves are stacked on a leading axis of
        size ``n_stages``; sharded so stage s's slice lives on stage-s
        devices.
      microbatches: (M, mb, ...) stacked µbatch inputs.
      mesh: mesh containing ``cfg.stage_axis``.

    Returns:
      (M, mb, ...) outputs of the final stage.
    """
    S, M = cfg.n_stages, cfg.n_microbatches
    ax = cfg.stage_axis
    if microbatches.shape[0] != M:
        raise ValueError(
            f"expected {M} microbatches, got {microbatches.shape[0]}"
        )
    if mesh.shape[ax] != S:
        raise ValueError(
            f"mesh axis {ax!r} has {mesh.shape[ax]} devices, need {S}"
        )

    def _per_stage(params, mb_stream):
        # Inside shard_map: params leaves have leading dim 1 (this stage's
        # slice); mb_stream is the full (M, mb, ...) stream, replicated.
        params = jax.tree_util.tree_map(lambda p: p[0], params)
        stage_id = jax.lax.axis_index(ax)
        zero = jnp.zeros_like(mb_stream[0])
        out_buf = jnp.zeros_like(mb_stream)

        def tick(carry, t):
            buf, out_buf = carry
            # Stage 0 injects µbatch t (zeros once the stream is drained).
            inject = jnp.where(t < M, t, 0)
            x0 = jax.lax.dynamic_index_in_dim(
                mb_stream, inject, axis=0, keepdims=False
            )
            x = jnp.where(stage_id == 0, x0, buf)
            y = stage_fn(params, x)
            # µbatch index this stage just processed; valid window check.
            mb_idx = t - stage_id
            valid_out = jnp.logical_and(
                stage_id == S - 1,
                jnp.logical_and(mb_idx >= 0, mb_idx < M),
            )
            out_buf = jax.lax.dynamic_update_index_in_dim(
                out_buf,
                jnp.where(valid_out, y, jax.lax.dynamic_index_in_dim(
                    out_buf, jnp.clip(mb_idx, 0, M - 1), axis=0, keepdims=False
                )),
                jnp.clip(mb_idx, 0, M - 1),
                axis=0,
            )
            # Stream the activation to the next stage (edge = physical link).
            nxt = jax.lax.ppermute(
                y, ax, [(i, i + 1) for i in range(S - 1)]
            )
            return (nxt, out_buf), None

        (_, out_buf), _ = jax.lax.scan(
            tick, (zero, out_buf), jnp.arange(M + S - 1)
        )
        # Leading singleton stage axis so out_specs can shard it.
        return out_buf[None]

    in_specs = (
        jax.tree_util.tree_map(lambda _: P(ax), stage_params),
        P(),  # µbatch stream replicated (only stage 0 reads it)
    )
    # jax.shard_map only exists on newer jax; fall back to the experimental
    # home (same API modulo the check_rep/check_vma rename).
    if hasattr(jax, "shard_map"):
        shmap = jax.shard_map(
            _per_stage,
            mesh=mesh,
            in_specs=in_specs,
            out_specs=P(ax),
            check_vma=False,
        )
    else:
        from jax.experimental.shard_map import shard_map as _shard_map

        shmap = _shard_map(
            _per_stage,
            mesh=mesh,
            in_specs=in_specs,
            out_specs=P(ax),
            check_rep=False,
        )
    stacked = shmap(stage_params, microbatches)  # (S, M, mb, ...)
    return stacked[-1]


def stack_stage_params(per_stage_params: list):
    """Stack a list of per-stage param pytrees along a new leading axis."""
    return jax.tree_util.tree_map(
        lambda *xs: jnp.stack(xs, axis=0), *per_stage_params
    )


def make_conv_stage(
    *,
    padding: str = "SAME",
    act: str = "relu",
    pool: int = 0,
    pool_stride: int | None = None,
    stride: int = 1,
    act_bits: int | None = None,
    backend: str | None = None,
):
    """Build a single-layer pipeline stage body — a compiler-emitted DHM
    actor chain (conv -> bias -> activation (-> pool -> stream quant)) as
    one fused kernel call on ``params = {"w": (K, K, C, N), "b": (N,)}``.

    Thin veneer over :func:`repro.core.dhm.compiler.emit_conv_stage`, so
    the pipeline stage bodies and the single-device plans share ONE
    lowering path (act/pool/padding/stride are validated at build time
    there). With SAME padding, ``stride=1``, ``pool=0`` and C == N the
    stage is shape-homogeneous, which is what ``pipeline_forward``
    requires.
    """
    import types

    from repro.core.dhm.compiler import emit_conv_stage

    spec = types.SimpleNamespace(
        padding=padding, act=act, pool=pool, pool_stride=pool_stride,
        stride=stride,
    )
    return emit_conv_stage((spec,), backend=backend, act_bits=act_bits)
