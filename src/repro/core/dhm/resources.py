"""FPGA resource model for DHM (paper §4.2, Tables 2-3).

Three multiplier-implementation strategies:

  DSP        : every multiplier uses one hardwired DSP block.
  LE         : every multiplier synthesized from logic elements (ALMs) —
               the paper's measured cost at 5 bits is exactly 17 ALMs per
               multiplier (433,500 ALMs / 25,500 multipliers), which pins the
               quadratic coefficient of the classic AND-gate + half-adder-
               tree construction [Altera app-note]: cost(b) = 0.68 * b^2.
  LE_CONST   : constant-coefficient specialization (the paper's tactic):
               x0 multipliers vanish, x1 are wires, x(2^k) are fixed shifts
               (routing, no logic); only "other" constants burn a generic
               LE multiplier. Adder trees shrink too: a zero weight removes
               its adder-tree input.

The model is calibrated against the paper's three published LeNet5@5bit
points (Table 2) and the cross-network proportions of Table 3. It consumes
parameter-class fractions (zero/one/pow2/other) either from the paper's
Table 1 or measured from a trained+quantized model via
``repro.core.quant.classify_params``.
"""
from __future__ import annotations

import dataclasses
import enum


class MultiplierStrategy(enum.Enum):
    DSP = "dsp"
    LE = "le"
    LE_CONST = "le_const"


@dataclasses.dataclass(frozen=True)
class DeviceModel:
    """An FPGA device resource envelope."""

    name: str
    logic_cells: int  # ALMs (Intel) or slices (Xilinx)
    dsp_blocks: int
    bram_bits: int
    # Xilinx slices hold ~2x the logic of an Intel ALM for this construction;
    # the paper's LeNet5 pair (8067 ALMs vs 25031 slices incl. different FC
    # mapping) fixes the conversion factor per-device.
    logic_per_alm: float = 1.0


# Intel Cyclone V 5CGXFC9E7: 113,560 ALMs, 342 DSP blocks, 12,200 Kb M10K.
CYCLONE_V_5CGXFC9E7 = DeviceModel(
    name="cyclone_v_5cgxfc9e7",
    logic_cells=113_560,
    dsp_blocks=342,
    bram_bits=12_200 * 1024,
)

# Xilinx Zynq-7045 (XC7Z045, Kintex-7 fabric): 218,600 LUTs, 900 DSP48,
# 19.2 Mb BRAM. The paper's Table 3-b "Slices" percentages only make sense
# against the LUT count (172,219/218,600 = 79%), so the device is modeled in
# LUTs. The paper's own cross-device pairs give the LUT-per-ALM conversion:
# 25,031/8,067 = 3.10 (LeNet5), 172,219/51,276 = 3.36 (Cifar10),
# 136,675/39,513 = 3.46 (SVHN) -> 3.3.
KINTEX7_XC7Z045 = DeviceModel(
    name="kintex7_xc7z045",
    logic_cells=218_600,  # LUTs
    dsp_blocks=900,
    bram_bits=19_200 * 1024,
    logic_per_alm=3.3,
)


@dataclasses.dataclass(frozen=True)
class ParamClassFractions:
    zero: float
    one: float
    pow2: float
    other: float

    def __post_init__(self):
        tot = self.zero + self.one + self.pow2 + self.other
        if abs(tot - 1.0) > 1e-3:
            raise ValueError(f"fractions must sum to 1, got {tot}")


# Paper Table 1 fractions (percent -> fraction).
PAPER_TABLE1 = {
    "lenet5": ParamClassFractions(zero=0.8859, one=0.0631, pow2=0.0005, other=0.0505),
    "cifar10": ParamClassFractions(zero=0.3378, one=0.4532, pow2=0.1640, other=0.0450),
    "svhn": ParamClassFractions(zero=0.3714, one=0.4650, pow2=0.1362, other=0.0274),
}


@dataclasses.dataclass(frozen=True)
class ResourceReport:
    strategy: MultiplierStrategy
    device: DeviceModel
    logic_used: int
    dsp_used: int
    memory_bits: int

    @property
    def logic_utilization(self) -> float:
        return self.logic_used / self.device.logic_cells

    @property
    def dsp_utilization(self) -> float:
        return self.dsp_used / max(1, self.device.dsp_blocks)

    @property
    def fits(self) -> bool:
        return self.logic_utilization <= 1.0 and self.dsp_utilization <= 1.0

    def summary(self) -> str:
        return (
            f"{self.device.name:>22s} {self.strategy.value:>8s}: "
            f"logic {self.logic_used:>8d} ({100 * self.logic_utilization:5.1f}%) "
            f"dsp {self.dsp_used:>6d} ({100 * self.dsp_utilization:6.1f}%) "
            f"mem {self.memory_bits:>8d} bits "
            f"{'FITS' if self.fits else 'DOES NOT FIT'}"
        )


# Calibrated constants (see module docstring and EXPERIMENTS.md §Resource
# model calibration). The LE coefficient is pinned *exactly* by the paper's
# Table 2 (433,500 ALMs / 25,500 multipliers @5 bits = 17 = 0.68 * 25).
# The constant-specialized path models what the synthesis tool does after
# specialization: surviving "other" constants are CSD-recoded (~b/3 nonzero
# signed digits -> that many adders), and the per-engine accumulation uses
# carry-save compressor trees whose cost per live input bit is far below a
# ripple adder. ALM_PER_ADDER_BIT is fitted to Table 3 (the absolute post-
# fit numbers embed Quartus' multiple-constant-multiplication sharing, which
# a closed-form model can only approximate — deviations are reported, the
# qualitative fit/no-fit claims all reproduce).
ALM_PER_MULT_COEFF = 0.68  # cost(b) = coeff * b^2 ALMs (generic LE mult)
ALM_PER_ADDER_BIT = 0.08  # carry-save compressor tree, per live input bit
ACT_ALM = 24  # tanh LUT actor (b-bit in/out lookup + interp)


def _alm_per_mult(bits: int) -> float:
    return ALM_PER_MULT_COEFF * bits * bits


def _csd_adds(bits: int) -> int:
    """Canonical-signed-digit recoding: expected nonzero digits of a random
    b-bit constant ~ b/3; each nonzero digit costs one adder."""
    return max(1, round(bits / 3))


def estimate_resources(
    graph,
    device: DeviceModel,
    *,
    bits: int,
    strategy: MultiplierStrategy,
    fractions: ParamClassFractions | None = None,
) -> ResourceReport:
    """Resource estimate for a DPN expanded by ``cnn_to_dpn``.

    ``fractions`` (zero/one/pow2/other) is required for LE_CONST — it decides
    how many multipliers survive specialization and how many adder-tree
    inputs disappear (zero weights feed nothing).
    """
    from repro.core.dhm.graph import ActorKind

    n_mult = graph.total_multipliers()
    n_addtree = graph.total_adders()  # adder-tree/neuron-sum actors
    n_act = graph.count(ActorKind.ACTIVATION)
    acc_bits = 2 * bits + 4  # accumulate across K*K*C with headroom

    mem_bits = graph.total_line_buffer_bits()

    if strategy == MultiplierStrategy.DSP:
        logic = int(
            n_addtree * acc_bits * ALM_PER_ADDER_BIT + n_act * ACT_ALM
        )
        return ResourceReport(
            strategy=strategy,
            device=device,
            logic_used=int(logic * device.logic_per_alm),
            dsp_used=n_mult,
            memory_bits=mem_bits,
        )

    if strategy == MultiplierStrategy.LE:
        # The paper's 433,500-ALM point = 17 ALM/mult at 5 bits with the
        # adder tree folded into the per-multiplier constant.
        logic = n_mult * _alm_per_mult(bits)
        logic += n_act * ACT_ALM
        return ResourceReport(
            strategy=strategy,
            device=device,
            logic_used=int(logic * device.logic_per_alm),
            dsp_used=0,
            memory_bits=mem_bits,
        )

    if strategy == MultiplierStrategy.LE_CONST:
        if fractions is None:
            raise ValueError("LE_CONST needs parameter-class fractions")
        # Surviving "other" constants are CSD-recoded into a few adders;
        # zero weights vanish, ones are wires, pow2s are fixed shifts.
        other_mults = fractions.other * n_mult
        logic = other_mults * _csd_adds(bits) * (2 * bits) * ALM_PER_ADDER_BIT
        # Adder trees keep one slot per live (non-zero) product, at product
        # width, compressor-tree packed.
        live_inputs = (1.0 - fractions.zero) * n_mult
        logic += live_inputs * (2 * bits) * ALM_PER_ADDER_BIT
        logic += n_act * ACT_ALM
        return ResourceReport(
            strategy=strategy,
            device=device,
            logic_used=int(logic * device.logic_per_alm),
            dsp_used=0,
            memory_bits=mem_bits,
        )

    raise ValueError(strategy)
