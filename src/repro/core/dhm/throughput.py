"""DHM throughput model (paper Table 4).

With full pipelining the accelerator ingests one input *sample* (one pixel
of one channel of the streamed frame) per clock cycle, and every mapped
operation fires once per ingested frame. Hence

    throughput [op/s] = f_clk * ops_per_frame / (H * W * C_in)

This formula reproduces the paper's Table 4 rows exactly:
  LeNet5  @65.71 MHz: 3.8e6 ops / 784  * 65.71e6 = 318.5 Gop/s  (paper 318.48)
  Cifar10 @63.89 MHz: 24.8e6 / 3072    * 63.89e6 = 515.8 Gop/s  (paper 515.78)
  SVHN(Zynq) @54.17 MHz: 24.8e6 / 3072 * 54.17e6 = 437.3 Gop/s  (paper 437.30)

The TPU translation of the same law: the spatial pipeline's steady-state
throughput equals (slowest stage time)^-1 * work per µbatch — used by
``mapping.balance_report``.
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class ThroughputReport:
    name: str
    workload_mop: float  # ops per frame (feature extractor)
    f_clk_mhz: float
    gops: float
    frames_per_s: float

    def summary(self) -> str:
        return (
            f"{self.name:>10s}: {self.workload_mop:6.1f} Mop @ "
            f"{self.f_clk_mhz:6.2f} MHz -> {self.gops:7.2f} Gop/s "
            f"({self.frames_per_s:9.1f} frames/s)"
        )


def dhm_throughput_gops(topo, f_clk_mhz: float) -> ThroughputReport:
    """Throughput of a DHM-mapped feature extractor at a clock frequency."""
    ops = topo.feature_extractor_ops()
    h_in, w_in = topo.input_shape
    samples = h_in * w_in * topo.input_channels
    f = f_clk_mhz * 1e6
    gops = f * ops / samples / 1e9
    return ThroughputReport(
        name=topo.name,
        workload_mop=ops / 1e6,
        f_clk_mhz=f_clk_mhz,
        gops=gops,
        frames_per_s=f / samples,
    )
