"""DHM throughput models: the paper's FPGA streaming law (Table 4) and
the TPU spatial-pipeline cost model + measurement-driven µbatch autotuner.

FPGA law (paper Table 4). With full pipelining the accelerator ingests
one input *sample* (one pixel of one channel of the streamed frame) per
clock cycle, and every mapped operation fires once per ingested frame:

    throughput [op/s] = f_clk * ops_per_frame / (H * W * C_in)

:func:`dhm_throughput_gops` reproduces the paper's Table 4 rows exactly:
  LeNet5  @65.71 MHz: 3.8e6 ops / 784  * 65.71e6 = 318.5 Gop/s  (paper 318.48)
  Cifar10 @63.89 MHz: 24.8e6 / 3072    * 63.89e6 = 515.8 Gop/s  (paper 515.78)
  SVHN(Zynq) @54.17 MHz: 24.8e6 / 3072 * 54.17e6 = 437.3 Gop/s  (paper 437.30)

TPU translation of the same law (the GPipe spatial pipeline of
``pipeline.py``): steady-state throughput is bounded by the slowest
stage's per-tick time, fill/drain ticks dilute it by the bubble fraction,
and each tick additionally pays the interior-edge ICI traffic (sized by
:func:`repro.core.dhm.pipeline.plan_edges` — exact-shape classes, not the
max box) plus a fixed dispatch overhead. :func:`estimate_pipeline` prices
a (n_microbatches, batch grain, data split, overlap) configuration with
three machine constants — effective FLOP/s, effective edge bytes/s, and
per-tick overhead — which :func:`fit_constants` recovers from measured
sweep rows (``path: pipeline_sweep`` in ``BENCH_history.jsonl``) by least
squares. :func:`autotune_pipeline` searches the candidate grid; measured
sweep points outrank model estimates, so with a sweep on record the tuner
returns a configuration that was actually benchmarked.
"""
from __future__ import annotations

import dataclasses
import json
import pathlib
from typing import Optional, Sequence


@dataclasses.dataclass(frozen=True)
class ThroughputReport:
    name: str
    workload_mop: float  # ops per frame (feature extractor)
    f_clk_mhz: float
    gops: float
    frames_per_s: float

    def summary(self) -> str:
        return (
            f"{self.name:>10s}: {self.workload_mop:6.1f} Mop @ "
            f"{self.f_clk_mhz:6.2f} MHz -> {self.gops:7.2f} Gop/s "
            f"({self.frames_per_s:9.1f} frames/s)"
        )


def streaming_throughput(
    ops_per_frame: float, samples_per_frame: float, f_clk_hz: float
) -> tuple:
    """The paper's streaming law: a fully-pipelined dataflow graph ingests
    one sample per clock, so every mapped op fires once per frame.

    Returns ``(op_per_s, frames_per_s)``.
    """
    frames = f_clk_hz / samples_per_frame
    return ops_per_frame * frames, frames


def dhm_throughput_gops(topo, f_clk_mhz: float) -> ThroughputReport:
    """Throughput of a DHM-mapped feature extractor at a clock frequency
    (thin wrapper over :func:`streaming_throughput` — the paper's Table 4
    formula, unchanged)."""
    ops = topo.feature_extractor_ops()
    h_in, w_in = topo.input_shape
    samples = h_in * w_in * topo.input_channels
    op_per_s, frames = streaming_throughput(ops, samples, f_clk_mhz * 1e6)
    return ThroughputReport(
        name=topo.name,
        workload_mop=ops / 1e6,
        f_clk_mhz=f_clk_mhz,
        gops=op_per_s / 1e9,
        frames_per_s=frames,
    )


# ---------------------------------------------------------------------------
# Spatial-pipeline cost model.


@dataclasses.dataclass(frozen=True)
class PipelineCostConstants:
    """The three machine constants the pipeline model prices ticks with:
    effective per-device FLOP/s on stage bodies, effective edge bytes/s
    over ICI, and fixed per-tick overhead (collective launch + switch
    dispatch). Defaults are deliberately round host-CPU-mesh numbers;
    :func:`fit_constants` replaces them with least-squares values from
    measured sweeps."""

    flops_per_s: float = 2.0e9
    bytes_per_s: float = 1.0e9
    tick_overhead_s: float = 2.0e-4
    source: str = "default"  # "default" or "fitted"


@dataclasses.dataclass(frozen=True)
class PipelineEstimate:
    """Model-priced execution of one pipelined group: T ticks of the
    GPipe scan at ``t_tick_s`` each (compute and comm overlap only under
    the double-buffered schedule), fill/drain diluting throughput by
    ``bubble_fraction``, the slowest stage setting the pace
    (``imbalance`` = max stage FLOPs / mean)."""

    n_ticks: int
    t_compute_s: float  # slowest stage body, one tick
    t_comm_s: float  # interior-edge ICI traffic, one tick
    t_tick_s: float
    total_s: float
    frames_per_s: float
    bubble_fraction: float
    imbalance: float

    def summary(self) -> str:
        return (
            f"{self.n_ticks} ticks x {self.t_tick_s * 1e6:.0f}us "
            f"(compute {self.t_compute_s * 1e6:.0f}us, comm "
            f"{self.t_comm_s * 1e6:.0f}us) -> "
            f"{self.frames_per_s:.0f} frames/s, bubble "
            f"{self.bubble_fraction:.2f}, imbalance {self.imbalance:.2f}"
        )


def pipeline_workload(plan) -> tuple:
    """(per-stage FLOPs per frame, per-interior-edge bytes per frame) of a
    compiled plan — the actor payloads the mapper balanced stages with,
    and the exact edge shapes the executor streams over ICI."""
    from repro.core.dhm.pipeline import plan_edges

    stage_flops = tuple(float(st.cost_flops) for st in plan.stages)
    ep = plan_edges([st.io for st in plan.stages])
    edge_bytes = tuple(
        4.0 * _prod(shape) for shape in ep.edge_shapes
    )
    return stage_flops, edge_bytes


def _prod(shape) -> float:
    n = 1.0
    for d in shape:
        n *= d
    return n


def estimate_pipeline(
    plan,
    *,
    n_microbatches: int,
    microbatch: int,
    data: int = 1,
    overlap: bool = False,
    edge_mode: str = "auto",
    constants: Optional[PipelineCostConstants] = None,
) -> PipelineEstimate:
    """Price one pipeline configuration for a compiled plan.

    Per tick every stage fires once on ``microbatch / data`` frames; the
    slowest stage body sets the compute time, the interior edges (grouped
    into shape classes per ``edge_mode`` — boxed classes pay for their
    padding) set the comm time. The serial schedule pays
    ``t_compute + t_comm`` per tick over ``M + (S-1)`` ticks; the
    overlapped schedule pays ``max(t_compute, t_comm)`` over
    ``M + 2(S-1)`` ticks (double-buffered edge slots — latency traded for
    concurrency, see ``pipeline.py``).
    """
    from repro.core.dhm.pipeline import plan_edges

    c = constants or PipelineCostConstants()
    S = plan.n_stages
    M = int(n_microbatches)
    if microbatch % data:
        raise ValueError(
            f"batch grain {microbatch} not divisible by data split {data}"
        )
    mb_local = microbatch // data
    stage_flops, _ = pipeline_workload(plan)
    f_max = max(stage_flops)
    ep = plan_edges([st.io for st in plan.stages], mode=edge_mode)
    # Boxed classes ship the class buffer for every edge in the class —
    # padding included — which is exactly what the executor sends.
    class_bytes = ep.class_bytes(4)
    sent_bytes = sum(class_bytes[ep.edge_class[e]] for e in range(ep.n_edges))
    t_compute = f_max * mb_local / c.flops_per_s
    t_comm = sent_bytes * mb_local / c.bytes_per_s
    delay = (2 if overlap else 1) * (S - 1)
    n_ticks = M + delay
    body = max(t_compute, t_comm) if overlap else t_compute + t_comm
    t_tick = c.tick_overhead_s + body
    total = n_ticks * t_tick
    mean_flops = sum(stage_flops) / len(stage_flops)
    return PipelineEstimate(
        n_ticks=n_ticks,
        t_compute_s=t_compute,
        t_comm_s=t_comm,
        t_tick_s=t_tick,
        total_s=total,
        frames_per_s=M * microbatch / total,
        bubble_fraction=delay / n_ticks,
        imbalance=f_max / mean_flops if mean_flops else 1.0,
    )


# ---------------------------------------------------------------------------
# Fitting the constants from measured sweeps.


def sweep_sample(
    plan,
    *,
    n_microbatches: int,
    microbatch: int,
    data: int,
    frames_per_s: float,
    overlap: bool = False,
    edge_mode: str = "auto",
) -> dict:
    """One measured sweep point in the form :func:`fit_constants` solves
    on: the per-run totals of the three cost features (FLOPs on the
    critical stage, edge bytes shipped, tick count) plus the measured
    wall time."""
    from repro.core.dhm.pipeline import plan_edges

    S = plan.n_stages
    M = int(n_microbatches)
    mb_local = microbatch // data
    stage_flops, _ = pipeline_workload(plan)
    ep = plan_edges([st.io for st in plan.stages], mode=edge_mode)
    class_bytes = ep.class_bytes(4)
    sent = sum(class_bytes[ep.edge_class[e]] for e in range(ep.n_edges))
    n_ticks = M + (2 if overlap else 1) * (S - 1)
    return {
        "flops": n_ticks * max(stage_flops) * mb_local,
        "bytes": n_ticks * sent * mb_local,
        "ticks": float(n_ticks),
        "total_s": M * microbatch / frames_per_s,
        "overlap": bool(overlap),
    }


def fit_constants(samples: Sequence[dict]) -> PipelineCostConstants:
    """Least-squares fit of the three machine constants from measured
    serial-schedule sweep points (overlapped samples are excluded: their
    tick body is a max(), not a sum, so they are nonlinear in the
    constants). Falls back to defaults when the system is degenerate or
    the fit goes nonpositive (a sweep too small/collinear to trust)."""
    import numpy as np

    serial = [s for s in samples if not s.get("overlap")]
    if len(serial) < 3:
        return PipelineCostConstants()
    A = np.array(
        [[s["flops"], s["bytes"], s["ticks"]] for s in serial], dtype=float
    )
    b = np.array([s["total_s"] for s in serial], dtype=float)
    try:
        coef, _, rank, _ = np.linalg.lstsq(A, b, rcond=None)
    except np.linalg.LinAlgError:
        return PipelineCostConstants()
    if rank < 3 or np.any(coef <= 0):
        return PipelineCostConstants()
    inv_flops, inv_bytes, overhead = coef
    return PipelineCostConstants(
        flops_per_s=1.0 / inv_flops,
        bytes_per_s=1.0 / inv_bytes,
        tick_overhead_s=float(overhead),
        source="fitted",
    )


def load_sweep_measurements(
    history_path, topology: str, label: str = "fp32"
) -> list:
    """The ``path: pipeline_sweep`` rows recorded for one
    (topology, precision) across every run in ``BENCH_history.jsonl`` —
    the measured crossover sweep the autotuner trusts over its own model.
    Returns the raw row dicts (n_microbatches/microbatch/data/overlap/
    edge_mode/frames_per_s); missing file -> empty list."""
    path = pathlib.Path(history_path)
    if not path.exists():
        return []
    out = []
    for line in path.read_text().splitlines():
        line = line.strip()
        if not line:
            continue
        try:
            rec = json.loads(line)
        except json.JSONDecodeError:
            continue
        for row in rec.get("rows", ()):
            if (
                row.get("path") == "pipeline_sweep"
                and row.get("topology") == topology
                and row.get("label") == label
            ):
                out.append(row)
    return out


# ---------------------------------------------------------------------------
# The autotuner.


@dataclasses.dataclass(frozen=True)
class PipelineTuning:
    """The configuration the autotuner picked for (plan, device count):
    ``source`` records whether it came off a measured sweep point
    ("measured" — preferred whenever measurements exist) or the fitted
    cost model ("model"); ``estimate`` carries the model's pricing of the
    choice either way."""

    n_stages: int
    n_microbatches: int
    microbatch: int
    data: int
    overlap: bool
    edge_mode: str
    source: str
    frames_per_s: float  # measured (source="measured") or model estimate
    estimate: Optional[PipelineEstimate] = None

    def summary(self) -> str:
        return (
            f"S={self.n_stages} M={self.n_microbatches} "
            f"mb={self.microbatch} data={self.data} "
            f"overlap={self.overlap} edges={self.edge_mode} "
            f"[{self.source}] ~{self.frames_per_s:.0f} frames/s"
        )


def candidate_grid(
    plan,
    n_devices: int,
    *,
    microbatches: Sequence[int] = (1, 2, 4, 8),
    grains: Sequence[int] = (8, 16, 32),
    overlaps: Sequence[bool] = (False, True),
    edge_mode: str = "auto",
) -> list:
    """All (M, grain, data split, overlap) candidates that fit the mesh:
    the data split is whatever the stage axis leaves over, and the batch
    grain must divide across it."""
    S = plan.n_stages
    data = max(1, n_devices // S)
    out = []
    for mb in grains:
        if mb % data:
            continue
        for M in microbatches:
            for ov in overlaps:
                out.append(
                    {
                        "n_microbatches": int(M),
                        "microbatch": int(mb),
                        "data": int(data),
                        "overlap": bool(ov),
                        "edge_mode": edge_mode,
                    }
                )
    return out


def autotune_pipeline(
    plan,
    n_devices: int,
    *,
    measurements: Sequence[dict] = (),
    constants: Optional[PipelineCostConstants] = None,
    microbatches: Sequence[int] = (1, 2, 4, 8),
    grains: Sequence[int] = (8, 16, 32),
    overlaps: Sequence[bool] = (False, True),
    edge_mode: str = "auto",
) -> PipelineTuning:
    """Pick (n_microbatches, batch grain, data split, overlap) for a plan
    on an ``n_devices`` mesh.

    Measured sweep points (``measurements`` — e.g. from
    :func:`load_sweep_measurements`) outrank the model: when any
    measurement fits the mesh, the tuner returns the fastest *measured*
    configuration, so its choice is by construction within 0% of the best
    measured sweep point. Only with no usable measurements does it fall
    back to pricing the candidate grid with :func:`estimate_pipeline`
    under ``constants`` (fit them from the sweep via
    :func:`fit_constants` when you have one).
    """
    S = plan.n_stages
    data = max(1, n_devices // S)
    if constants is None:
        samples = [
            sweep_sample(
                plan,
                n_microbatches=m["n_microbatches"],
                microbatch=m["microbatch"],
                data=m["data"],
                frames_per_s=m["frames_per_s"],
                overlap=m.get("overlap", False),
                edge_mode=m.get("edge_mode", "auto"),
            )
            for m in measurements
            if m.get("n_stages", S) == S
        ]
        constants = fit_constants(samples)

    usable = [
        m
        for m in measurements
        if m.get("n_stages", S) == S
        and m.get("data", data) == data
        and m.get("frames_per_s", 0) > 0
    ]
    if usable:
        best = max(usable, key=lambda m: m["frames_per_s"])
        est = estimate_pipeline(
            plan,
            n_microbatches=best["n_microbatches"],
            microbatch=best["microbatch"],
            data=best["data"],
            overlap=best.get("overlap", False),
            edge_mode=best.get("edge_mode", "auto"),
            constants=constants,
        )
        return PipelineTuning(
            n_stages=S,
            n_microbatches=int(best["n_microbatches"]),
            microbatch=int(best["microbatch"]),
            data=int(best["data"]),
            overlap=bool(best.get("overlap", False)),
            edge_mode=str(best.get("edge_mode", "auto")),
            source="measured",
            frames_per_s=float(best["frames_per_s"]),
            estimate=est,
        )

    cands = candidate_grid(
        plan,
        n_devices,
        microbatches=microbatches,
        grains=grains,
        overlaps=overlaps,
        edge_mode=edge_mode,
    )
    if not cands:
        raise ValueError(
            f"no pipeline candidate fits {n_devices} devices for "
            f"{S} stages (grains {tuple(grains)})"
        )
    best_c, best_est = None, None
    for cand in cands:
        est = estimate_pipeline(plan, constants=constants, **cand)
        if best_est is None or est.frames_per_s > best_est.frames_per_s:
            best_c, best_est = cand, est
    return PipelineTuning(
        n_stages=S,
        n_microbatches=best_c["n_microbatches"],
        microbatch=best_c["microbatch"],
        data=best_c["data"],
        overlap=best_c["overlap"],
        edge_mode=best_c["edge_mode"],
        source="model",
        frames_per_s=best_est.frames_per_s,
        estimate=best_est,
    )
