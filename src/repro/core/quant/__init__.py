"""Quantization substrate implementing the paper's arithmetic tactics.

- ``fixed_point``: Q-format fixed-point representation (paper §4.1) with
  straight-through-estimator fake-quant for quantization-aware fine-tuning.
- ``pow2``: classification/projection of parameters onto {0, ±1, ±2^k}
  (paper §4.2, the constant-specialized-multiplier tactic) and the Table 1
  parameter-class histogram.
- ``packing``: 4-bit (sign | log2-magnitude | zero) code packing used by the
  Pallas pow2 matmul kernel.
- ``bitwidth_search``: the Fig. 3 accuracy-vs-bit-width exploration harness.
"""
from repro.core.quant.fixed_point import (
    FixedPointSpec,
    quantize_fixed,
    dequantize_fixed,
    fake_quant,
    fake_quant_ste,
)
from repro.core.quant.pow2 import (
    ParamClassStats,
    classify_params,
    project_pow2,
    pow2_codes,
    decode_pow2,
    POW2_ZERO_CODE,
)
from repro.core.quant.packing import pack_codes_u4, unpack_codes_u4
from repro.core.quant.bitwidth_search import BitwidthSearchResult, search_bitwidth

__all__ = [
    "FixedPointSpec",
    "quantize_fixed",
    "dequantize_fixed",
    "fake_quant",
    "fake_quant_ste",
    "ParamClassStats",
    "classify_params",
    "project_pow2",
    "pow2_codes",
    "decode_pow2",
    "POW2_ZERO_CODE",
    "pack_codes_u4",
    "unpack_codes_u4",
    "BitwidthSearchResult",
    "search_bitwidth",
]
