"""Bit-width exploration (paper Fig. 3).

The paper selects each network's fixed-point width by sweeping bit-widths and
keeping the smallest one whose (optionally fine-tuned) accuracy stays within
an acceptable drop of the float baseline (3 bits for LeNet5, 6 for
SVHN/CIFAR10). This harness is model-agnostic: callers supply

  eval_quantized(bits)  -> accuracy of the model quantized at ``bits``
                           (the callable decides whether to fine-tune, mirror
                           the paper's footnote-2 retraining, etc.)

and the float baseline accuracy.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Mapping, Sequence


@dataclasses.dataclass(frozen=True)
class BitwidthSearchResult:
    float_accuracy: float
    accuracy_by_bits: Mapping[int, float]
    selected_bits: int
    max_drop: float

    def curve(self) -> list:
        """(bits, accuracy) pairs, ascending bits — the Fig. 3 curve."""
        return sorted(self.accuracy_by_bits.items())


def search_bitwidth(
    eval_quantized: Callable[[int], float],
    *,
    float_accuracy: float,
    bit_range: Sequence[int] = (2, 3, 4, 5, 6, 7, 8),
    max_drop: float = 0.04,
) -> BitwidthSearchResult:
    """Sweep bit-widths ascending; select the smallest within ``max_drop``
    (absolute accuracy drop) of the float baseline.

    The full curve is evaluated (not early-stopped) because the paper reports
    the whole exploration, and the curve is itself a deliverable (Fig. 3).
    """
    accs = {int(b): float(eval_quantized(int(b))) for b in bit_range}
    selected = max(bit_range)
    for b in sorted(accs):
        if float_accuracy - accs[b] <= max_drop:
            selected = b
            break
    return BitwidthSearchResult(
        float_accuracy=float(float_accuracy),
        accuracy_by_bits=accs,
        selected_bits=int(selected),
        max_drop=float(max_drop),
    )
