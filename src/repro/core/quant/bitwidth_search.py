"""Bit-width exploration (paper Fig. 3).

The paper selects each network's fixed-point width by sweeping bit-widths and
keeping the smallest one whose (optionally fine-tuned) accuracy stays within
an acceptable drop of the float baseline (3 bits for LeNet5, 6 for
SVHN/CIFAR10). This harness is model-agnostic: callers supply

  eval_quantized(bits)  -> accuracy of the model quantized at ``bits``
                           (the callable decides whether to fine-tune, mirror
                           the paper's footnote-2 retraining, etc.)

and the float baseline accuracy.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Mapping, Sequence


@dataclasses.dataclass(frozen=True)
class BitwidthSearchResult:
    float_accuracy: float
    accuracy_by_bits: Mapping[int, float]
    selected_bits: int
    max_drop: float

    def curve(self) -> list:
        """(bits, accuracy) pairs, ascending bits — the Fig. 3 curve."""
        return sorted(self.accuracy_by_bits.items())


def search_bitwidth(
    eval_quantized: Callable[[int], float],
    *,
    float_accuracy: float,
    bit_range: Sequence[int] = (2, 3, 4, 5, 6, 7, 8),
    max_drop: float = 0.04,
) -> BitwidthSearchResult:
    """Sweep bit-widths ascending; select the smallest within ``max_drop``
    (absolute accuracy drop) of the float baseline.

    The full curve is evaluated (not early-stopped) because the paper reports
    the whole exploration, and the curve is itself a deliverable (Fig. 3).
    """
    accs = {int(b): float(eval_quantized(int(b))) for b in bit_range}
    selected = max(bit_range)
    for b in sorted(accs):
        if float_accuracy - accs[b] <= max_drop:
            selected = b
            break
    return BitwidthSearchResult(
        float_accuracy=float(float_accuracy),
        accuracy_by_bits=accs,
        selected_bits=int(selected),
        max_drop=float(max_drop),
    )


def search_plan_bitwidths(
    topo,
    params: dict,
    evaluate: Callable,
    *,
    float_accuracy: float,
    bit_range: Sequence[int] = (2, 3, 4, 5, 6, 7, 8),
    max_drop: float = 0.04,
    int8_compute: bool = False,
    **compile_kw,
):
    """The Fig. 3 sweep as a COMPILER knob: each candidate width compiles
    to a real :class:`~repro.core.dhm.compiler.CompiledDHM` (weights and
    feature-stream quantization baked at that width) and ``evaluate(plan)``
    scores it; the selected width lands on the returned plan as a
    ``QuantSpec.per_layer_bits`` attribute — a compile-time plan property
    the cost model and invariants can see, not an offline note.

    ``int8_compute=True`` restricts the sweep to widths <= 8 and compiles
    the candidates (and the final plan) on the true-integer path.

    Returns ``(BitwidthSearchResult, CompiledDHM)`` — the curve plus the
    plan compiled at the selected width.
    """
    from repro.core.dhm.compiler import QuantSpec, compile_dhm

    bits = [int(b) for b in bit_range]
    if int8_compute:
        bits = [b for b in bits if b <= 8]
        if not bits:
            raise ValueError(
                f"int8_compute sweep needs widths <= 8, got {bit_range}"
            )

    def _plan(b: int):
        return compile_dhm(
            topo,
            params,
            quant=QuantSpec(
                weight_bits=b, act_bits=b, int8_compute=int8_compute
            ),
            **compile_kw,
        )

    result = search_bitwidth(
        lambda b: evaluate(_plan(b)),
        float_accuracy=float_accuracy,
        bit_range=bits,
        max_drop=max_drop,
    )
    b = result.selected_bits
    final = compile_dhm(
        topo,
        params,
        quant=QuantSpec(
            weight_bits=b,
            act_bits=b,
            int8_compute=int8_compute,
            per_layer_bits=(b,) * len(topo.conv_layers),
        ),
        **compile_kw,
    )
    return result, final
