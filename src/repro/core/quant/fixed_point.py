"""Fixed-point Q-format quantization (paper §4.1).

The paper represents data and parameters in short fixed-point formats
(3 bits for LeNet5, 6 bits for SVHN/CIFAR10). A ``b``-bit signed two's
complement Q(m, f) number has one sign bit, ``m`` integer bits and ``f``
fractional bits with b = 1 + m + f, representable range
[-2^m, 2^m - 2^-f] with step 2^-f.

``fake_quant_ste`` implements quantization-aware training with the
straight-through estimator (identity gradient), used for the paper's
post-bit-width-selection fine-tuning step (footnote 2).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class FixedPointSpec:
    """A signed two's-complement fixed-point format.

    Attributes:
      bits: total bit-width, including the sign bit. Must be >= 2.
      frac_bits: number of fractional bits ``f``. The scale is ``2**-f``.
    """

    bits: int
    frac_bits: int

    def __post_init__(self) -> None:
        if self.bits < 2:
            raise ValueError(f"fixed-point needs >=2 bits, got {self.bits}")

    @property
    def int_bits(self) -> int:
        return self.bits - 1 - self.frac_bits

    @property
    def scale(self) -> float:
        return 2.0 ** (-self.frac_bits)

    @property
    def qmin(self) -> int:
        return -(2 ** (self.bits - 1))

    @property
    def qmax(self) -> int:
        return 2 ** (self.bits - 1) - 1

    @property
    def min_value(self) -> float:
        return self.qmin * self.scale

    @property
    def max_value(self) -> float:
        return self.qmax * self.scale

    @staticmethod
    def for_tensor(x: jax.Array, bits: int) -> "FixedPointSpec":
        """Choose frac_bits so the tensor's max-abs value fits (paper's
        'inferring the minimal required precision')."""
        max_abs = float(jnp.max(jnp.abs(x)))
        if max_abs == 0.0 or not jnp.isfinite(max_abs):
            return FixedPointSpec(bits=bits, frac_bits=bits - 1)
        # Smallest m with 2^m >= max_abs, then f = bits - 1 - m. m may be
        # negative (small-magnitude tensors get extra fractional bits) and
        # f may be negative (scale > 1 for large-magnitude tensors).
        import math

        m = math.ceil(math.log2(max_abs + 1e-12))
        return FixedPointSpec(bits=bits, frac_bits=bits - 1 - m)


def quantize_fixed(x: jax.Array, spec: FixedPointSpec) -> jax.Array:
    """Quantize to integer codes (int32) with round-to-nearest-even."""
    q = jnp.round(x / spec.scale)
    return jnp.clip(q, spec.qmin, spec.qmax).astype(jnp.int32)


def dequantize_fixed(q: jax.Array, spec: FixedPointSpec) -> jax.Array:
    return q.astype(jnp.float32) * spec.scale


def fake_quant(x: jax.Array, spec: FixedPointSpec) -> jax.Array:
    """Quantize-dequantize round trip (no gradient defined)."""
    return dequantize_fixed(quantize_fixed(x, spec), spec)


@jax.custom_vjp
def _ste(x: jax.Array, scale: jax.Array, qmin: jax.Array, qmax: jax.Array):
    q = jnp.clip(jnp.round(x / scale), qmin, qmax)
    return q * scale


def _ste_fwd(x, scale, qmin, qmax):
    return _ste(x, scale, qmin, qmax), (x, scale, qmin, qmax)


def _ste_bwd(res, g):
    x, scale, qmin, qmax = res
    # Straight-through inside the representable range; zero outside
    # (clipped values carry no gradient).
    inside = jnp.logical_and(x >= qmin * scale, x <= qmax * scale)
    return (jnp.where(inside, g, 0.0), None, None, None)


_ste.defvjp(_ste_fwd, _ste_bwd)


def fake_quant_ste(x: jax.Array, spec: FixedPointSpec) -> jax.Array:
    """Fake-quant with straight-through-estimator gradients (QAT)."""
    return _ste(
        x,
        jnp.asarray(spec.scale, x.dtype),
        jnp.asarray(spec.qmin, x.dtype),
        jnp.asarray(spec.qmax, x.dtype),
    )


def dynamic_spec(x: jax.Array, bits: int) -> FixedPointSpec:
    """The STATIC ``FixedPointSpec`` whose pow2 scale equals the in-graph
    scale ``fake_quant_dynamic`` would derive for this tensor.

    Mirrors ``fake_quant_dynamic`` op-for-op (``jnp`` float32 ``log2`` /
    ``ceil`` on ``max(|x|, 1e-12)``) rather than going through
    ``for_tensor``: the two differ when ``max|x|`` lands exactly on a
    power of two (``for_tensor`` adds 1e-12 before the log, which tips
    ``ceil`` up a notch), and the true-int8 compile path needs its baked
    integer codes to reproduce the fake-quant values bit-exactly.
    """
    max_abs = jnp.max(jnp.abs(jax.lax.stop_gradient(x)))
    m = int(jnp.ceil(jnp.log2(jnp.maximum(max_abs, 1e-12))))
    return FixedPointSpec(bits=bits, frac_bits=(bits - 1) - m)


def fake_quant_dynamic(x: jax.Array, bits: int) -> jax.Array:
    """Trace-compatible fake-quant: the power-of-two scale is derived from the
    live tensor max (``for_tensor`` done in-graph), with STE gradients.

    Used for QAT where parameters move during training so the Q-format must
    track them; at export time the final static ``FixedPointSpec`` is taken
    from the trained tensor.
    """
    max_abs = jnp.max(jnp.abs(jax.lax.stop_gradient(x)))
    m = jnp.ceil(jnp.log2(jnp.maximum(max_abs, 1e-12)))
    scale = jnp.exp2(m - (bits - 1)).astype(x.dtype)
    qmax = jnp.asarray(2 ** (bits - 1) - 1, x.dtype)
    qmin = jnp.asarray(-(2 ** (bits - 1)), x.dtype)
    return _ste(x, scale, qmin, qmax)
