"""Packing of 4-bit pow2 codes, two per byte.

The Pallas pow2 matmul kernel streams weights as uint8 with two 4-bit codes
per byte (even index in the low nibble), a 4x footprint/bandwidth reduction
vs bf16 — the TPU translation of the paper's multiplier-area reduction.

Packing is along the *last* axis, which must be even.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def pack_codes_u4(codes: jax.Array) -> jax.Array:
    """Pack uint8 codes in [0,16) two-per-byte along the last axis."""
    codes = jnp.asarray(codes, dtype=jnp.uint8)
    if codes.shape[-1] % 2 != 0:
        raise ValueError(f"last axis must be even, got {codes.shape}")
    lo = codes[..., 0::2]
    hi = codes[..., 1::2]
    return jnp.bitwise_or(lo, jnp.left_shift(hi, 4)).astype(jnp.uint8)


def unpack_codes_u4(packed: jax.Array) -> jax.Array:
    """Inverse of :func:`pack_codes_u4`."""
    packed = jnp.asarray(packed, dtype=jnp.uint8)
    lo = jnp.bitwise_and(packed, 0x0F)
    hi = jnp.right_shift(packed, 4)
    out = jnp.stack([lo, hi], axis=-1)
    return out.reshape(*packed.shape[:-1], packed.shape[-1] * 2)
