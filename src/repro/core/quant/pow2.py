"""Power-of-two ("constant-specialized multiplier") quantization (paper §4.2).

The paper exploits that, after short fixed-point quantization, >90% of CNN
parameters fall into {0, ±1, ±2^k}: multiplications by those constants need
no multiplier at all (removed / wire / shift). Two pieces live here:

1. ``classify_params`` — the Table 1 histogram: the fraction of quantized
   parameters that are exactly zero / ±1 / ±2^k / other.

2. A logarithmic (pow2-codebook) weight representation used by the TPU
   adaptation: each weight is a 4-bit code ``(sign, magnitude-index)`` with a
   per-output-channel float scale:

       code 0          -> 0.0
       code m, sign s  -> (-1)^s * scale * 2^(m-1),   m in [1..7]

   i.e. 7 octaves of magnitude per sign plus exact zero. Decoding a code is
   an *exponent add* (a shift), never a multiply — the TPU-native analogue of
   the paper's shift-register multipliers. Codes pack two-per-byte (see
   ``packing.py``) giving 4-bit weight storage.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

# Number of non-zero magnitude levels per sign (3 magnitude bits, m=1..7).
POW2_LEVELS = 7
POW2_ZERO_CODE = 0
# Largest representable multiple of the scale: 2^(POW2_LEVELS-1).
POW2_MAX_MAG = 2 ** (POW2_LEVELS - 1)


@dataclasses.dataclass(frozen=True)
class ParamClassStats:
    """Fractions of quantized parameters per multiplier-specialization class
    (paper Table 1)."""

    zero: float
    one: float
    pow2: float
    other: float
    total: int

    @property
    def multiplierless(self) -> float:
        """Fraction of parameters needing no hardware multiplier."""
        return self.zero + self.one + self.pow2

    def as_percent(self) -> dict:
        return {
            "zero %": 100.0 * self.zero,
            "one %": 100.0 * self.one,
            "pow2 %": 100.0 * self.pow2,
            "other %": 100.0 * self.other,
        }


def _is_pow2_int(q: jnp.ndarray) -> jnp.ndarray:
    """True where |q| is a (positive) power of two, elementwise, int32 input."""
    a = jnp.abs(q)
    return jnp.logical_and(a > 0, jnp.bitwise_and(a, a - 1) == 0)


def classify_params(q_codes: jax.Array, frac_bits: int) -> ParamClassStats:
    """Classify integer fixed-point codes into zero/one/pow2/other.

    A code ``q`` represents the value ``q * 2**-frac_bits``; the value is
    ±1 iff |q| == 2**frac_bits, and a power of two iff |q| is a power of two
    (positive or negative exponents both count: x0.5 is a shift as well).
    """
    q = jnp.asarray(q_codes).astype(jnp.int32).ravel()
    total = q.size
    one_mag = 2**frac_bits if frac_bits >= 0 else 0
    is_zero = q == 0
    is_one = jnp.abs(q) == one_mag if one_mag > 0 else jnp.zeros_like(is_zero)
    is_p2 = jnp.logical_and(_is_pow2_int(q), jnp.logical_not(is_one))
    n_zero = int(jnp.sum(is_zero))
    n_one = int(jnp.sum(is_one))
    n_p2 = int(jnp.sum(is_p2))
    n_other = total - n_zero - n_one - n_p2
    return ParamClassStats(
        zero=n_zero / total,
        one=n_one / total,
        pow2=n_p2 / total,
        other=n_other / total,
        total=total,
    )


def _per_channel_scale(w: jax.Array, axis: int) -> jax.Array:
    """Scale so the largest magnitude maps to the top code (2^6 * scale)."""
    reduce_axes = tuple(i for i in range(w.ndim) if i != axis)
    max_abs = jnp.max(jnp.abs(w), axis=reduce_axes, keepdims=True)
    # Guard all-zero channels.
    max_abs = jnp.where(max_abs == 0, 1.0, max_abs)
    return max_abs / POW2_MAX_MAG


def pow2_codes(w: jax.Array, *, channel_axis: int = -1):
    """Quantize ``w`` to the pow2 codebook.

    Returns:
      codes: uint8 array, same shape as w, values in [0, 15]:
             bit 3 = sign, bits 2:0 = magnitude index m (0 => zero).
      scale: float32 per-channel scale, broadcastable against w.
    """
    w = jnp.asarray(w)
    axis = channel_axis % w.ndim
    scale = _per_channel_scale(w, axis).astype(jnp.float32)
    normalized = w.astype(jnp.float32) / scale  # in [-64, 64]
    mag = jnp.abs(normalized)
    # Round in the log domain to the nearest power of two:
    # exponent e = round(log2(mag)), clipped to [0, 6]; m = e + 1.
    safe = jnp.maximum(mag, 1e-30)
    e = jnp.round(jnp.log2(safe))
    e = jnp.clip(e, 0, POW2_LEVELS - 1)
    # Underflow to zero: values closer to 0 than to scale*2^0 in log space.
    # The geometric midpoint between 0 and 1 in this codebook is 2^-0.5.
    is_zero = mag < 2.0**-0.5
    m = jnp.where(is_zero, 0, e.astype(jnp.int32) + 1)
    sign_bit = (normalized < 0).astype(jnp.int32) << 3
    codes = jnp.where(m == 0, 0, sign_bit | m).astype(jnp.uint8)
    return codes, scale


def decode_pow2(codes: jax.Array, scale: jax.Array) -> jax.Array:
    """Decode 4-bit pow2 codes back to float32 values.

    The decode path is multiplication-free in spirit: 2^(m-1) is produced by
    exponent construction (ldexp), and the per-channel scale is folded into
    the activation/output path at one multiply per *channel*, not per weight.
    Here (the reference) we fold it directly for clarity.
    """
    codes = jnp.asarray(codes)
    m = jnp.bitwise_and(codes, 0x7).astype(jnp.int32)
    sign = jnp.where(jnp.bitwise_and(codes, 0x8) != 0, -1.0, 1.0)
    mag = jnp.where(m == 0, 0.0, jnp.exp2((m - 1).astype(jnp.float32)))
    return sign * mag * scale


def project_pow2(w: jax.Array, *, channel_axis: int = -1) -> jax.Array:
    """Project weights onto the nearest pow2-codebook value (round trip)."""
    codes, scale = pow2_codes(w, channel_axis=channel_axis)
    return decode_pow2(codes, scale).astype(w.dtype)


@jax.custom_vjp
def _pow2_ste(w: jax.Array):
    return project_pow2(w)


def _pow2_ste_fwd(w):
    return _pow2_ste(w), None


def _pow2_ste_bwd(_, g):
    return (g,)


_pow2_ste.defvjp(_pow2_ste_fwd, _pow2_ste_bwd)


def project_pow2_ste(w: jax.Array) -> jax.Array:
    """Pow2 projection with straight-through gradients (for pow2-aware
    fine-tuning, the TPU analogue of the paper's post-quantization retrain)."""
    return _pow2_ste(w)
