"""Data pipeline: deterministic synthetic datasets (offline container) with
sharded host loading and prefetch for the distributed training loop."""
from repro.data.synthetic import (
    SyntheticImageDataset,
    make_image_dataset,
    synthetic_token_batches,
    TokenStreamConfig,
)
from repro.data.loader import ShardedLoader

__all__ = [
    "SyntheticImageDataset",
    "make_image_dataset",
    "synthetic_token_batches",
    "TokenStreamConfig",
    "ShardedLoader",
]
