"""Sharded host→device loader with background prefetch.

On a real multi-host TPU deployment each host produces only its slice of the
global batch; ``ShardedLoader`` reproduces that contract: it takes a host
iterator of numpy batches plus a ``jax.sharding.NamedSharding`` for each
array, slices out this process's shard, and overlaps host generation with
device compute via a small prefetch queue of ``jax.device_put`` futures
(device transfers in JAX are async, so holding K in-flight batches is enough
to hide host latency — the standard MaxText/t5x pattern).
"""
from __future__ import annotations

import collections
from typing import Iterator, Mapping

import jax
import numpy as np


class ShardedLoader:
    def __init__(
        self,
        host_iter: Iterator[Mapping[str, np.ndarray]],
        shardings: Mapping[str, jax.sharding.Sharding] | None = None,
        *,
        prefetch: int = 2,
    ) -> None:
        self._host_iter = host_iter
        self._shardings = shardings
        self._prefetch = max(1, prefetch)
        self._queue: collections.deque = collections.deque()

    def _put(self, batch: Mapping[str, np.ndarray]):
        if self._shardings is None:
            return {k: jax.device_put(v) for k, v in batch.items()}
        out = {}
        for k, v in batch.items():
            sharding = self._shardings.get(k)
            if sharding is None:
                out[k] = jax.device_put(v)
            else:
                # make_array_from_process_local_data handles the host-slice →
                # global-array assembly on multi-host; on one host it's a put.
                out[k] = jax.make_array_from_process_local_data(sharding, v)
        return out

    def __iter__(self):
        return self

    def __next__(self):
        while len(self._queue) < self._prefetch:
            try:
                self._queue.append(self._put(next(self._host_iter)))
            except StopIteration:
                break
        if not self._queue:
            raise StopIteration
        return self._queue.popleft()
