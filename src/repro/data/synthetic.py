"""Deterministic synthetic datasets.

This container ships no MNIST/CIFAR/SVHN, so the paper-faithful CNN
experiments train on a *structured* synthetic classification task: each class
is a smooth random template; samples are template + per-sample deformation +
noise. The task is (a) learnable by the paper's topologies, (b) hard enough
that accuracy degrades as bit-width shrinks — which is the property Fig. 3
measures.

For LM training, ``synthetic_token_batches`` yields an affine-recurrence
token stream with injected noise: next = (a * prev + b) mod V with
probability (1-eps), uniform otherwise. The induced conditional entropy gives
a known loss floor, so training curves have a meaningful target.
"""
from __future__ import annotations

import dataclasses
from typing import Iterator

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class SyntheticImageDataset:
    x_train: jax.Array  # (N, H, W, C) float32 in [-1, 1]
    y_train: jax.Array  # (N,) int32
    x_test: jax.Array
    y_test: jax.Array
    n_classes: int


def _smooth_field(key: jax.Array, hw: int, channels: int, cutoff: int = 6):
    """Low-frequency random field: random spectrum, zeroed high frequencies."""
    spec = jax.random.normal(key, (hw, hw, channels, 2))
    spec = spec[..., 0] + 1j * spec[..., 1]
    fx = jnp.fft.fftfreq(hw) * hw
    mask = (jnp.abs(fx)[:, None] <= cutoff) & (jnp.abs(fx)[None, :] <= cutoff)
    spec = spec * mask[..., None]
    field = jnp.fft.ifft2(spec, axes=(0, 1)).real
    field = field / (jnp.max(jnp.abs(field), axis=(0, 1), keepdims=True) + 1e-9)
    return field.astype(jnp.float32)


def make_image_dataset(
    *,
    hw: int,
    channels: int,
    n_classes: int = 10,
    n_train_per_class: int = 256,
    n_test_per_class: int = 64,
    noise: float = 1.3,
    seed: int = 0,
) -> SyntheticImageDataset:
    key = jax.random.PRNGKey(seed)
    tkey, trkey, tekey = jax.random.split(key, 3)
    templates = jnp.stack(
        [_smooth_field(k, hw, channels) for k in jax.random.split(tkey, n_classes)]
    )  # (n_classes, H, W, C)

    def _make_split(key, n_per_class):
        n = n_classes * n_per_class
        y = jnp.tile(jnp.arange(n_classes), n_per_class).astype(jnp.int32)
        nkey, skey = jax.random.split(key)
        eps = jax.random.normal(nkey, (n, hw, hw, channels)) * noise
        # Per-sample random gain in [0.7, 1.3] to prevent trivial matching.
        gain = jax.random.uniform(skey, (n, 1, 1, 1), minval=0.7, maxval=1.3)
        x = templates[y] * gain + eps
        return jnp.clip(x, -2.0, 2.0).astype(jnp.float32), y

    x_train, y_train = _make_split(trkey, n_train_per_class)
    x_test, y_test = _make_split(tekey, n_test_per_class)
    return SyntheticImageDataset(x_train, y_train, x_test, y_test, n_classes)


@dataclasses.dataclass(frozen=True)
class TokenStreamConfig:
    vocab_size: int
    seq_len: int
    batch_size: int
    noise_eps: float = 0.15
    mult: int = 31  # recurrence multiplier (coprime with typical vocabs)
    add: int = 7

    @property
    def loss_floor(self) -> float:
        """Conditional entropy of the stream in nats (optimal model loss)."""
        e, v = self.noise_eps, self.vocab_size
        p_correct = (1 - e) + e / v
        p_other = e / v
        return float(
            -(p_correct * np.log(p_correct) + (v - 1) * p_other * np.log(p_other))
        )


def synthetic_token_batches(
    cfg: TokenStreamConfig, *, seed: int = 0
) -> Iterator[dict]:
    """Infinite iterator of {'tokens': (B, T+1) int32} batches (host-side
    numpy, to mimic a real host-input pipeline feeding device puts)."""
    rng = np.random.default_rng(seed)
    v = cfg.vocab_size
    while True:
        start = rng.integers(0, v, size=(cfg.batch_size, 1))
        toks = [start]
        for _ in range(cfg.seq_len):
            nxt = (toks[-1] * cfg.mult + cfg.add) % v
            flip = rng.random((cfg.batch_size, 1)) < cfg.noise_eps
            rand = rng.integers(0, v, size=(cfg.batch_size, 1))
            toks.append(np.where(flip, rand, nxt))
        yield {"tokens": np.concatenate(toks, axis=1).astype(np.int32)}
