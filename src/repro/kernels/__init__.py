"""Pallas TPU kernels for the paper's compute hot-spots.

- ``pow2_matmul``: weight-only pow2-codebook quantized matmul — the TPU
  translation of the paper's constant-specialized multipliers (§4.2).
- ``stream_conv``: line-buffer streaming convolution — the paper's dataflow
  conv engine [10] with VMEM-resident sliding windows.

Each kernel ships as ``<name>.py`` (pl.pallas_call + BlockSpec),
``ops.py`` (jit'd public wrapper) and ``ref.py`` (pure-jnp oracle).
On this CPU container kernels run in interpret mode; on TPU the same
pallas_call lowers to Mosaic.
"""
