"""Pallas TPU kernels for the paper's compute hot-spots.

- ``pow2_matmul``: weight-only pow2-codebook quantized matmul — the TPU
  translation of the paper's constant-specialized multipliers (§4.2).
- ``stream_conv``: row-blocked streaming convolution with a fused
  conv -> bias -> activation -> 2x2-max-pool epilogue — the paper's
  dataflow conv/activation/pool actor chain [10] as one kernel, ONE MXU
  matmul per row block.

Each kernel ships as the kernel module (pl.pallas_call + BlockSpec),
``ops.py`` (jit'd public wrapper) and ``ref.py`` (pure-jnp oracle).
Backends are selected per call (``backends.py``): ``pallas`` is the
compiled default — Mosaic on TPU, an XLA lowering of the same algorithm on
platforms where compiled Pallas is unavailable (XLA:CPU) — and
``pallas_interpret`` runs the exact kernel program through the Pallas
interpreter as the correctness oracle.
"""
