"""Backend selection shared by the kernel wrappers.

Every public kernel wrapper takes an explicit ``backend=`` enum:

  ``pallas``           compiled Pallas — the production path. On TPU this
                       lowers through Mosaic. On backends where compiled
                       Pallas is unavailable (XLA:CPU only supports
                       interpret mode), the wrapper dispatches to an
                       XLA-compiled implementation of the *same* algorithm,
                       so ``pallas`` always means "compiled, fast".
  ``pallas_interpret`` the Pallas kernel body run through the Pallas
                       interpreter — slow, but executes the exact kernel
                       program; kept as the correctness oracle in tests.
  ``ref``              the pure-jnp reference (``lax.conv`` / dense matmul).

Unknown strings raise: a typo like ``"palas_interpret"`` must never silently
select a different path.
"""
from __future__ import annotations

import jax

VALID_BACKENDS = ("pallas", "pallas_interpret", "ref")

# Compiled by default. Interpret mode stays available as the oracle.
DEFAULT_BACKEND = "pallas"


def validate_backend(backend: str) -> str:
    """Raise ValueError on anything outside the enum; return it unchanged."""
    if backend not in VALID_BACKENDS:
        raise ValueError(
            f"unknown backend {backend!r}; expected one of {VALID_BACKENDS}"
        )
    return backend


def compiled_pallas_available() -> bool:
    """Whether `pallas_call(interpret=False)` can lower on this platform.

    Mosaic compiles on TPU; XLA:CPU (and GPU without Triton here) only
    supports the interpreter, so the ``pallas`` backend falls back to the
    XLA rendering of the same algorithm there.
    """
    return jax.default_backend() == "tpu"
