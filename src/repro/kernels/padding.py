"""Shared padding/rounding helpers for the kernel wrappers.

Zero padding is exact for every kernel here: zero input rows/channels
contribute zero partial sums, and zero pow2 codes decode to 0.0.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def round_up(x: int, mult: int) -> int:
    """Smallest multiple of ``mult`` >= x."""
    return -(-x // mult) * mult


def pad_axis_to(x: jax.Array, axis: int, target: int) -> jax.Array:
    """Zero-pad ``axis`` up to ``target`` elements (no-op if already there)."""
    pad = target - x.shape[axis]
    if pad <= 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


def pad_axis_to_multiple(x: jax.Array, axis: int, mult: int) -> jax.Array:
    """Zero-pad ``axis`` up to the next multiple of ``mult``."""
    return pad_axis_to(x, axis, round_up(x.shape[axis], mult))
