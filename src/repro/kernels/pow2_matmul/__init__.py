from repro.kernels.pow2_matmul.ops import pow2_matmul, quantize_weights
from repro.kernels.pow2_matmul.ref import pow2_matmul_ref

__all__ = ["pow2_matmul", "quantize_weights", "pow2_matmul_ref"]
