"""Public jit'd wrapper for the pow2 matmul: quantization, padding to block
multiples, and dispatch by backend (see ``repro.kernels.backends``).

``backend="pallas"`` (the default) means compiled: Mosaic-compiled Pallas
on TPU; on platforms without compiled Pallas (XLA:CPU) it lowers the same
decode-then-matmul semantics through the XLA reference, so the default is
always a compiled path. ``pallas_interpret`` runs the kernel body through
the Pallas interpreter (the correctness oracle); ``ref`` forces the jnp
reference. Unknown backend strings raise.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.quant.packing import pack_codes_u4
from repro.core.quant.pow2 import pow2_codes
from repro.kernels.backends import (
    DEFAULT_BACKEND,
    compiled_pallas_available,
    validate_backend,
)
from repro.kernels.padding import pad_axis_to, pad_axis_to_multiple
from repro.kernels.pow2_matmul.pow2 import pow2_matmul_pallas
from repro.kernels.pow2_matmul.ref import pow2_matmul_int_ref, pow2_matmul_ref


def quantize_weights(w: jax.Array):
    """(K, N) float weights -> (packed (K, ceil(N/2)) uint8, scale (N,) f32).

    Odd N is auto-padded with a zero column so two codes always fill a
    byte; zero codes decode to 0.0, so the pad is exact. The returned
    ``scale`` keeps the TRUE width N — it is the layer-width source of
    truth that lets ``pow2_matmul`` slice its output back to (M, N).
    """
    if w.ndim != 2:
        raise ValueError(f"expected (K, N) weights, got {w.shape}")
    n = w.shape[1]
    if n % 2:
        w = jnp.pad(w, ((0, 0), (0, 1)))
    codes, scale = pow2_codes(w, channel_axis=1)  # scale (1, N_even)
    return pack_codes_u4(codes), scale.reshape(-1)[:n]


@functools.partial(
    jax.jit,
    static_argnames=(
        "block_m", "block_n", "block_k", "out_dtype", "backend", "x_spec",
    ),
)
def pow2_matmul(
    x: jax.Array,
    packed: jax.Array,
    scale: jax.Array,
    *,
    block_m: int = 128,
    block_n: int = 128,
    block_k: int = 128,
    out_dtype=jnp.float32,
    backend: str = DEFAULT_BACKEND,  # pallas | pallas_interpret | ref
    x_spec=None,  # FixedPointSpec of x's grid -> true-integer rendering
) -> jax.Array:
    """out[m, n] = sum_k x[m, k] * decode(codes[k, n]) * scale[n].

    The true layer width N is ``scale.shape[0]``; ``packed`` carries
    ceil(N/2) bytes (odd N is zero-column-padded by ``quantize_weights``).
    Shapes need not be block-aligned; inputs are zero-padded here (honoring
    the kernel's "pad in ops.pow2_matmul" contract — zero codes decode to
    0.0, so padding is exact) and the result is sliced back to (M, N).

    ``x_spec`` (a static ``FixedPointSpec``) switches XLA-rendered routes
    to the true-integer path: pow2 codes decode to int8 shift weights,
    activations quantize onto ``x_spec``'s grid, and one int8xint8->int32
    matmul replaces the decode-to-fp32 matmul (exact for on-grid x — both
    scales are pow2). The compiled TPU Pallas kernel keeps the fp32 decode
    for now (Mosaic-native shift-add is a roadmap item), so ``x_spec`` is
    honored on ref / CPU-fallback and ignored on compiled pallas.
    """
    validate_backend(backend)
    n = scale.shape[0]
    if packed.shape[1] != (n + 1) // 2:
        raise ValueError(
            f"packed width {packed.shape[1]} inconsistent with scale length "
            f"{n} (expected ceil(N/2) = {(n + 1) // 2} bytes)"
        )
    if backend == "ref" or (
        backend == "pallas" and not compiled_pallas_available()
    ):
        if x_spec is not None:
            return pow2_matmul_int_ref(
                x, packed, scale, x_spec=x_spec, out_dtype=out_dtype
            )
        return pow2_matmul_ref(x, packed, scale, out_dtype=out_dtype)
    m, k = x.shape
    n_even = packed.shape[1] * 2
    bm, bn, bk = min(block_m, m), min(block_n, n_even), min(block_k, k)
    bn = max(2, bn - (bn % 2))
    xp = pad_axis_to_multiple(pad_axis_to_multiple(x, 0, bm), 1, bk)
    wp = pad_axis_to_multiple(pad_axis_to_multiple(packed, 0, bk), 1, bn // 2)
    sp = pad_axis_to_multiple(pad_axis_to(scale, 0, n_even), 0, bn)
    out = pow2_matmul_pallas(
        xp,
        wp,
        sp,
        block_m=bm,
        block_n=bn,
        block_k=bk,
        out_dtype=out_dtype,
        interpret=(backend == "pallas_interpret"),
    )
    return out[:m, :n]
