"""Pallas TPU kernel: weight-only pow2-codebook quantized matmul.

The TPU-native rendering of the paper's constant-specialized multipliers:

- Weights live in HBM as **4-bit codes, two per byte** — 4x less weight
  bandwidth than bf16, 8x less than f32. On the bandwidth-bound decode path
  this is the direct analogue of the paper's multiplier-area reduction.
- In-kernel decode is **multiplication-free**: a code (sign s, magnitude m)
  becomes the float 2^(m-1) by *integer exponent construction*
  (``(126 + m) << 23`` bitcast to f32) — i.e. a shift, exactly like the
  paper's shift-register multipliers. Zero codes (m=0) decode to +0.0, the
  "multiplication removed" case.
- The per-output-channel scale is folded **after** the K-reduction: one
  multiply per output element instead of one per weight (the paper folds it
  into the activation's fixed-point alignment).

Grid: (M/bm, N/bn, K/bk), K innermost; accumulation in an f32 VMEM scratch,
written out (scaled) on the last K step. Block shapes default to MXU-aligned
128x128x128; the packed weight block is (bk, bn//2) uint8.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _decode_codes_f32(codes: jax.Array) -> jax.Array:
    """4-bit (sign|mag) codes -> f32 via exponent construction (no mults).

    value = (-1)^s * 2^(m-1) for m in [1..7]; m == 0 -> +0.0.
    IEEE754: exponent_field = 127 + (m - 1) = 126 + m.
    """
    c = codes.astype(jnp.int32)
    m = jnp.bitwise_and(c, 0x7)
    s = jnp.bitwise_and(c, 0x8)
    bits = jnp.left_shift(126 + m, 23) | jnp.left_shift(s, 28)  # s<<3 -> bit31
    bits = jnp.where(m == 0, 0, bits)
    return jax.lax.bitcast_convert_type(bits, jnp.float32)


def _unpack_u4(packed: jax.Array) -> jax.Array:
    """(bk, bn//2) uint8 -> (bk, bn) uint8, even codes in low nibbles."""
    lo = jnp.bitwise_and(packed, 0x0F)
    hi = jnp.right_shift(packed, 4)
    return jnp.stack([lo, hi], axis=-1).reshape(packed.shape[0], -1)


def _pow2_matmul_kernel(x_ref, w_ref, s_ref, o_ref, acc_ref, *, out_dtype):
    k_step = pl.program_id(2)
    n_k = pl.num_programs(2)

    @pl.when(k_step == 0)
    def _zero_acc():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    codes = _unpack_u4(w_ref[...])
    w = _decode_codes_f32(codes)  # (bk, bn) f32, unit scale
    acc_ref[...] += jnp.dot(
        x_ref[...].astype(jnp.float32), w, preferred_element_type=jnp.float32
    )

    @pl.when(k_step == n_k - 1)
    def _write_out():
        o_ref[...] = (acc_ref[...] * s_ref[...].astype(jnp.float32)).astype(
            out_dtype
        )


@functools.partial(
    jax.jit,
    static_argnames=("block_m", "block_n", "block_k", "out_dtype", "interpret"),
)
def pow2_matmul_pallas(
    x: jax.Array,  # (M, K)
    packed: jax.Array,  # (K, N//2) uint8
    scale: jax.Array,  # (N,) f32
    *,
    block_m: int = 128,
    block_n: int = 128,
    block_k: int = 128,
    out_dtype=jnp.float32,
    interpret: bool = False,
) -> jax.Array:
    m, k = x.shape
    k2, n_half = packed.shape
    n = n_half * 2
    if k2 != k:
        raise ValueError(f"K mismatch: x {x.shape} vs packed {packed.shape}")
    if scale.shape != (n,):
        raise ValueError(f"scale must be ({n},), got {scale.shape}")
    bm, bn, bk = min(block_m, m), min(block_n, n), min(block_k, k)
    if m % bm or n % bn or k % bk:
        raise ValueError(
            f"shape ({m},{k},{n}) not divisible by blocks ({bm},{bk},{bn}); "
            "pad in ops.pow2_matmul"
        )
    if bn % 2:
        raise ValueError("block_n must be even (codes pack 2/byte)")

    grid = (m // bm, n // bn, k // bk)
    kernel = functools.partial(_pow2_matmul_kernel, out_dtype=out_dtype)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, s: (i, s)),
            pl.BlockSpec((bk, bn // 2), lambda i, j, s: (s, j)),
            pl.BlockSpec((bn,), lambda i, j, s: (j,)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, s: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(x, packed, scale)
