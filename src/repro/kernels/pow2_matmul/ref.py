"""Pure-jnp oracle for the pow2-quantized matmul.

Semantics: ``out = x @ decode(codes) * scale`` where codes are 4-bit
(sign | magnitude) pow2 codes packed two-per-byte along N, and ``scale`` is
the per-output-channel float scale. The oracle decodes through the same
float construction the kernel uses, so kernel-vs-ref comparison is exact
(up to accumulation order).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.quant.packing import unpack_codes_u4
from repro.core.quant.pow2 import decode_pow2


def pow2_matmul_ref(
    x: jax.Array,  # (M, K) float
    packed: jax.Array,  # (K, ceil(N/2)) uint8
    scale: jax.Array,  # (N,) float32 — N is the true layer width
    *,
    out_dtype=jnp.float32,
) -> jax.Array:
    codes = unpack_codes_u4(packed)  # (K, 2 * ceil(N/2))
    w = decode_pow2(codes, jnp.ones((), jnp.float32))  # unit-scale decode
    acc = jnp.dot(
        x.astype(jnp.float32), w, preferred_element_type=jnp.float32
    )
    # Odd N: the pad column holds zero codes; slice it off before scaling.
    n = scale.shape[0]
    return (acc[:, :n] * scale[None, :]).astype(out_dtype)


def pow2_matmul_int_ref(
    x: jax.Array,  # (M, K) float on the x_spec grid (or int8 codes)
    packed: jax.Array,  # (K, ceil(N/2)) uint8
    scale: jax.Array,  # (N,) float32 — N is the true layer width
    *,
    x_spec,  # FixedPointSpec of x's grid
    out_dtype=jnp.float32,
) -> jax.Array:
    """True-integer rendering: the pow2 codes decode to INTEGER shift
    weights (0 or ±2^(m-1), magnitude <= 64 — int8), the activations
    quantize onto their fixed-point grid as int8 codes, and ONE integer
    matmul accumulates in int32; the per-channel float scale and the
    activation scale fold in afterwards. Skips the decode-to-fp32 matmul
    entirely — the shift-add multiplier of the paper's pow2 arithmetic,
    rendered as int8 MXU arithmetic.
    """
    from repro.core.quant.fixed_point import quantize_fixed

    codes = unpack_codes_u4(packed)  # (K, 2 * ceil(N/2)) uint8
    mag = (codes & 0x7).astype(jnp.int32)
    wi = jnp.where(mag == 0, 0, 1 << jnp.maximum(mag - 1, 0))
    wi = jnp.where((codes & 0x8) != 0, -wi, wi).astype(jnp.int8)
    qx = (
        quantize_fixed(x, x_spec).astype(jnp.int8)
        if jnp.issubdtype(x.dtype, jnp.floating)
        else x
    )
    acc = jnp.dot(qx, wi, preferred_element_type=jnp.int32)
    n = scale.shape[0]
    out = acc[:, :n].astype(jnp.float32) * (x_spec.scale * scale[None, :])
    return out.astype(out_dtype)
