"""Pure-jnp oracle for the pow2-quantized matmul.

Semantics: ``out = x @ decode(codes) * scale`` where codes are 4-bit
(sign | magnitude) pow2 codes packed two-per-byte along N, and ``scale`` is
the per-output-channel float scale. The oracle decodes through the same
float construction the kernel uses, so kernel-vs-ref comparison is exact
(up to accumulation order).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.quant.packing import unpack_codes_u4
from repro.core.quant.pow2 import decode_pow2


def pow2_matmul_ref(
    x: jax.Array,  # (M, K) float
    packed: jax.Array,  # (K, ceil(N/2)) uint8
    scale: jax.Array,  # (N,) float32 — N is the true layer width
    *,
    out_dtype=jnp.float32,
) -> jax.Array:
    codes = unpack_codes_u4(packed)  # (K, 2 * ceil(N/2))
    w = decode_pow2(codes, jnp.ones((), jnp.float32))  # unit-scale decode
    acc = jnp.dot(
        x.astype(jnp.float32), w, preferred_element_type=jnp.float32
    )
    # Odd N: the pad column holds zero codes; slice it off before scaling.
    n = scale.shape[0]
    return (acc[:, :n] * scale[None, :]).astype(out_dtype)
