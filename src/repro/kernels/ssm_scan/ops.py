"""Public wrapper for the fused SSM scan. Backend enum as in
``repro.kernels.backends``: ``pallas`` (compiled default; falls back to
the XLA reference scan where compiled Pallas is unavailable),
``pallas_interpret`` (kernel-body oracle), ``ref``."""
from __future__ import annotations

import functools

import jax

from repro.kernels.backends import (
    DEFAULT_BACKEND,
    compiled_pallas_available,
    validate_backend,
)
from repro.kernels.ssm_scan.ref import ssm_scan_ref
from repro.kernels.ssm_scan.scan import ssm_scan_pallas


@functools.partial(jax.jit, static_argnames=("block_d", "backend"))
def ssm_scan(
    x, dt, b, c, a, d_skip, *, block_d: int = 256,
    backend: str = DEFAULT_BACKEND,
):
    """Fused Mamba-1 selective scan: y_t = (h_t . C_t) + D*x_t with
    h_t = exp(dt_t A) h_{t-1} + (dt_t x_t) B_t. States stay in VMEM."""
    validate_backend(backend)
    if backend == "ref" or (
        backend == "pallas" and not compiled_pallas_available()
    ):
        return ssm_scan_ref(x, dt, b, c, a, d_skip)
    di = x.shape[-1]
    bd = block_d
    while di % bd:
        bd //= 2
    return ssm_scan_pallas(
        x, dt, b, c, a, d_skip,
        block_d=max(1, bd),
        interpret=(backend == "pallas_interpret"),
    )
