"""Pure-jnp oracle for the fused selective-SSM scan.

Semantics (Mamba-1 inner recurrence, diagonal A):

    h_t = exp(dt_t * A) * h_{t-1} + (dt_t * x_t) outer B_t
    y_t = (h_t . C_t) + D * x_t

Shapes: x, dt (Bz, S, D); B, C (Bz, S, N); A (D, N); Dskip (D,).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def ssm_scan_ref(x, dt, b, c, a, d_skip):
    bz, s, di = x.shape
    n = b.shape[-1]

    def per_batch(xb, dtb, bb, cb):
        def step(h, inp):
            x_t, dt_t, b_t, c_t = inp
            dta = jnp.exp(dt_t[:, None] * a)  # (D, N)
            h = dta * h + (dt_t * x_t)[:, None] * b_t[None, :]
            y = jnp.sum(h * c_t[None, :], axis=-1) + d_skip * x_t
            return h, y

        h0 = jnp.zeros((di, n), jnp.float32)
        _, ys = jax.lax.scan(
            step, h0,
            (xb.astype(jnp.float32), dtb.astype(jnp.float32),
             bb.astype(jnp.float32), cb.astype(jnp.float32)),
        )
        return ys

    return jax.vmap(per_batch)(x, dt, b, c).astype(jnp.float32)
