"""Pallas TPU kernel: fused selective-SSM scan (Mamba-1 inner loop).

The XLA lowering of the SSM recurrence materializes the discretized
(B, S, D, N) tensors in HBM — a 2N x blowup over the model activations
that makes falcon-mamba the most memory-bound cell in the roofline. The
fused kernel is the canonical fix (it *is* Mamba's contribution on GPU,
re-tiled for TPU):

  - grid (batch, D/bd): each cell owns a (bd, N) f32 state held in a VMEM
    scratch for the whole sequence — the state never touches HBM;
  - per step: discretize (exp(dt*A)), update the state, contract with C_t
    — all in VMEM registers;
  - HBM traffic is exactly the functional inputs and outputs:
    x, dt (S, bd), B, C (S, N) in and y (S, bd) out — the (S, bd, N)
    intermediates never exist.

Sequential in S by construction (true recurrence); the parallelism is the
(batch x D-blocks) grid, which on falcon-mamba's d_inner=8192 gives
64 x batch independent cells per layer.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssm_scan_kernel(x_ref, dt_ref, b_ref, c_ref, a_ref, dskip_ref, y_ref,
                     h_ref, *, seq_len: int):
    h_ref[...] = jnp.zeros_like(h_ref)
    a = a_ref[...].astype(jnp.float32)  # (bd, N)
    d_skip = dskip_ref[...].astype(jnp.float32)  # (bd,)

    def step(t, _):
        x_t = x_ref[0, t].astype(jnp.float32)  # (bd,)
        dt_t = dt_ref[0, t].astype(jnp.float32)  # (bd,)
        b_t = b_ref[0, t].astype(jnp.float32)  # (N,)
        c_t = c_ref[0, t].astype(jnp.float32)  # (N,)
        dta = jnp.exp(dt_t[:, None] * a)  # (bd, N)
        h = dta * h_ref[...] + (dt_t * x_t)[:, None] * b_t[None, :]
        h_ref[...] = h
        y_t = jnp.sum(h * c_t[None, :], axis=-1) + d_skip * x_t
        # All-Slice indices: mixing raw ints/slices into the store index
        # breaks the state-discharge rule on some jax versions.
        pl.store(
            y_ref,
            (pl.dslice(0, 1), pl.dslice(t, 1), pl.dslice(0, y_t.shape[0])),
            y_t.astype(y_ref.dtype)[None, None],
        )
        return 0

    jax.lax.fori_loop(0, seq_len, step, 0)


@functools.partial(
    jax.jit, static_argnames=("block_d", "out_dtype", "interpret")
)
def ssm_scan_pallas(
    x: jax.Array,  # (Bz, S, D)
    dt: jax.Array,  # (Bz, S, D)
    b: jax.Array,  # (Bz, S, N)
    c: jax.Array,  # (Bz, S, N)
    a: jax.Array,  # (D, N) f32
    d_skip: jax.Array,  # (D,) f32
    *,
    block_d: int = 256,
    out_dtype=jnp.float32,
    interpret: bool = True,
) -> jax.Array:
    bz, s, di = x.shape
    n = b.shape[-1]
    bd = min(block_d, di)
    if di % bd:
        raise ValueError(f"D={di} not divisible by block_d={bd}")
    grid = (bz, di // bd)
    kernel = functools.partial(_ssm_scan_kernel, seq_len=s)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, s, bd), lambda i, j: (i, 0, j)),
            pl.BlockSpec((1, s, bd), lambda i, j: (i, 0, j)),
            pl.BlockSpec((1, s, n), lambda i, j: (i, 0, 0)),
            pl.BlockSpec((1, s, n), lambda i, j: (i, 0, 0)),
            pl.BlockSpec((bd, n), lambda i, j: (j, 0)),
            pl.BlockSpec((bd,), lambda i, j: (j,)),
        ],
        out_specs=pl.BlockSpec((1, s, bd), lambda i, j: (i, 0, j)),
        out_shape=jax.ShapeDtypeStruct((bz, s, di), out_dtype),
        scratch_shapes=[pltpu.VMEM((bd, n), jnp.float32)],
        interpret=interpret,
    )(x, dt, b, c, a, d_skip)
