from repro.kernels.stream_conv.ops import stream_conv2d
from repro.kernels.stream_conv.ref import stream_conv2d_ref

__all__ = ["stream_conv2d", "stream_conv2d_ref"]
