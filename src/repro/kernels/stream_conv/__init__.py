from repro.kernels.stream_conv.legacy import stream_conv2d_pallas_seed
from repro.kernels.stream_conv.ops import (
    stream_conv2d,
    stream_conv_block,
    stream_conv_pyramid,
)
from repro.kernels.stream_conv.ref import (
    stream_conv2d_ref,
    stream_conv_block_ref,
    stream_conv_pyramid_ref,
)

__all__ = [
    "stream_conv2d",
    "stream_conv_block",
    "stream_conv_pyramid",
    "stream_conv2d_ref",
    "stream_conv_block_ref",
    "stream_conv_pyramid_ref",
    "stream_conv2d_pallas_seed",
]
