"""Pallas TPU kernel: row/column-blocked streaming convolution with a fused
conv -> bias -> activation -> pool epilogue (paper [10], §5).

The FPGA conv engine of the paper chains three always-firing actors —
convolution, activation, pooling — with no intermediate frame storage. The
TPU rendering streams the image through the grid in **row x column blocks**
and runs the whole actor chain on each block before anything is written
back:

  grid = (B, H'/R, W'/WC, N/bn, C/bc): one (R x WC)-output tile per
  (batch, row-block, col-block, feature-block) cell, accumulated over
  channel blocks. Each step

    1. receives its input tile through the BlockSpec pipeline: an
       (R*s x WC*s) body block plus a halo strip on the bottom/right edge
       (and the corner) — the halo is the line buffer: the only pixels
       ever fetched twice. The halo width ``hb = max(0, (P_w - P_s)*s +
       K - s)`` covers both the conv window overlap (K - s) and the pool
       window overlap ((P_w - P_s) conv rows re-computed so overlapping
       pool windows never straddle a block boundary),
    2. assembles the K*K stride-s shifted views into ONE
       (R'*WC', K*K*bc) operand and issues a SINGLE MXU matmul against the
       flattened (K*K*bc, bn) tap matrix — the fully-unrolled multiplier
       array of Fig. 1-c collapsed into one systolic pass, not K*K
       per-tap dots,
    3. on the last channel block, applies the fused epilogue in VMEM:
       + bias, activation (relu/tanh), P_w x P_w / stride-P_s max-pool —
       conv, activation and pooling actors as one hardware pipeline stage,
    4. writes back only the pooled tile: HBM traffic is one read of x
       (plus the halo strips), zero intermediate conv/activation frames,
       and a pool-factor-smaller output.

Weights are expected as (K*K, C, N) — taps flattened, channels C and
features N as the hardware-aligned dims. VALID padding, conv stride ``s``
(SAME is padded by the host wrapper, as the FPGA engine pads the pixel
stream at frame edges). Channel blocks (``block_c``) and feature blocks
(``block_n``) bound the VMEM working set so CIFAR/SVHN-sized layers fit;
row blocks (``block_r``) amortize grid overhead and feed the MXU tall
operands; column blocks (``block_w``) let frames wider than VMEM lower
(0 = whole width per block, the single-column-block fast path).

Block-size legality: the conv-output rows per block R must be a multiple
of lcm(pool stride, hb / gcd(hb, s)) so (a) pooled rows tile exactly and
(b) the halo BlockSpec's element offset (rb+1)*R*s is expressible in
halo-block units. Same rule for WC along the width. The wrapper rounds the
requested block_r/block_w up to the nearest legal size.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.padding import pad_axis_to, round_up
from repro.kernels.stream_conv.epilogue import (
    apply_epilogue,
    normalize_pool,
    pool_out_dim,
    validate_epilogue,
)
from repro.kernels.stream_conv.halo import group_geometry


def _kernel_body(
    x_blk, w_ref, b_ref, o_ref, acc_ref, *, k, s, r_conv, w_conv, act,
    pool, pool_stride, act_bits, int8_scales, out_dtype,
):
    """Shared body: x_blk is the assembled ((r_conv-1)*s + k,
    (w_conv-1)*s + k, bc) input tile (body + halo strips).

    With ``int8_scales`` the tile and taps are int8 codes: the single
    matmul contracts integers into the int32 accumulator scratch and the
    write-back epilogue dequantizes with one exact pow2 multiply before
    requantizing onto the ``act_bits`` stream grid — true integer MXU
    arithmetic, same epilogue contract."""
    cb = pl.program_id(4)
    n_cb = pl.num_programs(4)

    @pl.when(cb == 0)
    def _zero():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    bc = x_blk.shape[-1]
    # K*K stride-s shifted views of the tile -> one tall operand. Pure data
    # movement (VPU); the contraction below is the only matmul.
    taps = []
    for ki in range(k):
        band = jax.lax.slice_in_dim(
            x_blk, ki, ki + (r_conv - 1) * s + 1, stride=s, axis=0
        )  # (r_conv, ·, bc)
        for kj in range(k):
            taps.append(
                jax.lax.slice_in_dim(
                    band, kj, kj + (w_conv - 1) * s + 1, stride=s, axis=1
                )
            )
    patches = jnp.stack(taps, axis=2)  # (r_conv, w_conv, k*k, bc)
    if int8_scales is not None:
        operand = patches.reshape(r_conv * w_conv, k * k * bc)
        w_flat = w_ref[...].reshape(k * k * bc, -1)
        # ONE integer MXU matmul per tile, int32 accumulation.
        acc_ref[...] += jnp.dot(
            operand, w_flat, preferred_element_type=jnp.int32
        ).reshape(r_conv, w_conv, -1)
    else:
        operand = patches.reshape(
            r_conv * w_conv, k * k * bc
        ).astype(jnp.float32)
        w_flat = w_ref[...].reshape(k * k * bc, -1).astype(jnp.float32)
        # ONE MXU matmul per tile (per channel-block accumulation step).
        acc_ref[...] += jnp.dot(
            operand, w_flat, preferred_element_type=jnp.float32
        ).reshape(r_conv, w_conv, -1)

    @pl.when(cb == n_cb - 1)
    def _write():
        y = acc_ref[...]
        if int8_scales is not None:
            y = y.astype(jnp.float32) * int8_scales.deq_scale
        y = apply_epilogue(
            y, b_ref[...], act=act, pool=pool,
            pool_stride=pool_stride, act_bits=act_bits,
        )
        o_ref[0] = y.astype(out_dtype)


def _fused_kernel_halo(
    x_cur_ref, x_rh_ref, x_ch_ref, x_corner_ref, w_ref, b_ref, o_ref,
    acc_ref, **kw,
):
    top = jnp.concatenate([x_cur_ref[0], x_ch_ref[0]], axis=1)
    bot = jnp.concatenate([x_rh_ref[0], x_corner_ref[0]], axis=1)
    x_blk = jnp.concatenate([top, bot], axis=0)
    _kernel_body(x_blk, w_ref, b_ref, o_ref, acc_ref, **kw)


def _fused_kernel_nohalo(x_cur_ref, w_ref, b_ref, o_ref, acc_ref, **kw):
    _kernel_body(x_cur_ref[0], w_ref, b_ref, o_ref, acc_ref, **kw)


def _block_multiple(k: int, s: int, pw: int, ps: int) -> tuple:
    """(legal block multiple, halo pixels, pool-overlap conv rows) for one
    spatial dim. The block multiple is lcm(pool stride, hb/gcd(hb, s)):
    pooled outputs must tile blocks exactly, and the halo BlockSpec offset
    (idx+1)*R*s must land on a halo-block boundary."""
    overlap = max(0, pw - ps) if pw else 0
    hb = max(0, overlap * s + k - s)
    mult = 1
    if pw:
        mult = math.lcm(mult, ps)
    if hb:
        mult = math.lcm(mult, hb // math.gcd(hb, s))
    return mult, hb, overlap


@functools.partial(
    jax.jit,
    static_argnames=(
        "k", "stride", "act", "pool", "pool_stride", "act_bits",
        "int8_scales", "block_r", "block_w", "block_c", "block_n",
        "out_dtype", "interpret",
    ),
)
def stream_conv_fused_pallas(
    x: jax.Array,  # (B, H, W, C), already SAME-padded if needed
    w_taps: jax.Array,  # (K*K, C, N)
    bias: jax.Array,  # (N,)
    *,
    k: int,
    stride: int = 1,
    act: str = "none",
    pool: int = 0,
    pool_stride: int | None = None,
    act_bits: int | None = None,
    int8_scales=None,
    block_r: int = 8,
    block_w: int = 0,  # 0 = full conv-output width per block
    block_c: int = 0,  # 0 = full C per step
    block_n: int = 0,  # 0 = full N per step
    out_dtype=jnp.float32,
    interpret: bool = False,
) -> jax.Array:
    """Fused streaming conv. VALID, conv stride ``stride``; ``pool`` is a
    square max-pool window (0 = none) sliding with ``pool_stride``
    (default: the window); act in {none, relu, tanh}; ``act_bits``
    quantizes the output feature stream in-kernel. Returns (B, H', W', N)
    where H', W' are the pooled output dims.

    ``int8_scales`` (``epilogue.Int8Scales``) selects the true-int8
    rendering: ``x`` must arrive pre-quantized as int8 stream codes (the
    host wrapper quantizes OUTSIDE the pallas_call so the resident frame
    is 1 byte/element) and ``w_taps`` as int8 weight codes; the kernel
    contracts integers into an int32 accumulator scratch and dequantizes
    at write-back."""
    b, h, wd, c = x.shape
    kk, c2, n = w_taps.shape
    if int8_scales is not None:
        if x.dtype != jnp.int8 or w_taps.dtype != jnp.int8:
            raise ValueError(
                "int8_scales requires int8 code operands, got "
                f"x={x.dtype}, w_taps={w_taps.dtype}"
            )
    if kk != k * k or c2 != c:
        raise ValueError(f"w_taps {w_taps.shape} inconsistent with k={k}, C={c}")
    if bias.shape != (n,):
        raise ValueError(f"bias must be ({n},), got {bias.shape}")
    if stride < 1:
        raise ValueError(f"conv stride must be >= 1, got {stride}")
    validate_epilogue(act, pool, pool_stride, act_bits)
    pw, ps = normalize_pool(pool, pool_stride)
    s = stride
    h_out, w_out = (h - k) // s + 1, (wd - k) // s + 1
    if h_out <= 0 or w_out <= 0:
        raise ValueError(f"image {h}x{wd} too small for k={k}, stride={s}")
    if pw and (h_out < pw or w_out < pw):
        raise ValueError(
            f"conv output {h_out}x{w_out} too small for {pw}x{pw} pool"
        )

    mult, hb, overlap = _block_multiple(k, s, pw, ps)
    r = round_up(max(block_r, mult), mult)
    r = min(r, round_up(h_out, mult))
    wc = block_w if block_w > 0 else w_out
    wc = round_up(max(wc, mult), mult)
    wc = min(wc, round_up(w_out, mult))
    # Conv rows/cols actually computed per block: the pool-window overlap
    # rows are re-computed from the halo so overlapping pool windows never
    # cross a block boundary.
    r_conv, w_conv = r + overlap, wc + overlap

    r_o = r // ps if pw else r
    wc_o = wc // ps if pw else wc
    h_keep = pool_out_dim(h_out, pw, ps) if pw else h_out
    w_keep = pool_out_dim(w_out, pw, ps) if pw else w_out
    n_rb = -(-h_keep // r_o)
    n_wb = -(-w_keep // wc_o)

    bc = min(block_c, c) if block_c > 0 else c
    bn = min(block_n, n) if block_n > 0 else n
    c_pad = round_up(c, bc)
    n_pad = round_up(n, bn)

    # Host-side zero padding: rows/cols so every body+halo block is in
    # bounds (pad pixels only feed discarded outputs: kept pool windows
    # read only conv outputs < h_out/w_out, which read only real pixels),
    # channels/features so the block grid divides evenly (zero channels
    # contribute zero partials).
    xp = pad_axis_to(x, 1, n_rb * r * s + hb)
    xp = pad_axis_to(xp, 2, n_wb * wc * s + hb)
    xp = pad_axis_to(xp, 3, c_pad)
    wp = pad_axis_to(pad_axis_to(w_taps, 1, c_pad), 2, n_pad)
    bp = pad_axis_to(bias, 0, n_pad)

    grid = (b, n_rb, n_wb, n_pad // bn, c_pad // bc)
    kw = dict(
        k=k, s=s, r_conv=r_conv, w_conv=w_conv, act=act, pool=pool,
        pool_stride=pool_stride, act_bits=act_bits,
        int8_scales=int8_scales, out_dtype=out_dtype,
    )

    in_specs = [
        pl.BlockSpec(
            (1, r * s, wc * s, bc),
            lambda bb, rb, wb, nb, cb: (bb, rb, wb, cb),
        ),
    ]
    inputs = [xp]
    if hb:
        # Halo strips: bottom rows, right cols, and the corner. Element
        # offset (idx+1)*R*s expressed in hb-sized block units (legal by
        # the block-multiple rule above).
        rs_hb = r * s // hb
        ws_hb = wc * s // hb
        in_specs += [
            pl.BlockSpec(
                (1, hb, wc * s, bc),
                lambda bb, rb, wb, nb, cb: (bb, (rb + 1) * rs_hb, wb, cb),
            ),
            pl.BlockSpec(
                (1, r * s, hb, bc),
                lambda bb, rb, wb, nb, cb: (bb, rb, (wb + 1) * ws_hb, cb),
            ),
            pl.BlockSpec(
                (1, hb, hb, bc),
                lambda bb, rb, wb, nb, cb: (
                    bb, (rb + 1) * rs_hb, (wb + 1) * ws_hb, cb
                ),
            ),
        ]
        inputs += [xp, xp, xp]
        kernel = functools.partial(_fused_kernel_halo, **kw)
    else:
        kernel = functools.partial(_fused_kernel_nohalo, **kw)
    in_specs += [
        pl.BlockSpec((k * k, bc, bn), lambda bb, rb, wb, nb, cb: (0, cb, nb)),
        pl.BlockSpec((bn,), lambda bb, rb, wb, nb, cb: (nb,)),
    ]
    inputs += [wp, bp]

    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec(
            (1, r_o, wc_o, bn), lambda bb, rb, wb, nb, cb: (bb, rb, wb, nb)
        ),
        out_shape=jax.ShapeDtypeStruct(
            (b, n_rb * r_o, n_wb * wc_o, n_pad), out_dtype
        ),
        scratch_shapes=[
            pltpu.VMEM(
                (r_conv, w_conv, bn),
                jnp.int32 if int8_scales is not None else jnp.float32,
            )
        ],
        interpret=interpret,
    )(*inputs)
    return out[:, :h_keep, :w_keep, :n]


# ---------------------------------------------------------------------------
# Cross-layer fused pyramid: several conv->bias->act->pool layers per
# pallas_call, inter-layer slabs VMEM-resident.


def _assemble_taps(slab, k: int, s: int, conv_rows: int, conv_cols: int):
    """Two-step tap assembly: column shifts first (k slices of the slab),
    then row shifts of the column-assembled operand — 2k strided views
    instead of k*k, same (rows*cols, k*k*C) matmul operand. Pure VPU data
    movement; the contraction stays ONE matmul per layer per block."""
    z = jnp.stack(
        [
            jax.lax.slice_in_dim(
                slab, kj, kj + (conv_cols - 1) * s + 1, stride=s, axis=1
            )
            for kj in range(k)
        ],
        axis=2,
    )  # (rows, conv_cols, k, C)
    patches = jnp.stack(
        [
            jax.lax.slice_in_dim(
                z, ki, ki + (conv_rows - 1) * s + 1, stride=s, axis=0
            )
            for ki in range(k)
        ],
        axis=2,
    )  # (conv_rows, conv_cols, ki, kj, C)
    c = slab.shape[-1]
    return patches.reshape(conv_rows * conv_cols, k * k * c)


def _pyramid_kernel(*refs, geom, act_bits, int8_scales, out_dtype):
    """Kernel body: stream one row block of the final output through the
    whole fusion group. refs = (x_ref, w_ref0, b_ref0, w_ref1, b_ref1, ...,
    o_ref). Every inter-layer slab lives in VMEM for the block's lifetime;
    nothing is written back until the last layer's pooled rows.

    ``act_bits`` is a per-layer tuple; ``int8_scales`` (None or a
    per-layer tuple of ``Int8Scales``) selects true integer arithmetic:
    the resident frame and every inter-layer slab are int8 stream CODES
    (1 byte/element in VMEM — intermediate epilogues emit ``codes_out``),
    each layer's single matmul contracts integers into int32, and only
    the group's final epilogue dequantizes to fp32 grid values."""
    x_ref, o_ref = refs[0], refs[-1]
    wb = refs[1:-1]
    rb = pl.program_id(1)
    n_layers = len(geom.layers)

    g0 = geom.layers[0]
    start0 = g0.in_mult * rb + g0.in_off + geom.input_row_shift
    slab = pl.load(
        x_ref,
        (
            pl.dslice(0, 1),
            pl.dslice(start0, g0.in_slab_rows),
            slice(None),
            slice(None),
        ),
    )[0]
    if int8_scales is None:
        slab = slab.astype(jnp.float32)

    for i, g in enumerate(geom.layers):
        sc = None if int8_scales is None else int8_scales[i]
        if i > 0:
            # The slab is the previous layer's output over an affine row
            # interval that may reach outside the frame: rows outside
            # [0, in_rows) are exactly this layer's SAME zero padding
            # (VALID layers never read them — they only feed rows that
            # are discarded downstream). Zero is dtype-preserving: on the
            # int8 path code 0 IS value 0.
            rows = (
                jax.lax.broadcasted_iota(jnp.int32, slab.shape, 0)
                + g.in_mult * rb + g.in_off
            )
            slab = jnp.where(
                (rows >= 0) & (rows < g.in_rows), slab, jnp.zeros_like(slab)
            )
            lc, rc = g.pads[1]
            if lc or rc:
                slab = jnp.pad(slab, ((0, 0), (lc, rc), (0, 0)))
        operand = _assemble_taps(
            slab, g.k, g.stride, g.conv_slab_rows, g.conv_cols
        )
        w_flat = wb[2 * i][...].reshape(g.k * g.k * g.in_ch, g.n_out)
        if sc is not None:
            # ONE integer MXU matmul per layer per block -> int32 acc ->
            # exact pow2 dequant.
            y = jnp.dot(
                operand, w_flat, preferred_element_type=jnp.int32
            ).reshape(g.conv_slab_rows, g.conv_cols, g.n_out)
            y = y.astype(jnp.float32) * sc.deq_scale
        else:
            # ONE MXU matmul per layer per block.
            y = jnp.dot(
                operand,
                w_flat.astype(jnp.float32),
                preferred_element_type=jnp.float32,
            ).reshape(g.conv_slab_rows, g.conv_cols, g.n_out)
        slab = apply_epilogue(
            y, wb[2 * i + 1][...], act=g.act, pool=g.pw,
            pool_stride=g.ps, act_bits=act_bits[i], pool_first=True,
            codes_out=sc is not None and i < n_layers - 1,
        )
    o_ref[0] = slab.astype(out_dtype)


@functools.partial(
    jax.jit,
    static_argnames=(
        "layers", "act_bits", "int8_scales", "block_rows", "out_dtype",
        "interpret",
    ),
)
def stream_conv_pyramid_pallas(
    x: jax.Array,  # (B, H, W, C0), unpadded
    weights: tuple,  # per layer (K, K, C, N) HWIO
    biases: tuple,  # per layer (N,)
    *,
    layers: tuple,  # PyramidLayer per layer
    act_bits=None,  # int | None | per-layer tuple
    int8_scales=None,  # None | per-layer tuple of Int8Scales
    block_rows: int = 0,  # final-output rows per block; 0 = whole frame
    out_dtype=jnp.float32,
    interpret: bool = False,
) -> jax.Array:
    """Cross-layer fused conv pyramid: the whole group is ONE pallas_call.

    Grid = (B, n_row_blocks); each cell streams one block of the *final*
    output rows through every layer of the group — conv (one matmul per
    layer), bias, pool, act, stream quant — with all inter-layer feature
    slabs VMEM-resident. The block's input halo is the composed per-layer
    requirement (``halo.group_geometry``); SAME padding of intermediate
    layers is realized by masking slab rows outside the valid frame, which
    is exactly the zero padding those rows carry. Returns the group output
    (B, H', W', N_last).
    """
    b, h, w, c = x.shape
    if int8_scales is not None and x.dtype != jnp.int8:
        raise ValueError(
            f"int8_scales requires a pre-quantized int8 frame, got {x.dtype}"
        )
    bits = (
        act_bits
        if isinstance(act_bits, tuple)
        else (act_bits,) * len(layers)
    )
    kernels = tuple(wt.shape[0] for wt in weights)
    n_outs = tuple(wt.shape[3] for wt in weights)
    geom = group_geometry(
        h, w, c, layers, kernels, n_outs, block_rows=block_rows
    )
    g0 = geom.layers[0]
    lc, rc = geom.in_pad_cols
    xp = jnp.pad(
        x,
        (
            (0, 0),
            (geom.in_pad_top, geom.in_pad_rows_total - h - geom.in_pad_top),
            (lc, rc),
            (0, 0),
        ),
    )
    rows_tot, cols_tot = xp.shape[1], xp.shape[2]

    grid = (b, geom.n_row_blocks)
    in_specs = [
        pl.BlockSpec((1, rows_tot, cols_tot, c), lambda bb, rb: (bb, 0, 0, 0))
    ]
    inputs = [xp]
    for g, wt, bs in zip(geom.layers, weights, biases):
        in_specs.append(
            pl.BlockSpec(
                (g.k, g.k, g.in_ch, g.n_out), lambda bb, rb: (0, 0, 0, 0)
            )
        )
        in_specs.append(pl.BlockSpec((g.n_out,), lambda bb, rb: (0,)))
        inputs += [wt, bs]

    n_last = n_outs[-1]
    out = pl.pallas_call(
        functools.partial(
            _pyramid_kernel, geom=geom, act_bits=bits,
            int8_scales=int8_scales, out_dtype=out_dtype,
        ),
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec(
            (1, geom.block_rows, geom.out_cols, n_last),
            lambda bb, rb: (bb, rb, 0, 0),
        ),
        out_shape=jax.ShapeDtypeStruct(
            (b, geom.n_row_blocks * geom.block_rows, geom.out_cols, n_last),
            out_dtype,
        ),
        interpret=interpret,
    )(*inputs)
    return out[:, : geom.out_rows]
