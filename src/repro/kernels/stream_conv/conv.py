"""Pallas TPU kernel: streaming line-buffer convolution (paper [10], §5).

The FPGA conv engine keeps (K-1) image lines in registers and slides a KxK
window one pixel per clock. The TPU adaptation keeps a (K-1)-row **line
buffer in VMEM scratch** and streams the image row-by-row through the grid:

  grid = (B, H_out): one output row per step. Each step
    1. loads ONE new input row (the BlockSpec pipeline streams rows
       HBM -> VMEM, the analogue of the pixel stream),
    2. assembles the KxK window rows from [line buffer ++ new row],
    3. computes the output row with K*K shifted row-segment matmuls
       against the (C, N) tap matrices — the fully-unrolled multiplier
       array of Fig. 1-c, with the MXU playing the adder tree,
    4. rotates the line buffer by one row.

The weight tensor is expected as (K*K, C, N) — taps flattened — so each tap
is one MXU matmul; channels C and features N are the hardware-aligned dims.
VALID padding, stride 1. The line buffer makes the kernel's HBM traffic
exactly one read of x and one write of y (no im2col inflation): bytes =
B*H*W*C + B*H_out*W_out*N elements, matching the FPGA engine's
zero-intermediate-storage property.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _stream_conv_kernel(x_row_ref, w_ref, o_ref, lbuf_ref, *, k: int, w_out: int):
    """One grid step: consume input row (r + K - 1), emit output row r."""
    new_row = x_row_ref[0, 0]  # (W, C) — the row streamed in this step

    # Window rows: lbuf holds rows r .. r+K-2, new_row is row r+K-1.
    acc = jnp.zeros((w_out, o_ref.shape[-1]), jnp.float32)
    for ki in range(k):
        row = lbuf_ref[ki] if ki < k - 1 else new_row
        for kj in range(k):
            seg = jax.lax.dynamic_slice_in_dim(row, kj, w_out, axis=0)
            tap = w_ref[ki * k + kj]  # (C, N)
            acc += jnp.dot(
                seg.astype(jnp.float32),
                tap.astype(jnp.float32),
                preferred_element_type=jnp.float32,
            )
    o_ref[0, 0] = acc.astype(o_ref.dtype)

    # Rotate the line buffer: drop row r, append row r+K-1.
    for ki in range(k - 2):
        lbuf_ref[ki] = lbuf_ref[ki + 1]
    if k >= 2:
        lbuf_ref[k - 2] = new_row


def _fill_kernel(x_rows_ref, lbuf_ref):
    """Pre-load the first K-1 rows of image b into the line buffer."""
    lbuf_ref[...] = x_rows_ref[0]


@functools.partial(
    jax.jit, static_argnames=("k", "block_n", "out_dtype", "interpret")
)
def stream_conv2d_pallas(
    x: jax.Array,  # (B, H, W, C)
    w_taps: jax.Array,  # (K*K, C, N)
    *,
    k: int,
    out_dtype=jnp.float32,
    block_n: int = 0,  # unused placeholder for tuning API symmetry
    interpret: bool = True,
) -> jax.Array:
    b, h, wd, c = x.shape
    kk, c2, n = w_taps.shape
    if kk != k * k or c2 != c:
        raise ValueError(f"w_taps {w_taps.shape} inconsistent with k={k}, C={c}")
    h_out, w_out = h - k + 1, wd - k + 1
    if h_out <= 0 or w_out <= 0:
        raise ValueError(f"image {h}x{wd} too small for k={k}")

    kernel = functools.partial(_stream_conv_kernel, k=k, w_out=w_out)

    # Two-phase schedule per image: a fill pass primes the line buffer with
    # rows [0, K-1), then the stream pass consumes one row per output row.
    # Phases are fused into one grid by handing the stream pass row
    # (r + K - 1) and priming the buffer when r == 0 via input_output_aliasing
    # of a scratch; Pallas TPU scratch persists across grid steps of the same
    # pallas_call, so the fill runs as the first grid column (r == 0 loads
    # rows 0..K-2 through a second input spec).
    def _kernel_with_fill(x_row_ref, x_fill_ref, w_ref, o_ref, lbuf_ref):
        r = pl.program_id(1)

        @pl.when(r == 0)
        def _fill():
            lbuf_ref[...] = x_fill_ref[0]

        kernel(x_row_ref, w_ref, o_ref, lbuf_ref)

    grid = (b, h_out)
    return pl.pallas_call(
        _kernel_with_fill,
        grid=grid,
        in_specs=[
            # One input row per step: row (r + K - 1) of image b.
            pl.BlockSpec(
                (1, 1, wd, c), lambda bb, r: (bb, r + k - 1, 0, 0)
            ),
            # Fill rows [0, K-1) of image b (same block every r; only read
            # at r == 0).
            pl.BlockSpec(
                (1, max(1, k - 1), wd, c), lambda bb, r: (bb, 0, 0, 0)
            ),
            pl.BlockSpec((k * k, c, n), lambda bb, r: (0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, w_out, n), lambda bb, r: (bb, r, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h_out, w_out, n), out_dtype),
        scratch_shapes=[pltpu.VMEM((max(1, k - 1), wd, c), x.dtype)],
        interpret=interpret,
    )(
        x.reshape(b, h, wd, c),
        x,
        w_taps,
    )
