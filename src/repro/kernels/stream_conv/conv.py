"""Pallas TPU kernel: row-blocked streaming convolution with a fused
conv -> bias -> activation -> pool epilogue (paper [10], §5).

The FPGA conv engine of the paper chains three always-firing actors —
convolution, activation, pooling — with no intermediate frame storage. The
TPU rendering streams the image through the grid in **row blocks** and runs
the whole actor chain on each block before anything is written back:

  grid = (B, H_out/R, N/bn, C/bc): one R-row block of output per
  (batch, row-block, feature-block) cell, accumulated over channel blocks.
  Each step

    1. receives R+K-1 input rows through the BlockSpec pipeline (an R-row
       body block plus a (K-1)-row halo — the halo is the line buffer: the
       only rows ever fetched twice),
    2. assembles the K*K shifted views into ONE (R*W_out, K*K*bc) operand
       and issues a SINGLE MXU matmul against the flattened
       (K*K*bc, bn) tap matrix — the fully-unrolled multiplier array of
       Fig. 1-c collapsed into one systolic pass, not K*K per-tap dots,
    3. on the last channel block, applies the fused epilogue in VMEM:
       + bias, activation (relu/tanh), 2x2 max-pool — conv, activation and
       pooling actors as one hardware pipeline stage,
    4. writes back only the pooled block: HBM traffic is one read of x
       (plus the (K-1)-row halo), zero intermediate conv/activation frames,
       and a 4x-smaller pooled output.

Weights are expected as (K*K, C, N) — taps flattened, channels C and
features N as the hardware-aligned dims. VALID padding, stride 1 (SAME is
padded by the host wrapper, as the FPGA engine pads the pixel stream at
frame edges). Channel blocks (``block_c``) and feature blocks (``block_n``)
bound the VMEM working set so CIFAR/SVHN-sized layers fit; row blocks
(``block_r``) amortize grid overhead and feed the MXU tall operands.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.padding import pad_axis_to, round_up
from repro.kernels.stream_conv.epilogue import apply_epilogue, validate_epilogue


def _kernel_body(
    x_blk, w_ref, b_ref, o_ref, acc_ref, *, k, r, w_out, act, pool, act_bits,
    out_dtype,
):
    """Shared body: x_blk is the (r + k - 1, W, bc) window block."""
    cb = pl.program_id(3)
    n_cb = pl.num_programs(3)

    @pl.when(cb == 0)
    def _zero():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    bc = x_blk.shape[-1]
    # K*K shifted views of the block -> one tall operand. Pure data
    # movement (VPU); the contraction below is the only matmul.
    taps = []
    for ki in range(k):
        band = jax.lax.slice_in_dim(x_blk, ki, ki + r, axis=0)  # (r, W, bc)
        for kj in range(k):
            taps.append(jax.lax.slice_in_dim(band, kj, kj + w_out, axis=1))
    patches = jnp.stack(taps, axis=2)  # (r, w_out, k*k, bc)
    operand = patches.reshape(r * w_out, k * k * bc).astype(jnp.float32)
    w_flat = w_ref[...].reshape(k * k * bc, -1).astype(jnp.float32)
    # ONE MXU matmul per row block (per channel-block accumulation step).
    acc_ref[...] += jnp.dot(
        operand, w_flat, preferred_element_type=jnp.float32
    ).reshape(r, w_out, -1)

    @pl.when(cb == n_cb - 1)
    def _write():
        y = apply_epilogue(
            acc_ref[...], b_ref[...], act=act, pool=pool, act_bits=act_bits
        )
        o_ref[0] = y.astype(out_dtype)


def _fused_kernel_halo(x_cur_ref, x_halo_ref, w_ref, b_ref, o_ref, acc_ref, **kw):
    x_blk = jnp.concatenate([x_cur_ref[0], x_halo_ref[0]], axis=0)
    _kernel_body(x_blk, w_ref, b_ref, o_ref, acc_ref, **kw)


def _fused_kernel_k1(x_cur_ref, w_ref, b_ref, o_ref, acc_ref, **kw):
    _kernel_body(x_cur_ref[0], w_ref, b_ref, o_ref, acc_ref, **kw)


@functools.partial(
    jax.jit,
    static_argnames=(
        "k", "act", "pool", "act_bits", "block_r", "block_c", "block_n",
        "out_dtype", "interpret",
    ),
)
def stream_conv_fused_pallas(
    x: jax.Array,  # (B, H, W, C), already SAME-padded if needed
    w_taps: jax.Array,  # (K*K, C, N)
    bias: jax.Array,  # (N,)
    *,
    k: int,
    act: str = "none",
    pool: int = 0,
    act_bits: int | None = None,
    block_r: int = 8,
    block_c: int = 0,  # 0 = full C per step
    block_n: int = 0,  # 0 = full N per step
    out_dtype=jnp.float32,
    interpret: bool = False,
) -> jax.Array:
    """Fused streaming conv. VALID, stride 1; pool in {0, 2}; act in
    {none, relu, tanh}; ``act_bits`` quantizes the output feature stream
    in-kernel. Returns (B, H', W', N) where H', W' are the conv output
    dims, halved (floor) when pool == 2."""
    b, h, wd, c = x.shape
    kk, c2, n = w_taps.shape
    if kk != k * k or c2 != c:
        raise ValueError(f"w_taps {w_taps.shape} inconsistent with k={k}, C={c}")
    if bias.shape != (n,):
        raise ValueError(f"bias must be ({n},), got {bias.shape}")
    validate_epilogue(act, pool, act_bits)
    h_out, w_out = h - k + 1, wd - k + 1
    if h_out <= 0 or w_out <= 0:
        raise ValueError(f"image {h}x{wd} too small for k={k}")
    if pool == 2 and (h_out < 2 or w_out < 2):
        raise ValueError(f"conv output {h_out}x{w_out} too small for 2x2 pool")

    # Row block: a multiple of the halo height (so the halo BlockSpec's
    # element offset (rb+1)*r is expressible in halo-block units) and of the
    # pool stride, clipped to the smallest cover of h_out.
    hb = k - 1
    mult = 1
    if hb:
        mult = math.lcm(mult, hb)
    if pool == 2:
        mult = math.lcm(mult, 2)
    r = round_up(max(block_r, mult), mult)
    r = min(r, round_up(h_out, mult))
    n_rb = -(-h_out // r)

    bc = min(block_c, c) if block_c > 0 else c
    bn = min(block_n, n) if block_n > 0 else n
    c_pad = round_up(c, bc)
    n_pad = round_up(n, bn)

    # Host-side zero padding: rows so every body+halo block is in bounds
    # (zero rows only feed discarded outputs), channels/features so the
    # block grid divides evenly (zero channels contribute zero partials).
    h_rows = n_rb * r + hb
    xp = pad_axis_to(pad_axis_to(x, 1, h_rows), 3, c_pad)
    wp = pad_axis_to(pad_axis_to(w_taps, 1, c_pad), 2, n_pad)
    bp = pad_axis_to(bias, 0, n_pad)

    r_out = r // 2 if pool == 2 else r
    w_pool = w_out // 2 if pool == 2 else w_out
    h_keep = h_out // 2 if pool == 2 else h_out

    grid = (b, n_rb, n_pad // bn, c_pad // bc)
    kw = dict(
        k=k, r=r, w_out=w_out, act=act, pool=pool, act_bits=act_bits,
        out_dtype=out_dtype,
    )

    in_specs = [
        pl.BlockSpec((1, r, wd, bc), lambda bb, rb, nb, cb: (bb, rb, 0, cb)),
    ]
    if hb:
        stride = r // hb
        in_specs.append(
            pl.BlockSpec(
                (1, hb, wd, bc),
                lambda bb, rb, nb, cb: (bb, (rb + 1) * stride, 0, cb),
            )
        )
        kernel = functools.partial(_fused_kernel_halo, **kw)
    else:
        kernel = functools.partial(_fused_kernel_k1, **kw)
    in_specs += [
        pl.BlockSpec((k * k, bc, bn), lambda bb, rb, nb, cb: (0, cb, nb)),
        pl.BlockSpec((bn,), lambda bb, rb, nb, cb: (nb,)),
    ]

    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec(
            (1, r_out, w_pool, bn), lambda bb, rb, nb, cb: (bb, rb, 0, nb)
        ),
        out_shape=jax.ShapeDtypeStruct((b, n_rb * r_out, w_pool, n_pad), out_dtype),
        scratch_shapes=[pltpu.VMEM((r, w_out, bn), jnp.float32)],
        interpret=interpret,
    )(*([xp] + ([xp] if hb else []) + [wp, bp]))
    return out[:, :h_keep, :, :n]
