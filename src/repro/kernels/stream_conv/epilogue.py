"""Shared fused epilogue: bias -> activation -> NxN/stride-s max-pool ->
feature-stream fixed-point quantization.

One definition used by BOTH compiled conv paths (the Pallas kernel body in
``conv.py`` and the XLA fallback in ``xla.py``), so the backends cannot
drift apart. The jnp reference (``ref.py``) deliberately keeps its own
independent composition (``lax.reduce_window`` + ``fake_quant_ste``): it is
the oracle the fused paths are tested against, so it must not share this
code.

Pooling is a square ``pool x pool`` max window sliding with ``pool_stride``
(default: ``pool``, the classic non-overlapping case — ``pool=2`` keeps
meaning 2x2/stride-2). Overlapping windows (``pool_stride < pool``, e.g.
Caffe's cifar10_full 3x3/stride-2) and strided sub-sampling windows
(``pool_stride > pool``) are both legal; output dims follow the VALID
sliding-window rule ``(d - pool) // pool_stride + 1``.

``act_bits`` is the paper's "quantize the pixel flow": the inter-actor
feature stream is a short fixed-point format, so the quantization step
belongs INSIDE the fused actor chain — the block is rounded in VMEM before
write-back, never as a separate pass over the HBM-resident frame. The
Q-format matches the model reference (``FixedPointSpec(bits, bits - 2)``,
the format ``cnn_apply``'s fake-quant composition uses for activations),
and the forward computation — clip(round(y / scale)) * scale — is exactly
``fake_quant_ste``'s forward.

Works on any (..., H, W, N) float32 block — the Pallas kernel calls it on
an (r, w, bn) VMEM block, the XLA path on a (B, r, w, N) row block.
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp

from repro.core.quant.fixed_point import FixedPointSpec

ACTS = ("none", "relu", "tanh")


@dataclasses.dataclass(frozen=True)
class Int8Scales:
    """Static (hashable — rides jit ``static_argnames``) descriptor of one
    conv layer's true-int8 arithmetic contract.

    ``in_bits`` names the input stream's Q-format (the PRODUCER's
    ``act_bits``; for the first layer, its own stream grid — the plan
    quantizes the incoming frame onto it). ``w_scale`` is the baked
    weights' static pow2 scale: ``int8 codes * w_scale`` reproduces the
    fake-quant weight values bit-exactly, so the integer matmul's int32
    accumulator dequantizes with one exact pow2 multiply
    (``in_scale * w_scale``) back to the fp32 values the fake-quant
    oracle computes.
    """

    in_bits: int
    w_scale: float

    @property
    def in_spec(self) -> FixedPointSpec:
        return stream_quant_spec(self.in_bits)

    @property
    def in_scale(self) -> float:
        return self.in_spec.scale

    @property
    def deq_scale(self) -> float:
        """int32 accumulator -> fp32 values (exact: pow2 * pow2)."""
        return self.in_scale * self.w_scale


def normalize_pool(pool: int, pool_stride: int | None = None) -> tuple:
    """Normalize the (pool, pool_stride) sugar into a concrete
    ``(window, stride)`` pair; ``(0, 0)`` means pooling disabled.

    ``pool`` is the square window size (0 disables, the historic ``pool=2``
    means 2x2); ``pool_stride=None`` defaults to the window (the
    window == stride case every paper topology uses).
    """
    if pool is None:
        pool = 0
    if not isinstance(pool, int) or isinstance(pool, bool):
        raise ValueError(f"pool must be an int window size, got {pool!r}")
    if pool < 0:
        raise ValueError(f"pool window must be >= 0 (0 = no pool), got {pool}")
    if pool == 0:
        if pool_stride not in (None, 0):
            raise ValueError(
                f"pool_stride={pool_stride!r} given but pooling is disabled "
                "(pool=0)"
            )
        return (0, 0)
    ps = pool if pool_stride is None else pool_stride
    if not isinstance(ps, int) or isinstance(ps, bool) or ps < 1:
        raise ValueError(
            f"pool_stride must be a positive int (or None = window), got "
            f"{pool_stride!r}"
        )
    return (pool, ps)


def pool_out_dim(d: int, window: int, stride: int) -> int:
    """VALID sliding-window output length for one spatial dim."""
    return (d - window) // stride + 1


def stream_quant_spec(act_bits: int) -> FixedPointSpec:
    """The feature-stream Q-format: 1 sign bit, 1 integer bit, rest
    fractional — the same format the model-level fake-quant reference
    applies to activations."""
    return FixedPointSpec(bits=act_bits, frac_bits=act_bits - 2)


def validate_epilogue(
    act: str,
    pool: int,
    pool_stride: int | None = None,
    act_bits: int | None = None,
) -> None:
    if act not in ACTS:
        raise ValueError(f"unknown act {act!r}; expected one of {ACTS}")
    normalize_pool(pool, pool_stride)
    if act_bits is not None and act_bits < 2:
        raise ValueError(f"act_bits must be >= 2 (or None), got {act_bits}")


def _maxpool_window(y, window: int, stride: int):
    """Square max-pool over the trailing (H, W, N) dims of ``y`` via
    window*window shifted strided views — plain jnp ops (elementwise max +
    static strided slices), so it runs unchanged inside a Pallas kernel
    body on a VMEM-resident block."""
    *_, h, w, _ = y.shape
    hp = pool_out_dim(h, window, stride)
    wp = pool_out_dim(w, window, stride)
    out = None
    for di in range(window):
        for dj in range(window):
            v = y[
                ...,
                di : di + (hp - 1) * stride + 1 : stride,
                dj : dj + (wp - 1) * stride + 1 : stride,
                :,
            ]
            out = v if out is None else jnp.maximum(out, v)
    return out


def quantize_stream(x, act_bits: int):
    """Quantize fp32 values onto the ``act_bits`` stream grid as int8
    CODES (value = code * scale). Exact (a pure representation change)
    when ``x`` already sits on the grid — which every fused-kernel
    boundary guarantees. int8 holds any stream code: ``act_bits <= 8``
    is enforced by the compile-time ``int8_compute`` validation."""
    spec = stream_quant_spec(act_bits)
    q = jnp.clip(jnp.round(x / spec.scale), spec.qmin, spec.qmax)
    return q.astype(jnp.int8)


def apply_epilogue(
    y, bias, *, act: str, pool: int, pool_stride: int | None = None,
    act_bits: int | None = None, ste: bool = False, pool_first: bool = False,
    codes_out: bool = False,
):
    """y: (..., H, W, N) f32; bias: (N,). Returns the block after
    bias + activation + optional pool x pool / pool_stride max-pool (VALID
    floor semantics) + optional feature-stream quantization — all
    in-register/VMEM.

    ``ste=True`` routes the quantization through ``fake_quant_ste``
    (identity gradient inside the representable range) — same forward
    values, used by the differentiable XLA rendering so QAT through the
    fused path keeps training. The Pallas kernel body keeps the raw
    round/clip (``ste=False``): it is forward-only anyway, and the kernel
    program must stay plain jnp ops.

    ``pool_first=True`` swaps the act/pool actors: bias -> max-pool ->
    activation -> quant, which is the composition order of
    ``cnn_apply_reference``. Because max-pool commutes with the monotone
    activations the two orders agree; pooling first shrinks the
    activation work by the pool factor, so the cross-layer fused pyramid
    uses it (the single-layer actor chain keeps the paper's
    conv -> act -> pool order).

    ``codes_out=True`` (true-int8 pyramid interiors) returns the stream
    quantization's int8 CODES instead of the dequantized fp32 values —
    the inter-layer slab stays 1 byte/element in VMEM and the next
    layer's integer matmul consumes it directly. Requires ``act_bits``
    and is mutually exclusive with ``ste`` (the codes path is
    forward-only).
    """
    validate_epilogue(act, pool, pool_stride, act_bits)
    if codes_out and (act_bits is None or ste):
        raise ValueError(
            "codes_out requires act_bits and is forward-only (ste=False)"
        )
    pw, ps = normalize_pool(pool, pool_stride)
    y = y + bias.astype(jnp.float32)
    if pool_first and pw:
        y = _maxpool_window(y, pw, ps)
    if act == "relu":
        y = jnp.maximum(y, 0.0)
    elif act == "tanh":
        y = jnp.tanh(y)
    if not pool_first and pw:
        y = _maxpool_window(y, pw, ps)
    if act_bits is not None:
        spec = stream_quant_spec(act_bits)
        if ste:
            from repro.core.quant.fixed_point import fake_quant_ste

            y = fake_quant_ste(y, spec)
        else:
            q = jnp.clip(jnp.round(y / spec.scale), spec.qmin, spec.qmax)
            y = q.astype(jnp.int8) if codes_out else q * spec.scale
    return y
