"""Shared fused epilogue: bias -> activation -> 2x2 max-pool.

One definition used by BOTH compiled conv paths (the Pallas kernel body in
``conv.py`` and the XLA fallback in ``xla.py``), so the backends cannot
drift apart. The jnp reference (``ref.py``) deliberately keeps its own
independent ``lax.reduce_window`` composition: it is the oracle the fused
paths are tested against, so it must not share this code.

Works on any (..., H, W, N) float32 block — the Pallas kernel calls it on
a (r, w_out, bn) VMEM block, the XLA path on a (B, r, w_out, N) row block.
"""
from __future__ import annotations

import jax.numpy as jnp

ACTS = ("none", "relu", "tanh")
POOLS = (0, 2)


def validate_epilogue(act: str, pool: int) -> None:
    if act not in ACTS:
        raise ValueError(f"unknown act {act!r}; expected one of {ACTS}")
    if pool not in POOLS:
        raise ValueError(f"pool must be 0 or 2, got {pool}")


def apply_epilogue(y, bias, *, act: str, pool: int):
    """y: (..., H, W, N) f32; bias: (N,). Returns the block after
    bias + activation + optional 2x2 max-pool (floor semantics)."""
    validate_epilogue(act, pool)
    y = y + bias.astype(jnp.float32)
    if act == "relu":
        y = jnp.maximum(y, 0.0)
    elif act == "tanh":
        y = jnp.tanh(y)
    if pool == 2:
        *lead, h, w, n = y.shape
        h2, w2 = 2 * (h // 2), 2 * (w // 2)
        y = y[..., :h2, :w2, :]
        y = y.reshape(*lead, h2 // 2, 2, w2 // 2, 2, n)
        y = y.max(axis=(-4, -2))
    return y
