"""Shared fused epilogue: bias -> activation -> 2x2 max-pool -> feature-stream
fixed-point quantization.

One definition used by BOTH compiled conv paths (the Pallas kernel body in
``conv.py`` and the XLA fallback in ``xla.py``), so the backends cannot
drift apart. The jnp reference (``ref.py``) deliberately keeps its own
independent composition (``lax.reduce_window`` + ``fake_quant_ste``): it is
the oracle the fused paths are tested against, so it must not share this
code.

``act_bits`` is the paper's "quantize the pixel flow": the inter-actor
feature stream is a short fixed-point format, so the quantization step
belongs INSIDE the fused actor chain — the block is rounded in VMEM before
write-back, never as a separate pass over the HBM-resident frame. The
Q-format matches the model reference (``FixedPointSpec(bits, bits - 2)``,
the format ``cnn_apply``'s fake-quant composition uses for activations),
and the forward computation — clip(round(y / scale)) * scale — is exactly
``fake_quant_ste``'s forward.

Works on any (..., H, W, N) float32 block — the Pallas kernel calls it on
a (r, w_out, bn) VMEM block, the XLA path on a (B, r, w_out, N) row block.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core.quant.fixed_point import FixedPointSpec

ACTS = ("none", "relu", "tanh")
POOLS = (0, 2)


def stream_quant_spec(act_bits: int) -> FixedPointSpec:
    """The feature-stream Q-format: 1 sign bit, 1 integer bit, rest
    fractional — the same format the model-level fake-quant reference
    applies to activations."""
    return FixedPointSpec(bits=act_bits, frac_bits=act_bits - 2)


def validate_epilogue(act: str, pool: int, act_bits: int | None = None) -> None:
    if act not in ACTS:
        raise ValueError(f"unknown act {act!r}; expected one of {ACTS}")
    if pool not in POOLS:
        raise ValueError(f"pool must be 0 or 2, got {pool}")
    if act_bits is not None and act_bits < 2:
        raise ValueError(f"act_bits must be >= 2 (or None), got {act_bits}")


def apply_epilogue(
    y, bias, *, act: str, pool: int, act_bits: int | None = None,
    ste: bool = False,
):
    """y: (..., H, W, N) f32; bias: (N,). Returns the block after
    bias + activation + optional 2x2 max-pool (floor semantics) + optional
    feature-stream quantization — all in-register/VMEM.

    ``ste=True`` routes the quantization through ``fake_quant_ste``
    (identity gradient inside the representable range) — same forward
    values, used by the differentiable XLA rendering so QAT through the
    fused path keeps training. The Pallas kernel body keeps the raw
    round/clip (``ste=False``): it is forward-only anyway, and the kernel
    program must stay plain jnp ops.
    """
    validate_epilogue(act, pool, act_bits)
    y = y + bias.astype(jnp.float32)
    if act == "relu":
        y = jnp.maximum(y, 0.0)
    elif act == "tanh":
        y = jnp.tanh(y)
    if pool == 2:
        *lead, h, w, n = y.shape
        h2, w2 = 2 * (h // 2), 2 * (w // 2)
        y = y[..., :h2, :w2, :]
        y = y.reshape(*lead, h2 // 2, 2, w2 // 2, 2, n)
        y = y.max(axis=(-4, -2))
    if act_bits is not None:
        spec = stream_quant_spec(act_bits)
        if ste:
            from repro.core.quant.fixed_point import fake_quant_ste

            y = fake_quant_ste(y, spec)
        else:
            q = jnp.clip(jnp.round(y / spec.scale), spec.qmin, spec.qmax)
            y = q * spec.scale
    return y
