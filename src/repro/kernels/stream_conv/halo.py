"""Composed halo algebra for cross-layer fused conv pyramids.

A fusion group runs several consecutive conv -> bias -> act -> pool layers
over one block of the *final* output rows, keeping every inter-layer
feature slab in VMEM. The geometry problem is the composition of the
single-layer halo rule (a block of pooled rows needs
``(r-1)*pool_stride + pool`` conv rows, which need ``(r_conv-1)*stride + K``
input rows): walked backwards from the last layer to the first, a block of
``R`` final rows maps to an *affine* interval of every intermediate
feature map —

    rows of layer i's input needed by block ``rb`` =
        [ M_i * rb + O_i,  M_i * rb + O_i + L_i )

with static per-layer multiplier ``M_i``, offset ``O_i`` (negative offsets
mean the block reaches into SAME top padding) and constant slab length
``L_i``. The fused block's input halo is exactly the composition of each
layer's ``max(0, (pool - pool_stride)*s + K - s)`` requirement; overlap
rows are recomputed per block so pool windows never straddle blocks.

This module computes that geometry once, statically, for all three
renderings of a fusion group (the Pallas kernel, the XLA fallback and the
planner's VMEM cost model):

- :func:`group_geometry` builds the per-layer :class:`LayerGeom` chain for
  a given final-rows-per-block ``R``;
- :func:`working_set_bytes` costs the per-block VMEM working set (input
  frame + per-layer slabs + tap operands + weights) that the fusion
  planner compares against its budget.

Row coordinates are *unpadded* feature-map coordinates for every layer:
SAME row padding is part of the interval composition (offsets go
negative), and the kernels realize it by masking slab rows outside
``[0, H_i)`` to zero — which is exactly the SAME zero-padding of that
layer once the slab is consumed by the next conv. Columns are not
blocked: every block spans the full feature width, so column SAME padding
stays a static per-layer pad.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence


def same_pads(d: int, stride: int, k: int) -> tuple:
    """XLA's SAME convention for one spatial dim: total = max((ceil(d/s) -
    1)*s + k - d, 0), low = total // 2. Returns (lo, hi)."""
    out = -(-d // stride)
    tot = max((out - 1) * stride + k - d, 0)
    lo = tot // 2
    return lo, tot - lo


@dataclasses.dataclass(frozen=True)
class PyramidLayer:
    """Static per-layer config of a fusion group (the layer vocabulary of
    one conv actor chain, minus the tensor shapes)."""

    padding: str = "VALID"
    stride: int = 1
    act: str = "none"
    pool: int = 0
    pool_stride: int | None = None


def as_pyramid_layers(specs: Sequence) -> tuple:
    """Normalize duck-typed conv-layer specs (e.g. ``ConvLayerSpec``) into
    hashable :class:`PyramidLayer` statics."""
    return tuple(
        PyramidLayer(
            padding=s.padding,
            stride=getattr(s, "stride", 1),
            act=s.act,
            pool=s.pool,
            pool_stride=getattr(s, "pool_stride", None),
        )
        for s in specs
    )


@dataclasses.dataclass(frozen=True)
class LayerGeom:
    """Static geometry of one layer inside a fusion group."""

    # Layer vocabulary (pool window normalized).
    k: int
    stride: int
    act: str
    pw: int  # pool window (0 = none)
    ps: int  # pool stride
    # Frame geometry (unpadded input -> conv -> pooled output).
    in_rows: int
    in_cols: int
    in_ch: int
    pads: tuple  # ((top, bottom), (left, right)) SAME pads
    conv_rows: int
    conv_cols: int
    out_rows: int
    out_cols: int
    n_out: int
    # Per-block affine row intervals (start = mult * rb + off, len rows).
    in_mult: int
    in_off: int
    in_slab_rows: int
    conv_slab_rows: int
    out_slab_rows: int


@dataclasses.dataclass(frozen=True)
class GroupGeometry:
    """The full static geometry of a fusion group for block size R."""

    layers: tuple  # LayerGeom per layer, first to last
    block_rows: int  # R: final output rows per block
    n_row_blocks: int
    out_rows: int  # h_keep of the whole group
    out_cols: int
    # Host-side row padding of the group input frame: the exact SAME pads
    # of layer 0 plus the extra rows that keep every block's (halo'd,
    # possibly negative-offset) read in bounds.
    in_pad_top: int
    in_pad_rows_total: int  # total padded frame rows after host padding
    in_pad_cols: tuple  # (left, right) exact SAME col pads of layer 0

    @property
    def input_row_shift(self) -> int:
        """Shift from unpadded layer-0 row coords to host-padded coords."""
        return self.in_pad_top


def _pool_cfg(layer: PyramidLayer) -> tuple:
    from repro.kernels.stream_conv.epilogue import normalize_pool

    return normalize_pool(layer.pool, layer.pool_stride)


def group_geometry(
    in_rows: int,
    in_cols: int,
    in_ch: int,
    layers: Sequence[PyramidLayer],
    kernels: Sequence[int],
    n_outs: Sequence[int],
    *,
    block_rows: int = 0,
) -> GroupGeometry:
    """Build the composed-halo geometry of a fusion group.

    ``block_rows=0`` means one block covering the whole final output (the
    no-halo fast path). Raises if any layer's spatial dims collapse.
    """
    if not layers:
        raise ValueError("a fusion group needs at least one layer")
    if not len(layers) == len(kernels) == len(n_outs):
        raise ValueError("layers/kernels/n_outs length mismatch")

    # Forward pass: frame dims per layer.
    dims = []  # (H, W, C, pads, conv_r, conv_c, out_r, out_c)
    h, w, c = in_rows, in_cols, in_ch
    for layer, k, n in zip(layers, kernels, n_outs):
        s = layer.stride
        if layer.padding == "SAME":
            pr, pc = same_pads(h, s, k), same_pads(w, s, k)
        elif layer.padding == "VALID":
            pr, pc = (0, 0), (0, 0)
        else:
            raise ValueError(f"unknown padding {layer.padding!r}")
        conv_r = (h + pr[0] + pr[1] - k) // s + 1
        conv_c = (w + pc[0] + pc[1] - k) // s + 1
        if conv_r < 1 or conv_c < 1:
            raise ValueError(
                f"conv output {conv_r}x{conv_c} empty for {h}x{w} input "
                f"(k={k}, stride={s})"
            )
        pw, ps = _pool_cfg(layer)
        if pw:
            if conv_r < pw or conv_c < pw:
                raise ValueError(
                    f"conv output {conv_r}x{conv_c} too small for "
                    f"{pw}x{pw} pool"
                )
            out_r = (conv_r - pw) // ps + 1
            out_c = (conv_c - pw) // ps + 1
        else:
            out_r, out_c = conv_r, conv_c
        dims.append((h, w, c, (pr, pc), conv_r, conv_c, out_r, out_c))
        h, w, c = out_r, out_c, n

    h_keep, w_keep = dims[-1][6], dims[-1][7]
    r = block_rows if block_rows > 0 else h_keep
    r = min(r, h_keep)
    n_rb = -(-h_keep // r)

    # Backward pass: affine input interval per layer, last to first.
    mult, off, length = r, 0, r
    geoms = [None] * len(layers)
    for i in reversed(range(len(layers))):
        layer, k = layers[i], kernels[i]
        h, w, c, pads, conv_r, conv_c, out_r, out_c = dims[i]
        pw, ps = _pool_cfg(layer)
        out_slab = length
        if pw:
            mult, off, length = mult * ps, off * ps, (length - 1) * ps + pw
        conv_slab = length
        s = layer.stride
        tp = pads[0][0]
        mult, off, length = mult * s, off * s - tp, (length - 1) * s + k
        geoms[i] = LayerGeom(
            k=k, stride=s, act=layer.act, pw=pw, ps=ps,
            in_rows=h, in_cols=w, in_ch=c, pads=pads,
            conv_rows=conv_r, conv_cols=conv_c,
            out_rows=out_r, out_cols=out_c, n_out=n_outs[i],
            in_mult=mult, in_off=off, in_slab_rows=length,
            conv_slab_rows=conv_slab, out_slab_rows=out_slab,
        )

    g0 = geoms[0]
    tp0 = g0.pads[0][0]
    # Host row padding: exact SAME top pad plus whatever keeps the most
    # negative block offset in bounds; bottom rows up to the deepest read.
    pad_top = tp0 + max(0, -(g0.in_off + tp0))
    last_end = g0.in_mult * (n_rb - 1) + g0.in_off + pad_top + g0.in_slab_rows
    rows_total = max(last_end, in_rows + pad_top)
    return GroupGeometry(
        layers=tuple(geoms),
        block_rows=r,
        n_row_blocks=n_rb,
        out_rows=h_keep,
        out_cols=w_keep,
        in_pad_top=pad_top,
        in_pad_rows_total=rows_total,
        in_pad_cols=g0.pads[1],
    )


def working_set_bytes(
    geom: GroupGeometry, *, elem_bytes: int = 4, acc_bytes: int | None = None
) -> int:
    """Per-block VMEM working set of the fused pyramid kernel, in bytes.

    Counts the (host-padded) input frame resident per grid cell, and per
    layer: the padded input slab, the column-assembled tap operand, the
    K*K patch operand feeding the single matmul, the conv-output slab, the
    pooled output slab, and the layer's weights + bias. This is the
    quantity the fusion planner holds against its VMEM budget.

    The costing is dtype-parametric: ``elem_bytes`` is the byte width of
    the streamed slabs (frames, inter-layer feature slabs, tap operands,
    weight codes — 4 on the fp32/fake-quant path, 1 when the plan
    computes in true int8 and the slabs really are int8 codes) and
    ``acc_bytes`` the accumulator/epilogue width (the int32 accumulator
    and its fp32 dequantization — defaults to ``elem_bytes`` so the
    historic fp32 totals are unchanged). Bias stays f32 on every path.
    """
    return sum(
        working_set_breakdown(
            geom, elem_bytes=elem_bytes, acc_bytes=acc_bytes
        ).values()
    )


def working_set_breakdown(
    geom: GroupGeometry, *, elem_bytes: int = 4, acc_bytes: int | None = None
) -> dict:
    """Per-component bytes of :func:`working_set_bytes` — ``frame`` for
    the resident input frame plus, per layer i, ``L{i}/slab_in``, ``z``,
    ``patches``, ``conv``, ``out`` and ``weights``. The plan verifier's
    resource findings (V201/V202/V204) cite this so a budget blow-up
    names the component that grew, not just the total.

    Streamed components (frame, input slabs, tap assembly, patches,
    inter-layer outputs, weight codes) are charged at ``elem_bytes``; the
    conv accumulator slabs and the group's final fp32 output at
    ``acc_bytes`` (default: ``elem_bytes``); bias at 4 bytes (f32 on
    every path)."""
    acc = elem_bytes if acc_bytes is None else acc_bytes
    g0 = geom.layers[0]
    last = len(geom.layers) - 1
    cols0 = g0.in_cols + sum(geom.in_pad_cols)
    parts = {
        "frame": geom.in_pad_rows_total * cols0 * g0.in_ch * elem_bytes
    }
    for i, g in enumerate(geom.layers):
        padded_cols = g.in_cols + g.pads[1][0] + g.pads[1][1]
        parts[f"L{i}/slab_in"] = (
            g.in_slab_rows * padded_cols * g.in_ch * elem_bytes
        )
        parts[f"L{i}/z"] = (
            g.in_slab_rows * g.conv_cols * g.k * g.in_ch * elem_bytes
        )
        parts[f"L{i}/patches"] = (
            g.conv_slab_rows * g.conv_cols * g.k * g.k * g.in_ch * elem_bytes
        )
        parts[f"L{i}/conv"] = g.conv_slab_rows * g.conv_cols * g.n_out * acc
        out_bytes = acc if i == last else elem_bytes
        parts[f"L{i}/out"] = g.out_slab_rows * g.out_cols * g.n_out * out_bytes
        parts[f"L{i}/weights"] = (
            g.k * g.k * g.in_ch * g.n_out * elem_bytes + g.n_out * 4
        )
    return parts
