"""The seed streaming-conv kernel, kept verbatim as a benchmark baseline.

One output row per grid step, K*K per-tap dots against (C, N) tap matrices,
with a (K-1)-row VMEM line buffer rotated by hand. Superseded by the
row-blocked single-matmul kernel in ``conv.py`` — this version exists only
so ``benchmarks/kernel_bench.py`` can keep measuring the speedup of the
fused path against the original design, and as a second correctness oracle.
Interpret mode only; do not use in model code.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _stream_conv_kernel_seed(x_row_ref, w_ref, o_ref, lbuf_ref, *, k, w_out):
    """One grid step: consume input row (r + K - 1), emit output row r."""
    new_row = x_row_ref[0, 0]  # (W, C)

    acc = jnp.zeros((w_out, o_ref.shape[-1]), jnp.float32)
    for ki in range(k):
        row = lbuf_ref[ki] if ki < k - 1 else new_row
        for kj in range(k):
            seg = jax.lax.dynamic_slice_in_dim(row, kj, w_out, axis=0)
            tap = w_ref[ki * k + kj]  # (C, N)
            acc += jnp.dot(
                seg.astype(jnp.float32),
                tap.astype(jnp.float32),
                preferred_element_type=jnp.float32,
            )
    o_ref[0, 0] = acc.astype(o_ref.dtype)

    for ki in range(k - 2):
        lbuf_ref[ki] = lbuf_ref[ki + 1]
    if k >= 2:
        lbuf_ref[k - 2] = new_row


@functools.partial(jax.jit, static_argnames=("k", "out_dtype", "interpret"))
def stream_conv2d_pallas_seed(
    x: jax.Array,  # (B, H, W, C)
    w_taps: jax.Array,  # (K*K, C, N)
    *,
    k: int,
    out_dtype=jnp.float32,
    interpret: bool = True,
) -> jax.Array:
    b, h, wd, c = x.shape
    kk, c2, n = w_taps.shape
    if kk != k * k or c2 != c:
        raise ValueError(f"w_taps {w_taps.shape} inconsistent with k={k}, C={c}")
    h_out, w_out = h - k + 1, wd - k + 1
    if h_out <= 0 or w_out <= 0:
        raise ValueError(f"image {h}x{wd} too small for k={k}")

    kernel = functools.partial(_stream_conv_kernel_seed, k=k, w_out=w_out)

    def _kernel_with_fill(x_row_ref, x_fill_ref, w_ref, o_ref, lbuf_ref):
        r = pl.program_id(1)

        @pl.when(r == 0)
        def _fill():
            lbuf_ref[...] = x_fill_ref[0]

        kernel(x_row_ref, w_ref, o_ref, lbuf_ref)

    grid = (b, h_out)
    return pl.pallas_call(
        _kernel_with_fill,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, wd, c), lambda bb, r: (bb, r + k - 1, 0, 0)),
            pl.BlockSpec((1, max(1, k - 1), wd, c), lambda bb, r: (bb, 0, 0, 0)),
            pl.BlockSpec((k * k, c, n), lambda bb, r: (0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, w_out, n), lambda bb, r: (bb, r, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h_out, w_out, n), out_dtype),
        scratch_shapes=[pltpu.VMEM((max(1, k - 1), wd, c), x.dtype)],
        interpret=interpret,
    )(
        x.reshape(b, h, wd, c),
        x,
        w_taps,
    )
