"""Public wrappers for the streaming conv kernels.

``stream_conv2d`` is the bare conv (kept for API compatibility and as the
benchmark subject); ``stream_conv_block`` is the fused
conv -> bias -> activation -> max-pool actor chain — the DHM pipeline
stage — used by the CNN model, the DHM pipeline stage bodies, and the
examples. Both accept a conv ``stride``; the block additionally takes a
``(pool, pool_stride)`` pair (square window, sliding stride; ``pool=2``
keeps meaning the classic 2x2/stride-2) and ``block_w`` column blocking
for frames wider than VMEM.

Backends (validated; see ``repro.kernels.backends``):
  - ``pallas``:           compiled. Mosaic-compiled Pallas on TPU; on
                          platforms without compiled Pallas (XLA:CPU) the
                          same row-block single-matmul algorithm is lowered
                          through XLA (``xla.py``). This is the default.
  - ``pallas_interpret``: the Pallas kernel through the interpreter — the
                          correctness oracle.
  - ``ref``:              plain ``lax.conv`` composition.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.backends import (
    DEFAULT_BACKEND,
    compiled_pallas_available,
    validate_backend,
)
from repro.kernels.stream_conv.conv import (
    stream_conv_fused_pallas,
    stream_conv_pyramid_pallas,
)
from repro.kernels.stream_conv.halo import as_pyramid_layers
from repro.kernels.stream_conv.ref import (
    stream_conv_block_ref,
    stream_conv_pyramid_ref,
)
from repro.kernels.stream_conv.xla import (
    stream_conv_fused_xla,
    stream_conv_pyramid_xla,
)


def _pad_same(x: jax.Array, k: int, stride: int = 1) -> jax.Array:
    """SAME pads on the host side (the FPGA engine pads the pixel stream
    at frame edges). XLA's SAME convention — per dim, total = max((ceil(d/s)
    - 1)*s + k - d, 0), low = total//2, high = total - low — so strided and
    even-K results match the lax.conv reference backend exactly."""

    def split(d: int) -> tuple:
        out = -(-d // stride)
        tot = max((out - 1) * stride + k - d, 0)
        lo = tot // 2
        return lo, tot - lo

    ph = split(x.shape[1])
    pw = split(x.shape[2])
    return jnp.pad(x, ((0, 0), ph, pw, (0, 0)))


def _validate_int8(int8_scales, act_bits, w) -> None:
    if int8_scales is None:
        return
    if act_bits is None:
        raise ValueError("int8_scales requires act_bits (the stream grid)")
    if not jnp.issubdtype(w.dtype, jnp.signedinteger):
        raise ValueError(
            f"int8_scales requires int8 weight codes, got {w.dtype} — bake "
            "weights with quantize_fixed(w, dynamic_spec(w, bits))"
        )


def _fused_dispatch(
    x, w, b, *, padding, stride, act, pool, pool_stride, act_bits,
    int8_scales, out_dtype, backend, block_r, block_w, block_c, block_n,
):
    k = w.shape[0]
    if w.shape[1] != k:
        raise ValueError(f"only square kernels, got {w.shape}")
    validate_backend(backend)
    _validate_int8(int8_scales, act_bits, w)
    if backend == "ref":
        return stream_conv_block_ref(
            x, w, b, padding=padding, stride=stride, act=act, pool=pool,
            pool_stride=pool_stride, act_bits=act_bits,
            int8_scales=int8_scales,
        ).astype(out_dtype)
    if padding == "SAME":
        x = _pad_same(x, k, stride)
    elif padding != "VALID":
        raise ValueError(padding)
    if int8_scales is not None and jnp.issubdtype(x.dtype, jnp.floating):
        # Quantize onto the input stream grid OUTSIDE the kernel call: the
        # resident frame is int8 codes (1 byte/element — what the fusion
        # planner charges), and pad zeros above are code 0 == value 0.
        from repro.core.quant.fixed_point import quantize_fixed

        x = quantize_fixed(x, int8_scales.in_spec).astype(jnp.int8)
    w_taps = w.reshape(k * k, w.shape[2], w.shape[3])
    if backend == "pallas" and not compiled_pallas_available():
        # Compiled fallback: identical algorithm, lowered through XLA.
        # Row blocks there are sized from a memory budget, not VMEM, so
        # the block_* tuning knobs are Pallas-only.
        return stream_conv_fused_xla(
            x, w_taps, b, k=k, stride=stride, act=act, pool=pool,
            pool_stride=pool_stride, act_bits=act_bits,
            int8_scales=int8_scales, out_dtype=out_dtype,
        )
    return stream_conv_fused_pallas(
        x,
        w_taps,
        b,
        k=k,
        stride=stride,
        act=act,
        pool=pool,
        pool_stride=pool_stride,
        act_bits=act_bits,
        int8_scales=int8_scales,
        block_r=block_r,
        block_w=block_w,
        block_c=block_c,
        block_n=block_n,
        out_dtype=out_dtype,
        interpret=(backend == "pallas_interpret"),
    )


@functools.partial(
    jax.jit,
    static_argnames=(
        "padding", "stride", "backend", "out_dtype", "block_r", "block_w",
        "block_c", "block_n",
    ),
)
def stream_conv2d(
    x: jax.Array,  # (B, H, W, C)
    w: jax.Array,  # (K, K, C, N) HWIO
    *,
    padding: str = "VALID",
    stride: int = 1,
    out_dtype=jnp.float32,
    backend: str = DEFAULT_BACKEND,
    block_r: int = 8,
    block_w: int = 0,
    block_c: int = 0,
    block_n: int = 0,
) -> jax.Array:
    """Streaming conv2d, stride ``stride``, no epilogue. SAME pads on the
    host side."""
    zero_b = jnp.zeros((w.shape[3],), jnp.float32)
    return _fused_dispatch(
        x, w, zero_b,
        padding=padding, stride=stride, act="none", pool=0, pool_stride=None,
        act_bits=None, int8_scales=None, out_dtype=out_dtype, backend=backend,
        block_r=block_r, block_w=block_w, block_c=block_c, block_n=block_n,
    )


@functools.partial(
    jax.jit,
    static_argnames=(
        "padding", "stride", "act", "pool", "pool_stride", "act_bits",
        "int8_scales", "backend", "out_dtype", "block_r", "block_w",
        "block_c", "block_n",
    ),
)
def stream_conv_block(
    x: jax.Array,  # (B, H, W, C)
    w: jax.Array,  # (K, K, C, N) HWIO
    b: jax.Array,  # (N,)
    *,
    padding: str = "VALID",
    stride: int = 1,
    act: str = "relu",
    pool: int = 2,
    pool_stride: int | None = None,
    act_bits: int | None = None,
    int8_scales=None,
    out_dtype=jnp.float32,
    backend: str = DEFAULT_BACKEND,
    block_r: int = 8,
    block_w: int = 0,
    block_c: int = 0,
    block_n: int = 0,
) -> jax.Array:
    """Fused conv -> bias -> act -> NxN/stride-s-max-pool block (one DHM
    pipeline stage). ``pool=0`` disables pooling, ``pool_stride=None``
    means window == stride (so ``pool=2`` is the classic 2x2/2),
    ``act='none'`` the activation; ``act_bits`` quantizes the output
    feature stream inside the same fused epilogue (the paper's quantized
    pixel flow — no separate HBM pass).

    ``int8_scales`` (a static ``epilogue.Int8Scales``) switches all
    backends to true integer arithmetic: ``w`` must be int8 weight codes,
    the input is quantized onto its stream grid (exact for on-grid
    values), and the conv contracts int8 x int8 -> int32 before the
    requantizing epilogue — fp32 values on the ``act_bits`` grid out, so
    the call boundary contract is unchanged."""
    return _fused_dispatch(
        x, w, b,
        padding=padding, stride=stride, act=act, pool=pool,
        pool_stride=pool_stride, act_bits=act_bits, int8_scales=int8_scales,
        out_dtype=out_dtype, backend=backend,
        block_r=block_r, block_w=block_w, block_c=block_c, block_n=block_n,
    )


def stream_conv_pyramid(
    x: jax.Array,  # (B, H, W, C0)
    weights,  # sequence of (K, K, C, N) HWIO, one per layer
    biases,  # sequence of (N,), one per layer
    *,
    layers,  # sequence of layer specs (padding/stride/act/pool[/pool_stride])
    act_bits=None,  # int | None | per-layer tuple
    int8_scales=None,  # None | per-layer tuple of Int8Scales
    block_rows: int = 0,
    out_dtype=jnp.float32,
    backend: str = DEFAULT_BACKEND,
) -> jax.Array:
    """Cross-layer fused conv pyramid: a whole fusion group of consecutive
    conv -> bias -> act -> pool layers as ONE kernel invocation, with all
    inter-layer feature slabs kept on-chip (VMEM scratch on the Pallas
    path) — the paper's no-external-memory dataflow property extended
    across layer boundaries.

    ``layers`` is a sequence of duck-typed layer specs (``ConvLayerSpec``
    or anything with ``padding``/``act``/``pool`` and the optional
    generalized fields); ``weights``/``biases`` are the matching per-layer
    tensors. ``block_rows`` sets the final-output rows streamed per block
    on the Pallas path (0 = whole frame; the input halo per block is the
    composed per-layer requirement from ``halo.group_geometry``). The
    ``pallas`` backend lowers through Mosaic on TPU and through the
    one-closure XLA rendering elsewhere; ``pallas_interpret`` runs the
    exact multi-layer kernel program as the oracle; ``ref`` is the
    unfused per-layer chain.

    ``act_bits`` may be a per-layer tuple (mixed-bitwidth plans);
    ``int8_scales`` (per-layer tuple of ``Int8Scales``) selects true
    integer arithmetic: the frame is quantized onto layer 0's stream grid
    before the kernel (1-byte resident frame), interior layers consume
    and emit int8 stream codes, and each ``Int8Scales.in_bits`` must name
    the previous layer's ``act_bits`` (the code chain contract).
    """
    validate_backend(backend)
    weights = tuple(weights)
    biases = tuple(biases)
    layers = tuple(layers)
    if not weights or len(weights) != len(biases) or len(weights) != len(layers):
        raise ValueError(
            f"pyramid needs matching layers/weights/biases, got "
            f"{len(layers)}/{len(weights)}/{len(biases)}"
        )
    for li, w in enumerate(weights):
        if w.ndim != 4 or w.shape[0] != w.shape[1]:
            raise ValueError(
                f"pyramid layer {li}: only square HWIO kernels, got {w.shape}"
            )
    bits = (
        act_bits if isinstance(act_bits, tuple)
        else (act_bits,) * len(layers)
    )
    if len(bits) != len(layers):
        raise ValueError(
            f"act_bits tuple has {len(bits)} entries for "
            f"{len(layers)} layers"
        )
    if int8_scales is not None:
        int8_scales = tuple(int8_scales)
        if len(int8_scales) != len(layers):
            raise ValueError(
                f"int8_scales has {len(int8_scales)} entries for "
                f"{len(layers)} layers"
            )
        for li, (sc, w) in enumerate(zip(int8_scales, weights)):
            _validate_int8(sc, bits[li], w)
            if li and sc.in_bits != bits[li - 1]:
                raise ValueError(
                    f"pyramid layer {li}: in_bits={sc.in_bits} must equal "
                    f"the previous layer's act_bits={bits[li - 1]} (the "
                    "inter-layer code chain)"
                )
    pyr = as_pyramid_layers(layers)
    if backend == "ref":
        return stream_conv_pyramid_ref(
            x, weights, biases, layers=pyr, act_bits=bits,
            int8_scales=int8_scales,
        ).astype(out_dtype)
    if backend == "pallas" and not compiled_pallas_available():
        return stream_conv_pyramid_xla(
            x, weights, biases, layers=pyr, act_bits=bits,
            int8_scales=int8_scales, out_dtype=out_dtype,
        )
    if int8_scales is not None and jnp.issubdtype(x.dtype, jnp.floating):
        # Quantize onto layer 0's stream grid OUTSIDE the pallas_call: the
        # VMEM-resident frame is int8 codes — what the planner charges.
        from repro.core.quant.fixed_point import quantize_fixed

        x = quantize_fixed(x, int8_scales[0].in_spec).astype(jnp.int8)
    return stream_conv_pyramid_pallas(
        x, weights, biases, layers=pyr, act_bits=bits,
        int8_scales=int8_scales, block_rows=block_rows, out_dtype=out_dtype,
        interpret=(backend == "pallas_interpret"),
    )
