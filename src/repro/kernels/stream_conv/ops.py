"""Public wrappers for the streaming conv kernels.

``stream_conv2d`` is the bare conv (kept for API compatibility and as the
benchmark subject); ``stream_conv_block`` is the fused
conv -> bias -> activation -> max-pool actor chain — the DHM pipeline
stage — used by the CNN model, the DHM pipeline stage bodies, and the
examples. Both accept a conv ``stride``; the block additionally takes a
``(pool, pool_stride)`` pair (square window, sliding stride; ``pool=2``
keeps meaning the classic 2x2/stride-2) and ``block_w`` column blocking
for frames wider than VMEM.

Backends (validated; see ``repro.kernels.backends``):
  - ``pallas``:           compiled. Mosaic-compiled Pallas on TPU; on
                          platforms without compiled Pallas (XLA:CPU) the
                          same row-block single-matmul algorithm is lowered
                          through XLA (``xla.py``). This is the default.
  - ``pallas_interpret``: the Pallas kernel through the interpreter — the
                          correctness oracle.
  - ``ref``:              plain ``lax.conv`` composition.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.backends import (
    DEFAULT_BACKEND,
    compiled_pallas_available,
    validate_backend,
)
from repro.kernels.stream_conv.conv import (
    stream_conv_fused_pallas,
    stream_conv_pyramid_pallas,
)
from repro.kernels.stream_conv.halo import as_pyramid_layers
from repro.kernels.stream_conv.ref import (
    stream_conv_block_ref,
    stream_conv_pyramid_ref,
)
from repro.kernels.stream_conv.xla import (
    stream_conv_fused_xla,
    stream_conv_pyramid_xla,
)


def _pad_same(x: jax.Array, k: int, stride: int = 1) -> jax.Array:
    """SAME pads on the host side (the FPGA engine pads the pixel stream
    at frame edges). XLA's SAME convention — per dim, total = max((ceil(d/s)
    - 1)*s + k - d, 0), low = total//2, high = total - low — so strided and
    even-K results match the lax.conv reference backend exactly."""

    def split(d: int) -> tuple:
        out = -(-d // stride)
        tot = max((out - 1) * stride + k - d, 0)
        lo = tot // 2
        return lo, tot - lo

    ph = split(x.shape[1])
    pw = split(x.shape[2])
    return jnp.pad(x, ((0, 0), ph, pw, (0, 0)))


def _fused_dispatch(
    x, w, b, *, padding, stride, act, pool, pool_stride, act_bits, out_dtype,
    backend, block_r, block_w, block_c, block_n,
):
    k = w.shape[0]
    if w.shape[1] != k:
        raise ValueError(f"only square kernels, got {w.shape}")
    validate_backend(backend)
    if backend == "ref":
        return stream_conv_block_ref(
            x, w, b, padding=padding, stride=stride, act=act, pool=pool,
            pool_stride=pool_stride, act_bits=act_bits,
        ).astype(out_dtype)
    if padding == "SAME":
        x = _pad_same(x, k, stride)
    elif padding != "VALID":
        raise ValueError(padding)
    w_taps = w.reshape(k * k, w.shape[2], w.shape[3])
    if backend == "pallas" and not compiled_pallas_available():
        # Compiled fallback: identical algorithm, lowered through XLA.
        # Row blocks there are sized from a memory budget, not VMEM, so
        # the block_* tuning knobs are Pallas-only.
        return stream_conv_fused_xla(
            x, w_taps, b, k=k, stride=stride, act=act, pool=pool,
            pool_stride=pool_stride, act_bits=act_bits, out_dtype=out_dtype,
        )
    return stream_conv_fused_pallas(
        x,
        w_taps,
        b,
        k=k,
        stride=stride,
        act=act,
        pool=pool,
        pool_stride=pool_stride,
        act_bits=act_bits,
        block_r=block_r,
        block_w=block_w,
        block_c=block_c,
        block_n=block_n,
        out_dtype=out_dtype,
        interpret=(backend == "pallas_interpret"),
    )


@functools.partial(
    jax.jit,
    static_argnames=(
        "padding", "stride", "backend", "out_dtype", "block_r", "block_w",
        "block_c", "block_n",
    ),
)
def stream_conv2d(
    x: jax.Array,  # (B, H, W, C)
    w: jax.Array,  # (K, K, C, N) HWIO
    *,
    padding: str = "VALID",
    stride: int = 1,
    out_dtype=jnp.float32,
    backend: str = DEFAULT_BACKEND,
    block_r: int = 8,
    block_w: int = 0,
    block_c: int = 0,
    block_n: int = 0,
) -> jax.Array:
    """Streaming conv2d, stride ``stride``, no epilogue. SAME pads on the
    host side."""
    zero_b = jnp.zeros((w.shape[3],), jnp.float32)
    return _fused_dispatch(
        x, w, zero_b,
        padding=padding, stride=stride, act="none", pool=0, pool_stride=None,
        act_bits=None, out_dtype=out_dtype, backend=backend,
        block_r=block_r, block_w=block_w, block_c=block_c, block_n=block_n,
    )


@functools.partial(
    jax.jit,
    static_argnames=(
        "padding", "stride", "act", "pool", "pool_stride", "act_bits",
        "backend", "out_dtype", "block_r", "block_w", "block_c", "block_n",
    ),
)
def stream_conv_block(
    x: jax.Array,  # (B, H, W, C)
    w: jax.Array,  # (K, K, C, N) HWIO
    b: jax.Array,  # (N,)
    *,
    padding: str = "VALID",
    stride: int = 1,
    act: str = "relu",
    pool: int = 2,
    pool_stride: int | None = None,
    act_bits: int | None = None,
    out_dtype=jnp.float32,
    backend: str = DEFAULT_BACKEND,
    block_r: int = 8,
    block_w: int = 0,
    block_c: int = 0,
    block_n: int = 0,
) -> jax.Array:
    """Fused conv -> bias -> act -> NxN/stride-s-max-pool block (one DHM
    pipeline stage). ``pool=0`` disables pooling, ``pool_stride=None``
    means window == stride (so ``pool=2`` is the classic 2x2/2),
    ``act='none'`` the activation; ``act_bits`` quantizes the output
    feature stream inside the same fused epilogue (the paper's quantized
    pixel flow — no separate HBM pass)."""
    return _fused_dispatch(
        x, w, b,
        padding=padding, stride=stride, act=act, pool=pool,
        pool_stride=pool_stride, act_bits=act_bits,
        out_dtype=out_dtype, backend=backend,
        block_r=block_r, block_w=block_w, block_c=block_c, block_n=block_n,
    )


def stream_conv_pyramid(
    x: jax.Array,  # (B, H, W, C0)
    weights,  # sequence of (K, K, C, N) HWIO, one per layer
    biases,  # sequence of (N,), one per layer
    *,
    layers,  # sequence of layer specs (padding/stride/act/pool[/pool_stride])
    act_bits: int | None = None,
    block_rows: int = 0,
    out_dtype=jnp.float32,
    backend: str = DEFAULT_BACKEND,
) -> jax.Array:
    """Cross-layer fused conv pyramid: a whole fusion group of consecutive
    conv -> bias -> act -> pool layers as ONE kernel invocation, with all
    inter-layer feature slabs kept on-chip (VMEM scratch on the Pallas
    path) — the paper's no-external-memory dataflow property extended
    across layer boundaries.

    ``layers`` is a sequence of duck-typed layer specs (``ConvLayerSpec``
    or anything with ``padding``/``act``/``pool`` and the optional
    generalized fields); ``weights``/``biases`` are the matching per-layer
    tensors. ``block_rows`` sets the final-output rows streamed per block
    on the Pallas path (0 = whole frame; the input halo per block is the
    composed per-layer requirement from ``halo.group_geometry``). The
    ``pallas`` backend lowers through Mosaic on TPU and through the
    one-closure XLA rendering elsewhere; ``pallas_interpret`` runs the
    exact multi-layer kernel program as the oracle; ``ref`` is the
    unfused per-layer chain.
    """
    validate_backend(backend)
    weights = tuple(weights)
    biases = tuple(biases)
    layers = tuple(layers)
    if not weights or len(weights) != len(biases) or len(weights) != len(layers):
        raise ValueError(
            f"pyramid needs matching layers/weights/biases, got "
            f"{len(layers)}/{len(weights)}/{len(biases)}"
        )
    for li, w in enumerate(weights):
        if w.ndim != 4 or w.shape[0] != w.shape[1]:
            raise ValueError(
                f"pyramid layer {li}: only square HWIO kernels, got {w.shape}"
            )
    pyr = as_pyramid_layers(layers)
    if backend == "ref":
        return stream_conv_pyramid_ref(
            x, weights, biases, layers=pyr, act_bits=act_bits
        ).astype(out_dtype)
    if backend == "pallas" and not compiled_pallas_available():
        return stream_conv_pyramid_xla(
            x, weights, biases, layers=pyr, act_bits=act_bits,
            out_dtype=out_dtype,
        )
    return stream_conv_pyramid_pallas(
        x, weights, biases, layers=pyr, act_bits=act_bits,
        block_rows=block_rows, out_dtype=out_dtype,
        interpret=(backend == "pallas_interpret"),
    )
