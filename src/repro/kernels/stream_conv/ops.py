"""Public wrappers for the streaming conv kernels.

``stream_conv2d`` is the bare conv (kept for API compatibility and as the
benchmark subject); ``stream_conv_block`` is the fused
conv -> bias -> activation -> 2x2-max-pool actor chain — the DHM pipeline
stage — used by the CNN model, the DHM pipeline stage bodies, and the
examples.

Backends (validated; see ``repro.kernels.backends``):
  - ``pallas``:           compiled. Mosaic-compiled Pallas on TPU; on
                          platforms without compiled Pallas (XLA:CPU) the
                          same row-block single-matmul algorithm is lowered
                          through XLA (``xla.py``). This is the default.
  - ``pallas_interpret``: the Pallas kernel through the interpreter — the
                          correctness oracle.
  - ``ref``:              plain ``lax.conv`` composition.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.backends import (
    DEFAULT_BACKEND,
    compiled_pallas_available,
    validate_backend,
)
from repro.kernels.stream_conv.conv import stream_conv_fused_pallas
from repro.kernels.stream_conv.ref import stream_conv_block_ref
from repro.kernels.stream_conv.xla import stream_conv_fused_xla


def _pad_same(x: jax.Array, k: int) -> jax.Array:
    """SAME pads on the host side (the FPGA engine pads the pixel stream
    at frame edges). XLA's SAME convention — low = (k-1)//2, high = k//2 —
    so even-K results match the lax.conv reference backend exactly."""
    lo = (k - 1) // 2
    hi = k // 2
    return jnp.pad(x, ((0, 0), (lo, hi), (lo, hi), (0, 0)))


def _fused_dispatch(
    x, w, b, *, padding, act, pool, act_bits, out_dtype, backend,
    block_r, block_c, block_n,
):
    k = w.shape[0]
    if w.shape[1] != k:
        raise ValueError(f"only square kernels, got {w.shape}")
    validate_backend(backend)
    if backend == "ref":
        return stream_conv_block_ref(
            x, w, b, padding=padding, act=act, pool=pool, act_bits=act_bits
        ).astype(out_dtype)
    if padding == "SAME":
        x = _pad_same(x, k)
    elif padding != "VALID":
        raise ValueError(padding)
    w_taps = w.reshape(k * k, w.shape[2], w.shape[3])
    if backend == "pallas" and not compiled_pallas_available():
        # Compiled fallback: identical algorithm, lowered through XLA.
        # Row blocks there are sized from a memory budget, not VMEM, so
        # the block_* tuning knobs are Pallas-only.
        return stream_conv_fused_xla(
            x, w_taps, b, k=k, act=act, pool=pool, act_bits=act_bits,
            out_dtype=out_dtype,
        )
    return stream_conv_fused_pallas(
        x,
        w_taps,
        b,
        k=k,
        act=act,
        pool=pool,
        act_bits=act_bits,
        block_r=block_r,
        block_c=block_c,
        block_n=block_n,
        out_dtype=out_dtype,
        interpret=(backend == "pallas_interpret"),
    )


@functools.partial(
    jax.jit,
    static_argnames=(
        "padding", "backend", "out_dtype", "block_r", "block_c", "block_n"
    ),
)
def stream_conv2d(
    x: jax.Array,  # (B, H, W, C)
    w: jax.Array,  # (K, K, C, N) HWIO
    *,
    padding: str = "VALID",
    out_dtype=jnp.float32,
    backend: str = DEFAULT_BACKEND,
    block_r: int = 8,
    block_c: int = 0,
    block_n: int = 0,
) -> jax.Array:
    """Streaming conv2d, stride 1, no epilogue. SAME pads on the host side."""
    zero_b = jnp.zeros((w.shape[3],), jnp.float32)
    return _fused_dispatch(
        x, w, zero_b,
        padding=padding, act="none", pool=0, act_bits=None,
        out_dtype=out_dtype, backend=backend,
        block_r=block_r, block_c=block_c, block_n=block_n,
    )


@functools.partial(
    jax.jit,
    static_argnames=(
        "padding", "act", "pool", "act_bits", "backend", "out_dtype",
        "block_r", "block_c", "block_n",
    ),
)
def stream_conv_block(
    x: jax.Array,  # (B, H, W, C)
    w: jax.Array,  # (K, K, C, N) HWIO
    b: jax.Array,  # (N,)
    *,
    padding: str = "VALID",
    act: str = "relu",
    pool: int = 2,
    act_bits: int | None = None,
    out_dtype=jnp.float32,
    backend: str = DEFAULT_BACKEND,
    block_r: int = 8,
    block_c: int = 0,
    block_n: int = 0,
) -> jax.Array:
    """Fused conv -> bias -> act -> 2x2-max-pool block (one DHM pipeline
    stage). ``pool=0`` disables pooling, ``act='none'`` the activation;
    ``act_bits`` quantizes the output feature stream inside the same fused
    epilogue (the paper's quantized pixel flow — no separate HBM pass)."""
    return _fused_dispatch(
        x, w, b,
        padding=padding, act=act, pool=pool, act_bits=act_bits,
        out_dtype=out_dtype, backend=backend,
        block_r=block_r, block_c=block_c, block_n=block_n,
    )
