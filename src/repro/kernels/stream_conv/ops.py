"""Public wrapper for the streaming line-buffer conv2d."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.stream_conv.conv import stream_conv2d_pallas
from repro.kernels.stream_conv.ref import stream_conv2d_ref


@functools.partial(jax.jit, static_argnames=("padding", "backend", "out_dtype"))
def stream_conv2d(
    x: jax.Array,  # (B, H, W, C)
    w: jax.Array,  # (K, K, C, N) HWIO
    *,
    padding: str = "VALID",
    out_dtype=jnp.float32,
    backend: str = "pallas_interpret",
) -> jax.Array:
    """Streaming conv2d, stride 1. SAME pads on the host side (the FPGA
    engine pads the pixel stream at frame edges)."""
    k = w.shape[0]
    if w.shape[1] != k:
        raise ValueError(f"only square kernels, got {w.shape}")
    if padding == "SAME":
        pad = k // 2
        x = jnp.pad(x, ((0, 0), (pad, k - 1 - pad), (pad, k - 1 - pad), (0, 0)))
    elif padding != "VALID":
        raise ValueError(padding)
    if backend == "ref":
        return stream_conv2d_ref(x, w).astype(out_dtype)
    w_taps = w.reshape(k * k, w.shape[2], w.shape[3])
    return stream_conv2d_pallas(
        x,
        w_taps,
        k=k,
        out_dtype=out_dtype,
        interpret=(backend == "pallas_interpret"),
    )
