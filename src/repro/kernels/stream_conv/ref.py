"""Pure-jnp oracles for the streaming conv kernels.

``stream_conv2d_ref`` is a plain VALID conv2d (NHWC x HWIO -> NHWC) with a
configurable stride — the semantics of the paper's dataflow conv engine
once the stream is re-assembled into a frame. ``stream_conv_block_ref``
composes the UNFUSED actor chain (conv, + bias, activation, NxN/stride-s
max-pool, feature-stream fake-quant) as separate XLA ops; the fused
kernels must match it exactly. The quantization step here deliberately
goes through ``fake_quant_ste`` (the model-level reference) so the
in-kernel epilogue is tested against an independent rendering of the same
Q-format, and the pooling goes through ``lax.reduce_window`` so the
epilogue's shifted-strided-view pool is tested against an independent
rendering too.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.quant.fixed_point import (
    FixedPointSpec,
    fake_quant_ste,
    quantize_fixed,
)
from repro.kernels.stream_conv.epilogue import ACTS, normalize_pool


def stream_conv2d_ref(
    x: jax.Array, w: jax.Array, *, stride: int = 1
) -> jax.Array:
    """x: (B, H, W, C); w: (K, K, C, N). VALID, stride ``stride`` ->
    (B, (H-K)//s+1, (W-K)//s+1, N)."""
    return jax.lax.conv_general_dilated(
        x.astype(jnp.float32),
        w.astype(jnp.float32),
        window_strides=(stride, stride),
        padding="VALID",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )


def stream_conv_block_ref(
    x: jax.Array,  # (B, H, W, C)
    w: jax.Array,  # (K, K, C, N) HWIO
    b: jax.Array,  # (N,)
    *,
    padding: str = "VALID",
    stride: int = 1,
    act: str = "none",
    pool: int = 0,
    pool_stride: int | None = None,
    act_bits: int | None = None,
    int8_scales=None,
) -> jax.Array:
    """Unfused conv -> bias -> act -> NxN/stride-s max-pool -> fake-quant
    reference composition.

    ``int8_scales`` (an ``epilogue.Int8Scales``) switches the conv to the
    true-integer rendering: the input is quantized onto its stream grid as
    int8 codes (exact for on-grid values), ``w`` must already be int8
    weight codes, and the conv contracts integers into an int32
    accumulator (``preferred_element_type``) that one exact pow2 multiply
    dequantizes back to fp32 before the bias/act/pool/quant chain.
    """
    if act not in ACTS:
        raise ValueError(f"unknown act {act!r}")
    pw, ps = normalize_pool(pool, pool_stride)
    if int8_scales is not None:
        if not jnp.issubdtype(w.dtype, jnp.signedinteger):
            raise ValueError(
                f"int8_scales given but weights are {w.dtype}, not int codes"
            )
        qx = (
            quantize_fixed(x, int8_scales.in_spec).astype(jnp.int8)
            if jnp.issubdtype(x.dtype, jnp.floating)
            else x
        )
        y = jax.lax.conv_general_dilated(
            qx,
            w.astype(jnp.int8),
            window_strides=(stride, stride),
            padding=padding,
            dimension_numbers=("NHWC", "HWIO", "NHWC"),
            preferred_element_type=jnp.int32,
        )
        y = y.astype(jnp.float32) * int8_scales.deq_scale
    else:
        y = jax.lax.conv_general_dilated(
            x.astype(jnp.float32),
            w.astype(jnp.float32),
            window_strides=(stride, stride),
            padding=padding,
            dimension_numbers=("NHWC", "HWIO", "NHWC"),
        )
    y = y + b.astype(jnp.float32)
    if act == "relu":
        y = jnp.maximum(y, 0.0)
    elif act == "tanh":
        y = jnp.tanh(y)
    if pw:
        y = jax.lax.reduce_window(
            y,
            -jnp.inf,
            jax.lax.max,
            window_dimensions=(1, pw, pw, 1),
            window_strides=(1, ps, ps, 1),
            padding="VALID",
        )
    if act_bits is not None:
        y = fake_quant_ste(y, FixedPointSpec(bits=act_bits, frac_bits=act_bits - 2))
    return y


def stream_conv_pyramid_ref(
    x: jax.Array,
    weights,  # per layer (K, K, C, N)
    biases,  # per layer (N,)
    *,
    layers,  # PyramidLayer per layer (padding/stride/act/pool/pool_stride)
    act_bits=None,  # int | None | per-layer tuple
    int8_scales=None,  # None | per-layer tuple of Int8Scales
) -> jax.Array:
    """Reference rendering of a fusion group: the plain per-layer
    ``stream_conv_block_ref`` chain. Fusion is a scheduling decision, not
    a semantic one — the group's math is exactly the layer composition."""
    n = len(tuple(layers))
    bits = act_bits if isinstance(act_bits, tuple) else (act_bits,) * n
    for i, (layer, w, b) in enumerate(zip(layers, weights, biases)):
        x = stream_conv_block_ref(
            x, w, b, padding=layer.padding, stride=layer.stride,
            act=layer.act, pool=layer.pool, pool_stride=layer.pool_stride,
            act_bits=bits[i],
            int8_scales=None if int8_scales is None else int8_scales[i],
        )
    return x
