"""Pure-jnp oracle for the streaming line-buffer convolution: a plain VALID
conv2d (NHWC x HWIO -> NHWC), stride 1 — the semantics of the paper's
dataflow conv engine once the stream is re-assembled into a frame."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def stream_conv2d_ref(x: jax.Array, w: jax.Array) -> jax.Array:
    """x: (B, H, W, C); w: (K, K, C, N). VALID, stride 1 -> (B, H-K+1, W-K+1, N)."""
    return jax.lax.conv_general_dilated(
        x.astype(jnp.float32),
        w.astype(jnp.float32),
        window_strides=(1, 1),
        padding="VALID",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )
