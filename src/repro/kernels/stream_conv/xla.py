"""XLA rendering of the fused streaming conv — the compiled path on
platforms where Mosaic/Pallas compilation is unavailable (XLA:CPU only
supports the Pallas interpreter).

Same algorithm as the Pallas kernel in ``conv.py``, including the row
blocking: each row block's K*K stride-s shifted views are assembled into
one tall operand and contracted against the flattened (K*K*C, N) tap
matrix in a SINGLE matmul per row block, then the shared bias ->
activation -> NxN/stride-s max-pool epilogue runs in-block (overlapping
pool windows re-compute their ``pool - pool_stride`` boundary conv rows
inside each block, exactly like the Pallas kernel's halo). No ``lax.conv``,
and no unbounded im2col: R is sized so the per-block operand stays under a
fixed byte budget (the XLA analogue of the kernel's VMEM blocking), so
arbitrarily large batch/feature-map products cannot blow up memory. Small
workloads fit one block and skip the ``lax.map`` loop entirely. Width
blocking is a VMEM concern, not an XLA one — the whole output width is
processed per row block here (``block_w`` is Pallas-only).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.padding import round_up
from repro.kernels.stream_conv.epilogue import (
    apply_epilogue,
    normalize_pool,
    pool_out_dim,
    validate_epilogue,
)

# Per-block im2col operand budget. ~128 MB: big enough that realistic
# single-frame layers run as one fused block, small enough that batched
# CIFAR-scale layers (which would need GBs unblocked) get row-blocked.
_BLOCK_BYTES_BUDGET = 128 * 1024 * 1024


@functools.partial(
    jax.jit,
    static_argnames=(
        "k", "stride", "act", "pool", "pool_stride", "act_bits",
        "int8_scales", "out_dtype",
    ),
)
def stream_conv_fused_xla(
    x: jax.Array,  # (B, H, W, C), already SAME-padded if needed
    w_taps: jax.Array,  # (K*K, C, N)
    bias: jax.Array,  # (N,)
    *,
    k: int,
    stride: int = 1,
    act: str = "none",
    pool: int = 0,
    pool_stride: int | None = None,
    act_bits: int | None = None,
    int8_scales=None,
    out_dtype=jnp.float32,
) -> jax.Array:
    if int8_scales is not None:
        # True-int8 rendering: quantize a float input onto its stream grid
        # (int8 codes; a no-op representation change for on-grid values —
        # pre-quantized int8 frames pass straight through), contract
        # integer codes into an int32 accumulator, dequantize with one
        # exact pow2 multiply. Forward-only (the fp32 path keeps QAT).
        if not jnp.issubdtype(w_taps.dtype, jnp.signedinteger):
            raise ValueError(
                f"int8_scales given but w_taps are {w_taps.dtype}, "
                "not int codes"
            )
        if jnp.issubdtype(x.dtype, jnp.floating):
            from repro.core.quant.fixed_point import quantize_fixed

            x = quantize_fixed(x, int8_scales.in_spec).astype(jnp.int8)
    b, h, wd, c = x.shape
    kk, c2, n = w_taps.shape
    if kk != k * k or c2 != c:
        raise ValueError(f"w_taps {w_taps.shape} inconsistent with k={k}, C={c}")
    if stride < 1:
        raise ValueError(f"conv stride must be >= 1, got {stride}")
    validate_epilogue(act, pool, pool_stride, act_bits)
    pw, ps = normalize_pool(pool, pool_stride)
    s = stride
    h_out, w_out = (h - k) // s + 1, (wd - k) // s + 1
    if h_out <= 0 or w_out <= 0:
        raise ValueError(f"image {h}x{wd} too small for k={k}, stride={s}")
    if pw and (h_out < pw or w_out < pw):
        raise ValueError(
            f"conv output {h_out}x{w_out} too small for {pw}x{pw} pool"
        )

    # Row block from the byte budget: largest R (multiple of the pool
    # stride) whose (B, R, W_out, K*K, C) f32 operand fits.
    overlap = max(0, pw - ps) if pw else 0
    mult = ps if pw else 1
    row_bytes = max(1, b * w_out * k * k * c * 4)
    r = max(mult, (_BLOCK_BYTES_BUDGET // row_bytes) // mult * mult)
    r = min(r, round_up(h_out, mult))
    r_conv = r + overlap  # pool-overlap rows re-computed per block
    r_o = r // ps if pw else r
    h_keep = pool_out_dim(h_out, pw, ps) if pw else h_out
    w_keep = pool_out_dim(w_out, pw, ps) if pw else w_out
    n_rb = -(-h_keep // r_o)

    # Pad rows so every block can read its (r_conv - 1)*s + k input rows
    # (zero rows only feed outputs that are sliced off below).
    blk_in = (r_conv - 1) * s + k
    h_rows = (n_rb - 1) * r * s + blk_in
    if h_rows > h:
        x = jnp.pad(x, ((0, 0), (0, h_rows - h), (0, 0), (0, 0)))
    if int8_scales is not None:
        w_flat = w_taps.reshape(k * k * c, n).astype(jnp.int8)
    else:
        w_flat = w_taps.reshape(k * k * c, n).astype(jnp.float32)

    def block_fn(rb):
        xb = jax.lax.dynamic_slice_in_dim(x, rb * r * s, blk_in, axis=1)
        taps = []
        for ki in range(k):
            for kj in range(k):
                taps.append(
                    xb[
                        :,
                        ki : ki + (r_conv - 1) * s + 1 : s,
                        kj : kj + (w_out - 1) * s + 1 : s,
                        :,
                    ]
                )
        patches = jnp.stack(taps, axis=3)  # (B, r_conv, w_out, k*k, C)
        operand = patches.reshape(b * r_conv * w_out, k * k * c)
        if int8_scales is not None:
            # ONE integer matmul -> int32 accumulator -> exact pow2 dequant.
            yb = jnp.dot(
                operand, w_flat, preferred_element_type=jnp.int32
            ).reshape(b, r_conv, w_out, n)
            yb = yb.astype(jnp.float32) * int8_scales.deq_scale
        else:
            yb = jnp.dot(
                operand.astype(jnp.float32),
                w_flat,
                preferred_element_type=jnp.float32,
            ).reshape(b, r_conv, w_out, n)
        # ste=True: identical forward values, STE gradients — the XLA
        # rendering is the differentiable fused path, so in-kernel stream
        # quantization must not zero out QAT gradients. The int8 path is
        # forward-only (the input rounding has no gradient anyway).
        return apply_epilogue(
            yb, bias, act=act, pool=pool, pool_stride=pool_stride,
            act_bits=act_bits, ste=int8_scales is None,
        )

    if n_rb == 1:
        y = block_fn(0)
    else:
        blocks = jax.lax.map(block_fn, jnp.arange(n_rb))  # (n_rb, B, ...)
        y = jnp.moveaxis(blocks, 0, 1).reshape(b, n_rb * r_o, w_keep, n)
    return y[:, :h_keep].astype(out_dtype)


# ---------------------------------------------------------------------------
# Cross-layer fused pyramid: the whole fusion group as ONE XLA closure.


def _assemble_taps_xla(xp, k: int, s: int, conv_r: int, conv_c: int):
    """Two-step tap assembly on a (B, H, W, C) frame: the Pallas kernel's
    rank-3 ``_assemble_taps`` vmapped over the batch, so the strided-shift
    index arithmetic and the (ki, kj, C) flattening order (which must
    match the HWIO weight reshape exactly) live in ONE place."""
    from repro.kernels.stream_conv.conv import _assemble_taps

    patches = jax.vmap(
        lambda f: _assemble_taps(f, k, s, conv_r, conv_c)
    )(xp)  # (B, conv_r*conv_c, k*k*C)
    b = xp.shape[0]
    c = xp.shape[-1]
    return patches.reshape(b * conv_r * conv_c, k * k * c)


@functools.partial(
    jax.jit,
    static_argnames=("layers", "act_bits", "int8_scales", "out_dtype"),
)
def stream_conv_pyramid_xla(
    x: jax.Array,  # (B, H, W, C0), unpadded
    weights: tuple,  # per layer (K, K, C, N) HWIO
    biases: tuple,  # per layer (N,)
    *,
    layers: tuple,  # PyramidLayer per layer
    act_bits=None,  # int | None | per-layer tuple
    int8_scales=None,  # None | per-layer tuple of Int8Scales
    out_dtype=jnp.float32,
) -> jax.Array:
    """XLA rendering of the fused pyramid — the compiled fallback where
    Mosaic is unavailable. The whole group is one fused XLA graph (this
    function is one jit cache entry): per layer, two-step tap assembly
    feeds a single matmul, then the shared bias -> pool -> act -> quant
    epilogue (``pool_first`` — the ``cnn_apply_reference`` composition
    order, saving the pool factor of activation work). Intermediate
    feature maps stay whole-frame (CPU memory, not VMEM, is the
    constraint here); if a layer's patch operand would exceed the im2col
    byte budget, the closure degrades to the row-blocked per-layer path
    so memory stays bounded.
    """
    from repro.kernels.stream_conv.halo import same_pads

    n_layers = len(layers)
    bits = act_bits if isinstance(act_bits, tuple) else (act_bits,) * n_layers
    big = any(
        x.shape[0] * g_h * g_w * k * k * c * 4 > _BLOCK_BYTES_BUDGET
        for (g_h, g_w, k, c) in _pyramid_conv_dims(x.shape, weights, layers)
    )
    for i, (layer, w_t, b_t) in enumerate(zip(layers, weights, biases)):
        k = w_t.shape[0]
        s = layer.stride
        sc = None if int8_scales is None else int8_scales[i]
        if sc is not None and jnp.issubdtype(x.dtype, jnp.floating):
            # Quantize onto the layer's input stream grid before padding:
            # int8 codes thread through SAME pads (code 0 == value 0) and
            # the tap assembly unchanged.
            from repro.core.quant.fixed_point import quantize_fixed

            x = quantize_fixed(x, sc.in_spec).astype(jnp.int8)
        if layer.padding == "SAME":
            ph = same_pads(x.shape[1], s, k)
            pw_ = same_pads(x.shape[2], s, k)
            x = jnp.pad(x, ((0, 0), ph, pw_, (0, 0)))
        if big:
            # Bounded-memory fallback: same grouping contract (one jitted
            # closure), row-blocked per-layer kernels inside.
            x = stream_conv_fused_xla(
                x, w_t.reshape(k * k, w_t.shape[2], w_t.shape[3]), b_t,
                k=k, stride=s, act=layer.act, pool=layer.pool,
                pool_stride=layer.pool_stride, act_bits=bits[i],
                int8_scales=sc, out_dtype=jnp.float32,
            )
            continue
        b, h, w, c = x.shape
        conv_r, conv_c = (h - k) // s + 1, (w - k) // s + 1
        operand = _assemble_taps_xla(x, k, s, conv_r, conv_c)
        if sc is not None:
            y = jnp.dot(
                operand,
                w_t.reshape(k * k * c, -1).astype(jnp.int8),
                preferred_element_type=jnp.int32,
            ).reshape(b, conv_r, conv_c, -1)
            y = y.astype(jnp.float32) * sc.deq_scale
        else:
            y = jnp.dot(
                operand.astype(jnp.float32),
                w_t.reshape(k * k * c, -1).astype(jnp.float32),
                preferred_element_type=jnp.float32,
            ).reshape(b, conv_r, conv_c, -1)
        # ste=True: the XLA rendering is the differentiable fused path
        # (the int8 rendering is forward-only).
        x = apply_epilogue(
            y, b_t, act=layer.act, pool=layer.pool,
            pool_stride=layer.pool_stride, act_bits=bits[i],
            ste=sc is None, pool_first=True,
        )
    return x.astype(out_dtype)


def _pyramid_conv_dims(x_shape, weights, layers):
    """Per-layer (conv_rows, conv_cols, k, C) for the pyramid's memory
    guard, read from the shared geometry model (``halo.group_geometry``)
    so the byte guard can never diverge from what the renderers compute."""
    from repro.kernels.stream_conv.halo import group_geometry

    _, h, w, c = x_shape
    geom = group_geometry(
        h, w, c, layers,
        tuple(w_t.shape[0] for w_t in weights),
        tuple(w_t.shape[3] for w_t in weights),
    )
    return [(g.conv_rows, g.conv_cols, g.k, g.in_ch) for g in geom.layers]
