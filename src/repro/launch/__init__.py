"""Launch layer: production meshes, sharding rules, jitted train/serve
steps, and the multi-pod dry-run driver."""
