import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# Multi-pod dry-run: lower + compile every (architecture x input-shape x
# mesh) cell against ShapeDtypeStruct stand-ins, and record memory analysis,
# cost analysis and the collective schedule for the roofline.
#
# MUST be invoked as its own process (the 512 fake host devices are locked in
# at first jax init — never import this module from tests/benches):
#
#     PYTHONPATH=src python -m repro.launch.dryrun --arch qwen2.5-3b \
#         --shape train_4k [--multi-pod]
#     PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod]
#
# Results land in results/dryrun/<arch>__<shape>__<mesh>.json and are
# consumed by benchmarks/roofline.py and EXPERIMENTS.md.

import argparse
import json
import re
import time
import traceback

import jax
import jax.numpy as jnp

from repro.configs import SHAPES, get_arch, list_archs
from repro.launch.mesh import make_production_mesh
from repro.launch.specs import cache_shapes, input_specs, params_shapes
from repro.launch.steps import jit_train_step, make_decode_step, make_prefill_step
from repro.launch.sharding import (
    batch_specs,
    cache_specs,
    constrain_spec,
    param_specs,
)
from jax.sharding import NamedSharding, PartitionSpec as P

RESULTS_DIR = os.environ.get("REPRO_RESULTS_DIR", "results")

COLLECTIVE_OPS = (
    "all-gather",
    "all-reduce",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
)

_SHAPE_RE = re.compile(r"(?:\((?P<tuple>[^()]*)\)|(?P<single>[a-z0-9]+\[[0-9,]*\]))")
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?(?P<name>[\w.\-]+)\s*=\s*(?P<shape>\([^)]*\)|[a-z0-9]+\[[0-9,]*\][^\s]*)\s*(?P<op>[\w\-]+)\((?P<args>.*)\)",
)

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16, "token": 0, "tuple": 0,
}


def _shape_bytes(shape_str: str) -> int:
    """'bf16[128,512]{1,0}' -> bytes. Tuples sum their elements."""
    total = 0
    for m in re.finditer(r"([a-z0-9]+)\[([0-9,]*)\]", shape_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def parse_collectives(hlo_text: str) -> dict:
    """Sum per-device operand bytes of every collective op in the HLO.

    Builds an instruction-name -> shape map first, then charges each
    collective its operands' bytes (the data each device contributes).
    """
    shapes: dict = {}
    for line in hlo_text.splitlines():
        m = _INSTR_RE.match(line)
        if m:
            shapes[m.group("name")] = m.group("shape")
    stats = {op: {"count": 0, "operand_bytes": 0, "result_bytes": 0}
             for op in COLLECTIVE_OPS}
    for line in hlo_text.splitlines():
        m = _INSTR_RE.match(line)
        if not m:
            continue
        op = m.group("op")
        base = None
        for c in COLLECTIVE_OPS:
            if op == c or op.startswith(c + "-start") or op == c + "-done":
                base = c
                break
        if base is None:
            continue
        if op.endswith("-done"):
            continue  # avoid double counting async pairs
        args = m.group("args")
        operand_names = re.findall(r"%?([\w.\-]+)", args)
        ob = 0
        for name in operand_names:
            if name in shapes:
                ob += _shape_bytes(shapes[name])
        stats[base]["count"] += 1
        stats[base]["operand_bytes"] += ob
        stats[base]["result_bytes"] += _shape_bytes(m.group("shape"))
    return stats


def _mem_dict(ma) -> dict:
    keys = (
        "generated_code_size_in_bytes",
        "argument_size_in_bytes",
        "output_size_in_bytes",
        "alias_size_in_bytes",
        "temp_size_in_bytes",
    )
    return {k: int(getattr(ma, k, 0)) for k in keys}


def run_cell(arch: str, shape_name: str, *, multi_pod: bool = False,
             opt=None) -> dict:
    import dataclasses as _dc

    from repro.launch.optflags import BASELINE

    opt = opt or BASELINE
    cfg = get_arch(arch)
    shape = SHAPES[shape_name]
    if not cfg.supports_shape(shape):
        return {
            "arch": arch, "shape": shape_name,
            "mesh": "2x16x16" if multi_pod else "16x16",
            "opt": opt.name,
            "status": "skipped",
            "reason": "full-attention arch; long_500k needs sub-quadratic "
                      "attention (DESIGN.md §Arch-applicability)",
        }
    cfg = _dc.replace(
        cfg,
        opt_no_f32_cast_attn=opt.no_f32_cast_attn,
        opt_ce_remat=opt.ce_remat,
        opt_bf16_ssm=opt.bf16_ssm,
        opt_shard_attn_batch=opt.shard_attn_batch,
        **(
            {"capacity_factor": opt.capacity_factor}
            if opt.capacity_factor
            else {}
        ),
    )
    mesh = make_production_mesh(multi_pod=multi_pod)
    # Ambient mesh so in-model with_sharding_constraint (attention batch
    # pinning) can resolve axis names.
    jax.set_mesh(mesh)
    result = {
        "arch": arch,
        "shape": shape_name,
        "mesh": "x".join(str(s) for s in mesh.devices.shape),
        "opt": opt.name,
        "status": "ok",
        "n_devices": mesh.devices.size,
    }
    t0 = time.time()
    serving_fsdp = not opt.tp_serving_params
    params_sds, params_shardings, _ = params_shapes(
        cfg, mesh, fsdp=True if shape.kind == "train" else serving_fsdp
    )
    inputs = input_specs(cfg, shape, mesh)

    if shape.kind == "train":
        jitted, opt_sds = jit_train_step(
            cfg, mesh, params_sds, inputs, microbatches=opt.microbatches
        )
        lowered = jitted.lower(params_sds, opt_sds, inputs)
    elif shape.kind == "prefill":
        step = make_prefill_step(cfg, shape)
        _, cache_shardings, c_specs = cache_shapes(
            cfg, shape, mesh, seq_sharded=opt.seq_sharded_cache
        )
        da = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
        logit_spec = constrain_spec(
            P(da, "model"), (shape.global_batch, cfg.vocab_size), mesh
        )
        lowered = jax.jit(
            step,
            out_shardings=(
                NamedSharding(mesh, logit_spec),
                cache_shardings,
            ),
        ).lower(params_sds, inputs)
    else:  # decode / long_decode
        step = make_decode_step(cfg)
        cache_sds, cache_shardings, _ = cache_shapes(
            cfg, shape, mesh, seq_sharded=opt.seq_sharded_cache
        )
        da = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
        logit_spec = constrain_spec(
            P(da, "model"), (shape.global_batch, cfg.vocab_size), mesh
        )
        lowered = jax.jit(
            step,
            out_shardings=(
                NamedSharding(mesh, logit_spec),
                cache_shardings,
            ),
            donate_argnums=(1,),
        ).lower(params_sds, cache_sds, inputs["token"], inputs["index"])
    result["lower_s"] = round(time.time() - t0, 2)

    t1 = time.time()
    compiled = lowered.compile()
    result["compile_s"] = round(time.time() - t1, 2)

    ma = compiled.memory_analysis()
    result["memory"] = _mem_dict(ma)
    ca = compiled.cost_analysis()
    # XLA's cost model counts while bodies once (known limitation); kept for
    # reference only. The roofline uses the trip-count-aware analysis below.
    result["cost"] = {
        "flops_per_device": float(ca.get("flops", 0.0)),
        "bytes_accessed_per_device": float(ca.get("bytes accessed", 0.0)),
    }
    hlo = compiled.as_text()
    from repro.launch.hlo_analysis import analyze_hlo

    analysis = analyze_hlo(hlo)
    result["analysis"] = {
        "flops_per_device": analysis.flops,
        "hbm_bytes_per_device": analysis.hbm_bytes,
        "collective_bytes_per_device": analysis.collective_bytes,
        "collective_counts": analysis.collective_counts,
        "unknown_trip_whiles": analysis.unknown_trip_whiles,
    }
    result["collectives"] = parse_collectives(hlo)
    result["hlo_bytes"] = len(hlo)
    _save_hlo(arch, shape_name, multi_pod, hlo, opt.name)

    from repro.models.accounting import (
        active_param_count,
        model_flops,
        param_count,
    )

    result["params"] = param_count(cfg)
    result["active_params"] = active_param_count(cfg)
    result["model_flops"] = model_flops(cfg, shape)
    return result


def _named(mesh, spec_tree):
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s) if isinstance(s, P) else s,
        spec_tree,
        is_leaf=lambda x: isinstance(x, P) or x is None,
    )


def cell_path(arch: str, shape_name: str, multi_pod: bool,
              opt_name: str = "baseline") -> str:
    mesh = "2x16x16" if multi_pod else "16x16"
    suffix = "" if opt_name == "baseline" else f"__{opt_name}"
    return os.path.join(
        RESULTS_DIR, "dryrun", f"{arch}__{shape_name}__{mesh}{suffix}.json"
    )


def _save_hlo(arch: str, shape_name: str, multi_pod: bool, hlo: str,
              opt_name: str = "baseline") -> None:
    """Compressed post-optimization HLO kept next to the JSON so the
    roofline can be re-derived without recompiling."""
    import zstandard as zstd

    path = cell_path(arch, shape_name, multi_pod, opt_name).replace(
        ".json", ".hlo.zst"
    )
    with open(path, "wb") as f:
        f.write(zstd.ZstdCompressor(level=9).compress(hlo.encode()))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=list_archs())
    ap.add_argument("--shape", choices=list(SHAPES))
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument(
        "--opt", nargs="*", default=[],
        help="optimization flags: tp_serving_params seq_sharded_cache "
             "no_f32_cast_attn ce_remat bf16_ssm mb=<n> cf=<x> | ALL",
    )
    args = ap.parse_args()

    from repro.launch.optflags import BASELINE, OPTIMIZED, OptFlags

    if args.opt == ["ALL"]:
        opt = OPTIMIZED
    else:
        kw = {}
        for o in args.opt:
            if o.startswith("mb="):
                kw["microbatches"] = int(o[3:])
            elif o.startswith("cf="):
                kw["capacity_factor"] = float(o[3:])
            else:
                kw[o] = True
        opt = OptFlags(**kw) if kw else BASELINE

    cells = []
    if args.all:
        for a in list_archs():
            for s in SHAPES:
                cells.append((a, s))
    else:
        if not args.arch or not args.shape:
            ap.error("--arch and --shape required unless --all")
        cells.append((args.arch, args.shape))

    os.makedirs(os.path.join(RESULTS_DIR, "dryrun"), exist_ok=True)
    failures = 0
    for arch, shape_name in cells:
        path = cell_path(arch, shape_name, args.multi_pod, opt.name)
        if os.path.exists(path) and not args.force:
            print(f"[skip cached] {path}")
            continue
        print(f"[dryrun] {arch} x {shape_name} "
              f"({'2x16x16' if args.multi_pod else '16x16'}, {opt.name}) ...",
              flush=True)
        try:
            res = run_cell(arch, shape_name, multi_pod=args.multi_pod,
                           opt=opt)
        except Exception as e:  # noqa: BLE001 — record and continue
            res = {
                "arch": arch, "shape": shape_name,
                "mesh": "2x16x16" if args.multi_pod else "16x16",
                "opt": opt.name,
                "status": "error",
                "error": f"{type(e).__name__}: {e}",
                "traceback": traceback.format_exc()[-4000:],
            }
            failures += 1
        with open(path, "w") as f:
            json.dump(res, f, indent=2)
        print(f"  -> {res['status']}"
              + (f" (lower {res.get('lower_s')}s, compile "
                 f"{res.get('compile_s')}s)" if res["status"] == "ok" else ""),
              flush=True)
    if failures:
        raise SystemExit(f"{failures} cells failed")


if __name__ == "__main__":
    main()
