"""Trip-count-aware HLO analysis.

XLA's ``cost_analysis()`` counts while-loop bodies ONCE (a known
limitation), which under-reports scan-over-layers models by ~n_layers x.
The post-optimization HLO carries ``known_trip_count`` on every counted
loop, so this module re-derives the three roofline inputs exactly:

  flops            dot/convolution FLOPs, x trip counts, recursing into
                   fusions and called computations
  hbm_bytes        fusion-aware: per *top-level* instruction, operand +
                   result bytes (fusion internals live in registers/VMEM),
                   x trip counts
  collective_bytes per collective op, operand shard bytes, x trip counts

All byte counts are per-device (SPMD HLO is the per-partition program).
"""
from __future__ import annotations

import dataclasses
import json
import re
from typing import Optional

_DTYPE_BYTES = {
    "pred": 1, "s2": 1, "u2": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1,
    "s16": 2, "u16": 2, "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

COLLECTIVE_OPS = (
    "all-gather",
    "all-reduce",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
)

_COMP_START = re.compile(
    r"^(ENTRY\s+)?%?(?P<name>[\w.\-]+)\s*\(.*->.*\{\s*$"
)
_INSTR = re.compile(
    r"^\s*(?:ROOT\s+)?%?(?P<name>[\w.\-]+)\s*=\s*(?P<shape>\([^)]*\)|[\w\[\],]+(?:\{[^}]*\})?)\s*(?P<op>[\w\-]+)\((?P<rest>.*)$"
)


def _shape_elems_bytes(shape_str: str):
    """Sum elements/bytes over all array shapes in a (possibly tuple) type."""
    total_b = 0
    for m in re.finditer(r"([a-z]\d*|pred|bf16|f16|f32|f64|c64|c128)\[([0-9,]*)\]", shape_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total_b += n * _DTYPE_BYTES[dt]
    return total_b


def _result_elems(shape_str: str) -> int:
    m = re.search(r"[a-z0-9]+\[([0-9,]*)\]", shape_str)
    if not m:
        return 0
    n = 1
    if m.group(1):
        for d in m.group(1).split(","):
            n *= int(d)
    return n


@dataclasses.dataclass
class Instr:
    name: str
    shape: str
    op: str
    rest: str  # everything after the opening paren of the operand list


def parse_computations(hlo: str) -> dict:
    comps: dict = {}
    current: Optional[str] = None
    entry: Optional[str] = None
    for raw in hlo.splitlines():
        line = raw.rstrip()
        if current is None:
            m = _COMP_START.match(line.strip())
            if m and ("->" in line) and line.strip().endswith("{"):
                current = m.group("name")
                comps[current] = []
                if line.strip().startswith("ENTRY"):
                    entry = current
            continue
        if line.strip() == "}":
            current = None
            continue
        m = _INSTR.match(line)
        if m:
            comps[current].append(
                Instr(m.group("name"), m.group("shape"), m.group("op"),
                      m.group("rest"))
            )
    return {"computations": comps, "entry": entry}


def _operand_names(rest: str) -> list:
    # operands are up to the first "), " or end; names like %foo.1
    args = rest.split(")")[0]
    return re.findall(r"%([\w.\-]+)", args)


def _attr(rest: str, key: str) -> Optional[str]:
    m = re.search(key + r"=%?([\w.\-]+)", rest)
    return m.group(1) if m else None


def _trip_count(rest: str) -> int:
    m = re.search(r'"known_trip_count":\{"n":"(\d+)"\}', rest)
    return int(m.group(1)) if m else 1


def _dot_flops(instr: Instr, shapes: dict) -> float:
    out_elems = _result_elems(instr.shape)
    m = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", instr.rest)
    ops = _operand_names(instr.rest)
    if not m or not ops:
        return 2.0 * out_elems
    lhs_shape = shapes.get(ops[0], "")
    dims_m = re.search(r"\[([0-9,]*)\]", lhs_shape)
    if not dims_m:
        return 2.0 * out_elems
    dims = [int(d) for d in dims_m.group(1).split(",") if d]
    contract = 1
    for idx in (int(i) for i in m.group(1).split(",") if i):
        if idx < len(dims):
            contract *= dims[idx]
    return 2.0 * out_elems * contract


def _conv_flops(instr: Instr, shapes: dict) -> float:
    out_elems = _result_elems(instr.shape)
    ops = _operand_names(instr.rest)
    if len(ops) < 2:
        return 2.0 * out_elems
    rhs_shape = shapes.get(ops[1], "")
    dims_m = re.search(r"\[([0-9,]*)\]", rhs_shape)
    if not dims_m:
        return 2.0 * out_elems
    kernel_elems = 1
    for d in dims_m.group(1).split(","):
        if d:
            kernel_elems *= int(d)
    # kernel contains (spatial x in_features x out_features); per output
    # element we do spatial*in_features MACs = kernel_elems / out_features.
    out_feat_m = re.search(r"f=(\d+)", instr.rest) or re.search(
        r"o=(\d+)", instr.rest
    )
    per_out = kernel_elems
    m2 = re.search(r"dim_labels=\S*->\S*", instr.rest)
    # Fall back: charge kernel_elems MACs per output element / assume last
    # kernel dim is out-features.
    dims = [int(d) for d in dims_m.group(1).split(",") if d]
    if dims:
        per_out = kernel_elems // dims[-1]
    return 2.0 * out_elems * max(1, per_out)


_SKIP_BYTES_OPS = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "after-all", "partition-id", "replica-id", "iota",
}

# Reads of these ops touch only their *result*-sized region of the base
# operand (slice semantics) — charging the base would overcount stacked
# scan weights by n_layers and embedding tables by vocab/batch.
_SLICE_READ_OPS = {"dynamic-slice", "slice", "gather"}


def _fusion_bytes(fname, fusion_instr, comps, shapes_per_comp, caller_shapes):
    """Fusion traffic: per input, the region actually read (slice-size when
    every use is a slice-like op); internals are registers/VMEM; the root
    write is the result (or the update region for DUS roots)."""
    callee_instrs = comps.get(fname, [])
    cal_sh = shapes_per_comp.get(fname, {})
    # Map positional parameters -> caller operand full sizes.
    operand_names = _operand_names(fusion_instr.rest)
    param_order = [ci.name for ci in callee_instrs if ci.op == "parameter"]
    # Dtype/layout-transparent aliasing: convert/bitcast/copy/reshape of a
    # parameter is still "the parameter" for traffic purposes (the CPU
    # backend wraps bf16 data in f32 round-trips that a bf16-native TPU
    # doesn't emit).
    _TRANSPARENT = {"convert", "bitcast", "copy", "reshape"}
    alias = {p: p for p in param_order}
    for ci in callee_instrs:
        if ci.op in _TRANSPARENT:
            ops = _operand_names(ci.rest)
            if len(ops) == 1 and ops[0] in alias:
                alias[ci.name] = alias[ops[0]]
    # Uses of each param (through aliases) inside the callee.
    uses: dict = {p: [] for p in param_order}
    for ci in callee_instrs:
        if ci.name in alias and ci.op in _TRANSPARENT:
            continue  # transparent hop, not a real use
        for o in _operand_names(ci.rest):
            root = alias.get(o)
            if root is not None:
                uses[root].append(ci)
    total = 0
    for idx, p in enumerate(param_order):
        full = _shape_elems_bytes(cal_sh.get(p, ""))
        if idx < len(operand_names):
            full = max(
                full, _shape_elems_bytes(caller_shapes.get(operand_names[idx], ""))
            ) if full == 0 else full
        us = uses.get(p, [])
        # Per-use charging: slice-like reads cost their result; being the
        # *base* of a dynamic-update-slice costs nothing (in-place); any
        # other use reads the whole region once.
        charged_full = False
        part = 0
        for u in us:
            if u.op in _SLICE_READ_OPS:
                part += _shape_elems_bytes(u.shape)
            elif u.op == "dynamic-update-slice" and (
                alias.get(_operand_names(u.rest)[0]) == p
                if _operand_names(u.rest)
                else False
            ):
                continue
            else:
                charged_full = True
        total += full if charged_full else part
    # Root write.
    dus_upd = 0
    for ci in callee_instrs:
        if ci.op == "dynamic-update-slice":
            o = _operand_names(ci.rest)
            if len(o) > 1:
                dus_upd += _shape_elems_bytes(cal_sh.get(o[1], ""))
    if dus_upd:
        total += dus_upd  # written region
    else:
        total += _shape_elems_bytes(fusion_instr.shape)
    return total


@dataclasses.dataclass
class Analysis:
    flops: float = 0.0
    hbm_bytes: float = 0.0
    collective_bytes: float = 0.0
    collective_counts: dict = dataclasses.field(default_factory=dict)
    unknown_trip_whiles: int = 0


_LAYOUT_ONLY_OPS = {
    "parameter", "convert", "bitcast", "copy", "transpose", "reshape",
    "tuple", "get-tuple-element", "constant",
}


def _upcast_and_dus_sets(comps, shapes_per_comp):
    """Identify (a) bf16->f32 upcast instructions/fusions — XLA:CPU inserts
    these around every dot because it lacks native bf16 matmul; on the TPU
    target they don't exist, so they are charged at bf16 size and their
    consumers read bf16 — and (b) fusions whose root is a dynamic-update-
    slice of one of their operands — in-place on TPU (buffer aliasing), so
    they are charged the update slice, not the full buffer."""
    upcast: dict = {}  # (comp, name) -> bf16 bytes
    dus_fusions: dict = {}  # (comp, name) -> charged bytes

    def _callee_is_layout_only(callee):
        return all(i.op in _LAYOUT_ONLY_OPS for i in comps.get(callee, []))

    for cname, instrs in comps.items():
        sh = shapes_per_comp[cname]
        for i in instrs:
            out_b = _shape_elems_bytes(i.shape)
            if i.op == "convert" and "f32[" in i.shape:
                ops = _operand_names(i.rest)
                if ops:
                    in_b = _shape_elems_bytes(sh.get(ops[0], ""))
                    if 0 < in_b == out_b // 2:
                        upcast[(cname, i.name)] = in_b
            elif i.op == "fusion":
                callee = _attr(i.rest, "calls")
                if not callee:
                    continue
                callee_instrs = comps.get(callee, [])
                has_dus = any(
                    ci.op == "dynamic-update-slice" for ci in callee_instrs
                )
                if has_dus:
                    cal_sh = shapes_per_comp.get(callee, {})
                    upd = 0
                    for ci in callee_instrs:
                        if ci.op == "dynamic-update-slice":
                            o = _operand_names(ci.rest)
                            if len(o) > 1:
                                upd += _shape_elems_bytes(cal_sh.get(o[1], ""))
                    # read update + write update (+ small index/operand reads)
                    dus_fusions[(cname, i.name)] = 2 * upd
                elif (
                    "f32[" in i.shape
                    and _callee_is_layout_only(callee)
                ):
                    ops = _operand_names(i.rest)
                    in_b = sum(
                        _shape_elems_bytes(shapes_per_comp[cname].get(n, ""))
                        for n in ops
                    )
                    if 0 < in_b <= out_b // 2 + 8:
                        upcast[(cname, i.name)] = in_b
    return upcast, dus_fusions


def analyze_hlo(hlo: str) -> Analysis:
    parsed = parse_computations(hlo)
    comps = parsed["computations"]
    entry = parsed["entry"]
    shapes_per_comp = {
        cname: {i.name: i.shape for i in instrs}
        for cname, instrs in comps.items()
    }
    upcast, dus_fusions = _upcast_and_dus_sets(comps, shapes_per_comp)
    res = Analysis()
    memo_flops: dict = {}

    def _operand_bytes(cname, sh, name):
        if (cname, name) in upcast:
            return upcast[(cname, name)]  # consumer reads bf16 on TPU
        return _shape_elems_bytes(sh.get(name, ""))

    def comp_flops(cname: str) -> float:
        """FLOPs of one execution of a computation (recursing into calls,
        fusions, and whiles x their trip counts)."""
        if cname in memo_flops:
            return memo_flops[cname]
        total = 0.0
        shapes = shapes_per_comp.get(cname, {})
        for i in comps.get(cname, []):
            if i.op == "dot":
                total += _dot_flops(i, shapes)
            elif i.op == "convolution":
                total += _conv_flops(i, shapes)
            elif i.op == "while":
                body = _attr(i.rest, "body")
                if body:
                    total += _trip_count(i.rest) * comp_flops(body)
            elif i.op == "fusion":
                callee = _attr(i.rest, "calls")
                if callee:
                    total += comp_flops(callee)
            elif i.op in ("call", "async-start"):
                callee = _attr(i.rest, "to_apply") or _attr(i.rest, "calls")
                if callee and callee in comps:
                    total += comp_flops(callee)
            elif i.op == "conditional":
                branches = re.findall(
                    r"(?:branch_computations=\{([^}]*)\}|true_computation=%?([\w.\-]+)|false_computation=%?([\w.\-]+))",
                    i.rest,
                )
                names = []
                for tup in branches:
                    for t in tup:
                        if t:
                            names.extend(re.findall(r"%?([\w.\-]+)", t))
                if names:
                    total += max(comp_flops(n) for n in names if n in comps)
        memo_flops[cname] = total
        return total

    def walk_bytes(cname: str, mult: float) -> None:
        """Fusion-aware bytes + collectives, multiplied by loop trips."""
        shapes = shapes_per_comp.get(cname, {})
        for i in comps.get(cname, []):
            if i.op == "while":
                body = _attr(i.rest, "body")
                if body:
                    walk_bytes(body, mult * _trip_count(i.rest))
                    if _trip_count(i.rest) == 1 and '"known_trip_count"' not in i.rest:
                        res.unknown_trip_whiles += 1
                continue
            if i.op in ("call",):
                callee = _attr(i.rest, "to_apply") or _attr(i.rest, "calls")
                if callee and callee in comps:
                    walk_bytes(callee, mult)
                continue
            if i.op == "conditional":
                continue  # negligible here
            if i.op in _SKIP_BYTES_OPS:
                continue
            operands = _operand_names(i.rest)
            if (cname, i.name) in upcast:
                # CPU-only bf16->f32 upcast: on TPU the consumer reads the
                # bf16 buffer directly; charge one bf16 read, no write.
                res.hbm_bytes += mult * upcast[(cname, i.name)]
                continue
            if i.op == "fusion":
                callee = _attr(i.rest, "calls")
                if callee:
                    res.hbm_bytes += mult * _fusion_bytes(
                        callee, i, comps, shapes_per_comp, shapes
                    )
                continue
            if i.op in _SLICE_READ_OPS:
                # Slice reads touch only the result-sized region.
                res.hbm_bytes += mult * 2 * _shape_elems_bytes(i.shape)
                continue
            if i.op == "dynamic-update-slice":
                # XLA updates in place (buffer aliasing): traffic is the
                # update slice (read) + the written region, not the base.
                upd = (
                    _operand_bytes(cname, shapes, operands[1])
                    if len(operands) > 1
                    else 0
                )
                res.hbm_bytes += mult * 2 * upd
                continue
            if i.op == "scatter":
                # In-place base; traffic ~ updates read + written + indices.
                upd = (
                    _operand_bytes(cname, shapes, operands[2])
                    if len(operands) > 2
                    else 0
                )
                idxb = (
                    _operand_bytes(cname, shapes, operands[1])
                    if len(operands) > 1
                    else 0
                )
                res.hbm_bytes += mult * (2 * upd + idxb)
                continue
            ob = sum(_operand_bytes(cname, shapes, n) for n in operands)
            rb = _shape_elems_bytes(i.shape)
            res.hbm_bytes += mult * (ob + rb)
            for c in COLLECTIVE_OPS:
                if i.op == c or i.op.startswith(c + "-start"):
                    res.collective_bytes += mult * ob
                    entry_stats = res.collective_counts.setdefault(
                        c, {"count": 0.0, "operand_bytes": 0.0}
                    )
                    entry_stats["count"] += mult
                    entry_stats["operand_bytes"] += mult * ob
                    break

    if entry:
        res.flops = comp_flops(entry)
        walk_bytes(entry, 1.0)
    return res


def top_contributors(hlo: str, n: int = 12) -> list:
    """Ranked (bytes, op, site-name, shape) HBM-traffic contributors, using
    the same charging rules as :func:`analyze_hlo` — the dry-run 'profile'
    the §Perf loop iterates on."""
    parsed = parse_computations(hlo)
    comps = parsed["computations"]
    entry = parsed["entry"]
    shapes_per_comp = {
        cname: {i.name: i.shape for i in instrs}
        for cname, instrs in comps.items()
    }
    upcast, _ = _upcast_and_dus_sets(comps, shapes_per_comp)
    contrib: dict = {}

    def walk(cname, mult):
        sh = shapes_per_comp.get(cname, {})
        for i in comps.get(cname, []):
            if i.op == "while":
                body = _attr(i.rest, "body")
                if body:
                    walk(body, mult * _trip_count(i.rest))
                continue
            if i.op in ("call",):
                callee = _attr(i.rest, "to_apply") or _attr(i.rest, "calls")
                if callee and callee in comps:
                    walk(callee, mult)
                continue
            if i.op in _SKIP_BYTES_OPS or i.op == "conditional":
                continue
            operands = _operand_names(i.rest)
            if (cname, i.name) in upcast:
                b = upcast[(cname, i.name)]
            elif i.op == "fusion":
                callee = _attr(i.rest, "calls")
                b = (
                    _fusion_bytes(callee, i, comps, shapes_per_comp, sh)
                    if callee
                    else 0
                )
            elif i.op in _SLICE_READ_OPS:
                b = 2 * _shape_elems_bytes(i.shape)
            elif i.op == "dynamic-update-slice":
                b = (
                    2 * _shape_elems_bytes(sh.get(operands[1], ""))
                    if len(operands) > 1
                    else 0
                )
            else:
                b = sum(
                    _shape_elems_bytes(sh.get(nm, "")) for nm in operands
                ) + _shape_elems_bytes(i.shape)
            key = (i.op, i.name.rsplit(".", 1)[0], i.shape.split("{")[0])
            contrib[key] = contrib.get(key, 0) + mult * b

    if entry:
        walk(entry, 1.0)
    ranked = sorted(contrib.items(), key=lambda kv: -kv[1])[:n]
    return [
        {"bytes": v, "op": k[0], "site": k[1], "shape": k[2]}
        for k, v in ranked
    ]
