"""Production mesh construction.

Defined as FUNCTIONS (never module-level constants) so importing this module
never touches jax device state — the dry-run must set XLA_FLAGS before any
device query, and smoke tests must keep seeing 1 device.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; multi-pod adds a leading 2-pod axis."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh(shape, axes):
    """Generic escape hatch (tests, small meshes, DHM stage meshes)."""
    return jax.make_mesh(tuple(shape), tuple(axes))


def data_axes(mesh) -> tuple:
    """Axes the global batch shards over."""
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def model_axes(mesh) -> tuple:
    return tuple(a for a in ("model",) if a in mesh.axis_names)
