"""Optimization flags for the §Perf hillclimb.

Each flag is one hypothesis-driven change; the dry-run can lower any cell
with any combination so before/after roofline terms are directly
comparable. ``baseline`` (all off) is the paper-faithful starting point.
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class OptFlags:
    # Serving params in TP-only layout (no FSDP all-gathers per layer).
    # Hypothesis: FSDP weight gathers dominate the serving collective term.
    tp_serving_params: bool = False
    # KV cache sharded over the sequence dim ("context parallel" decode).
    # Hypothesis: hd-sharded caches force full-cache reshard copies per
    # layer (measured 550 GB/step on command-r decode); S-sharding makes
    # the token insert slice-local and attention context-parallel.
    seq_sharded_cache: bool = False
    # Keep bf16 operands in attention einsums (accumulate f32 via
    # preferred_element_type) instead of materializing f32 casts.
    no_f32_cast_attn: bool = False
    # Remat the chunked-vocab CE scan step (recompute logits chunks in bwd).
    ce_remat: bool = False
    # Gradient-accumulation microbatches per train step.
    microbatches: int = 1
    # Store SSM discretized inputs in bf16 (states stay f32).
    bf16_ssm: bool = False
    # Pin the batch dim's sharding inside blockwise attention (GSPMD
    # otherwise re-replicates it in the score loop on some cells).
    shard_attn_batch: bool = False
    # MoE capacity factor override (baseline 1.25).
    capacity_factor: float = 0.0  # 0 = keep config value

    @property
    def name(self) -> str:
        parts = []
        if self.tp_serving_params:
            parts.append("tpserve")
        if self.seq_sharded_cache:
            parts.append("seqcache")
        if self.no_f32_cast_attn:
            parts.append("bf16attn")
        if self.ce_remat:
            parts.append("ceremat")
        if self.microbatches > 1:
            parts.append(f"mb{self.microbatches}")
        if self.bf16_ssm:
            parts.append("bf16ssm")
        if self.shard_attn_batch:
            parts.append("attnpin")
        if self.capacity_factor:
            parts.append(f"cf{self.capacity_factor}")
        return "+".join(parts) or "baseline"


BASELINE = OptFlags()

# The full-stack optimized configuration used for the "opt" sweep.
OPTIMIZED = OptFlags(
    tp_serving_params=True,
    seq_sharded_cache=True,
    no_f32_cast_attn=True,
    ce_remat=True,
    microbatches=8,
    bf16_ssm=True,
    shard_attn_batch=True,
)
