"""Re-derive the analysis fields of dry-run JSONs from the saved
(compressed) HLO — no recompilation. Run after analyzer improvements:

    PYTHONPATH=src python -m repro.launch.reanalyze
"""
from __future__ import annotations

import glob
import json
import os

import zstandard as zstd

from repro.launch.hlo_analysis import analyze_hlo

RESULTS_DIR = os.environ.get("REPRO_RESULTS_DIR", "results")


def reanalyze_all() -> int:
    n = 0
    for jpath in sorted(glob.glob(os.path.join(RESULTS_DIR, "dryrun", "*.json"))):
        hpath = jpath.replace(".json", ".hlo.zst")
        if not os.path.exists(hpath):
            continue
        with open(jpath) as f:
            cell = json.load(f)
        if cell.get("status") != "ok":
            continue
        with open(hpath, "rb") as f:
            hlo = zstd.ZstdDecompressor().decompress(f.read()).decode()
        a = analyze_hlo(hlo)
        cell["analysis"] = {
            "flops_per_device": a.flops,
            "hbm_bytes_per_device": a.hbm_bytes,
            "collective_bytes_per_device": a.collective_bytes,
            "collective_counts": a.collective_counts,
            "unknown_trip_whiles": a.unknown_trip_whiles,
        }
        with open(jpath, "w") as f:
            json.dump(cell, f, indent=2)
        n += 1
    return n


if __name__ == "__main__":
    print(f"re-analyzed {reanalyze_all()} cells")
