"""Sharding rules: parameter tree -> PartitionSpecs.

DP/TP/EP layout (megatron-style):
  - batch dims shard over ("pod", "data")
  - attention heads, FFN hidden, expert dim, vocab shard over "model"
  - norms/scales replicate
Rules match on the path of each leaf (e.g. ``stack/units/0/attn/wq/w``) and
right-align to the leaf's rank, so the same rule covers scanned (stacked)
and tail (unstacked) layers.
"""
from __future__ import annotations

import re
from typing import Optional

import jax
from jax.sharding import NamedSharding, PartitionSpec as P


# (path regex, spec for the *trailing* dims). Earlier rules win.
PARAM_RULES = (
    # Attention projections.
    (r"attn/wq/w$", ("data", "model")),
    (r"attn/wk/w$", ("data", "model")),
    (r"attn/wv/w$", ("data", "model")),
    (r"attn/wo/w$", ("model", "data")),
    (r"xattn/w[qkv]/w$", ("data", "model")),
    (r"xattn/wo/w$", ("model", "data")),
    (r"attn/w[qkv]/b$", ("model",)),
    (r"attn/wo/b$", (None,)),
    # Dense MLP.
    (r"ffn/(gate|up)/w$", ("data", "model")),
    (r"ffn/down/w$", ("model", "data")),
    (r"ffn/shared/(gate|up)/w$", ("data", "model")),
    (r"ffn/shared/down/w$", ("model", "data")),
    # MoE experts: expert dim over "model" (expert parallelism).
    (r"ffn/router$", (None, None)),
    (r"ffn/w_(gate|up)$", ("model", "data", None)),
    (r"ffn/w_down$", ("model", None, "data")),
    # Mamba.
    (r"mamba/in_proj/w$", ("data", "model")),
    (r"mamba/conv_w$", (None, "model")),
    (r"mamba/conv_b$", ("model",)),
    (r"mamba/x_proj/w$", ("model", None)),
    (r"mamba/dt_proj/w$", (None, "model")),
    (r"mamba/dt_proj/b$", ("model",)),
    (r"mamba/a_log$", ("model", None)),
    (r"mamba/d_skip$", ("model",)),
    (r"mamba/out_proj/w$", ("model", "data")),
    # RG-LRU.
    (r"rglru/in_(x|gate)/w$", ("data", "model")),
    (r"rglru/conv_w$", (None, "model")),
    (r"rglru/w_[ri]/w$", (None, "model")),
    (r"rglru/lam$", ("model",)),
    (r"rglru/out/w$", ("model", "data")),
    # Embeddings / head / positions.
    (r"(^|/)embed$", ("model", "data")),
    (r"(^|/)lm_head$", ("data", "model")),
    (r"pos_table$", (None, "data")),
    # Everything else (norm scales, biases): replicated.
)


def _path_str(path) -> str:
    parts = []
    for k in path:
        if hasattr(k, "key"):
            parts.append(str(k.key))
        elif hasattr(k, "idx"):
            parts.append(str(k.idx))
        else:
            parts.append(str(k))
    return "/".join(parts)


def constrain_spec(spec: P, shape, mesh) -> P:
    """Drop sharding axes that don't evenly divide their dimension (e.g.
    vocab 92553 over a 16-wide axis, or batch 1 at long_500k decode)."""
    out = []
    for i, entry in enumerate(spec):
        if entry is None:
            out.append(None)
            continue
        axes = entry if isinstance(entry, tuple) else (entry,)
        total = 1
        for a in axes:
            total *= mesh.shape[a]
        if i < len(shape) and shape[i] % total == 0:
            out.append(entry)
        else:
            # Try a prefix of the axis tuple before giving up entirely.
            kept = []
            run = 1
            for a in axes:
                if i < len(shape) and shape[i] % (run * mesh.shape[a]) == 0:
                    kept.append(a)
                    run *= mesh.shape[a]
            out.append(tuple(kept) if len(kept) > 1 else (kept[0] if kept else None))
    return P(*out)


def _spec_for(path_s: str, ndim: int, mesh_axes) -> P:
    for pat, trailing in PARAM_RULES:
        if re.search(pat, path_s):
            spec = [None] * ndim
            t = list(trailing)[-ndim:] if ndim < len(trailing) else list(trailing)
            spec[ndim - len(t):] = t
            # Drop axes the mesh doesn't have; 'data' on params is only used
            # for FSDP mode (see fsdp arg below) and dropped otherwise.
            spec = [a if a in mesh_axes else None for a in spec]
            return P(*spec)
    return P()


def param_specs(params, mesh, *, fsdp: bool = False):
    """PartitionSpec tree for a parameter (or optimizer-state) tree.

    With ``fsdp=False`` (default) the 'data' entries in the rules are
    dropped: parameters replicate over the data axis (pure DP + TP). With
    ``fsdp=True`` they are honored, fully sharding every matrix over
    (data x model) — ZeRO-3-style, the default for the big train shapes.
    """
    keep = set(mesh.axis_names) - (set() if fsdp else {"data", "pod"})

    def f(path, leaf):
        spec = _spec_for(_path_str(path), getattr(leaf, "ndim", 0), keep)
        return constrain_spec(spec, getattr(leaf, "shape", ()), mesh)

    return jax.tree_util.tree_map_with_path(f, params)


def named(mesh, spec_tree):
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), spec_tree,
        is_leaf=lambda x: isinstance(x, P),
    )


def batch_specs(batch, mesh):
    """Shard every batch array's leading dim over (pod, data)."""
    daxes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)

    def f(leaf):
        ndim = getattr(leaf, "ndim", 0)
        if not ndim:
            return P()
        spec = P(daxes, *([None] * (ndim - 1)))
        return constrain_spec(spec, getattr(leaf, "shape", ()), mesh)

    return jax.tree_util.tree_map(f, batch)


def cache_specs(cache, mesh, cfg, *, seq_sharded: bool = False):
    """KV/state cache sharding for decode.

    Batch over (pod, data). Baseline: KV heads shard over 'model' when
    divisible, else the head_dim axis takes the model axis. With
    ``seq_sharded`` (context-parallel decode, the §Perf fix) the cache's
    *sequence* dim takes the model axis instead: the new-token insert is
    slice-local on one shard and attention runs context-parallel with a
    small softmax-combine collective — no full-cache resharding copies.
    SSM/RG-LRU states shard their feature dim over 'model'.
    """
    daxes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    model_size = mesh.shape.get("model", 1)

    def f(path, leaf):
        path_s = _path_str(path)
        ndim = getattr(leaf, "ndim", 0)
        shape = getattr(leaf, "shape", ())
        # Stacked leading (n_units) dim present for scanned caches.
        lead = [None] * (ndim - 4) if ndim >= 4 else [None] * max(0, ndim - 3)
        if re.search(r"(^|/)(k|v|xk|xv)$", path_s) and ndim >= 4:
            n_kv = shape[-2]
            if seq_sharded:
                spec = P(*lead, daxes, "model", None, None)
            elif n_kv % model_size == 0:
                spec = P(*lead, daxes, None, "model", None)
            else:
                spec = P(*lead, daxes, None, None, "model")
        elif re.search(r"/ssm$", path_s):  # (..., B, d_inner, N)
            spec = P(*([None] * (ndim - 3)), daxes, "model", None)
        elif re.search(r"/conv$", path_s):  # (..., B, K-1, d_inner)
            spec = P(*([None] * (ndim - 3)), daxes, None, "model")
        elif re.search(r"/h$", path_s):  # (..., B, lru_width)
            spec = P(*([None] * (ndim - 2)), daxes, "model")
        elif ndim:
            spec = P(*([None] * ndim))
        else:
            return P()
        return constrain_spec(spec, shape, mesh)

    return jax.tree_util.tree_map_with_path(f, cache)
