"""ShapeDtypeStruct stand-ins for every model input: the dry-run lowers
against these (weak-type-correct, shardable, no device allocation)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig, ShapeConfig
from repro.launch.sharding import (
    batch_specs,
    cache_specs,
    constrain_spec,
    param_specs,
)


def _sds(shape, dtype, mesh=None, spec=None):
    if mesh is None:
        return jax.ShapeDtypeStruct(shape, dtype)
    spec = constrain_spec(spec, shape, mesh)
    return jax.ShapeDtypeStruct(
        shape, dtype, sharding=NamedSharding(mesh, spec)
    )


def _daxes(mesh):
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def input_specs(cfg: ArchConfig, shape: ShapeConfig, mesh) -> dict:
    """Model inputs for one step of the given shape, as sharded
    ShapeDtypeStructs.

    train/prefill: {'tokens': (B, S[+1])} (+ modality-stub embeddings).
    decode: {'token': (B, 1), 'index': scalar} — the cache is produced by
    ``cache_shapes`` separately.
    """
    b, s = shape.global_batch, shape.seq_len
    da = _daxes(mesh)
    dt = jnp.dtype(cfg.dtype)
    out: dict = {}
    if shape.kind == "train":
        out["tokens"] = _sds((b, s + 1), jnp.int32, mesh, P(da, None))
    elif shape.kind == "prefill":
        out["tokens"] = _sds((b, s), jnp.int32, mesh, P(da, None))
    else:  # decode / long_decode
        out["token"] = _sds((b, 1), jnp.int32, mesh, P(da, None))
        out["index"] = _sds((), jnp.int32, mesh, P())
    if cfg.family == "vlm" and shape.kind in ("train", "prefill"):
        out["prefix_embeds"] = _sds(
            (b, cfg.n_prefix_tokens, cfg.d_model), dt, mesh, P(da, None, None)
        )
    if cfg.family == "encdec" and shape.kind in ("train", "prefill"):
        out["encoder_frames"] = _sds(
            (b, cfg.encoder_seq, cfg.d_model), dt, mesh, P(da, None, None)
        )
    return out


def params_shapes(cfg: ArchConfig, mesh, *, fsdp: bool = True):
    """(ShapeDtypeStruct param tree, matching NamedSharding tree)."""
    from repro.models.transformer import init_params

    shapes = jax.eval_shape(lambda k: init_params(k, cfg), jax.random.PRNGKey(0))
    specs = param_specs(shapes, mesh, fsdp=fsdp)
    shardings = jax.tree_util.tree_map(
        lambda sp: NamedSharding(mesh, sp), specs,
        is_leaf=lambda x: isinstance(x, P),
    )
    sds = jax.tree_util.tree_map(
        lambda sh, sharding: jax.ShapeDtypeStruct(
            sh.shape, sh.dtype, sharding=sharding
        ),
        shapes,
        shardings,
    )
    return sds, shardings, specs


def cache_shapes(cfg: ArchConfig, shape: ShapeConfig, mesh, *,
                 seq_sharded: bool = False):
    """(ShapeDtypeStruct cache tree, NamedSharding tree) for decode."""
    from repro.models.transformer import init_stack_cache

    b, s = shape.global_batch, shape.seq_len
    shapes = jax.eval_shape(
        lambda: init_stack_cache(cfg, cfg.n_layers, b, s)
    )
    specs = cache_specs(shapes, mesh, cfg, seq_sharded=seq_sharded)
    shardings = jax.tree_util.tree_map(
        lambda sp: NamedSharding(mesh, sp), specs,
        is_leaf=lambda x: isinstance(x, P),
    )
    sds = jax.tree_util.tree_map(
        lambda sh, sharding: jax.ShapeDtypeStruct(
            sh.shape, sh.dtype, sharding=sharding
        ),
        shapes,
        shardings,
    )
    return sds, shardings, specs
