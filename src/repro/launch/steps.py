"""Jitted distributed steps: train_step (loss + grad + clip + AdamW) and
serve steps (prefill / single-token decode), with explicit in/out shardings.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig, ShapeConfig
from repro.launch.sharding import batch_specs, param_specs
from repro.models.transformer import (
    decode_step as model_decode_step,
    init_stack_cache,
    prefill as model_prefill,
    train_loss,
)
from repro.optim import AdamWConfig, adamw_init, adamw_update, clip_by_global_norm

VOCAB_CHUNK = 8192


def opt_specs_like(param_spec_tree):
    """Optimizer-state specs: moments mirror the param layout."""
    return param_spec_tree


def make_train_step(cfg: ArchConfig, mesh, *, lr: float = 3e-4,
                    opt_cfg: Optional[AdamWConfig] = None,
                    microbatches: int = 1):
    """Distributed train step. ``microbatches > 1`` enables gradient
    accumulation: the global batch is split along its leading dim and
    scanned, dividing the live activation set by the µbatch count (the
    standard production memory lever; the optimizer update happens once)."""
    opt_cfg = opt_cfg or AdamWConfig()

    def loss_fn(p, batch):
        loss, metrics = train_loss(p, cfg, batch, vocab_chunk=VOCAB_CHUNK)
        return loss, metrics

    def step(params, opt_state, batch):
        if microbatches == 1:
            (loss, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True
            )(params, batch)
        else:
            def split(x):
                b = x.shape[0]
                if b % microbatches:
                    raise ValueError(
                        f"batch {b} not divisible by µbatches {microbatches}"
                    )
                return x.reshape((microbatches, b // microbatches) + x.shape[1:])

            mb = jax.tree_util.tree_map(split, batch)
            zero_grads = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params
            )

            def accum(carry, mb_i):
                g_acc, loss_acc, aux_acc = carry
                (loss, metrics), g = jax.value_and_grad(
                    loss_fn, has_aux=True
                )(params, mb_i)
                g_acc = jax.tree_util.tree_map(
                    lambda a, b: a + b.astype(jnp.float32) / microbatches,
                    g_acc, g,
                )
                return (g_acc, loss_acc + loss / microbatches,
                        aux_acc + metrics["aux"] / microbatches), None

            (grads, loss, aux), _ = jax.lax.scan(
                accum,
                (zero_grads, jnp.zeros((), jnp.float32),
                 jnp.zeros((), jnp.float32)),
                mb,
            )
            metrics = {"ce": loss, "aux": aux}
        grads, gnorm = clip_by_global_norm(grads, 1.0)
        params, opt_state = adamw_update(
            grads, opt_state, params, opt_cfg, jnp.asarray(lr, jnp.float32)
        )
        metrics = dict(metrics, loss=loss, grad_norm=gnorm)
        return params, opt_state, metrics

    return step


def jit_train_step(cfg: ArchConfig, mesh, params_sds, batch_sds,
                   microbatches: int = 1, **kw):
    """jit the train step with explicit shardings, ready to lower."""
    step = make_train_step(cfg, mesh, microbatches=microbatches, **kw)
    p_specs = param_specs(params_sds, mesh, fsdp=True)
    opt_sds = jax.eval_shape(
        lambda p: adamw_init(p, AdamWConfig()), params_sds
    )
    # Moments mirror params; step scalar + master=None handled structurally.
    from repro.optim import OptState

    opt_specs = OptState(
        step=P(),
        m=param_specs(opt_sds.m, mesh, fsdp=True),
        v=param_specs(opt_sds.v, mesh, fsdp=True),
        master=None,
    )
    b_specs = batch_specs(batch_sds, mesh)
    metric_specs = {"ce": P(), "aux": P(), "loss": P(), "grad_norm": P()}
    jitted = jax.jit(
        step,
        in_shardings=(_named(mesh, p_specs), _named(mesh, opt_specs),
                      _named(mesh, b_specs)),
        out_shardings=(_named(mesh, p_specs), _named(mesh, opt_specs),
                       _named(mesh, metric_specs)),
        donate_argnums=(0, 1),
    )
    return jitted, opt_sds


def make_prefill_step(cfg: ArchConfig, shape: ShapeConfig):
    def step(params, batch):
        logits, cache = model_prefill(
            params,
            cfg,
            batch["tokens"],
            max_len=shape.seq_len,
            prefix_embeds=batch.get("prefix_embeds"),
            encoder_frames=batch.get("encoder_frames"),
        )
        return logits, cache

    return step


def make_decode_step(cfg: ArchConfig):
    def step(params, cache, token, index):
        return model_decode_step(params, cfg, token, cache, index)

    return step


def _named(mesh, spec_tree):
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s) if isinstance(s, P) else s,
        spec_tree,
        is_leaf=lambda x: isinstance(x, P) or x is None,
    )
