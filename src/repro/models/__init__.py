"""Model definitions: the paper's CNN substrate and the assigned LM-family
architectures (dense/GQA transformers, MoE, SSM, hybrid, enc-dec, VLM)."""
