"""Parameter / FLOP accounting for the roofline analysis.

MODEL_FLOPS conventions (EXPERIMENTS.md §Roofline):
  train    : 6 * N_active * D   (fwd 2ND + bwd 4ND)
  prefill  : 2 * N_active * D
  decode   : 2 * N_active * B   (one token per sequence) + attention reads
The ratio MODEL_FLOPS / HLO_FLOPs then measures how much compiled compute
is "useful" (catches remat recompute, capacity over-provisioning, masked
attention waste).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, ShapeConfig


def param_count(cfg: ArchConfig) -> int:
    """Exact total parameter count (eval_shape over the real initializer)."""
    import math

    from repro.models.transformer import init_params

    shapes = jax.eval_shape(
        lambda key: init_params(key, cfg), jax.random.PRNGKey(0)
    )
    return sum(
        math.prod(l.shape) for l in jax.tree_util.tree_leaves(shapes)
    )


def _expert_params_per_moe_layer(cfg: ArchConfig) -> int:
    # Per-expert SwiGLU: 3 * d * moe_d_ff.
    return 3 * cfg.d_model * cfg.moe_d_ff


def _n_moe_layers(cfg: ArchConfig) -> int:
    if not cfg.n_experts:
        return 0
    return sum(1 for i in range(cfg.n_layers)
               if cfg.block_pattern[i % len(cfg.block_pattern)] == "attn")


def active_param_count(cfg: ArchConfig) -> int:
    """Parameters touched per token: total minus the (E - top_k) unused
    experts per MoE layer."""
    total = param_count(cfg)
    if not cfg.n_experts:
        return total
    unused = (cfg.n_experts - cfg.top_k) * _expert_params_per_moe_layer(cfg)
    return total - unused * _n_moe_layers(cfg)


def model_flops(cfg: ArchConfig, shape: ShapeConfig) -> float:
    """The 'useful work' FLOP count for one step of the given shape."""
    n_active = active_param_count(cfg)
    # Embedding + unembedding are gathers/matmuls already inside N; the
    # dominant correction is attention score/value FLOPs, added explicitly.
    if shape.kind == "train":
        d_tokens = shape.seq_len * shape.global_batch
        base = 6.0 * n_active * d_tokens
        attn = 3.0 * _attention_flops(cfg, shape.seq_len, shape.global_batch)
        return base + attn
    if shape.kind == "prefill":
        d_tokens = shape.seq_len * shape.global_batch
        return 2.0 * n_active * d_tokens + _attention_flops(
            cfg, shape.seq_len, shape.global_batch
        )
    # decode: one token per sequence, reading a seq_len-deep cache.
    base = 2.0 * n_active * shape.global_batch
    attn = _decode_attention_flops(cfg, shape.seq_len, shape.global_batch)
    return base + attn


def _visible_kv(cfg: ArchConfig, kind: str, s: int) -> float:
    if kind == "local":
        return min(cfg.window, s)
    if kind == "chunked":
        return min(cfg.chunk, s)
    return s


def _attention_flops(cfg: ArchConfig, s: int, b: int) -> float:
    """Exact causal/windowed score+value FLOPs across layers (fwd)."""
    total = 0.0
    hd = cfg.hd
    for i in range(cfg.n_layers):
        kind_b = cfg.block_pattern[i % len(cfg.block_pattern)]
        if kind_b != "attn":
            continue
        kind_a = cfg.attn_kind_for_layer(i % len(cfg.block_pattern))
        w = _visible_kv(cfg, kind_a, s)
        # Average visible kv per query ~ w/2 for causal-limited windows.
        avg = (w + 1) / 2 if kind_a != "full" or True else w
        total += 4.0 * b * s * avg * cfg.n_heads * hd  # QK^T + PV
    return total


def _decode_attention_flops(cfg: ArchConfig, s: int, b: int) -> float:
    total = 0.0
    hd = cfg.hd
    for i in range(cfg.n_layers):
        kind_b = cfg.block_pattern[i % len(cfg.block_pattern)]
        if kind_b != "attn":
            continue
        kind_a = cfg.attn_kind_for_layer(i % len(cfg.block_pattern))
        w = _visible_kv(cfg, kind_a, s)
        total += 4.0 * b * w * cfg.n_heads * hd
    return total


def param_bytes(cfg: ArchConfig) -> int:
    bytes_per = jnp.dtype(cfg.dtype).itemsize
    return param_count(cfg) * bytes_per
