"""Attention: GQA/MQA/MHA with blockwise (flash-style) online-softmax
computation, causal / sliding-window / chunked masks, cross-attention, and
KV-cache decode.

The blockwise formulation (lax.scan over KV blocks with running max/sum)
keeps the S x S score matrix from ever materializing — required for the
32k-prefill and 4k-train shapes at production batch sizes, and it is the
structure the TPU wants (VMEM-resident blocks, MXU matmuls).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

NEG_INF = -1e30


def _constrain(x, *spec):
    """with_sharding_constraint against the ambient mesh, if one exists and
    carries the referenced axes; identity otherwise (keeps model code usable
    outside jit / on a single device). Dims whose size doesn't divide are
    dropped per-axis."""
    try:
        mesh = jax.sharding.get_abstract_mesh()
        axes = set(mesh.axis_names or ())
    except Exception:  # noqa: BLE001
        return x
    if not axes:
        return x
    fixed = []
    for i, entry in enumerate(spec):
        names = entry if isinstance(entry, tuple) else (entry,)
        names = tuple(n for n in names if n in axes)
        total = 1
        for n in names:
            total *= mesh.shape[n]
        if not names or x.shape[i] % total:
            fixed.append(None)
        else:
            fixed.append(names if len(names) > 1 else names[0])
    return jax.lax.with_sharding_constraint(x, P(*fixed))


@dataclasses.dataclass(frozen=True)
class AttnSpec:
    """Static attention configuration for one layer."""

    kind: str = "causal"  # causal | local | chunked | full
    window: int = 0  # local: kv in (q - window, q]
    chunk: int = 0  # chunked: causal within q//chunk == kv//chunk


def _mask(spec: AttnSpec, q_pos, kv_pos):
    """(Sq, Skv) boolean mask: True = attend."""
    dq, dk = q_pos[:, None], kv_pos[None, :]
    if spec.kind == "full":
        return jnp.ones((q_pos.size, kv_pos.size), bool)
    m = dk <= dq  # causal
    if spec.kind == "local":
        m = jnp.logical_and(m, dk > dq - spec.window)
    elif spec.kind == "chunked":
        m = jnp.logical_and(m, dk // spec.chunk == dq // spec.chunk)
    elif spec.kind != "causal":
        raise ValueError(spec.kind)
    return m


def blockwise_attention(
    q: jax.Array,  # (B, Sq, Hkv, G, Dh)
    k: jax.Array,  # (B, Skv, Hkv, Dh)
    v: jax.Array,  # (B, Skv, Hkv, Dh)
    spec: AttnSpec,
    *,
    q_offset: int = 0,
    q_block: int = 1024,
    kv_block: int = 1024,
    exact_f32: bool = True,
    pin_batch: bool = False,
) -> jax.Array:
    """Online-softmax attention; returns (B, Sq, Hkv, G, Dh).

    ``exact_f32=False`` keeps bf16 einsum operands with f32 accumulation
    (preferred_element_type) — the flash-attention numerics, halving the
    attention HBM traffic; True materializes f32 casts (baseline).

    The batch dim is pinned to the data axes: without the constraint GSPMD
    sometimes re-replicates the batch inside the blockwise loop, inflating
    the score traffic by the data-parallel degree (measured on the
    command-r train cell)."""
    if pin_batch:
        # Flatten the (Hkv, G) grouping to H = Hkv*G heads so the model
        # axis can shard heads even when Hkv and G individually don't
        # divide it (command-r: 8x8 heads vs a 16-wide axis). The KV repeat
        # costs G x KV bytes — orders of magnitude below the score traffic
        # it lets the mesh shard away.
        b0, s0, hkv0, g0, dh0 = q.shape
        if g0 > 1:
            k = jnp.repeat(k, g0, axis=2)
            v = jnp.repeat(v, g0, axis=2)
            q = q.reshape(b0, s0, hkv0 * g0, 1, dh0)
        q = _constrain(q, ("pod", "data"), None, "model", None, None)
        k = _constrain(k, ("pod", "data"), None, "model", None)
        v = _constrain(v, ("pod", "data"), None, "model", None)
    b, sq, hkv, g, dh = q.shape
    skv = k.shape[1]
    scale = 1.0 / jnp.sqrt(dh).astype(jnp.float32)
    qb = min(q_block, sq)
    kb = min(kv_block, skv)
    # Pad to block multiples.
    sq_p, skv_p = -(-sq // qb) * qb, -(-skv // kb) * kb
    q = jnp.pad(q, ((0, 0), (0, sq_p - sq), (0, 0), (0, 0), (0, 0)))
    k = jnp.pad(k, ((0, 0), (0, skv_p - skv), (0, 0), (0, 0)))
    v = jnp.pad(v, ((0, 0), (0, skv_p - skv), (0, 0), (0, 0)))
    n_q, n_kv = sq_p // qb, skv_p // kb

    k_blocks = k.reshape(b, n_kv, kb, hkv, dh).transpose(1, 0, 2, 3, 4)
    v_blocks = v.reshape(b, n_kv, kb, hkv, dh).transpose(1, 0, 2, 3, 4)

    def q_block_fn(qi, q_blk):
        # q_blk: (B, qb, Hkv, G, Dh)
        q_pos = q_offset + qi * qb + jnp.arange(qb)

        def kv_step(carry, inp):
            m_run, l_run, o_run = carry
            kj, (k_blk, v_blk) = inp
            kv_pos = kj * kb + jnp.arange(kb)
            if exact_f32:
                s = jnp.einsum(
                    "bihgd,bjhd->bhgij",
                    q_blk.astype(jnp.float32),
                    k_blk.astype(jnp.float32),
                ) * scale
            else:
                s = jnp.einsum(
                    "bihgd,bjhd->bhgij", q_blk, k_blk,
                    preferred_element_type=jnp.float32,
                ) * scale  # (B, Hkv, G, qb, kb)
            mask = _mask(spec, q_pos, kv_pos)
            mask = jnp.logical_and(mask, (kv_pos < skv)[None, :])
            s = jnp.where(mask[None, None, None], s, NEG_INF)
            m_new = jnp.maximum(m_run, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_new[..., None])
            alpha = jnp.exp(m_run - m_new)
            l_new = l_run * alpha + jnp.sum(p, axis=-1)
            if exact_f32:
                pv = jnp.einsum(
                    "bhgij,bjhd->bhgid", p, v_blk.astype(jnp.float32)
                )
            else:
                pv = jnp.einsum(
                    "bhgij,bjhd->bhgid", p.astype(v_blk.dtype), v_blk,
                    preferred_element_type=jnp.float32,
                )
            o_new = o_run * alpha[..., None] + pv
            return (m_new, l_new, o_new), None

        m0 = jnp.full((b, hkv, g, qb), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, hkv, g, qb), jnp.float32)
        o0 = jnp.zeros((b, hkv, g, qb, dh), jnp.float32)
        (m_f, l_f, o_f), _ = jax.lax.scan(
            kv_step, (m0, l0, o0), (jnp.arange(n_kv), (k_blocks, v_blocks))
        )
        o = o_f / jnp.maximum(l_f[..., None], 1e-30)
        return o.transpose(0, 3, 1, 2, 4)  # (B, qb, Hkv, G, Dh)

    q_blocks = q.reshape(b, n_q, qb, hkv, g, dh).transpose(1, 0, 2, 3, 4, 5)
    out = jax.lax.map(
        lambda args: q_block_fn(args[0], args[1]), (jnp.arange(n_q), q_blocks)
    )  # (n_q, B, qb, Hkv, G, Dh)
    out = out.transpose(1, 0, 2, 3, 4, 5).reshape(b, sq_p, hkv, g, dh)
    if pin_batch:
        out = _constrain(out, ("pod", "data"), None, "model", None, None)
    return out[:, :sq].astype(q.dtype)


def decode_attention(
    q: jax.Array,  # (B, 1, Hkv, G, Dh)
    cache_k: jax.Array,  # (B, S_cache, Hkv, Dh)
    cache_v: jax.Array,
    cur_index,  # scalar int: position of the new token
    spec: AttnSpec,
    *,
    exact_f32: bool = True,
) -> jax.Array:
    """Single-token attention against a KV cache (the serve_step path).

    The cache is a ring buffer: slot i holds absolute position
    ``cur - ((cur - i) mod S)``; for an unwrapped cache (S > cur) this
    reduces to position i. Windowed/chunked layers size their cache to the
    window so old positions are naturally evicted.
    """
    b, _, hkv, g, dh = q.shape
    s_cache = cache_k.shape[1]
    scale = 1.0 / jnp.sqrt(dh).astype(jnp.float32)
    slots = jnp.arange(s_cache)
    kv_pos = cur_index - jnp.mod(cur_index - slots, s_cache)
    ok = jnp.logical_and(kv_pos >= 0, kv_pos <= cur_index)
    if spec.kind == "local":
        ok = jnp.logical_and(ok, kv_pos > cur_index - spec.window)
    elif spec.kind == "chunked":
        ok = jnp.logical_and(ok, kv_pos // spec.chunk == cur_index // spec.chunk)
    if exact_f32:
        s = jnp.einsum(
            "bihgd,bjhd->bhgij",
            q.astype(jnp.float32),
            cache_k.astype(jnp.float32),
        ) * scale  # (B, Hkv, G, 1, S_cache)
    else:
        s = jnp.einsum(
            "bihgd,bjhd->bhgij", q, cache_k,
            preferred_element_type=jnp.float32,
        ) * scale
    s = jnp.where(ok[None, None, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    if exact_f32:
        o = jnp.einsum("bhgij,bjhd->bihgd", p, cache_v.astype(jnp.float32))
    else:
        o = jnp.einsum(
            "bhgij,bjhd->bihgd", p.astype(cache_v.dtype), cache_v,
            preferred_element_type=jnp.float32,
        )
    return o.astype(q.dtype)


def init_kv_cache(batch: int, max_len: int, n_kv: int, head_dim: int, dtype):
    return {
        "k": jnp.zeros((batch, max_len, n_kv, head_dim), dtype),
        "v": jnp.zeros((batch, max_len, n_kv, head_dim), dtype),
    }


def update_kv_cache(cache: dict, k_new: jax.Array, v_new: jax.Array, index):
    """Insert (B, 1, Hkv, Dh) new KV at position ``index`` (mod cache len —
    ring-buffer semantics for windowed layers)."""
    s_cache = cache["k"].shape[1]
    slot = jnp.mod(index, s_cache)
    return {
        "k": jax.lax.dynamic_update_slice_in_dim(cache["k"], k_new, slot, axis=1),
        "v": jax.lax.dynamic_update_slice_in_dim(cache["v"], v_new, slot, axis=1),
    }
