"""CNN substrate in pure JAX: the paper's three benchmark networks plus
the non-paper generalization topologies.

Topologies (paper Table 1):

  LeNet5   : 28x28x1  -> conv(20,5) mpool tanh -> conv(50,5) mpool tanh -> FC
  Cifar10  : 32x32x3  -> conv(32,5) mpool tanh -> conv(32,5) mpool tanh
                       -> conv(64,5) mpool tanh -> FC
  SVHN     : same topology as Cifar10 (different learned kernel values).

LeNet5 uses VALID convolutions (Caffe's original LeNet), the CIFAR10/SVHN
topology uses SAME padding (Caffe's cifar10_quick), which reproduces the
paper's workload numbers exactly: 3.8 Mop (LeNet5 feature extractor) and
24.6 Mop (Cifar10/SVHN feature extractor).

Beyond the paper, ``CIFAR10_FULL`` (Caffe's cifar10_full: 5x5 SAME convs
with overlapping 3x3/stride-2 max-pool) and ``CIFAR10_STRIDED`` (stride-2
downsampling convs instead of pooling) exercise the generalized layer
vocabulary — conv ``stride``, ``(pool, pool_stride)`` windows with
window != stride, and rectangular frames — through the same DHM lowering
path as the paper nets.

Everything is functional: ``init_cnn`` builds a param pytree, ``cnn_apply``
runs the forward pass. ``cnn_apply`` is a thin veneer over the DHM
compiler: the topology + params + quantization spec lower through
``repro.core.dhm.compiler.compile_dhm`` into a plan of fused actor-chain
stages, and the forward pass runs that plan. ``conv_backend=None`` selects
the ``ref`` kernel backend (the lax.conv composition — the fast,
well-differentiable path for training); any ``repro.kernels.backends``
name routes the stages through the corresponding fused streaming kernel.
``cnn_apply_reference`` keeps the original hand-composed forward pass
(separate conv/bias/pool/act/fake-quant XLA ops) as the oracle compiled
plans are tested against. The two agree because pooling and the (monotone)
activations commute.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Sequence

import jax
import jax.numpy as jnp

from repro.core.quant.fixed_point import (
    FixedPointSpec,
    fake_quant_dynamic,
    fake_quant_ste,
)


@dataclasses.dataclass(frozen=True)
class ConvLayerSpec:
    """One conv+mpool+act stage (a row of paper Table 1, generalized).

    ``pool`` is the square max-pool window (0 = no pool) and
    ``pool_stride`` its sliding stride; ``pool_stride=None`` means
    window == stride, so the historic ``pool=2`` sugar still reads as
    2x2/stride-2. ``stride`` is the conv stride.
    """

    n_out: int  # N: output feature maps
    kernel: int  # K
    padding: str = "VALID"  # VALID (LeNet5) or SAME (Cifar10/SVHN)
    pool: int = 2  # mpool window (0 = no pool)
    act: str = "tanh"
    stride: int = 1  # conv stride
    pool_stride: int | None = None  # None -> == pool (window == stride)

    @property
    def pool_cfg(self) -> tuple:
        """Concrete ``(window, stride)`` pool pair; ``(0, 0)`` = no pool.
        Only ``None`` defaults the stride to the window — an explicit
        invalid stride (e.g. 0) is kept so the compiler's validation
        rejects it instead of shape paths silently disagreeing."""
        if not self.pool:
            return (0, 0)
        ps = self.pool if self.pool_stride is None else self.pool_stride
        return (self.pool, ps)

    def out_hw(self, h: int, w: int) -> tuple:
        """(H, W) after this layer's conv + pool, from an (H, W) input."""
        h_c, w_c = self.conv_hw(h, w)
        pw, ps = self.pool_cfg
        if pw:
            return (h_c - pw) // ps + 1, (w_c - pw) // ps + 1
        return h_c, w_c

    def conv_hw(self, h: int, w: int) -> tuple:
        """(H, W) after the conv alone (pre-pool)."""
        s = self.stride
        if self.padding == "SAME":
            return -(-h // s), -(-w // s)
        return (h - self.kernel) // s + 1, (w - self.kernel) // s + 1


@dataclasses.dataclass(frozen=True)
class CNNTopology:
    name: str
    input_hw: object  # int (square frame) or (H, W) tuple
    input_channels: int
    conv_layers: tuple
    fc_dims: tuple  # hidden FC dims of the classifier head
    n_classes: int

    def __post_init__(self):
        hw = self.input_hw
        ok = isinstance(hw, int) or (
            isinstance(hw, tuple) and len(hw) == 2
            and all(isinstance(d, int) for d in hw)
        )
        if not ok:
            raise ValueError(
                f"{self.name}: input_hw must be an int (square frame) or an "
                f"(H, W) tuple of ints, got {hw!r}"
            )

    @property
    def input_shape(self) -> tuple:
        """(H, W) of the input frame (int sugar means square)."""
        if isinstance(self.input_hw, int):
            return (self.input_hw, self.input_hw)
        return self.input_hw

    def square_input_hw(self) -> int:
        """The square frame side — raises clearly on rectangular inputs
        for the few paths (synthetic datasets) that still require
        squareness, instead of silently mis-shaping."""
        h, w = self.input_shape
        if h != w:
            raise ValueError(
                f"{self.name}: this path requires a square input frame, "
                f"got {h}x{w}"
            )
        return h

    def conv_shapes(self):
        """Per-layer (C_in, N_out, K, H_out, W_out) after conv (pre-pool)."""
        h, w = self.input_shape
        c = self.input_channels
        out = []
        for spec in self.conv_layers:
            h_conv, w_conv = spec.conv_hw(h, w)
            out.append((c, spec.n_out, spec.kernel, h_conv, w_conv))
            h, w = spec.out_hw(h, w)
            c = spec.n_out
        return out

    def feature_shape(self) -> tuple:
        """(H, W, C) of the feature-extractor output (FC head input)."""
        h, w = self.input_shape
        c = self.input_channels
        for spec in self.conv_layers:
            h, w = spec.out_hw(h, w)
            c = spec.n_out
        return h, w, c

    def feature_extractor_macs(self) -> int:
        """MACs of the conv stack for one input frame."""
        return sum(c * n * k * k * h * w for (c, n, k, h, w) in self.conv_shapes())

    def feature_extractor_ops(self) -> int:
        """Ops (1 MAC = 2 ops) — the paper's 'Workload' column in Table 4."""
        return 2 * self.feature_extractor_macs()

    def n_multipliers(self) -> int:
        """Multipliers a full DHM instantiation needs: N*C*K*K per layer."""
        return sum(c * n * k * k for (c, n, k, _, _) in self.conv_shapes())


LENET5 = CNNTopology(
    name="lenet5",
    input_hw=28,
    input_channels=1,
    conv_layers=(
        ConvLayerSpec(n_out=20, kernel=5, padding="VALID"),
        ConvLayerSpec(n_out=50, kernel=5, padding="VALID"),
    ),
    fc_dims=(500,),
    n_classes=10,
)

CIFAR10 = CNNTopology(
    name="cifar10",
    input_hw=32,
    input_channels=3,
    conv_layers=(
        ConvLayerSpec(n_out=32, kernel=5, padding="SAME"),
        ConvLayerSpec(n_out=32, kernel=5, padding="SAME"),
        ConvLayerSpec(n_out=64, kernel=5, padding="SAME"),
    ),
    fc_dims=(64,),
    n_classes=10,
)

SVHN = dataclasses.replace(CIFAR10, name="svhn")

PAPER_TOPOLOGIES = {"lenet5": LENET5, "cifar10": CIFAR10, "svhn": SVHN}

# Caffe's cifar10_full: 5x5 SAME convs with OVERLAPPING 3x3/stride-2
# max-pool (32 -> 15 -> 7 -> 3) — the pool-window != pool-stride case the
# paper topologies never exercise.
CIFAR10_FULL = CNNTopology(
    name="cifar10_full",
    input_hw=32,
    input_channels=3,
    conv_layers=(
        ConvLayerSpec(n_out=32, kernel=5, padding="SAME", pool=3,
                      pool_stride=2, act="relu"),
        ConvLayerSpec(n_out=32, kernel=5, padding="SAME", pool=3,
                      pool_stride=2, act="relu"),
        ConvLayerSpec(n_out=64, kernel=5, padding="SAME", pool=3,
                      pool_stride=2, act="relu"),
    ),
    fc_dims=(64,),
    n_classes=10,
)

# Stride-2 downsampling variant: the first two layers downsample with conv
# stride instead of pooling (32 -> 16 -> 8), the last keeps a 2x2/2 pool.
CIFAR10_STRIDED = CNNTopology(
    name="cifar10_strided",
    input_hw=32,
    input_channels=3,
    conv_layers=(
        ConvLayerSpec(n_out=32, kernel=5, padding="SAME", stride=2, pool=0,
                      act="relu"),
        ConvLayerSpec(n_out=64, kernel=3, padding="SAME", stride=2, pool=0,
                      act="relu"),
        ConvLayerSpec(n_out=64, kernel=3, padding="SAME", pool=2,
                      act="relu"),
    ),
    fc_dims=(64,),
    n_classes=10,
)

EXTRA_TOPOLOGIES = {
    "cifar10_full": CIFAR10_FULL,
    "cifar10_strided": CIFAR10_STRIDED,
}
ALL_TOPOLOGIES = {**PAPER_TOPOLOGIES, **EXTRA_TOPOLOGIES}


def _act(name: str) -> Callable:
    return {"tanh": jnp.tanh, "relu": jax.nn.relu, "none": lambda x: x}[name]


def init_cnn(key: jax.Array, topo: CNNTopology, dtype=jnp.float32) -> dict:
    """Glorot-init parameters for a topology. Layout:
    conv kernels HWIO (K, K, C, N); FC weights (in, out)."""
    params: dict = {"conv": [], "fc": []}
    c = topo.input_channels
    for spec in topo.conv_layers:
        key, wk, bk = jax.random.split(key, 3)
        fan_in = spec.kernel * spec.kernel * c
        w = jax.random.normal(wk, (spec.kernel, spec.kernel, c, spec.n_out), dtype)
        w = w * jnp.sqrt(2.0 / fan_in)
        b = jnp.zeros((spec.n_out,), dtype)
        params["conv"].append({"w": w, "b": b})
        c = spec.n_out
    h, w_, c = topo.feature_shape()
    flat = h * w_ * c
    dims = (flat,) + tuple(topo.fc_dims) + (topo.n_classes,)
    for d_in, d_out in zip(dims[:-1], dims[1:]):
        key, wk = jax.random.split(key)
        w = jax.random.normal(wk, (d_in, d_out), dtype) * jnp.sqrt(2.0 / d_in)
        params["fc"].append({"w": w, "b": jnp.zeros((d_out,), dtype)})
    return params


def _maxpool(x: jax.Array, window: int, stride: int | None = None) -> jax.Array:
    stride = stride or window
    return jax.lax.reduce_window(
        x,
        -jnp.inf,
        jax.lax.max,
        window_dimensions=(1, window, window, 1),
        window_strides=(1, stride, stride, 1),
        padding="VALID",
    )


def quantize_cnn_params(params: dict, bits: int) -> dict:
    """Fake-quantize all parameters with per-tensor dynamic power-of-two
    scales (trace-compatible, STE gradients)."""
    return jax.tree_util.tree_map(lambda p: fake_quant_dynamic(p, bits), params)


def export_cnn_specs(params: dict, bits: int) -> dict:
    """Static per-tensor FixedPointSpec tree for a *trained* model (the
    offline Q-format the paper's synthesis flow consumes)."""
    return jax.tree_util.tree_map(
        lambda p: FixedPointSpec.for_tensor(p, bits),
        params,
        is_leaf=lambda x: isinstance(x, jnp.ndarray),
    )


def cnn_apply(
    params: dict,
    topo: CNNTopology,
    x: jax.Array,
    *,
    weight_bits: int | None = None,
    act_bits: int | None = None,
    pow2_weights: bool = False,
    conv_backend: str | None = None,
    vmem_budget: int | None = None,
) -> jax.Array:
    """Forward pass. x: (B, H, W, C) NHWC. Returns logits (B, n_classes).

    Lowers through the DHM compiler: topology + params + quantization spec
    become a single-device :class:`~repro.core.dhm.compiler.CompiledDHM`
    plan of fused actor-chain stages, which is then run on ``x``.

    ``weight_bits`` enables fixed-point fake-quant of all parameters (QAT
    via STE); ``act_bits`` additionally quantizes the inter-layer feature
    streams — inside the fused kernel epilogue, the paper's quantized pixel
    flow. ``pow2_weights`` projects every weight onto the {0, ±2^k}
    codebook with STE and lowers the FC head through the packed
    ``pow2_matmul`` kernel (beyond-paper: 100%-multiplierless QAT).
    ``conv_backend`` (a ``repro.kernels.backends`` name) selects the kernel
    backend for every conv stage; None means the ``ref`` composition
    (lax.conv — the fast path for training, with well-tuned gradients).
    ``vmem_budget`` is the compiler's cross-layer fusion budget in bytes
    (None = the default, which fuses every paper topology's feature
    extractor into one kernel group; 0 = per-layer stages).
    """
    from repro.core.dhm.compiler import QuantSpec, compile_dhm
    from repro.core.dhm.engine import forward as engine_forward

    plan = compile_dhm(
        topo,
        params,
        quant=QuantSpec(
            weight_bits=weight_bits,
            act_bits=act_bits,
            pow2_weights=pow2_weights,
        ),
        backend=conv_backend if conv_backend is not None else "ref",
        vmem_budget=vmem_budget,
    )
    # Run through the engine's EAGER path rather than plan.__call__:
    # eager model-level calls build a fresh plan per invocation, so the
    # plan-level cached jit would retrace every call — the stage bodies
    # are module-level jitted kernels with process-wide caches instead.
    return engine_forward(plan, x)


def cnn_apply_reference(
    params: dict,
    topo: CNNTopology,
    x: jax.Array,
    *,
    weight_bits: int | None = None,
    act_bits: int | None = None,
    pow2_weights: bool = False,
) -> jax.Array:
    """The hand-composed forward pass (separate conv / bias / pool / act /
    fake-quant XLA ops) — the oracle every compiled plan is tested against.
    Kept free of the compiler and the fused kernels on purpose."""
    if pow2_weights:
        from repro.core.quant.pow2 import project_pow2_ste

        params = jax.tree_util.tree_map(
            lambda p: project_pow2_ste(p) if p.ndim > 1 else p, params
        )
    if weight_bits is not None:
        params = quantize_cnn_params(params, weight_bits)

    def maybe_qact(h):
        if act_bits is None:
            return h
        spec = FixedPointSpec(bits=act_bits, frac_bits=act_bits - 2)
        return fake_quant_ste(h, spec)

    h = x
    for spec, p in zip(topo.conv_layers, params["conv"]):
        h = jax.lax.conv_general_dilated(
            h,
            p["w"],
            window_strides=(spec.stride, spec.stride),
            padding=spec.padding,
            dimension_numbers=("NHWC", "HWIO", "NHWC"),
        )
        h = h + p["b"]
        pw, ps = spec.pool_cfg
        if pw:
            h = _maxpool(h, pw, ps)
        h = _act(spec.act)(h)
        h = maybe_qact(h)
    h = h.reshape(h.shape[0], -1)
    for i, p in enumerate(params["fc"]):
        h = h @ p["w"] + p["b"]
        if i < len(params["fc"]) - 1:
            h = jnp.tanh(h)
            h = maybe_qact(h)
    return h
