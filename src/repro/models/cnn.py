"""CNN substrate in pure JAX: the paper's three benchmark networks.

Topologies (paper Table 1):

  LeNet5   : 28x28x1  -> conv(20,5) mpool tanh -> conv(50,5) mpool tanh -> FC
  Cifar10  : 32x32x3  -> conv(32,5) mpool tanh -> conv(32,5) mpool tanh
                       -> conv(64,5) mpool tanh -> FC
  SVHN     : same topology as Cifar10 (different learned kernel values).

LeNet5 uses VALID convolutions (Caffe's original LeNet), the CIFAR10/SVHN
topology uses SAME padding (Caffe's cifar10_quick), which reproduces the
paper's workload numbers exactly: 3.8 Mop (LeNet5 feature extractor) and
24.6 Mop (Cifar10/SVHN feature extractor).

Everything is functional: ``init_cnn`` builds a param pytree, ``cnn_apply``
runs the forward pass. ``cnn_apply`` is a thin veneer over the DHM
compiler: the topology + params + quantization spec lower through
``repro.core.dhm.compiler.compile_dhm`` into a plan of fused actor-chain
stages, and the forward pass runs that plan. ``conv_backend=None`` selects
the ``ref`` kernel backend (the lax.conv composition — the fast,
well-differentiable path for training); any ``repro.kernels.backends``
name routes the stages through the corresponding fused streaming kernel.
``cnn_apply_reference`` keeps the original hand-composed forward pass
(separate conv/bias/pool/act/fake-quant XLA ops) as the oracle compiled
plans are tested against. The two agree because pooling and the (monotone)
activations commute.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Sequence

import jax
import jax.numpy as jnp

from repro.core.quant.fixed_point import (
    FixedPointSpec,
    fake_quant_dynamic,
    fake_quant_ste,
)


@dataclasses.dataclass(frozen=True)
class ConvLayerSpec:
    """One conv+mpool+act stage (a row of paper Table 1)."""

    n_out: int  # N: output feature maps
    kernel: int  # K
    padding: str = "VALID"  # VALID (LeNet5) or SAME (Cifar10/SVHN)
    pool: int = 2  # mpool window/stride (0 = no pool)
    act: str = "tanh"


@dataclasses.dataclass(frozen=True)
class CNNTopology:
    name: str
    input_hw: int
    input_channels: int
    conv_layers: tuple
    fc_dims: tuple  # hidden FC dims of the classifier head
    n_classes: int

    def conv_shapes(self):
        """Per-layer (C_in, N_out, K, H_out, W_out) after conv (pre-pool)."""
        h = self.input_hw
        c = self.input_channels
        out = []
        for spec in self.conv_layers:
            h_conv = h if spec.padding == "SAME" else h - spec.kernel + 1
            out.append((c, spec.n_out, spec.kernel, h_conv, h_conv))
            h = h_conv // spec.pool if spec.pool else h_conv
            c = spec.n_out
        return out

    def feature_extractor_macs(self) -> int:
        """MACs of the conv stack for one input frame."""
        return sum(c * n * k * k * h * w for (c, n, k, h, w) in self.conv_shapes())

    def feature_extractor_ops(self) -> int:
        """Ops (1 MAC = 2 ops) — the paper's 'Workload' column in Table 4."""
        return 2 * self.feature_extractor_macs()

    def n_multipliers(self) -> int:
        """Multipliers a full DHM instantiation needs: N*C*K*K per layer."""
        return sum(c * n * k * k for (c, n, k, _, _) in self.conv_shapes())


LENET5 = CNNTopology(
    name="lenet5",
    input_hw=28,
    input_channels=1,
    conv_layers=(
        ConvLayerSpec(n_out=20, kernel=5, padding="VALID"),
        ConvLayerSpec(n_out=50, kernel=5, padding="VALID"),
    ),
    fc_dims=(500,),
    n_classes=10,
)

CIFAR10 = CNNTopology(
    name="cifar10",
    input_hw=32,
    input_channels=3,
    conv_layers=(
        ConvLayerSpec(n_out=32, kernel=5, padding="SAME"),
        ConvLayerSpec(n_out=32, kernel=5, padding="SAME"),
        ConvLayerSpec(n_out=64, kernel=5, padding="SAME"),
    ),
    fc_dims=(64,),
    n_classes=10,
)

SVHN = dataclasses.replace(CIFAR10, name="svhn")

PAPER_TOPOLOGIES = {"lenet5": LENET5, "cifar10": CIFAR10, "svhn": SVHN}


def _act(name: str) -> Callable:
    return {"tanh": jnp.tanh, "relu": jax.nn.relu, "none": lambda x: x}[name]


def init_cnn(key: jax.Array, topo: CNNTopology, dtype=jnp.float32) -> dict:
    """Glorot-init parameters for a topology. Layout:
    conv kernels HWIO (K, K, C, N); FC weights (in, out)."""
    params: dict = {"conv": [], "fc": []}
    h = topo.input_hw
    c = topo.input_channels
    for spec in topo.conv_layers:
        key, wk, bk = jax.random.split(key, 3)
        fan_in = spec.kernel * spec.kernel * c
        w = jax.random.normal(wk, (spec.kernel, spec.kernel, c, spec.n_out), dtype)
        w = w * jnp.sqrt(2.0 / fan_in)
        b = jnp.zeros((spec.n_out,), dtype)
        params["conv"].append({"w": w, "b": b})
        h_conv = h if spec.padding == "SAME" else h - spec.kernel + 1
        h = h_conv // spec.pool if spec.pool else h_conv
        c = spec.n_out
    flat = h * h * c
    dims = (flat,) + tuple(topo.fc_dims) + (topo.n_classes,)
    for d_in, d_out in zip(dims[:-1], dims[1:]):
        key, wk = jax.random.split(key)
        w = jax.random.normal(wk, (d_in, d_out), dtype) * jnp.sqrt(2.0 / d_in)
        params["fc"].append({"w": w, "b": jnp.zeros((d_out,), dtype)})
    return params


def _maxpool(x: jax.Array, window: int) -> jax.Array:
    return jax.lax.reduce_window(
        x,
        -jnp.inf,
        jax.lax.max,
        window_dimensions=(1, window, window, 1),
        window_strides=(1, window, window, 1),
        padding="VALID",
    )


def quantize_cnn_params(params: dict, bits: int) -> dict:
    """Fake-quantize all parameters with per-tensor dynamic power-of-two
    scales (trace-compatible, STE gradients)."""
    return jax.tree_util.tree_map(lambda p: fake_quant_dynamic(p, bits), params)


def export_cnn_specs(params: dict, bits: int) -> dict:
    """Static per-tensor FixedPointSpec tree for a *trained* model (the
    offline Q-format the paper's synthesis flow consumes)."""
    return jax.tree_util.tree_map(
        lambda p: FixedPointSpec.for_tensor(p, bits),
        params,
        is_leaf=lambda x: isinstance(x, jnp.ndarray),
    )


def cnn_apply(
    params: dict,
    topo: CNNTopology,
    x: jax.Array,
    *,
    weight_bits: int | None = None,
    act_bits: int | None = None,
    pow2_weights: bool = False,
    conv_backend: str | None = None,
) -> jax.Array:
    """Forward pass. x: (B, H, W, C) NHWC. Returns logits (B, n_classes).

    Lowers through the DHM compiler: topology + params + quantization spec
    become a single-device :class:`~repro.core.dhm.compiler.CompiledDHM`
    plan of fused actor-chain stages, which is then run on ``x``.

    ``weight_bits`` enables fixed-point fake-quant of all parameters (QAT
    via STE); ``act_bits`` additionally quantizes the inter-layer feature
    streams — inside the fused kernel epilogue, the paper's quantized pixel
    flow. ``pow2_weights`` projects every weight onto the {0, ±2^k}
    codebook with STE and lowers the FC head through the packed
    ``pow2_matmul`` kernel (beyond-paper: 100%-multiplierless QAT).
    ``conv_backend`` (a ``repro.kernels.backends`` name) selects the kernel
    backend for every conv stage; None means the ``ref`` composition
    (lax.conv — the fast path for training, with well-tuned gradients).
    """
    from repro.core.dhm.compiler import QuantSpec, compile_dhm

    plan = compile_dhm(
        topo,
        params,
        quant=QuantSpec(
            weight_bits=weight_bits,
            act_bits=act_bits,
            pow2_weights=pow2_weights,
        ),
        backend=conv_backend if conv_backend is not None else "ref",
    )
    return plan(x)


def cnn_apply_reference(
    params: dict,
    topo: CNNTopology,
    x: jax.Array,
    *,
    weight_bits: int | None = None,
    act_bits: int | None = None,
    pow2_weights: bool = False,
) -> jax.Array:
    """The hand-composed forward pass (separate conv / bias / pool / act /
    fake-quant XLA ops) — the oracle every compiled plan is tested against.
    Kept free of the compiler and the fused kernels on purpose."""
    if pow2_weights:
        from repro.core.quant.pow2 import project_pow2_ste

        params = jax.tree_util.tree_map(
            lambda p: project_pow2_ste(p) if p.ndim > 1 else p, params
        )
    if weight_bits is not None:
        params = quantize_cnn_params(params, weight_bits)

    def maybe_qact(h):
        if act_bits is None:
            return h
        spec = FixedPointSpec(bits=act_bits, frac_bits=act_bits - 2)
        return fake_quant_ste(h, spec)

    h = x
    for spec, p in zip(topo.conv_layers, params["conv"]):
        h = jax.lax.conv_general_dilated(
            h,
            p["w"],
            window_strides=(1, 1),
            padding=spec.padding,
            dimension_numbers=("NHWC", "HWIO", "NHWC"),
        )
        h = h + p["b"]
        if spec.pool:
            h = _maxpool(h, spec.pool)
        h = _act(spec.act)(h)
        h = maybe_qact(h)
    h = h.reshape(h.shape[0], -1)
    for i, p in enumerate(params["fc"]):
        h = h @ p["w"] + p["b"]
        if i < len(params["fc"]) - 1:
            h = jnp.tanh(h)
            h = maybe_qact(h)
    return h
