"""Core NN layers (functional, pytree params): norms, linear (with optional
pow2 weight-only quantization — the paper's tactic applied to LM serving),
rotary embeddings, gated MLPs, embeddings with chunked-vocab logits."""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.quant.pow2 import decode_pow2, project_pow2_ste

# ---------------------------------------------------------------------------
# Initialization


def he_init(key, shape, dtype, fan_in=None):
    fan_in = fan_in or shape[0]
    return (jax.random.normal(key, shape) * jnp.sqrt(2.0 / fan_in)).astype(dtype)


def xavier_init(key, shape, dtype):
    fan_in, fan_out = shape[0], shape[-1]
    s = jnp.sqrt(2.0 / (fan_in + fan_out))
    return (jax.random.normal(key, shape) * s).astype(dtype)


def embed_init(key, shape, dtype):
    return (jax.random.normal(key, shape) * 0.02).astype(dtype)


# ---------------------------------------------------------------------------
# Norms


def rms_norm(x, weight, *, eps: float = 1e-6):
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    out = x * jax.lax.rsqrt(var + eps)
    return (out * (1.0 + weight.astype(jnp.float32))).astype(dtype)


def layer_norm(x, weight, bias, *, eps: float = 1e-5):
    dtype = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    out = (x - mu) * jax.lax.rsqrt(var + eps)
    return (out * weight.astype(jnp.float32) + bias.astype(jnp.float32)).astype(
        dtype
    )


def apply_norm(x, p: dict, kind: str):
    if kind == "rmsnorm":
        return rms_norm(x, p["scale"])
    if kind == "layernorm":
        return layer_norm(x, p["scale"], p["bias"])
    raise ValueError(kind)


def init_norm(d: int, kind: str, dtype=jnp.float32) -> dict:
    if kind == "rmsnorm":
        return {"scale": jnp.zeros((d,), dtype)}
    if kind == "layernorm":
        return {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)}
    raise ValueError(kind)


# ---------------------------------------------------------------------------
# Linear — supports three weight modes:
#   dense        : w (d_in, d_out)
#   pow2_qat     : dense weights, pow2-projected with STE on the fly
#                  (training toward the constant-specialized deployment)
#   pow2_packed  : w stored as 4-bit codes + per-channel scale (serving);
#                  decoded in-graph (multiplication-free decode; on TPU the
#                  Pallas kernel repro.kernels.pow2_matmul fuses this)


def linear(x, p: dict, *, quant: Optional[str] = None):
    if "codes" in p:  # pow2_packed
        from repro.core.quant.packing import unpack_codes_u4

        codes = unpack_codes_u4(p["codes"])
        # Odd layer widths are packed with a zero pad column; the scale
        # keeps the true width, so slice the decoded codes back to it.
        n = p["scale"].shape[-1]
        w = decode_pow2(codes[..., :n], p["scale"]).astype(x.dtype)
    elif quant == "pow2_qat":
        w = project_pow2_ste(p["w"])
    else:
        w = p["w"]
    out = jnp.einsum("...k,kn->...n", x, w)
    if "b" in p:
        out = out + p["b"]
    return out


def init_linear(key, d_in: int, d_out: int, *, bias: bool = False, dtype=jnp.float32):
    p = {"w": xavier_init(key, (d_in, d_out), dtype)}
    if bias:
        p["b"] = jnp.zeros((d_out,), dtype)
    return p


def pack_linear_pow2(p: dict) -> dict:
    """Convert a dense linear param dict to packed pow2 serving format.

    Odd output widths are packed with a zero pad column (zero codes decode
    to 0.0); the stored scale keeps the true width so ``linear`` can slice
    the decoded weights back.

    Stacked (scan-layer) weights of shape ``(*lead, K, N)`` are packed
    per layer via ``vmap`` so every layer keeps its own per-channel
    scales; the stored scale then has shape ``(*lead, 1, N)`` so a
    scanned per-layer slice broadcasts as ``(1, N)``.
    """
    from repro.core.quant.packing import pack_codes_u4
    from repro.core.quant.pow2 import pow2_codes

    w = p["w"]
    n = w.shape[-1]
    if n % 2:
        w = jnp.pad(w, [(0, 0)] * (w.ndim - 1) + [(0, 1)])
    if w.ndim == 2:
        codes, scale = pow2_codes(w, channel_axis=1)
        out = {"codes": pack_codes_u4(codes), "scale": scale.reshape(-1)[:n]}
    else:
        lead = w.shape[:-2]
        w2 = w.reshape((-1,) + w.shape[-2:])
        codes, scale = jax.vmap(lambda wi: pow2_codes(wi, channel_axis=1))(w2)
        out = {
            "codes": pack_codes_u4(codes).reshape(
                lead + (w.shape[-2], w.shape[-1] // 2)
            ),
            "scale": scale[..., :n].reshape(lead + (1, n)),
        }
    if "b" in p:
        out["b"] = p["b"]
    return out


def pack_params_pow2(params):
    """Walk a param pytree and pack every linear (any dict with a >= 2D
    ``w``) to the pow2 serving format — the whole-stack constant
    specialization the paper's tactic becomes at serving time."""
    if isinstance(params, dict):
        if "w" in params and getattr(params["w"], "ndim", 0) >= 2:
            return pack_linear_pow2(params)
        return {k: pack_params_pow2(v) for k, v in params.items()}
    if isinstance(params, list):
        return [pack_params_pow2(v) for v in params]
    return params


# ---------------------------------------------------------------------------
# Rotary position embeddings


def rope_frequencies(head_dim: int, theta: float = 10_000.0):
    return 1.0 / (
        theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim)
    )


def apply_rope(x, positions, *, theta: float = 10_000.0):
    """x: (..., S, H, Dh); positions: broadcastable to (..., S)."""
    dh = x.shape[-1]
    freqs = rope_frequencies(dh, theta)  # (Dh/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, Dh/2)
    cos = jnp.cos(angles)[..., None, :]  # (..., S, 1, Dh/2)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Gated MLPs


def mlp(x, p: dict, *, act: str = "silu", quant=None):
    """SwiGLU/GeGLU/plain-GELU feed-forward."""
    if act in ("silu", "gelu_glu"):
        gate = linear(x, p["gate"], quant=quant)
        up = linear(x, p["up"], quant=quant)
        g = jax.nn.silu(gate) if act == "silu" else jax.nn.gelu(gate)
        return linear(g * up, p["down"], quant=quant)
    if act == "gelu":  # plain 2-layer (whisper)
        h = jax.nn.gelu(linear(x, p["up"], quant=quant))
        return linear(h, p["down"], quant=quant)
    raise ValueError(act)


def init_mlp(key, d: int, d_ff: int, *, act: str = "silu", bias: bool = False,
             dtype=jnp.float32):
    k1, k2, k3 = jax.random.split(key, 3)
    if act in ("silu", "gelu_glu"):
        return {
            "gate": init_linear(k1, d, d_ff, bias=bias, dtype=dtype),
            "up": init_linear(k2, d, d_ff, bias=bias, dtype=dtype),
            "down": init_linear(k3, d_ff, d, bias=bias, dtype=dtype),
        }
    if act == "gelu":
        return {
            "up": init_linear(k1, d, d_ff, bias=bias, dtype=dtype),
            "down": init_linear(k2, d_ff, d, bias=bias, dtype=dtype),
        }
    raise ValueError(act)
