"""Mixture-of-Experts FFN: token-choice top-k routing with per-group
capacity (GShard/Switch semantics), gather-based dispatch.

Dispatch avoids the classic (tokens, experts, capacity) one-hot einsum —
whose memory blows up at production token counts — and instead builds an
(E, C) token-index table per routing group with a cumsum + scatter, then
gathers. Groups are the batch rows (each sequence routes independently),
so no cross-shard cumsum is needed: the same group-local trick GShard uses.

Experts are sharded over the ``model`` ("expert-parallel") mesh axis by the
launch layer; the (B, E, C, d) dispatch tensors shard over both batch and
expert axes.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models.layers import xavier_init


@dataclasses.dataclass(frozen=True)
class MoESpec:
    d_model: int
    n_experts: int
    top_k: int
    d_ff: int  # per-expert hidden dim
    capacity_factor: float = 1.25
    shared_d_ff: int = 0  # optional always-on shared expert (llama4)
    norm_topk: bool = True  # renormalize top-k gate weights (qwen3)

    def capacity(self, tokens_per_group: int) -> int:
        c = int(tokens_per_group * self.top_k * self.capacity_factor
                / self.n_experts)
        return max(self.top_k, min(c, tokens_per_group))


def init_moe(key, spec: MoESpec, dtype=jnp.float32) -> dict:
    ks = jax.random.split(key, 7)
    e, d, f = spec.n_experts, spec.d_model, spec.d_ff
    p = {
        "router": xavier_init(ks[0], (d, e), jnp.float32),
        "w_gate": xavier_init(ks[1], (e, d, f), dtype),
        "w_up": xavier_init(ks[2], (e, d, f), dtype),
        "w_down": xavier_init(ks[3], (e, f, d), dtype),
    }
    if spec.shared_d_ff:
        p["shared"] = {
            "gate": {"w": xavier_init(ks[4], (d, spec.shared_d_ff), dtype)},
            "up": {"w": xavier_init(ks[5], (d, spec.shared_d_ff), dtype)},
            "down": {"w": xavier_init(ks[6], (spec.shared_d_ff, d), dtype)},
        }
    return p


def _route_group(x, p, spec: MoESpec):
    """Route one group. x: (T, d). Returns (y (T, d), aux_loss scalar)."""
    t, d = x.shape
    e, k = spec.n_experts, spec.top_k
    c = spec.capacity(t)

    logits = (x.astype(jnp.float32) @ p["router"]).astype(jnp.float32)  # (T,E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_ids = jax.lax.top_k(probs, k)  # (T, k)
    if spec.norm_topk:
        gate_vals = gate_vals / jnp.sum(gate_vals, axis=-1, keepdims=True)

    # Position of each (token, slot) within its expert, in token order.
    onehot = jax.nn.one_hot(expert_ids, e, dtype=jnp.int32)  # (T, k, E)
    flat = onehot.reshape(t * k, e)
    pos = jnp.cumsum(flat, axis=0) - 1  # (T*k, E)
    pos_in_e = jnp.sum(pos * flat, axis=-1)  # (T*k,)
    flat_e = expert_ids.reshape(t * k)
    keep = pos_in_e < c

    # (E, C) token-index table; -1 = empty slot.
    dest = flat_e * c + jnp.where(keep, pos_in_e, 0)
    token_idx = jnp.repeat(jnp.arange(t), k)
    table = jnp.full((e * c,), -1, jnp.int32)
    table = table.at[dest].set(jnp.where(keep, token_idx, -1), mode="drop")
    table = table.reshape(e, c)
    slot_ok = table >= 0

    gathered = jnp.where(
        slot_ok[..., None], x[jnp.maximum(table, 0)], 0.0
    )  # (E, C, d)

    # Expert FFN (SwiGLU), batched over experts.
    h_g = jnp.einsum("ecd,edf->ecf", gathered, p["w_gate"])
    h_u = jnp.einsum("ecd,edf->ecf", gathered, p["w_up"])
    h = jax.nn.silu(h_g) * h_u
    y_e = jnp.einsum("ecf,efd->ecd", h, p["w_down"])  # (E, C, d)

    # Combine: scatter each slot's output back, weighted by its gate value.
    slot_gate = jnp.zeros((e * c,), jnp.float32)
    slot_gate = slot_gate.at[dest].set(
        jnp.where(keep, gate_vals.reshape(t * k), 0.0), mode="drop"
    )
    y_flat = (y_e.reshape(e * c, d).astype(jnp.float32)
              * slot_gate[:, None])
    out = jnp.zeros((t, d), jnp.float32)
    out = out.at[jnp.maximum(table.reshape(-1), 0)].add(
        jnp.where(slot_ok.reshape(-1)[:, None], y_flat, 0.0), mode="drop"
    )

    # Switch load-balancing aux loss: E * sum_e f_e * P_e.
    f_e = jnp.mean(
        jnp.sum(jax.nn.one_hot(expert_ids, e, dtype=jnp.float32), axis=1),
        axis=0,
    )  # fraction routed per expert (x k)
    p_e = jnp.mean(probs, axis=0)
    aux = e * jnp.sum((f_e / k) * p_e)
    return out.astype(x.dtype), aux


def moe_apply(p: dict, spec: MoESpec, x: jax.Array):
    """x: (B, S, d) -> (y (B, S, d), aux_loss scalar). Routing groups are
    the batch rows."""
    y, aux = jax.vmap(lambda xs: _route_group(xs, p, spec))(x)
    if spec.shared_d_ff:
        from repro.models.layers import mlp

        y = y + mlp(x, p["shared"], act="silu")
    return y, jnp.mean(aux)
