"""State-space / linear-recurrence mixers: Mamba-1 (falcon-mamba) and
RG-LRU (recurrentgemma / Griffin).

Both are diagonal linear recurrences  h_t = a_t * h_{t-1} + b_t  computed
with a **chunked associative scan**: ``associative_scan`` inside fixed-size
chunks (parallel, TPU-friendly) and a ``lax.scan`` carrying the boundary
state across chunks — so the full (B, S, d_inner, N) state tensor never
materializes, only (B, chunk, d_inner, N) per step. Decode is the O(1)
single-step recurrence with carried state.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models.layers import init_linear, linear


# ---------------------------------------------------------------------------
# Chunked diagonal linear recurrence


def _combine(left, right):
    a1, b1 = left
    a2, b2 = right
    return a1 * a2, a2 * b1 + b2


def chunked_linear_recurrence(a, b, h0, *, chunk: int = 256):
    """h_t = a_t * h_{t-1} + b_t along axis 1.

    a, b: (B, S, ...); h0: (B, ...). Returns (all h (B, S, ...), final h).
    """
    bsz, s = a.shape[0], a.shape[1]
    c = min(chunk, s)
    pad = (-s) % c
    if pad:
        a = jnp.pad(a, [(0, 0), (0, pad)] + [(0, 0)] * (a.ndim - 2),
                    constant_values=1.0)
        b = jnp.pad(b, [(0, 0), (0, pad)] + [(0, 0)] * (b.ndim - 2))
    n_chunks = a.shape[1] // c
    a_c = a.reshape((bsz, n_chunks, c) + a.shape[2:]).swapaxes(0, 1)
    b_c = b.reshape((bsz, n_chunks, c) + b.shape[2:]).swapaxes(0, 1)

    def step(h, inp):
        a_blk, b_blk = inp  # (B, c, ...)
        # Fold carry into the first element: b'_0 = a_0 * h + b_0.
        b_blk = b_blk.at[:, 0].add(a_blk[:, 0] * h)
        cum_a, cum_b = jax.lax.associative_scan(_combine, (a_blk, b_blk), axis=1)
        return cum_b[:, -1], cum_b

    h_final, h_all = jax.lax.scan(step, h0, (a_c, b_c))
    h_all = h_all.swapaxes(0, 1).reshape((bsz, n_chunks * c) + a.shape[2:])
    return h_all[:, :s], h_final


# ---------------------------------------------------------------------------
# Causal depthwise conv1d (both mixers use a short 'smear' conv)


def causal_conv1d(x, w, *, state: Optional[jax.Array] = None):
    """x: (B, S, D); w: (K, D) depthwise causal. Optional carried state
    (B, K-1, D) for decode. Returns (y, new_state)."""
    k = w.shape[0]
    if state is None:
        x_pad = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    else:
        x_pad = jnp.concatenate([state, x], axis=1)
    y = sum(
        x_pad[:, i : i + x.shape[1]] * w[i][None, None, :] for i in range(k)
    )
    new_state = x_pad[:, -(k - 1):] if k > 1 else jnp.zeros_like(x[:, :0])
    return y, new_state


# ---------------------------------------------------------------------------
# Mamba-1 (selective SSM)


@dataclasses.dataclass(frozen=True)
class MambaSpec:
    d_model: int
    d_inner: int
    d_state: int = 16
    d_conv: int = 4
    dt_rank: int = 0  # 0 -> ceil(d_model / 16)

    @property
    def dtr(self) -> int:
        return self.dt_rank or -(-self.d_model // 16)


def init_mamba(key, spec: MambaSpec, dtype=jnp.float32) -> dict:
    ks = jax.random.split(key, 6)
    di, n, dtr = spec.d_inner, spec.d_state, spec.dtr
    # S4D-real init for A.
    a_log = jnp.log(jnp.tile(jnp.arange(1, n + 1, dtype=jnp.float32), (di, 1)))
    return {
        "in_proj": init_linear(ks[0], spec.d_model, 2 * di, dtype=dtype),
        "conv_w": (jax.random.normal(ks[1], (spec.d_conv, di)) * 0.2).astype(dtype),
        "conv_b": jnp.zeros((di,), dtype),
        "x_proj": init_linear(ks[2], di, dtr + 2 * n, dtype=dtype),
        "dt_proj": init_linear(ks[3], dtr, di, bias=True, dtype=dtype),
        "a_log": a_log.astype(jnp.float32),  # kept f32 (exp-sensitive)
        "d_skip": jnp.ones((di,), jnp.float32),
        "out_proj": init_linear(ks[4], di, spec.d_model, dtype=dtype),
    }


def mamba_apply(
    p: dict,
    spec: MambaSpec,
    x: jax.Array,  # (B, S, d_model)
    *,
    ssm_state: Optional[jax.Array] = None,  # (B, d_inner, N) decode carry
    conv_state: Optional[jax.Array] = None,  # (B, d_conv-1, d_inner)
    chunk: int = 256,
    state_dtype=jnp.float32,
):
    """Returns (y (B, S, d_model), new_ssm_state, new_conv_state).

    ``state_dtype=bfloat16`` halves the recurrence HBM traffic (the
    (B,S,d_inner,N) discretized tensors dominate the layer's bytes); the
    clean TPU solution is the fused Pallas scan (kernels/ssm_scan) which
    keeps f32 states VMEM-resident with bf16 HBM I/O — the XLA-level bf16
    mode mirrors that kernel's memory behaviour for the dry-run."""
    di, n, dtr = spec.d_inner, spec.d_state, spec.dtr
    xz = linear(x, p["in_proj"])
    xin, z = jnp.split(xz, 2, axis=-1)  # (B, S, di) each
    xin, new_conv = causal_conv1d(xin, p["conv_w"], state=conv_state)
    xin = jax.nn.silu(xin + p["conv_b"])

    proj = linear(xin, p["x_proj"])  # (B, S, dtr + 2N)
    dt_in, b_in, c_in = jnp.split(proj, [dtr, dtr + n], axis=-1)
    dt = jax.nn.softplus(linear(dt_in, p["dt_proj"]).astype(jnp.float32))
    a = -jnp.exp(p["a_log"])  # (di, N)

    # Discretize: a_t = exp(dt * A) (B,S,di,N); b_t = dt * x * B_t.
    dta = jnp.exp(dt[..., None] * a[None, None]).astype(state_dtype)
    bx = (
        (dt * xin.astype(jnp.float32))[..., None]
        * b_in.astype(jnp.float32)[:, :, None, :]
    ).astype(state_dtype)  # (B,S,di,N)
    h0 = (
        ssm_state.astype(state_dtype)
        if ssm_state is not None
        else jnp.zeros((x.shape[0], di, n), state_dtype)
    )
    h_all, h_last = chunked_linear_recurrence(dta, bx, h0, chunk=chunk)
    h_last = h_last.astype(jnp.float32)
    y = jnp.einsum(
        "bsdn,bsn->bsd", h_all, c_in.astype(state_dtype),
        preferred_element_type=jnp.float32,
    )
    y = y + xin.astype(jnp.float32) * p["d_skip"]
    y = y * jax.nn.silu(z.astype(jnp.float32))
    return linear(y.astype(x.dtype), p["out_proj"]), h_last, new_conv


# ---------------------------------------------------------------------------
# RG-LRU (Griffin / recurrentgemma recurrent block)


@dataclasses.dataclass(frozen=True)
class RGLRUSpec:
    d_model: int
    lru_width: int
    d_conv: int = 4
    c: float = 8.0  # recurrence sharpness constant


def init_rglru(key, spec: RGLRUSpec, dtype=jnp.float32) -> dict:
    ks = jax.random.split(key, 6)
    w = spec.lru_width
    # Lambda init so a^c in [0.9, 0.999] (Griffin appendix).
    u = jax.random.uniform(ks[0], (w,), minval=0.9, maxval=0.999)
    lam = jnp.log(jnp.expm1(-jnp.log(u) / spec.c))  # softplus^-1
    return {
        "in_x": init_linear(ks[1], spec.d_model, w, dtype=dtype),
        "in_gate": init_linear(ks[2], spec.d_model, w, dtype=dtype),
        "conv_w": (jax.random.normal(ks[3], (spec.d_conv, w)) * 0.2).astype(dtype),
        "w_r": init_linear(ks[4], w, w, dtype=dtype),
        "w_i": init_linear(ks[5], w, w, dtype=dtype),
        "lam": lam.astype(jnp.float32),
        "out": init_linear(jax.random.fold_in(key, 7), w, spec.d_model, dtype=dtype),
    }


def rglru_apply(
    p: dict,
    spec: RGLRUSpec,
    x: jax.Array,  # (B, S, d_model)
    *,
    h_state: Optional[jax.Array] = None,  # (B, lru_width)
    conv_state: Optional[jax.Array] = None,
    chunk: int = 256,
):
    """Griffin recurrent block: gate branch (GeLU) ⊙ (conv → RG-LRU).
    Returns (y, new_h_state, new_conv_state)."""
    gate = jax.nn.gelu(linear(x, p["in_gate"]))
    u, new_conv = causal_conv1d(linear(x, p["in_x"]), p["conv_w"], state=conv_state)

    r = jax.nn.sigmoid(linear(u, p["w_r"]).astype(jnp.float32))
    i = jax.nn.sigmoid(linear(u, p["w_i"]).astype(jnp.float32))
    log_a = -spec.c * jax.nn.softplus(p["lam"]) * r  # (B,S,W)
    a = jnp.exp(log_a)
    gated_x = i * u.astype(jnp.float32)
    b = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12)) * gated_x
    h0 = (
        h_state
        if h_state is not None
        else jnp.zeros((x.shape[0], spec.lru_width), jnp.float32)
    )
    h_all, h_last = chunked_linear_recurrence(a, b, h0, chunk=chunk)
    y = (h_all.astype(x.dtype)) * gate
    return linear(y, p["out"]), h_last, new_conv
