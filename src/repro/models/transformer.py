"""Transformer assembly: heterogeneous block stacks (attention / Mamba /
RG-LRU / MoE), scan-over-layers, remat, chunked-vocab cross-entropy,
prefill and single-token decode.

Layer stacking
--------------
``block_pattern`` (e.g. ``("rglru", "rglru", "attn")``) repeats down the
stack. Layers are scanned over *pattern units*: parameters for pattern
position i are stacked across units into one leaf with a leading
``n_units`` dim, so the compiled HLO contains ONE copy of the unit body
regardless of depth (command-r's 40 layers and granite's 88 compile the
same size). ``n_layers mod len(pattern)`` tail layers run unscanned.

Decode caches mirror the parameter tree (stacked per pattern position):
attention layers hold ring-buffer KV caches sized to their visibility
window (full causal -> max_len; local -> window; chunked -> chunk), SSM
layers hold O(1) recurrent + conv states — this is what makes the
long_500k decode shape representable for ssm/hybrid/chunked families.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import layers as L
from repro.models.attention import (
    AttnSpec,
    blockwise_attention,
    decode_attention,
)
from repro.models.moe import MoESpec, init_moe, moe_apply
from repro.models.ssm import (
    MambaSpec,
    RGLRUSpec,
    causal_conv1d,
    init_mamba,
    init_rglru,
    mamba_apply,
    rglru_apply,
)

# ---------------------------------------------------------------------------
# Config-derived specs


def _dtype(cfg: ArchConfig):
    return jnp.dtype(cfg.dtype)


def _attn_spec(cfg: ArchConfig, kind: str) -> AttnSpec:
    if kind == "local":
        return AttnSpec("local", window=cfg.window)
    if kind == "chunked":
        return AttnSpec("chunked", chunk=cfg.chunk)
    return AttnSpec(kind)


def _mamba_spec(cfg: ArchConfig) -> MambaSpec:
    return MambaSpec(
        d_model=cfg.d_model,
        d_inner=cfg.ssm_expand * cfg.d_model,
        d_state=cfg.ssm_state,
        d_conv=cfg.ssm_conv,
    )


def _rglru_spec(cfg: ArchConfig) -> RGLRUSpec:
    return RGLRUSpec(d_model=cfg.d_model, lru_width=cfg.lru_width,
                     d_conv=cfg.ssm_conv)


def _moe_spec(cfg: ArchConfig) -> MoESpec:
    return MoESpec(
        d_model=cfg.d_model,
        n_experts=cfg.n_experts,
        top_k=cfg.top_k,
        d_ff=cfg.moe_d_ff,
        capacity_factor=cfg.capacity_factor,
        shared_d_ff=cfg.shared_expert_d_ff,
    )


def _use_rope(cfg: ArchConfig, attn_kind: str) -> bool:
    if cfg.pos_embedding != "rope":
        return False
    # llama4 iRoPE: the periodic full-attention layers carry no positional
    # encoding (NoPE); only chunked layers are rotary.
    if cfg.chunk > 0 and attn_kind == "causal":
        return False
    return True


# ---------------------------------------------------------------------------
# Block init


def _init_attn(key, cfg: ArchConfig, *, cross: bool = False) -> dict:
    ks = jax.random.split(key, 4)
    d, hd = cfg.d_model, cfg.hd
    nq, nkv = cfg.n_heads, (cfg.n_heads if cross else cfg.n_kv_heads)
    if cross:
        nkv = cfg.n_kv_heads
    dt = _dtype(cfg)
    p = {
        "wq": L.init_linear(ks[0], d, nq * hd, bias=cfg.qkv_bias, dtype=dt),
        "wk": L.init_linear(ks[1], d, nkv * hd, bias=cfg.qkv_bias, dtype=dt),
        "wv": L.init_linear(ks[2], d, nkv * hd, bias=cfg.qkv_bias, dtype=dt),
        "wo": L.init_linear(ks[3], nq * hd, d, dtype=dt),
    }
    if cfg.qk_norm:
        p["q_norm"] = {"scale": jnp.zeros((hd,), dt)}
        p["k_norm"] = {"scale": jnp.zeros((hd,), dt)}
    return p


def _init_ffn(key, cfg: ArchConfig) -> dict:
    if cfg.n_experts:
        return init_moe(key, _moe_spec(cfg), dtype=_dtype(cfg))
    return L.init_mlp(key, cfg.d_model, cfg.d_ff, act=cfg.mlp_act,
                      dtype=_dtype(cfg))


def init_block(key, cfg: ArchConfig, kind: str, *, cross_attn: bool = False) -> dict:
    ks = jax.random.split(key, 6)
    dt = _dtype(cfg)
    d = cfg.d_model
    if kind == "attn":
        if cfg.parallel_block:
            p = {
                "ln": L.init_norm(d, cfg.norm, dt),
                "attn": _init_attn(ks[0], cfg),
                "ffn": _init_ffn(ks[1], cfg),
            }
        else:
            p = {
                "ln1": L.init_norm(d, cfg.norm, dt),
                "attn": _init_attn(ks[0], cfg),
                "ln2": L.init_norm(d, cfg.norm, dt),
                "ffn": _init_ffn(ks[1], cfg),
            }
        if cross_attn:
            p["ln_x"] = L.init_norm(d, cfg.norm, dt)
            p["xattn"] = _init_attn(ks[2], cfg, cross=True)
        return p
    if kind == "mamba":
        return {
            "ln1": L.init_norm(d, cfg.norm, dt),
            "mamba": init_mamba(ks[0], _mamba_spec(cfg), dtype=dt),
        }
    if kind == "rglru":
        return {
            "ln1": L.init_norm(d, cfg.norm, dt),
            "rglru": init_rglru(ks[0], _rglru_spec(cfg), dtype=dt),
            "ln2": L.init_norm(d, cfg.norm, dt),
            "ffn": _init_ffn(ks[1], cfg),
        }
    raise ValueError(kind)


# ---------------------------------------------------------------------------
# Block caches (decode)


def init_block_cache(cfg: ArchConfig, kind: str, attn_kind: str, batch: int,
                     max_len: int):
    dt = _dtype(cfg)
    if kind == "attn":
        if attn_kind == "local":
            s = min(max_len, cfg.window)
        elif attn_kind == "chunked":
            s = min(max_len, cfg.chunk)
        else:
            s = max_len
        cache = {
            "k": jnp.zeros((batch, s, cfg.n_kv_heads, cfg.hd), dt),
            "v": jnp.zeros((batch, s, cfg.n_kv_heads, cfg.hd), dt),
        }
        if cfg.n_encoder_layers:  # cross-attention KV (filled at prefill)
            cache["xk"] = jnp.zeros(
                (batch, cfg.encoder_seq, cfg.n_kv_heads, cfg.hd), dt
            )
            cache["xv"] = jnp.zeros(
                (batch, cfg.encoder_seq, cfg.n_kv_heads, cfg.hd), dt
            )
        return cache
    if kind == "mamba":
        spec = _mamba_spec(cfg)
        return {
            "ssm": jnp.zeros((batch, spec.d_inner, spec.d_state), jnp.float32),
            "conv": jnp.zeros((batch, spec.d_conv - 1, spec.d_inner), dt),
        }
    if kind == "rglru":
        spec = _rglru_spec(cfg)
        return {
            "h": jnp.zeros((batch, spec.lru_width), jnp.float32),
            "conv": jnp.zeros((batch, spec.d_conv - 1, spec.lru_width), dt),
        }
    raise ValueError(kind)


# ---------------------------------------------------------------------------
# Block apply


def _split_heads(x, n_heads, hd):
    return x.reshape(x.shape[:-1] + (n_heads, hd))


def _attn_apply(
    p: dict,
    cfg: ArchConfig,
    x: jax.Array,  # (B, S, d)
    attn_kind: str,
    positions,  # (S,) or (B, S)
    *,
    cache: Optional[dict] = None,
    decode_index=None,
    is_cross: bool = False,
    kv_source: Optional[jax.Array] = None,  # cross-attention source
    cache_keys=("k", "v"),
):
    b, s, _ = x.shape
    hkv, g, hd = cfg.n_kv_heads, cfg.q_groups, cfg.hd
    spec = _attn_spec(cfg, attn_kind)
    q = _split_heads(L.linear(x, p["wq"]), cfg.n_heads, hd)
    if cfg.qk_norm:
        q = L.rms_norm(q, p["q_norm"]["scale"])
    use_rope = _use_rope(cfg, attn_kind) and not is_cross

    if is_cross:
        # Cross attention: KV from encoder states. At prefill they are
        # computed from ``kv_source`` and written into the cache; at decode
        # they are read back (encoder states are static across steps).
        new_cache = cache
        if decode_index is not None:
            k, v = cache[cache_keys[0]], cache[cache_keys[1]]
        else:
            k = _split_heads(L.linear(kv_source, p["wk"]), hkv, hd)
            v = _split_heads(L.linear(kv_source, p["wv"]), hkv, hd)
            if cache is not None:
                new_cache = dict(cache)
                new_cache[cache_keys[0]] = k
                new_cache[cache_keys[1]] = v
        q5 = q.reshape(b, s, hkv, g, hd)
        out = blockwise_attention(q5, k, v, AttnSpec("full"))
    else:
        k = _split_heads(L.linear(x, p["wk"]), hkv, hd)
        v = _split_heads(L.linear(x, p["wv"]), hkv, hd)
        if cfg.qk_norm:
            k = L.rms_norm(k, p["k_norm"]["scale"])
        if use_rope:
            q = L.apply_rope(q, positions, theta=cfg.rope_theta)
            k = L.apply_rope(k, positions, theta=cfg.rope_theta)
        q5 = q.reshape(b, s, hkv, g, hd)
        exact_f32 = not cfg.opt_no_f32_cast_attn
        if decode_index is not None:
            # Single-token decode against the ring-buffer cache.
            s_cache = cache["k"].shape[1]
            slot = jnp.mod(decode_index, s_cache)
            new_cache = dict(cache)
            new_cache["k"] = jax.lax.dynamic_update_slice_in_dim(
                cache["k"], k, slot, axis=1
            )
            new_cache["v"] = jax.lax.dynamic_update_slice_in_dim(
                cache["v"], v, slot, axis=1
            )
            out = decode_attention(
                q5, new_cache["k"], new_cache["v"], decode_index, spec,
                exact_f32=exact_f32,
            )
        else:
            out = blockwise_attention(
                q5, k, v, spec, exact_f32=exact_f32,
                pin_batch=cfg.opt_shard_attn_batch,
            )
            new_cache = cache
            if cache is not None:
                # Prefill: write the (windowed) KV tail into the cache.
                s_cache = cache["k"].shape[1]
                take = min(s, s_cache)
                new_cache = dict(cache)
                new_cache["k"] = jax.lax.dynamic_update_slice_in_dim(
                    cache["k"], k[:, -take:], 0, axis=1
                )
                new_cache["v"] = jax.lax.dynamic_update_slice_in_dim(
                    cache["v"], v[:, -take:], 0, axis=1
                )
    out = out.reshape(b, s, cfg.n_heads * hd)
    return L.linear(out, p["wo"]), new_cache


def _ffn_apply(p: dict, cfg: ArchConfig, x: jax.Array):
    if cfg.n_experts:
        return moe_apply(p, _moe_spec(cfg), x)
    return L.mlp(x, p, act=cfg.mlp_act), jnp.zeros((), jnp.float32)


def apply_block(
    p: dict,
    cfg: ArchConfig,
    kind: str,
    attn_kind: str,
    x: jax.Array,
    positions,
    *,
    cache: Optional[dict] = None,
    decode_index=None,
    encoder_out: Optional[jax.Array] = None,
):
    """Returns (x, new_cache, aux_loss)."""
    aux = jnp.zeros((), jnp.float32)
    if kind == "attn":
        if cfg.parallel_block:
            h = L.apply_norm(x, p["ln"], cfg.norm)
            a, new_cache = _attn_apply(
                p["attn"], cfg, h, attn_kind, positions,
                cache=cache, decode_index=decode_index,
            )
            f, aux = _ffn_apply(p["ffn"], cfg, h)
            return x + a + f, new_cache, aux
        h = L.apply_norm(x, p["ln1"], cfg.norm)
        a, new_cache = _attn_apply(
            p["attn"], cfg, h, attn_kind, positions,
            cache=cache, decode_index=decode_index,
        )
        x = x + a
        if "xattn" in p:
            hx = L.apply_norm(x, p["ln_x"], cfg.norm)
            ax, new_cache2 = _attn_apply(
                p["xattn"], cfg, hx, "full", positions,
                cache=new_cache, decode_index=decode_index,
                is_cross=True, kv_source=encoder_out, cache_keys=("xk", "xv"),
            )
            new_cache = new_cache2 if new_cache2 is not None else new_cache
            x = x + ax
        h2 = L.apply_norm(x, p["ln2"], cfg.norm)
        f, aux = _ffn_apply(p["ffn"], cfg, h2)
        return x + f, new_cache, aux
    if kind == "mamba":
        h = L.apply_norm(x, p["ln1"], cfg.norm)
        sdt = jnp.bfloat16 if cfg.opt_bf16_ssm else jnp.float32
        if decode_index is not None or cache is not None:
            y, ssm_new, conv_new = mamba_apply(
                p["mamba"], _mamba_spec(cfg), h,
                ssm_state=cache["ssm"], conv_state=cache["conv"],
                state_dtype=sdt,
            )
            new_cache = {"ssm": ssm_new, "conv": conv_new}
        else:
            y, _, _ = mamba_apply(p["mamba"], _mamba_spec(cfg), h,
                                  state_dtype=sdt)
            new_cache = None
        return x + y, new_cache, aux
    if kind == "rglru":
        h = L.apply_norm(x, p["ln1"], cfg.norm)
        if decode_index is not None or cache is not None:
            y, h_new, conv_new = rglru_apply(
                p["rglru"], _rglru_spec(cfg), h,
                h_state=cache["h"], conv_state=cache["conv"],
            )
            new_cache = {"h": h_new, "conv": conv_new}
        else:
            y, _, _ = rglru_apply(p["rglru"], _rglru_spec(cfg), h)
            new_cache = None
        x = x + y
        h2 = L.apply_norm(x, p["ln2"], cfg.norm)
        f, aux = _ffn_apply(p["ffn"], cfg, h2)
        return x + f, new_cache, aux
    raise ValueError(kind)


# ---------------------------------------------------------------------------
# Stack = scanned pattern units + tail


def _pattern_layout(cfg: ArchConfig, n_layers: int):
    pat = cfg.block_pattern
    n_units = n_layers // len(pat) if cfg.scan_layers else 0
    tail = n_layers - n_units * len(pat)
    return pat, n_units, tail


def init_stack(key, cfg: ArchConfig, n_layers: int, *, cross_attn=False) -> dict:
    pat, n_units, tail = _pattern_layout(cfg, n_layers)
    params: dict = {"units": None, "tail": []}
    if n_units:
        per_pos = []
        for i, kind in enumerate(pat):
            stacked = [
                init_block(jax.random.fold_in(key, u * len(pat) + i), cfg, kind,
                           cross_attn=cross_attn)
                for u in range(n_units)
            ]
            per_pos.append(
                jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *stacked)
                if n_units > 1
                else jax.tree_util.tree_map(lambda x: x[None], stacked[0])
            )
        params["units"] = per_pos
    for t in range(tail):
        kind = pat[t % len(pat)]
        params["tail"].append(
            init_block(jax.random.fold_in(key, 10_000 + t), cfg, kind,
                       cross_attn=cross_attn)
        )
    return params


def init_stack_cache(cfg: ArchConfig, n_layers: int, batch: int, max_len: int):
    pat, n_units, tail = _pattern_layout(cfg, n_layers)
    cache: dict = {"units": None, "tail": []}
    if n_units:
        per_pos = []
        for i, kind in enumerate(pat):
            one = init_block_cache(
                cfg, kind, cfg.attn_kind_for_layer(i), batch, max_len
            )
            per_pos.append(
                jax.tree_util.tree_map(
                    lambda x: jnp.broadcast_to(x[None], (n_units,) + x.shape), one
                )
            )
        cache["units"] = per_pos
    for t in range(tail):
        kind = pat[t % len(pat)]
        cache["tail"].append(
            init_block_cache(cfg, kind, cfg.attn_kind_for_layer(t), batch, max_len)
        )
    return cache


def _remat_wrap(cfg: ArchConfig, fn):
    if cfg.remat == "none":
        return fn
    policy = (
        jax.checkpoint_policies.dots_with_no_batch_dims_saveable
        if cfg.remat == "dots"
        else None  # None = save nothing (full remat)
    )
    return jax.checkpoint(fn, policy=policy)


def apply_stack(
    params: dict,
    cfg: ArchConfig,
    x: jax.Array,
    positions,
    *,
    cache: Optional[dict] = None,
    decode_index=None,
    encoder_out: Optional[jax.Array] = None,
):
    """Run the full stack. Returns (x, new_cache, total_aux)."""
    pat = cfg.block_pattern
    total_aux = jnp.zeros((), jnp.float32)
    new_cache: dict = {"units": None, "tail": []}

    if params["units"] is not None:

        def unit_body(carry, unit_in):
            h, aux = carry
            unit_params, unit_cache = unit_in
            out_caches = []
            for i, kind in enumerate(pat):
                c_i = unit_cache[i] if unit_cache is not None else None
                h, c_new, a = apply_block(
                    unit_params[i], cfg, kind, cfg.attn_kind_for_layer(i), h,
                    positions, cache=c_i, decode_index=decode_index,
                    encoder_out=encoder_out,
                )
                out_caches.append(c_new)
                aux = aux + a
            return (h, aux), out_caches

        body = _remat_wrap(cfg, unit_body)
        unit_cache_xs = cache["units"] if cache is not None else None
        (x, total_aux), caches_out = jax.lax.scan(
            body,
            (x, total_aux),
            (params["units"], unit_cache_xs),
        )
        new_cache["units"] = caches_out

    for t, p_t in enumerate(params["tail"]):
        kind = pat[t % len(pat)]
        c_t = cache["tail"][t] if cache is not None else None
        x, c_new, a = apply_block(
            p_t, cfg, kind, cfg.attn_kind_for_layer(t), x, positions,
            cache=c_t, decode_index=decode_index, encoder_out=encoder_out,
        )
        new_cache["tail"].append(c_new)
        total_aux = total_aux + a
    return x, (new_cache if cache is not None else None), total_aux


# ---------------------------------------------------------------------------
# Full model


def init_params(key, cfg: ArchConfig) -> dict:
    ks = jax.random.split(key, 6)
    dt = _dtype(cfg)
    params = {
        "embed": L.embed_init(ks[0], (cfg.vocab_size, cfg.d_model), dt),
        "stack": init_stack(ks[1], cfg, cfg.n_layers,
                            cross_attn=cfg.n_encoder_layers > 0),
        "final_norm": L.init_norm(cfg.d_model, cfg.norm, dt),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = L.xavier_init(
            ks[2], (cfg.d_model, cfg.vocab_size), dt
        )
    if cfg.pos_embedding == "learned":
        params["pos_table"] = L.embed_init(
            ks[3], (cfg.max_position, cfg.d_model), dt
        )
    if cfg.n_encoder_layers:
        enc_cfg = dataclasses.replace(
            cfg,
            attn_pattern=("full",),
            n_encoder_layers=0,  # plain self-attention stack
        )
        params["encoder"] = {
            "pos_table": L.embed_init(ks[4], (cfg.encoder_seq, cfg.d_model), dt),
            "stack": init_stack(ks[5], enc_cfg, cfg.n_encoder_layers),
            "norm": L.init_norm(cfg.d_model, cfg.norm, dt),
        }
    return params


def encode(params: dict, cfg: ArchConfig, frames: jax.Array) -> jax.Array:
    """Audio/vision encoder over precomputed frame embeddings (the modality
    frontend is a stub per the assignment; frames: (B, encoder_seq, d))."""
    enc = params["encoder"]
    x = frames + enc["pos_table"][None]
    enc_cfg = dataclasses.replace(cfg, attn_pattern=("full",), n_encoder_layers=0)
    pos = jnp.arange(frames.shape[1])
    x, _, _ = apply_stack(enc["stack"], enc_cfg, x, pos)
    return L.apply_norm(x, enc["norm"], cfg.norm)


def embed_tokens(params, cfg: ArchConfig, tokens, prefix_embeds=None):
    h = jnp.take(params["embed"], tokens, axis=0)
    if prefix_embeds is not None:
        h = jnp.concatenate([prefix_embeds.astype(h.dtype), h], axis=1)
    return h


def forward_hidden(
    params: dict,
    cfg: ArchConfig,
    tokens: jax.Array,  # (B, S_tok)
    *,
    prefix_embeds=None,  # (B, P, d) VLM patch embeddings
    encoder_frames=None,  # (B, encoder_seq, d) enc-dec source
    cache=None,
    decode_index=None,
):
    h = embed_tokens(params, cfg, tokens, prefix_embeds)
    b, s = h.shape[0], h.shape[1]
    if decode_index is not None:
        positions = jnp.broadcast_to(decode_index, (b, 1))
    else:
        positions = jnp.arange(s)
    if cfg.pos_embedding == "learned":
        pos_idx = positions if positions.ndim > 0 else jnp.arange(s)
        h = h + jnp.take(params["pos_table"], pos_idx, axis=0).reshape(
            (b, s, -1) if decode_index is not None else (s, -1)
        )
    encoder_out = None
    if encoder_frames is not None:
        encoder_out = encode(params, cfg, encoder_frames)
    h, new_cache, aux = apply_stack(
        params["stack"], cfg, h, positions,
        cache=cache, decode_index=decode_index, encoder_out=encoder_out,
    )
    h = L.apply_norm(h, params["final_norm"], cfg.norm)
    return h, new_cache, aux


def unembed(params: dict, cfg: ArchConfig, h: jax.Array) -> jax.Array:
    w = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    return jnp.einsum("...d,dv->...v", h, w)


# ---------------------------------------------------------------------------
# Chunked-vocab cross-entropy (256k-vocab logits never materialize)


def chunked_softmax_ce(
    h: jax.Array,  # (B, S, d)
    w_vocab: jax.Array,  # (d, V)
    targets: jax.Array,  # (B, S) int32
    *,
    vocab_chunk: int = 8192,
    remat_chunks: bool = False,
):
    """Mean CE via online logsumexp over vocab chunks.

    ``remat_chunks`` recomputes each logits chunk in the backward pass
    instead of saving it (saves B*S*V*4 bytes of residuals for one extra
    h @ w_c matmul per chunk)."""
    b, s, d = h.shape
    v = w_vocab.shape[1]
    c = min(vocab_chunk, v)
    pad = (-v) % c
    n_chunks = (v + pad) // c
    h32 = h.astype(jnp.float32)

    def step(carry, ci):
        m, lse_l, tgt = carry
        start = ci * c
        w_c = jax.lax.dynamic_slice(w_vocab, (0, start), (d, c)).astype(
            jnp.float32
        )
        logits = jnp.einsum("bsd,dc->bsc", h32, w_c)
        # Mask vocab padding in the final chunk.
        col = start + jnp.arange(c)
        logits = jnp.where((col < v)[None, None, :], logits, -1e30)
        m_new = jnp.maximum(m, jnp.max(logits, axis=-1))
        lse_l = lse_l * jnp.exp(m - m_new) + jnp.sum(
            jnp.exp(logits - m_new[..., None]), axis=-1
        )
        local = jnp.clip(targets - start, 0, c - 1)
        t_log = jnp.take_along_axis(logits, local[..., None], axis=-1)[..., 0]
        in_chunk = jnp.logical_and(targets >= start, targets < start + c)
        tgt = jnp.where(in_chunk, t_log, tgt)
        return (m_new, lse_l, tgt), None

    m0 = jnp.full((b, s), -1e30, jnp.float32)
    l0 = jnp.zeros((b, s), jnp.float32)
    t0 = jnp.zeros((b, s), jnp.float32)
    body = jax.checkpoint(step) if remat_chunks else step
    (m, lse_l, tgt), _ = jax.lax.scan(body, (m0, l0, t0), jnp.arange(n_chunks))
    nll = (m + jnp.log(jnp.maximum(lse_l, 1e-30))) - tgt
    return jnp.mean(nll)


# ---------------------------------------------------------------------------
# Training loss / prefill / decode entry points


def train_loss(
    params: dict,
    cfg: ArchConfig,
    batch: dict,
    *,
    aux_weight: float = 0.01,
    vocab_chunk: int = 8192,
):
    """batch: {'tokens': (B, S+1)} (+ 'prefix_embeds' / 'encoder_frames')."""
    tokens = batch["tokens"]
    inputs, targets = tokens[:, :-1], tokens[:, 1:]
    h, _, aux = forward_hidden(
        params, cfg, inputs,
        prefix_embeds=batch.get("prefix_embeds"),
        encoder_frames=batch.get("encoder_frames"),
    )
    if batch.get("prefix_embeds") is not None:
        h = h[:, batch["prefix_embeds"].shape[1]:]
    w = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    ce = chunked_softmax_ce(
        h, w, targets, vocab_chunk=vocab_chunk,
        remat_chunks=cfg.opt_ce_remat,
    )
    return ce + aux_weight * aux, {"ce": ce, "aux": aux}


def prefill(
    params: dict,
    cfg: ArchConfig,
    tokens: jax.Array,
    *,
    max_len: int,
    prefix_embeds=None,
    encoder_frames=None,
):
    """Process a prompt, returning (last-token logits, primed cache)."""
    b = tokens.shape[0]
    cache = init_stack_cache(cfg, cfg.n_layers, b, max_len)
    h, cache, _ = forward_hidden(
        params, cfg, tokens,
        prefix_embeds=prefix_embeds, encoder_frames=encoder_frames,
        cache=cache,
    )
    logits = unembed(params, cfg, h[:, -1:])[:, 0]
    return logits, cache


def decode_step(
    params: dict,
    cfg: ArchConfig,
    token: jax.Array,  # (B, 1) int32
    cache,
    index,  # scalar int32: absolute position of this token
    *,
    encoder_out=None,
):
    """One serving step: logits for the next token + updated cache."""
    h, new_cache, _ = forward_hidden(
        params, cfg, token, cache=cache, decode_index=index,
    )
    logits = unembed(params, cfg, h[:, 0])
    return logits, new_cache
