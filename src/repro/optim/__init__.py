"""Optimizer substrate (no external deps): AdamW, schedules, clipping,
gradient accumulation and error-feedback gradient compression."""
from repro.optim.adamw import AdamWConfig, adamw_init, adamw_update, OptState
from repro.optim.schedules import (
    cosine_schedule,
    linear_warmup_cosine,
    constant_schedule,
)
from repro.optim.clipping import global_norm, clip_by_global_norm
from repro.optim.compression import (
    compress_grads_int8,
    decompress_grads_int8,
    ErrorFeedbackState,
)

__all__ = [
    "AdamWConfig",
    "adamw_init",
    "adamw_update",
    "OptState",
    "cosine_schedule",
    "linear_warmup_cosine",
    "constant_schedule",
    "global_norm",
    "clip_by_global_norm",
    "compress_grads_int8",
    "decompress_grads_int8",
    "ErrorFeedbackState",
]
