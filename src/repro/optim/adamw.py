"""AdamW, functional, pytree-generic.

State layout mirrors the params pytree (one m/v slot per leaf), kept in
float32 regardless of param dtype (mixed-precision training: bf16 params,
fp32 master copies live in the state when ``keep_master_copy``).
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    keep_master_copy: bool = False  # fp32 master params for bf16 training


class OptState(NamedTuple):
    step: jax.Array  # int32 scalar
    m: Any  # first moment, fp32
    v: Any  # second moment, fp32
    master: Any  # fp32 master params or None


def adamw_init(params: Any, cfg: AdamWConfig) -> OptState:
    zeros = jax.tree_util.tree_map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params
    )
    master = (
        jax.tree_util.tree_map(lambda p: p.astype(jnp.float32), params)
        if cfg.keep_master_copy
        else None
    )
    return OptState(step=jnp.zeros((), jnp.int32), m=zeros, v=jax.tree_util.tree_map(jnp.copy, zeros), master=master)


def adamw_update(
    grads: Any,
    state: OptState,
    params: Any,
    cfg: AdamWConfig,
    lr: jax.Array,
):
    """One AdamW step. Returns (new_params, new_state)."""
    step = state.step + 1
    t = step.astype(jnp.float32)
    c1 = 1.0 - cfg.b1**t
    c2 = 1.0 - cfg.b2**t

    source = state.master if cfg.keep_master_copy else params

    def _leaf(g, m, v, p):
        g32 = g.astype(jnp.float32)
        m_new = cfg.b1 * m + (1 - cfg.b1) * g32
        v_new = cfg.b2 * v + (1 - cfg.b2) * jnp.square(g32)
        update = (m_new / c1) / (jnp.sqrt(v_new / c2) + cfg.eps)
        p32 = p.astype(jnp.float32)
        p_new = p32 - lr * (update + cfg.weight_decay * p32)
        return m_new, v_new, p_new

    flat_g, treedef = jax.tree_util.tree_flatten(grads)
    flat_m = treedef.flatten_up_to(state.m)
    flat_v = treedef.flatten_up_to(state.v)
    flat_p = treedef.flatten_up_to(source)
    outs = [_leaf(g, m, v, p) for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p)]
    new_m = treedef.unflatten([o[0] for o in outs])
    new_v = treedef.unflatten([o[1] for o in outs])
    new_p32 = treedef.unflatten([o[2] for o in outs])

    if cfg.keep_master_copy:
        new_params = jax.tree_util.tree_map(
            lambda p32, p: p32.astype(p.dtype), new_p32, params
        )
        new_state = OptState(step=step, m=new_m, v=new_v, master=new_p32)
    else:
        new_params = jax.tree_util.tree_map(
            lambda p32, p: p32.astype(p.dtype), new_p32, params
        )
        new_state = OptState(step=step, m=new_m, v=new_v, master=None)
    return new_params, new_state
