"""Error-feedback int8 gradient compression for data-parallel all-reduce.

At 1000+ node scale the DP all-reduce of fp32/bf16 gradients can dominate
step time on oversubscribed DCN links between pods. The standard mitigation
(1-bit Adam / EF-SGD family) is: quantize the gradient per-tensor to int8
with a float scale, all-reduce the int8 payload (4x less traffic than fp32),
and accumulate the quantization error locally into the next step's gradient
(error feedback keeps the method convergent).

These helpers are pure functions; the training step wires them around its
``psum`` when ``grad_compression=int8`` is configured. The all-reduce itself
still happens in whatever precision the collective is given — compression
changes the *payload*, which is what the collective-roofline term charges.
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


class ErrorFeedbackState(NamedTuple):
    residual: Any  # pytree like grads, fp32


def ef_init(grads_shape_tree) -> ErrorFeedbackState:
    return ErrorFeedbackState(
        residual=jax.tree_util.tree_map(
            lambda g: jnp.zeros(g.shape, jnp.float32), grads_shape_tree
        )
    )


def compress_grads_int8(grads, ef: ErrorFeedbackState | None = None):
    """Quantize each leaf to (int8 codes, fp32 scale); fold in EF residual.

    Returns (codes_tree, scales_tree, new_ef_state).
    """

    def _leaf(g, r):
        g32 = g.astype(jnp.float32) + (r if r is not None else 0.0)
        scale = jnp.max(jnp.abs(g32)) / 127.0 + 1e-12
        q = jnp.clip(jnp.round(g32 / scale), -127, 127).astype(jnp.int8)
        err = g32 - q.astype(jnp.float32) * scale
        return q, scale, err

    res = ef.residual if ef is not None else jax.tree_util.tree_map(
        lambda _: None, grads, is_leaf=lambda x: x is None
    )
    flat_g, treedef = jax.tree_util.tree_flatten(grads)
    flat_r = treedef.flatten_up_to(res) if ef is not None else [None] * len(flat_g)
    outs = [_leaf(g, r) for g, r in zip(flat_g, flat_r)]
    codes = treedef.unflatten([o[0] for o in outs])
    scales = treedef.unflatten([o[1] for o in outs])
    new_ef = ErrorFeedbackState(residual=treedef.unflatten([o[2] for o in outs]))
    return codes, scales, new_ef


def decompress_grads_int8(codes, scales):
    return jax.tree_util.tree_map(
        lambda q, s: q.astype(jnp.float32) * s, codes, scales
    )
