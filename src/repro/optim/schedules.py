"""Learning-rate schedules as step -> lr callables (jit-traceable)."""
from __future__ import annotations

import jax.numpy as jnp


def constant_schedule(lr: float):
    def f(step):
        return jnp.asarray(lr, jnp.float32)

    return f


def cosine_schedule(peak_lr: float, total_steps: int, final_frac: float = 0.1):
    def f(step):
        t = jnp.clip(step.astype(jnp.float32) / total_steps, 0.0, 1.0)
        cos = 0.5 * (1.0 + jnp.cos(jnp.pi * t))
        return peak_lr * (final_frac + (1 - final_frac) * cos)

    return f


def linear_warmup_cosine(
    peak_lr: float, warmup_steps: int, total_steps: int, final_frac: float = 0.1
):
    cos = cosine_schedule(peak_lr, max(1, total_steps - warmup_steps), final_frac)

    def f(step):
        s = step.astype(jnp.float32)
        warm = peak_lr * s / max(1, warmup_steps)
        return jnp.where(s < warmup_steps, warm, cos(step - warmup_steps))

    return f
