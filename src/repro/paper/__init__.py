"""Paper-faithful experiment drivers: CNN training, Table 1 parameter-class
histograms, Fig. 3 bit-width exploration, and cached artifacts."""
