"""Model-level quantization analysis shared by benchmarks and examples."""
from __future__ import annotations

from repro.core.quant import FixedPointSpec, classify_params, quantize_fixed
from repro.core.quant.pow2 import ParamClassStats


def classify_model(params: dict, bits: int) -> ParamClassStats:
    """Aggregate zero/one/pow2/other fractions over a CNN's conv stack
    (paper Table 1)."""
    counts = {"zero": 0.0, "one": 0.0, "pow2": 0.0, "other": 0.0, "total": 0}
    for layer in params["conv"]:
        w = layer["w"]
        spec = FixedPointSpec.for_tensor(w, bits)
        stats = classify_params(quantize_fixed(w, spec), spec.frac_bits)
        for k in ("zero", "one", "pow2", "other"):
            counts[k] += getattr(stats, k) * stats.total
        counts["total"] += stats.total
    t = counts["total"]
    return ParamClassStats(
        zero=counts["zero"] / t,
        one=counts["one"] / t,
        pow2=counts["pow2"] / t,
        other=counts["other"] / t,
        total=t,
    )
