"""Training driver for the paper's three CNN topologies.

Trains on the deterministic synthetic image task (see ``repro.data``),
optionally with fixed-point quantization-aware fine-tuning (the paper's
footnote-2 retraining step). Artifacts are cached under ``results/cnn/`` so
benchmarks and tests share one trained model per topology.
"""
from __future__ import annotations

import dataclasses
import json
import os
import pickle
import zlib
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.data import make_image_dataset
from repro.models.cnn import CNNTopology, PAPER_TOPOLOGIES, cnn_apply, init_cnn
from repro.optim import (
    AdamWConfig,
    adamw_init,
    adamw_update,
    clip_by_global_norm,
    linear_warmup_cosine,
)

RESULTS_DIR = os.environ.get("REPRO_RESULTS_DIR", "results")


@dataclasses.dataclass
class TrainedCNN:
    topo: CNNTopology
    params: dict
    float_accuracy: float
    history: list


def _loss_fn(params, topo, batch_x, batch_y, weight_bits, act_bits,
             pow2_weights=False):
    logits = cnn_apply(
        params, topo, batch_x, weight_bits=weight_bits, act_bits=act_bits,
        pow2_weights=pow2_weights,
    )
    logp = jax.nn.log_softmax(logits)
    nll = -jnp.take_along_axis(logp, batch_y[:, None], axis=1).mean()
    return nll


def evaluate(params, topo, x, y, *, weight_bits=None, act_bits=None,
             pow2_weights=False, batch=256):
    """Classification accuracy over a split."""
    correct = 0
    for i in range(0, x.shape[0], batch):
        logits = cnn_apply(
            params, topo, x[i : i + batch], weight_bits=weight_bits,
            act_bits=act_bits, pow2_weights=pow2_weights,
        )
        correct += int(jnp.sum(jnp.argmax(logits, -1) == y[i : i + batch]))
    return correct / x.shape[0]


def train_cnn(
    topo: CNNTopology,
    *,
    steps: int = 400,
    batch_size: int = 128,
    peak_lr: float = 3e-3,
    seed: int = 0,
    weight_bits: Optional[int] = None,
    act_bits: Optional[int] = None,
    pow2_weights: bool = False,
    init_params: Optional[dict] = None,
    dataset=None,
    log_every: int = 100,
    verbose: bool = False,
) -> TrainedCNN:
    ds = dataset or make_image_dataset(
        hw=topo.square_input_hw(), channels=topo.input_channels, seed=seed
    )
    key = jax.random.PRNGKey(seed + 1)
    params = init_params or init_cnn(key, topo)
    cfg = AdamWConfig(weight_decay=0.01)
    state = adamw_init(params, cfg)
    sched = linear_warmup_cosine(peak_lr, warmup_steps=20, total_steps=steps)
    n = ds.x_train.shape[0]

    @jax.jit
    def step_fn(params, state, x, y, step):
        loss, grads = jax.value_and_grad(_loss_fn)(
            params, topo, x, y, weight_bits, act_bits, pow2_weights
        )
        grads, gnorm = clip_by_global_norm(grads, 1.0)
        params, state = adamw_update(grads, state, params, cfg, sched(step))
        return params, state, loss, gnorm

    rng = np.random.default_rng(seed + 2)
    history = []
    for s in range(steps):
        idx = rng.integers(0, n, size=batch_size)
        params, state, loss, gnorm = step_fn(
            params, state, ds.x_train[idx], ds.y_train[idx], jnp.asarray(s)
        )
        if s % log_every == 0 or s == steps - 1:
            history.append({"step": s, "loss": float(loss)})
            if verbose:
                print(f"[{topo.name}] step {s:4d} loss {float(loss):.4f}")
    acc = evaluate(
        params, topo, ds.x_test, ds.y_test, weight_bits=weight_bits,
        act_bits=act_bits, pow2_weights=pow2_weights,
    )
    return TrainedCNN(topo=topo, params=params, float_accuracy=acc, history=history)


def _cache_path(name: str) -> str:
    return os.path.join(RESULTS_DIR, "cnn", f"{name}.pkl")


def topology_seed(name: str) -> int:
    """Deterministic per-topology training seed.

    SVHN and CIFAR-10 share one topology dataclass; with a single global
    seed they trained on the *same* synthetic dataset from the *same*
    init and produced byte-identical parameters — so Table 1 reported
    byte-identical quantized-parameter statistics for two supposedly
    different trained models. Deriving the seed from the topology name
    keeps every run reproducible while giving each named model its own
    dataset draw and init, as the paper's per-dataset models have."""
    return zlib.crc32(name.encode("utf-8")) % (2**16)


def get_trained_cnn(name: str, *, steps: int = 400, force: bool = False) -> TrainedCNN:
    """Train-or-load the named paper topology (cached artifact). The cache
    blob records the training seed; artifacts trained under a different
    seed regime (e.g. the old shared-global-seed one that aliased cifar10
    and svhn) are treated as misses and retrained."""
    topo = PAPER_TOPOLOGIES[name]
    path = _cache_path(name)
    seed = topology_seed(name)
    if not force and os.path.exists(path):
        with open(path, "rb") as f:
            blob = pickle.load(f)
        if blob.get("seed") == seed:
            return TrainedCNN(
                topo=topo,
                params=jax.tree_util.tree_map(jnp.asarray, blob["params"]),
                float_accuracy=blob["float_accuracy"],
                history=blob["history"],
            )
    trained = train_cnn(topo, steps=steps, seed=seed)
    os.makedirs(os.path.dirname(path), exist_ok=True)
    with open(path, "wb") as f:
        pickle.dump(
            {
                "params": jax.tree_util.tree_map(np.asarray, trained.params),
                "float_accuracy": trained.float_accuracy,
                "history": trained.history,
                "seed": seed,
            },
            f,
        )
    return trained
