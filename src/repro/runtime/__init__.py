"""Distributed runtime: fault-tolerant training driver (checkpoint/restart),
straggler detection, elastic re-meshing."""
from repro.runtime.driver import (
    ElasticMesh,
    FaultInjector,
    NodeFailure,
    ResilientTrainer,
    StragglerMonitor,
)

__all__ = [
    "ElasticMesh",
    "FaultInjector",
    "NodeFailure",
    "ResilientTrainer",
    "StragglerMonitor",
]
