"""Fault-tolerant training runtime.

At 1000+ node scale the mean time between node failures drops below the
job length, so the loop must be restart-safe by construction:

- **Checkpoint/restart**: the driver checkpoints every ``ckpt_every`` steps
  (atomic commits, see repro.checkpoint). On a failure it restores the
  latest checkpoint and replays — the data pipeline is seeded by step, so
  replayed batches are identical and the run is bitwise reproducible.
- **Straggler mitigation**: per-step wall times feed a robust (median/MAD)
  detector; sustained stragglers trigger a remediation callback (on real
  fleets: hot-spare swap or re-mesh; here: recorded + surfaced).
- **Elastic re-meshing**: on permanent capacity change the mesh is rebuilt
  on the surviving device set and the state is re-sharded onto it (host
  round-trip; on TPU fleets this is a device_put with new shardings).

Failures are injected deterministically in tests via ``FaultInjector`` —
the driver itself is production-shaped: it only sees exceptions.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Optional

import jax
import numpy as np

from repro.checkpoint import CheckpointManager


class NodeFailure(RuntimeError):
    """A (simulated or real) irrecoverable worker failure."""


@dataclasses.dataclass
class FaultInjector:
    """Deterministic failure schedule: raise NodeFailure at given steps."""

    fail_at_steps: tuple = ()
    fired: set = dataclasses.field(default_factory=set)

    def check(self, step: int) -> None:
        if step in self.fail_at_steps and step not in self.fired:
            self.fired.add(step)
            raise NodeFailure(f"injected failure at step {step}")


class StragglerMonitor:
    """Median/MAD step-time outlier detector."""

    def __init__(self, threshold: float = 3.0, window: int = 50):
        self.threshold = threshold
        self.window = window
        self.times: list = []
        self.flagged: list = []

    def record(self, step: int, seconds: float) -> bool:
        self.times.append(seconds)
        recent = self.times[-self.window:]
        if len(recent) < 5:
            return False
        med = float(np.median(recent))
        mad = float(np.median(np.abs(np.asarray(recent) - med))) + 1e-9
        is_straggler = seconds > med + self.threshold * 1.4826 * mad and (
            seconds > 1.5 * med
        )
        if is_straggler:
            self.flagged.append((step, seconds, med))
        return is_straggler


class ElasticMesh:
    """Rebuild the mesh on a surviving device set and re-shard state."""

    def __init__(self, axis_names=("data", "model")):
        self.axis_names = axis_names

    def best_shape(self, n_devices: int, *, model_parallel: int = 1) -> tuple:
        model = min(model_parallel, n_devices)
        while n_devices % model:
            model -= 1
        return (n_devices // model, model)

    def remesh(self, devices, *, model_parallel: int = 1):
        n = len(devices)
        shape = self.best_shape(n, model_parallel=model_parallel)
        dev_array = np.asarray(devices)[: shape[0] * shape[1]].reshape(shape)
        return jax.sharding.Mesh(dev_array, self.axis_names)

    def reshard_state(self, state: Any, spec_tree, mesh):
        """Host round-trip re-put with the new mesh's shardings."""
        from jax.sharding import NamedSharding

        def f(leaf, spec):
            host = np.asarray(leaf)
            return jax.device_put(host, NamedSharding(mesh, spec))

        return jax.tree_util.tree_map(
            f, state, spec_tree,
            is_leaf=lambda x: not isinstance(x, (dict, list, tuple)),
        )


class ResilientTrainer:
    """Checkpoint/restart training loop.

    step_fn(state, batch, step) -> (state, metrics); batches come from
    batch_fn(step) so replay after restore is deterministic.
    """

    def __init__(
        self,
        step_fn: Callable,
        batch_fn: Callable,
        ckpt: CheckpointManager,
        *,
        ckpt_every: int = 50,
        max_restarts: int = 10,
        straggler: Optional[StragglerMonitor] = None,
        fault_injector: Optional[FaultInjector] = None,
        on_failure: Optional[Callable] = None,
    ):
        self.step_fn = step_fn
        self.batch_fn = batch_fn
        self.ckpt = ckpt
        self.ckpt_every = ckpt_every
        self.max_restarts = max_restarts
        self.straggler = straggler or StragglerMonitor()
        self.fault_injector = fault_injector
        self.on_failure = on_failure
        self.restarts = 0
        self.history: list = []

    def run(self, state: Any, *, start_step: int = 0, num_steps: int) -> tuple:
        """Run to ``num_steps`` total steps, surviving failures.

        Returns (final_state, last_step_metrics).
        """
        step = start_step
        metrics = None
        # Resume from the newest checkpoint if one exists.
        latest = self.ckpt.latest_step()
        if latest is not None and latest > step:
            state, step = self.ckpt.restore(state)
            step += 1
        while step < num_steps:
            try:
                t0 = time.time()
                if self.fault_injector is not None:
                    self.fault_injector.check(step)
                batch = self.batch_fn(step)
                state, metrics = self.step_fn(state, batch, step)
                dt = time.time() - t0
                self.straggler.record(step, dt)
                self.history.append({"step": step, "seconds": dt})
                if (step + 1) % self.ckpt_every == 0 or step == num_steps - 1:
                    self.ckpt.save(step, state)
                step += 1
            except NodeFailure as e:
                self.restarts += 1
                if self.on_failure is not None:
                    self.on_failure(step, e)
                if self.restarts > self.max_restarts:
                    raise RuntimeError(
                        f"exceeded max_restarts={self.max_restarts}"
                    ) from e
                try:
                    state, restored = self.ckpt.restore(state)
                    step = restored + 1
                except FileNotFoundError:
                    step = start_step  # no checkpoint yet: cold restart
        self.ckpt.wait()
        return state, metrics
