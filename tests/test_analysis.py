"""repro.analysis: the static plan-verifier + AST lint gate.

Covers the acceptance contract of the subsystem:
- clean run: current plans (all topologies x fp32/quant) verify with
  ZERO findings, and the repo's own sources lint clean;
- seeded defects: every mutation of a good plan (non-finite params,
  broken IO chain, inflated/stale working sets, tampered edge plan,
  dropped donation, dtype drift, host callback) and every planted lint
  hazard is reported with the RIGHT invariant/rule ID;
- the serving engine's probe (``check_plan`` / demotion records) cites
  the same registry IDs.
"""

import dataclasses
import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import pytest

from repro.analysis.ast_lint import lint_source
from repro.analysis.findings import Finding, has_errors
from repro.analysis.invariants import REGISTRY, SCOPES
from repro.analysis.jaxpr_utils import count_primitive
from repro.analysis.verify import make_pipeline_probe, verify_plan
from repro.core.dhm.compiler import PlanCheckError, QuantSpec, compile_dhm
from repro.core.dhm.pipeline import StageIOSpec
from repro.models.cnn import ALL_TOPOLOGIES, LENET5, init_cnn

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

needs_two_devices = pytest.mark.skipif(
    len(jax.devices()) < 2,
    reason="pipeline-scope probes need a stage mesh "
    "(XLA_FLAGS=--xla_force_host_platform_device_count=8)",
)


def _plan(topo=LENET5, **kw):
    params = init_cnn(jax.random.PRNGKey(0), topo)
    return compile_dhm(topo, params, **kw)


def _ids(findings):
    return sorted({f.rule for f in findings})


def _replace_group(plan, gi, **changes):
    """A copy of ``plan`` with fusion group ``gi`` mutated."""
    flat = list(plan.fusion_groups)
    flat[gi] = dataclasses.replace(flat[gi], **changes)
    stages, k = [], 0
    for st in plan.stages:
        n = len(st.groups)
        stages.append(
            dataclasses.replace(st, groups=tuple(flat[k:k + n]))
        )
        k += n
    return dataclasses.replace(plan, stages=tuple(stages))


# ---------------------------------------------------------------------------
# registry hygiene


class TestRegistry:
    def test_ids_are_unique_and_scoped(self):
        assert len(REGISTRY) == len({inv.id for inv in REGISTRY.values()})
        for inv in REGISTRY.values():
            assert inv.scope in SCOPES
            assert inv.doc, f"{inv.id} has no doc"

    def test_expected_invariants_present(self):
        want = {
            "V001", "V002", "V003", "V004", "V005", "V006", "V007", "V008",
            "V101", "V102", "V103",
            "V201", "V202", "V203", "V204",
            "V301", "V302", "V303", "V304", "V305",
        }
        assert want <= set(REGISTRY)

    def test_finding_severity_validated(self):
        with pytest.raises(ValueError):
            Finding(rule="X", name="x", severity="fatal", message="m")


# ---------------------------------------------------------------------------
# clean runs


class TestCleanRun:
    def test_lenet5_verifies_clean(self):
        plan = _plan()
        assert verify_plan(
            plan, scopes=("plan", "structure", "resource")
        ) == []

    def test_interpret_probe_verifies_clean(self):
        plan = _plan(quant=QuantSpec(weight_bits=3, act_bits=3),
                     backend="pallas_interpret")
        assert verify_plan(
            plan, ids=("V001", "V002", "V003", "V007", "V203")
        ) == []

    def test_int8_plan_verifies_clean(self):
        """True-int8 plans pass the full single-device registry, including
        the integer-compute (V008) and int8-slab-costing (V204) gates."""
        plan = _plan(
            quant=QuantSpec(weight_bits=8, act_bits=8, int8_compute=True)
        )
        assert verify_plan(
            plan, scopes=("plan", "structure", "resource")
        ) == []

    def test_int8_interpret_probe_verifies_clean(self):
        """On the interpret probe the int8 plan keeps one in-kernel quant
        round per layer plus exactly one host-side input-quantize round
        per fusion group (the V007 int8 accounting), integer pallas-body
        dots (V008) and int8 traced footprints under the int8 costing
        (V203/V204)."""
        plan = _plan(
            quant=QuantSpec(weight_bits=8, act_bits=8, int8_compute=True),
            backend="pallas_interpret",
        )
        assert verify_plan(
            plan,
            ids=("V001", "V002", "V003", "V007", "V008", "V203", "V204"),
        ) == []

    @pytest.mark.slow
    def test_all_topologies_fp32_and_quant_verify_clean(self):
        """The acceptance matrix: five topologies x fp32/quant, zero
        findings (single-device artifacts; the pipelined closures get
        the same treatment in the CLI and the mesh-gated test below)."""
        for name, topo in ALL_TOPOLOGIES.items():
            params = init_cnn(jax.random.PRNGKey(0), topo)
            for quant in (QuantSpec(), QuantSpec(weight_bits=6, act_bits=6)):
                plan = compile_dhm(topo, params, quant=quant)
                assert verify_plan(
                    plan, scopes=("plan", "structure", "resource")
                ) == [], f"{name}/{quant}"

    @needs_two_devices
    def test_pipelined_closure_verifies_clean(self):
        plan = _plan(n_stages=2)
        probe = make_pipeline_probe(plan, microbatch=2)
        assert probe.edge_plan.mode == "exact"
        assert verify_plan(
            plan, scopes=("pipeline",), pipeline=probe
        ) == []

    def test_repo_sources_lint_clean(self):
        from repro.analysis.cli import run_lint

        assert run_lint() == []


# ---------------------------------------------------------------------------
# seeded plan defects -> named invariant IDs


class TestSeededPlanDefects:
    def test_nonfinite_param_is_V301(self):
        plan = _plan()
        bad_params = tuple(
            {k: (v.at[0].set(jnp.nan) if k == "b" else v)
             for k, v in p.items()} if i == 0 else p
            for i, p in enumerate(plan.conv_params)
        )
        bad = dataclasses.replace(plan, conv_params=bad_params)
        assert _ids(
            [f for f in verify_plan(bad, scopes=("plan",)) if f.is_error]
        ) == ["V301"]
        with pytest.raises(PlanCheckError) as ei:
            bad.self_check()
        assert ei.value.invariants == ("V301",)

    def test_broken_io_chain_is_V302(self):
        plan = _plan(n_stages=2)
        st0 = plan.stages[0]
        bad_io = StageIOSpec(
            in_shape=st0.io.in_shape, out_shape=(1, 1, 999)
        )
        bad = dataclasses.replace(
            plan,
            stages=(dataclasses.replace(st0, io=bad_io),) + plan.stages[1:],
        )
        ids = _ids(verify_plan(bad, scopes=("plan",)))
        assert "V302" in ids  # the chain breaks at the tampered edge
        assert "V303" in ids  # and the stage body contradicts its spec

    def test_inflated_working_set_is_V201_V202(self):
        plan = _plan()
        bad = _replace_group(plan, 0, working_set=10**9)
        assert _ids(verify_plan(bad, scopes=("resource",))) == [
            "V201", "V202"
        ]
        # the V202 message names the dominant cost component
        msgs = [
            f.message for f in verify_plan(bad, scopes=("resource",))
            if f.rule == "V202"
        ]
        assert any("largest component" in m for m in msgs)

    def test_underestimated_working_set_is_V203(self):
        plan = _plan(backend="pallas_interpret")
        bad = _replace_group(plan, 0, working_set=1)
        ids = _ids(verify_plan(bad, scopes=("resource",)))
        assert "V203" in ids  # traced footprint exceeds the recorded cost
        assert "V202" in ids  # and the cost model disagrees too

    def test_fp32_compute_under_int8_contract_is_V008(self):
        """Seeded defect: a plan whose kernels matmul in fp32 (the
        fake-quant lowering) but whose QuantSpec claims int8_compute —
        the hidden-upcast class V008 exists to catch."""
        fq = _plan(quant=QuantSpec(weight_bits=8, act_bits=8))
        lying = dataclasses.replace(
            fq, quant=QuantSpec(weight_bits=8, act_bits=8, int8_compute=True)
        )
        findings = verify_plan(lying, ids=("V008",))
        assert _ids(findings) == ["V008"]
        assert any("float" in f.message for f in findings)

    def test_fp32_bytes_under_int8_contract_is_V204(self):
        """Seeded defect: an int8 plan whose fusion group books the fp32
        working set — the budget headroom the 1-byte slabs buy is
        silently wasted."""
        from repro.core.dhm.fusion import group_working_set

        plan = _plan(
            quant=QuantSpec(weight_bits=8, act_bits=8, int8_compute=True)
        )
        g = plan.fusion_groups[0]
        fp32_cost = group_working_set(
            plan.topo, g.layers, block_rows=g.block_rows, elem_bytes=4
        )
        bad = _replace_group(plan, 0, working_set=fp32_cost)
        findings = verify_plan(bad, ids=("V204",))
        assert _ids(findings) == ["V204"]
        # and the honest int8 plan is clean under the same gate
        assert verify_plan(plan, ids=("V204",)) == []

    def test_dtype_drift_is_V004(self):
        plan = _plan()
        drifted = dataclasses.replace(
            plan,
            head_fn=lambda h, _inner=plan.head_fn: _inner(
                h.astype(jnp.bfloat16).astype(jnp.float32)
            ),
        )
        assert _ids(verify_plan(drifted, ids=("V004",))) == ["V004"]

    def test_host_callback_is_V005(self):
        plan = _plan()

        def cb_head(h, _inner=plan.head_fn):
            h = jax.pure_callback(
                lambda a: a, jax.ShapeDtypeStruct(h.shape, h.dtype), h
            )
            return _inner(h)

        bad = dataclasses.replace(plan, head_fn=cb_head)
        assert _ids(verify_plan(bad, ids=("V005",))) == ["V005"]

    def test_dropped_donation_is_V006(self):
        plan = _plan()

        class _DropsDonate:
            """A plan whose jitted_forward silently ignores donate=."""

            topo = plan.topo
            backend = plan.backend

            def jitted_forward(self, *, donate=False):
                return plan.jitted_forward(donate=False)

        assert _ids(verify_plan(_DropsDonate(), ids=("V006",))) == ["V006"]

    def test_good_plan_declares_donation(self):
        assert verify_plan(_plan(), ids=("V006",)) == []


@needs_two_devices
class TestSeededPipelineDefects:
    def _probe(self, plan):
        return make_pipeline_probe(plan, microbatch=2)

    def test_dropped_edge_class_is_V101(self):
        plan = _plan(n_stages=2)
        probe = self._probe(plan)
        ep = probe.edge_plan
        # claim a second shape class that no traced collective serves
        tampered = dataclasses.replace(
            ep,
            class_shapes=ep.class_shapes + ((9, 9, 9),),
            edge_class=tuple(1 for _ in ep.edge_class),
        )
        bad = dataclasses.replace(probe, edge_plan=tampered)
        ids = _ids(verify_plan(plan, scopes=("pipeline",), pipeline=bad))
        assert "V101" in ids

    def test_wrong_edge_shape_is_V102(self):
        plan = _plan(n_stages=2)
        probe = self._probe(plan)
        ep = probe.edge_plan
        tampered = dataclasses.replace(
            ep, class_shapes=((9, 9, 9),) * len(ep.class_shapes)
        )
        bad = dataclasses.replace(probe, edge_plan=tampered)
        ids = _ids(verify_plan(plan, scopes=("pipeline",), pipeline=bad))
        assert "V102" in ids

    def test_boxed_fallback_is_flagged_V103(self):
        plan = _plan(n_stages=2)
        probe = make_pipeline_probe(plan, microbatch=2, edge_mode="boxed")
        findings = verify_plan(plan, scopes=("pipeline",), pipeline=probe)
        warnings_ = [f for f in findings if f.rule == "V103"]
        assert len(warnings_) == 1
        assert not warnings_[0].is_error
        assert "padding" in warnings_[0].message


# ---------------------------------------------------------------------------
# engine integration: same registry on the serving path


class TestEngineIntegration:
    def test_check_plan_runs_plan_scope(self):
        # a good plan passes the same gate the engine probes before
        # activating a rung
        _plan().self_check()

    def test_demotion_record_cites_invariants(self):
        from repro.core.dhm.engine import Engine

        e = PlanCheckError("nope", invariants=("V301", "V303"))
        rec = Engine._demotion_record("fused", e)
        assert rec["invariants"] == ["V301", "V303"]
        assert rec["rung"] == "fused"
        # plain exceptions keep the legacy record shape
        rec = Engine._demotion_record("fused", RuntimeError("x"))
        assert "invariants" not in rec


# ---------------------------------------------------------------------------
# AST lint: seeded fixtures -> named rule IDs


_ENGINE_PATH = "src/repro/core/dhm/engine.py"
_BENCH_PATH = "benchmarks/my_bench.py"


class TestLintRules:
    def test_eager_concat_is_DHM001(self):
        src = (
            "import jax.numpy as jnp\n"
            "def flush(frames):\n"
            "    return jnp.concatenate(frames, axis=0)\n"
        )
        ids = _ids(lint_source(src, _ENGINE_PATH))
        assert ids == ["DHM001"]

    def test_numpy_pack_is_clean(self):
        src = (
            "import numpy as np\n"
            "def flush(frames):\n"
            "    return np.concatenate(frames, axis=0)\n"
        )
        assert lint_source(src, _ENGINE_PATH) == []

    def test_stack_inside_jit_is_DHM002(self):
        src = (
            "import jax\n"
            "import jax.numpy as jnp\n"
            "@jax.jit\n"
            "def fwd(leaves, x):\n"
            "    w = jnp.stack(leaves, axis=0)\n"
            "    return x @ w\n"
        )
        ids = _ids(lint_source(src, _ENGINE_PATH))
        assert ids == ["DHM002"]

    def test_jax_jit_by_reference_is_DHM002(self):
        src = (
            "import jax\n"
            "import jax.numpy as jnp\n"
            "def fwd(leaves, x):\n"
            "    return x @ jnp.stack(leaves)\n"
            "fwd_j = jax.jit(fwd)\n"
        )
        ids = _ids(lint_source(src, "src/repro/core/dhm/pipeline.py"))
        assert ids == ["DHM002"]

    def test_eager_stack_outside_jit_in_pipeline_is_clean(self):
        # the PR-7 fix: box + stack EAGERLY, outside any trace
        src = (
            "import jax.numpy as jnp\n"
            "def box(params):\n"
            "    return jnp.stack(params, axis=0)\n"
        )
        assert lint_source(src, "src/repro/core/dhm/pipeline.py") == []

    def test_timing_without_block_is_DHM003(self):
        src = (
            "import time\n"
            "import jax.numpy as jnp\n"
            "def bench(a, b):\n"
            "    t0 = time.perf_counter()\n"
            "    y = jnp.dot(a, b)\n"
            "    return time.perf_counter() - t0, y\n"
        )
        ids = _ids(lint_source(src, _BENCH_PATH))
        assert ids == ["DHM003"]

    def test_timing_with_block_is_clean(self):
        src = (
            "import time\n"
            "import jax\n"
            "import jax.numpy as jnp\n"
            "def bench(a, b):\n"
            "    t0 = time.perf_counter()\n"
            "    y = jax.block_until_ready(jnp.dot(a, b))\n"
            "    return time.perf_counter() - t0, y\n"
        )
        assert lint_source(src, _BENCH_PATH) == []

    def test_bare_except_is_DHM004(self):
        src = (
            "def drain(q):\n"
            "    try:\n"
            "        q.get()\n"
            "    except:\n"
            "        pass\n"
        )
        ids = _ids(lint_source(src, _ENGINE_PATH))
        assert ids == ["DHM004"]

    def test_swallowed_request_error_is_DHM004(self):
        src = (
            "from repro.core.dhm.engine import DeadlineExceeded\n"
            "def flush(req):\n"
            "    try:\n"
            "        req.complete()\n"
            "    except DeadlineExceeded:\n"
            "        pass\n"
        )
        ids = _ids(lint_source(src, _ENGINE_PATH))
        assert ids == ["DHM004"]

    def test_handled_request_error_is_clean(self):
        src = (
            "from repro.core.dhm.engine import DeadlineExceeded\n"
            "def flush(req):\n"
            "    try:\n"
            "        req.complete()\n"
            "    except DeadlineExceeded as e:\n"
            "        req.fail(e)\n"
        )
        assert lint_source(src, _ENGINE_PATH) == []

    def test_float64_cast_is_DHM005(self):
        src = (
            "import jax.numpy as jnp\n"
            "def widen(x):\n"
            "    return x.astype('float64') + jnp.zeros((), jnp.float64)\n"
        )
        findings = lint_source(src, "src/repro/core/dhm/anything.py")
        assert _ids(findings) == ["DHM005"]
        assert len(findings) == 2  # the astype and the jnp.float64

    def test_unbounded_background_thread_is_DHM006(self):
        # the PR-9 stop() bug class: a serving thread with no
        # timeout-bounded join leaks past interpreter shutdown
        src = (
            "import threading\n"
            "def start(loop):\n"
            "    t = threading.Thread(target=loop, daemon=True)\n"
            "    t.start()\n"
            "    return t\n"
        )
        ids = _ids(lint_source(src, _ENGINE_PATH))
        assert ids == ["DHM006"]
        assert _ids(
            lint_source(src, "src/repro/core/dhm/multitenant.py")
        ) == ["DHM006"]

    def test_bounded_join_is_clean_DHM006(self):
        src = (
            "import threading\n"
            "def start(loop):\n"
            "    t = threading.Thread(target=loop, daemon=True)\n"
            "    t.start()\n"
            "    return t\n"
            "def stop(t):\n"
            "    t.join(timeout=30.0)\n"
            "    if t.is_alive():\n"
            "        raise RuntimeError('wedged')\n"
        )
        assert lint_source(src, _ENGINE_PATH) == []

    def test_str_join_does_not_satisfy_DHM006(self):
        # '; '.join(msgs) is not a thread join — the rule must still fire
        src = (
            "import threading\n"
            "def start(loop, msgs):\n"
            "    t = threading.Thread(target=loop)\n"
            "    t.start()\n"
            "    return '; '.join(msgs)\n"
        )
        assert _ids(lint_source(src, _ENGINE_PATH)) == ["DHM006"]

    def test_rules_are_scoped_by_path(self):
        # a kernel body may stack taps eagerly — serving rules must not
        # fire outside their path scope
        src = (
            "import jax.numpy as jnp\n"
            "def kernel(taps):\n"
            "    return jnp.stack(taps, axis=2)\n"
        )
        assert lint_source(src, "src/repro/kernels/stream_conv/conv.py") == []
        # DHM006 is a serving-file rule: kernel/pipeline modules may own
        # unjoined worker threads (the watchdog does)
        src = (
            "import threading\n"
            "def watch(fn):\n"
            "    threading.Thread(target=fn, daemon=True).start()\n"
        )
        assert lint_source(src, "src/repro/core/dhm/pipeline.py") == []

    def test_findings_carry_file_and_line(self):
        src = (
            "import jax.numpy as jnp\n"
            "def flush(frames):\n"
            "    return jnp.stack(frames)\n"
        )
        (f,) = lint_source(src, _ENGINE_PATH)
        assert f.where == f"{_ENGINE_PATH}:3"
        assert has_errors([f])


# ---------------------------------------------------------------------------
# shared jaxpr helpers (the deduped _count_primitive home)


class TestJaxprUtils:
    def test_count_descends_into_pjit(self):
        f = jax.jit(lambda a, b: a @ b)
        jaxpr = jax.make_jaxpr(f)(jnp.ones((4, 4)), jnp.ones((4, 4)))
        assert count_primitive(jaxpr, "dot_general") == 1

    def test_counts_match_legacy_semantics(self):
        from repro.analysis.jaxpr_utils import count_primitive_in_pallas

        plan = _plan(quant=QuantSpec(act_bits=4), backend="pallas_interpret")
        jaxpr = jax.make_jaxpr(plan.features)(
            jnp.ones((1,) + tuple(plan.stages[0].io.in_shape))
        )
        n_conv = len(plan.topo.conv_layers)
        assert count_primitive_in_pallas(jaxpr, "round") == n_conv
        assert count_primitive(jaxpr, "round") == n_conv


# ---------------------------------------------------------------------------
# CLI


class TestCLI:
    @pytest.mark.slow
    def test_cli_clean_run_exits_zero(self, tmp_path):
        out = tmp_path / "findings.json"
        proc = subprocess.run(
            [
                sys.executable, "-m", "repro.analysis", "all",
                "--topology", "lenet5", "--format", "json",
                "--out", str(out), "--no-pipeline",
            ],
            capture_output=True, text=True, timeout=560,
            env={
                **os.environ,
                "PYTHONPATH": os.path.join(REPO, "src"),
            },
            cwd=REPO,
        )
        assert proc.returncode == 0, proc.stderr[-2000:]
        rep = json.loads(proc.stdout)
        assert rep["errors"] == 0
        assert json.loads(out.read_text())["findings"] == rep["findings"]

    def test_verify_rejects_unknown_scope(self):
        with pytest.raises(ValueError, match="unknown scopes"):
            verify_plan(_plan(), scopes=("nope",))
