"""Per-architecture smoke tests: reduced same-family configs, one
forward/train step on CPU, shape + finiteness asserts, decode consistency,
and gradient flow. The FULL configs are exercised only via the dry-run."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import SHAPES, get_arch, list_archs
from repro.models.transformer import (
    decode_step,
    forward_hidden,
    init_params,
    prefill,
    train_loss,
    unembed,
)

ARCHS = list_archs()


def _reduced(name):
    cfg = get_arch(name).scaled_down()
    if cfg.n_experts:
        # Exact decode-vs-forward equality needs drop-free routing (capacity
        # skew between prompt lengths is inherent to token-choice MoE).
        cfg = dataclasses.replace(cfg, capacity_factor=8.0)
    return cfg


def _batch(cfg, b=2, s=16, key=0):
    batch = {
        "tokens": jax.random.randint(
            jax.random.PRNGKey(key), (b, s + 1), 0, cfg.vocab_size
        )
    }
    if cfg.family == "vlm":
        batch["prefix_embeds"] = (
            jax.random.normal(
                jax.random.PRNGKey(key + 1),
                (b, cfg.n_prefix_tokens, cfg.d_model),
            )
            * 0.1
        )
    if cfg.family == "encdec":
        batch["encoder_frames"] = (
            jax.random.normal(
                jax.random.PRNGKey(key + 2), (b, cfg.encoder_seq, cfg.d_model)
            )
            * 0.1
        )
    return batch


@pytest.mark.parametrize("name", ARCHS)
def test_full_config_fields(name):
    """Exact assigned configs load with the published dimensions."""
    cfg = get_arch(name)
    expected = {
        "command-r-35b": (40, 8192, 64, 8, 22528, 256_000),
        "granite-34b": (88, 6144, 48, 1, 24576, 49152),
        "stablelm-12b": (40, 5120, 32, 8, 13824, 100_352),
        "qwen2.5-3b": (36, 2048, 16, 2, 11008, 151_936),
        "whisper-base": (6, 512, 8, 8, 2048, 51_865),
        "internvl2-2b": (24, 2048, 16, 8, 8192, 92_553),
        "recurrentgemma-9b": (38, 4096, 16, 1, 12288, 256_000),
        "qwen3-moe-235b-a22b": (94, 4096, 64, 4, 1536, 151_936),
        "llama4-scout-17b-a16e": (48, 5120, 40, 8, 8192, 202_048),
        "falcon-mamba-7b": (64, 4096, 1, 1, 0, 65_024),
    }[name]
    got = (
        cfg.n_layers,
        cfg.d_model,
        cfg.n_heads,
        cfg.n_kv_heads,
        cfg.d_ff,
        cfg.vocab_size,
    )
    assert got == expected


@pytest.mark.parametrize("name", ARCHS)
def test_train_step_smoke(name):
    """Reduced config: one loss+grad step, finite, loss near ln(V)."""
    cfg = _reduced(name)
    params = init_params(jax.random.PRNGKey(0), cfg)
    batch = _batch(cfg)

    def loss_fn(p):
        loss, metrics = train_loss(p, cfg, batch, vocab_chunk=64)
        return loss

    loss, grads = jax.value_and_grad(loss_fn)(params)
    assert bool(jnp.isfinite(loss))
    assert abs(float(loss) - np.log(cfg.vocab_size)) < 1.5
    gnorms = [
        float(jnp.linalg.norm(g))
        for g in jax.tree_util.tree_leaves(grads)
    ]
    assert all(np.isfinite(gnorms))
    assert sum(gnorms) > 0.0


@pytest.mark.parametrize("name", ARCHS)
def test_forward_shapes(name):
    cfg = _reduced(name)
    params = init_params(jax.random.PRNGKey(0), cfg)
    b, s = 2, 16
    batch = _batch(cfg, b=b, s=s)
    h, _, _ = forward_hidden(
        params,
        cfg,
        batch["tokens"][:, :-1],
        prefix_embeds=batch.get("prefix_embeds"),
        encoder_frames=batch.get("encoder_frames"),
    )
    p = cfg.n_prefix_tokens if cfg.family == "vlm" else 0
    assert h.shape == (b, s + p, cfg.d_model)
    logits = unembed(params, cfg, h)
    assert logits.shape == (b, s + p, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))


@pytest.mark.parametrize("name", ARCHS)
def test_decode_matches_forward(name):
    """prefill + decode_step reproduce the full-forward logits."""
    cfg = _reduced(name)
    params = init_params(jax.random.PRNGKey(0), cfg)
    b, s = 2, 12
    batch = _batch(cfg, b=b, s=s)
    toks = batch["tokens"][:, :s]
    kw = {
        k: batch[k]
        for k in ("prefix_embeds", "encoder_frames")
        if k in batch
    }
    h, _, _ = forward_hidden(params, cfg, toks, **kw)
    full_logits = unembed(params, cfg, h)
    p = cfg.n_prefix_tokens if cfg.family == "vlm" else 0
    logits_pre, cache = prefill(params, cfg, toks[:, : s - 1], max_len=s + p + 4, **kw)
    np.testing.assert_allclose(
        np.asarray(logits_pre, np.float32),
        np.asarray(full_logits[:, p + s - 2], np.float32),
        atol=2e-2,
        rtol=1e-2,
    )
    logits_dec, _ = decode_step(
        params, cfg, toks[:, s - 1 : s], cache, jnp.asarray(p + s - 1)
    )
    np.testing.assert_allclose(
        np.asarray(logits_dec, np.float32),
        np.asarray(full_logits[:, p + s - 1], np.float32),
        atol=2e-2,
        rtol=1e-2,
    )


@pytest.mark.parametrize("name", ARCHS)
def test_long_500k_support_flags(name):
    """long_500k runs exactly for the ssm/hybrid/chunked families."""
    cfg = get_arch(name)
    runs = cfg.supports_shape(SHAPES["long_500k"])
    should_run = name in (
        "recurrentgemma-9b",
        "llama4-scout-17b-a16e",
        "falcon-mamba-7b",
    )
    assert runs == should_run


def test_scan_vs_unscanned_equivalence():
    """scan-over-layers == the same stack applied layer by layer."""
    cfg = _reduced("granite-34b")
    cfg = dataclasses.replace(cfg, n_layers=4)
    params = init_params(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0, cfg.vocab_size)
    h_scan, _, _ = forward_hidden(params, cfg, toks)

    # Rebuild as unscanned (tail-only) by unstacking the unit params.
    cfg_unroll = dataclasses.replace(cfg, scan_layers=False)
    unit = params["stack"]["units"][0]  # (4, ...) stacked single-pos pattern
    tail = [
        jax.tree_util.tree_map(lambda x: x[i], unit) for i in range(cfg.n_layers)
    ]
    params_unroll = dict(params)
    params_unroll["stack"] = {"units": None, "tail": tail}
    h_unroll, _, _ = forward_hidden(params_unroll, cfg_unroll, toks)
    np.testing.assert_allclose(
        np.asarray(h_scan, np.float32),
        np.asarray(h_unroll, np.float32),
        atol=1e-4,
    )
