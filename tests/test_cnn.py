"""Tests for the CNN substrate + paper topologies (workload numbers,
training, quantized inference)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data import make_image_dataset
from repro.models.cnn import (
    CIFAR10,
    CIFAR10_FULL,
    CIFAR10_STRIDED,
    CNNTopology,
    ConvLayerSpec,
    EXTRA_TOPOLOGIES,
    LENET5,
    PAPER_TOPOLOGIES,
    SVHN,
    cnn_apply,
    init_cnn,
)
from repro.paper.train_cnn import evaluate, train_cnn


class TestTopologies:
    def test_paper_workloads(self):
        """Table 4 'Workload' column: 3.8 Mop LeNet5, 24.8 Mop Cifar10."""
        assert LENET5.feature_extractor_ops() == pytest.approx(3.8e6, rel=0.02)
        assert CIFAR10.feature_extractor_ops() == pytest.approx(24.8e6, rel=0.02)
        assert SVHN.feature_extractor_ops() == CIFAR10.feature_extractor_ops()

    def test_conv_shapes_lenet(self):
        # 28 -VALID5-> 24 -pool-> 12 -VALID5-> 8 -pool-> 4
        assert LENET5.conv_shapes() == [(1, 20, 5, 24, 24), (20, 50, 5, 8, 8)]

    def test_conv_shapes_cifar(self):
        assert CIFAR10.conv_shapes() == [
            (3, 32, 5, 32, 32),
            (32, 32, 5, 16, 16),
            (32, 64, 5, 8, 8),
        ]

    def test_multiplier_counts(self):
        # Full DHM LeNet5 needs C*N*K^2 per layer = 500 + 25000.
        assert LENET5.n_multipliers() == 25500

    def test_conv_shapes_cifar10_full(self):
        # Caffe cifar10_full: overlapping 3x3/stride-2 pool, 32->15->7->3.
        assert CIFAR10_FULL.conv_shapes() == [
            (3, 32, 5, 32, 32),
            (32, 32, 5, 15, 15),
            (32, 64, 5, 7, 7),
        ]
        assert CIFAR10_FULL.feature_shape() == (3, 3, 64)

    def test_conv_shapes_cifar10_strided(self):
        # Stride-2 downsampling convs: 32->16->8, then 2x2/2 pool -> 4.
        assert CIFAR10_STRIDED.conv_shapes() == [
            (3, 32, 5, 16, 16),
            (32, 64, 3, 8, 8),
            (64, 64, 3, 8, 8),
        ]
        assert CIFAR10_STRIDED.feature_shape() == (4, 4, 64)

    def test_rectangular_input_shapes(self):
        topo = CNNTopology(
            name="rect", input_hw=(16, 24), input_channels=1,
            conv_layers=(
                ConvLayerSpec(n_out=4, kernel=3, padding="SAME", pool=2),
            ),
            fc_dims=(), n_classes=2,
        )
        assert topo.input_shape == (16, 24)
        assert topo.conv_shapes() == [(1, 4, 3, 16, 24)]
        assert topo.feature_shape() == (8, 12, 4)

    def test_square_required_raises_clearly(self):
        topo = CNNTopology(
            name="rect", input_hw=(16, 24), input_channels=1,
            conv_layers=(ConvLayerSpec(n_out=4, kernel=3),),
            fc_dims=(), n_classes=2,
        )
        with pytest.raises(ValueError, match="square"):
            topo.square_input_hw()

    def test_bad_input_hw_raises(self):
        with pytest.raises(ValueError, match="input_hw"):
            CNNTopology(
                name="bad", input_hw=[16, 24], input_channels=1,
                conv_layers=(ConvLayerSpec(n_out=4, kernel=3),),
                fc_dims=(), n_classes=2,
            )


class TestForward:
    @pytest.mark.parametrize("name", sorted(PAPER_TOPOLOGIES))
    def test_forward_shapes_and_finite(self, name):
        topo = PAPER_TOPOLOGIES[name]
        params = init_cnn(jax.random.PRNGKey(0), topo)
        x = jnp.ones((2, topo.input_hw, topo.input_hw, topo.input_channels))
        logits = cnn_apply(params, topo, x)
        assert logits.shape == (2, topo.n_classes)
        assert bool(jnp.all(jnp.isfinite(logits)))

    @pytest.mark.parametrize("name", sorted(EXTRA_TOPOLOGIES))
    def test_forward_generalized_topologies(self, name):
        """The non-paper topologies (overlapping pool / strided conv) run
        through the same cnn_apply -> compile_dhm path."""
        topo = EXTRA_TOPOLOGIES[name]
        params = init_cnn(jax.random.PRNGKey(0), topo)
        h, w = topo.input_shape
        x = jnp.ones((2, h, w, topo.input_channels))
        logits = cnn_apply(params, topo, x)
        assert logits.shape == (2, topo.n_classes)
        assert bool(jnp.all(jnp.isfinite(logits)))

    def test_quantized_forward_finite(self):
        params = init_cnn(jax.random.PRNGKey(0), LENET5)
        x = jax.random.normal(jax.random.PRNGKey(1), (2, 28, 28, 1))
        for bits in (3, 6, 8):
            logits = cnn_apply(params, LENET5, x, weight_bits=bits, act_bits=bits)
            assert bool(jnp.all(jnp.isfinite(logits)))

    def test_quantization_changes_output(self):
        params = init_cnn(jax.random.PRNGKey(0), LENET5)
        x = jax.random.normal(jax.random.PRNGKey(1), (2, 28, 28, 1))
        full = cnn_apply(params, LENET5, x)
        q3 = cnn_apply(params, LENET5, x, weight_bits=3, act_bits=3)
        assert not np.allclose(full, q3)

    @pytest.mark.parametrize("name", sorted(PAPER_TOPOLOGIES))
    def test_fused_conv_backend_matches_reference(self, name):
        """cnn_apply(conv_backend=...) routes every conv stage through the
        fused streaming kernel; logits must match the lax.conv composition
        (pool and the monotone activations commute)."""
        topo = PAPER_TOPOLOGIES[name]
        params = init_cnn(jax.random.PRNGKey(3), topo)
        x = jax.random.normal(
            jax.random.PRNGKey(4),
            (2, topo.input_hw, topo.input_hw, topo.input_channels),
        )
        ref = cnn_apply(params, topo, x)
        fused = cnn_apply(params, topo, x, conv_backend="pallas")
        np.testing.assert_allclose(np.asarray(fused), np.asarray(ref),
                                   rtol=1e-4, atol=1e-4)

    def test_fused_conv_backend_quantized(self):
        """Fused path composes with weight/activation fake-quant."""
        params = init_cnn(jax.random.PRNGKey(0), LENET5)
        x = jax.random.normal(jax.random.PRNGKey(1), (2, 28, 28, 1))
        ref = cnn_apply(params, LENET5, x, weight_bits=4, act_bits=4)
        fused = cnn_apply(params, LENET5, x, weight_bits=4, act_bits=4,
                          conv_backend="pallas")
        np.testing.assert_allclose(np.asarray(fused), np.asarray(ref),
                                   rtol=1e-4, atol=1e-4)


class TestTraining:
    def test_loss_decreases_and_accuracy(self):
        ds = make_image_dataset(hw=28, channels=1, seed=0, n_train_per_class=64)
        trained = train_cnn(LENET5, steps=60, dataset=ds, log_every=20)
        first = trained.history[0]["loss"]
        last = trained.history[-1]["loss"]
        assert last < first * 0.5
        assert trained.float_accuracy > 0.5  # 10-class chance = 0.1

    def test_qat_trains(self):
        """Quantization-aware fine-tuning (STE) makes progress at 4 bits."""
        ds = make_image_dataset(hw=28, channels=1, seed=0, n_train_per_class=64)
        trained = train_cnn(
            LENET5, steps=60, dataset=ds, weight_bits=4, act_bits=4, log_every=20
        )
        assert trained.history[-1]["loss"] < trained.history[0]["loss"] * 0.7
        assert np.isfinite(trained.history[-1]["loss"])
