"""Tests for the DHM compiler: compile-time validation, end-to-end
equivalence of compiled plans vs the hand-composed reference (all three
paper topologies, fp32 + quantized + pow2), the in-kernel feature-stream
quantization, structural single-matmul guarantees on the compiler path,
and the pipelined executor matching the single-device plan."""
import os
import pathlib
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.dhm.compiler import (
    CompiledDHM,
    QuantSpec,
    compile_dhm,
    emit_conv_stage,
    validate_topology,
)
from repro.models.cnn import (
    CNNTopology,
    ConvLayerSpec,
    EXTRA_TOPOLOGIES,
    LENET5,
    PAPER_TOPOLOGIES,
    cnn_apply,
    cnn_apply_reference,
    init_cnn,
)


# The ONE jaxpr-walking helper pair, shared with the static-analysis
# engine (tests and the `repro.analysis` CLI can never drift apart).
from repro.analysis.jaxpr_utils import (  # noqa: E402
    count_primitive as _count_primitive,
    count_primitive_in_pallas as _count_primitive_in_pallas,
)


def _mk_inputs(topo, seed=4, batch=2):
    params = init_cnn(jax.random.PRNGKey(seed - 1), topo)
    h, w = topo.input_shape
    x = jax.random.normal(
        jax.random.PRNGKey(seed), (batch, h, w, topo.input_channels)
    )
    return params, x


class TestValidation:
    def _topo(self, **layer_kw):
        return CNNTopology(
            name="bad", input_hw=12, input_channels=2,
            conv_layers=(ConvLayerSpec(n_out=4, kernel=3, **layer_kw),),
            fc_dims=(), n_classes=2,
        )

    def test_typo_act_raises_at_compile_time(self):
        """A typo'd act raises a ValueError naming the options from
        compile_dhm — not a KeyError deep inside a kernel trace."""
        topo = self._topo(act="rleu")
        params = init_cnn(jax.random.PRNGKey(0), topo)
        with pytest.raises(ValueError, match="rleu.*none.*relu.*tanh"):
            compile_dhm(topo, params)

    def test_typo_padding_raises(self):
        with pytest.raises(ValueError, match="SMAE"):
            validate_topology(self._topo(padding="SMAE"))

    def test_bad_pool_raises(self):
        with pytest.raises(ValueError, match="pool"):
            validate_topology(self._topo(pool=-1))

    def test_bad_pool_stride_raises(self):
        with pytest.raises(ValueError, match="pool_stride"):
            validate_topology(self._topo(pool=3, pool_stride=0))
        # pool_stride without pooling is a spec contradiction, not silence.
        with pytest.raises(ValueError, match="pool_stride"):
            validate_topology(self._topo(pool=0, pool_stride=2))

    def test_bad_conv_stride_raises(self):
        with pytest.raises(ValueError, match="stride"):
            validate_topology(self._topo(stride=0))

    def test_oversized_pool_window_raises(self):
        """A pool window larger than the conv output raises at compile
        time instead of silently emitting an empty frame."""
        # 12x12 input, VALID k=3 -> 10x10 conv out; 11x11 pool impossible.
        with pytest.raises(ValueError, match="too small"):
            validate_topology(self._topo(pool=11))

    def test_empty_conv_output_raises(self):
        topo = CNNTopology(
            name="bad", input_hw=4, input_channels=1,
            conv_layers=(
                ConvLayerSpec(n_out=2, kernel=7, padding="VALID", pool=0),
            ),
            fc_dims=(), n_classes=2,
        )
        with pytest.raises(ValueError, match="empty"):
            validate_topology(topo)

    def test_cnn_apply_validates_too(self):
        """The model entry point inherits compile-time validation."""
        topo = self._topo(act="rleu")
        params = init_cnn(jax.random.PRNGKey(0), topo)
        x = jnp.ones((1, 12, 12, 2))
        with pytest.raises(ValueError, match="unknown act"):
            cnn_apply(params, topo, x)

    def test_emit_conv_stage_validates(self):
        import types

        spec = types.SimpleNamespace(padding="SAME", act="relu", pool=-2)
        with pytest.raises(ValueError, match="pool"):
            emit_conv_stage((spec,))

    def test_unknown_backend_raises(self):
        params, _ = _mk_inputs(LENET5)
        with pytest.raises(ValueError, match="unknown backend"):
            compile_dhm(LENET5, params, backend="palas")

    def test_bad_n_stages_raises(self):
        params, _ = _mk_inputs(LENET5)
        with pytest.raises(ValueError, match="n_stages"):
            compile_dhm(LENET5, params, n_stages=3)  # LeNet5 has 2 conv layers

    def test_bad_quant_bits_raise(self):
        with pytest.raises(ValueError, match="act_bits"):
            QuantSpec(act_bits=1)
        with pytest.raises(ValueError, match="weight_bits"):
            QuantSpec(weight_bits=0)


class TestLoweringArtifacts:
    def test_plan_carries_graph_and_assignment(self):
        """The plan exposes the IR it lowered through: the paper-granularity
        DPN and the min-max stage assignment costed from actor payloads."""
        params, _ = _mk_inputs(LENET5)
        plan = compile_dhm(LENET5, params, n_stages=2)
        assert isinstance(plan, CompiledDHM)
        assert plan.graph.total_multipliers() == LENET5.n_multipliers()
        assert plan.assignment.n_stages == 2
        # Stage costs come from the actor FLOP payloads: together they
        # cover every actor in the graph (conv engines + neuron sums +
        # activations + pools — slightly above the bare MAC workload).
        assert sum(s.cost_flops for s in plan.stages) == pytest.approx(
            plan.graph.total_flops()
        )
        assert sum(s.cost_flops for s in plan.stages) == pytest.approx(
            LENET5.feature_extractor_ops(), rel=0.05
        )
        assert [s.conv_layers for s in plan.stages] == [(0,), (1,)]

    def test_stage_partition_is_contiguous_cover(self):
        params, _ = _mk_inputs(PAPER_TOPOLOGIES["cifar10"])
        plan = compile_dhm(PAPER_TOPOLOGIES["cifar10"], params, n_stages=2)
        covered = [i for s in plan.stages for i in s.conv_layers]
        assert covered == list(range(len(plan.topo.conv_layers)))


class TestEndToEndEquivalence:
    """CompiledDHM logits vs the hand-composed cnn_apply_reference, for all
    three paper topologies."""

    @pytest.mark.parametrize(
        "name",
        [
            "lenet5",
            # The CIFAR-sized interpret-mode runs dominate tier-1 wall
            # time; the fast tier keeps the LeNet5 oracle coverage.
            pytest.param("cifar10", marks=pytest.mark.slow),
            pytest.param("svhn", marks=pytest.mark.slow),
        ],
    )
    def test_fp32_oracle_backend_matches_reference(self, name):
        """fp32 plan through the Pallas-interpreter oracle backend."""
        topo = PAPER_TOPOLOGIES[name]
        params, x = _mk_inputs(topo, batch=1)
        plan = compile_dhm(topo, params, backend="pallas_interpret")
        ref = cnn_apply_reference(params, topo, x)
        np.testing.assert_allclose(
            np.asarray(plan(x)), np.asarray(ref), rtol=1e-4, atol=1e-5
        )

    @pytest.mark.parametrize("name", sorted(PAPER_TOPOLOGIES))
    def test_fp32_compiled_backend_matches_reference(self, name):
        topo = PAPER_TOPOLOGIES[name]
        params, x = _mk_inputs(topo)
        plan = compile_dhm(topo, params, backend="pallas")
        ref = cnn_apply_reference(params, topo, x)
        np.testing.assert_allclose(
            np.asarray(plan(x)), np.asarray(ref), rtol=1e-4, atol=1e-4
        )

    @pytest.mark.parametrize("name", sorted(PAPER_TOPOLOGIES))
    def test_quantized_plan_matches_fake_quant_reference(self, name):
        """Quantized plan (weights + in-kernel feature stream) vs the
        model-level fake-quant composition, at the paper's bit-widths."""
        bits = {"lenet5": 3, "cifar10": 6, "svhn": 6}[name]
        topo = PAPER_TOPOLOGIES[name]
        params, x = _mk_inputs(topo)
        plan = compile_dhm(
            topo, params,
            quant=QuantSpec(weight_bits=bits, act_bits=bits),
            backend="pallas",
        )
        ref = cnn_apply_reference(
            params, topo, x, weight_bits=bits, act_bits=bits
        )
        np.testing.assert_allclose(
            np.asarray(plan(x)), np.asarray(ref), rtol=1e-4, atol=1e-4
        )

    def test_pow2_packed_head_matches_projected_reference(self):
        """quant.pow2_weights lowers the FC head through the packed
        pow2_matmul kernel; logits must match the reference that computes
        x @ project_pow2(w) densely."""
        params, x = _mk_inputs(LENET5)
        plan = compile_dhm(
            LENET5, params, quant=QuantSpec(pow2_weights=True),
            backend="pallas",
        )
        ref = cnn_apply_reference(params, LENET5, x, pow2_weights=True)
        np.testing.assert_allclose(
            np.asarray(plan(x)), np.asarray(ref), rtol=1e-4, atol=1e-3
        )

    def test_pow2_packed_head_keeps_ste_gradients(self):
        """The packed forward must not kill pow2 QAT: grads reach every
        parameter (straight-through, as with project_pow2_ste)."""
        params, x = _mk_inputs(LENET5)

        def loss(p):
            return jnp.sum(cnn_apply(p, LENET5, x, pow2_weights=True) ** 2)

        g = jax.grad(loss)(params)
        for leaf in jax.tree_util.tree_leaves(g):
            assert bool(jnp.all(jnp.isfinite(leaf)))
        fc_w_grad = g["fc"][0]["w"]
        assert float(jnp.max(jnp.abs(fc_w_grad))) > 0.0

    def test_cnn_apply_is_the_compiled_plan(self):
        """cnn_apply runs the compiled plan's closures: one lowering path,
        no separate hand-wired composition left in the model. (cnn_apply
        stays eager — a fresh plan per call must not retrace a per-plan
        jit — so it is bitwise the plan's stage/head composition and
        allclose to the jitted ``plan(x)``, which XLA re-associates.)"""
        params, x = _mk_inputs(LENET5)
        plan = compile_dhm(LENET5, params, backend="ref")
        out = cnn_apply(params, LENET5, x)
        np.testing.assert_array_equal(
            np.asarray(out), np.asarray(plan.head_fn(plan.features(x)))
        )
        np.testing.assert_allclose(
            np.asarray(out), np.asarray(plan(x)), rtol=1e-5, atol=1e-6
        )

    def test_n_stages_does_not_change_logits(self):
        topo = PAPER_TOPOLOGIES["cifar10"]
        params, x = _mk_inputs(topo)
        one = compile_dhm(topo, params, n_stages=1)(x)
        three = compile_dhm(topo, params, n_stages=3)(x)
        np.testing.assert_allclose(
            np.asarray(one), np.asarray(three), rtol=1e-5, atol=1e-6
        )


class TestGeneralizedTopologies:
    """The non-paper topologies — cifar10_full (overlapping 3x3/stride-2
    pool) and cifar10_strided (stride-2 downsampling convs) — lower
    through compile_dhm on all three backends, matching the hand-composed
    reference exactly."""

    @pytest.mark.parametrize("name", sorted(EXTRA_TOPOLOGIES))
    @pytest.mark.parametrize("backend", ["ref", "pallas"])
    def test_fp32_matches_reference(self, name, backend):
        topo = EXTRA_TOPOLOGIES[name]
        params, x = _mk_inputs(topo)
        plan = compile_dhm(topo, params, backend=backend)
        ref = cnn_apply_reference(params, topo, x)
        np.testing.assert_allclose(
            np.asarray(plan(x)), np.asarray(ref), rtol=1e-4, atol=1e-4
        )

    @pytest.mark.slow
    @pytest.mark.parametrize("name", sorted(EXTRA_TOPOLOGIES))
    def test_fp32_oracle_backend_matches_reference(self, name):
        """The exact Pallas kernel program (interpreter oracle) handles the
        generalized layer vocabulary end to end."""
        topo = EXTRA_TOPOLOGIES[name]
        params, x = _mk_inputs(topo, batch=1)
        plan = compile_dhm(topo, params, backend="pallas_interpret")
        ref = cnn_apply_reference(params, topo, x)
        np.testing.assert_allclose(
            np.asarray(plan(x)), np.asarray(ref), rtol=1e-4, atol=1e-5
        )

    @pytest.mark.parametrize("name", sorted(EXTRA_TOPOLOGIES))
    def test_quantized_plan_matches_fake_quant_reference(self, name):
        """Weights + in-kernel feature-stream quantization through the
        generalized epilogue (overlapping pool / strided conv)."""
        topo = EXTRA_TOPOLOGIES[name]
        params, x = _mk_inputs(topo)
        plan = compile_dhm(
            topo, params, quant=QuantSpec(weight_bits=6, act_bits=6),
            backend="pallas",
        )
        ref = cnn_apply_reference(params, topo, x, weight_bits=6, act_bits=6)
        np.testing.assert_allclose(
            np.asarray(plan(x)), np.asarray(ref), rtol=1e-4, atol=1e-4
        )

    def test_block_w_does_not_change_logits(self):
        """Width blocking is a pure tiling decision: a plan compiled with
        a small block_w (column halo exercised) produces the same numbers
        as the unblocked plan, through the kernel oracle."""
        topo = CNNTopology(
            name="wide", input_hw=(10, 26), input_channels=2,
            conv_layers=(
                ConvLayerSpec(n_out=4, kernel=3, padding="SAME", pool=3,
                              pool_stride=2, act="relu"),
            ),
            fc_dims=(), n_classes=2,
        )
        params, x = _mk_inputs(topo, batch=1)
        full = compile_dhm(topo, params, backend="pallas_interpret")(x)
        blocked = compile_dhm(
            topo, params, backend="pallas_interpret", block_w=4
        )(x)
        np.testing.assert_array_equal(np.asarray(full), np.asarray(blocked))

    def test_rectangular_input_plan(self):
        """(H, W) input frames lower end to end (no square assumption left
        on the compiler path)."""
        topo = CNNTopology(
            name="rect", input_hw=(14, 18), input_channels=2,
            conv_layers=(
                ConvLayerSpec(n_out=4, kernel=3, padding="SAME", pool=3,
                              pool_stride=2, act="relu"),
                ConvLayerSpec(n_out=6, kernel=3, padding="SAME", stride=2,
                              pool=0, act="relu"),
            ),
            fc_dims=(8,), n_classes=3,
        )
        params, x = _mk_inputs(topo)
        plan = compile_dhm(topo, params, backend="pallas")
        ref = cnn_apply_reference(params, topo, x)
        np.testing.assert_allclose(
            np.asarray(plan(x)), np.asarray(ref), rtol=1e-4, atol=1e-4
        )


class TestFusedStreamQuant:
    """The act_bits feature-stream quantization lives inside the fused
    kernel epilogue and agrees with fake_quant_ste on every backend."""

    @pytest.mark.parametrize("backend", ["pallas", "pallas_interpret", "ref"])
    def test_matches_fake_quant_ste_reference(self, backend):
        from repro.core.quant.fixed_point import FixedPointSpec, fake_quant_ste
        from repro.kernels.stream_conv import (
            stream_conv_block,
            stream_conv_block_ref,
        )

        kx, kw, kb = jax.random.split(jax.random.PRNGKey(7), 3)
        x = jax.random.normal(kx, (2, 13, 13, 3))
        w = jax.random.normal(kw, (5, 5, 3, 8)) * 0.2
        b = jax.random.normal(kb, (8,)) * 0.1
        out = stream_conv_block(
            x, w, b, padding="SAME", act="relu", pool=2, act_bits=4,
            backend=backend,
        )
        unquant = stream_conv_block_ref(
            x, w, b, padding="SAME", act="relu", pool=2
        )
        ref = fake_quant_ste(unquant, FixedPointSpec(bits=4, frac_bits=2))
        np.testing.assert_allclose(
            np.asarray(out), np.asarray(ref), rtol=1e-4, atol=1e-5
        )

    def test_quant_is_inside_the_kernel(self):
        """Structural: with act_bits set, the rounding happens inside the
        pallas_call body (fused epilogue), with no separate post-conv quant
        pass in the surrounding graph."""
        from repro.kernels.stream_conv import stream_conv_block

        x = jnp.ones((1, 16, 16, 3))
        w = jnp.ones((5, 5, 3, 8))
        b = jnp.ones((8,))
        jaxpr = jax.make_jaxpr(
            lambda a, ww, bb: stream_conv_block(
                a, ww, bb, padding="SAME", act="relu", pool=2, act_bits=4,
                backend="pallas_interpret",
            )
        )(x, w, b).jaxpr
        total = _count_primitive(jaxpr, "round")
        inside = _count_primitive_in_pallas(jaxpr, "round")
        assert inside == 1
        assert total == inside  # nothing quantizes the stream outside

    def test_compiled_plan_uses_in_kernel_quant(self):
        """The whole quantized plan traces with its only feature-stream
        rounding inside pallas_call bodies (one per conv stage) —
        enforced through the static-analysis registry (invariant V007),
        so this test and the CLI gate can never drift apart."""
        from repro.analysis.verify import verify_plan

        topo = LENET5
        params, _x = _mk_inputs(topo, batch=1)
        plan = compile_dhm(
            topo, params, quant=QuantSpec(act_bits=4),
            backend="pallas_interpret",
        )
        assert verify_plan(plan, ids=("V007",)) == []


class TestStructureCompilerPath:
    """The structural single-matmul guarantee carries over to the compiler
    path: a compiled conv stage still traces to exactly ONE dot_general per
    row block and zero lax.conv."""

    @pytest.mark.parametrize("backend", ["pallas", "pallas_interpret"])
    def test_single_matmul_per_row_block(self, backend):
        topo = CNNTopology(
            name="one", input_hw=32, input_channels=3,
            conv_layers=(
                ConvLayerSpec(n_out=32, kernel=5, padding="SAME", pool=2,
                              act="relu"),
            ),
            fc_dims=(), n_classes=2,
        )
        from repro.analysis.verify import verify_plan

        params = init_cnn(jax.random.PRNGKey(0), topo)
        plan = compile_dhm(topo, params, backend=backend)
        # one conv layer -> exactly one dot_general and zero lax.conv:
        # registry invariants V001/V003 (same checks the CLI gate runs)
        assert verify_plan(plan, ids=("V001", "V003")) == []

    def test_make_conv_stage_is_compiler_emitted(self):
        """The pipeline stage-body builder and emit_conv_stage produce the
        same computation (one lowering path for stage bodies)."""
        import types

        from repro.core.dhm.pipeline import make_conv_stage

        k1, k2 = jax.random.split(jax.random.PRNGKey(0))
        params = {
            "w": jax.random.normal(k1, (3, 3, 4, 4)) * 0.3,
            "b": jnp.zeros((4,)),
        }
        x = jax.random.normal(k2, (2, 8, 8, 4))
        via_pipeline = make_conv_stage(padding="SAME", act="tanh", pool=0)
        spec = types.SimpleNamespace(padding="SAME", act="tanh", pool=0)
        via_compiler = emit_conv_stage((spec,))
        np.testing.assert_array_equal(
            np.asarray(via_pipeline(params, x)),
            np.asarray(via_compiler([params], x)),
        )


PIPELINE_PLAN_SUBPROCESS = r"""
import jax, jax.numpy as jnp, numpy as np
from repro.core.dhm.compiler import compile_dhm
from repro.models.cnn import LENET5, init_cnn

# LeNet5 with 2 stages is genuinely heterogeneous (28x28x1 -> 12x12x20 ->
# 4x4x50): the old executor refused it; the boxed executor streams it
# bit-exact vs the single-device plan at the same batch grain.
plan = compile_dhm(LENET5, init_cnn(jax.random.PRNGKey(0), LENET5), n_stages=2)
mbs = jax.random.normal(jax.random.PRNGKey(1), (4, 2, 28, 28, 1))
seq = jnp.stack([plan.features(mbs[i]) for i in range(4)])
out = plan.run_pipelined(mbs, mesh=jax.make_mesh((2,), ('stage',)))
assert (np.asarray(out) == np.asarray(seq)).all(), 'stage-mesh plan mismatch'
# 2D (stage, data) mesh: batch sharding composes with the stage pipeline.
mesh2 = jax.make_mesh((2, 2), ('stage', 'data'))
out2 = plan.run_pipelined(mbs, mesh=mesh2, data_axis='data')
assert np.allclose(np.asarray(out2), np.asarray(seq), atol=1e-5), '2D mismatch'
print('OK')
"""


class TestPipelinedPlan:
    def test_heterogeneous_stages_emit_pipeline_spec(self):
        """Heterogeneous stages pipeline now: the plan emits per-stage
        closures + chaining StageIOSpec geometry instead of raising."""
        params, _ = _mk_inputs(LENET5)
        plan = compile_dhm(LENET5, params, n_stages=2)
        fns, stage_params, io = plan.pipeline_spec()
        assert len(fns) == 2 and len(stage_params) == 2
        assert io[0].in_shape == (28, 28, 1)
        assert io[0].out_shape == io[1].in_shape == (12, 12, 20)
        assert io[1].out_shape == (4, 4, 50)

    @pytest.mark.slow
    def test_pipelined_plan_matches_single_device_4dev(self):
        """The compiled heterogeneous staged plan on a forced-host-device
        mesh == the same plan run sequentially on one device (subprocess
        with forced host devices)."""
        repo_root = pathlib.Path(__file__).resolve().parents[1]
        res = subprocess.run(
            [sys.executable, "-c", PIPELINE_PLAN_SUBPROCESS],
            capture_output=True,
            text=True,
            env={
                **os.environ,
                "XLA_FLAGS": "--xla_force_host_platform_device_count=4",
                "PYTHONPATH": str(repo_root / "src"),
            },
            cwd=str(repo_root),
            timeout=600,
        )
        assert res.returncode == 0, res.stderr[-2000:]
        assert "OK" in res.stdout


class TestPow2OddWidth:
    """Satellite bugfix: odd output widths pack via an auto-pad instead of
    raising (kernel wrapper) or being silently skipped (serving walk)."""

    def test_quantize_weights_odd_n(self):
        from repro.core.quant.pow2 import project_pow2
        from repro.kernels.pow2_matmul import pow2_matmul, quantize_weights

        kx, kw = jax.random.split(jax.random.PRNGKey(1))
        x = jax.random.normal(kx, (9, 13))
        w = jax.random.normal(kw, (13, 7))
        packed, scale = quantize_weights(w)
        assert scale.shape == (7,)
        assert packed.shape == (13, 4)  # ceil(7/2) bytes
        for backend in ("ref", "pallas", "pallas_interpret"):
            out = pow2_matmul(
                x, packed, scale, block_m=8, block_n=8, block_k=8,
                backend=backend,
            )
            assert out.shape == (9, 7)
            np.testing.assert_allclose(
                np.asarray(out),
                np.asarray(x @ project_pow2(w, channel_axis=1)),
                rtol=1e-4, atol=1e-4,
            )

    def test_inconsistent_packed_scale_raises(self):
        from repro.kernels.pow2_matmul import pow2_matmul

        x = jnp.ones((4, 6))
        packed = jnp.zeros((6, 2), jnp.uint8)  # 4 columns
        scale = jnp.ones((7,))  # claims 7
        with pytest.raises(ValueError, match="inconsistent"):
            pow2_matmul(x, packed, scale)

    def test_linear_pack_odd_n(self):
        from repro.core.quant.pow2 import project_pow2
        from repro.models.layers import linear, pack_linear_pow2

        k1, k2 = jax.random.split(jax.random.PRNGKey(2))
        p = {"w": jax.random.normal(k1, (12, 7)), "b": jnp.ones((7,))}
        x = jax.random.normal(k2, (3, 12))
        out = linear(x, pack_linear_pow2(p))
        ref = x @ project_pow2(p["w"], channel_axis=1) + p["b"]
        assert out.shape == (3, 7)
        np.testing.assert_allclose(
            np.asarray(out), np.asarray(ref), rtol=1e-4, atol=1e-4
        )
