"""Data-pipeline tests: synthetic streams, determinism, sharded loader.
Property sweeps are seeded parametrized cases (no hypothesis dependency)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data import (
    ShardedLoader,
    TokenStreamConfig,
    make_image_dataset,
    synthetic_token_batches,
)


class TestImageDataset:
    def test_shapes_and_determinism(self):
        a = make_image_dataset(hw=16, channels=2, n_train_per_class=8,
                               n_test_per_class=4, seed=7)
        b = make_image_dataset(hw=16, channels=2, n_train_per_class=8,
                               n_test_per_class=4, seed=7)
        assert a.x_train.shape == (80, 16, 16, 2)
        assert a.x_test.shape == (40, 16, 16, 2)
        np.testing.assert_array_equal(a.x_train, b.x_train)

    def test_different_seeds_differ(self):
        a = make_image_dataset(hw=8, channels=1, n_train_per_class=4,
                               n_test_per_class=2, seed=0)
        b = make_image_dataset(hw=8, channels=1, n_train_per_class=4,
                               n_test_per_class=2, seed=1)
        assert not np.allclose(a.x_train, b.x_train)

    def test_labels_balanced(self):
        ds = make_image_dataset(hw=8, channels=1, n_train_per_class=8,
                                n_test_per_class=2, seed=0, n_classes=5)
        counts = np.bincount(np.asarray(ds.y_train), minlength=5)
        assert np.all(counts == 8)


class TestTokenStream:
    def test_deterministic_by_seed(self):
        cfg = TokenStreamConfig(vocab_size=97, seq_len=32, batch_size=4)
        a = next(synthetic_token_batches(cfg, seed=3))["tokens"]
        b = next(synthetic_token_batches(cfg, seed=3))["tokens"]
        np.testing.assert_array_equal(a, b)

    def test_shapes_and_range(self):
        cfg = TokenStreamConfig(vocab_size=97, seq_len=32, batch_size=4)
        t = next(synthetic_token_batches(cfg, seed=0))["tokens"]
        assert t.shape == (4, 33)
        assert t.min() >= 0 and t.max() < 97

    def test_recurrence_structure(self):
        """With eps=0 the stream is exactly the affine recurrence."""
        cfg = TokenStreamConfig(vocab_size=101, seq_len=16, batch_size=2,
                                noise_eps=0.0)
        t = next(synthetic_token_batches(cfg, seed=0))["tokens"]
        pred = (t[:, :-1] * cfg.mult + cfg.add) % cfg.vocab_size
        np.testing.assert_array_equal(pred, t[:, 1:])

    @pytest.mark.parametrize(
        "eps,v",
        [(0.01, 8), (0.05, 16), (0.1, 64), (0.2, 97), (0.3, 128),
         (0.4, 256), (0.49, 512), (0.25, 11), (0.15, 33), (0.5, 500)],
    )
    def test_property_loss_floor_bounds(self, eps, v):
        cfg = TokenStreamConfig(vocab_size=v, seq_len=8, batch_size=1,
                                noise_eps=eps)
        floor = cfg.loss_floor
        assert 0.0 < floor < np.log(v) + 1e-6


class TestShardedLoader:
    def test_prefetch_preserves_order(self):
        def gen():
            for i in range(5):
                yield {"x": np.full((2, 3), i, np.float32)}

        loader = ShardedLoader(gen(), prefetch=3)
        vals = [int(b["x"][0, 0]) for b in loader]
        assert vals == [0, 1, 2, 3, 4]

    def test_device_put(self):
        def gen():
            yield {"x": np.ones((2, 2), np.float32)}

        batch = next(iter(ShardedLoader(gen())))
        assert isinstance(batch["x"], jax.Array)

    def test_sharded_put_single_device(self):
        from jax.sharding import NamedSharding, PartitionSpec as P

        mesh = jax.make_mesh((1,), ("data",))
        sh = NamedSharding(mesh, P("data"))

        def gen():
            yield {"x": np.arange(8, dtype=np.float32).reshape(4, 2)}

        batch = next(iter(ShardedLoader(gen(), shardings={"x": sh})))
        np.testing.assert_array_equal(
            np.asarray(batch["x"]), np.arange(8).reshape(4, 2)
        )
