"""Tests for the DHM core: DPN graph expansion (paper Fig. 2 counts),
resource model (Table 2 calibration), throughput model (Table 4),
stage partitioning, and the streaming pipeline executor."""
import dataclasses
import subprocess
import sys

import pytest

from repro.core.dhm import (
    CYCLONE_V_5CGXFC9E7,
    KINTEX7_XC7Z045,
    MultiplierStrategy,
    balance_report,
    cnn_to_dpn,
    dhm_throughput_gops,
    estimate_resources,
    layer_costs_to_dpn,
    partition_stages,
)
from repro.core.dhm.graph import ActorKind
from repro.core.dhm.resources import PAPER_TABLE1
from repro.models.cnn import CIFAR10, LENET5, CNNTopology, ConvLayerSpec


class TestGraph:
    def test_fig2_actor_counts(self):
        """Paper Fig. 2: C=3, N=5, K=3 -> 15 conv engines (135 multipliers,
        15 adder trees), 5 neuron sums (total 20 sums), 5 activations."""
        fig2 = CNNTopology(
            name="fig2",
            input_hw=8,
            input_channels=3,
            conv_layers=(ConvLayerSpec(n_out=5, kernel=3, padding="SAME", pool=0),),
            fc_dims=(),
            n_classes=2,
        )
        g = cnn_to_dpn(fig2, bits=8)
        assert g.count(ActorKind.CONV_ENGINE) == 15
        assert g.total_multipliers() == 135
        assert g.total_adders() == 20  # 15 trees + 5 neuron sums
        assert g.count(ActorKind.ACTIVATION) == 5

    def test_lenet_multiplier_count(self):
        g = cnn_to_dpn(LENET5, bits=5)
        assert g.total_multipliers() == 25500  # 500 + 25000

    def test_validate_catches_duplicates(self):
        g = cnn_to_dpn(LENET5, bits=3)
        g.actors.append(g.actors[-1])
        with pytest.raises(ValueError):
            g.validate()

    def test_layer_costs_dpn(self):
        g = layer_costs_to_dpn("lm", [{"flops": 10.0}] * 4)
        assert g.count(ActorKind.BLOCK) == 4
        assert g.total_flops() == 40.0

    def test_pool_actor_general_window(self):
        """Pool actors model window != stride correctly (cifar10_full:
        3x3/stride-2): output dims follow the VALID sliding rule — NOT
        h_out // window — and the streaming pool buffers (window - 1)
        conv-output lines."""
        from repro.models.cnn import CIFAR10_FULL

        bits = 6
        g = cnn_to_dpn(CIFAR10_FULL, bits=bits)
        # Layer 1: conv out 32x32, 3x3/2 pool -> 15x15.
        p1 = g.actor("pool1_n0")
        assert p1.line_buffer_bits == (3 - 1) * 32 * bits
        assert p1.stream_bytes == 15 * 15 * bits / 8.0
        # Layer 2 consumes the POOLED 15-wide frame: its window actors
        # buffer 15-pixel lines, its engines work on the 15x15 conv out.
        w2 = g.actor("win2_c0")
        assert w2.line_buffer_bits == (5 - 1) * 15 * bits
        e2 = g.actor("conv2_n0_c0")
        assert e2.flops == 2.0 * 5 * 5 * 15 * 15
        # Layer 3: conv out 7x7, pool -> 3x3 (the old h_out // pool rule
        # would have claimed 7 // 3 = 2).
        p3 = g.actor("pool3_n0")
        assert p3.stream_bytes == 3 * 3 * bits / 8.0

    def test_strided_conv_dpn(self):
        """Strided convs shrink the engine payloads (conv output dims
        already reflect the stride) and the window buffers keep the full
        input line width."""
        from repro.models.cnn import CIFAR10_STRIDED

        bits = 6
        g = cnn_to_dpn(CIFAR10_STRIDED, bits=bits)
        e1 = g.actor("conv1_n0_c0")
        assert e1.flops == 2.0 * 5 * 5 * 16 * 16  # 32 -> 16 via stride 2
        w1 = g.actor("win1_c0")
        assert w1.line_buffer_bits == (5 - 1) * 32 * bits  # input lines

    def test_rectangular_frame_dpn(self):
        """(H, W) frames expand without any square assumption: stream
        bytes use H_p * W_p, not H_p**2."""
        from repro.models.cnn import CNNTopology, ConvLayerSpec

        topo = CNNTopology(
            name="rect", input_hw=(12, 20), input_channels=1,
            conv_layers=(
                ConvLayerSpec(n_out=2, kernel=3, padding="SAME", pool=2),
            ),
            fc_dims=(), n_classes=2,
        )
        g = cnn_to_dpn(topo, bits=8)
        p = g.actor("pool1_n0")
        assert p.stream_bytes == (12 // 2) * (20 // 2) * 8 / 8.0


class TestResources:
    def test_table2_dsp_strategy_overflows(self):
        """Paper: DSP-based LeNet5 needs ~72x the device's DSP blocks."""
        g = cnn_to_dpn(LENET5, bits=5)
        rep = estimate_resources(
            g, CYCLONE_V_5CGXFC9E7, bits=5, strategy=MultiplierStrategy.DSP
        )
        assert not rep.fits
        assert 60 < rep.dsp_utilization < 80  # paper: 71.59x

    def test_table2_le_strategy(self):
        """Paper: LE-based needs 433,500 ALMs = 381% of the Cyclone V."""
        g = cnn_to_dpn(LENET5, bits=5)
        rep = estimate_resources(
            g, CYCLONE_V_5CGXFC9E7, bits=5, strategy=MultiplierStrategy.LE
        )
        assert not rep.fits
        assert rep.logic_used == pytest.approx(433_500, rel=0.02)

    def test_table2_le_const_fits(self):
        """Paper: constant-specialized multipliers make LeNet5 FIT on the
        Cyclone V (50,452 ALMs = 44%); our closed-form model lands below
        the device cap (the paper's absolute figure embeds synthesis-tool
        sharing; fractions are Table 1's, measured at 3 bits)."""
        g = cnn_to_dpn(LENET5, bits=5)
        rep = estimate_resources(
            g,
            CYCLONE_V_5CGXFC9E7,
            bits=5,
            strategy=MultiplierStrategy.LE_CONST,
            fractions=PAPER_TABLE1["lenet5"],
        )
        assert rep.fits
        assert rep.logic_utilization < 0.44  # paper's measured upper bound

    def test_specialization_factor(self):
        """Paper: tailored multipliers reduce logic >= 8.6x vs generic LE
        (their 8.6x is a lower bound for us: Table 1's 3-bit fractions have
        more zeros than the unpublished 5-bit ones the paper synthesized)."""
        g = cnn_to_dpn(LENET5, bits=5)
        le = estimate_resources(
            g, CYCLONE_V_5CGXFC9E7, bits=5, strategy=MultiplierStrategy.LE
        )
        const = estimate_resources(
            g,
            CYCLONE_V_5CGXFC9E7,
            bits=5,
            strategy=MultiplierStrategy.LE_CONST,
            fractions=PAPER_TABLE1["lenet5"],
        )
        factor = le.logic_used / const.logic_used
        assert factor >= 8.6  # paper's measured reduction

    def test_table3_all_nets_fit_both_devices(self):
        """Paper Table 3: all three CNNs fit both embedded devices with
        zero DSP blocks and tiny memory."""
        for name, bits in (("lenet5", 3), ("cifar10", 6), ("svhn", 6)):
            topo = {"lenet5": LENET5, "cifar10": CIFAR10, "svhn": CIFAR10}[name]
            g = cnn_to_dpn(topo, bits=bits)
            for dev in (CYCLONE_V_5CGXFC9E7, KINTEX7_XC7Z045):
                rep = estimate_resources(
                    g,
                    dev,
                    bits=bits,
                    strategy=MultiplierStrategy.LE_CONST,
                    fractions=PAPER_TABLE1[name],
                )
                assert rep.fits, rep.summary()
                assert rep.dsp_used == 0  # zero DSP blocks, like the paper
                # memory footprint is line buffers only: ~1% of BRAM
                assert rep.memory_bits < 0.02 * dev.bram_bits

    def test_table3_orderings(self):
        """Qualitative Table 3 claims: logic grows with CNN size, and the
        sparser SVHN (more zeros) uses less logic than Cifar10."""
        reps = {}
        for name, bits in (("lenet5", 3), ("cifar10", 6), ("svhn", 6)):
            topo = {"lenet5": LENET5, "cifar10": CIFAR10, "svhn": CIFAR10}[name]
            g = cnn_to_dpn(topo, bits=bits)
            reps[name] = estimate_resources(
                g,
                CYCLONE_V_5CGXFC9E7,
                bits=bits,
                strategy=MultiplierStrategy.LE_CONST,
                fractions=PAPER_TABLE1[name],
            )
        assert reps["lenet5"].logic_used < reps["svhn"].logic_used
        assert reps["svhn"].logic_used < reps["cifar10"].logic_used


class TestThroughput:
    def test_table4_haddoc2_rows(self):
        """Reproduce the three Haddoc2 rows of Table 4 (<2%)."""
        assert dhm_throughput_gops(LENET5, 65.71).gops == pytest.approx(
            318.48, rel=0.02
        )
        assert dhm_throughput_gops(CIFAR10, 63.89).gops == pytest.approx(
            515.78, rel=0.02
        )
        assert dhm_throughput_gops(CIFAR10, 54.17).gops == pytest.approx(
            437.30, rel=0.02
        )

    def test_fpgaconvnet_speedup(self):
        """Paper: x2.63 over fpgaConvNet on the Cifar10 workload (Zynq)."""
        ours = dhm_throughput_gops(CIFAR10, 54.17).gops
        fpgaconvnet = 166.16
        assert ours / fpgaconvnet == pytest.approx(2.63, rel=0.03)


class TestPartition:
    def test_exact_small(self):
        pa = partition_stages([1, 1, 1, 4, 1, 1, 1], 3)
        assert pa.bottleneck == 4.0
        assert pa.n_stages == 3
        assert pa.boundaries[0] == 0 and pa.boundaries[-1] == 7

    def test_uniform_perfect(self):
        pa = partition_stages([2.0] * 8, 4)
        assert pa.stage_costs == (4.0, 4.0, 4.0, 4.0)

    def test_single_stage(self):
        pa = partition_stages([3, 5, 2], 1)
        assert pa.bottleneck == 10.0

    def test_too_many_stages_raises(self):
        with pytest.raises(ValueError):
            partition_stages([1, 2], 3)

    def test_stage_of_layer_roundtrip(self):
        pa = partition_stages([1, 2, 3, 4, 5, 6], 3)
        for layer in range(6):
            s = pa.stage_of_layer(layer)
            assert layer in pa.layers_of_stage(s)

    @pytest.mark.parametrize("seed", range(40))
    def test_property_optimal_vs_greedy(self, seed):
        """DP bottleneck always >= max(cost) and >= total/S (lower bounds),
        over seeded random cost vectors and stage counts."""
        import random

        rnd = random.Random(seed)
        n = rnd.randint(2, 30)
        s = min(rnd.randint(1, 6), n)
        costs = [rnd.uniform(0.1, 10.0) for _ in range(n)]
        pa = partition_stages(costs, s)
        assert pa.bottleneck >= max(costs) - 1e-9
        assert pa.bottleneck >= sum(costs) / s - 1e-9
        assert sum(pa.stage_costs) == pytest.approx(sum(costs))

    def test_balance_report(self):
        br = balance_report([1.0] * 8, 4, 16)
        assert br.bubble_fraction == pytest.approx(3 / 19)
        assert br.imbalance == pytest.approx(1.0)


class TestConvStage:
    def test_make_conv_stage_matches_unfused(self):
        """The fused conv stage body == the unfused reference composition,
        and is shape-homogeneous (SAME, pool=0, C == N)."""
        import jax
        import jax.numpy as jnp

        from repro.core.dhm.pipeline import make_conv_stage
        from repro.kernels.stream_conv import stream_conv_block_ref

        k1, k2 = jax.random.split(jax.random.PRNGKey(0))
        params = {
            "w": jax.random.normal(k1, (3, 3, 4, 4)) * 0.3,
            "b": jnp.zeros((4,)),
        }
        x = jax.random.normal(k2, (2, 8, 8, 4))
        stage_fn = make_conv_stage(padding="SAME", act="tanh", pool=0)
        y = stage_fn(params, x)
        assert y.shape == x.shape
        ref = stream_conv_block_ref(
            x, params["w"], params["b"], padding="SAME", act="tanh", pool=0
        )
        import numpy as np

        np.testing.assert_allclose(np.asarray(y), np.asarray(ref),
                                   rtol=1e-4, atol=1e-5)


PIPELINE_SUBPROCESS = r"""
import jax, jax.numpy as jnp, numpy as np
from repro.core.dhm.pipeline import PipelineConfig, pipeline_forward, stack_stage_params
mesh = jax.make_mesh((4,), ('stage',))
Ws = [jax.random.normal(jax.random.PRNGKey(i), (8, 8)) * 0.3 for i in range(4)]
params = stack_stage_params([{'w': w} for w in Ws])
mbs = jax.random.normal(jax.random.PRNGKey(9), (6, 2, 8))
def stage_fn(p, x):
    return jnp.tanh(x @ p['w'])
out = pipeline_forward(stage_fn, params, mbs, mesh=mesh, cfg=PipelineConfig(4, 6))
ref = mbs
for w in Ws:
    ref = jnp.tanh(ref @ w)
assert np.allclose(np.asarray(out), np.asarray(ref), atol=1e-5), 'pipeline mismatch'
print('OK')
"""


class TestPipeline:
    @pytest.mark.slow
    def test_pipeline_matches_sequential_4dev(self):
        """Streaming shard_map pipeline == sequential layer application
        (run in a subprocess with 4 forced host devices)."""
        import os
        import pathlib

        repo_root = pathlib.Path(__file__).resolve().parents[1]
        res = subprocess.run(
            [sys.executable, "-c", PIPELINE_SUBPROCESS],
            capture_output=True,
            text=True,
            env={
                **os.environ,
                "XLA_FLAGS": "--xla_force_host_platform_device_count=4",
                "PYTHONPATH": str(repo_root / "src"),
            },
            cwd=str(repo_root),
            timeout=600,
        )
        assert res.returncode == 0, res.stderr[-2000:]
        assert "OK" in res.stdout
