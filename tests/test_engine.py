"""Tests for the serving engine (single-device fast tier): the request
queue / micro-batching, double-buffered donated closures, warmup, stats,
and the execution paths extracted from the compiler (eager forward,
cached jitted forward, pipeline_spec / StageIOSpec emission)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.dhm.compiler import QuantSpec, compile_dhm
from repro.core.dhm.engine import Engine, forward, plan_jitted_forward
from repro.core.dhm.pipeline import StageIOSpec, derive_io_specs
from repro.models.cnn import ALL_TOPOLOGIES, LENET5, init_cnn


def _plan(name="lenet5", n_stages=1, **quant_kw):
    topo = ALL_TOPOLOGIES[name]
    params = init_cnn(jax.random.PRNGKey(0), topo)
    quant = QuantSpec(**quant_kw) if quant_kw else QuantSpec()
    return topo, compile_dhm(topo, params, quant=quant, n_stages=n_stages)


def _frames(topo, n, seed=1):
    h, w = topo.input_shape
    return jax.random.normal(
        jax.random.PRNGKey(seed), (n, h, w, topo.input_channels)
    )


class TestStageIO:
    def test_compiled_stages_carry_chaining_io(self):
        """The compiler emits a StageIOSpec per stage that chains
        edge-to-edge and ends at the topology's feature shape."""
        topo, plan = _plan("cifar10", n_stages=3)
        h, w = topo.input_shape
        assert plan.stages[0].io.in_shape == (h, w, topo.input_channels)
        for a, b in zip(plan.stages[:-1], plan.stages[1:]):
            assert a.io.out_shape == b.io.in_shape
        assert plan.stages[-1].io.out_shape == topo.feature_shape()

    def test_heterogeneous_stages_have_pipeline_spec(self):
        """Heterogeneous stages (different specs per stage) now emit a
        pipeline spec instead of refusing — the old homogeneity
        restriction is gone."""
        _, plan = _plan("lenet5", n_stages=2)
        fns, params, io = plan.pipeline_spec()
        assert len(fns) == len(params) == len(io) == 2
        assert io[0].out_shape == io[1].in_shape
        assert io[0].in_shape != io[1].in_shape  # genuinely heterogeneous

    def test_derive_io_specs_matches_compiler(self):
        """eval_shape chaining over the emitted stage bodies recovers the
        same geometry the compiler computed from the topology."""
        topo, plan = _plan("cifar10_full", n_stages=3)
        fns, params, io = plan.pipeline_spec()
        derived = derive_io_specs(fns, params, io[0].in_shape)
        assert tuple(derived) == tuple(io)

    def test_bad_io_spec_raises(self):
        with pytest.raises(ValueError, match="positive ints"):
            StageIOSpec(in_shape=(0, 4, 4), out_shape=(4, 4, 4))


class TestEngineQueue:
    def test_requests_match_plan(self):
        """Queued requests of uneven sizes are packed into micro-batches
        (zero-padded tail) and each gets exactly its own logits back."""
        topo, plan = _plan("lenet5")
        eng = Engine(plan, microbatch=4)
        x = _frames(topo, 7)
        r1, r2, r3 = eng.submit(x[:3]), eng.submit(x[3:6]), eng.submit(x[6])
        eng.flush()
        got = jnp.concatenate([r1.result(), r2.result(), r3.result()])
        np.testing.assert_allclose(
            np.asarray(got), np.asarray(plan(x)), rtol=1e-4, atol=1e-5
        )
        assert r3.result().shape == (1, topo.n_classes)  # single frame

    def test_result_triggers_flush(self):
        topo, plan = _plan("lenet5")
        eng = Engine(plan, microbatch=2)
        req = eng.submit(_frames(topo, 2))
        assert not req.done
        out = req.result()  # implicit flush
        assert req.done and out.shape == (2, topo.n_classes)
        assert req.latency_s > 0

    def test_no_retrace_across_flushes(self):
        """The donated closure is built once; repeated flushes reuse it
        (the jit cache holds exactly one entry)."""
        topo, plan = _plan("lenet5")
        eng = Engine(plan, microbatch=4)
        for seed in range(3):
            eng.infer(_frames(topo, 4, seed=seed))
        assert plan_jitted_forward(plan, donate=True)._cache_size() == 1

    def test_quantized_plan_serves(self):
        topo, plan = _plan("lenet5", weight_bits=3, act_bits=3)
        eng = Engine(plan, microbatch=2)
        x = _frames(topo, 2)
        np.testing.assert_allclose(
            np.asarray(eng.infer(x)), np.asarray(plan(x)),
            rtol=1e-4, atol=1e-5,
        )

    def test_stats(self):
        topo, plan = _plan("lenet5")
        eng = Engine(plan, microbatch=4)
        eng.infer(_frames(topo, 6))
        st = eng.stats()
        assert st.n_requests == 1
        assert st.n_frames == 6
        assert st.n_batches == 2  # 6 frames -> two 4-frame µbatches
        assert st.frames_per_s > 0
        assert st.max_latency_s >= st.mean_latency_s > 0
        assert "frames/s" in st.summary()

    def test_flush_empty_queue_is_noop(self):
        _, plan = _plan("lenet5")
        eng = Engine(plan, microbatch=2)
        eng.flush()
        assert eng.stats().n_frames == 0

    def test_bad_frame_shape_raises(self):
        topo, plan = _plan("lenet5")
        eng = Engine(plan, microbatch=2)
        with pytest.raises(ValueError, match="expected frames"):
            eng.submit(jnp.zeros((2, 14, 14, 1)))

    def test_bad_microbatch_raises(self):
        _, plan = _plan("lenet5")
        with pytest.raises(ValueError, match="microbatch"):
            Engine(plan, microbatch=0)

    def test_undonated_engine(self):
        topo, plan = _plan("lenet5")
        eng = Engine(plan, microbatch=2, donate=False, warmup=False)
        x = _frames(topo, 2)
        out = eng.infer(x)
        np.testing.assert_allclose(
            np.asarray(out), np.asarray(plan(x)), rtol=1e-4, atol=1e-5
        )


class TestExtractedExecution:
    def test_forward_is_cnn_apply_path(self):
        """engine.forward == the eager stage/head composition cnn_apply
        routes through (bitwise — same closures, same order)."""
        topo, plan = _plan("lenet5")
        x = _frames(topo, 2)
        np.testing.assert_array_equal(
            np.asarray(forward(plan, x)),
            np.asarray(plan.head_fn(plan.features(x))),
        )

    def test_jitted_forward_cached_per_plan(self):
        _, plan = _plan("lenet5")
        assert plan.jitted_forward() is plan.jitted_forward()
        assert plan.jitted_forward(donate=True) is not plan.jitted_forward()


class TestPackedPow2Stacked:
    """Satellite: the stacked-weight pow2 packing that used to live inline
    in examples/serve.py is now models.layers.pack_linear_pow2 (odd widths
    zero-padded, per-layer scales via vmap)."""

    def test_stacked_pack_matches_per_layer(self):
        from repro.core.quant.pow2 import project_pow2
        from repro.models.layers import linear, pack_linear_pow2

        k1, k2 = jax.random.split(jax.random.PRNGKey(5))
        w = jax.random.normal(k1, (3, 10, 7))  # stacked, odd width
        x = jax.random.normal(k2, (3, 4, 10))
        packed = pack_linear_pow2({"w": w, "b": jnp.ones((7,))})
        assert packed["codes"].shape == (3, 10, 4)  # ceil(8/2) per layer
        assert packed["scale"].shape == (3, 1, 7)
        for layer in range(3):
            got = linear(
                x[layer],
                {
                    "codes": packed["codes"][layer],
                    "scale": packed["scale"][layer],
                    "b": packed["b"],
                },
            )
            ref = (
                x[layer] @ project_pow2(w[layer], channel_axis=1)
                + jnp.ones((7,))
            )
            np.testing.assert_allclose(
                np.asarray(got), np.asarray(ref), rtol=1e-4, atol=1e-4
            )

    def test_pack_params_pow2_walks_trees(self):
        from repro.models.layers import pack_params_pow2

        params = {
            "stack": [{"w": jnp.ones((4, 6)), "b": jnp.zeros((6,))}],
            "norm": {"scale": jnp.ones((4,))},
        }
        out = pack_params_pow2(params)
        assert "codes" in out["stack"][0] and "w" not in out["stack"][0]
        assert out["norm"]["scale"].shape == (4,)  # non-linears untouched
