"""Tests for the serving engine (single-device fast tier): the request
queue / micro-batching, double-buffered donated closures, warmup, stats,
deadline SLOs, admission control, and the execution paths extracted from
the compiler (eager forward, cached jitted forward, pipeline_spec /
StageIOSpec emission). Fault injection lives in test_faults.py."""
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.dhm.compiler import QuantSpec, compile_dhm
from repro.core.dhm.engine import (
    DeadlineExceeded,
    Engine,
    FlusherWedged,
    Shed,
    forward,
    plan_jitted_forward,
)
from repro.core.dhm.faults import DelayedFlush, FaultPlan
from repro.core.dhm.pipeline import StageIOSpec, derive_io_specs
from repro.models.cnn import ALL_TOPOLOGIES, LENET5, init_cnn


def _plan(name="lenet5", n_stages=1, **quant_kw):
    topo = ALL_TOPOLOGIES[name]
    params = init_cnn(jax.random.PRNGKey(0), topo)
    quant = QuantSpec(**quant_kw) if quant_kw else QuantSpec()
    return topo, compile_dhm(topo, params, quant=quant, n_stages=n_stages)


def _frames(topo, n, seed=1):
    h, w = topo.input_shape
    return jax.random.normal(
        jax.random.PRNGKey(seed), (n, h, w, topo.input_channels)
    )


class TestStageIO:
    def test_compiled_stages_carry_chaining_io(self):
        """The compiler emits a StageIOSpec per stage that chains
        edge-to-edge and ends at the topology's feature shape."""
        topo, plan = _plan("cifar10", n_stages=3)
        h, w = topo.input_shape
        assert plan.stages[0].io.in_shape == (h, w, topo.input_channels)
        for a, b in zip(plan.stages[:-1], plan.stages[1:]):
            assert a.io.out_shape == b.io.in_shape
        assert plan.stages[-1].io.out_shape == topo.feature_shape()

    def test_heterogeneous_stages_have_pipeline_spec(self):
        """Heterogeneous stages (different specs per stage) now emit a
        pipeline spec instead of refusing — the old homogeneity
        restriction is gone."""
        _, plan = _plan("lenet5", n_stages=2)
        fns, params, io = plan.pipeline_spec()
        assert len(fns) == len(params) == len(io) == 2
        assert io[0].out_shape == io[1].in_shape
        assert io[0].in_shape != io[1].in_shape  # genuinely heterogeneous

    def test_derive_io_specs_matches_compiler(self):
        """eval_shape chaining over the emitted stage bodies recovers the
        same geometry the compiler computed from the topology."""
        topo, plan = _plan("cifar10_full", n_stages=3)
        fns, params, io = plan.pipeline_spec()
        derived = derive_io_specs(fns, params, io[0].in_shape)
        assert tuple(derived) == tuple(io)

    def test_bad_io_spec_raises(self):
        with pytest.raises(ValueError, match="positive ints"):
            StageIOSpec(in_shape=(0, 4, 4), out_shape=(4, 4, 4))


class TestEngineQueue:
    def test_requests_match_plan(self):
        """Queued requests of uneven sizes are packed into micro-batches
        (zero-padded tail) and each gets exactly its own logits back."""
        topo, plan = _plan("lenet5")
        eng = Engine(plan, microbatch=4)
        x = _frames(topo, 7)
        r1, r2, r3 = eng.submit(x[:3]), eng.submit(x[3:6]), eng.submit(x[6])
        eng.flush()
        got = jnp.concatenate([r1.result(), r2.result(), r3.result()])
        np.testing.assert_allclose(
            np.asarray(got), np.asarray(plan(x)), rtol=1e-4, atol=1e-5
        )
        assert r3.result().shape == (1, topo.n_classes)  # single frame

    def test_result_triggers_flush(self):
        topo, plan = _plan("lenet5")
        eng = Engine(plan, microbatch=2)
        req = eng.submit(_frames(topo, 2))
        assert not req.done
        out = req.result()  # implicit flush
        assert req.done and out.shape == (2, topo.n_classes)
        assert req.latency_s > 0

    def test_no_retrace_across_flushes(self):
        """The donated closure is built once; repeated flushes reuse it
        (the jit cache holds exactly one entry)."""
        topo, plan = _plan("lenet5")
        eng = Engine(plan, microbatch=4)
        for seed in range(3):
            eng.infer(_frames(topo, 4, seed=seed))
        assert plan_jitted_forward(plan, donate=True)._cache_size() == 1

    def test_quantized_plan_serves(self):
        topo, plan = _plan("lenet5", weight_bits=3, act_bits=3)
        eng = Engine(plan, microbatch=2)
        x = _frames(topo, 2)
        np.testing.assert_allclose(
            np.asarray(eng.infer(x)), np.asarray(plan(x)),
            rtol=1e-4, atol=1e-5,
        )

    def test_stats(self):
        topo, plan = _plan("lenet5")
        eng = Engine(plan, microbatch=4)
        eng.infer(_frames(topo, 6))
        st = eng.stats()
        assert st.n_requests == 1
        assert st.n_frames == 6
        assert st.n_batches == 2  # 6 frames -> two 4-frame µbatches
        assert st.frames_per_s > 0
        assert st.max_latency_s >= st.mean_latency_s > 0
        assert "frames/s" in st.summary()

    def test_flush_empty_queue_is_noop(self):
        _, plan = _plan("lenet5")
        eng = Engine(plan, microbatch=2)
        eng.flush()
        assert eng.stats().n_frames == 0

    def test_bad_frame_shape_raises(self):
        topo, plan = _plan("lenet5")
        eng = Engine(plan, microbatch=2)
        with pytest.raises(ValueError, match="expected frames"):
            eng.submit(jnp.zeros((2, 14, 14, 1)))

    def test_bad_microbatch_raises(self):
        _, plan = _plan("lenet5")
        with pytest.raises(ValueError, match="microbatch"):
            Engine(plan, microbatch=0)

    def test_undonated_engine(self):
        topo, plan = _plan("lenet5")
        eng = Engine(plan, microbatch=2, donate=False, warmup=False)
        x = _frames(topo, 2)
        out = eng.infer(x)
        np.testing.assert_allclose(
            np.asarray(out), np.asarray(plan(x)), rtol=1e-4, atol=1e-5
        )


class TestDeadlines:
    def test_background_flusher_dispatches_for_deadline(self):
        """With a huge flush interval, only the request's deadline can
        trigger dispatch — the flusher must wake for it."""
        topo, plan = _plan("lenet5")
        with Engine(
            plan, microbatch=8, auto_flush=True, flush_interval_ms=5000.0
        ) as eng:
            req = eng.submit(_frames(topo, 1), deadline_ms=100.0)
            out = req.result(timeout=10.0)
        assert out.shape == (1, topo.n_classes)
        assert req.ok and req.latency_s < 2.0  # nowhere near the interval

    def test_background_flusher_dispatches_on_full_batch(self):
        topo, plan = _plan("lenet5")
        with Engine(
            plan, microbatch=4, auto_flush=True, flush_interval_ms=5000.0
        ) as eng:
            req = eng.submit(_frames(topo, 4))  # fills the micro-batch
            out = req.result(timeout=10.0)
        assert out.shape == (4, topo.n_classes)
        assert req.latency_s < 2.0

    def test_expired_deadline_is_a_structured_error(self):
        topo, plan = _plan("lenet5")
        eng = Engine(plan, microbatch=2)
        req = eng.submit(_frames(topo, 1), deadline_ms=0.001)
        time.sleep(0.01)
        with pytest.raises(DeadlineExceeded, match="deadline passed"):
            req.result()
        assert req.done and not req.ok
        assert eng.stats().n_deadline_exceeded == 1

    def test_default_deadline_applies(self):
        topo, plan = _plan("lenet5")
        eng = Engine(plan, microbatch=2, default_deadline_ms=50.0)
        req = eng.submit(_frames(topo, 1))
        assert req.deadline_at is not None
        assert req.result().shape == (1, topo.n_classes)

    def test_every_request_completes_under_load(self):
        """Property: a random mix of sizes / deadlines through the
        background flusher with a bounded shedding queue — every request
        completes (never hangs), with logits or a structured error, and
        the terminal-outcome counters partition the request count."""
        topo, plan = _plan("lenet5")
        rng = np.random.default_rng(0)
        n_req = 30
        with Engine(
            plan, microbatch=4, auto_flush=True, flush_interval_ms=2.0,
            max_queue=8, admission="shed_oldest",
        ) as eng:
            reqs = []
            for i in range(n_req):
                n = int(rng.integers(1, 5))
                dl = (
                    float(rng.uniform(5.0, 50.0))
                    if rng.random() < 0.5 else None
                )
                reqs.append(eng.submit(_frames(topo, n, seed=i), deadline_ms=dl))
        # stop() drained the queue: nothing may still be pending.
        for r in reqs:
            assert r.done
            if r.ok:
                out = r.result()
                assert out.shape == (r.n_frames, topo.n_classes)
                assert bool(jnp.isfinite(out).all())
            else:
                assert isinstance(r.error, (DeadlineExceeded, Shed))
        st = eng.stats()
        assert st.n_failed == st.n_invalid == st.n_rejected == 0
        assert st.n_ok + st.n_shed + st.n_deadline_exceeded == n_req
        assert st.n_ok > 0


class TestAdmission:
    def test_block_policy_drains_inline(self):
        """Without a flusher, a blocked submitter drains the queue itself
        — submission never deadlocks and every request is served."""
        topo, plan = _plan("lenet5")
        eng = Engine(plan, microbatch=2, max_queue=1, admission="block")
        r1 = eng.submit(_frames(topo, 1))
        r2 = eng.submit(_frames(topo, 1, seed=2))  # forces an inline flush
        assert r1.done and r1.ok
        assert r2.result().shape == (1, topo.n_classes)
        assert eng.stats().n_ok == 2

    def test_admission_policy_validated(self):
        _, plan = _plan("lenet5")
        with pytest.raises(ValueError, match="admission policy"):
            Engine(plan, admission="drop_table")

    def test_hyphenated_policy_normalized(self):
        _, plan = _plan("lenet5")
        eng = Engine(plan, microbatch=2, max_queue=1, admission="shed-oldest")
        assert eng.admission == "shed_oldest"


class TestFlushSemantics:
    def test_double_flush_is_noop(self):
        topo, plan = _plan("lenet5")
        eng = Engine(plan, microbatch=2)
        eng.infer(_frames(topo, 2))
        n = eng.stats().n_batches
        eng.flush()
        eng.flush()
        assert eng.stats().n_batches == n

    def test_start_stop_idempotent(self):
        topo, plan = _plan("lenet5")
        eng = Engine(plan, microbatch=2)
        eng.start()
        eng.start()  # idempotent
        req = eng.submit(_frames(topo, 2))
        assert req.result(timeout=10.0).shape == (2, topo.n_classes)
        eng.stop()
        eng.stop()  # also idempotent
        # After stop, the engine still serves synchronously.
        assert eng.infer(_frames(topo, 2)).shape == (2, topo.n_classes)


class TestStatsWindowAndStop:
    """Satellites: per-rung latency percentiles, stats reset, the bounded
    flush quantum, and the loud wedged-stop path."""

    def test_per_rung_latency_percentiles(self):
        topo, plan = _plan("lenet5")
        eng = Engine(plan, microbatch=4)
        for i in range(8):
            eng.infer(_frames(topo, 4, seed=i))
        st = eng.stats()
        lat = st.rung_latency_ms["fused"]
        assert lat["n"] == 8
        assert 0 < lat["p50_ms"] <= lat["p99_ms"]
        assert "rung fused" in st.summary()

    def test_reset_stats_zeroes_window_but_keeps_ledger(self):
        topo, plan = _plan("lenet5")
        eng = Engine(plan, microbatch=4)
        eng.infer(_frames(topo, 4))
        assert eng.stats().n_ok == 1
        eng.reset_stats()
        st = eng.stats()
        assert st.n_requests == 0
        assert st.n_frames == 0
        assert st.n_ok == 0
        assert st.rung_latency_ms == {}
        # The engine still serves, and fresh completions repopulate.
        eng.infer(_frames(topo, 4, seed=2))
        st = eng.stats()
        assert st.n_ok == 1
        assert st.rung_latency_ms["fused"]["n"] == 1

    def test_flush_max_frames_is_one_quantum(self):
        topo, plan = _plan("lenet5")
        eng = Engine(plan, microbatch=2)
        reqs = [eng.submit(_frames(topo, 2, seed=i)) for i in range(3)]
        # One bounded flush takes whole requests up to ~max_frames from
        # the head — here exactly the first request.
        assert eng.flush(max_frames=2) == 2
        assert reqs[0].done
        assert not reqs[1].done and not reqs[2].done
        # The rest drains with an unbounded flush.
        assert eng.flush() == 4
        assert all(r.done for r in reqs)
        assert eng.flush() == 0

    def test_wedged_stop_raises_and_sheds(self):
        topo, plan = _plan("lenet5")
        eng = Engine(
            plan,
            microbatch=2,
            auto_flush=True,
            fault_plan=FaultPlan(
                [DelayedFlush(at=0, times=None, delay_s=2.0)], seed=0
            ),
        )
        # The flusher wakes for this and stalls 2 s inside the flush —
        # the stall hits before the queue pop, so both requests are
        # still queued when the bounded join gives up.
        first = eng.submit(_frames(topo, 2))
        time.sleep(0.3)
        second = eng.submit(_frames(topo, 2, seed=2))
        with pytest.raises(FlusherWedged, match="did not exit"):
            eng.stop(join_timeout_s=0.2)
        # Every queued request completed with a structured Shed — no
        # request left hanging, no silent thread leak.
        for req in (first, second):
            with pytest.raises(Shed):
                req.result(timeout=1.0)
        # The wedged flusher eventually wakes, finds nothing, and exits;
        # stop() is idempotent afterwards.
        eng.stop()


class TestExtractedExecution:
    def test_forward_is_cnn_apply_path(self):
        """engine.forward == the eager stage/head composition cnn_apply
        routes through (bitwise — same closures, same order)."""
        topo, plan = _plan("lenet5")
        x = _frames(topo, 2)
        np.testing.assert_array_equal(
            np.asarray(forward(plan, x)),
            np.asarray(plan.head_fn(plan.features(x))),
        )

    def test_jitted_forward_cached_per_plan(self):
        _, plan = _plan("lenet5")
        assert plan.jitted_forward() is plan.jitted_forward()
        assert plan.jitted_forward(donate=True) is not plan.jitted_forward()


class TestPackedPow2Stacked:
    """Satellite: the stacked-weight pow2 packing that used to live inline
    in examples/serve.py is now models.layers.pack_linear_pow2 (odd widths
    zero-padded, per-layer scales via vmap)."""

    def test_stacked_pack_matches_per_layer(self):
        from repro.core.quant.pow2 import project_pow2
        from repro.models.layers import linear, pack_linear_pow2

        k1, k2 = jax.random.split(jax.random.PRNGKey(5))
        w = jax.random.normal(k1, (3, 10, 7))  # stacked, odd width
        x = jax.random.normal(k2, (3, 4, 10))
        packed = pack_linear_pow2({"w": w, "b": jnp.ones((7,))})
        assert packed["codes"].shape == (3, 10, 4)  # ceil(8/2) per layer
        assert packed["scale"].shape == (3, 1, 7)
        for layer in range(3):
            got = linear(
                x[layer],
                {
                    "codes": packed["codes"][layer],
                    "scale": packed["scale"][layer],
                    "b": packed["b"],
                },
            )
            ref = (
                x[layer] @ project_pow2(w[layer], channel_axis=1)
                + jnp.ones((7,))
            )
            np.testing.assert_allclose(
                np.asarray(got), np.asarray(ref), rtol=1e-4, atol=1e-4
            )

    def test_pack_params_pow2_walks_trees(self):
        from repro.models.layers import pack_params_pow2

        params = {
            "stack": [{"w": jnp.ones((4, 6)), "b": jnp.zeros((6,))}],
            "norm": {"scale": jnp.ones((4,))},
        }
        out = pack_params_pow2(params)
        assert "codes" in out["stack"][0] and "w" not in out["stack"][0]
        assert out["norm"]["scale"].shape == (4,)  # non-linears untouched
