"""Chaos suite: the serving engine under deterministic fault injection.

Contract asserted for EVERY injected fault class: the engine returns
structured per-request errors or demotes one rung of the execution ladder
and keeps serving — no hang, no crash — and surviving requests' logits
stay bit-exact vs the single-device plan. Also covers the demotion-ladder
order, retry-with-backoff healing, poisoned-batch isolation, the plan
self-check, and the dispatch watchdog."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.dhm.compiler import (
    PlanCheckError,
    QuantSpec,
    check_plan,
    compile_dhm,
)
from repro.core.dhm.engine import (
    BatchFailed,
    DeadlineExceeded,
    Engine,
    InvalidRequest,
    Rejected,
    Shed,
)
from repro.core.dhm.faults import (
    DelayedFlush,
    DeviceLoss,
    DispatchError,
    FaultPlan,
    NaNActivation,
    StalledDispatch,
)
from repro.core.dhm.pipeline import CollectiveTimeout, call_with_timeout
from repro.models.cnn import ALL_TOPOLOGIES, init_cnn

TOPO = ALL_TOPOLOGIES["lenet5"]


@pytest.fixture(scope="module")
def plan():
    params = init_cnn(jax.random.PRNGKey(0), TOPO)
    return compile_dhm(TOPO, params, quant=QuantSpec())


def _frames(n, seed=1):
    h, w = TOPO.input_shape
    return jax.random.normal(
        jax.random.PRNGKey(seed), (n, h, w, TOPO.input_channels)
    )


def _engine(plan, **kw):
    kw.setdefault("microbatch", 4)
    kw.setdefault("retry_backoff_s", 1e-4)
    return Engine(plan, **kw)


# ---------------------------------------------------------------------------
# The fault plan itself: deterministic triggers.


class TestFaultPlan:
    def test_trigger_window(self):
        fp = FaultPlan([DispatchError(at=1, times=2)])
        effs = [fp.dispatch_effects(rung="fused") for _ in range(5)]
        assert [e.exc is not None for e in effs] == [
            False, True, True, False, False
        ]

    def test_forever_window(self):
        fp = FaultPlan([DispatchError(at=0, times=None)])
        assert all(
            fp.dispatch_effects(rung="x").exc is not None for _ in range(4)
        )

    def test_rung_filter(self):
        fp = FaultPlan([DeviceLoss(at=0, times=None, rung="mesh")])
        assert fp.dispatch_effects(rung="mesh").exc is not None
        assert fp.dispatch_effects(rung="fused").clean

    def test_seeded_probability_is_deterministic(self):
        def run():
            fp = FaultPlan([DispatchError(prob=0.5)], seed=7)
            return [
                fp.dispatch_effects(rung=None).exc is not None
                for _ in range(32)
            ]

        fires = [run(), run()]
        assert fires[0] == fires[1]
        assert any(fires[0]) and not all(fires[0])

    def test_flush_delay_counter(self):
        fp = FaultPlan([DelayedFlush(at=1, delay_s=0.25)])
        assert fp.on_flush() == 0.0
        assert fp.on_flush() == 0.25
        assert fp.on_flush() == 0.0

    def test_non_fault_spec_rejected(self):
        with pytest.raises(TypeError, match="Fault specs"):
            FaultPlan(["boom"])

    def test_tenant_scoped_window_is_deterministic_under_interleaving(self):
        """Each tenant advances its OWN event counter, so a tenant-scoped
        window fires at the same point in that tenant's stream no matter
        how other tenants' dispatches interleave."""
        fp = FaultPlan([DispatchError(at=1, times=1, tenant="A")])
        fired = []
        for _ in range(3):  # A/B/untenanted round-robin
            fired.append(fp.dispatch_effects(rung="fused", tenant="A"))
            assert fp.dispatch_effects(rung="fused", tenant="B").clean
            assert fp.dispatch_effects(rung="fused", tenant=None).clean
        # Only A's SECOND event is faulted — B and the untenanted stream
        # never see it even though they pass through the same plan.
        assert [e.exc is not None for e in fired] == [False, True, False]
        assert fp.n_dispatch_events_for("A") == 3
        assert fp.n_dispatch_events_for("B") == 3
        assert fp.n_dispatch_events_for(None) == 3


class TestWatchdog:
    def test_timeout_raises_instead_of_hanging(self):
        import time

        with pytest.raises(CollectiveTimeout, match="did not complete"):
            call_with_timeout(
                lambda: time.sleep(5), timeout_s=0.05, what="test sleep"
            )

    def test_value_and_error_pass_through(self):
        assert call_with_timeout(lambda: 42, timeout_s=1.0) == 42
        with pytest.raises(KeyError):
            call_with_timeout(
                lambda: (_ for _ in ()).throw(KeyError("k")), timeout_s=1.0
            )


# ---------------------------------------------------------------------------
# Plan self-check (the health probe).


class TestPlanCheck:
    def test_healthy_plan_passes(self, plan):
        check_plan(plan)
        plan.self_check()

    def test_nonfinite_params_fail(self, plan):
        bad_conv = list(plan.conv_params)
        bad_conv[0] = {
            "w": bad_conv[0]["w"].at[0, 0, 0, 0].set(jnp.nan),
            "b": bad_conv[0]["b"],
        }
        bad = dataclasses.replace(plan, conv_params=tuple(bad_conv))
        with pytest.raises(PlanCheckError, match="non-finite"):
            check_plan(bad)

    def test_inconsistent_io_fails(self, plan):
        st0 = plan.stages[0]
        bad_io = dataclasses.replace(
            st0.io, out_shape=(1, 1, st0.io.out_shape[-1])
        )
        bad = dataclasses.replace(
            plan,
            stages=(dataclasses.replace(st0, io=bad_io),) + plan.stages[1:],
        )
        with pytest.raises(PlanCheckError):
            check_plan(bad)

    def test_engine_refuses_unhealthy_plan(self, plan):
        bad_conv = list(plan.conv_params)
        bad_conv[0] = {
            "w": jnp.full_like(bad_conv[0]["w"], jnp.inf),
            "b": bad_conv[0]["b"],
        }
        bad = dataclasses.replace(plan, conv_params=tuple(bad_conv))
        with pytest.raises(PlanCheckError):
            _engine(bad)


# ---------------------------------------------------------------------------
# Fault classes, one by one: structured errors or one-rung demotion, and
# bit-exact survivors.


class TestTransientDispatchError:
    def test_retry_heals_bit_exact(self, plan):
        eng = _engine(
            plan, fault_plan=FaultPlan([DispatchError(at=0, times=1)])
        )
        x = _frames(4)
        got = eng.infer(x)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(plan(x)))
        st = eng.stats()
        assert st.n_retries == 1
        assert st.n_demotions == 0
        assert eng.rung == "fused"

    def test_persistent_error_demotes_and_serves(self, plan):
        # 1 attempt + 2 retries all fail on the fused rung -> demote; the
        # per-layer rung serves the same batch (retry counter reset).
        eng = _engine(
            plan,
            fault_plan=FaultPlan([DispatchError(at=0, times=3, rung="fused")]),
            max_retries=2,
        )
        x = _frames(4)
        got = eng.infer(x)
        np.testing.assert_allclose(
            np.asarray(got), np.asarray(plan(x)), rtol=1e-4, atol=1e-5
        )
        st = eng.stats()
        assert st.n_retries == 2
        assert st.n_demotions == 1
        assert eng.rung == "per_layer"
        assert eng.demotions[0]["rung"] == "fused"


class TestLadder:
    def test_demotion_order_and_exhaustion(self, plan):
        # Every rung's dispatch fails (no retries): the ladder walks
        # fused -> per_layer -> ref in order, the batch fails with a
        # structured error, and the engine KEEPS SERVING once the fault
        # clears (still on the last rung).
        eng = _engine(
            plan,
            fault_plan=FaultPlan([DispatchError(at=0, times=3)]),
            max_retries=0,
        )
        req = eng.submit(_frames(4))
        eng.flush()
        with pytest.raises(BatchFailed, match="batch failed"):
            req.result()
        assert [d["rung"] for d in eng.demotions] == [
            "fused", "per_layer", "ref"
        ]
        assert eng.rung == "ref"
        # Fault window closed: the engine still serves, on the last rung.
        x = _frames(4, seed=2)
        np.testing.assert_allclose(
            np.asarray(eng.infer(x)), np.asarray(plan(x)),
            rtol=1e-4, atol=1e-5,
        )
        st = eng.stats()
        assert st.n_failed == 1 and st.n_ok == 1
        assert "demotions" in st.summary()

    def test_allow_degraded_false_pins_the_rung(self, plan):
        eng = _engine(
            plan,
            fault_plan=FaultPlan([DispatchError(at=0, times=None)]),
            max_retries=0,
            allow_degraded=False,
        )
        req = eng.submit(_frames(4))
        eng.flush()  # must not raise: the failure is per-request
        with pytest.raises(BatchFailed):
            req.result()
        assert eng.rung == "fused"


class TestStalledDispatch:
    def test_timeout_demotes_instead_of_hanging(self, plan):
        eng = _engine(
            plan,
            fault_plan=FaultPlan(
                [StalledDispatch(at=0, times=1, stall_s=5.0, rung="fused")]
            ),
            dispatch_timeout_s=0.2,
        )
        x = _frames(4)
        got = eng.infer(x)  # returns promptly: watchdog + demotion
        np.testing.assert_allclose(
            np.asarray(got), np.asarray(plan(x)), rtol=1e-4, atol=1e-5
        )
        st = eng.stats()
        assert st.n_demotions == 1
        assert st.n_retries == 0  # timeouts demote, they don't retry
        assert "did not complete" in eng.demotions[0]["reason"]


class TestNaNActivation:
    def test_transient_corruption_retries_bit_exact(self, plan):
        eng = _engine(
            plan,
            fault_plan=FaultPlan([NaNActivation(at=0, times=1, stage=0)]),
        )
        x = _frames(4)
        got = eng.infer(x)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(plan(x)))
        st = eng.stats()
        assert st.n_retries == 1 and st.n_demotions == 0

    def test_persistent_corruption_demotes(self, plan):
        # The fused rung keeps producing NaN logits -> retries burn ->
        # demote to per_layer, where the fault (rung-filtered) is gone.
        eng = _engine(
            plan,
            fault_plan=FaultPlan(
                [NaNActivation(at=0, times=None, stage=0, rung="fused")]
            ),
            max_retries=1,
        )
        x = _frames(4)
        got = eng.infer(x)
        np.testing.assert_allclose(
            np.asarray(got), np.asarray(plan(x)), rtol=1e-4, atol=1e-5
        )
        st = eng.stats()
        assert st.n_demotions == 1 and eng.rung == "per_layer"
        assert "non-finite" in eng.demotions[0]["reason"]


class TestDeviceLoss:
    def test_device_loss_demotes_without_retry(self, plan):
        eng = _engine(
            plan, fault_plan=FaultPlan([DeviceLoss(at=0, times=1)])
        )
        x = _frames(4)
        got = eng.infer(x)
        np.testing.assert_allclose(
            np.asarray(got), np.asarray(plan(x)), rtol=1e-4, atol=1e-5
        )
        st = eng.stats()
        assert st.n_demotions == 1
        assert st.n_retries == 0
        assert "device loss" in eng.demotions[0]["reason"]


class TestBadFrames:
    def test_gate_validation_fails_alone(self, plan):
        eng = _engine(plan)
        bad = _frames(2).at[0, 0, 0, 0].set(jnp.nan)
        good_req = eng.submit(_frames(2))
        bad_req = eng.submit(bad)
        eng.flush()
        with pytest.raises(InvalidRequest, match="NaN/Inf"):
            bad_req.result()
        np.testing.assert_allclose(
            np.asarray(good_req.result()),
            np.asarray(plan(_frames(2))),
            rtol=1e-4, atol=1e-5,
        )
        st = eng.stats()
        assert st.n_invalid == 1 and st.n_ok == 1

    def test_wrong_dtype_fails_alone(self, plan):
        eng = _engine(plan)
        h, w = TOPO.input_shape
        req = eng.submit(jnp.zeros((1, h, w, TOPO.input_channels), jnp.int32))
        with pytest.raises(InvalidRequest, match="floating"):
            req.result()

    def test_poisoned_batch_is_isolated(self, plan):
        # With the gate off, a NaN frame reaches the packed batch; the
        # engine detects the poisoned output, reruns requests isolated,
        # and only the invalid request fails.
        eng = _engine(plan, validate=False)
        bad = _frames(2).at[1, 3, 3, 0].set(jnp.nan)
        good_req = eng.submit(_frames(2))
        bad_req = eng.submit(bad)
        eng.flush()
        with pytest.raises(InvalidRequest, match="isolated"):
            bad_req.result()
        np.testing.assert_allclose(
            np.asarray(good_req.result()),
            np.asarray(plan(_frames(2))),
            rtol=1e-4, atol=1e-5,
        )
        st = eng.stats()
        assert st.n_invalid == 1 and st.n_ok == 1
        assert st.n_demotions == 0  # isolation, not demotion


class TestDelayedFlushDeadlines:
    def test_stalled_flush_expires_deadlines_only(self, plan):
        eng = _engine(
            plan,
            fault_plan=FaultPlan([DelayedFlush(at=0, times=1, delay_s=0.05)]),
        )
        slo = eng.submit(_frames(2), deadline_ms=5.0)
        free = eng.submit(_frames(2, seed=3))
        eng.flush()
        with pytest.raises(DeadlineExceeded, match="deadline passed"):
            slo.result()
        np.testing.assert_allclose(
            np.asarray(free.result()),
            np.asarray(plan(_frames(2, seed=3))),
            rtol=1e-4, atol=1e-5,
        )
        st = eng.stats()
        assert st.n_deadline_exceeded == 1 and st.n_ok == 1


class TestAdmissionUnderChaos:
    def test_reject_policy(self, plan):
        eng = _engine(plan, max_queue=1, admission="reject")
        r1 = eng.submit(_frames(1))
        r2 = eng.submit(_frames(1))
        with pytest.raises(Rejected, match="queue full"):
            r2.result()
        assert r1.result().shape == (1, TOPO.n_classes)
        assert eng.stats().n_rejected == 1

    def test_shed_oldest_policy(self, plan):
        eng = _engine(plan, max_queue=1, admission="shed_oldest")
        r1 = eng.submit(_frames(1))
        r2 = eng.submit(_frames(1, seed=4))
        with pytest.raises(Shed, match="shed by newer work"):
            r1.result()
        np.testing.assert_allclose(
            np.asarray(r2.result()),
            np.asarray(plan(_frames(1, seed=4))),
            rtol=1e-4, atol=1e-5,
        )
        st = eng.stats()
        assert st.n_shed == 1 and st.n_ok == 1


# ---------------------------------------------------------------------------
# Chaos on the mesh rung (runs under the CI chaos job's 8 forced host
# devices; skipped on single-device runs).


def _mesh_engine(n_stages=2, **kw):
    params = init_cnn(jax.random.PRNGKey(0), TOPO)
    plan = compile_dhm(TOPO, params, n_stages=n_stages)
    mesh = jax.make_mesh((n_stages,), ("stage",))
    eng = Engine(
        plan, microbatch=2, mesh=mesh, n_microbatches=2,
        retry_backoff_s=1e-4, **kw,
    )
    return plan, eng


@pytest.mark.skipif(
    len(jax.devices()) < 2, reason="mesh chaos needs >= 2 devices"
)
class TestMeshChaos:
    def test_device_loss_demotes_to_single_device(self):
        plan, eng = _mesh_engine(
            fault_plan=FaultPlan([DeviceLoss(at=0, times=None, rung="mesh")])
        )
        assert eng.rung == "mesh"
        x = _frames(4)
        got = eng.infer(x)
        np.testing.assert_allclose(
            np.asarray(got), np.asarray(plan(x)), rtol=1e-4, atol=1e-5
        )
        assert eng.rung == "fused"
        assert eng.demotions[0]["rung"] == "mesh"

    def test_stalled_collective_times_out_and_demotes(self):
        plan, eng = _mesh_engine(
            fault_plan=FaultPlan(
                [StalledDispatch(at=0, times=None, stall_s=5.0, rung="mesh")]
            ),
            dispatch_timeout_s=0.3,
        )
        x = _frames(4)
        got = eng.infer(x)
        np.testing.assert_allclose(
            np.asarray(got), np.asarray(plan(x)), rtol=1e-4, atol=1e-5
        )
        assert eng.rung == "fused"
        assert eng.stats().n_demotions == 1
