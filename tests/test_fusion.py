"""Tests for cross-layer VMEM-resident fusion: the budget-aware planner
(maximal groups, per-layer fallback, exact-fit boundaries), composed-halo
correctness of the fused pyramid kernel vs the hand-composed reference on
every topology and backend, and the structural one-pallas_call-per-group
guarantee."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.dhm.compiler import QuantSpec, compile_dhm
from repro.core.dhm.fusion import (
    DEFAULT_VMEM_BUDGET,
    group_working_set,
    plan_fusion_groups,
)
from repro.kernels.stream_conv import stream_conv_pyramid
from repro.models.cnn import (
    ALL_TOPOLOGIES,
    CNNTopology,
    ConvLayerSpec,
    PAPER_TOPOLOGIES,
    cnn_apply_reference,
    init_cnn,
)


# The ONE jaxpr-walking helper, shared with the static-analysis engine
# (tests and the `repro.analysis` CLI can never drift apart).
from repro.analysis.jaxpr_utils import count_primitive as _count_primitive


def _mk_inputs(topo, seed=4, batch=2):
    params = init_cnn(jax.random.PRNGKey(seed - 1), topo)
    h, w = topo.input_shape
    x = jax.random.normal(
        jax.random.PRNGKey(seed), (batch, h, w, topo.input_channels)
    )
    return params, x


# A small two-layer topology whose working sets are a few tens of KB —
# cheap enough for interpret-mode oracle runs in the fast tier, gnarly
# enough to exercise SAME padding, overlapping pool and rectangularity.
SMALL2 = CNNTopology(
    name="small2", input_hw=(14, 18), input_channels=2,
    conv_layers=(
        ConvLayerSpec(n_out=4, kernel=3, padding="SAME", pool=3,
                      pool_stride=2, act="relu"),
        ConvLayerSpec(n_out=5, kernel=3, padding="SAME", pool=2, act="tanh"),
    ),
    fc_dims=(8,), n_classes=3,
)


class TestPlanner:
    def test_paper_topologies_fuse_whole_pyramid_by_default(self):
        """Under the default VMEM budget every paper topology's feature
        extractor is ONE fusion group (single fused kernel + FC head)."""
        for name, topo in PAPER_TOPOLOGIES.items():
            params, _ = _mk_inputs(topo)
            plan = compile_dhm(topo, params)
            groups = plan.fusion_groups
            assert len(groups) == 1, (name, groups)
            assert groups[0].layers == tuple(range(len(topo.conv_layers)))
            assert groups[0].working_set <= DEFAULT_VMEM_BUDGET

    def test_tiny_budget_gives_per_layer_plan(self):
        """A budget too small for any 2-layer group degenerates to the
        pre-fusion plan: all-singleton groups, same structure and logits
        as fusion disabled."""
        topo = PAPER_TOPOLOGIES["cifar10"]
        params, x = _mk_inputs(topo)
        tiny = compile_dhm(topo, params, vmem_budget=1024)
        assert [g.layers for g in tiny.fusion_groups] == [(0,), (1,), (2,)]
        off = compile_dhm(topo, params, vmem_budget=0)
        np.testing.assert_array_equal(
            np.asarray(tiny(x)), np.asarray(off(x))
        )

    def test_budget_exactly_fits_is_inclusive(self):
        """The planner accepts a group whose costed working set equals the
        budget exactly, and rejects it one byte under."""
        topo = SMALL2
        ws = group_working_set(topo, (0, 1))  # whole-frame block
        groups = plan_fusion_groups(topo, (0, 1), vmem_budget=ws)
        assert [g.layers for g in groups] == [(0, 1)]
        assert groups[0].working_set == ws
        # One byte below: the whole-frame block no longer fits; the
        # planner either row-blocks (smaller working set) or splits.
        groups = plan_fusion_groups(topo, (0, 1), vmem_budget=ws - 1)
        if len(groups) == 1:
            assert groups[0].working_set <= ws - 1
            assert groups[0].block_rows >= 1
        else:
            assert [g.layers for g in groups] == [(0,), (1,)]

    def test_huge_budget_whole_pyramid(self):
        for topo in ALL_TOPOLOGIES.values():
            groups = plan_fusion_groups(
                topo, range(len(topo.conv_layers)), vmem_budget=2**40
            )
            assert len(groups) == 1
            assert groups[0].layers == tuple(range(len(topo.conv_layers)))

    def test_mid_budget_splits_into_maximal_groups(self):
        """A budget that fits 2-layer but not 3-layer groups on cifar10
        yields a maximal first group and a trailing singleton."""
        topo = PAPER_TOPOLOGIES["cifar10"]
        ws3 = group_working_set(topo, (0, 1, 2), block_rows=1)
        ws2 = group_working_set(topo, (0, 1), block_rows=1)
        assert ws2 < ws3
        groups = plan_fusion_groups(topo, (0, 1, 2), vmem_budget=ws2)
        assert [g.layers for g in groups] == [(0, 1), (2,)]

    def test_budget_shrinks_block_rows(self):
        """Between whole-frame and nothing, the planner keeps the group
        and streams smaller row blocks."""
        topo = SMALL2
        whole = group_working_set(topo, (0, 1))
        one_row = group_working_set(topo, (0, 1), block_rows=1)
        assert one_row < whole
        groups = plan_fusion_groups(topo, (0, 1), vmem_budget=whole - 1)
        if len(groups) == 1:  # fits at a reduced block size
            assert 1 <= groups[0].block_rows
            assert groups[0].working_set < whole

    def test_noncontiguous_layers_raise(self):
        with pytest.raises(ValueError, match="contiguous"):
            plan_fusion_groups(PAPER_TOPOLOGIES["cifar10"], (0, 2))

    def test_negative_budget_raises(self):
        with pytest.raises(ValueError, match="vmem_budget"):
            compile_dhm(
                PAPER_TOPOLOGIES["cifar10"],
                _mk_inputs(PAPER_TOPOLOGIES["cifar10"])[0],
                vmem_budget=-1,
            )


class TestFusedCorrectness:
    """Composed-halo correctness: fused plans match the hand-composed
    reference on every topology, fp32 and quantized."""

    @pytest.mark.parametrize("name", sorted(ALL_TOPOLOGIES))
    def test_fused_plan_matches_reference_compiled(self, name):
        topo = ALL_TOPOLOGIES[name]
        params, x = _mk_inputs(topo)
        plan = compile_dhm(topo, params, backend="pallas")
        assert any(g.fused for g in plan.fusion_groups)
        ref = cnn_apply_reference(params, topo, x)
        np.testing.assert_allclose(
            np.asarray(plan(x)), np.asarray(ref), rtol=1e-4, atol=1e-4
        )

    @pytest.mark.parametrize("name", sorted(ALL_TOPOLOGIES))
    def test_fused_quant_plan_matches_reference(self, name):
        bits = {"lenet5": 3}.get(name, 6)
        topo = ALL_TOPOLOGIES[name]
        params, x = _mk_inputs(topo)
        plan = compile_dhm(
            topo, params, quant=QuantSpec(weight_bits=bits, act_bits=bits),
            backend="pallas",
        )
        ref = cnn_apply_reference(
            params, topo, x, weight_bits=bits, act_bits=bits
        )
        np.testing.assert_allclose(
            np.asarray(plan(x)), np.asarray(ref), rtol=1e-4, atol=1e-4
        )

    def test_fused_oracle_small_topology(self):
        """The exact multi-layer kernel program (interpreter) on the small
        gnarly topology: overlapping pool + SAME composed halos."""
        params, x = _mk_inputs(SMALL2, batch=1)
        plan = compile_dhm(SMALL2, params, backend="pallas_interpret")
        assert [g.layers for g in plan.fusion_groups] == [(0, 1)]
        ref = cnn_apply_reference(params, SMALL2, x)
        np.testing.assert_allclose(
            np.asarray(plan(x)), np.asarray(ref), rtol=1e-4, atol=1e-5
        )

    def test_row_blocking_does_not_change_values(self):
        """Streaming the pyramid in small row blocks (composed halo per
        block) is bit-identical to the whole-frame block, through the
        kernel oracle."""
        params, x = _mk_inputs(SMALL2, batch=1)
        ws = [p["w"] for p in params["conv"]]
        bs = [p["b"] for p in params["conv"]]
        whole = stream_conv_pyramid(
            x, ws, bs, layers=SMALL2.conv_layers,
            backend="pallas_interpret", block_rows=0,
        )
        for br in (1, 2):
            blocked = stream_conv_pyramid(
                x, ws, bs, layers=SMALL2.conv_layers,
                backend="pallas_interpret", block_rows=br,
            )
            np.testing.assert_array_equal(
                np.asarray(whole), np.asarray(blocked)
            )

    @pytest.mark.slow
    @pytest.mark.parametrize("name", ["lenet5", "cifar10_full"])
    def test_fused_oracle_matches_reference(self, name):
        """Interpreter oracle on the real topologies, including
        cifar10_full's overlapping 3x3/stride-2 pool through the composed
        halo."""
        topo = ALL_TOPOLOGIES[name]
        params, x = _mk_inputs(topo, batch=1)
        plan = compile_dhm(topo, params, backend="pallas_interpret")
        assert any(g.fused for g in plan.fusion_groups)
        ref = cnn_apply_reference(params, topo, x)
        np.testing.assert_allclose(
            np.asarray(plan(x)), np.asarray(ref), rtol=1e-4, atol=1e-5
        )

    def test_ref_backend_fused_plan_matches_reference(self):
        """Fusion is a scheduling decision on the ref backend too (the
        group lowers as the per-layer chain)."""
        topo = PAPER_TOPOLOGIES["cifar10"]
        params, x = _mk_inputs(topo)
        plan = compile_dhm(topo, params, backend="ref")
        ref = cnn_apply_reference(params, topo, x)
        np.testing.assert_allclose(
            np.asarray(plan(x)), np.asarray(ref), rtol=1e-4, atol=1e-4
        )


class TestStructure:
    def test_one_pallas_call_per_fusion_group(self):
        """Structural: a fused plan traces to exactly ONE pallas_call per
        fusion group — the whole feature extractor of a paper topology is
        a single kernel invocation. Enforced through the static-analysis
        registry (invariant V002), so this test and the CLI gate can
        never drift apart."""
        from repro.analysis.verify import verify_plan

        topo = PAPER_TOPOLOGIES["cifar10"]
        params, _x = _mk_inputs(topo, batch=1)
        plan = compile_dhm(topo, params, backend="pallas_interpret")
        assert verify_plan(plan, ids=("V002",)) == []
        assert len(plan.fusion_groups) == 1
        # and the per-layer plan keeps one pallas_call per (single-layer
        # group ==) layer
        plan_pl = compile_dhm(
            topo, params, backend="pallas_interpret", vmem_budget=0
        )
        assert verify_plan(plan_pl, ids=("V002",)) == []
        assert len(plan_pl.fusion_groups) == len(topo.conv_layers)

    def test_one_matmul_per_layer_inside_group(self):
        """The fused pyramid keeps the one-MXU-matmul-per-layer contract:
        a fused 3-layer group traces to exactly 3 dot_generals and no
        lax.conv (registry invariants V001/V003)."""
        from repro.analysis.verify import verify_plan

        topo = PAPER_TOPOLOGIES["cifar10"]
        params, _x = _mk_inputs(topo, batch=1)
        plan = compile_dhm(topo, params, backend="pallas_interpret")
        assert verify_plan(plan, ids=("V001", "V003")) == []

    def test_boundary_stream_bytes_reports_pooled_frame(self):
        """The DPN boundary-stream payload (what fusion keeps on-chip per
        fused layer edge) is the pooled output frame at the stream
        bit-width: cifar10 conv1 = 32 maps x 16x16 pooled pixels x 6b."""
        topo = PAPER_TOPOLOGIES["cifar10"]
        params, _ = _mk_inputs(topo)
        plan = compile_dhm(
            topo, params, quant=QuantSpec(weight_bits=6, act_bits=6)
        )
        expected = 32 * 16 * 16 * 6 / 8
        assert plan.graph.boundary_stream_bytes(1) == pytest.approx(expected)

    def test_call_reuses_one_jitted_closure(self):
        """CompiledDHM.__call__ runs one cached end-to-end jitted closure:
        repeated calls never retrace, and the donated variant is a
        separate cached entry."""
        topo = PAPER_TOPOLOGIES["lenet5"]
        params, x = _mk_inputs(topo)
        plan = compile_dhm(topo, params)
        first = plan.jitted_forward()
        for _ in range(4):
            plan(x)
        assert plan.jitted_forward() is first
        assert first._cache_size() == 1
        donating = plan.jitted_forward(donate=True)
        assert donating is not first
        x2 = jnp.array(x)
        out = donating(x2)
        np.testing.assert_allclose(
            np.asarray(out), np.asarray(plan(x)), rtol=1e-6, atol=1e-6
        )
