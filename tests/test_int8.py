"""True int8 compute: the requantizing integer kernel paths vs the
fake-quant fp32 oracle, dtype-aware fusion widening, the integer pow2 FC
head, and the mixed-bitwidth compiler knob.

The numeric contract under test: with weights baked to int8 codes on the
same dynamic pow2 grid ``fake_quant_dynamic`` uses, and an input already
on its stream grid, every backend's int8 rendering (int8 x int8 -> int32
accumulate -> one exact pow2 dequant -> fp32 epilogue) produces EXACTLY
the fake-quant reference's values — all scales are powers of two, so the
requantization introduces zero extra ULPs.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.dhm.compiler import QuantSpec, compile_dhm, emit_conv_stage
from repro.core.dhm.fusion import (
    group_working_set,
    plan_elem_bytes,
    widening_budget,
)
from repro.core.quant.fixed_point import (
    FixedPointSpec,
    dynamic_spec,
    fake_quant_dynamic,
    quantize_fixed,
)
from repro.kernels.stream_conv import stream_conv_block, stream_conv_pyramid
from repro.kernels.stream_conv.epilogue import Int8Scales, stream_quant_spec
from repro.kernels.stream_conv.ref import stream_conv_block_ref
from repro.models.cnn import (
    ALL_TOPOLOGIES,
    CNNTopology,
    ConvLayerSpec,
    init_cnn,
)

BITS = 8


def _bake(w, bits=BITS):
    """(int8 codes, Int8Scales-ready w_scale) on the fake_quant grid."""
    spec = dynamic_spec(w, bits)
    codes = quantize_fixed(w, spec).astype(jnp.int8)
    return codes, float(spec.scale)


def _grid_input(key, shape, bits=BITS):
    """A random frame snapped onto the ``bits``-wide stream grid."""
    spec = stream_quant_spec(bits)
    x = jax.random.normal(key, shape)
    return quantize_fixed(x, spec).astype(jnp.float32) * spec.scale


def _case(key, h, w, c, n, k=3):
    kw, kx, kb = jax.random.split(key, 3)
    wts = jax.random.normal(kw, (k, k, c, n)) * 0.5
    b = jax.random.normal(kb, (n,)) * 0.1
    x = _grid_input(kx, (2, h, w, c))
    return x, wts, b


def test_bake_matches_fake_quant_grid():
    """codes * scale == fake_quant_dynamic(w, bits) exactly — the int8
    weight baking and the fake-quant oracle share one grid."""
    w = jax.random.normal(jax.random.PRNGKey(0), (5, 5, 3, 8))
    codes, scale = _bake(w)
    np.testing.assert_array_equal(
        np.asarray(codes, np.float32) * scale,
        np.asarray(fake_quant_dynamic(w, BITS)),
    )


# The stride x pool x rect-frame property grid of the epilogue contract.
GRID = [
    dict(padding="VALID", stride=1, act="relu", pool=2, pool_stride=None),
    dict(padding="VALID", stride=2, act="tanh", pool=0, pool_stride=None),
    dict(padding="SAME", stride=1, act="relu", pool=3, pool_stride=2),
    dict(padding="SAME", stride=2, act="none", pool=2, pool_stride=None),
]


def _oracle(x, wts, b, cfg, bits=BITS):
    """The fake-quant fp32 reference: fake-quantized weights/bias, fp32
    conv, epilogue, stream quant."""
    return stream_conv_block_ref(
        x, fake_quant_dynamic(wts, bits), fake_quant_dynamic(b, bits),
        act_bits=bits, **cfg,
    )


@pytest.mark.parametrize("cfg", GRID, ids=lambda c: (
    f"{c['padding']}-s{c['stride']}-{c['act']}-p{c['pool']}"
))
@pytest.mark.parametrize("backend", ["ref", "pallas"])
def test_int8_block_matches_fake_quant_oracle(backend, cfg):
    x, wts, b = _case(jax.random.PRNGKey(3), 14, 18, 2, 5)
    codes, w_scale = _bake(wts)
    sc = Int8Scales(in_bits=BITS, w_scale=w_scale)
    got = stream_conv_block(
        x, codes, fake_quant_dynamic(b, BITS),
        act_bits=BITS, int8_scales=sc, backend=backend, **cfg,
    )
    np.testing.assert_array_equal(
        np.asarray(got), np.asarray(_oracle(x, wts, b, cfg))
    )


@pytest.mark.slow
@pytest.mark.parametrize("cfg", GRID, ids=lambda c: (
    f"{c['padding']}-s{c['stride']}-{c['act']}-p{c['pool']}"
))
def test_int8_block_matches_oracle_interpret(cfg):
    """The interpret backend runs the actual pallas body (int8 patches,
    int32 scratch accumulator, in-kernel requantizing epilogue)."""
    x, wts, b = _case(jax.random.PRNGKey(4), 14, 18, 2, 5)
    codes, w_scale = _bake(wts)
    sc = Int8Scales(in_bits=BITS, w_scale=w_scale)
    got = stream_conv_block(
        x, codes, fake_quant_dynamic(b, BITS),
        act_bits=BITS, int8_scales=sc, backend="pallas_interpret", **cfg,
    )
    np.testing.assert_array_equal(
        np.asarray(got), np.asarray(_oracle(x, wts, b, cfg))
    )


@pytest.mark.parametrize(
    "backend",
    ["ref", "pallas", pytest.param("pallas_interpret", marks=pytest.mark.slow)],
)
def test_int8_pyramid_matches_fake_quant_oracle(backend):
    """A 2-layer fused group on a rectangular SAME frame: interior layer
    emits int8 codes (1-byte inter-layer slab), last layer emits fp32 —
    exactly the per-layer fake-quant composition."""
    key = jax.random.PRNGKey(5)
    k0, k1, kx = jax.random.split(key, 3)
    layers = (
        ConvLayerSpec(n_out=4, kernel=3, padding="SAME", pool=3,
                      pool_stride=2, act="relu"),
        ConvLayerSpec(n_out=5, kernel=3, padding="SAME", pool=2, act="tanh"),
    )
    w0 = jax.random.normal(k0, (3, 3, 2, 4)) * 0.5
    w1 = jax.random.normal(k1, (3, 3, 4, 5)) * 0.5
    b0 = jnp.zeros((4,)) + 0.0625
    b1 = jnp.zeros((5,)) - 0.125
    x = _grid_input(kx, (2, 14, 18, 2))
    (c0, s0), (c1, s1) = _bake(w0), _bake(w1)
    scales = (
        Int8Scales(in_bits=BITS, w_scale=s0),
        Int8Scales(in_bits=BITS, w_scale=s1),
    )
    got = stream_conv_pyramid(
        x, [c0, c1], [fake_quant_dynamic(b0, BITS), fake_quant_dynamic(b1, BITS)],
        layers=layers, act_bits=BITS, int8_scales=scales, backend=backend,
    )
    want = x
    for wts, b, layer in ((w0, b0, layers[0]), (w1, b1, layers[1])):
        want = _oracle(
            want, wts, b,
            dict(padding=layer.padding, stride=layer.stride, act=layer.act,
                 pool=layer.pool, pool_stride=layer.pool_stride),
        )
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


class TestCompiledInt8Plans:
    def _topo_params(self, name="lenet5"):
        topo = ALL_TOPOLOGIES[name]
        params = init_cnn(jax.random.PRNGKey(0), topo)
        return topo, params

    @pytest.mark.parametrize(
        "backend",
        ["ref", "pallas",
         pytest.param("pallas_interpret", marks=pytest.mark.slow)],
    )
    def test_plan_logits_match_fake_quant_plan(self, backend):
        """End to end through compile_dhm: the int8 plan's logits equal
        the fake-quant plan's logits exactly for an on-grid frame."""
        topo, params = self._topo_params()
        h, w = topo.input_shape
        x = _grid_input(
            jax.random.PRNGKey(1), (2, h, w, topo.input_channels),
            bits=BITS,
        )
        fq = compile_dhm(
            topo, params,
            quant=QuantSpec(weight_bits=BITS, act_bits=BITS), backend=backend,
        )
        i8 = compile_dhm(
            topo, params,
            quant=QuantSpec(weight_bits=BITS, act_bits=BITS,
                            int8_compute=True),
            backend=backend,
        )
        np.testing.assert_array_equal(np.asarray(fq(x)), np.asarray(i8(x)))

    def test_int8_closure_does_not_retrace(self):
        """The int8 jitted closure traces once across repeated batches —
        static Int8Scales must not leak into the pytree."""
        topo, params = self._topo_params()
        plan = compile_dhm(
            topo, params,
            quant=QuantSpec(weight_bits=BITS, act_bits=BITS,
                            int8_compute=True),
        )
        h, w = topo.input_shape
        x = _grid_input(
            jax.random.PRNGKey(2), (2, h, w, topo.input_channels)
        )
        fwd = plan.jitted_forward()
        fwd(x)
        fwd(x + 0.25)
        fwd(x * 0.5)
        assert fwd._cache_size() == 1

    def test_plan_params_are_int8_codes(self):
        topo, params = self._topo_params()
        plan = compile_dhm(
            topo, params,
            quant=QuantSpec(weight_bits=BITS, act_bits=BITS,
                            int8_compute=True),
        )
        assert len(plan.int8_scales) == len(topo.conv_layers)
        for p, sc in zip(plan.conv_params, plan.int8_scales):
            assert p["w"].dtype == jnp.int8
            assert sc.in_bits == BITS
            assert sc.w_scale > 0
        assert plan_elem_bytes(plan.quant) == 1

    def test_stage_quant_kwargs_rebuild_matches(self):
        """Degradation-ladder rebuilds (emit_conv_stage from
        stage_quant_kwargs) reproduce the plan's stage bodies exactly."""
        topo, params = self._topo_params()
        plan = compile_dhm(
            topo, params,
            quant=QuantSpec(weight_bits=BITS, act_bits=BITS,
                            int8_compute=True),
        )
        h, w = topo.input_shape
        x = _grid_input(
            jax.random.PRNGKey(6), (2, h, w, topo.input_channels)
        )
        st = plan.stages[0]
        rebuilt = emit_conv_stage(
            st.specs, backend=plan.backend, **plan.stage_quant_kwargs(0)
        )
        np.testing.assert_array_equal(
            np.asarray(rebuilt(plan.stage_params(0), x)),
            np.asarray(st.fn(plan.stage_params(0), x)),
        )

    def test_mixed_bitwidth_plan_compiles_and_runs(self):
        topo, params = self._topo_params()
        n = len(topo.conv_layers)
        bits = tuple(6 if i % 2 else 8 for i in range(n))
        plan = compile_dhm(
            topo, params,
            quant=QuantSpec(int8_compute=True, per_layer_bits=bits),
        )
        assert plan.quant.mixed_bitwidth
        for i in range(n):
            assert plan.quant.conv_act_bits(i) == bits[i]
        # chain contract: layer i ingests layer i-1's stream width
        for i in range(1, n):
            assert plan.int8_scales[i].in_bits == bits[i - 1]
        h, w = topo.input_shape
        x = _grid_input(
            jax.random.PRNGKey(7), (2, h, w, topo.input_channels)
        )
        logits = plan(x)
        assert logits.shape == (2, topo.n_classes)
        assert bool(jnp.isfinite(logits).all())

    def test_int8_requires_bits(self):
        with pytest.raises(ValueError, match="int8_compute requires"):
            QuantSpec(int8_compute=True)
        with pytest.raises(ValueError, match="<= 8"):
            QuantSpec(weight_bits=9, act_bits=9, int8_compute=True)

    def test_per_layer_bits_length_checked(self):
        topo, params = self._topo_params()
        with pytest.raises(ValueError, match="per_layer_bits"):
            compile_dhm(
                topo, params,
                quant=QuantSpec(per_layer_bits=(8,) * 17),
            )


class TestInt8FusionWidening:
    def test_int8_slabs_widen_fusion_groups(self):
        """The tentpole's costing claim, asserted structurally: at the
        probe budget (1 byte under the cheapest whole-run fp32 cost) the
        fp32 plan cannot fuse the full conv stack, the int8 plan can —
        1-byte slabs buy a strictly larger group under the SAME budget."""
        widened = []
        for name, topo in ALL_TOPOLOGIES.items():
            idxs = tuple(range(len(topo.conv_layers)))
            probe = widening_budget(topo, idxs)
            if probe is None:
                continue
            if probe["int8_max_group"] > probe["fp32_max_group"]:
                widened.append((name, probe))
        assert widened, "no topology widens under int8 slab costing"

    def test_compiled_plans_realize_the_widening(self):
        """Compile fp32 and int8 plans at the probe budget and compare
        the actual fusion groups the compiler emitted."""
        for name, topo in ALL_TOPOLOGIES.items():
            idxs = tuple(range(len(topo.conv_layers)))
            probe = widening_budget(topo, idxs)
            if probe is None or probe["int8_max_group"] <= probe["fp32_max_group"]:
                continue
            params = init_cnn(jax.random.PRNGKey(0), topo)
            fp = compile_dhm(topo, params, vmem_budget=probe["budget"])
            i8 = compile_dhm(
                topo, params,
                quant=QuantSpec(weight_bits=8, act_bits=8,
                                int8_compute=True),
                vmem_budget=probe["budget"],
            )
            fp_max = max(len(g.layers) for g in fp.fusion_groups)
            i8_max = max(len(g.layers) for g in i8.fusion_groups)
            assert i8_max > fp_max, name
            # and the recorded working sets honor the int8 costing
            for g in i8.fusion_groups:
                assert g.working_set == group_working_set(
                    topo, g.layers, block_rows=g.block_rows, elem_bytes=1
                )
            return
        pytest.skip("no widening topology found (covered by the test above)")

    def test_fp32_costing_unchanged(self):
        """elem_bytes=4 defaults reproduce the historical costs — fp32
        plans keep byte-identical working sets."""
        for topo in ALL_TOPOLOGIES.values():
            idxs = tuple(range(len(topo.conv_layers)))
            a = group_working_set(topo, idxs, block_rows=8)
            b = group_working_set(topo, idxs, block_rows=8, elem_bytes=4)
            assert a == b


class TestIntPow2Head:
    def test_int_head_matches_fp32_decode_head(self):
        """pow2 packed head: the integer shift-add rendering equals the
        decode-to-fp32 matmul exactly for on-grid activations."""
        topo = CNNTopology(
            name="p2head", input_hw=(12, 12), input_channels=2,
            conv_layers=(
                ConvLayerSpec(n_out=4, kernel=3, padding="SAME", pool=2,
                              act="tanh"),
            ),
            fc_dims=(16,), n_classes=5,
        )
        params = init_cnn(jax.random.PRNGKey(0), topo)
        x = _grid_input(jax.random.PRNGKey(1), (2, 12, 12, 2))
        fp = compile_dhm(
            topo, params,
            quant=QuantSpec(act_bits=8, pow2_weights=True,
                            per_layer_bits=(8,)),
            backend="ref",
        )
        i8 = compile_dhm(
            topo, params,
            quant=QuantSpec(act_bits=8, pow2_weights=True, int8_compute=True,
                            per_layer_bits=(8,)),
            backend="ref",
        )
        np.testing.assert_array_equal(np.asarray(fp(x)), np.asarray(i8(x)))

    def test_int_head_skips_fp32_matmul(self):
        """The head's jaxpr contains integer dot_generals only — the
        decode-to-fp32 matmul is structurally gone."""
        from repro.analysis.jaxpr_utils import find_primitive

        topo = CNNTopology(
            name="p2head2", input_hw=(12, 12), input_channels=2,
            conv_layers=(
                ConvLayerSpec(n_out=4, kernel=3, padding="SAME", pool=2,
                              act="tanh"),
            ),
            fc_dims=(16,), n_classes=5,
        )
        params = init_cnn(jax.random.PRNGKey(0), topo)
        plan = compile_dhm(
            topo, params,
            quant=QuantSpec(act_bits=8, pow2_weights=True, int8_compute=True,
                            per_layer_bits=(8,)),
            backend="ref",
        )
        feat = jax.eval_shape(
            plan.features,
            jax.ShapeDtypeStruct((1, 12, 12, 2), jnp.float32),
        )
        jaxpr = jax.make_jaxpr(plan.head_fn)(
            jnp.zeros(feat.shape, feat.dtype)
        )
        dots = find_primitive(jaxpr, "dot_general")
        assert dots, "head lost its matmuls"
        for eqn in dots:
            for v in eqn.invars:
                assert jnp.issubdtype(v.aval.dtype, jnp.integer), (
                    f"fp32 operand {v.aval.dtype} survived in the packed head"
                )
            assert eqn.outvars[0].aval.dtype == jnp.int32


class TestBitwidthSearchCompilerKnob:
    def test_search_plan_bitwidths_returns_mixed_plan(self):
        from repro.core.quant.bitwidth_search import search_plan_bitwidths

        topo = ALL_TOPOLOGIES["lenet5"]
        params = init_cnn(jax.random.PRNGKey(0), topo)
        h, w = topo.input_shape
        x = _grid_input(
            jax.random.PRNGKey(3), (2, h, w, topo.input_channels)
        )

        seen = []

        def evaluate(plan):
            seen.append(plan)
            logits = plan(x)
            # a monotone accuracy proxy: wider streams -> higher "score"
            return float(plan.quant.conv_act_bits(0)) / 10.0

        result, final = search_plan_bitwidths(
            topo, params, evaluate,
            float_accuracy=0.8, bit_range=(4, 6, 8), max_drop=0.25,
            int8_compute=True,
        )
        # every candidate was a REAL compiled int8 plan
        assert len(seen) == 3
        for p in seen:
            assert p.quant.int8_compute
            assert plan_elem_bytes(p.quant) == 1
        # the selected width is a compile-time plan attribute
        assert result.selected_bits == 6  # 0.8 - 0.6 <= 0.25, 0.4 too low
        assert final.quant.per_layer_bits == (6,) * len(topo.conv_layers)
        assert final.quant.int8_compute
        logits = final(x)
        assert logits.shape == (2, topo.n_classes)

    def test_int8_sweep_rejects_wide_bits(self):
        from repro.core.quant.bitwidth_search import search_plan_bitwidths

        topo = ALL_TOPOLOGIES["lenet5"]
        params = init_cnn(jax.random.PRNGKey(0), topo)
        with pytest.raises(ValueError, match="<= 8"):
            search_plan_bitwidths(
                topo, params, lambda p: 1.0,
                float_accuracy=1.0, bit_range=(12, 16), int8_compute=True,
            )


class TestEngineInt8:
    def test_engine_serves_int8_plan_and_degrades(self):
        """The serving engine's degradation rungs (per_layer, ref) rebuild
        int8 stage bodies through stage_quant_kwargs — logits stay exact
        across rungs."""
        from repro.core.dhm.engine import Engine

        topo = ALL_TOPOLOGIES["lenet5"]
        params = init_cnn(jax.random.PRNGKey(0), topo)
        plan = compile_dhm(
            topo, params,
            quant=QuantSpec(weight_bits=BITS, act_bits=BITS,
                            int8_compute=True),
        )
        h, w = topo.input_shape
        x = _grid_input(
            jax.random.PRNGKey(9), (2, h, w, topo.input_channels)
        )
        want = np.asarray(plan(x))
        eng = Engine(plan, warmup=False)
        fused = eng._ladder[[n for n, _ in eng._ladder].index("fused")][1]()
        per_layer = eng._ladder[
            [n for n, _ in eng._ladder].index("per_layer")
        ][1]()
        ref = eng._ladder[[n for n, _ in eng._ladder].index("ref")][1]()
        np.testing.assert_array_equal(np.asarray(fused(x)), want)
        np.testing.assert_array_equal(np.asarray(per_layer(x)), want)
        np.testing.assert_array_equal(np.asarray(ref(x)), want)


def test_dynamic_spec_matches_fake_quant_scale():
    """dynamic_spec's static pow2 scale reproduces fake_quant_dynamic's
    in-graph scale — including the exact-pow2 max-abs corner."""
    for seed in range(4):
        w = jax.random.normal(jax.random.PRNGKey(seed), (7, 11))
        spec = dynamic_spec(w, 8)
        np.testing.assert_array_equal(
            np.asarray(quantize_fixed(w, spec), np.float32) * spec.scale,
            np.asarray(fake_quant_dynamic(w, 8)),
        )
    # exact power-of-two max abs: the ceil must not tip up an extra bit
    w = jnp.array([0.5, -0.25, 0.125])
    spec = dynamic_spec(w, 6)
    np.testing.assert_array_equal(
        np.asarray(quantize_fixed(w, spec), np.float32) * spec.scale,
        np.asarray(fake_quant_dynamic(w, 6)),
    )


def test_int8_scales_is_static_and_hashable():
    sc = Int8Scales(in_bits=8, w_scale=0.0078125)
    assert hash(sc) == hash(Int8Scales(in_bits=8, w_scale=0.0078125))
    assert isinstance(sc.in_spec, FixedPointSpec)
    assert sc.deq_scale == sc.in_spec.scale * sc.w_scale
