"""Per-kernel allclose tests vs the pure-jnp oracles, with hypothesis
shape/dtype sweeps (interpret mode executes the kernel bodies on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.quant.pow2 import project_pow2
from repro.kernels.pow2_matmul import pow2_matmul, pow2_matmul_ref, quantize_weights
from repro.kernels.ssm_scan import ssm_scan, ssm_scan_ref
from repro.kernels.stream_conv import stream_conv2d, stream_conv2d_ref


class TestPow2Matmul:
    def _mk(self, m, k, n, seed=0, dtype=jnp.float32):
        kx, kw = jax.random.split(jax.random.PRNGKey(seed))
        x = jax.random.normal(kx, (m, k), dtype)
        w = jax.random.normal(kw, (k, n), jnp.float32)
        packed, scale = quantize_weights(w)
        return x, w, packed, scale

    def test_matches_ref_aligned(self):
        x, _, packed, scale = self._mk(128, 128, 128)
        out = pow2_matmul(x, packed, scale)
        ref = pow2_matmul_ref(x, packed, scale)
        np.testing.assert_allclose(out, ref, rtol=1e-5, atol=1e-5)

    def test_matches_ref_ragged(self):
        """Non-block-aligned shapes go through the padding path."""
        x, _, packed, scale = self._mk(37, 53, 66)
        out = pow2_matmul(x, packed, scale, block_m=32, block_n=32, block_k=32)
        ref = pow2_matmul_ref(x, packed, scale)
        np.testing.assert_allclose(out, ref, rtol=1e-5, atol=1e-5)

    def test_matches_projected_dense_matmul(self):
        """Kernel semantics == x @ project_pow2(w): the quantized network the
        paper synthesizes is exactly the one the kernel computes."""
        x, w, packed, scale = self._mk(16, 64, 32)
        out = pow2_matmul(x, packed, scale, block_m=16, block_n=16, block_k=16)
        dense = x @ project_pow2(w, channel_axis=1)
        np.testing.assert_allclose(out, dense, rtol=1e-4, atol=1e-4)

    def test_bf16_activations(self):
        x, _, packed, scale = self._mk(32, 64, 32, dtype=jnp.bfloat16)
        out = pow2_matmul(x, packed, scale, block_m=32, block_n=32, block_k=32)
        ref = pow2_matmul_ref(x, packed, scale)
        rel = float(
            jnp.linalg.norm(out.astype(jnp.float32) - ref) / jnp.linalg.norm(ref)
        )
        assert rel < 5e-3  # bf16 mantissa

    def test_bf16_output_dtype(self):
        x, _, packed, scale = self._mk(32, 32, 32)
        out = pow2_matmul(
            x, packed, scale, block_m=32, block_n=32, block_k=32,
            out_dtype=jnp.bfloat16,
        )
        assert out.dtype == jnp.bfloat16

    def test_zero_codes_exact(self):
        """All-zero weights -> exactly zero output (the 'removed multiplier'
        case -- also proves zero-padding correctness)."""
        x = jax.random.normal(jax.random.PRNGKey(0), (8, 16))
        w = jnp.zeros((16, 8))
        packed, scale = quantize_weights(w)
        out = pow2_matmul(x, packed, scale, block_m=8, block_n=8, block_k=8)
        assert np.array_equal(np.asarray(out), np.zeros((8, 8), np.float32))

    def test_weight_bandwidth_is_quarter(self):
        """Packed weights are 4 bits/element = 4x less than bf16."""
        w = jnp.zeros((256, 256))
        packed, scale = quantize_weights(w)
        packed_bytes = packed.size  # uint8, two codes per byte
        bf16_bytes = w.size * 2
        assert packed_bytes * 4 == bf16_bytes

    @given(
        m=st.integers(1, 70),
        k=st.integers(1, 70),
        n_half=st.integers(1, 35),
        seed=st.integers(0, 1000),
    )
    @settings(max_examples=15, deadline=None)
    def test_property_shape_sweep(self, m, k, n_half, seed):
        n = 2 * n_half
        x, _, packed, scale = self._mk(m, k, n, seed=seed)
        out = pow2_matmul(x, packed, scale, block_m=32, block_n=32, block_k=32)
        ref = pow2_matmul_ref(x, packed, scale)
        assert out.shape == (m, n)
        np.testing.assert_allclose(out, ref, rtol=1e-4, atol=1e-4)


class TestStreamConv:
    def _mk(self, b, h, w, c, n, k, seed=0, dtype=jnp.float32):
        kx, kw = jax.random.split(jax.random.PRNGKey(seed))
        x = jax.random.normal(kx, (b, h, w, c), dtype)
        wt = jax.random.normal(kw, (k, k, c, n), jnp.float32) * 0.2
        return x, wt

    @pytest.mark.parametrize("k", [1, 3, 5])
    def test_matches_ref_valid(self, k):
        x, w = self._mk(2, 14, 14, 3, 8, k)
        out = stream_conv2d(x, w, padding="VALID")
        ref = stream_conv2d_ref(x, w)
        np.testing.assert_allclose(out, ref, rtol=1e-4, atol=1e-5)

    def test_matches_ref_same(self):
        x, w = self._mk(2, 16, 16, 4, 8, 5)
        out = stream_conv2d(x, w, padding="SAME")
        ref = jax.lax.conv_general_dilated(
            x, w, (1, 1), "SAME", dimension_numbers=("NHWC", "HWIO", "NHWC")
        )
        np.testing.assert_allclose(out, ref, rtol=1e-4, atol=1e-5)

    def test_lenet_conv1_shape(self):
        """The paper's LeNet5 conv1: 28x28x1 -> 24x24x20, K=5."""
        x, w = self._mk(1, 28, 28, 1, 20, 5)
        out = stream_conv2d(x, w, padding="VALID")
        assert out.shape == (1, 24, 24, 20)

    def test_bf16(self):
        x, w = self._mk(1, 10, 10, 2, 4, 3, dtype=jnp.bfloat16)
        out = stream_conv2d(x, w, padding="VALID")
        ref = stream_conv2d_ref(x, w)
        rel = float(
            jnp.linalg.norm(out.astype(jnp.float32) - ref)
            / (jnp.linalg.norm(ref) + 1e-9)
        )
        assert rel < 1e-2

    @given(
        b=st.integers(1, 3),
        h=st.integers(6, 20),
        c=st.integers(1, 5),
        n=st.integers(1, 8),
        k=st.sampled_from([1, 3, 5]),
        seed=st.integers(0, 1000),
    )
    @settings(max_examples=15, deadline=None)
    def test_property_shape_sweep(self, b, h, c, n, k, seed):
        if h < k:
            h = k + 1
        x, w = self._mk(b, h, h, c, n, k, seed=seed)
        out = stream_conv2d(x, w, padding="VALID")
        ref = stream_conv2d_ref(x, w)
        assert out.shape == ref.shape
        np.testing.assert_allclose(out, ref, rtol=1e-4, atol=1e-4)


class TestSSMScan:
    def _mk(self, bz, s, d, n, seed=0):
        ks = jax.random.split(jax.random.PRNGKey(seed), 5)
        x = jax.random.normal(ks[0], (bz, s, d)) * 0.5
        dt = jax.nn.softplus(jax.random.normal(ks[1], (bz, s, d)))
        b = jax.random.normal(ks[2], (bz, s, n))
        c = jax.random.normal(ks[3], (bz, s, n))
        a = -jnp.exp(jax.random.normal(ks[4], (d, n)) * 0.3)
        d_skip = jnp.ones((d,))
        return x, dt, b, c, a, d_skip

    def test_matches_ref(self):
        args = self._mk(2, 24, 16, 4)
        out = ssm_scan(*args, block_d=8)
        ref = ssm_scan_ref(*args)
        np.testing.assert_allclose(out, ref, atol=1e-5)

    def test_matches_model_recurrence(self):
        """Kernel == the chunked_linear_recurrence path used by the model
        (the y the falcon-mamba layer computes)."""
        from repro.models.ssm import chunked_linear_recurrence

        x, dt, b, c, a, d_skip = self._mk(2, 17, 8, 4, seed=3)
        out = ssm_scan(x, dt, b, c, a, d_skip, block_d=8)
        dta = jnp.exp(dt[..., None] * a[None, None])
        bx = (dt * x)[..., None] * b[:, :, None, :]
        h_all, _ = chunked_linear_recurrence(
            dta, bx, jnp.zeros((2, 8, 4)), chunk=8
        )
        y = jnp.einsum("bsdn,bsn->bsd", h_all, c) + x * d_skip
        np.testing.assert_allclose(out, np.asarray(y), atol=1e-4)

    def test_state_never_in_output_path(self):
        """HBM IO is only x/dt/B/C in and y out: output must not depend on
        block_d tiling (the VMEM state is internal)."""
        args = self._mk(1, 12, 16, 2, seed=5)
        o1 = ssm_scan(*args, block_d=16)
        o2 = ssm_scan(*args, block_d=4)
        np.testing.assert_allclose(o1, o2, atol=1e-6)

    @given(
        bz=st.integers(1, 2),
        s=st.integers(2, 20),
        d=st.sampled_from([4, 8, 16]),
        n=st.sampled_from([1, 2, 4]),
        seed=st.integers(0, 500),
    )
    @settings(max_examples=10, deadline=None)
    def test_property_shape_sweep(self, bz, s, d, n, seed):
        args = self._mk(bz, s, d, n, seed=seed)
        out = ssm_scan(*args, block_d=4)
        ref = ssm_scan_ref(*args)
        assert out.shape == (bz, s, d)
        np.testing.assert_allclose(out, ref, atol=1e-4)
