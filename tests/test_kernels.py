"""Per-kernel allclose tests vs the pure-jnp oracles, with seeded
parametrized shape sweeps (no hypothesis dependency — the suite must
collect on a clean machine). ``pallas_interpret`` executes the kernel
bodies through the Pallas interpreter and is the correctness oracle; the
default ``pallas`` backend is the compiled path (XLA-lowered on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.quant.pow2 import project_pow2
from repro.kernels.backends import VALID_BACKENDS
from repro.kernels.pow2_matmul import pow2_matmul, pow2_matmul_ref, quantize_weights
from repro.kernels.ssm_scan import ssm_scan, ssm_scan_ref
from repro.kernels.stream_conv import (
    stream_conv2d,
    stream_conv2d_pallas_seed,
    stream_conv2d_ref,
    stream_conv_block,
    stream_conv_block_ref,
)


# The ONE jaxpr-walking helper, shared with the static-analysis engine
# (tests and the `repro.analysis` CLI can never drift apart).
from repro.analysis.jaxpr_utils import count_primitive as _count_primitive


class TestPow2Matmul:
    def _mk(self, m, k, n, seed=0, dtype=jnp.float32):
        kx, kw = jax.random.split(jax.random.PRNGKey(seed))
        x = jax.random.normal(kx, (m, k), dtype)
        w = jax.random.normal(kw, (k, n), jnp.float32)
        packed, scale = quantize_weights(w)
        return x, w, packed, scale

    def test_matches_ref_aligned(self):
        x, _, packed, scale = self._mk(128, 128, 128)
        out = pow2_matmul(x, packed, scale, backend="pallas_interpret")
        ref = pow2_matmul_ref(x, packed, scale)
        np.testing.assert_allclose(out, ref, rtol=1e-5, atol=1e-5)

    def test_matches_ref_ragged(self):
        """Non-block-aligned shapes go through the padding path."""
        x, _, packed, scale = self._mk(37, 53, 66)
        out = pow2_matmul(x, packed, scale, block_m=32, block_n=32, block_k=32,
                          backend="pallas_interpret")
        ref = pow2_matmul_ref(x, packed, scale)
        np.testing.assert_allclose(out, ref, rtol=1e-5, atol=1e-5)

    @pytest.mark.parametrize("m,k,n", [(1, 1, 2), (3, 5, 2), (7, 13, 6),
                                       (129, 127, 130)])
    def test_matches_ref_odd_shapes(self, m, k, n):
        """Odd / prime / off-by-one M,K,N: the ops wrapper pads to block
        multiples (the kernel's 'pad in ops.pow2_matmul' contract) and
        slices the result back."""
        x, _, packed, scale = self._mk(m, k, n, seed=m * k * n)
        out = pow2_matmul(x, packed, scale, block_m=32, block_n=32, block_k=32,
                          backend="pallas_interpret")
        ref = pow2_matmul_ref(x, packed, scale)
        assert out.shape == (m, n)
        np.testing.assert_allclose(out, ref, rtol=1e-4, atol=1e-4)

    def test_kernel_rejects_unpadded(self):
        """The raw kernel itself refuses non-divisible shapes, pointing at
        the wrapper that pads."""
        from repro.kernels.pow2_matmul.pow2 import pow2_matmul_pallas

        x, _, packed, scale = self._mk(33, 32, 32)
        with pytest.raises(ValueError, match="pad in ops.pow2_matmul"):
            pow2_matmul_pallas(x, packed, scale, block_m=32, block_n=32,
                               block_k=32, interpret=True)

    def test_unknown_backend_raises(self):
        x, _, packed, scale = self._mk(8, 8, 8)
        with pytest.raises(ValueError, match="unknown backend"):
            pow2_matmul(x, packed, scale, backend="palas_interpret")

    def test_matches_projected_dense_matmul(self):
        """Kernel semantics == x @ project_pow2(w): the quantized network the
        paper synthesizes is exactly the one the kernel computes."""
        x, w, packed, scale = self._mk(16, 64, 32)
        out = pow2_matmul(x, packed, scale, block_m=16, block_n=16, block_k=16,
                          backend="pallas_interpret")
        dense = x @ project_pow2(w, channel_axis=1)
        np.testing.assert_allclose(out, dense, rtol=1e-4, atol=1e-4)

    def test_bf16_activations(self):
        x, _, packed, scale = self._mk(32, 64, 32, dtype=jnp.bfloat16)
        out = pow2_matmul(x, packed, scale, block_m=32, block_n=32, block_k=32,
                          backend="pallas_interpret")
        ref = pow2_matmul_ref(x, packed, scale)
        rel = float(
            jnp.linalg.norm(out.astype(jnp.float32) - ref) / jnp.linalg.norm(ref)
        )
        assert rel < 5e-3  # bf16 mantissa

    def test_bf16_output_dtype(self):
        x, _, packed, scale = self._mk(32, 32, 32)
        out = pow2_matmul(
            x, packed, scale, block_m=32, block_n=32, block_k=32,
            out_dtype=jnp.bfloat16, backend="pallas_interpret",
        )
        assert out.dtype == jnp.bfloat16

    def test_zero_codes_exact(self):
        """All-zero weights -> exactly zero output (the 'removed multiplier'
        case -- also proves zero-padding correctness)."""
        x = jax.random.normal(jax.random.PRNGKey(0), (8, 16))
        w = jnp.zeros((16, 8))
        packed, scale = quantize_weights(w)
        out = pow2_matmul(x, packed, scale, block_m=8, block_n=8, block_k=8,
                          backend="pallas_interpret")
        assert np.array_equal(np.asarray(out), np.zeros((8, 8), np.float32))

    def test_weight_bandwidth_is_quarter(self):
        """Packed weights are 4 bits/element = 4x less than bf16."""
        w = jnp.zeros((256, 256))
        packed, scale = quantize_weights(w)
        packed_bytes = packed.size  # uint8, two codes per byte
        bf16_bytes = w.size * 2
        assert packed_bytes * 4 == bf16_bytes

    def test_compiled_default_matches_oracle(self):
        """The default (compiled) backend agrees with the interpret oracle."""
        x, _, packed, scale = self._mk(24, 40, 16, seed=11)
        out = pow2_matmul(x, packed, scale, block_m=16, block_n=16, block_k=16)
        oracle = pow2_matmul(x, packed, scale, block_m=16, block_n=16,
                             block_k=16, backend="pallas_interpret")
        np.testing.assert_allclose(out, oracle, rtol=1e-5, atol=1e-5)

    @pytest.mark.parametrize(
        "m,k,n_half,seed",
        [(1, 1, 1, 0), (5, 9, 3, 1), (17, 33, 9, 2), (64, 32, 16, 3),
         (70, 70, 35, 4), (2, 64, 32, 5), (31, 2, 5, 6), (48, 17, 20, 7)],
    )
    def test_shape_sweep(self, m, k, n_half, seed):
        n = 2 * n_half
        x, _, packed, scale = self._mk(m, k, n, seed=seed)
        out = pow2_matmul(x, packed, scale, block_m=32, block_n=32, block_k=32,
                          backend="pallas_interpret")
        ref = pow2_matmul_ref(x, packed, scale)
        assert out.shape == (m, n)
        np.testing.assert_allclose(out, ref, rtol=1e-4, atol=1e-4)


class TestStreamConv:
    def _mk(self, b, h, w, c, n, k, seed=0, dtype=jnp.float32):
        kx, kw = jax.random.split(jax.random.PRNGKey(seed))
        x = jax.random.normal(kx, (b, h, w, c), dtype)
        wt = jax.random.normal(kw, (k, k, c, n), jnp.float32) * 0.2
        return x, wt

    @pytest.mark.parametrize("k", [1, 3, 5])
    @pytest.mark.parametrize("backend", ["pallas", "pallas_interpret"])
    def test_matches_ref_valid(self, k, backend):
        x, w = self._mk(2, 14, 14, 3, 8, k)
        out = stream_conv2d(x, w, padding="VALID", backend=backend)
        ref = stream_conv2d_ref(x, w)
        np.testing.assert_allclose(out, ref, rtol=1e-4, atol=1e-5)

    @pytest.mark.parametrize("backend", ["pallas", "pallas_interpret"])
    def test_matches_ref_same(self, backend):
        x, w = self._mk(2, 16, 16, 4, 8, 5)
        out = stream_conv2d(x, w, padding="SAME", backend=backend)
        ref = jax.lax.conv_general_dilated(
            x, w, (1, 1), "SAME", dimension_numbers=("NHWC", "HWIO", "NHWC")
        )
        np.testing.assert_allclose(out, ref, rtol=1e-4, atol=1e-5)

    @pytest.mark.parametrize("k", [2, 4])
    @pytest.mark.parametrize("backend", ["pallas", "pallas_interpret"])
    def test_even_kernel_same_matches_xla_convention(self, k, backend):
        """Even K: host-side SAME padding must follow XLA's low=(k-1)//2,
        high=k//2 split — a regression here shows up as a one-pixel shift
        between backends."""
        x, w = self._mk(1, 9, 9, 2, 3, k, seed=k)
        out = stream_conv2d(x, w, padding="SAME", backend=backend)
        ref = jax.lax.conv_general_dilated(
            x, w, (1, 1), "SAME", dimension_numbers=("NHWC", "HWIO", "NHWC")
        )
        assert out.shape == ref.shape
        np.testing.assert_allclose(out, ref, rtol=1e-4, atol=1e-5)

    def test_lenet_conv1_shape(self):
        """The paper's LeNet5 conv1: 28x28x1 -> 24x24x20, K=5."""
        x, w = self._mk(1, 28, 28, 1, 20, 5)
        out = stream_conv2d(x, w, padding="VALID")
        assert out.shape == (1, 24, 24, 20)

    def test_bf16(self):
        x, w = self._mk(1, 10, 10, 2, 4, 3, dtype=jnp.bfloat16)
        out = stream_conv2d(x, w, padding="VALID", backend="pallas_interpret")
        ref = stream_conv2d_ref(x, w)
        rel = float(
            jnp.linalg.norm(out.astype(jnp.float32) - ref)
            / (jnp.linalg.norm(ref) + 1e-9)
        )
        assert rel < 1e-2

    def test_unknown_backend_raises(self):
        x, w = self._mk(1, 8, 8, 2, 4, 3)
        with pytest.raises(ValueError, match="unknown backend"):
            stream_conv2d(x, w, backend="palas_interpret")
        with pytest.raises(ValueError, match="unknown backend"):
            stream_conv_block(x, w, jnp.zeros((4,)), backend="mosaic")

    def test_backend_enum_is_closed(self):
        assert set(VALID_BACKENDS) == {"pallas", "pallas_interpret", "ref"}

    def test_seed_kernel_still_matches(self):
        """The archived seed kernel (benchmark baseline) stays correct."""
        x, w = self._mk(2, 12, 12, 3, 6, 3, seed=4)
        out = stream_conv2d_pallas_seed(x, w.reshape(9, 3, 6), k=3)
        np.testing.assert_allclose(
            out, stream_conv2d_ref(x, w), rtol=1e-4, atol=1e-5
        )

    @pytest.mark.parametrize(
        "b,h,c,n,k,seed",
        [(1, 6, 1, 1, 1, 0), (1, 7, 2, 3, 3, 1), (2, 9, 3, 5, 5, 2),
         (3, 20, 5, 8, 3, 3), (1, 12, 4, 7, 5, 4), (2, 16, 1, 2, 5, 5),
         (1, 6, 5, 4, 5, 6), (2, 11, 2, 6, 3, 7)],
    )
    def test_shape_sweep(self, b, h, c, n, k, seed):
        if h < k:
            h = k + 1
        x, w = self._mk(b, h, h, c, n, k, seed=seed)
        out = stream_conv2d(x, w, padding="VALID", backend="pallas_interpret")
        ref = stream_conv2d_ref(x, w)
        assert out.shape == ref.shape
        np.testing.assert_allclose(out, ref, rtol=1e-4, atol=1e-4)


class TestStreamConvFused:
    """The fused conv -> bias -> act -> pool path vs the unfused reference
    composition, across kernel sizes, paddings, backends and block shapes."""

    def _mk(self, b, h, w, c, n, k, seed=0):
        kx, kw, kb = jax.random.split(jax.random.PRNGKey(seed), 3)
        x = jax.random.normal(kx, (b, h, w, c))
        wt = jax.random.normal(kw, (k, k, c, n)) * 0.2
        bias = jax.random.normal(kb, (n,)) * 0.1
        return x, wt, bias

    @pytest.mark.parametrize("k", [3, 5])
    @pytest.mark.parametrize("padding", ["VALID", "SAME"])
    @pytest.mark.parametrize("backend", ["pallas", "pallas_interpret"])
    def test_fused_matches_unfused(self, k, padding, backend):
        x, w, b = self._mk(2, 14, 14, 3, 8, k, seed=k)
        out = stream_conv_block(
            x, w, b, padding=padding, act="relu", pool=2, backend=backend
        )
        ref = stream_conv_block_ref(x, w, b, padding=padding, act="relu", pool=2)
        assert out.shape == ref.shape
        np.testing.assert_allclose(out, ref, rtol=1e-4, atol=1e-5)

    @pytest.mark.parametrize("act", ["none", "relu", "tanh"])
    @pytest.mark.parametrize("pool", [0, 2])
    def test_epilogue_combinations(self, act, pool):
        x, w, b = self._mk(1, 11, 11, 4, 6, 3, seed=9)
        out = stream_conv_block(
            x, w, b, padding="VALID", act=act, pool=pool,
            backend="pallas_interpret",
        )
        ref = stream_conv_block_ref(x, w, b, padding="VALID", act=act, pool=pool)
        np.testing.assert_allclose(out, ref, rtol=1e-4, atol=1e-5)

    @pytest.mark.parametrize("block_c,block_n,block_r", [
        (2, 12, 4),   # C=3 not a multiple of 2, N=32 not a multiple of 12
        (3, 32, 8),   # exact blocks
        (1, 5, 2),    # degenerate channel blocks, ragged feature blocks
    ])
    def test_channel_feature_blocking(self, block_c, block_n, block_r):
        """CIFAR-sized layer with non-multiple-of-block channel/feature
        counts: host-side zero padding keeps the result exact."""
        x, w, b = self._mk(1, 32, 32, 3, 32, 5, seed=5)
        out = stream_conv_block(
            x, w, b, padding="SAME", act="relu", pool=2,
            backend="pallas_interpret",
            block_c=block_c, block_n=block_n, block_r=block_r,
        )
        ref = stream_conv_block_ref(x, w, b, padding="SAME", act="relu", pool=2)
        assert out.shape == ref.shape
        np.testing.assert_allclose(out, ref, rtol=1e-4, atol=1e-5)

    def test_interpret_vs_compiled_agree(self):
        """The interpret oracle and the compiled default produce the same
        numbers for the fused path."""
        x, w, b = self._mk(2, 16, 16, 5, 9, 5, seed=3)
        compiled = stream_conv_block(x, w, b, padding="SAME", act="relu", pool=2)
        oracle = stream_conv_block(
            x, w, b, padding="SAME", act="relu", pool=2,
            backend="pallas_interpret",
        )
        np.testing.assert_allclose(compiled, oracle, rtol=1e-5, atol=1e-6)

    def test_odd_spatial_dims(self):
        """Odd H/W: pooling floors, row blocks are padded and sliced."""
        x, w, b = self._mk(1, 13, 13, 2, 4, 3, seed=8)
        out = stream_conv_block(
            x, w, b, padding="VALID", act="relu", pool=2,
            backend="pallas_interpret",
        )
        ref = stream_conv_block_ref(x, w, b, padding="VALID", act="relu", pool=2)
        assert out.shape == ref.shape == (1, 5, 5, 4)
        np.testing.assert_allclose(out, ref, rtol=1e-4, atol=1e-5)


class TestStreamConvGeneralizedProperty:
    """Randomized (seeded — the suite must stay deterministic) property
    test over the generalized layer vocabulary: conv stride ∈ {1, 2},
    pool ∈ {none, 2x2/2, 3x3/2}, odd/even H != W, and block_w column
    splits. All three backends — the Pallas-interpreter oracle, the ref
    composition, and the compiled default (the XLA fallback on CPU) —
    must agree BIT-EXACTLY with the epilogue quantization on: the
    in-kernel round/clip collapses accumulation-order noise onto the same
    fixed-point lattice on every backend."""

    # none, classic window==stride, overlapping 3x3/2, and the
    # window < stride sub-sampling case the contract also covers.
    POOLS = ((0, None), (2, None), (3, 2), (2, 3))

    @pytest.mark.parametrize("seed", range(12))
    def test_backends_agree_bit_exact(self, seed):
        rng = np.random.default_rng(seed)
        stride = int(rng.choice([1, 2]))
        pool, pool_stride = self.POOLS[int(rng.integers(len(self.POOLS)))]
        k = int(rng.choice([3, 5]))
        padding = ["VALID", "SAME"][int(rng.integers(2))]
        # Sizes guaranteeing conv output >= 4 in both dims (>= any pool
        # window), with independent odd/even H and W.
        base = k + 3 * stride if padding == "VALID" else 4 * stride
        h = base + int(rng.integers(0, 7))
        w = base + int(rng.integers(0, 7))
        c = int(rng.integers(1, 4))
        n = int(rng.integers(1, 7))
        block_w = int(rng.choice([0, 3, 5]))
        block_r = int(rng.choice([2, 4, 8]))
        x = jnp.asarray(rng.normal(size=(2, h, w, c)), jnp.float32)
        wt = jnp.asarray(rng.normal(size=(k, k, c, n)) * 0.2, jnp.float32)
        b = jnp.asarray(rng.normal(size=(n,)) * 0.1, jnp.float32)
        kw = dict(
            padding=padding, stride=stride, act="relu", pool=pool,
            pool_stride=pool_stride, act_bits=5,
        )
        outs = {
            backend: np.asarray(
                stream_conv_block(
                    x, wt, b, backend=backend, block_r=block_r,
                    block_w=block_w, **kw,
                )
            )
            for backend in ("pallas_interpret", "ref", "pallas")
        }
        case = (
            f"seed={seed} k={k} s={stride} pool={pool}/{pool_stride} "
            f"{padding} {h}x{w}x{c}->{n} block_r={block_r} block_w={block_w}"
        )
        assert outs["ref"].shape == outs["pallas_interpret"].shape, case
        np.testing.assert_array_equal(
            outs["pallas_interpret"], outs["ref"], err_msg=case
        )
        np.testing.assert_array_equal(
            outs["pallas_interpret"], outs["pallas"], err_msg=case
        )

    def test_xla_fallback_path_directly(self):
        """The XLA fallback entry point itself (not just via the wrapper
        dispatch) handles stride + overlapping pool + quantization."""
        from repro.kernels.stream_conv.xla import stream_conv_fused_xla

        rng = np.random.default_rng(99)
        x = jnp.asarray(rng.normal(size=(2, 13, 17, 3)), jnp.float32)
        wt = jnp.asarray(rng.normal(size=(3, 3, 3, 5)) * 0.2, jnp.float32)
        b = jnp.asarray(rng.normal(size=(5,)) * 0.1, jnp.float32)
        out = stream_conv_fused_xla(
            x, wt.reshape(9, 3, 5), b, k=3, stride=2, act="relu", pool=3,
            pool_stride=2, act_bits=5,
        )
        ref = stream_conv_block_ref(
            x, wt, b, padding="VALID", stride=2, act="relu", pool=3,
            pool_stride=2, act_bits=5,
        )
        np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))

    def test_strided_conv2d_matches_lax(self):
        """Bare strided conv (no epilogue) vs lax.conv, SAME and VALID."""
        rng = np.random.default_rng(7)
        x = jnp.asarray(rng.normal(size=(1, 11, 15, 2)), jnp.float32)
        wt = jnp.asarray(rng.normal(size=(5, 5, 2, 4)) * 0.2, jnp.float32)
        for padding in ("VALID", "SAME"):
            out = stream_conv2d(
                x, wt, padding=padding, stride=2, backend="pallas_interpret"
            )
            ref = jax.lax.conv_general_dilated(
                x, wt, (2, 2), padding,
                dimension_numbers=("NHWC", "HWIO", "NHWC"),
            )
            assert out.shape == ref.shape
            np.testing.assert_allclose(out, ref, rtol=1e-4, atol=1e-5)


class TestStreamConvStructure:
    """Structural guarantees of the rewritten kernel: ONE matmul per row
    block, no K^2 per-tap dot loop, no hidden lax.conv."""

    def _jaxpr(self, backend, fused=False):
        x = jnp.ones((1, 32, 32, 3))
        w = jnp.ones((5, 5, 3, 32))
        b = jnp.ones((32,))
        if fused:
            fn = lambda a, ww, bb: stream_conv_block(  # noqa: E731
                a, ww, bb, padding="SAME", act="relu", pool=2, backend=backend
            )
            return jax.make_jaxpr(fn)(x, w, b).jaxpr
        fn = lambda a, ww: stream_conv2d(  # noqa: E731
            a, ww, padding="SAME", backend=backend
        )
        return jax.make_jaxpr(fn)(x, w).jaxpr

    @pytest.mark.parametrize("backend", ["pallas", "pallas_interpret"])
    @pytest.mark.parametrize("fused", [False, True])
    def test_single_matmul_per_row_block(self, backend, fused):
        jaxpr = self._jaxpr(backend, fused=fused)
        assert _count_primitive(jaxpr, "dot_general") == 1
        assert _count_primitive(jaxpr, "conv_general_dilated") == 0

    def test_seed_kernel_had_kk_dots(self):
        """Contrast: the seed kernel issued K*K=25 per-tap dots."""
        x = jnp.ones((1, 32, 32, 3))
        w = jnp.ones((25, 3, 32))
        jaxpr = jax.make_jaxpr(
            lambda a, ww: stream_conv2d_pallas_seed(a, ww, k=5)
        )(x, w).jaxpr
        assert _count_primitive(jaxpr, "dot_general") == 25


class TestSSMScan:
    def _mk(self, bz, s, d, n, seed=0):
        ks = jax.random.split(jax.random.PRNGKey(seed), 5)
        x = jax.random.normal(ks[0], (bz, s, d)) * 0.5
        dt = jax.nn.softplus(jax.random.normal(ks[1], (bz, s, d)))
        b = jax.random.normal(ks[2], (bz, s, n))
        c = jax.random.normal(ks[3], (bz, s, n))
        a = -jnp.exp(jax.random.normal(ks[4], (d, n)) * 0.3)
        d_skip = jnp.ones((d,))
        return x, dt, b, c, a, d_skip

    def test_matches_ref(self):
        args = self._mk(2, 24, 16, 4)
        out = ssm_scan(*args, block_d=8, backend="pallas_interpret")
        ref = ssm_scan_ref(*args)
        np.testing.assert_allclose(out, ref, atol=1e-5)

    def test_unknown_backend_raises(self):
        args = self._mk(1, 4, 4, 2)
        with pytest.raises(ValueError, match="unknown backend"):
            ssm_scan(*args, backend="palas")

    def test_matches_model_recurrence(self):
        """Kernel == the chunked_linear_recurrence path used by the model
        (the y the falcon-mamba layer computes)."""
        from repro.models.ssm import chunked_linear_recurrence

        x, dt, b, c, a, d_skip = self._mk(2, 17, 8, 4, seed=3)
        out = ssm_scan(x, dt, b, c, a, d_skip, block_d=8,
                       backend="pallas_interpret")
        dta = jnp.exp(dt[..., None] * a[None, None])
        bx = (dt * x)[..., None] * b[:, :, None, :]
        h_all, _ = chunked_linear_recurrence(
            dta, bx, jnp.zeros((2, 8, 4)), chunk=8
        )
        y = jnp.einsum("bsdn,bsn->bsd", h_all, c) + x * d_skip
        np.testing.assert_allclose(out, np.asarray(y), atol=1e-4)

    def test_state_never_in_output_path(self):
        """HBM IO is only x/dt/B/C in and y out: output must not depend on
        block_d tiling (the VMEM state is internal)."""
        args = self._mk(1, 12, 16, 2, seed=5)
        o1 = ssm_scan(*args, block_d=16, backend="pallas_interpret")
        o2 = ssm_scan(*args, block_d=4, backend="pallas_interpret")
        np.testing.assert_allclose(o1, o2, atol=1e-6)

    @pytest.mark.parametrize(
        "bz,s,d,n,seed",
        [(1, 2, 4, 1, 0), (2, 7, 8, 2, 1), (1, 20, 16, 4, 2),
         (2, 13, 4, 4, 3), (1, 5, 8, 1, 4), (2, 16, 16, 2, 5)],
    )
    def test_shape_sweep(self, bz, s, d, n, seed):
        args = self._mk(bz, s, d, n, seed=seed)
        out = ssm_scan(*args, block_d=4, backend="pallas_interpret")
        ref = ssm_scan_ref(*args)
        assert out.shape == (bz, s, d)
        np.testing.assert_allclose(out, ref, atol=1e-4)
