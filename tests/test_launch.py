"""Launch-layer tests: sharding rules, spec constraint, HLO analyzer."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import SHAPES, get_arch
from repro.launch.hlo_analysis import analyze_hlo
from repro.launch.sharding import (
    batch_specs,
    cache_specs,
    constrain_spec,
    param_specs,
)


def _mesh11():
    return jax.make_mesh((1, 1), ("data", "model"))


class TestConstrainSpec:
    def test_drops_nondivisible(self):
        mesh = _mesh11()
        # model axis size 1 always divides; fake a bigger mesh via shape math
        mesh16 = jax.sharding.Mesh(
            np.asarray(jax.devices() * 1).reshape(1, 1), ("data", "model")
        )
        spec = constrain_spec(P("model", None), (92553, 2048), mesh16)
        assert spec == P("model", None)  # axis size 1 divides anything

    def test_axis_tuple_prefix(self):
        # batch 16 over ('pod','data') of sizes (2,16): 16 % 32 != 0 but
        # 16 % 2 == 0 -> keep only 'pod'.
        class FakeMesh:
            shape = {"pod": 2, "data": 16}

        spec = constrain_spec(P(("pod", "data"), None), (16, 8), FakeMesh())
        assert spec == P("pod", None)

    def test_full_drop(self):
        class FakeMesh:
            shape = {"data": 16}

        spec = constrain_spec(P("data", None), (1, 8), FakeMesh())
        assert spec == P(None, None)


class TestParamSpecs:
    def test_rules_cover_all_arch_params(self):
        """Every leaf of every arch gets a valid spec (rank-matched)."""
        from repro.models.transformer import init_params

        mesh = _mesh11()
        for name in ("qwen2.5-3b", "recurrentgemma-9b", "qwen3-moe-235b-a22b",
                     "falcon-mamba-7b", "whisper-base"):
            cfg = get_arch(name).scaled_down()
            shapes = jax.eval_shape(
                lambda k, c=cfg: init_params(k, c), jax.random.PRNGKey(0)
            )
            specs = param_specs(shapes, mesh, fsdp=True)
            flat_shapes = jax.tree_util.tree_leaves(shapes)
            flat_specs = jax.tree_util.tree_leaves(
                specs, is_leaf=lambda x: isinstance(x, P)
            )
            assert len(flat_shapes) == len(flat_specs)
            for sh, sp in zip(flat_shapes, flat_specs):
                assert len(sp) <= sh.ndim, (sh.shape, sp)

    def test_attention_rules_hit(self):
        from repro.models.transformer import init_params

        mesh = _mesh11()
        cfg = get_arch("qwen2.5-3b").scaled_down()
        shapes = jax.eval_shape(
            lambda k: init_params(k, cfg), jax.random.PRNGKey(0)
        )
        specs = param_specs(shapes, mesh, fsdp=False)
        unit = specs["stack"]["units"][0]
        # wq: (n_units, d, H*hd) -> last dim model-sharded
        assert unit["attn"]["wq"]["w"][-1] == "model"
        assert unit["attn"]["wo"]["w"][-2] == "model"
        assert unit["ffn"]["down"]["w"][-2] == "model"
        # fsdp off: no 'data' anywhere
        for sp in jax.tree_util.tree_leaves(
            specs, is_leaf=lambda x: isinstance(x, P)
        ):
            for e in sp:
                axes = e if isinstance(e, tuple) else (e,)
                assert "data" not in axes

    def test_moe_expert_parallel(self):
        from repro.models.transformer import init_params

        mesh = _mesh11()
        cfg = get_arch("qwen3-moe-235b-a22b").scaled_down()
        shapes = jax.eval_shape(
            lambda k: init_params(k, cfg), jax.random.PRNGKey(0)
        )
        specs = param_specs(shapes, mesh, fsdp=False)
        unit = specs["stack"]["units"][0]
        # Expert dim (after the stacked n_units dim) on 'model'.
        assert unit["ffn"]["w_gate"][-3] == "model"
        assert unit["ffn"]["w_down"][-3] == "model"


class TestCacheSpecs:
    def test_kv_heads_or_hd_sharding(self):
        from repro.models.transformer import init_stack_cache

        mesh = _mesh11()
        cfg = get_arch("qwen2.5-3b").scaled_down()
        cache = jax.eval_shape(
            lambda: init_stack_cache(cfg, cfg.n_layers, 4, 64)
        )
        specs = cache_specs(cache, mesh, cfg)
        k_spec = specs["units"][0]["k"]
        assert k_spec[-4] == "data" or k_spec[-4] == ("data",)

    def test_ssm_state_sharding(self):
        from repro.models.transformer import init_stack_cache

        mesh = _mesh11()
        cfg = get_arch("falcon-mamba-7b").scaled_down()
        cache = jax.eval_shape(
            lambda: init_stack_cache(cfg, cfg.n_layers, 4, 64)
        )
        specs = cache_specs(cache, mesh, cfg)
        assert specs["units"][0]["ssm"][-2] == "model"


class TestHLOAnalysis:
    def test_scan_trip_count_flops(self):
        def f(x):
            def body(c, _):
                return jnp.tanh(c @ c), None

            out, _ = jax.lax.scan(body, x, None, length=7)
            return out

        comp = jax.jit(f).lower(
            jax.ShapeDtypeStruct((128, 128), jnp.float32)
        ).compile()
        a = analyze_hlo(comp.as_text())
        assert a.flops == pytest.approx(7 * 2 * 128**3, rel=0.01)

    def test_plain_matmul_flops_and_bytes(self):
        comp = jax.jit(lambda a, b: a @ b).lower(
            jax.ShapeDtypeStruct((64, 64), jnp.float32),
            jax.ShapeDtypeStruct((64, 64), jnp.float32),
        ).compile()
        a = analyze_hlo(comp.as_text())
        assert a.flops == pytest.approx(2 * 64**3, rel=0.01)
        assert a.hbm_bytes >= 3 * 64 * 64 * 4  # 2 reads + 1 write

    def test_dus_counts_slice_not_base(self):
        def f(base, upd):
            return jax.lax.dynamic_update_slice(base, upd, (0, 0))

        comp = jax.jit(f, donate_argnums=(0,)).lower(
            jax.ShapeDtypeStruct((4096, 4096), jnp.float32),
            jax.ShapeDtypeStruct((4, 4096), jnp.float32),
        ).compile()
        a = analyze_hlo(comp.as_text())
        # Traffic should be ~2x the update slice, far below the 64MB base.
        assert a.hbm_bytes < 4096 * 4096 * 4 / 2

    def test_nested_scan_multiplies(self):
        def f(x):
            def outer(c, _):
                def inner(ci, _):
                    return ci @ ci, None

                ci, _ = jax.lax.scan(inner, c, None, length=3)
                return ci, None

            out, _ = jax.lax.scan(outer, x, None, length=5)
            return out

        comp = jax.jit(f).lower(
            jax.ShapeDtypeStruct((32, 32), jnp.float32)
        ).compile()
        a = analyze_hlo(comp.as_text())
        assert a.flops == pytest.approx(15 * 2 * 32**3, rel=0.01)
